//! Quickstart: the whole ReCross pipeline in ~60 lines, through the
//! `deploy` facade.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! 1. Describe the workload in a `Config` (Table I's "software").
//! 2. `Deployment::of(cfg).scheme(..).build()` runs the offline phase —
//!    co-occurrence graph → Algorithm 1 grouping → Eq. 1 duplication —
//!    exactly once and hands back a `Prepared` bundle.
//! 3. Online phase: simulate the held-out trace on the crossbar pool and
//!    compare against the naive baseline.
//! 4. If AOT artifacts are present, run one real embedding reduction
//!    through the PJRT runtime and check it against the reference.

use recross::config::Config;
use recross::deploy::Deployment;
use recross::engine::Scheme;
use recross::workload::Query;

fn main() -> anyhow::Result<()> {
    // --- 1. workload -----------------------------------------------------
    let mut cfg = Config::paper_default();
    cfg.workload.history_queries = 2_000;
    cfg.workload.eval_queries = 512;
    const SCALE: f64 = 0.25;

    // --- 2. offline phase (once per scheme) ------------------------------
    let recross = Deployment::of(cfg.clone())
        .scheme(Scheme::ReCross)
        .scale(SCALE)
        .build()?;
    let naive = Deployment::of(cfg.clone())
        .scheme(Scheme::Naive)
        .scale(SCALE)
        .build()?;
    println!(
        "workload: {} embeddings, {} history / {} eval queries, {:.1} lookups/query",
        recross.eval().num_embeddings,
        recross.history().queries.len(),
        recross.eval().queries.len(),
        recross.eval().mean_lookups()
    );
    println!(
        "mapping: {} groups, {} physical crossbars after Eq. 1 duplication",
        recross.engine().mapping().num_groups(),
        recross.engine().physical_crossbars()
    );

    // --- 3. online phase (circuit simulation) -----------------------------
    let bs = cfg.scheme.batch_size;
    let s_re = recross.engine().run_trace(recross.eval(), bs);
    let s_nv = naive.engine().run_trace(naive.eval(), bs);
    println!("\ncircuit simulation over the eval trace:");
    println!(
        "  naive  : {:>10.1} µs, {:>8.1} nJ, {} activations",
        s_nv.completion_ns / 1e3,
        s_nv.energy_pj / 1e3,
        s_nv.activations
    );
    println!(
        "  recross: {:>10.1} µs, {:>8.1} nJ, {} activations ({} in read mode)",
        s_re.completion_ns / 1e3,
        s_re.energy_pj / 1e3,
        s_re.activations,
        s_re.read_activations
    );
    println!(
        "  -> {:.2}x faster, {:.2}x more energy-efficient",
        s_nv.completion_ns / s_re.completion_ns,
        s_nv.energy_pj / s_re.energy_pj
    );

    // --- 4. real numerics through PJRT ------------------------------------
    if recross::runtime::artifacts_available(&cfg.artifacts_dir) {
        let q = Query::new(recross.eval().queries[0].items.clone());
        let mut pipeline = recross.into_pipeline()?;
        let got = pipeline.reduce_query(&q)?;
        let expect = pipeline.store().reduce_reference(&q.items);
        let max_err = got
            .iter()
            .zip(&expect)
            .map(|(g, e)| (g - e).abs())
            .fold(0.0f32, f32::max);
        println!(
            "\nPJRT check: reduced a {}-lookup query through the crossbar artifact, max |err| = {max_err:.2e}",
            q.len()
        );
        assert!(max_err < 1e-3);
    } else {
        println!("\n(artifacts missing — run `make artifacts` to exercise the PJRT path)");
    }
    println!("\nquickstart OK");
    Ok(())
}
