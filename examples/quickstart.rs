//! Quickstart: the whole ReCross pipeline in ~60 lines.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! 1. Generate a synthetic Amazon-like workload (Table I's "software").
//! 2. Offline phase: co-occurrence graph → Algorithm 1 grouping → Eq. 1
//!    duplication.
//! 3. Online phase: simulate a batch on the crossbar pool and compare
//!    against the naive baseline.
//! 4. If AOT artifacts are present, run one real embedding reduction
//!    through the PJRT runtime and check it against the reference.

use recross::config::Config;
use recross::coordinator;
use recross::engine::{Engine, Scheme};
use recross::graph::CoGraph;
use recross::workload::{generate, DatasetSpec, Query};

fn main() -> anyhow::Result<()> {
    // --- 1. workload -----------------------------------------------------
    let mut cfg = Config::paper_default();
    cfg.workload.history_queries = 2_000;
    cfg.workload.eval_queries = 512;
    let spec = DatasetSpec::by_name("software").unwrap().scaled(0.25);
    let (history, eval) = generate(
        &spec,
        cfg.workload.history_queries,
        cfg.workload.eval_queries,
        42,
    );
    println!(
        "workload: {} embeddings, {} history / {} eval queries, {:.1} lookups/query",
        spec.num_embeddings,
        history.queries.len(),
        eval.queries.len(),
        eval.mean_lookups()
    );

    // --- 2. offline phase ------------------------------------------------
    let graph = CoGraph::build(&history);
    println!(
        "co-occurrence graph: {} nodes, {} edges",
        graph.num_nodes(),
        graph.num_edges()
    );
    let recross = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
    let naive = Engine::prepare(Scheme::Naive, &graph, &history, &cfg);
    println!(
        "mapping: {} groups, {} physical crossbars after Eq. 1 duplication",
        recross.mapping().num_groups(),
        recross.physical_crossbars()
    );

    // --- 3. online phase (circuit simulation) -----------------------------
    let s_re = recross.run_trace(&eval, cfg.scheme.batch_size);
    let s_nv = naive.run_trace(&eval, cfg.scheme.batch_size);
    println!("\ncircuit simulation over the eval trace:");
    println!(
        "  naive  : {:>10.1} µs, {:>8.1} nJ, {} activations",
        s_nv.completion_ns / 1e3,
        s_nv.energy_pj / 1e3,
        s_nv.activations
    );
    println!(
        "  recross: {:>10.1} µs, {:>8.1} nJ, {} activations ({} in read mode)",
        s_re.completion_ns / 1e3,
        s_re.energy_pj / 1e3,
        s_re.activations,
        s_re.read_activations
    );
    println!(
        "  -> {:.2}x faster, {:.2}x more energy-efficient",
        s_nv.completion_ns / s_re.completion_ns,
        s_nv.energy_pj / s_re.energy_pj
    );

    // --- 4. real numerics through PJRT ------------------------------------
    if recross::runtime::artifacts_available(&cfg.artifacts_dir) {
        let mut pipeline = coordinator::build_pipeline(&cfg, Scheme::ReCross, 0.25)?;
        let q = Query::new(eval.queries[0].items.clone());
        let got = pipeline.reduce_query(&q)?;
        let expect = pipeline.store().reduce_reference(&q.items);
        let max_err = got
            .iter()
            .zip(&expect)
            .map(|(g, e)| (g - e).abs())
            .fold(0.0f32, f32::max);
        println!(
            "\nPJRT check: reduced a {}-lookup query through the crossbar artifact, max |err| = {max_err:.2e}",
            q.len()
        );
        assert!(max_err < 1e-3);
    } else {
        println!("\n(artifacts missing — run `make artifacts` to exercise the PJRT path)");
    }
    println!("\nquickstart OK");
    Ok(())
}
