//! Trace analysis: the paper's §II-C characterisation study, reproduced on
//! the synthetic workloads — access-frequency and co-occurrence power laws
//! (Fig. 2), plus offline→online generalisation checks that justify the
//! history-driven mapping.
//!
//! ```bash
//! cargo run --release --example trace_analysis
//! ```

use recross::graph::CoGraph;
use recross::grouping::{CorrelationMapper, Mapper};
use recross::metrics::{fit_power_law, gini, Histogram};
use recross::workload::{access_frequencies, generate, DatasetSpec};

fn main() {
    println!("=== workload characterisation (paper §II-C) ===\n");
    for name in DatasetSpec::names() {
        let spec = DatasetSpec::by_name(name).unwrap().scaled(0.1);
        let (history, eval) = generate(&spec, 4_000, 1_024, 42);
        let graph = CoGraph::build(&history);

        // Access-frequency power law.
        let freq = access_frequencies(&history);
        let f_fit = fit_power_law(&freq).expect("freq fit");
        // Co-occurrence-degree power law (Fig. 2's y-axis).
        let deg = graph.degrees();
        let d_fit = fit_power_law(&deg).expect("degree fit");

        println!("--- {name} ({} embeddings, {} edges) ---", graph.num_nodes(), graph.num_edges());
        println!(
            "  access freq:   alpha={:.2}  R^2={:.3}  power-law={}",
            f_fit.alpha,
            f_fit.r_squared,
            f_fit.is_power_law()
        );
        println!(
            "  co-occurrence: alpha={:.2}  R^2={:.3}  power-law={}",
            d_fit.alpha,
            d_fit.r_squared,
            d_fit.is_power_law()
        );

        // Hot-set generalisation: does history predict eval?
        let h_freq = access_frequencies(&history);
        let e_freq = access_frequencies(&eval);
        let top = |f: &[u64], k: usize| {
            let mut idx: Vec<usize> = (0..f.len()).collect();
            idx.sort_by_key(|&i| std::cmp::Reverse(f[i]));
            idx[..k].iter().copied().collect::<std::collections::HashSet<_>>()
        };
        let k = 500.min(h_freq.len());
        let overlap = top(&h_freq, k).intersection(&top(&e_freq, k)).count();
        println!(
            "  hot-set overlap (top-{k}): {:.0}% — history predicts eval",
            100.0 * overlap as f64 / k as f64
        );

        // Load skew before/after grouping (Gini).
        let mapping = CorrelationMapper.map(&graph, 64);
        let gfreq = recross::allocation::group_frequencies(&mapping, &eval);
        let gfreq_f: Vec<f64> = gfreq.iter().map(|&x| x as f64).collect();
        let ifreq_f: Vec<f64> = e_freq.iter().map(|&x| x as f64).collect();
        println!(
            "  load gini: items {:.3} -> grouped crossbars {:.3} (power law persists, Fig. 4)",
            gini(&ifreq_f),
            gini(&gfreq_f)
        );

        // Mean lookups vs Table I target.
        println!(
            "  lookups/query: {:.1} (Table I target {:.1})",
            history.mean_lookups(),
            spec.avg_lookups
        );

        // Query-length histogram (compact).
        let mut h = Histogram::new();
        for q in &history.queries {
            h.add(q.len() as u64);
        }
        println!("  query-length p50≈{:.0}, max {}\n", h.mean(), h.max_value());
    }
    println!("trace_analysis example OK");
}
