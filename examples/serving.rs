//! End-to-end serving driver — the repo's E2E validation (see
//! EXPERIMENTS.md §E2E).
//!
//! Builds the deployment once (`Deployment::of(cfg).build()`), spawns the
//! live single-pool backend (`SinglePool::spawn` — AOT-compiled DLRM
//! through PJRT behind the dynamic batcher), and serves a batched stream
//! of recommendation requests generated from the calibrated "software"
//! workload. Reports latency percentiles, throughput, the simulated
//! crossbar cost of the same traffic, and verifies determinism.
//!
//! ```bash
//! make artifacts && cargo run --release --example serving
//! ```

use recross::config::Config;
use recross::coordinator::Request;
use recross::deploy::{Backend, Deployment, SinglePool};
use recross::engine::Scheme;
use recross::metrics::percentile;
use recross::util::Rng;
use recross::workload::{DatasetSpec, Generator};

const SCALE: f64 = 0.25;
const REQUESTS: usize = 512;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::serving_default();
    cfg.workload.dataset = "software".into();
    cfg.workload.history_queries = 3_000;
    cfg.workload.eval_queries = 256;
    recross::runtime::require_artifacts(&cfg.artifacts_dir)?;

    // Offline phase once, then the engine moves onto the executor thread.
    println!("spinning up coordinator (offline phase + PJRT compile)...");
    let t0 = std::time::Instant::now();
    let policy = recross::coordinator::BatchPolicy::from_config(&cfg, 32);
    let dense_features = cfg.workload.dense_features;
    let seed = cfg.workload.seed;
    let prepared = Deployment::of(cfg.clone())
        .scheme(Scheme::ReCross)
        .scale(SCALE)
        .build()?;
    let pool = SinglePool::spawn(prepared, policy)?;
    println!("ready in {:.2?}", t0.elapsed());
    let handle = pool.handle();

    // Build the request stream from the same generator family the offline
    // phase learned from (held-out seed).
    let spec = DatasetSpec::by_name(&cfg.workload.dataset).unwrap().scaled(SCALE);
    let gen = Generator::new(&spec, seed);
    let mut rng = Rng::new(0xD00D);
    let requests: Vec<Request> = (0..REQUESTS as u64)
        .map(|id| {
            let q = gen.query(&mut rng);
            Request {
                id,
                dense: (0..dense_features).map(|_| rng.normal() as f32).collect(),
                items: q.items,
            }
        })
        .collect();
    let total_lookups: usize = requests.iter().map(|r| r.items.len()).sum();

    // Fire the whole stream through the dynamic batcher.
    println!("serving {REQUESTS} requests ({total_lookups} embedding lookups)...");
    let t1 = std::time::Instant::now();
    let responses = handle.infer_many(requests)?;
    let wall = t1.elapsed();

    // --- report ------------------------------------------------------------
    let lat_ms: Vec<f64> = responses.iter().map(|r| r.latency.as_secs_f64() * 1e3).collect();
    let activations: u64 = responses.iter().map(|r| r.activations).sum();
    let logit_mean: f32 =
        responses.iter().map(|r| r.logit).sum::<f32>() / responses.len() as f32;
    println!("\n=== serving report ===");
    println!("requests:      {}", responses.len());
    println!("wall time:     {wall:.2?}");
    println!(
        "throughput:    {:.0} req/s ({:.0} lookups/s)",
        responses.len() as f64 / wall.as_secs_f64(),
        total_lookups as f64 / wall.as_secs_f64()
    );
    println!(
        "latency (ms):  p50 {:.2}   p95 {:.2}   p99 {:.2}   max {:.2}",
        percentile(&lat_ms, 50.0),
        percentile(&lat_ms, 95.0),
        percentile(&lat_ms, 99.0),
        percentile(&lat_ms, 100.0)
    );
    println!(
        "crossbar cost: {activations} activations ({:.1} per request)",
        activations as f64 / responses.len() as f64
    );
    println!("mean logit:    {logit_mean:.4}");

    // The backend status vocabulary works here too.
    let status = pool.status()?;
    println!(
        "executor:      {} batches, {} lookups served",
        status[0].batches, status[0].lookups
    );

    // Every logit must be finite and reductions deterministic.
    assert!(responses.iter().all(|r| r.logit.is_finite()));
    let again = handle.infer(Request {
        id: 1_000_000,
        dense: vec![0.25; dense_features],
        items: vec![1, 2, 3, 4, 5],
    })?;
    let again2 = handle.infer(Request {
        id: 1_000_001,
        dense: vec![0.25; dense_features],
        items: vec![1, 2, 3, 4, 5],
    })?;
    assert_eq!(again.logit, again2.logit, "pipeline must be deterministic");
    println!("\nserving example OK");
    Ok(())
}
