//! Design-space exploration: the knobs a deployer would actually sweep.
//!
//! * duplication area budget (Fig. 10's axis, extended),
//! * crossbar group size (64 default; what if crossbars were 32 or 128
//!   rows tall?),
//! * dynamic-switch ADC read-path width (3-bit default),
//! * bus channel count (the peripheral bandwidth wall).
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use recross::config::Config;
use recross::engine::{Engine, Scheme};
use recross::graph::CoGraph;
use recross::workload::{generate, DatasetSpec};
use recross::xbar::CircuitParams;

fn main() {
    let spec = DatasetSpec::by_name("automotive").unwrap().scaled(0.1);
    let (history, eval) = generate(&spec, 4_000, 512, 42);
    let graph = CoGraph::build(&history);
    let base_cfg = Config::paper_default();

    let naive = Engine::prepare(Scheme::Naive, &graph, &history, &base_cfg);
    let base = naive.run_trace(&eval, base_cfg.scheme.batch_size);
    println!(
        "baseline (naive): {:.1} µs, {:.1} nJ on automotive@0.1\n",
        base.completion_ns / 1e3,
        base.energy_pj / 1e3
    );

    // --- sweep 1: duplication budget ---------------------------------------
    println!("== duplication budget (Fig. 10 extended) ==");
    println!("{:>8} {:>10} {:>10} {:>8}", "dup%", "speedup", "energy-eff", "xbars");
    for ratio in [0.0, 0.025, 0.05, 0.10, 0.20, 0.40] {
        let mut cfg = base_cfg.clone();
        cfg.scheme.dup_ratio = ratio;
        let e = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
        let s = e.run_trace(&eval, cfg.scheme.batch_size);
        println!(
            "{:>7.1}% {:>9.2}x {:>9.2}x {:>8}",
            ratio * 100.0,
            base.completion_ns / s.completion_ns,
            base.energy_pj / s.energy_pj,
            e.physical_crossbars()
        );
    }

    // --- sweep 2: group size (crossbar height) ------------------------------
    println!("\n== crossbar group size ==");
    println!("{:>8} {:>12} {:>10} {:>10}", "rows", "activations", "speedup", "energy-eff");
    for rows in [16usize, 32, 64, 128] {
        let mut cfg = base_cfg.clone();
        cfg.hardware.xbar_rows = rows;
        cfg.scheme.group_size = rows;
        let e = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
        let nv = Engine::prepare(Scheme::Naive, &graph, &history, &cfg);
        let s = e.run_trace(&eval, cfg.scheme.batch_size);
        let b = nv.run_trace(&eval, cfg.scheme.batch_size);
        println!(
            "{:>8} {:>12} {:>9.2}x {:>9.2}x",
            rows,
            s.activations,
            b.completion_ns / s.completion_ns,
            b.energy_pj / s.energy_pj
        );
    }

    // --- sweep 3: read-path resolution --------------------------------------
    println!("\n== dynamic-switch read-path width (energy of full ReCross) ==");
    println!("{:>8} {:>12} {:>14}", "bits", "energy nJ", "vs 6-bit MAC");
    let mut cfg = base_cfg.clone();
    cfg.hardware.dynamic_switch = false;
    let no_switch = Engine::prepare(Scheme::ReCrossNoSwitch, &graph, &history, &cfg)
        .run_trace(&eval, cfg.scheme.batch_size);
    for bits in [1u32, 2, 3, 4, 6] {
        let mut cfg = base_cfg.clone();
        cfg.hardware.read_mode_bits = bits;
        let e = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
        let s = e.run_trace(&eval, cfg.scheme.batch_size);
        println!(
            "{:>8} {:>12.1} {:>13.2}x",
            bits,
            s.energy_pj / 1e3,
            no_switch.energy_pj / s.energy_pj
        );
    }

    // --- sweep 4: bus channels ----------------------------------------------
    println!("\n== global bus channels (completion time, full ReCross) ==");
    println!("{:>8} {:>12} {:>10}", "chans", "time µs", "speedup");
    for chans in [1usize, 4, 16, 64] {
        let mut cfg = base_cfg.clone();
        cfg.hardware.bus_channels = chans;
        let e = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
        let nv = Engine::prepare(Scheme::Naive, &graph, &history, &cfg);
        let s = e.run_trace(&eval, cfg.scheme.batch_size);
        let b = nv.run_trace(&eval, cfg.scheme.batch_size);
        println!(
            "{:>8} {:>12.1} {:>9.2}x",
            chans,
            s.completion_ns / 1e3,
            b.completion_ns / s.completion_ns
        );
    }

    let params = CircuitParams::default();
    println!(
        "\n(cost model: MAC {} ns / read {} ns array settle, {} comparators full vs {} gated)",
        params.array_mac_ns,
        params.array_read_ns,
        63,
        7
    );
    println!("\ndesign_space example OK");
}
