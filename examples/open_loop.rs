//! Open-loop traffic walkthrough: arrival processes, the simulated-time
//! driver, trace replay — no PJRT artifacts, no threads, fully
//! deterministic, and one `deploy` builder away.
//!
//! ```bash
//! cargo run --release --example open_loop
//! ```
//!
//! 1. Build a small ReCross pool through `Deployment::of(..).build()`.
//! 2. Stamp the same query stream with Poisson, bursty, and diurnal
//!    arrivals at the same mean rate and compare the latency tails —
//!    same work, very different p999.
//! 3. Push the offered load past capacity and watch the hockey stick.
//! 4. Round-trip a timed trace through the v2 on-disk format and replay
//!    it to identical results.

use recross::config::Config;
use recross::coordinator::BatchPolicy;
use recross::deploy::Deployment;
use recross::engine::Scheme;
use recross::loadgen::{drive, ArrivalKind, Arrivals, OpenLoopReport};
use recross::util::fmt_ns;
use recross::workload::{DatasetSpec, Generator, TimedTrace};
use std::time::Duration;

const SCALE: f64 = 0.05;
const QUERIES: usize = 1_024;
const SEED: u64 = 42;

fn report_row(name: &str, r: &OpenLoopReport) {
    println!(
        "{name:<10} p50 {:>10}  p99 {:>10}  p999 {:>10}  thrpt {:>9.0} q/s  depth {:>6.2}",
        fmt_ns(r.percentile_ns(50.0)),
        fmt_ns(r.percentile_ns(99.0)),
        fmt_ns(r.percentile_ns(99.9)),
        r.throughput_qps(),
        r.mean_queue_depth(),
    );
}

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::paper_default();
    cfg.workload.dataset = "software".into();
    cfg.workload.history_queries = 2_000;
    cfg.workload.eval_queries = 128;

    println!("offline phase (graph -> Algorithm 1 -> Eq. 1)...");
    let prepared = Deployment::of(cfg.clone())
        .scheme(Scheme::ReCross)
        .scale(SCALE)
        .build()?;
    let single = prepared.sim()?;
    let spec = DatasetSpec::by_name(&cfg.workload.dataset).unwrap().scaled(SCALE);
    let gen = Generator::new(&spec, cfg.workload.seed);
    let trace = gen.trace(QUERIES, cfg.workload.seed.wrapping_add(3));
    let policy = BatchPolicy {
        max_batch: 32,
        max_wait: Duration::from_micros(5),
    };

    // Capacity proxy so the demo rates mean something on any machine.
    let cap = QUERIES as f64
        / (prepared.engine().run_trace(&trace, policy.max_batch).completion_ns / 1e9);
    println!("closed-loop capacity estimate: {cap:.0} q/s\n");

    // --- same mean rate, three traffic shapes ----------------------------
    let rate = 0.5 * cap;
    println!("== traffic shape vs tail (offered {rate:.0} q/s, half capacity) ==");
    for kind in [ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Diurnal] {
        let arrivals = Arrivals::from_kind(kind, rate, SEED).take(QUERIES);
        let r = drive(&single, &trace.queries, &arrivals, &policy);
        report_row(kind.name(), &r);
    }

    // --- the hockey stick -------------------------------------------------
    println!("\n== offered load -> p99 (poisson, single pool vs 4 shards) ==");
    let sharded = prepared.sim_sharded(4, 0.10)?;
    println!(
        "{:>10} {:>14} {:>14}",
        "load/cap", "p99 single", "p99 sharded(4)"
    );
    for mult in [0.25, 0.5, 1.0, 2.0] {
        let arrivals = Arrivals::poisson(mult * cap, SEED).take(QUERIES);
        let r_single = drive(&single, &trace.queries, &arrivals, &policy);
        let r_sharded = drive(&sharded, &trace.queries, &arrivals, &policy);
        println!(
            "{mult:>10.2} {:>14} {:>14}",
            fmt_ns(r_single.percentile_ns(99.0)),
            fmt_ns(r_sharded.percentile_ns(99.0)),
        );
    }

    // --- record, persist, replay -----------------------------------------
    println!("\n== v2 trace round-trip + replay ==");
    let timed = Arrivals::poisson(rate, SEED).stamp(trace.clone());
    let path = std::env::temp_dir().join("recross_open_loop_example.rxtr");
    timed.save(&path)?;
    let loaded = TimedTrace::load(&path)?;
    let _ = std::fs::remove_file(&path);
    anyhow::ensure!(loaded == timed, "v2 round-trip must be lossless");
    let ts = loaded.arrivals_ns.expect("timestamps survived the disk");
    let live = drive(&single, &trace.queries, &ts, &policy);
    let fresh = Arrivals::poisson(rate, SEED).take(QUERIES);
    let again = drive(&single, &trace.queries, &fresh, &policy);
    anyhow::ensure!(live == again, "replayed traffic must reproduce the drive");
    println!("replayed {} arrivals from disk: drive is bit-identical", ts.len());

    println!("\nopen-loop example OK");
    Ok(())
}
