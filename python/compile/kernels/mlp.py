"""L1 Pallas kernel: fused two-layer ReLU MLP.

The DLRM bottom/top MLPs are small (dozens of units), so the whole layer
pair fits in VMEM at once; the win is fusing `x@w1+b1 -> relu -> @w2+b2`
into a single kernel so the intermediate activation never round-trips
through HBM. The grid tiles the batch dimension only.

VMEM per grid step (defaults: block_b=32, dims <= 64, f32): inputs
32x64 + both weight matrices 64x64 + hidden 32x64 + out 32x64 ≈ 50 KiB.

interpret=True for CPU-PJRT execution, as everywhere in this repo.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    """One batch tile through both layers, fused in VMEM."""
    x = x_ref[...]                      # [Bb, F]
    h = jnp.dot(x, w1_ref[...]) + b1_ref[...]   # [Bb, H]
    h = jnp.maximum(h, 0.0)
    out_ref[...] = jnp.dot(h, w2_ref[...]) + b2_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def mlp(x, w1, b1, w2, b2, *, block_b=32, interpret=True):
    """Fused relu(x @ w1 + b1) @ w2 + b2.

    Args:
      x:  [B, F] float32 inputs. B must be divisible by block_b (the
          callers pad batches to the AOT batch size anyway).
      w1: [F, H]; b1: [H]; w2: [H, O]; b2: [O].
      block_b: batch rows per grid step.

    Returns:
      [B, O] float32, == ref.mlp_ref.
    """
    b, f = x.shape
    f2, h = w1.shape
    h2, o = w2.shape
    assert f == f2 and h == h2, f"shape mismatch: {x.shape} {w1.shape} {w2.shape}"
    assert b1.shape == (h,) and b2.shape == (o,)
    block_b = min(block_b, b)
    assert b % block_b == 0, f"batch {b} not divisible by block {block_b}"

    return pl.pallas_call(
        _mlp_kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, o), lambda i: (0, 0)),
            pl.BlockSpec((o,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, o), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), w1, b1, w2, b2)
