"""L1 Pallas kernel: crossbar-tiled embedding reduction.

The kernel mirrors the ReRAM dataflow exactly (DESIGN.md
§Hardware-Adaptation): the grid iterates over (batch, crossbar-tile); each
grid step applies one query's multi-hot wordline vector to one 64xD
crossbar tile — `mask @ tile` is the column-wise bitline current sum — and
accumulates the partial result into the query's output row, which is what
the digital partial-sum merger does across crossbars.

BlockSpec = crossbar geometry:
  * one `tiles` block is one crossbar array (R x D cells) resident in VMEM,
  * one `masks` block is one query's wordline vector for that crossbar,
  * the output block is the query's D-wide accumulator, revisited across
    the T grid steps (accumulation in place).

VMEM footprint per grid step (defaults R=64, D=16, f32):
  tile 64x16x4 B = 4 KiB + mask 64x4 B + acc 16x4 B ≈ 4.3 KiB — far below
  the ~16 MiB VMEM of a TPU core, leaving headroom for double-buffering
  the HBM->VMEM tile stream. The contraction is a 1x64 @ 64xD product per
  step; on a real TPU the batch dimension would be widened to feed the
  128x128 MXU (see DESIGN.md §Perf for the estimate).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO with identical numerics.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _reduce_kernel(mask_ref, tile_ref, out_ref):
    """One grid step: accumulate mask @ tile into the output row.

    Shapes (leading singleton dims are the blocked batch/tile axes):
      mask_ref: [1, 1, R]  — wordline activations of query b on tile t
      tile_ref: [1, R, D]  — crossbar contents of tile t
      out_ref:  [1, D]     — accumulator for query b
    """
    t = pl.program_id(1)

    mask = mask_ref[0, 0, :]          # [R]
    tile = tile_ref[0, :, :]          # [R, D]
    # Bitline current sum: 1xR @ RxD. dot keeps it on the MXU path.
    partial = jnp.dot(mask[None, :], tile)[0]  # [D]

    # First visit to this output block initialises, later visits accumulate.
    @pl.when(t == 0)
    def _init():
        out_ref[0, :] = partial

    @pl.when(t != 0)
    def _accum():
        out_ref[0, :] += partial


@functools.partial(jax.jit, static_argnames=("interpret",))
def crossbar_reduce(masks, tiles, *, interpret=True):
    """Crossbar-tiled embedding reduction.

    Args:
      masks: [B, T, R] float32 multi-hot wordline activations.
      tiles: [T, R, D] float32 crossbar contents.
      interpret: lower in interpret mode (required on CPU PJRT).

    Returns:
      [B, D] float32 reduced embeddings, == ref.crossbar_reduce_ref.
    """
    b, t, r = masks.shape
    t2, r2, d = tiles.shape
    assert (t, r) == (t2, r2), f"masks {masks.shape} vs tiles {tiles.shape}"
    masks = masks.astype(jnp.float32)
    tiles = tiles.astype(jnp.float32)

    return pl.pallas_call(
        _reduce_kernel,
        grid=(b, t),
        in_specs=[
            pl.BlockSpec((1, 1, r), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, r, d), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(masks, tiles)
