"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the L1 kernels are tested against (pytest +
hypothesis). They are deliberately written in the most obvious way —
no tiling, no fusion — so a bug in the kernel cannot be mirrored here.
"""

import jax.numpy as jnp


def crossbar_reduce_ref(masks, tiles):
    """Crossbar-tiled embedding reduction, the analog MAC's numerics.

    Args:
      masks: [B, T, R] multi-hot wordline activations (0/1), float or int.
      tiles: [T, R, D] crossbar contents (R embeddings of dim D per tile).

    Returns:
      [B, D] — for each query b: sum over tiles t of masks[b,t] @ tiles[t],
      i.e. the summed bitline currents of every activated crossbar.
    """
    masks = masks.astype(tiles.dtype)
    # einsum is the single-line spec of the whole reduction.
    return jnp.einsum("btr,trd->bd", masks, tiles)


def mlp_ref(x, w1, b1, w2, b2):
    """Two-layer ReLU MLP: relu(x @ w1 + b1) @ w2 + b2."""
    h = jnp.maximum(x @ w1 + b1, 0.0)
    return h @ w2 + b2


def dlrm_forward_ref(dense, masks, tiles, params):
    """Reference DLRM forward pass (mirrors model.dlrm_forward).

    Args:
      dense: [B, F_dense] dense features.
      masks: [B, T, R] wordline activations.
      tiles: [T, R, D] embedding crossbar contents.
      params: dict with bottom/top MLP weights (see model.init_params).

    Returns:
      [B, 1] click logits.
    """
    bottom = mlp_ref(dense, params["w_bot1"], params["b_bot1"],
                     params["w_bot2"], params["b_bot2"])
    reduced = crossbar_reduce_ref(masks, tiles)
    inter = jnp.concatenate([bottom, reduced, bottom * reduced], axis=-1)
    return mlp_ref(inter, params["w_top1"], params["b_top1"],
                   params["w_top2"], params["b_top2"])
