"""L2: DLRM forward pass in JAX, calling the L1 Pallas kernels.

Architecture (Fig. 1a of the paper):

    dense features --[bottom MLP]--+
                                   +--[feature interaction]--[top MLP]--> logit
    sparse lookups --[embedding    |
                      reduction]---+

The embedding reduction runs through the crossbar-tiled Pallas kernel
(`kernels.crossbar_mac.crossbar_reduce`), so the AOT-lowered HLO contains
the exact dataflow the rust coordinator schedules: the coordinator decides
*which* crossbars to activate (masks) and the kernel computes the summed
bitline currents.

This module is build-time only: `aot.py` lowers `dlrm_forward` to HLO text
once, and the rust runtime executes the artifact. Python never serves a
request.
"""

import jax
import jax.numpy as jnp

from .kernels.crossbar_mac import crossbar_reduce
from .kernels.mlp import mlp

# Model dimensions (kept in sync with rust/src/runtime — see
# artifacts/manifest.toml written by aot.py).
DENSE_FEATURES = 13   # dense-feature width (Criteo-style)
EMBED_DIM = 16        # features per embedding (Table I geometry: 16x8bit)
BOTTOM_HIDDEN = 64
TOP_HIDDEN = 64
XBAR_ROWS = 64        # wordlines per crossbar tile


def init_params(key, dense_features=DENSE_FEATURES, embed_dim=EMBED_DIM,
                bottom_hidden=BOTTOM_HIDDEN, top_hidden=TOP_HIDDEN):
    """He-initialised MLP weights as a flat dict of jnp arrays."""
    ks = jax.random.split(key, 4)
    inter_dim = 3 * embed_dim  # [bottom, reduced, bottom*reduced]

    def he(k, shape):
        fan_in = shape[0]
        return jax.random.normal(k, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)

    return {
        "w_bot1": he(ks[0], (dense_features, bottom_hidden)),
        "b_bot1": jnp.zeros((bottom_hidden,), jnp.float32),
        "w_bot2": he(ks[1], (bottom_hidden, embed_dim)),
        "b_bot2": jnp.zeros((embed_dim,), jnp.float32),
        "w_top1": he(ks[2], (inter_dim, top_hidden)),
        "b_top1": jnp.zeros((top_hidden,), jnp.float32),
        "w_top2": he(ks[3], (top_hidden, 1)),
        "b_top2": jnp.zeros((1,), jnp.float32),
    }


# Parameter order for the flattened AOT signature (rust passes weights as
# positional literals; a stable order is part of the artifact ABI).
PARAM_ORDER = (
    "w_bot1", "b_bot1", "w_bot2", "b_bot2",
    "w_top1", "b_top1", "w_top2", "b_top2",
)


def params_to_args(params):
    """Flatten a param dict to the positional ABI tuple."""
    return tuple(params[name] for name in PARAM_ORDER)


def dlrm_forward(dense, masks, tiles, *params_flat, interpret=True):
    """DLRM forward pass.

    Args:
      dense: [B, DENSE_FEATURES] float32 dense features.
      masks: [B, T, XBAR_ROWS] float32 multi-hot wordline activations —
        the rust coordinator's crossbar schedule for each query.
      tiles: [T, XBAR_ROWS, EMBED_DIM] float32 crossbar contents.
      *params_flat: MLP weights in PARAM_ORDER.

    Returns:
      [B, 1] float32 click logits.
    """
    p = dict(zip(PARAM_ORDER, params_flat))
    bottom = mlp(dense, p["w_bot1"], p["b_bot1"], p["w_bot2"], p["b_bot2"],
                 interpret=interpret)                       # [B, E]
    reduced = crossbar_reduce(masks, tiles, interpret=interpret)  # [B, E]
    inter = jnp.concatenate([bottom, reduced, bottom * reduced], axis=-1)
    return mlp(inter, p["w_top1"], p["b_top1"], p["w_top2"], p["b_top2"],
               interpret=interpret)                         # [B, 1]


def dlrm_head(dense, reduced, *params_flat, interpret=True):
    """DLRM head: bottom MLP + interaction + top MLP over a pre-reduced
    embedding vector (the serving-path split — the rust coordinator
    computes `reduced` through the crossbar artifact, then batches heads).

    Args:
      dense: [B, DENSE_FEATURES] float32.
      reduced: [B, EMBED_DIM] float32 reduced embeddings.
      *params_flat: MLP weights in PARAM_ORDER.

    Returns:
      [B, 1] float32 click logits. dlrm_forward == dlrm_head on the output
      of embedding_reduce (tested in tests/test_model.py).
    """
    p = dict(zip(PARAM_ORDER, params_flat))
    bottom = mlp(dense, p["w_bot1"], p["b_bot1"], p["w_bot2"], p["b_bot2"],
                 interpret=interpret)
    inter = jnp.concatenate([bottom, reduced, bottom * reduced], axis=-1)
    return mlp(inter, p["w_top1"], p["b_top1"], p["w_top2"], p["b_top2"],
               interpret=interpret)


def embedding_reduce(masks, tiles, *, interpret=True):
    """Standalone embedding reduction (the paper's core op), for the
    dedicated artifact the rust hot path uses when only the reduction is
    needed."""
    return crossbar_reduce(masks, tiles, interpret=interpret)
