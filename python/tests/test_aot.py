"""AOT artifact tests: lowering produces parseable HLO text with the
expected entry signature, and the manifest matches the model constants."""

import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def hlo_texts():
    """Lower once per test session (lowering is the slow part)."""
    return {
        "dlrm": aot.to_hlo_text(aot.lower_dlrm(batch=2, tiles=2)),
        "reduce": aot.to_hlo_text(aot.lower_reduce(batch=2, tiles=2)),
    }


class TestLowering:
    def test_hlo_text_is_hlo(self, hlo_texts):
        for name, text in hlo_texts.items():
            assert text.startswith("HloModule"), f"{name}: {text[:40]!r}"
            assert "ENTRY" in text, name

    def test_dlrm_signature_arity(self, hlo_texts):
        # dense + masks + tiles + 8 params = 11 parameters.
        entry = hlo_texts["dlrm"][hlo_texts["dlrm"].index("ENTRY"):]
        entry = entry[:entry.index("\n}")]
        assert entry.count("parameter(") == 3 + len(model.PARAM_ORDER), entry

    def test_reduce_signature_shapes(self, hlo_texts):
        entry = hlo_texts["reduce"][hlo_texts["reduce"].index("ENTRY"):]
        entry = entry[:entry.index("\n}")]
        # masks [2,2,64], tiles [2,64,16]
        assert "f32[2,2,64]" in entry
        assert "f32[2,64,16]" in entry

    def test_outputs_are_tuples(self, hlo_texts):
        # return_tuple=True: rust unwraps with to_tuple1().
        for name, text in hlo_texts.items():
            entry = text[text.index("ENTRY"):]
            entry = entry[:entry.index("\n}")]
            root = [l for l in entry.splitlines() if "ROOT" in l]
            assert len(root) == 1, name
            assert "tuple(" in root[0], f"{name}: {root[0]!r}"

    def test_no_mosaic_custom_calls(self, hlo_texts):
        # interpret=True must lower to plain HLO the CPU client can run.
        for name, text in hlo_texts.items():
            assert "mosaic" not in text.lower(), name


class TestManifest:
    def test_manifest_roundtrip(self, tmp_path):
        path = os.path.join(tmp_path, "manifest.toml")
        aot.write_manifest(path, [1, 8], tiles=4)
        text = open(path).read()
        assert f"embed_dim = {model.EMBED_DIM}" in text
        assert f"xbar_rows = {model.XBAR_ROWS}" in text
        assert "batches = [1, 8]" in text
        assert "tiles = 4" in text
        for name in model.PARAM_ORDER:
            assert f'"{name}"' in text  # double-quoted (TOML-parseable)
