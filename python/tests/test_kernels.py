"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is the
core correctness signal of the whole stack (the rust side executes exactly
this lowered computation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.crossbar_mac import crossbar_reduce
from compile.kernels.mlp import mlp
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------- crossbar


class TestCrossbarReduce:
    def test_matches_ref_basic(self):
        key = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        masks = (jax.random.uniform(k1, (4, 3, 64)) < 0.2).astype(jnp.float32)
        tiles = rand(k2, (3, 64, 16))
        got = crossbar_reduce(masks, tiles)
        want = ref.crossbar_reduce_ref(masks, tiles)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_zero_mask_gives_zero(self):
        masks = jnp.zeros((2, 2, 64))
        tiles = rand(jax.random.PRNGKey(1), (2, 64, 16))
        np.testing.assert_allclose(crossbar_reduce(masks, tiles),
                                   jnp.zeros((2, 16)), atol=0)

    def test_single_row_is_plain_read(self):
        # popcount==1: the reduction must return exactly the stored row —
        # the invariant behind the paper's read-mode switch.
        tiles = rand(jax.random.PRNGKey(2), (2, 64, 16))
        masks = jnp.zeros((1, 2, 64)).at[0, 1, 37].set(1.0)
        got = crossbar_reduce(masks, tiles)
        np.testing.assert_allclose(got[0], tiles[1, 37], rtol=1e-6)

    def test_linearity_in_masks(self):
        # reduce(m1 + m2) == reduce(m1) + reduce(m2) for disjoint masks —
        # the analog current sum is linear.
        key = jax.random.PRNGKey(3)
        tiles = rand(key, (2, 64, 16))
        m1 = jnp.zeros((1, 2, 64)).at[0, 0, 5].set(1.0)
        m2 = jnp.zeros((1, 2, 64)).at[0, 1, 9].set(1.0)
        lhs = crossbar_reduce(m1 + m2, tiles)
        rhs = crossbar_reduce(m1, tiles) + crossbar_reduce(m2, tiles)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 6),
        t=st.integers(1, 5),
        r=st.sampled_from([8, 16, 64]),
        d=st.sampled_from([4, 16, 32]),
        density=st.floats(0.0, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, b, t, r, d, density, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        masks = (jax.random.uniform(k1, (b, t, r)) < density).astype(jnp.float32)
        tiles = rand(k2, (t, r, d))
        got = crossbar_reduce(masks, tiles)
        want = ref.crossbar_reduce_ref(masks, tiles)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        dtype=st.sampled_from([jnp.float32, jnp.bfloat16, jnp.int8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_mask_dtypes_accepted(self, dtype, seed):
        # Masks arrive as whatever the coordinator packs; the kernel casts.
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        masks = (jax.random.uniform(k1, (2, 2, 16)) < 0.3).astype(dtype)
        tiles = rand(k2, (2, 16, 8))
        got = crossbar_reduce(masks, tiles)
        want = ref.crossbar_reduce_ref(masks.astype(jnp.float32), tiles)
        np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-2)

    def test_shape_mismatch_rejected(self):
        with pytest.raises(AssertionError):
            crossbar_reduce(jnp.zeros((1, 2, 64)), jnp.zeros((3, 64, 16)))


# --------------------------------------------------------------------- mlp


class TestMlp:
    def test_matches_ref_basic(self):
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 5)
        x = rand(ks[0], (32, 13))
        w1, b1 = rand(ks[1], (13, 64)), rand(ks[2], (64,))
        w2, b2 = rand(ks[3], (64, 16)), rand(ks[4], (16,))
        got = mlp(x, w1, b1, w2, b2)
        want = ref.mlp_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_relu_actually_clips(self):
        # With all-negative first-layer output, result must be b2 exactly.
        x = jnp.ones((4, 4))
        w1 = -jnp.eye(4)
        b1 = jnp.zeros((4,))
        w2 = rand(jax.random.PRNGKey(1), (4, 3))
        b2 = jnp.array([1.0, 2.0, 3.0])
        got = mlp(x, w1, b1, w2, b2, block_b=4)
        np.testing.assert_allclose(got, jnp.tile(b2, (4, 1)), atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.sampled_from([1, 2, 4, 8, 32]),
        f=st.integers(1, 20),
        h=st.integers(1, 40),
        o=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, b, f, h, o, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        x = rand(ks[0], (b, f))
        w1, b1 = rand(ks[1], (f, h)), rand(ks[2], (h,))
        w2, b2 = rand(ks[3], (h, o)), rand(ks[4], (o,))
        got = mlp(x, w1, b1, w2, b2)
        want = ref.mlp_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_indivisible_batch_rejected(self):
        x = jnp.zeros((5, 3))
        w1, b1 = jnp.zeros((3, 4)), jnp.zeros((4,))
        w2, b2 = jnp.zeros((4, 2)), jnp.zeros((2,))
        with pytest.raises(AssertionError):
            mlp(x, w1, b1, w2, b2, block_b=2)
