"""L2 model tests: DLRM forward vs reference, shapes, and the
reduction-path semantics the rust coordinator relies on."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def make_inputs(key, batch=8, tiles=4):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dense = jax.random.normal(k1, (batch, model.DENSE_FEATURES), jnp.float32)
    masks = (jax.random.uniform(k2, (batch, tiles, model.XBAR_ROWS)) < 0.1
             ).astype(jnp.float32)
    tiles_arr = jax.random.normal(
        k3, (tiles, model.XBAR_ROWS, model.EMBED_DIM), jnp.float32)
    params = model.init_params(k4)
    return dense, masks, tiles_arr, params


class TestDlrmForward:
    def test_matches_reference(self):
        dense, masks, tiles, params = make_inputs(jax.random.PRNGKey(0))
        got = model.dlrm_forward(dense, masks, tiles,
                                 *model.params_to_args(params))
        want = ref.dlrm_forward_ref(dense, masks, tiles, params)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_output_shape(self):
        dense, masks, tiles, params = make_inputs(jax.random.PRNGKey(1),
                                                  batch=32, tiles=8)
        out = model.dlrm_forward(dense, masks, tiles,
                                 *model.params_to_args(params))
        assert out.shape == (32, 1)
        assert out.dtype == jnp.float32

    def test_deterministic(self):
        dense, masks, tiles, params = make_inputs(jax.random.PRNGKey(2))
        args = model.params_to_args(params)
        a = model.dlrm_forward(dense, masks, tiles, *args)
        b = model.dlrm_forward(dense, masks, tiles, *args)
        np.testing.assert_array_equal(a, b)

    def test_empty_masks_use_only_dense_path(self):
        # Zero masks -> reduced == 0 -> logits depend on dense only; two
        # different tile contents must give identical outputs.
        dense, masks, tiles, params = make_inputs(jax.random.PRNGKey(3))
        masks = jnp.zeros_like(masks)
        args = model.params_to_args(params)
        a = model.dlrm_forward(dense, masks, tiles, *args)
        b = model.dlrm_forward(dense, masks, tiles * 2.0 + 1.0, *args)
        np.testing.assert_allclose(a, b, rtol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(batch=st.sampled_from([1, 2, 8]), tiles=st.integers(1, 6),
           seed=st.integers(0, 2**31 - 1))
    def test_matches_reference_hypothesis(self, batch, tiles, seed):
        dense, masks, tiles_arr, params = make_inputs(
            jax.random.PRNGKey(seed), batch=batch, tiles=tiles)
        got = model.dlrm_forward(dense, masks, tiles_arr,
                                 *model.params_to_args(params))
        want = ref.dlrm_forward_ref(dense, masks, tiles_arr, params)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


class TestParams:
    def test_param_order_complete(self):
        params = model.init_params(jax.random.PRNGKey(0))
        assert set(model.PARAM_ORDER) == set(params.keys())
        flat = model.params_to_args(params)
        assert len(flat) == len(model.PARAM_ORDER)

    def test_shapes_consistent(self):
        params = model.init_params(jax.random.PRNGKey(0))
        assert params["w_bot1"].shape == (model.DENSE_FEATURES,
                                          model.BOTTOM_HIDDEN)
        assert params["w_bot2"].shape == (model.BOTTOM_HIDDEN,
                                          model.EMBED_DIM)
        assert params["w_top1"].shape == (3 * model.EMBED_DIM,
                                          model.TOP_HIDDEN)
        assert params["w_top2"].shape == (model.TOP_HIDDEN, 1)


class TestEmbeddingReduce:
    def test_standalone_matches_ref(self):
        _, masks, tiles, _ = make_inputs(jax.random.PRNGKey(5))
        got = model.embedding_reduce(masks, tiles)
        want = ref.crossbar_reduce_ref(masks, tiles)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
