//! API-compatible stand-in for the `xla` PJRT bindings.
//!
//! The reproduction environment often has no XLA toolchain; this stub
//! mirrors the exact subset of the `xla` crate surface the runtime uses so
//! the crate builds with default features. Every entry point that would
//! touch PJRT fails with [`Unavailable`]; `Runtime::load` therefore
//! reports "rebuild with `--features pjrt`" instead of a link error, and
//! all the non-PJRT paths (circuit simulation, cluster serving, reports)
//! work untouched.
#![allow(dead_code)]

/// Error every stubbed PJRT entry point returns.
#[derive(Clone, Copy)]
pub struct Unavailable;

impl std::fmt::Debug for Unavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "PJRT bindings not linked — rebuild with `--features pjrt` to run artifacts"
        )
    }
}

impl std::fmt::Display for Unavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        std::fmt::Debug::fmt(self, f)
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, Unavailable> {
        Err(Unavailable)
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Unavailable> {
        Err(Unavailable)
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Unavailable> {
        Err(Unavailable)
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Unavailable> {
        Err(Unavailable)
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Unavailable> {
        Err(Unavailable)
    }
}

/// Shape-less literal: carries nothing, validates nothing. The real shape
/// checks in `runtime::literal` run *before* construction, so the one
/// shape error test still passes against the stub.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Self {
        Literal
    }

    pub fn reshape(self, _shape: &[i64]) -> Result<Literal, Unavailable> {
        Ok(Literal)
    }

    pub fn to_tuple1(self) -> Result<Literal, Unavailable> {
        Err(Unavailable)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Unavailable> {
        Err(Unavailable)
    }
}
