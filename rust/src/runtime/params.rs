//! DLRM MLP parameters on the rust side.
//!
//! The artifact ABI passes the 8 MLP weight tensors positionally (see
//! `python/compile/model.py::PARAM_ORDER`). This module owns their shapes,
//! deterministic He-style initialisation (so rust-side and test runs are
//! reproducible without a checkpoint file), and a flat binary
//! checkpoint format for round-tripping trained weights.

use super::{xla, Manifest};
use crate::util::Rng;
use crate::Result;
use anyhow::{anyhow, bail};
use std::io::{Read, Write};

/// One named tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// The 8 DLRM MLP tensors, in ABI order.
#[derive(Debug, Clone, PartialEq)]
pub struct DlrmParams {
    pub tensors: Vec<Tensor>,
}

impl DlrmParams {
    /// Parameter shapes implied by the manifest dimensions.
    /// Order: w_bot1, b_bot1, w_bot2, b_bot2, w_top1, b_top1, w_top2, b_top2.
    pub fn shapes(m: &Manifest) -> Vec<(String, Vec<usize>)> {
        let f = m.dense_features;
        let d = m.embed_dim;
        let bh = 64; // BOTTOM_HIDDEN, fixed in model.py
        let th = 64; // TOP_HIDDEN
        vec![
            ("w_bot1".into(), vec![f, bh]),
            ("b_bot1".into(), vec![bh]),
            ("w_bot2".into(), vec![bh, d]),
            ("b_bot2".into(), vec![d]),
            ("w_top1".into(), vec![3 * d, th]),
            ("b_top1".into(), vec![th]),
            ("w_top2".into(), vec![th, 1]),
            ("b_top2".into(), vec![1]),
        ]
    }

    /// Deterministic He-initialised parameters.
    pub fn init(m: &Manifest, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let tensors = Self::shapes(m)
            .into_iter()
            .map(|(name, shape)| {
                let n: usize = shape.iter().product();
                let data = if name.starts_with('b') {
                    vec![0.0; n]
                } else {
                    let fan_in = shape[0].max(1) as f64;
                    let scale = (2.0 / fan_in).sqrt();
                    (0..n).map(|_| (rng.normal() * scale) as f32).collect()
                };
                Tensor { name, shape, data }
            })
            .collect();
        Self { tensors }
    }

    /// Validate against the manifest's declared order and shapes.
    pub fn validate(&self, m: &Manifest) -> Result<()> {
        let shapes = Self::shapes(m);
        anyhow::ensure!(
            self.tensors.len() == shapes.len(),
            "expected {} tensors, got {}",
            shapes.len(),
            self.tensors.len()
        );
        for (t, (name, shape)) in self.tensors.iter().zip(&shapes) {
            anyhow::ensure!(&t.name == name, "tensor order: {} vs {}", t.name, name);
            anyhow::ensure!(
                &t.shape == shape,
                "tensor {} shape {:?} vs expected {:?}",
                t.name,
                t.shape,
                shape
            );
            anyhow::ensure!(t.data.len() == t.elements(), "tensor {} data length", t.name);
        }
        for (t, o) in self.tensors.iter().zip(&m.param_order) {
            anyhow::ensure!(&t.name == o, "manifest order mismatch: {} vs {o}", t.name);
        }
        Ok(())
    }

    /// XLA literals in ABI order.
    pub fn literals(&self) -> Result<Vec<xla::Literal>> {
        self.tensors
            .iter()
            .map(|t| {
                let shape: Vec<i64> = t.shape.iter().map(|&s| s as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&shape)
                    .map_err(|e| anyhow!("param {}: {e:?}", t.name))
            })
            .collect()
    }

    /// Serialize to a flat binary checkpoint:
    /// magic `RXCP`, count u32, then per tensor: name-len u32 + utf8,
    /// rank u32, dims u32*, data f32* (LE).
    pub fn save<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(b"RXCP")?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for t in &self.tensors {
            w.write_all(&(t.name.len() as u32).to_le_bytes())?;
            w.write_all(t.name.as_bytes())?;
            w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            for &x in &t.data {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Load a checkpoint written by [`DlrmParams::save`].
    pub fn load_from<R: Read>(r: &mut R) -> Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != b"RXCP" {
            bail!("not a ReCross checkpoint");
        }
        let count = read_u32(r)? as usize;
        if count > 1024 {
            bail!("checkpoint declares {count} tensors; refusing");
        }
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name_len = read_u32(r)? as usize;
            if name_len > 256 {
                bail!("tensor name too long");
            }
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let rank = read_u32(r)? as usize;
            if rank > 8 {
                bail!("tensor rank {rank} too large");
            }
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u32(r)? as usize);
            }
            let n: usize = shape.iter().product();
            if n > 64 << 20 {
                bail!("tensor too large ({n} elems)");
            }
            let mut data = Vec::with_capacity(n);
            let mut buf = [0u8; 4];
            for _ in 0..n {
                r.read_exact(&mut buf)?;
                data.push(f32::from_le_bytes(buf));
            }
            tensors.push(Tensor {
                name: String::from_utf8(name)?,
                shape,
                data,
            });
        }
        Ok(Self { tensors })
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest {
            dense_features: 13,
            embed_dim: 16,
            xbar_rows: 64,
            tiles: 8,
            batches: vec![1],
            param_order: [
                "w_bot1", "b_bot1", "w_bot2", "b_bot2", "w_top1", "b_top1", "w_top2", "b_top2",
            ]
            .iter()
            .map(|s| s.to_string())
            .collect(),
        }
    }

    #[test]
    fn init_validates() {
        let m = manifest();
        let p = DlrmParams::init(&m, 42);
        p.validate(&m).unwrap();
        assert_eq!(p.tensors.len(), 8);
        assert_eq!(p.tensors[0].shape, vec![13, 64]);
        assert_eq!(p.tensors[4].shape, vec![48, 64]);
    }

    #[test]
    fn init_deterministic() {
        let m = manifest();
        assert_eq!(DlrmParams::init(&m, 7), DlrmParams::init(&m, 7));
        assert_ne!(DlrmParams::init(&m, 7), DlrmParams::init(&m, 8));
    }

    #[test]
    fn biases_zero_weights_scaled() {
        let m = manifest();
        let p = DlrmParams::init(&m, 1);
        assert!(p.tensors[1].data.iter().all(|&x| x == 0.0));
        let w = &p.tensors[0];
        let mean: f32 = w.data.iter().sum::<f32>() / w.data.len() as f32;
        assert!(mean.abs() < 0.1, "weight mean {mean}");
    }

    #[test]
    fn checkpoint_roundtrip() {
        let m = manifest();
        let p = DlrmParams::init(&m, 3);
        let mut buf = Vec::new();
        p.save(&mut buf).unwrap();
        let back = DlrmParams::load_from(&mut buf.as_slice()).unwrap();
        assert_eq!(p, back);
        back.validate(&m).unwrap();
    }

    #[test]
    fn checkpoint_rejects_garbage() {
        assert!(DlrmParams::load_from(&mut &b"XXXX"[..]).is_err());
        let mut buf = Vec::new();
        DlrmParams::init(&manifest(), 1).save(&mut buf).unwrap();
        buf.truncate(buf.len() / 2);
        assert!(DlrmParams::load_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn validate_catches_wrong_order() {
        let m = manifest();
        let mut p = DlrmParams::init(&m, 1);
        p.tensors.swap(0, 2);
        assert!(p.validate(&m).is_err());
    }
}
