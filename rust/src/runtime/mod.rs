//! PJRT runtime: load AOT artifacts and execute them on the request path.
//!
//! `make artifacts` lowers the L2 JAX model (with the L1 Pallas kernels
//! inlined, interpret-mode) to HLO **text**; this module loads each
//! artifact with `HloModuleProto::from_text_file`, compiles it once on the
//! PJRT CPU client, and exposes typed execute wrappers. After artifacts
//! are built, the rust binary is self-contained — Python never runs on the
//! request path.
//!
//! Artifact ABI (see `python/compile/aot.py::write_manifest`):
//! * `reduce_b{B}.hlo.txt`  — masks `[B,T,R]`, tiles `[T,R,D]` → `[B,D]`
//! * `dlrm_head_b{B}.hlo.txt` — dense `[B,F]`, reduced `[B,D]`, 8 MLP
//!   params → logits `[B,1]`
//! * `dlrm_b{B}.hlo.txt`    — the fused whole-model variant
//! * `manifest.toml`        — dimensions + parameter order

pub mod params;

#[cfg(not(feature = "pjrt"))]
mod stub;

// The real PJRT bindings are optional: the `pjrt` cargo feature links the
// `xla` crate; without it the in-tree [`stub`] keeps every signature
// compiling and `Runtime::load` returns a clear error instead. Both this
// module and [`params`] resolve `xla` through this alias.
#[cfg(feature = "pjrt")]
pub(crate) use ::xla;
#[cfg(not(feature = "pjrt"))]
pub(crate) use stub as xla;

pub use params::DlrmParams;

use crate::config::toml::Doc;
use crate::Result;
use anyhow::{anyhow, bail, Context};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Parsed `manifest.toml`.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub dense_features: usize,
    pub embed_dim: usize,
    pub xbar_rows: usize,
    pub tiles: usize,
    pub batches: Vec<usize>,
    pub param_order: Vec<String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.toml");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let doc = Doc::parse(text).map_err(|e| anyhow!("{e}"))?;
        let batches = doc
            .get("model.batches")
            .and_then(|v| v.as_array())
            .ok_or_else(|| anyhow!("manifest missing model.batches"))?
            .iter()
            .map(|v| v.as_i64().unwrap_or(0) as usize)
            .collect::<Vec<_>>();
        let param_order = doc
            .get("params.order")
            .and_then(|v| v.as_array())
            .ok_or_else(|| anyhow!("manifest missing params.order"))?
            .iter()
            .map(|v| v.as_str().unwrap_or("").to_string())
            .collect::<Vec<_>>();
        anyhow::ensure!(!batches.is_empty(), "manifest has no batch sizes");
        anyhow::ensure!(param_order.len() == 8, "expected 8 params");
        Ok(Self {
            dense_features: doc.usize_or("model.dense_features", 0),
            embed_dim: doc.usize_or("model.embed_dim", 0),
            xbar_rows: doc.usize_or("model.xbar_rows", 0),
            tiles: doc.usize_or("model.tiles", 0),
            batches,
            param_order,
        })
    }
}

/// One compiled artifact.
struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {path:?}: {e:?}"))?;
        Ok(Self { exe })
    }

    /// Execute with literal inputs; unwrap the 1-tuple output to an `f32`
    /// vector (artifacts are lowered with `return_tuple=True`).
    fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch: {e:?}"))?;
        let out = literal.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }
}

/// Build an `f32` literal of the given shape from a flat slice.
fn literal(data: &[f32], shape: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = shape.iter().product();
    anyhow::ensure!(
        expect as usize == data.len(),
        "literal shape {shape:?} wants {expect} elems, got {}",
        data.len()
    );
    xla::Literal::vec1(data)
        .reshape(shape)
        .map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
}

/// The PJRT runtime: compiled executables keyed by batch size.
pub struct Runtime {
    manifest: Manifest,
    reduce: BTreeMap<usize, Executable>,
    head: BTreeMap<usize, Executable>,
    dlrm: BTreeMap<usize, Executable>,
    platform: String,
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("manifest", &self.manifest)
            .field("platform", &self.platform)
            .finish()
    }
}

impl Runtime {
    /// Load and compile every artifact under `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let platform = client.platform_name();
        let mut reduce = BTreeMap::new();
        let mut head = BTreeMap::new();
        let mut dlrm = BTreeMap::new();
        for &b in &manifest.batches {
            reduce.insert(b, Executable::load(&client, &artifact(dir, "reduce", b))?);
            head.insert(b, Executable::load(&client, &artifact(dir, "dlrm_head", b))?);
            dlrm.insert(b, Executable::load(&client, &artifact(dir, "dlrm", b))?);
        }
        Ok(Self {
            manifest,
            reduce,
            head,
            dlrm,
            platform,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> &str {
        &self.platform
    }

    /// Smallest compiled batch size >= `n` (or the largest available).
    pub fn pick_batch(&self, n: usize) -> usize {
        for &b in &self.manifest.batches {
            if b >= n {
                return b;
            }
        }
        *self.manifest.batches.last().unwrap()
    }

    /// Embedding reduction: `masks [B,T,R]`, `tiles [T,R,D]` → `[B,D]`.
    pub fn reduce(&self, batch: usize, masks: &[f32], tiles: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let exe = self
            .reduce
            .get(&batch)
            .ok_or_else(|| anyhow!("no reduce artifact for batch {batch}"))?;
        let masks_l = literal(masks, &[batch as i64, m.tiles as i64, m.xbar_rows as i64])?;
        let tiles_l = literal(tiles, &[m.tiles as i64, m.xbar_rows as i64, m.embed_dim as i64])?;
        exe.run_f32(&[masks_l, tiles_l])
    }

    /// DLRM head: `dense [B,F]`, `reduced [B,D]`, params → logits `[B]`.
    pub fn dlrm_head(
        &self,
        batch: usize,
        dense: &[f32],
        reduced: &[f32],
        params: &DlrmParams,
    ) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let exe = self
            .head
            .get(&batch)
            .ok_or_else(|| anyhow!("no dlrm_head artifact for batch {batch}"))?;
        let mut inputs = vec![
            literal(dense, &[batch as i64, m.dense_features as i64])?,
            literal(reduced, &[batch as i64, m.embed_dim as i64])?,
        ];
        inputs.extend(params.literals()?);
        exe.run_f32(&inputs)
    }

    /// Fused whole-model forward: dense + masks + tiles + params → logits.
    pub fn dlrm_forward(
        &self,
        batch: usize,
        dense: &[f32],
        masks: &[f32],
        tiles: &[f32],
        params: &DlrmParams,
    ) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let exe = self
            .dlrm
            .get(&batch)
            .ok_or_else(|| anyhow!("no dlrm artifact for batch {batch}"))?;
        let mut inputs = vec![
            literal(dense, &[batch as i64, m.dense_features as i64])?,
            literal(masks, &[batch as i64, m.tiles as i64, m.xbar_rows as i64])?,
            literal(tiles, &[m.tiles as i64, m.xbar_rows as i64, m.embed_dim as i64])?,
        ];
        inputs.extend(params.literals()?);
        exe.run_f32(&inputs)
    }
}

fn artifact(dir: &Path, kind: &str, batch: usize) -> PathBuf {
    dir.join(format!("{kind}_b{batch}.hlo.txt"))
}

/// True when the artifact directory looks complete (used by tests and the
/// CLI to degrade gracefully with a clear message instead of a panic).
pub fn artifacts_available(dir: impl AsRef<Path>) -> bool {
    let dir = dir.as_ref();
    match Manifest::load(dir) {
        Ok(m) => m.batches.iter().all(|&b| {
            artifact(dir, "reduce", b).exists() && artifact(dir, "dlrm_head", b).exists()
        }),
        Err(_) => false,
    }
}

/// Bail with a friendly message when artifacts are missing.
pub fn require_artifacts(dir: impl AsRef<Path>) -> Result<()> {
    if !artifacts_available(&dir) {
        bail!(
            "AOT artifacts not found in {:?} — run `make artifacts` first",
            dir.as_ref()
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(
            "[model]\ndense_features = 13\nembed_dim = 16\nxbar_rows = 64\ntiles = 8\n\
             batches = [1, 8, 32]\n[params]\norder = [\"w_bot1\", \"b_bot1\", \"w_bot2\", \
             \"b_bot2\", \"w_top1\", \"b_top1\", \"w_top2\", \"b_top2\"]\n",
        )
        .unwrap();
        assert_eq!(m.embed_dim, 16);
        assert_eq!(m.batches, vec![1, 8, 32]);
        assert_eq!(m.param_order.len(), 8);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(Manifest::parse("[model]\n").is_err());
        assert!(Manifest::parse("batches = [1]").is_err());
    }

    #[test]
    fn literal_shape_checked() {
        assert!(literal(&[1.0, 2.0], &[3]).is_err());
        assert!(literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).is_ok());
    }

    #[test]
    fn pick_batch_rounds_up() {
        // pick_batch logic exercised without loading executables.
        let m = Manifest {
            dense_features: 13,
            embed_dim: 16,
            xbar_rows: 64,
            tiles: 8,
            batches: vec![1, 8, 32],
            param_order: vec![String::new(); 8],
        };
        let pick = |n: usize| -> usize {
            for &b in &m.batches {
                if b >= n {
                    return b;
                }
            }
            *m.batches.last().unwrap()
        };
        assert_eq!(pick(1), 1);
        assert_eq!(pick(2), 8);
        assert_eq!(pick(9), 32);
        assert_eq!(pick(100), 32);
    }

    // Full execute-path tests live in rust/tests/runtime_integration.rs
    // (they need `make artifacts`).
}
