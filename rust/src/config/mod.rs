//! Typed configuration for the whole system.
//!
//! Configuration flows through **one precedence chain**, lowest to
//! highest:
//!
//! 1. built-in defaults ([`Config::paper_default`] reproduces Table I;
//!    [`Config::serving_default`] / [`Config::open_loop_default`] adjust
//!    the workload sizing for the serving entry points),
//! 2. a TOML file (the in-tree [`toml`] subset parser), overlaid by
//!    [`Config::from_file_with_base`],
//! 3. explicitly passed CLI flags ([`Config::overlay_cli`] — declared
//!    CLI defaults do **not** clobber TOML values; only flags the user
//!    actually typed do),
//! 4. programmatic mutation (e.g.
//!    [`Deployment::workload`](crate::deploy::Deployment::workload)).

pub mod toml;

use crate::util::cli::Args;
use crate::Result;
use anyhow::Context;

/// Crossbar / tile / ADC hardware configuration (paper Table I).
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareConfig {
    /// Crossbar rows (= wordlines = embeddings per crossbar). Paper: 64.
    pub xbar_rows: usize,
    /// Crossbar columns (= bitlines). Paper: 64.
    pub xbar_cols: usize,
    /// Storage bits per ReRAM cell. Paper: 2.
    pub bits_per_cell: u32,
    /// Crossbars per tile edge: a tile is `tile_dim x tile_dim` crossbars
    /// sharing peripheral circuitry. Paper tile: 256x256 cells = 4x4
    /// crossbars of 64x64.
    pub tile_xbars: usize,
    /// ADC resolution in bits. Paper: 6 (quantized down from 8).
    pub adc_bits: u32,
    /// Number of columns multiplexed onto one ADC (ISAAC-style sharing).
    pub adc_share: usize,
    /// Bits resolved per cycle by the read-mode sense path of the
    /// dynamic-switch ADC (paper §IV-B: read mode uses 3 of the 6 bits).
    pub read_mode_bits: u32,
    /// Global bus width in bits. Paper: 512.
    pub bus_width_bits: usize,
    /// Independent global-bus/NoC channels carrying activation results to
    /// the accumulation units. Activation results contend for these — the
    /// peripheral bandwidth wall that makes "fewer activations" the
    /// paper's headline lever.
    pub bus_channels: usize,
    /// Core clock in MHz for the digital periphery.
    pub clock_mhz: f64,
    /// Whether the dynamic-switch ADC (read/MAC switching) is enabled.
    pub dynamic_switch: bool,
    /// Embedding feature dimension (learned features per embedding).
    /// 16 features x 8-bit at 2 bits/cell = 64 cells = one 64-col row.
    pub embedding_dim: usize,
    /// Fixed-point bits per embedding element as stored in cells.
    pub weight_bits: u32,
}

impl Default for HardwareConfig {
    fn default() -> Self {
        Self {
            xbar_rows: 64,
            xbar_cols: 64,
            bits_per_cell: 2,
            tile_xbars: 4,
            adc_bits: 6,
            adc_share: 8,
            read_mode_bits: 3,
            bus_width_bits: 512,
            bus_channels: 16,
            clock_mhz: 1000.0,
            dynamic_switch: true,
            embedding_dim: 16,
            weight_bits: 8,
        }
    }
}

impl HardwareConfig {
    /// Cells needed to store one embedding vector.
    pub fn cells_per_embedding(&self) -> usize {
        (self.embedding_dim * self.weight_bits as usize).div_ceil(self.bits_per_cell as usize)
    }

    /// Embeddings that fit in one crossbar (a.k.a. the grouping size).
    /// With the default config each embedding occupies exactly one row.
    pub fn embeddings_per_xbar(&self) -> usize {
        let rows_per_emb = self.cells_per_embedding().div_ceil(self.xbar_cols);
        self.xbar_rows / rows_per_emb.max(1)
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.xbar_rows > 0 && self.xbar_cols > 0, "zero crossbar dims");
        anyhow::ensure!(
            (1..=4).contains(&self.bits_per_cell),
            "bits_per_cell {} outside 1..=4",
            self.bits_per_cell
        );
        anyhow::ensure!(
            self.read_mode_bits <= self.adc_bits,
            "read-mode bits {} exceed ADC resolution {}",
            self.read_mode_bits,
            self.adc_bits
        );
        anyhow::ensure!(
            self.adc_share >= 1 && self.adc_share <= self.xbar_cols,
            "adc_share {} outside 1..=cols",
            self.adc_share
        );
        anyhow::ensure!(self.embeddings_per_xbar() >= 1, "embedding too large for crossbar");
        anyhow::ensure!(self.bus_channels >= 1, "need at least one bus channel");
        Ok(())
    }
}

/// ReCross scheme configuration (§III).
#[derive(Debug, Clone, PartialEq)]
pub struct SchemeConfig {
    /// Group size for Algorithm 1; defaults to embeddings-per-crossbar.
    pub group_size: usize,
    /// Duplication area budget as a fraction of baseline crossbar count
    /// (Fig. 10 sweeps 0 / 0.05 / 0.10 / 0.20).
    pub dup_ratio: f64,
    /// Inference batch size (paper evaluates batch 256).
    pub batch_size: usize,
    /// Enable access-aware duplication (§III-C).
    pub duplication: bool,
    /// Enable energy-aware dynamic switching (§III-D).
    pub dynamic_switching: bool,
    /// Dynamic-batcher wait window, µs: a serving batch closes when the
    /// oldest queued request has waited this long (or the batch fills).
    /// The live single-pool server, the sharded cluster, and the
    /// open-loop simulator all honor this one knob; only their built-in
    /// defaults differ (2 ms for the live demos' ms-scale PJRT batches,
    /// 5 µs for the µs-scale discrete-event simulator — see
    /// [`Config::open_loop_default`]).
    pub max_wait_us: u64,
}

impl Default for SchemeConfig {
    fn default() -> Self {
        Self {
            group_size: 64,
            dup_ratio: 0.10,
            batch_size: 256,
            duplication: true,
            dynamic_switching: true,
            max_wait_us: 2_000,
        }
    }
}

impl SchemeConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.group_size > 0, "zero group size");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.dup_ratio),
            "dup_ratio {} outside [0,1]",
            self.dup_ratio
        );
        anyhow::ensure!(self.batch_size > 0, "zero batch size");
        Ok(())
    }
}

/// Workload generation configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    /// Dataset name (one of the five Amazon categories, or "custom").
    pub dataset: String,
    /// Queries in the history trace used for the offline phase.
    pub history_queries: usize,
    /// Queries in the evaluation trace.
    pub eval_queries: usize,
    /// RNG seed.
    pub seed: u64,
    /// Dense (non-embedding) features per inference request — must match
    /// the AOT artifact manifest's `model.dense_features` when the PJRT
    /// head is served.
    pub dense_features: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            dataset: "software".to_string(),
            history_queries: 20_000,
            eval_queries: 4_096,
            seed: 42,
            dense_features: 13,
        }
    }
}

/// Observability configuration (see [`crate::obs`]). Off by default:
/// a disabled plane costs one branch per would-be record call, which
/// `benches/obs_overhead.rs` pins.
#[derive(Debug, Clone, PartialEq)]
pub struct ObsConfig {
    /// Master switch for the metrics plane and the flight recorder.
    pub enabled: bool,
    /// Fraction of query ids whose spans the flight recorder samples,
    /// in `[0, 1]` (deterministic in the query id; 1.0 = record all).
    pub sample_rate: f64,
    /// Flight-recorder ring capacity in spans (0 disables recording
    /// while keeping the metrics plane).
    pub ring_capacity: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            sample_rate: 1.0,
            ring_capacity: 4_096,
        }
    }
}

impl ObsConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.sample_rate),
            "obs.sample_rate {} outside [0,1]",
            self.sample_rate
        );
        Ok(())
    }
}

/// Service-level objectives evaluated by the telemetry watch loop
/// (see [`crate::obs::slo`]). Thresholds feed the default objective set
/// ([`crate::obs::SloTracker`]`::from_config`); the burn-rate shape is
/// shared by every objective.
#[derive(Debug, Clone, PartialEq)]
pub struct SloConfig {
    /// `sojourn-p99` objective: per-window p99 sojourn must stay below
    /// this (ns).
    pub p99_sojourn_ns: f64,
    /// `queue-depth` objective: window-mean batcher queue depth must
    /// stay below this (queries).
    pub max_queue_depth: f64,
    /// Fast burn-rate rule (severity `page`): this many consecutive
    /// breached windows fire.
    pub fast_windows: usize,
    /// Slow burn-rate rule (severity `warn`): evaluated over this many
    /// trailing windows.
    pub slow_windows: usize,
    /// Slow rule: breached fraction that fires, in `(0, 1]`.
    pub slow_burn: f64,
}

impl Default for SloConfig {
    fn default() -> Self {
        Self {
            p99_sojourn_ns: 5_000_000.0,
            max_queue_depth: 64.0,
            fast_windows: 1,
            slow_windows: 12,
            slow_burn: 0.5,
        }
    }
}

impl SloConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.p99_sojourn_ns > 0.0,
            "slo.p99_sojourn_ns {} must be positive",
            self.p99_sojourn_ns
        );
        anyhow::ensure!(
            self.max_queue_depth > 0.0,
            "slo.max_queue_depth {} must be positive",
            self.max_queue_depth
        );
        anyhow::ensure!(self.fast_windows >= 1, "slo.fast_windows must be >= 1");
        anyhow::ensure!(
            self.slow_windows >= self.fast_windows,
            "slo.slow_windows {} must span at least slo.fast_windows {}",
            self.slow_windows,
            self.fast_windows
        );
        anyhow::ensure!(
            self.slow_burn > 0.0 && self.slow_burn <= 1.0,
            "slo.slow_burn {} outside (0,1]",
            self.slow_burn
        );
        Ok(())
    }
}

/// Telemetry watch-loop configuration (`recross status --watch` and the
/// cluster drift loop's tick cadence).
#[derive(Debug, Clone, PartialEq)]
pub struct WatchConfig {
    /// Tick interval, ms. On the simulated watch clock one tick always
    /// advances exactly this far, so tick sequences are reproducible.
    pub interval_ms: u64,
    /// Time-series ring capacity: windows retained per metric.
    pub ring_capacity: usize,
    /// Watch ticks before exiting; 0 streams until interrupted.
    pub ticks: usize,
}

impl Default for WatchConfig {
    fn default() -> Self {
        Self {
            interval_ms: 1_000,
            ring_capacity: 512,
            ticks: 0,
        }
    }
}

impl WatchConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.interval_ms > 0, "watch.interval_ms must be positive");
        anyhow::ensure!(
            self.ring_capacity >= 1,
            "watch.ring_capacity must be >= 1"
        );
        Ok(())
    }
}

/// Offline-phase execution configuration (see [`crate::util::par`]).
#[derive(Debug, Clone, PartialEq)]
pub struct OfflineConfig {
    /// Worker threads for the offline phase's data-parallel passes
    /// (graph build, regrouping, replication scoring). `0` means "use
    /// every available core". Any value produces **bit-identical**
    /// results — the parallel substrate merges partials in a fixed
    /// order — so this knob trades wall-clock for cores, never output.
    pub workers: usize,
}

impl Default for OfflineConfig {
    fn default() -> Self {
        Self { workers: 0 }
    }
}

/// Tiered embedding storage configuration (see [`crate::store`]).
/// Capacities are in tiles (one tile = one group's crossbar-resident
/// rows); costs are the deterministic modeled fetch latencies the
/// timing twin folds into query finish times.
#[derive(Debug, Clone, PartialEq)]
pub struct StoreConfig {
    /// Hot-tier capacity in tiles (crossbar-resident groups).
    pub hot_tiles: usize,
    /// DRAM-tier capacity in tiles; `0` means unbounded (nothing is
    /// forced cold by DRAM pressure), matching `offline.workers`'s
    /// "0 = no limit" convention.
    pub dram_tiles: usize,
    /// Modeled ns to fetch one DRAM-resident tile.
    pub dram_ns: f64,
    /// Modeled ns to fetch one cold (file-resident) tile.
    pub cold_ns: f64,
    /// Recent-window hits required before a group may be promoted into
    /// the hot tier (admission hysteresis; values below 1 behave as 1).
    pub promote_hits: u64,
    /// Batches between tier replans in the `Tiered` backend.
    pub replan_batches: usize,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self {
            hot_tiles: 64,
            dram_tiles: 0,
            dram_ns: 120.0,
            cold_ns: 2_500.0,
            promote_hits: 2,
            replan_batches: 8,
        }
    }
}

impl StoreConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.dram_ns >= 0.0 && self.cold_ns >= 0.0,
            "store tier costs must be non-negative (dram_ns {}, cold_ns {})",
            self.dram_ns,
            self.cold_ns
        );
        anyhow::ensure!(
            self.replan_batches >= 1,
            "store.replan_batches must be >= 1"
        );
        Ok(())
    }
}

/// Top-level configuration bundle.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub hardware: HardwareConfig,
    pub scheme: SchemeConfig,
    pub workload: WorkloadConfig,
    pub obs: ObsConfig,
    pub slo: SloConfig,
    pub watch: WatchConfig,
    pub offline: OfflineConfig,
    pub store: StoreConfig,
    /// Directory with AOT artifacts for the PJRT runtime.
    pub artifacts_dir: String,
}

impl Config {
    /// Paper-default configuration.
    pub fn paper_default() -> Self {
        Self {
            artifacts_dir: "artifacts".to_string(),
            ..Default::default()
        }
    }

    /// Serving-entry-point defaults: the paper config with the workload
    /// sized for an interactive demo (history 4 000 / eval 1 024 instead
    /// of the full offline-report sizing). This is the base every
    /// `recross` subcommand overlays TOML and CLI flags onto.
    pub fn serving_default() -> Self {
        let mut cfg = Self::paper_default();
        cfg.workload.history_queries = 4_000;
        cfg.workload.eval_queries = 1_024;
        cfg
    }

    /// Open-loop-simulator defaults: [`Config::serving_default`] with the
    /// batcher wait window dropped to 5 µs — the discrete-event model
    /// serves µs-scale batches, so a 2 ms window would make every report
    /// pure batch-formation wait.
    pub fn open_loop_default() -> Self {
        let mut cfg = Self::serving_default();
        cfg.scheme.max_wait_us = 5;
        cfg
    }

    /// Load from a TOML file, overriding defaults.
    pub fn from_file(path: &str) -> Result<Self> {
        Self::from_file_with_base(path, Self::paper_default())
    }

    /// Load from a TOML file, overriding an explicit base configuration.
    pub fn from_file_with_base(path: &str, base: Self) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::from_toml_with_base(&text, base)
    }

    /// Parse from TOML text, overriding defaults.
    pub fn from_toml(text: &str) -> Result<Self> {
        Self::from_toml_with_base(text, Self::paper_default())
    }

    /// Parse from TOML text, overriding an explicit base configuration:
    /// fields the document does not mention keep the base's values.
    pub fn from_toml_with_base(text: &str, base: Self) -> Result<Self> {
        let doc = toml::Doc::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = base;
        let hw = &mut cfg.hardware;
        hw.xbar_rows = doc.usize_or("hardware.xbar_rows", hw.xbar_rows);
        hw.xbar_cols = doc.usize_or("hardware.xbar_cols", hw.xbar_cols);
        hw.bits_per_cell = doc.i64_or("hardware.bits_per_cell", hw.bits_per_cell as i64) as u32;
        hw.tile_xbars = doc.usize_or("hardware.tile_xbars", hw.tile_xbars);
        hw.adc_bits = doc.i64_or("hardware.adc_bits", hw.adc_bits as i64) as u32;
        hw.adc_share = doc.usize_or("hardware.adc_share", hw.adc_share);
        hw.read_mode_bits = doc.i64_or("hardware.read_mode_bits", hw.read_mode_bits as i64) as u32;
        hw.bus_width_bits = doc.usize_or("hardware.bus_width_bits", hw.bus_width_bits);
        hw.bus_channels = doc.usize_or("hardware.bus_channels", hw.bus_channels);
        hw.clock_mhz = doc.f64_or("hardware.clock_mhz", hw.clock_mhz);
        hw.dynamic_switch = doc.bool_or("hardware.dynamic_switch", hw.dynamic_switch);
        hw.embedding_dim = doc.usize_or("hardware.embedding_dim", hw.embedding_dim);
        hw.weight_bits = doc.i64_or("hardware.weight_bits", hw.weight_bits as i64) as u32;

        let sc = &mut cfg.scheme;
        sc.group_size = doc.usize_or("scheme.group_size", sc.group_size);
        sc.dup_ratio = doc.f64_or("scheme.dup_ratio", sc.dup_ratio);
        sc.batch_size = doc.usize_or("scheme.batch_size", sc.batch_size);
        sc.duplication = doc.bool_or("scheme.duplication", sc.duplication);
        sc.dynamic_switching = doc.bool_or("scheme.dynamic_switching", sc.dynamic_switching);
        // Clamp negatives to 0 (close immediately) instead of wrapping
        // to ~1.8e19 µs, which would silently disable the deadline
        // trigger.
        sc.max_wait_us = doc.i64_or("scheme.max_wait_us", sc.max_wait_us as i64).max(0) as u64;

        let wl = &mut cfg.workload;
        wl.dataset = doc.str_or("workload.dataset", &wl.dataset);
        wl.history_queries = doc.usize_or("workload.history_queries", wl.history_queries);
        wl.eval_queries = doc.usize_or("workload.eval_queries", wl.eval_queries);
        wl.seed = doc.i64_or("workload.seed", wl.seed as i64) as u64;
        wl.dense_features = doc.usize_or("workload.dense_features", wl.dense_features);

        let ob = &mut cfg.obs;
        ob.enabled = doc.bool_or("obs.enabled", ob.enabled);
        ob.sample_rate = doc.f64_or("obs.sample_rate", ob.sample_rate);
        ob.ring_capacity = doc.usize_or("obs.ring_capacity", ob.ring_capacity);

        let sl = &mut cfg.slo;
        sl.p99_sojourn_ns = doc.f64_or("slo.p99_sojourn_ns", sl.p99_sojourn_ns);
        sl.max_queue_depth = doc.f64_or("slo.max_queue_depth", sl.max_queue_depth);
        sl.fast_windows = doc.usize_or("slo.fast_windows", sl.fast_windows);
        sl.slow_windows = doc.usize_or("slo.slow_windows", sl.slow_windows);
        sl.slow_burn = doc.f64_or("slo.slow_burn", sl.slow_burn);

        let wa = &mut cfg.watch;
        wa.interval_ms = doc.i64_or("watch.interval_ms", wa.interval_ms as i64).max(0) as u64;
        wa.ring_capacity = doc.usize_or("watch.ring_capacity", wa.ring_capacity);
        wa.ticks = doc.usize_or("watch.ticks", wa.ticks);

        cfg.offline.workers = doc.usize_or("offline.workers", cfg.offline.workers);

        let st = &mut cfg.store;
        st.hot_tiles = doc.usize_or("store.hot_tiles", st.hot_tiles);
        st.dram_tiles = doc.usize_or("store.dram_tiles", st.dram_tiles);
        st.dram_ns = doc.f64_or("store.dram_ns", st.dram_ns);
        st.cold_ns = doc.f64_or("store.cold_ns", st.cold_ns);
        st.promote_hits = doc.i64_or("store.promote_hits", st.promote_hits as i64).max(0) as u64;
        st.replan_batches = doc.usize_or("store.replan_batches", st.replan_batches);

        cfg.artifacts_dir = doc.str_or("artifacts_dir", &cfg.artifacts_dir);
        cfg.validate()?;
        Ok(cfg)
    }

    /// Overlay explicitly passed CLI flags — the top (non-programmatic)
    /// layer of the precedence chain. Declared CLI defaults are *not*
    /// applied here; they live in the base config the subcommand chose
    /// (e.g. [`Config::serving_default`]), so a TOML file is never
    /// clobbered by a flag the user did not type. Unknown/undeclared
    /// option names are ignored, so one overlay serves every subcommand's
    /// `ArgSpec`.
    pub fn overlay_cli(&mut self, args: &Args) -> Result<()> {
        fn parse<T: std::str::FromStr>(args: &Args, name: &str) -> Result<T>
        where
            T::Err: std::fmt::Display,
        {
            args.get_as(name).map_err(anyhow::Error::msg)
        }
        if args.provided("dataset") {
            self.workload.dataset = args.get("dataset").to_string();
        }
        if args.provided("seed") {
            self.workload.seed = parse(args, "seed")?;
        }
        if args.provided("history") {
            self.workload.history_queries = parse(args, "history")?;
        }
        if args.provided("eval") {
            self.workload.eval_queries = parse(args, "eval")?;
        }
        if args.provided("max-wait-us") {
            self.scheme.max_wait_us = parse(args, "max-wait-us")?;
        }
        if args.provided("artifacts") {
            self.artifacts_dir = args.get("artifacts").to_string();
        }
        // `--obs` is a flag: presence enables, absence leaves the
        // TOML/base decision alone (a flag cannot express "false").
        if args.provided("obs") {
            self.obs.enabled = true;
        }
        if args.provided("obs-sample") {
            self.obs.sample_rate = parse(args, "obs-sample")?;
        }
        if args.provided("obs-ring") {
            self.obs.ring_capacity = parse(args, "obs-ring")?;
        }
        if args.provided("interval") {
            self.watch.interval_ms = parse(args, "interval")?;
        }
        if args.provided("ticks") {
            self.watch.ticks = parse(args, "ticks")?;
        }
        if args.provided("slo-p99-ns") {
            self.slo.p99_sojourn_ns = parse(args, "slo-p99-ns")?;
        }
        if args.provided("slo-depth") {
            self.slo.max_queue_depth = parse(args, "slo-depth")?;
        }
        // 0 is legal (= all cores), so this parses as a plain usize.
        if args.provided("workers") {
            self.offline.workers = parse(args, "workers")?;
        }
        if args.provided("store-hot") {
            self.store.hot_tiles = parse(args, "store-hot")?;
        }
        // 0 is legal (= unbounded DRAM), so this parses as a plain usize.
        if args.provided("store-dram") {
            self.store.dram_tiles = parse(args, "store-dram")?;
        }
        if args.provided("store-dram-ns") {
            self.store.dram_ns = parse(args, "store-dram-ns")?;
        }
        if args.provided("store-cold-ns") {
            self.store.cold_ns = parse(args, "store-cold-ns")?;
        }
        if args.provided("store-promote-hits") {
            self.store.promote_hits = parse(args, "store-promote-hits")?;
        }
        if args.provided("store-replan") {
            self.store.replan_batches = parse(args, "store-replan")?;
        }
        self.validate()
    }

    /// Validate all sections.
    pub fn validate(&self) -> Result<()> {
        self.hardware.validate()?;
        self.scheme.validate()?;
        self.obs.validate()?;
        self.slo.validate()?;
        self.watch.validate()?;
        self.store.validate()?;
        anyhow::ensure!(self.workload.history_queries > 0, "empty history");
        anyhow::ensure!(self.workload.dense_features > 0, "zero dense features");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table1() {
        let c = Config::paper_default();
        assert_eq!(c.hardware.xbar_rows, 64);
        assert_eq!(c.hardware.xbar_cols, 64);
        assert_eq!(c.hardware.bits_per_cell, 2);
        assert_eq!(c.hardware.adc_bits, 6);
        assert_eq!(c.hardware.bus_width_bits, 512);
        assert_eq!(c.scheme.batch_size, 256);
        c.validate().unwrap();
    }

    #[test]
    fn one_embedding_per_row_by_default() {
        let hw = HardwareConfig::default();
        // 16 features * 8 bits / 2 bits-per-cell = 64 cells = 1 row.
        assert_eq!(hw.cells_per_embedding(), 64);
        assert_eq!(hw.embeddings_per_xbar(), 64);
    }

    #[test]
    fn wide_embedding_spans_rows() {
        let hw = HardwareConfig {
            embedding_dim: 32,
            ..Default::default()
        };
        // 32*8/2 = 128 cells = 2 rows -> 32 embeddings per crossbar.
        assert_eq!(hw.embeddings_per_xbar(), 32);
    }

    #[test]
    fn toml_overrides() {
        let c = Config::from_toml(
            r#"
            [hardware]
            adc_bits = 8
            dynamic_switch = false
            [scheme]
            dup_ratio = 0.2
            batch_size = 128
            [workload]
            dataset = "automotive"
            seed = 7
            "#,
        )
        .unwrap();
        assert_eq!(c.hardware.adc_bits, 8);
        assert!(!c.hardware.dynamic_switch);
        assert_eq!(c.scheme.dup_ratio, 0.2);
        assert_eq!(c.scheme.batch_size, 128);
        assert_eq!(c.workload.dataset, "automotive");
        assert_eq!(c.workload.seed, 7);
        // untouched fields keep defaults
        assert_eq!(c.hardware.xbar_rows, 64);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(Config::from_toml("[scheme]\ndup_ratio = 1.5").is_err());
        assert!(Config::from_toml("[hardware]\nbits_per_cell = 9").is_err());
        assert!(Config::from_toml("[hardware]\nread_mode_bits = 7").is_err());
        assert!(Config::from_toml("[workload]\ndense_features = 0").is_err());
    }

    #[test]
    fn serving_and_open_loop_defaults() {
        let s = Config::serving_default();
        assert_eq!(s.workload.history_queries, 4_000);
        assert_eq!(s.workload.eval_queries, 1_024);
        assert_eq!(s.scheme.max_wait_us, 2_000);
        assert_eq!(s.workload.dense_features, 13);
        let o = Config::open_loop_default();
        assert_eq!(o.scheme.max_wait_us, 5);
        assert_eq!(o.workload.history_queries, 4_000);
    }

    #[test]
    fn toml_overlays_base_and_new_knobs() {
        let cfg = Config::from_toml_with_base(
            "[scheme]\nmax_wait_us = 77\n[workload]\ndense_features = 8",
            Config::open_loop_default(),
        )
        .unwrap();
        assert_eq!(cfg.scheme.max_wait_us, 77);
        assert_eq!(cfg.workload.dense_features, 8);
        // Untouched fields keep the *base*, not the paper default.
        assert_eq!(cfg.workload.history_queries, 4_000);
        // A negative wait clamps to "close immediately" instead of
        // wrapping to a deadline that never fires.
        let neg = Config::from_toml("[scheme]\nmax_wait_us = -1").unwrap();
        assert_eq!(neg.scheme.max_wait_us, 0);
    }

    #[test]
    fn obs_defaults_off_and_toml_overrides() {
        let c = Config::paper_default();
        assert!(!c.obs.enabled);
        assert_eq!(c.obs.sample_rate, 1.0);
        assert_eq!(c.obs.ring_capacity, 4_096);
        let c = Config::from_toml(
            "[obs]\nenabled = true\nsample_rate = 0.25\nring_capacity = 128",
        )
        .unwrap();
        assert!(c.obs.enabled);
        assert_eq!(c.obs.sample_rate, 0.25);
        assert_eq!(c.obs.ring_capacity, 128);
        // Out-of-range sampling rate is rejected.
        assert!(Config::from_toml("[obs]\nsample_rate = 1.5").is_err());
        assert!(Config::from_toml("[obs]\nsample_rate = -0.1").is_err());
    }

    #[test]
    fn obs_cli_overlay() {
        use crate::util::cli::ArgSpec;
        let spec = ArgSpec::new("t")
            .flag("obs", "")
            .opt("obs-sample", "1.0", "")
            .opt("obs-ring", "4096", "");
        let args = spec
            .parse(
                &["--obs", "--obs-sample", "0.5", "--obs-ring", "64"]
                    .iter()
                    .map(|s| s.to_string())
                    .collect::<Vec<_>>(),
            )
            .unwrap();
        let mut cfg = Config::serving_default();
        cfg.overlay_cli(&args).unwrap();
        assert!(cfg.obs.enabled);
        assert_eq!(cfg.obs.sample_rate, 0.5);
        assert_eq!(cfg.obs.ring_capacity, 64);
        // Absent flags leave the base alone.
        let none = spec.parse(&Vec::<String>::new()).unwrap();
        let mut cfg = Config::serving_default();
        cfg.obs.sample_rate = 0.75;
        cfg.overlay_cli(&none).unwrap();
        assert!(!cfg.obs.enabled);
        assert_eq!(cfg.obs.sample_rate, 0.75);
    }

    #[test]
    fn slo_watch_defaults_toml_and_validation() {
        let c = Config::paper_default();
        assert_eq!(c.slo.p99_sojourn_ns, 5_000_000.0);
        assert_eq!(c.slo.fast_windows, 1);
        assert_eq!(c.slo.slow_windows, 12);
        assert_eq!(c.watch.interval_ms, 1_000);
        assert_eq!(c.watch.ring_capacity, 512);
        assert_eq!(c.watch.ticks, 0);
        let c = Config::from_toml(
            "[slo]\np99_sojourn_ns = 2e6\nmax_queue_depth = 32.0\nslow_windows = 6\n\
             slow_burn = 0.75\n[watch]\ninterval_ms = 250\nring_capacity = 64\nticks = 10",
        )
        .unwrap();
        assert_eq!(c.slo.p99_sojourn_ns, 2e6);
        assert_eq!(c.slo.max_queue_depth, 32.0);
        assert_eq!(c.slo.slow_windows, 6);
        assert_eq!(c.slo.slow_burn, 0.75);
        assert_eq!(c.watch.interval_ms, 250);
        assert_eq!(c.watch.ring_capacity, 64);
        assert_eq!(c.watch.ticks, 10);
        // Degenerate rules are rejected through the one validate chain.
        assert!(Config::from_toml("[slo]\nslow_burn = 0.0").is_err());
        assert!(Config::from_toml("[slo]\nfast_windows = 0").is_err());
        assert!(Config::from_toml("[slo]\nfast_windows = 4\nslow_windows = 2").is_err());
        assert!(Config::from_toml("[watch]\ninterval_ms = 0").is_err());
        assert!(Config::from_toml("[watch]\nring_capacity = 0").is_err());
    }

    #[test]
    fn watch_cli_overlay_beats_toml() {
        use crate::util::cli::ArgSpec;
        let spec = ArgSpec::new("t")
            .opt("interval", "1000", "")
            .opt("ticks", "0", "")
            .opt("slo-p99-ns", "5000000", "")
            .opt("slo-depth", "64", "");
        let argv: Vec<String> = ["--interval", "100", "--ticks", "5", "--slo-p99-ns", "1e6"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = spec.parse(&argv).unwrap();
        let mut cfg = Config::from_toml_with_base(
            "[watch]\ninterval_ms = 400\n[slo]\nmax_queue_depth = 16.0",
            Config::open_loop_default(),
        )
        .unwrap();
        cfg.overlay_cli(&args).unwrap();
        // Explicit CLI beats TOML...
        assert_eq!(cfg.watch.interval_ms, 100);
        assert_eq!(cfg.watch.ticks, 5);
        assert_eq!(cfg.slo.p99_sojourn_ns, 1e6);
        // ...declared defaults do not clobber TOML, and untouched knobs
        // keep the base.
        assert_eq!(cfg.slo.max_queue_depth, 16.0);
        assert_eq!(cfg.watch.ring_capacity, 512);
    }

    #[test]
    fn offline_workers_defaults_toml_and_cli() {
        use crate::util::cli::ArgSpec;
        // Default: 0 = use every available core.
        let c = Config::paper_default();
        assert_eq!(c.offline.workers, 0);
        // TOML sets it...
        let c = Config::from_toml("[offline]\nworkers = 4").unwrap();
        assert_eq!(c.offline.workers, 4);
        // ...explicit CLI beats TOML, and 0 is a legal explicit value.
        let spec = ArgSpec::new("t").opt("workers", "0", "");
        let argv: Vec<String> = ["--workers", "2"].iter().map(|s| s.to_string()).collect();
        let args = spec.parse(&argv).unwrap();
        let mut cfg = Config::from_toml_with_base(
            "[offline]\nworkers = 8",
            Config::serving_default(),
        )
        .unwrap();
        cfg.overlay_cli(&args).unwrap();
        assert_eq!(cfg.offline.workers, 2);
        // The declared CLI default does not clobber TOML.
        let none = spec.parse(&Vec::<String>::new()).unwrap();
        let mut cfg = Config::from_toml_with_base(
            "[offline]\nworkers = 8",
            Config::serving_default(),
        )
        .unwrap();
        cfg.overlay_cli(&none).unwrap();
        assert_eq!(cfg.offline.workers, 8);
    }

    #[test]
    fn store_defaults_toml_and_cli() {
        use crate::util::cli::ArgSpec;
        let c = Config::paper_default();
        assert_eq!(c.store.hot_tiles, 64);
        assert_eq!(c.store.dram_tiles, 0);
        assert_eq!(c.store.dram_ns, 120.0);
        assert_eq!(c.store.cold_ns, 2_500.0);
        assert_eq!(c.store.promote_hits, 2);
        assert_eq!(c.store.replan_batches, 8);
        let c = Config::from_toml(
            "[store]\nhot_tiles = 16\ndram_tiles = 32\ndram_ns = 90.0\ncold_ns = 4000.0\n\
             promote_hits = 5\nreplan_batches = 4",
        )
        .unwrap();
        assert_eq!(c.store.hot_tiles, 16);
        assert_eq!(c.store.dram_tiles, 32);
        assert_eq!(c.store.dram_ns, 90.0);
        assert_eq!(c.store.cold_ns, 4_000.0);
        assert_eq!(c.store.promote_hits, 5);
        assert_eq!(c.store.replan_batches, 4);
        // Degenerate values rejected through the one validate chain.
        assert!(Config::from_toml("[store]\ndram_ns = -1.0").is_err());
        assert!(Config::from_toml("[store]\nreplan_batches = 0").is_err());
        // Explicit CLI beats TOML; declared defaults do not clobber it.
        let spec = ArgSpec::new("t")
            .opt("store-hot", "64", "")
            .opt("store-dram", "0", "")
            .opt("store-cold-ns", "2500", "");
        let argv: Vec<String> = ["--store-hot", "8", "--store-cold-ns", "9000"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = spec.parse(&argv).unwrap();
        let mut cfg = Config::from_toml_with_base(
            "[store]\nhot_tiles = 16\ndram_tiles = 2",
            Config::serving_default(),
        )
        .unwrap();
        cfg.overlay_cli(&args).unwrap();
        assert_eq!(cfg.store.hot_tiles, 8);
        assert_eq!(cfg.store.cold_ns, 9_000.0);
        assert_eq!(cfg.store.dram_tiles, 2);
    }

    #[test]
    fn overlay_cli_applies_explicit_flags_over_toml() {
        use crate::util::cli::ArgSpec;
        let spec = ArgSpec::new("t")
            .opt("dataset", "software", "")
            .opt("seed", "42", "")
            .opt("history", "4000", "")
            .opt("eval", "1024", "")
            .opt("max-wait-us", "5", "");
        let argv: Vec<String> = ["--seed", "7", "--max-wait-us", "9"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = spec.parse(&argv).unwrap();
        let mut cfg = Config::from_toml_with_base(
            "[workload]\ndataset = \"sports\"\nseed = 1\n[scheme]\nmax_wait_us = 50",
            Config::serving_default(),
        )
        .unwrap();
        cfg.overlay_cli(&args).unwrap();
        // Explicit CLI beats TOML...
        assert_eq!(cfg.workload.seed, 7);
        assert_eq!(cfg.scheme.max_wait_us, 9);
        // ...but declared CLI defaults do not clobber TOML values.
        assert_eq!(cfg.workload.dataset, "sports");
        // Base values survive where neither layer spoke.
        assert_eq!(cfg.workload.history_queries, 4_000);
    }
}
