//! Typed configuration for the whole system.
//!
//! Configuration is layered: built-in defaults reproduce the paper's
//! Table I setup exactly; a TOML file (parsed by the in-tree
//! [`toml`] subset parser) can override any field; the CLI can override a
//! handful of common knobs on top.

pub mod toml;

use crate::Result;
use anyhow::Context;

/// Crossbar / tile / ADC hardware configuration (paper Table I).
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareConfig {
    /// Crossbar rows (= wordlines = embeddings per crossbar). Paper: 64.
    pub xbar_rows: usize,
    /// Crossbar columns (= bitlines). Paper: 64.
    pub xbar_cols: usize,
    /// Storage bits per ReRAM cell. Paper: 2.
    pub bits_per_cell: u32,
    /// Crossbars per tile edge: a tile is `tile_dim x tile_dim` crossbars
    /// sharing peripheral circuitry. Paper tile: 256x256 cells = 4x4
    /// crossbars of 64x64.
    pub tile_xbars: usize,
    /// ADC resolution in bits. Paper: 6 (quantized down from 8).
    pub adc_bits: u32,
    /// Number of columns multiplexed onto one ADC (ISAAC-style sharing).
    pub adc_share: usize,
    /// Bits resolved per cycle by the read-mode sense path of the
    /// dynamic-switch ADC (paper §IV-B: read mode uses 3 of the 6 bits).
    pub read_mode_bits: u32,
    /// Global bus width in bits. Paper: 512.
    pub bus_width_bits: usize,
    /// Independent global-bus/NoC channels carrying activation results to
    /// the accumulation units. Activation results contend for these — the
    /// peripheral bandwidth wall that makes "fewer activations" the
    /// paper's headline lever.
    pub bus_channels: usize,
    /// Core clock in MHz for the digital periphery.
    pub clock_mhz: f64,
    /// Whether the dynamic-switch ADC (read/MAC switching) is enabled.
    pub dynamic_switch: bool,
    /// Embedding feature dimension (learned features per embedding).
    /// 16 features x 8-bit at 2 bits/cell = 64 cells = one 64-col row.
    pub embedding_dim: usize,
    /// Fixed-point bits per embedding element as stored in cells.
    pub weight_bits: u32,
}

impl Default for HardwareConfig {
    fn default() -> Self {
        Self {
            xbar_rows: 64,
            xbar_cols: 64,
            bits_per_cell: 2,
            tile_xbars: 4,
            adc_bits: 6,
            adc_share: 8,
            read_mode_bits: 3,
            bus_width_bits: 512,
            bus_channels: 16,
            clock_mhz: 1000.0,
            dynamic_switch: true,
            embedding_dim: 16,
            weight_bits: 8,
        }
    }
}

impl HardwareConfig {
    /// Cells needed to store one embedding vector.
    pub fn cells_per_embedding(&self) -> usize {
        (self.embedding_dim * self.weight_bits as usize).div_ceil(self.bits_per_cell as usize)
    }

    /// Embeddings that fit in one crossbar (a.k.a. the grouping size).
    /// With the default config each embedding occupies exactly one row.
    pub fn embeddings_per_xbar(&self) -> usize {
        let rows_per_emb = self.cells_per_embedding().div_ceil(self.xbar_cols);
        self.xbar_rows / rows_per_emb.max(1)
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.xbar_rows > 0 && self.xbar_cols > 0, "zero crossbar dims");
        anyhow::ensure!(
            (1..=4).contains(&self.bits_per_cell),
            "bits_per_cell {} outside 1..=4",
            self.bits_per_cell
        );
        anyhow::ensure!(
            self.read_mode_bits <= self.adc_bits,
            "read-mode bits {} exceed ADC resolution {}",
            self.read_mode_bits,
            self.adc_bits
        );
        anyhow::ensure!(
            self.adc_share >= 1 && self.adc_share <= self.xbar_cols,
            "adc_share {} outside 1..=cols",
            self.adc_share
        );
        anyhow::ensure!(self.embeddings_per_xbar() >= 1, "embedding too large for crossbar");
        anyhow::ensure!(self.bus_channels >= 1, "need at least one bus channel");
        Ok(())
    }
}

/// ReCross scheme configuration (§III).
#[derive(Debug, Clone, PartialEq)]
pub struct SchemeConfig {
    /// Group size for Algorithm 1; defaults to embeddings-per-crossbar.
    pub group_size: usize,
    /// Duplication area budget as a fraction of baseline crossbar count
    /// (Fig. 10 sweeps 0 / 0.05 / 0.10 / 0.20).
    pub dup_ratio: f64,
    /// Inference batch size (paper evaluates batch 256).
    pub batch_size: usize,
    /// Enable access-aware duplication (§III-C).
    pub duplication: bool,
    /// Enable energy-aware dynamic switching (§III-D).
    pub dynamic_switching: bool,
}

impl Default for SchemeConfig {
    fn default() -> Self {
        Self {
            group_size: 64,
            dup_ratio: 0.10,
            batch_size: 256,
            duplication: true,
            dynamic_switching: true,
        }
    }
}

impl SchemeConfig {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.group_size > 0, "zero group size");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.dup_ratio),
            "dup_ratio {} outside [0,1]",
            self.dup_ratio
        );
        anyhow::ensure!(self.batch_size > 0, "zero batch size");
        Ok(())
    }
}

/// Workload generation configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    /// Dataset name (one of the five Amazon categories, or "custom").
    pub dataset: String,
    /// Queries in the history trace used for the offline phase.
    pub history_queries: usize,
    /// Queries in the evaluation trace.
    pub eval_queries: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            dataset: "software".to_string(),
            history_queries: 20_000,
            eval_queries: 4_096,
            seed: 42,
        }
    }
}

/// Top-level configuration bundle.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    pub hardware: HardwareConfig,
    pub scheme: SchemeConfig,
    pub workload: WorkloadConfig,
    /// Directory with AOT artifacts for the PJRT runtime.
    pub artifacts_dir: String,
}

impl Config {
    /// Paper-default configuration.
    pub fn paper_default() -> Self {
        Self {
            artifacts_dir: "artifacts".to_string(),
            ..Default::default()
        }
    }

    /// Load from a TOML file, overriding defaults.
    pub fn from_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::from_toml(&text)
    }

    /// Parse from TOML text, overriding defaults.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = toml::Doc::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = Self::paper_default();
        let hw = &mut cfg.hardware;
        hw.xbar_rows = doc.usize_or("hardware.xbar_rows", hw.xbar_rows);
        hw.xbar_cols = doc.usize_or("hardware.xbar_cols", hw.xbar_cols);
        hw.bits_per_cell = doc.i64_or("hardware.bits_per_cell", hw.bits_per_cell as i64) as u32;
        hw.tile_xbars = doc.usize_or("hardware.tile_xbars", hw.tile_xbars);
        hw.adc_bits = doc.i64_or("hardware.adc_bits", hw.adc_bits as i64) as u32;
        hw.adc_share = doc.usize_or("hardware.adc_share", hw.adc_share);
        hw.read_mode_bits = doc.i64_or("hardware.read_mode_bits", hw.read_mode_bits as i64) as u32;
        hw.bus_width_bits = doc.usize_or("hardware.bus_width_bits", hw.bus_width_bits);
        hw.bus_channels = doc.usize_or("hardware.bus_channels", hw.bus_channels);
        hw.clock_mhz = doc.f64_or("hardware.clock_mhz", hw.clock_mhz);
        hw.dynamic_switch = doc.bool_or("hardware.dynamic_switch", hw.dynamic_switch);
        hw.embedding_dim = doc.usize_or("hardware.embedding_dim", hw.embedding_dim);
        hw.weight_bits = doc.i64_or("hardware.weight_bits", hw.weight_bits as i64) as u32;

        let sc = &mut cfg.scheme;
        sc.group_size = doc.usize_or("scheme.group_size", sc.group_size);
        sc.dup_ratio = doc.f64_or("scheme.dup_ratio", sc.dup_ratio);
        sc.batch_size = doc.usize_or("scheme.batch_size", sc.batch_size);
        sc.duplication = doc.bool_or("scheme.duplication", sc.duplication);
        sc.dynamic_switching = doc.bool_or("scheme.dynamic_switching", sc.dynamic_switching);

        let wl = &mut cfg.workload;
        wl.dataset = doc.str_or("workload.dataset", &wl.dataset);
        wl.history_queries = doc.usize_or("workload.history_queries", wl.history_queries);
        wl.eval_queries = doc.usize_or("workload.eval_queries", wl.eval_queries);
        wl.seed = doc.i64_or("workload.seed", wl.seed as i64) as u64;

        cfg.artifacts_dir = doc.str_or("artifacts_dir", &cfg.artifacts_dir);
        cfg.validate()?;
        Ok(cfg)
    }

    /// Validate all sections.
    pub fn validate(&self) -> Result<()> {
        self.hardware.validate()?;
        self.scheme.validate()?;
        anyhow::ensure!(self.workload.history_queries > 0, "empty history");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table1() {
        let c = Config::paper_default();
        assert_eq!(c.hardware.xbar_rows, 64);
        assert_eq!(c.hardware.xbar_cols, 64);
        assert_eq!(c.hardware.bits_per_cell, 2);
        assert_eq!(c.hardware.adc_bits, 6);
        assert_eq!(c.hardware.bus_width_bits, 512);
        assert_eq!(c.scheme.batch_size, 256);
        c.validate().unwrap();
    }

    #[test]
    fn one_embedding_per_row_by_default() {
        let hw = HardwareConfig::default();
        // 16 features * 8 bits / 2 bits-per-cell = 64 cells = 1 row.
        assert_eq!(hw.cells_per_embedding(), 64);
        assert_eq!(hw.embeddings_per_xbar(), 64);
    }

    #[test]
    fn wide_embedding_spans_rows() {
        let hw = HardwareConfig {
            embedding_dim: 32,
            ..Default::default()
        };
        // 32*8/2 = 128 cells = 2 rows -> 32 embeddings per crossbar.
        assert_eq!(hw.embeddings_per_xbar(), 32);
    }

    #[test]
    fn toml_overrides() {
        let c = Config::from_toml(
            r#"
            [hardware]
            adc_bits = 8
            dynamic_switch = false
            [scheme]
            dup_ratio = 0.2
            batch_size = 128
            [workload]
            dataset = "automotive"
            seed = 7
            "#,
        )
        .unwrap();
        assert_eq!(c.hardware.adc_bits, 8);
        assert!(!c.hardware.dynamic_switch);
        assert_eq!(c.scheme.dup_ratio, 0.2);
        assert_eq!(c.scheme.batch_size, 128);
        assert_eq!(c.workload.dataset, "automotive");
        assert_eq!(c.workload.seed, 7);
        // untouched fields keep defaults
        assert_eq!(c.hardware.xbar_rows, 64);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(Config::from_toml("[scheme]\ndup_ratio = 1.5").is_err());
        assert!(Config::from_toml("[hardware]\nbits_per_cell = 9").is_err());
        assert!(Config::from_toml("[hardware]\nread_mode_bits = 7").is_err());
    }
}
