//! A TOML-subset parser (offline stand-in for the `toml` crate).
//!
//! Supports the subset the ReCross config files use:
//! `[section]` / `[section.sub]` headers, `key = value` pairs with string,
//! integer, float, boolean, and flat array values, `#` comments, and basic
//! escape sequences in strings. No dotted keys, no inline tables, no
//! multi-line strings — config files here don't need them.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// A parsed document: `"section.key" -> Value` with dotted full paths.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Doc {
    entries: BTreeMap<String, Value>,
}

/// Parse error with 1-based line number.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Doc {
    /// Parse a TOML-subset document.
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: String| ParseError {
                line: lineno + 1,
                msg,
            };
            if let Some(inner) = line.strip_prefix('[') {
                let inner = inner
                    .strip_suffix(']')
                    .ok_or_else(|| err("unterminated section header".into()))?
                    .trim();
                if inner.is_empty() {
                    return Err(err("empty section name".into()));
                }
                if !inner
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == '-')
                {
                    return Err(err(format!("invalid section name {inner:?}")));
                }
                section = inner.to_string();
                continue;
            }
            let (key, rest) = line
                .split_once('=')
                .ok_or_else(|| err(format!("expected `key = value`, got {line:?}")))?;
            let key = key.trim();
            if key.is_empty()
                || !key
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
            {
                return Err(err(format!("invalid key {key:?}")));
            }
            let value = parse_value(rest.trim()).map_err(|m| err(m))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if entries.insert(full.clone(), value).is_some() {
                return Err(err(format!("duplicate key {full:?}")));
            }
        }
        Ok(Self { entries })
    }

    /// Look up a value by full dotted path.
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    /// Typed getters with defaults (config ergonomics).
    pub fn str_or(&self, path: &str, default: &str) -> String {
        self.get(path)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }
    pub fn i64_or(&self, path: &str, default: i64) -> i64 {
        self.get(path).and_then(|v| v.as_i64()).unwrap_or(default)
    }
    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(|v| v.as_f64()).unwrap_or(default)
    }
    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(|v| v.as_bool()).unwrap_or(default)
    }
    pub fn usize_or(&self, path: &str, default: usize) -> usize {
        self.i64_or(path, default as i64).max(0) as usize
    }

    /// All keys under a section prefix (e.g. `"datasets"`).
    pub fn keys_under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let pat = format!("{prefix}.");
        self.entries
            .keys()
            .filter(move |k| k.starts_with(&pat))
            .map(|k| k.as_str())
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a string literal must not start a comment.
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str && !escaped => {
                escaped = true;
                continue;
            }
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
        escaped = false;
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        return parse_string(rest).map(Value::Str);
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut out = Vec::new();
        for part in split_array_items(inner)? {
            let part = part.trim();
            if !part.is_empty() {
                out.push(parse_value(part)?);
            }
        }
        return Ok(Value::Array(out));
    }
    // Numbers: underscores allowed as separators.
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if cleaned.contains('.') || cleaned.contains('e') || cleaned.contains('E') {
        cleaned
            .parse::<f64>()
            .map(Value::Float)
            .map_err(|_| format!("invalid float {s:?}"))
    } else {
        cleaned
            .parse::<i64>()
            .map(Value::Int)
            .map_err(|_| format!("invalid value {s:?}"))
    }
}

fn parse_string(rest: &str) -> Result<String, String> {
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => {
                let tail: String = chars.collect();
                if !tail.trim().is_empty() {
                    return Err(format!("trailing garbage after string: {tail:?}"));
                }
                return Ok(out);
            }
            '\\' => match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                other => return Err(format!("bad escape \\{other:?}")),
            },
            c => out.push(c),
        }
    }
    Err("unterminated string".into())
}

/// Split array items on top-level commas (strings may contain commas).
fn split_array_items(s: &str) -> Result<Vec<&str>, String> {
    let mut items = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in s.char_indices() {
        match c {
            '\\' if in_str && !escaped => {
                escaped = true;
                continue;
            }
            '"' if !escaped => in_str = !in_str,
            ',' if !in_str => {
                items.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
        escaped = false;
    }
    if in_str {
        return Err("unterminated string in array".into());
    }
    items.push(&s[start..]);
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = Doc::parse(
            r#"
            # top comment
            name = "recross"
            [hardware]
            rows = 64
            freq_mhz = 1000.0
            dynamic_switch = true
            [hardware.adc]
            bits = 6
            "#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "recross");
        assert_eq!(doc.i64_or("hardware.rows", 0), 64);
        assert_eq!(doc.f64_or("hardware.freq_mhz", 0.0), 1000.0);
        assert!(doc.bool_or("hardware.dynamic_switch", false));
        assert_eq!(doc.i64_or("hardware.adc.bits", 0), 6);
    }

    #[test]
    fn arrays_and_inline_comments() {
        let doc = Doc::parse("ratios = [0.0, 0.05, 0.1, 0.2] # sweep\nnames = [\"a\", \"b,c\"]")
            .unwrap();
        let r = doc.get("ratios").unwrap().as_array().unwrap();
        assert_eq!(r.len(), 4);
        assert_eq!(r[1].as_f64(), Some(0.05));
        let n = doc.get("names").unwrap().as_array().unwrap();
        assert_eq!(n[1].as_str(), Some("b,c"));
    }

    #[test]
    fn string_escapes() {
        let doc = Doc::parse(r#"s = "a\"b\n\tc\\d""#).unwrap();
        assert_eq!(doc.str_or("s", ""), "a\"b\n\tc\\d");
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = Doc::parse(r##"s = "a#b" # real comment"##).unwrap();
        assert_eq!(doc.str_or("s", ""), "a#b");
    }

    #[test]
    fn underscored_numbers() {
        let doc = Doc::parse("n = 26_815").unwrap();
        assert_eq!(doc.i64_or("n", 0), 26_815);
    }

    #[test]
    fn int_coerces_to_f64() {
        let doc = Doc::parse("x = 3").unwrap();
        assert_eq!(doc.f64_or("x", 0.0), 3.0);
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(Doc::parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn bad_section_rejected() {
        assert!(Doc::parse("[unclosed").is_err());
        assert!(Doc::parse("[]").is_err());
    }

    #[test]
    fn bad_value_rejected() {
        assert!(Doc::parse("a = nonsense").is_err());
        assert!(Doc::parse("a = \"unterminated").is_err());
        assert!(Doc::parse("a =").is_err());
    }

    #[test]
    fn keys_under_prefix() {
        let doc = Doc::parse("[d]\nx = 1\ny = 2\n[e]\nz = 3").unwrap();
        let ks: Vec<_> = doc.keys_under("d").collect();
        assert_eq!(ks, vec!["d.x", "d.y"]);
    }

    #[test]
    fn error_reports_line() {
        let e = Doc::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
    }
}
