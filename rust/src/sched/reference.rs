//! The naive scheduler hot path, preserved verbatim as a differential
//! oracle.
//!
//! This is the pre-data-oriented implementation of [`super::Scheduler`]:
//! an O(copies) linear scan per activation for replica selection, an
//! O(bus_channels) scan per activation for bus-channel selection, and a
//! per-query `sort_unstable` in its run decomposition. It is kept — not
//! deleted — because the optimized scheduler's contract is *bit-identical
//! schedules*: `tests/sched_equivalence.rs` fuzzes the two against each
//! other on seeded workloads and requires exact `ExecStats` and per-query
//! `finish_ns` equality, covering replication, cold-start overflow,
//! nMARS, and the timed path. `benches/throughput.rs` runs both and
//! records the speedup and comparison-count ratio into
//! `BENCH_sched.json`.
//!
//! Apart from the comparison counter threaded through
//! [`least_loaded`] (one integer add per float compare, mirroring
//! [`super::minslot::MinSlotTable`]'s accounting), this file must stay a
//! faithful copy of the naive loop: fixes to the *model* belong in both
//! implementations, fixes to *performance* belong only in the optimized
//! one.

use super::ExecStats;
use crate::allocation::Replication;
use crate::grouping::Mapping;
use crate::workload::Query;
use crate::xbar::{AdcMode, CrossbarModel};

/// First least-loaded slot in a busy-until table (ties break toward the
/// lower index — the first minimum encountered by the scan). Counts one
/// comparison per scanned element after the first.
#[inline]
fn least_loaded(busy: &[f64], comparisons: &mut u64) -> (usize, f64) {
    debug_assert!(!busy.is_empty(), "least_loaded over an empty slot table");
    *comparisons += (busy.len() - 1) as u64;
    let mut idx = 0;
    let mut best = busy[0];
    for (i, &b) in busy.iter().enumerate().skip(1) {
        if b < best {
            best = b;
            idx = i;
        }
    }
    (idx, best)
}

/// Reusable per-batch scratch buffers for the reference scheduler.
#[derive(Debug, Default)]
pub struct ReferenceScratch {
    /// (group, rows) runs for the current query.
    runs: Vec<(u32, u32)>,
    /// group ids of the current query (pre-sort buffer).
    groups: Vec<u32>,
    /// busy-until time per physical crossbar.
    busy: Vec<f64>,
    /// busy-until time per global-bus channel.
    bus: Vec<f64>,
    /// Value comparisons performed by slot selection.
    comparisons: u64,
}

impl ReferenceScratch {
    /// Value comparisons since the last
    /// [`ReferenceScratch::reset_comparisons`] (accumulates across
    /// batches, like the optimized scheduler's counters).
    pub fn comparisons(&self) -> u64 {
        self.comparisons
    }

    /// Zero the comparison counter.
    pub fn reset_comparisons(&mut self) {
        self.comparisons = 0;
    }
}

/// The naive scheduler over a fixed mapping + replication plan. Same
/// model, same API surface as [`super::Scheduler`]; O(slots) selection.
#[derive(Debug)]
pub struct ReferenceScheduler<'a> {
    mapping: &'a Mapping,
    replication: &'a Replication,
    model: &'a CrossbarModel,
    /// Physical crossbar id of the first replica of each group.
    replica_base: Vec<u32>,
    /// Precomputed activation cost per activated-row count.
    cost_by_rows: Vec<crate::xbar::ActivationCost>,
}

impl<'a> ReferenceScheduler<'a> {
    pub fn new(
        mapping: &'a Mapping,
        replication: &'a Replication,
        model: &'a CrossbarModel,
        dynamic_switch: bool,
    ) -> Self {
        assert_eq!(
            mapping.num_groups(),
            replication.copies.len(),
            "replication plan does not match mapping"
        );
        let mut replica_base = Vec::with_capacity(mapping.num_groups());
        let mut next = 0u32;
        for &c in &replication.copies {
            replica_base.push(next);
            next += c;
        }
        let cost_by_rows = (0..=mapping.group_size)
            .map(|r| model.activation(r.max(1), dynamic_switch))
            .collect();
        Self {
            mapping,
            replication,
            model,
            replica_base,
            cost_by_rows,
        }
    }

    /// Total physical crossbars.
    pub fn num_physical(&self) -> usize {
        self.replication.total_crossbars
    }

    /// Simulate one batch (all queries arrive at t=0).
    pub fn run_batch(&self, queries: &[Query], scratch: &mut ReferenceScratch) -> ExecStats {
        self.run_batch_inner(queries, scratch, None)
    }

    /// As [`ReferenceScheduler::run_batch`], additionally reporting
    /// per-query finish times (ns relative to batch start, one entry per
    /// input query in order; empty queries finish at 0).
    pub fn run_batch_timed(
        &self,
        queries: &[Query],
        scratch: &mut ReferenceScratch,
        finish_ns: &mut Vec<f64>,
    ) -> ExecStats {
        finish_ns.clear();
        finish_ns.reserve(queries.len());
        self.run_batch_inner(queries, scratch, Some(finish_ns))
    }

    fn run_batch_inner(
        &self,
        queries: &[Query],
        scratch: &mut ReferenceScratch,
        mut finish_ns: Option<&mut Vec<f64>>,
    ) -> ExecStats {
        scratch.busy.clear();
        scratch.busy.resize(self.num_physical(), 0.0);
        scratch.bus.clear();
        scratch.bus.resize(self.model.bus_channels(), 0.0);
        let (add_ns, add_pj) = self.model.vector_add();
        let flit_ns = self.model.bus_flit_ns();

        let mut stats = ExecStats::default();
        let mut batch_finish = 0.0f64;

        for q in queries {
            if q.is_empty() {
                if let Some(f) = finish_ns.as_deref_mut() {
                    f.push(0.0);
                }
                continue;
            }
            self.query_runs(q, scratch);
            let mut query_finish = 0.0f64;
            let k = scratch.runs.len();

            for &(group, rows) in &scratch.runs {
                let cost = self.cost_by_rows[rows as usize];
                // least-loaded replica of this group
                let base = self.replica_base[group as usize] as usize;
                let copies = self.replication.copies_of(group) as usize;
                let (slot, start) =
                    least_loaded(&scratch.busy[base..base + copies], &mut scratch.comparisons);
                let finish = start + cost.latency_ns;
                scratch.busy[base + slot] = finish;

                // Result transfer on the least-busy global-bus channel.
                let (chan, chan_busy) = least_loaded(&scratch.bus, &mut scratch.comparisons);
                let t_start = finish.max(chan_busy);
                let t_finish = t_start + cost.bus_flits as f64 * flit_ns;
                scratch.bus[chan] = t_finish;

                stats.stall_ns += start; // queue wait from batch arrival
                stats.bus_wait_ns += t_start - finish;
                stats.energy_pj += cost.energy_pj;
                stats.activations += 1;
                stats.rows_activated += rows as u64;
                if rows == 1 {
                    stats.single_row_activations += 1;
                }
                match cost.mode {
                    AdcMode::Mac => stats.mac_activations += 1,
                    AdcMode::Read => stats.read_activations += 1,
                }
                query_finish = query_finish.max(t_finish);
            }

            // Merge partial sums across the k crossbars.
            if k > 1 {
                query_finish += (k - 1) as f64 * add_ns;
                stats.energy_pj += (k - 1) as f64 * add_pj;
            }
            if let Some(f) = finish_ns.as_deref_mut() {
                f.push(query_finish);
            }
            batch_finish = batch_finish.max(query_finish);
            stats.queries += 1;
            stats.lookups += q.len() as u64;
        }
        stats.completion_ns = batch_finish;
        stats
    }

    /// nMARS dataflow over the same mapping (parallel in-memory row
    /// lookups, sequential external aggregation).
    pub fn run_batch_nmars(&self, queries: &[Query], scratch: &mut ReferenceScratch) -> ExecStats {
        scratch.busy.clear();
        scratch.busy.resize(self.num_physical(), 0.0);
        scratch.bus.clear();
        scratch.bus.resize(self.model.bus_channels(), 0.0);
        let (add_ns, add_pj) = self.model.vector_add();
        let lookup = self.model.row_lookup();
        let flit_ns = self.model.bus_flit_ns();

        let mut stats = ExecStats::default();
        let mut batch_finish = 0.0f64;

        for q in queries {
            if q.is_empty() {
                continue;
            }
            let mut last_read = 0.0f64;
            for &e in &q.items {
                let slot = self.mapping.slot_of(e);
                let base = self.replica_base[slot.group as usize] as usize;
                let copies = self.replication.copies_of(slot.group) as usize;
                let (rep, start_busy) =
                    least_loaded(&scratch.busy[base..base + copies], &mut scratch.comparisons);
                let finish = start_busy + lookup.latency_ns;
                scratch.busy[base + rep] = finish;
                // Every looked-up row ships over the global bus.
                let (chan, chan_busy) = least_loaded(&scratch.bus, &mut scratch.comparisons);
                let t_start = finish.max(chan_busy);
                let t_finish = t_start + lookup.bus_flits as f64 * flit_ns;
                scratch.bus[chan] = t_finish;
                stats.stall_ns += start_busy;
                stats.bus_wait_ns += t_start - finish;
                stats.energy_pj += lookup.energy_pj;
                stats.activations += 1;
                stats.rows_activated += 1;
                stats.single_row_activations += 1;
                stats.read_activations += 1; // gated single-row sense
                last_read = last_read.max(t_finish);
            }
            // Sequential external aggregation (the nMARS bottleneck).
            let adds = (q.len() - 1) as f64;
            let query_finish = last_read + adds * add_ns;
            stats.energy_pj += adds * add_pj;
            batch_finish = batch_finish.max(query_finish);
            stats.queries += 1;
            stats.lookups += q.len() as u64;
        }
        stats.completion_ns = batch_finish;
        stats
    }

    /// Decompose a query into `(group, rows)` runs: sort every item's
    /// group id, then emit ascending-group runs with rows clamped to
    /// `group_size` (distinct cold-start ids collapse onto the overflow
    /// group's row 0 and can nominally exceed the crossbar height).
    fn query_runs(&self, q: &Query, scratch: &mut ReferenceScratch) {
        let max_rows = self.mapping.group_size.max(1) as u32;
        scratch.groups.clear();
        scratch
            .groups
            .extend(q.items.iter().map(|&e| self.mapping.slot_of(e).group));
        scratch.groups.sort_unstable();
        scratch.runs.clear();
        let mut i = 0;
        while i < scratch.groups.len() {
            let g = scratch.groups[i];
            let mut rows = 0u32;
            while i < scratch.groups.len() && scratch.groups[i] == g {
                rows += 1;
                i += 1;
            }
            scratch.runs.push((g, rows.min(max_rows)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::xbar::CircuitParams;

    fn model() -> CrossbarModel {
        CrossbarModel::new(&HardwareConfig::default(), &CircuitParams::default())
    }

    #[test]
    fn counts_linear_scan_comparisons() {
        // 2 groups x 3 copies, 16 bus channels: every activation scans
        // 3 replica slots (2 cmps) and 16 channels (15 cmps).
        let map = Mapping::from_groups(vec![vec![0, 1], vec![2, 3]], 2, 4);
        let rep = Replication::from_copies(vec![3, 3], 4);
        let m = model();
        let s = ReferenceScheduler::new(&map, &rep, &m, true);
        let mut scratch = ReferenceScratch::default();
        // One query touching one group = exactly one activation.
        let stats = s.run_batch(&[Query::new(vec![0, 1])], &mut scratch);
        assert_eq!(stats.activations, 1);
        assert_eq!(scratch.comparisons(), 2 + 15);
        scratch.reset_comparisons();
        assert_eq!(scratch.comparisons(), 0);
    }
}
