//! Online-phase scheduler: maps a batch of queries onto physical crossbars
//! and simulates completion time with a discrete-event model.
//!
//! Model (matches the paper's completion-time metric):
//!
//! * Every logical group `g` owns `copies[g]` physical crossbars. A query
//!   touching `g` is served by the **least-loaded replica** (greedy
//!   earliest-finish selection — this is where access-aware duplication
//!   buys parallelism).
//! * A physical crossbar serves activations serially; an activation's
//!   latency comes from [`CrossbarModel::activation`]. Waiting for a busy
//!   crossbar is recorded as **stall time** (the Fig. 4 contention the
//!   paper describes: "later queries experience long delays while waiting
//!   for prior queries to complete").
//! * A query's partial sums from `k` crossbars merge through `k-1` digital
//!   vector adds on its tile reducer; the query finishes when its last
//!   activation + merge completes. The batch completes when every query
//!   has finished.
//!
//! The same event loop also implements the nMARS dataflow (parallel
//! in-memory row lookups + *sequential* external aggregation) so all
//! schemes share one timing substrate.
//!
//! ## Hot-path layout (§Perf iteration 4)
//!
//! The inner loop is data-oriented: replica and bus-channel selection go
//! through [`minslot::MinSlotTable`] — a tournament tree with a flat-scan
//! fast path below [`minslot::FLAT_CROSSOVER`] — giving O(log C) instead
//! of O(C) selection on heavily replicated / wide-bus configurations, and
//! run decomposition is sort-free via the epoch-stamped
//! [`TouchSet`](crate::grouping::TouchSet) (O(k) accumulation; only the
//! ≤k distinct touched groups are sorted to preserve ascending-group run
//! order). The produced schedule is **bit-identical** to the naive loop,
//! which is preserved as [`reference::ReferenceScheduler`] and
//! differentially fuzzed against this one in
//! `tests/sched_equivalence.rs`; `benches/throughput.rs` measures both
//! and writes the comparison into `BENCH_sched.json`. See DESIGN.md
//! §"Simulator performance".

pub mod minslot;
pub mod reference;

pub use minslot::MinSlotTable;
pub use reference::{ReferenceScheduler, ReferenceScratch};

use crate::allocation::Replication;
use crate::grouping::{Mapping, TouchSet};
use crate::workload::Query;
use crate::xbar::{AdcMode, CrossbarModel};

/// Aggregated execution statistics for one batch (or a whole trace).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExecStats {
    /// Batch completion time (max query finish), ns.
    pub completion_ns: f64,
    /// Total energy, pJ.
    pub energy_pj: f64,
    /// Total crossbar activations (MAC or read).
    pub activations: u64,
    /// Activations that ran in full MAC mode.
    pub mac_activations: u64,
    /// Activations served in gated read mode.
    pub read_activations: u64,
    /// Activations that touched exactly one row (Fig. 6's quantity,
    /// independent of whether the dynamic switch was enabled).
    pub single_row_activations: u64,
    /// Total wordlines activated across all activations.
    pub rows_activated: u64,
    /// Total time queries spent queued behind busy crossbars, ns.
    pub stall_ns: f64,
    /// Total time activation results waited for a free bus channel, ns.
    pub bus_wait_ns: f64,
    /// Queries processed.
    pub queries: u64,
    /// Total embedding lookups processed.
    pub lookups: u64,
}

impl ExecStats {
    /// Merge another batch's stats (sequential batches: completion adds).
    ///
    /// Only correct when `other` ran *after* this work on the same
    /// executor. For independent executors running concurrently (e.g.
    /// cluster shards) use [`ExecStats::merge_parallel`].
    pub fn accumulate(&mut self, other: &ExecStats) {
        self.completion_ns += other.completion_ns;
        self.energy_pj += other.energy_pj;
        self.activations += other.activations;
        self.mac_activations += other.mac_activations;
        self.read_activations += other.read_activations;
        self.single_row_activations += other.single_row_activations;
        self.rows_activated += other.rows_activated;
        self.stall_ns += other.stall_ns;
        self.bus_wait_ns += other.bus_wait_ns;
        self.queries += other.queries;
        self.lookups += other.lookups;
    }

    /// Merge stats from an *independent executor running concurrently*
    /// (e.g. another shard of a sharded pool): completion time is the max
    /// across executors — the pool finishes when its slowest member does —
    /// while energy and every counter sum exactly as in
    /// [`ExecStats::accumulate`].
    pub fn merge_parallel(&mut self, other: &ExecStats) {
        let completion = self.completion_ns.max(other.completion_ns);
        self.accumulate(other);
        self.completion_ns = completion;
    }

    /// Mean completion time per query, ns.
    pub fn ns_per_query(&self) -> f64 {
        if self.queries == 0 {
            0.0
        } else {
            self.completion_ns / self.queries as f64
        }
    }

    /// Energy per lookup, pJ.
    pub fn pj_per_lookup(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.energy_pj / self.lookups as f64
        }
    }

    /// Fraction of activations that were single-row.
    pub fn single_row_share(&self) -> f64 {
        if self.activations == 0 {
            0.0
        } else {
            self.single_row_activations as f64 / self.activations as f64
        }
    }
}

/// Scheduler over a fixed mapping + replication plan.
#[derive(Debug)]
pub struct Scheduler<'a> {
    mapping: &'a Mapping,
    replication: &'a Replication,
    model: &'a CrossbarModel,
    /// Physical crossbar id of the first replica of each group.
    replica_base: Vec<u32>,
    /// Precomputed activation cost per activated-row count (§Perf
    /// iteration 3: the circuit model is pure in `rows`, so the per-
    /// activation float math is hoisted out of the batch loop).
    cost_by_rows: Vec<crate::xbar::ActivationCost>,
    /// Layout of the replica busy table: flat when the longest replica
    /// range (max copies) fits a scan, tree otherwise. Decided once here,
    /// not per batch — see [`minslot`]'s crossover discussion.
    busy_flat: bool,
    /// Layout of the bus-channel table (keyed on channel count).
    bus_flat: bool,
}

/// Reusable per-batch scratch buffers (hot path: allocation-free).
#[derive(Debug, Default)]
pub struct Scratch {
    /// (group, rows) runs for the current query.
    runs: Vec<(u32, u32)>,
    /// Epoch-stamped per-group touch counters (sort-free run decomposition).
    touch: TouchSet,
    /// Busy-until table per physical crossbar.
    busy: MinSlotTable,
    /// Busy-until table per global-bus channel.
    bus: MinSlotTable,
}

impl Scratch {
    /// Value comparisons performed by slot selection since the last
    /// [`Scratch::reset_comparisons`] (replica + bus tables; accumulates
    /// across batches). The reference scheduler counts the same quantity
    /// ([`ReferenceScratch::comparisons`]), so the two are directly
    /// comparable — `BENCH_sched.json`'s `comparison_ratio` is exactly
    /// this ratio.
    pub fn comparisons(&self) -> u64 {
        self.busy.comparisons() + self.bus.comparisons()
    }

    /// Zero the comparison counters.
    pub fn reset_comparisons(&mut self) {
        self.busy.reset_comparisons();
        self.bus.reset_comparisons();
    }
}

impl<'a> Scheduler<'a> {
    pub fn new(
        mapping: &'a Mapping,
        replication: &'a Replication,
        model: &'a CrossbarModel,
        dynamic_switch: bool,
    ) -> Self {
        assert_eq!(
            mapping.num_groups(),
            replication.copies.len(),
            "replication plan does not match mapping"
        );
        debug_assert!(
            model.bus_channels() >= 1,
            "CrossbarModel construction validates bus_channels >= 1"
        );
        let mut replica_base = Vec::with_capacity(mapping.num_groups());
        let mut next = 0u32;
        for &c in &replication.copies {
            replica_base.push(next);
            next += c;
        }
        let cost_by_rows = (0..=mapping.group_size)
            .map(|r| model.activation(r.max(1), dynamic_switch))
            .collect();
        let max_copies = replication.copies.iter().copied().max().unwrap_or(1) as usize;
        Self {
            mapping,
            replication,
            model,
            replica_base,
            cost_by_rows,
            busy_flat: max_copies <= minslot::FLAT_CROSSOVER,
            bus_flat: model.bus_channels() <= minslot::FLAT_CROSSOVER,
        }
    }

    /// Total physical crossbars.
    pub fn num_physical(&self) -> usize {
        self.replication.total_crossbars
    }

    /// The circuit cost model (used by the deprecated `drive_single`
    /// shim's timing adapter).
    pub fn model(&self) -> &'a CrossbarModel {
        self.model
    }

    /// Which slot-table layouts this scheduler decided on at
    /// construction: `(replica_table_flat, bus_table_flat)`. `true`
    /// means the flat-scan fast path, `false` the tournament tree —
    /// the observability plane's `sched.path_flat` / `sched.path_tree`
    /// counters report exactly this decision per scheduled batch.
    pub fn uses_flat_tables(&self) -> (bool, bool) {
        (self.busy_flat, self.bus_flat)
    }

    /// Simulate one batch. All queries arrive at t=0 (the paper's
    /// batch-synchronous inference); the returned stats cover this batch.
    pub fn run_batch(&self, queries: &[Query], scratch: &mut Scratch) -> ExecStats {
        self.run_batch_inner(queries, scratch, None)
    }

    /// As [`Scheduler::run_batch`], additionally reporting **per-query
    /// finish times** (ns relative to batch start, one entry per input
    /// query in order; empty queries finish at 0). `ExecStats` only keeps
    /// the batch max, which is enough for batch-synchronous figures but
    /// not for serving latency: the open-loop driver
    /// ([`crate::loadgen::driver`]) needs each query's own completion to
    /// compute sojourn times and tail percentiles.
    pub fn run_batch_timed(
        &self,
        queries: &[Query],
        scratch: &mut Scratch,
        finish_ns: &mut Vec<f64>,
    ) -> ExecStats {
        finish_ns.clear();
        finish_ns.reserve(queries.len());
        self.run_batch_inner(queries, scratch, Some(finish_ns))
    }

    fn run_batch_inner(
        &self,
        queries: &[Query],
        scratch: &mut Scratch,
        mut finish_ns: Option<&mut Vec<f64>>,
    ) -> ExecStats {
        scratch.busy.reset(self.num_physical(), self.busy_flat);
        scratch.bus.reset(self.model.bus_channels(), self.bus_flat);
        let (add_ns, add_pj) = self.model.vector_add();
        let flit_ns = self.model.bus_flit_ns();

        let mut stats = ExecStats::default();
        let mut batch_finish = 0.0f64;

        for q in queries {
            if q.is_empty() {
                if let Some(f) = finish_ns.as_deref_mut() {
                    f.push(0.0);
                }
                continue;
            }
            self.query_runs(q, scratch);
            let mut query_finish = 0.0f64;
            let k = scratch.runs.len();

            for &(group, rows) in &scratch.runs {
                let cost = self.cost_by_rows[rows as usize];
                // Least-loaded replica of this group. Unreplicated groups
                // (the common case under a tight dup budget) skip
                // selection entirely — matching the reference scan's zero
                // comparisons over a one-slot range.
                let base = self.replica_base[group as usize] as usize;
                let copies = self.replication.copies_of(group) as usize;
                let (slot, start) = if copies == 1 {
                    (base, scratch.busy.get(base))
                } else {
                    scratch.busy.min_range(base, base + copies)
                };
                let finish = start + cost.latency_ns;
                scratch.busy.set(slot, finish);

                // Result transfer on the least-busy global-bus channel.
                let (chan, chan_busy) = scratch.bus.min_all();
                let t_start = finish.max(chan_busy);
                let t_finish = t_start + cost.bus_flits as f64 * flit_ns;
                scratch.bus.set(chan, t_finish);

                stats.stall_ns += start; // queue wait from batch arrival
                stats.bus_wait_ns += t_start - finish;
                stats.energy_pj += cost.energy_pj;
                stats.activations += 1;
                stats.rows_activated += rows as u64;
                if rows == 1 {
                    stats.single_row_activations += 1;
                }
                match cost.mode {
                    AdcMode::Mac => stats.mac_activations += 1,
                    AdcMode::Read => stats.read_activations += 1,
                }
                query_finish = query_finish.max(t_finish);
            }

            // Merge partial sums across the k crossbars.
            if k > 1 {
                query_finish += (k - 1) as f64 * add_ns;
                stats.energy_pj += (k - 1) as f64 * add_pj;
            }
            if let Some(f) = finish_ns.as_deref_mut() {
                f.push(query_finish);
            }
            batch_finish = batch_finish.max(query_finish);
            stats.queries += 1;
            stats.lookups += q.len() as u64;
        }
        stats.completion_ns = batch_finish;
        stats
    }

    /// nMARS dataflow over the same mapping: every lookup is a single-row
    /// full-resolution read (in-memory lookup), aggregation is sequential
    /// per query on an external adder.
    pub fn run_batch_nmars(&self, queries: &[Query], scratch: &mut Scratch) -> ExecStats {
        scratch.busy.reset(self.num_physical(), self.busy_flat);
        scratch.bus.reset(self.model.bus_channels(), self.bus_flat);
        let (add_ns, add_pj) = self.model.vector_add();
        let lookup = self.model.row_lookup();
        let flit_ns = self.model.bus_flit_ns();

        let mut stats = ExecStats::default();
        let mut batch_finish = 0.0f64;

        for q in queries {
            if q.is_empty() {
                continue;
            }
            let mut last_read = 0.0f64;
            for &e in &q.items {
                let slot = self.mapping.slot_of(e);
                let base = self.replica_base[slot.group as usize] as usize;
                let copies = self.replication.copies_of(slot.group) as usize;
                let (rep, start_busy) = if copies == 1 {
                    (base, scratch.busy.get(base))
                } else {
                    scratch.busy.min_range(base, base + copies)
                };
                let finish = start_busy + lookup.latency_ns;
                scratch.busy.set(rep, finish);
                // Every looked-up row ships over the global bus.
                let (chan, chan_busy) = scratch.bus.min_all();
                let t_start = finish.max(chan_busy);
                let t_finish = t_start + lookup.bus_flits as f64 * flit_ns;
                scratch.bus.set(chan, t_finish);
                stats.stall_ns += start_busy;
                stats.bus_wait_ns += t_start - finish;
                stats.energy_pj += lookup.energy_pj;
                stats.activations += 1;
                stats.rows_activated += 1;
                stats.single_row_activations += 1;
                stats.read_activations += 1; // gated single-row sense
                last_read = last_read.max(t_finish);
            }
            // Sequential external aggregation (the nMARS bottleneck).
            let adds = (q.len() - 1) as f64;
            let query_finish = last_read + adds * add_ns;
            stats.energy_pj += adds * add_pj;
            batch_finish = batch_finish.max(query_finish);
            stats.queries += 1;
            stats.lookups += q.len() as u64;
        }
        stats.completion_ns = batch_finish;
        stats
    }

    /// Decompose a query into `(group, rows)` runs, sort-free: an
    /// epoch-stamped [`TouchSet`] accumulates per-group row counts in
    /// O(k), then only the ≤k distinct touched groups are sorted so the
    /// emitted runs keep the ascending-group order the sort-based
    /// decomposition ([`reference`]) produces — byte for byte.
    ///
    /// Rows are clamped to `group_size`: distinct cold-start ids beyond
    /// the catalogue all collapse onto the overflow group's row 0
    /// ([`Mapping::slot_of`]), so a run can nominally exceed the crossbar
    /// height even though the hardware can never activate more wordlines
    /// than it has.
    fn query_runs(&self, q: &Query, scratch: &mut Scratch) {
        let max_rows = self.mapping.group_size.max(1) as u32;
        let Scratch { runs, touch, .. } = scratch;
        touch.begin(self.mapping.num_groups());
        for &e in &q.items {
            touch.add(self.mapping.slot_of(e).group);
        }
        touch.sort_touched();
        runs.clear();
        for &g in touch.touched() {
            runs.push((g, touch.count_of(g).min(max_rows)));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::Replication;
    use crate::config::HardwareConfig;
    use crate::grouping::Mapping;
    use crate::workload::Query;
    use crate::xbar::CircuitParams;

    fn model() -> CrossbarModel {
        CrossbarModel::new(&HardwareConfig::default(), &CircuitParams::default())
    }

    fn mapping_2x2() -> Mapping {
        Mapping::from_groups(vec![vec![0, 1], vec![2, 3]], 2, 4)
    }

    #[test]
    fn single_query_one_group() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let s = Scheduler::new(&map, &rep, &m, true);
        let mut scratch = Scratch::default();
        let stats = s.run_batch(&[Query::new(vec![0, 1])], &mut scratch);
        assert_eq!(stats.activations, 1);
        assert_eq!(stats.rows_activated, 2);
        assert_eq!(stats.mac_activations, 1);
        assert_eq!(stats.single_row_activations, 0);
        assert_eq!(stats.stall_ns, 0.0);
        let expect = m.activation(2, true);
        let flit = m.bus_flit_ns();
        assert!((stats.completion_ns - (expect.latency_ns + flit)).abs() < 1e-9);
    }

    #[test]
    fn cross_group_query_pays_merge() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let s = Scheduler::new(&map, &rep, &m, true);
        let mut scratch = Scratch::default();
        let stats = s.run_batch(&[Query::new(vec![0, 2])], &mut scratch);
        assert_eq!(stats.activations, 2);
        assert_eq!(stats.read_activations, 2); // both single-row
        assert_eq!(stats.single_row_activations, 2);
        let act = m.activation(1, true);
        let (add_ns, _) = m.vector_add();
        let flit = m.bus_flit_ns();
        // two parallel activations on different crossbars (transfers land
        // on distinct bus channels) + one merge
        assert!((stats.completion_ns - (act.latency_ns + flit + add_ns)).abs() < 1e-9);
    }

    #[test]
    fn contention_serializes_and_stalls() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let s = Scheduler::new(&map, &rep, &m, true);
        let mut scratch = Scratch::default();
        // Two queries hitting the same group: second must queue.
        let qs = vec![Query::new(vec![0, 1]), Query::new(vec![0, 1])];
        let stats = s.run_batch(&qs, &mut scratch);
        let act = m.activation(2, true);
        let flit = m.bus_flit_ns();
        assert!((stats.completion_ns - (2.0 * act.latency_ns + flit)).abs() < 1e-9);
        assert!((stats.stall_ns - act.latency_ns).abs() < 1e-9);
    }

    #[test]
    fn replication_removes_contention() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication {
            copies: vec![2, 1],
            total_crossbars: 3,
            batch_size: 2,
        };
        let s = Scheduler::new(&map, &rep, &m, true);
        let mut scratch = Scratch::default();
        let qs = vec![Query::new(vec![0, 1]), Query::new(vec![0, 1])];
        let stats = s.run_batch(&qs, &mut scratch);
        let act = m.activation(2, true);
        let flit = m.bus_flit_ns();
        // both served in parallel on the two replicas (plenty of channels)
        assert!((stats.completion_ns - (act.latency_ns + flit)).abs() < 1e-9);
        assert_eq!(stats.stall_ns, 0.0);
    }

    #[test]
    fn dynamic_switch_saves_energy_not_counts() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let qs = vec![Query::new(vec![0]), Query::new(vec![2, 3])];
        let mut scratch = Scratch::default();
        let on = Scheduler::new(&map, &rep, &m, true).run_batch(&qs, &mut scratch);
        let off = Scheduler::new(&map, &rep, &m, false).run_batch(&qs, &mut scratch);
        assert_eq!(on.activations, off.activations);
        assert_eq!(on.single_row_activations, 1);
        assert_eq!(off.single_row_activations, 1);
        assert_eq!(on.read_activations, 1);
        assert_eq!(off.read_activations, 0);
        assert!(on.energy_pj < off.energy_pj);
    }

    #[test]
    fn nmars_pays_per_lookup() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let s = Scheduler::new(&map, &rep, &m, false);
        let mut scratch = Scratch::default();
        let stats = s.run_batch_nmars(&[Query::new(vec![0, 1, 2])], &mut scratch);
        assert_eq!(stats.activations, 3);
        assert_eq!(stats.lookups, 3);
        // rows 0,1 share a crossbar -> serialized; plus transfer + 2 adds.
        let lk = m.row_lookup();
        let (add_ns, _) = m.vector_add();
        let flit = m.bus_flit_ns();
        assert!(
            (stats.completion_ns - (2.0 * lk.latency_ns + flit + 2.0 * add_ns)).abs() < 1e-9
        );
    }

    #[test]
    fn accumulate_sums() {
        let mut a = ExecStats {
            completion_ns: 10.0,
            energy_pj: 5.0,
            activations: 2,
            queries: 1,
            lookups: 3,
            ..Default::default()
        };
        let b = a.clone();
        a.accumulate(&b);
        assert_eq!(a.completion_ns, 20.0);
        assert_eq!(a.activations, 4);
        assert_eq!(a.queries, 2);
        assert_eq!(a.lookups, 6);
    }

    #[test]
    fn merge_parallel_maxes_completion_sums_counters() {
        let mut a = ExecStats {
            completion_ns: 10.0,
            energy_pj: 5.0,
            activations: 2,
            stall_ns: 1.0,
            queries: 1,
            lookups: 3,
            ..Default::default()
        };
        let b = ExecStats {
            completion_ns: 25.0,
            energy_pj: 2.0,
            activations: 1,
            stall_ns: 4.0,
            queries: 2,
            lookups: 2,
            ..Default::default()
        };
        a.merge_parallel(&b);
        assert_eq!(a.completion_ns, 25.0); // max, not 35
        assert_eq!(a.energy_pj, 7.0);
        assert_eq!(a.activations, 3);
        assert_eq!(a.stall_ns, 5.0);
        assert_eq!(a.queries, 3);
        assert_eq!(a.lookups, 5);
    }

    #[test]
    fn cold_start_flood_does_not_panic() {
        // Regression: distinct out-of-catalogue ids all collapse onto the
        // overflow group's row 0; more of them than group_size used to
        // index cost_by_rows out of bounds and kill the executor thread.
        let m = model();
        let map = mapping_2x2(); // group_size 2
        let rep = Replication::identity(2, 4);
        let s = Scheduler::new(&map, &rep, &m, true);
        let mut scratch = Scratch::default();
        let cold: Vec<u32> = (100..110).collect(); // 10 ids, all unseen
        let stats = s.run_batch(&[Query::new(cold)], &mut scratch);
        assert_eq!(stats.activations, 1); // one (overflow-group) activation
        assert!(stats.rows_activated <= map.group_size as u64);
        assert!(stats.completion_ns > 0.0);
    }

    #[test]
    fn timed_batch_matches_untimed_and_maxes_to_completion() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let s = Scheduler::new(&map, &rep, &m, true);
        let mut scratch = Scratch::default();
        let qs = vec![
            Query::new(vec![0, 1]),
            Query::new(vec![0, 2]),
            Query::new(vec![]),
            Query::new(vec![3]),
        ];
        let plain = s.run_batch(&qs, &mut scratch);
        let mut finish = Vec::new();
        let timed = s.run_batch_timed(&qs, &mut scratch, &mut finish);
        assert_eq!(plain, timed, "timing must not perturb the schedule");
        assert_eq!(finish.len(), qs.len(), "one finish per input query");
        assert_eq!(finish[2], 0.0, "empty query finishes at t=0");
        let max = finish.iter().cloned().fold(0.0f64, f64::max);
        assert!((max - timed.completion_ns).abs() < 1e-9);
        assert!(finish.iter().all(|&f| f >= 0.0 && f <= timed.completion_ns));
    }

    #[test]
    fn empty_queries_skipped() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let s = Scheduler::new(&map, &rep, &m, true);
        let mut scratch = Scratch::default();
        let stats = s.run_batch(&[Query::new(vec![])], &mut scratch);
        assert_eq!(stats.queries, 0);
        assert_eq!(stats.completion_ns, 0.0);
    }

    /// In-module smoke of the equivalence contract (the full ≥200-config
    /// differential fuzz lives in `tests/sched_equivalence.rs`): a
    /// replicated, contended batch with cold-start ids must produce the
    /// exact same stats and finish times as the reference scheduler.
    #[test]
    fn matches_reference_scheduler_exactly() {
        let m = model();
        let map = Mapping::from_groups(
            vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]],
            2,
            8,
        );
        let rep = Replication::from_copies(vec![3, 1, 2, 1], 8);
        let opt = Scheduler::new(&map, &rep, &m, true);
        let naive = ReferenceScheduler::new(&map, &rep, &m, true);
        let qs: Vec<Query> = vec![
            Query::new(vec![0, 1, 2]),
            Query::new(vec![0, 4, 6]),
            Query::new(vec![]),
            Query::new(vec![7, 900, 901]), // cold-start tail
            Query::new(vec![0, 1]),
            Query::new(vec![2, 3, 4, 5, 6, 7]),
        ];
        let mut scratch = Scratch::default();
        let mut rscratch = ReferenceScratch::default();
        let (mut fa, mut fb) = (Vec::new(), Vec::new());
        assert_eq!(
            opt.run_batch(&qs, &mut scratch),
            naive.run_batch(&qs, &mut rscratch)
        );
        assert_eq!(
            opt.run_batch_timed(&qs, &mut scratch, &mut fa),
            naive.run_batch_timed(&qs, &mut rscratch, &mut fb)
        );
        assert_eq!(fa, fb, "per-query finish times must be bit-identical");
        assert_eq!(
            opt.run_batch_nmars(&qs, &mut scratch),
            naive.run_batch_nmars(&qs, &mut rscratch)
        );
    }

    #[test]
    fn comparison_counters_accumulate_and_reset() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let s = Scheduler::new(&map, &rep, &m, true);
        let mut scratch = Scratch::default();
        let qs = vec![Query::new(vec![0, 2]), Query::new(vec![0, 1, 3])];
        s.run_batch(&qs, &mut scratch);
        let once = scratch.comparisons();
        // Unreplicated groups cost nothing; the default 16-channel bus
        // table costs 15 per activation (flat scan), 4 activations total.
        assert_eq!(once, 4 * 15);
        s.run_batch(&qs, &mut scratch);
        assert_eq!(scratch.comparisons(), 2 * once, "counters accumulate");
        scratch.reset_comparisons();
        assert_eq!(scratch.comparisons(), 0);
    }

    #[test]
    fn flat_table_decision_is_exposed() {
        let m = model();
        let map = mapping_2x2();
        // Identity copies (1 each) and 16 bus channels: both flat.
        let rep = Replication::identity(2, 4);
        let s = Scheduler::new(&map, &rep, &m, true);
        assert_eq!(s.uses_flat_tables(), (true, true));
        // 64 copies of a group exceed FLAT_CROSSOVER: replica table goes
        // tree, bus table stays flat.
        let rep = Replication::from_copies(vec![64, 1], 64);
        let s = Scheduler::new(&map, &rep, &m, true);
        assert_eq!(s.uses_flat_tables(), (false, true));
    }

    #[test]
    fn scratch_survives_scheduler_and_size_changes() {
        // One Scratch serves schedulers of very different shapes (the
        // sharded driver reuses a single scratch across per-shard
        // schedulers): tables resize, epochs isolate, results stay right.
        let m = model();
        let map_a = mapping_2x2();
        let rep_a = Replication::identity(2, 4);
        let sa = Scheduler::new(&map_a, &rep_a, &m, true);
        let groups_b: Vec<Vec<u32>> = (0..40u32).map(|g| vec![2 * g, 2 * g + 1]).collect();
        let map_b = Mapping::from_groups(groups_b, 2, 80);
        let rep_b = Replication::from_copies(vec![40; 40], 80); // tree-mode busy table
        let sb = Scheduler::new(&map_b, &rep_b, &m, true);
        let mut scratch = Scratch::default();
        let qa = vec![Query::new(vec![0, 2])];
        let qb = vec![Query::new(vec![0, 11, 79])];
        let first = sa.run_batch(&qa, &mut scratch);
        sb.run_batch(&qb, &mut scratch);
        let again = sa.run_batch(&qa, &mut scratch);
        assert_eq!(first, again, "interleaving schedulers must not leak state");
    }
}
