//! Deterministic min-slot tables — the scheduler's O(log C) selection core.
//!
//! The hot loop of [`super::Scheduler`] answers the same question millions
//! of times per simulated second: *which slot in a busy-until table frees
//! up first?* — once per activation for the group's replica range, once
//! for the global bus-channel table. The naive answer
//! ([`super::reference`]) is a linear scan, O(slots) per activation; at
//! the paper's scale (heavy Eq. 1 replication, wide bus configs) the scan
//! dominates the simulator's own runtime.
//!
//! [`MinSlotTable`] replaces the scan with a **tournament (segment) tree**
//! over the busy-until times: every internal node caches the minimum of
//! its subtree *and the leftmost leaf index achieving it*, giving
//!
//! * `min_all` — O(1) (the root *is* the answer),
//! * `min_range(l, r)` — O(log(r−l)) node visits,
//! * `set(i, v)` — O(log C) parent recomputations.
//!
//! **Determinism / tie-break.** The reference scan keeps the *first*
//! (lowest-index) slot that attains the minimum (strict `<` while
//! scanning left to right). The tree reproduces that exactly: a parent
//! adopts its right child only on a strictly smaller value (equal values
//! keep the left child, whose indices are all lower), and range queries
//! fold candidate nodes with the lexicographic `(value, index)` order.
//! Both rules select the unique lexicographically-least `(value, index)`
//! pair, so tree and scan pick identical slots on every input — the
//! schedules are bit-identical, not merely statistically equivalent.
//!
//! **Crossover.** A tree walk beats a scan only when the scanned range is
//! long: a range of `c` slots costs the scan `c−1` comparisons but the
//! tree ~`2·log₂(c)` visits *plus* a `log₂(C)` root path per update. The
//! caller therefore chooses the layout per table via [`MinSlotTable::reset`]'s
//! `flat` flag, keyed on the longest range it will ever scan
//! ([`FLAT_CROSSOVER`]): max replica copies for the crossbar table,
//! channel count for the bus table. Paper-default configs (≤5 copies,
//! 16 channels) stay on the flat path and cannot regress.
//!
//! **Op counters.** Every value comparison — flat or tree — increments an
//! always-on counter ([`MinSlotTable::comparisons`]). The counters are
//! how `tests/sched_equivalence.rs` proves the asymptotic win and how
//! `benches/throughput.rs` reports it into `BENCH_sched.json`, so they
//! are not gated behind a feature; the cost is one integer add alongside
//! a float compare. Table (re)initialisation is excluded by both
//! implementations' accounting — it is the same O(C) fill either way.

/// Longest scan a flat table should absorb before the tree layout pays
/// for itself (see the module docs for the cost model). Conservatively
/// high: at the crossover the two layouts are within ~2× of each other,
/// and flat's cache behaviour is better.
pub const FLAT_CROSSOVER: usize = 32;

/// A busy-until table with deterministic least-loaded selection.
///
/// Two layouts behind one API (chosen by [`MinSlotTable::reset`]):
///
/// * **flat** — a plain `Vec<f64>`; selection scans, updates are O(1).
/// * **tree** — a perfect binary tournament tree in two flat arrays
///   (`val`/`idx`, children of `p` at `2p`/`2p+1`, leaves at
///   `cap..cap+len` with `+∞` padding); selection descends, updates walk
///   the root path.
#[derive(Debug, Clone, Default)]
pub struct MinSlotTable {
    /// Live slots.
    len: usize,
    /// Leaf capacity (power of two) in tree mode; 0 marks flat mode.
    cap: usize,
    /// Flat mode: `val[0..len]`. Tree mode: `val[1]` is the root,
    /// `val[cap + i]` is slot `i`, padding leaves are `+∞`.
    val: Vec<f64>,
    /// Tree mode only: leftmost argmin of each node's subtree.
    idx: Vec<u32>,
    /// Value comparisons performed since the last
    /// [`MinSlotTable::reset_comparisons`].
    comparisons: u64,
}

impl MinSlotTable {
    /// Reinitialise to `len` slots, all at time 0.0. `flat` picks the
    /// layout; pass `scan_len <= FLAT_CROSSOVER` where `scan_len` is the
    /// longest range the caller will query. Counters are preserved (they
    /// accumulate across batches until explicitly reset).
    pub fn reset(&mut self, len: usize, flat: bool) {
        self.len = len;
        if flat || len <= 1 {
            self.cap = 0;
            self.idx.clear();
            self.val.clear();
            self.val.resize(len, 0.0);
            return;
        }
        let cap = len.next_power_of_two();
        self.cap = cap;
        self.val.clear();
        self.val.resize(2 * cap, f64::INFINITY);
        self.idx.clear();
        self.idx.resize(2 * cap, 0);
        for v in &mut self.val[cap..cap + len] {
            *v = 0.0;
        }
        for (i, x) in self.idx[cap..].iter_mut().enumerate() {
            *x = i as u32;
        }
        // Build bottom-up. All live leaves are equal (0.0), so this is
        // initialisation, not scheduling work — neither layout counts its
        // O(C) fill (the flat table's `resize` is the same cost).
        for p in (1..cap).rev() {
            let (l, r) = (2 * p, 2 * p + 1);
            if self.val[r] < self.val[l] {
                self.val[p] = self.val[r];
                self.idx[p] = self.idx[r];
            } else {
                self.val[p] = self.val[l];
                self.idx[p] = self.idx[l];
            }
        }
    }

    /// Current busy-until time of slot `i`.
    #[inline]
    pub fn get(&self, i: usize) -> f64 {
        debug_assert!(i < self.len);
        if self.cap == 0 {
            self.val[i]
        } else {
            self.val[self.cap + i]
        }
    }

    /// Set slot `i`'s busy-until time.
    #[inline]
    pub fn set(&mut self, i: usize, v: f64) {
        debug_assert!(i < self.len);
        if self.cap == 0 {
            self.val[i] = v;
            return;
        }
        let mut p = self.cap + i;
        self.val[p] = v;
        p >>= 1;
        while p >= 1 {
            let (l, r) = (2 * p, 2 * p + 1);
            self.comparisons += 1;
            // Equal values keep the LEFT child: its leaves all have lower
            // indices, which is exactly the reference scan's first-minimum
            // rule.
            if self.val[r] < self.val[l] {
                self.val[p] = self.val[r];
                self.idx[p] = self.idx[r];
            } else {
                self.val[p] = self.val[l];
                self.idx[p] = self.idx[l];
            }
            p >>= 1;
        }
    }

    /// Least-loaded slot over the whole table; ties break toward the
    /// lowest index. Tree mode reads the root in O(1).
    #[inline]
    pub fn min_all(&mut self) -> (usize, f64) {
        debug_assert!(self.len > 0, "min over an empty slot table");
        if self.cap == 0 {
            return self.scan(0, self.len);
        }
        (self.idx[1] as usize, self.val[1])
    }

    /// Least-loaded slot in `[l, r)`; ties break toward the lowest index.
    pub fn min_range(&mut self, l: usize, r: usize) -> (usize, f64) {
        debug_assert!(l < r && r <= self.len, "min over empty range {l}..{r}");
        if self.cap == 0 {
            return self.scan(l, r);
        }
        let mut best_v = f64::INFINITY;
        let mut best_i = u32::MAX;
        let mut lo = self.cap + l;
        let mut hi = self.cap + r;
        while lo < hi {
            if lo & 1 == 1 {
                self.fold(&mut best_v, &mut best_i, lo);
                lo += 1;
            }
            if hi & 1 == 1 {
                hi -= 1;
                self.fold(&mut best_v, &mut best_i, hi);
            }
            lo >>= 1;
            hi >>= 1;
        }
        (best_i as usize, best_v)
    }

    /// Value comparisons since the last [`MinSlotTable::reset_comparisons`].
    pub fn comparisons(&self) -> u64 {
        self.comparisons
    }

    /// Zero the comparison counter.
    pub fn reset_comparisons(&mut self) {
        self.comparisons = 0;
    }

    /// Fold one covering node into the running `(value, index)` minimum.
    /// Lexicographic order makes the result visit-order independent: the
    /// winner is the unique least `(value, index)` pair in the range.
    #[inline]
    fn fold(&mut self, best_v: &mut f64, best_i: &mut u32, node: usize) {
        self.comparisons += 1;
        let (v, i) = (self.val[node], self.idx[node]);
        if v < *best_v || (v == *best_v && i < *best_i) {
            *best_v = v;
            *best_i = i;
        }
    }

    /// Flat-mode linear scan: first minimum wins, `r - l - 1` comparisons
    /// (identical count and selection to the reference scheduler's scan).
    fn scan(&mut self, l: usize, r: usize) -> (usize, f64) {
        self.comparisons += (r - l - 1) as u64;
        let mut idx = l;
        let mut best = self.val[l];
        for (off, &v) in self.val[l + 1..r].iter().enumerate() {
            if v < best {
                best = v;
                idx = l + 1 + off;
            }
        }
        (idx, best)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Naive model: plain vector + reference scan rule.
    struct Model(Vec<f64>);

    impl Model {
        fn min_range(&self, l: usize, r: usize) -> (usize, f64) {
            let mut idx = l;
            let mut best = self.0[l];
            for i in l + 1..r {
                if self.0[i] < best {
                    best = self.0[i];
                    idx = i;
                }
            }
            (idx, best)
        }
    }

    fn differential(len: usize, flat: bool, seed: u64) {
        let mut t = MinSlotTable::default();
        t.reset(len, flat);
        let mut m = Model(vec![0.0; len]);
        let mut rng = Rng::new(seed);
        for step in 0..2_000 {
            // Mutate a random slot; quantized values force frequent ties.
            let i = rng.index(len);
            let v = rng.below(8) as f64 * 0.5;
            t.set(i, v);
            m.0[i] = v;
            // Check a random range + the full table + a point read.
            let a = rng.index(len);
            let b = rng.index(len);
            let (l, r) = if a <= b { (a, b + 1) } else { (b, a + 1) };
            assert_eq!(t.min_range(l, r), m.min_range(l, r), "step {step} range {l}..{r}");
            assert_eq!(t.min_all(), m.min_range(0, len), "step {step} min_all");
            let j = rng.index(len);
            assert_eq!(t.get(j), m.0[j], "step {step} get({j})");
        }
    }

    #[test]
    fn tree_matches_reference_scan() {
        differential(100, false, 1);
        differential(64, false, 2); // exact power of two
        differential(33, false, 3); // just past the crossover
    }

    #[test]
    fn flat_matches_reference_scan() {
        differential(32, true, 4);
        differential(7, true, 5);
        differential(1, true, 6);
    }

    #[test]
    fn ties_break_toward_lowest_index() {
        for &flat in &[true, false] {
            let mut t = MinSlotTable::default();
            t.reset(40, flat);
            // All zeros: slot 0 wins everywhere.
            assert_eq!(t.min_all(), (0, 0.0));
            assert_eq!(t.min_range(5, 23), (5, 0.0));
            // Two equal minima: the lower index wins.
            for i in 0..40 {
                t.set(i, 9.0);
            }
            t.set(31, 2.0);
            t.set(11, 2.0);
            assert_eq!(t.min_all(), (11, 2.0));
            assert_eq!(t.min_range(12, 40), (31, 2.0));
            assert_eq!(t.min_range(11, 32), (11, 2.0));
        }
    }

    #[test]
    fn reset_restores_zero_and_keeps_counters() {
        let mut t = MinSlotTable::default();
        t.reset(50, false);
        t.set(3, 7.0);
        let _ = t.min_range(0, 50);
        let c = t.comparisons();
        assert!(c > 0);
        t.reset(50, false);
        assert_eq!(t.min_all(), (0, 0.0));
        assert_eq!(t.get(3), 0.0);
        assert_eq!(t.comparisons(), c, "reset must not clear counters");
        t.reset_comparisons();
        assert_eq!(t.comparisons(), 0);
        // Shrinking / growing across resets reuses the buffers.
        t.reset(8, true);
        assert_eq!(t.min_all(), (0, 0.0));
        t.reset(200, false);
        assert_eq!(t.min_range(150, 200), (150, 0.0));
    }

    #[test]
    fn tree_updates_cost_logarithmically() {
        // 1024 slots: a full-table scan costs 1023 comparisons; a tree
        // update costs log2(1024) = 10 and min_all is free.
        let mut tree = MinSlotTable::default();
        tree.reset(1024, false);
        tree.reset_comparisons();
        tree.set(513, 4.0);
        let (i, _) = tree.min_all();
        assert_eq!(i, 0);
        assert!(tree.comparisons() <= 10, "{} > 10", tree.comparisons());

        let mut flat = MinSlotTable::default();
        flat.reset(1024, true);
        flat.reset_comparisons();
        flat.set(513, 4.0);
        let _ = flat.min_all();
        assert_eq!(flat.comparisons(), 1023);
    }
}
