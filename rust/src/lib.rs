//! # ReCross — efficient embedding reduction for ReRAM-crossbar in-memory computing
//!
//! Reproduction of *"ReCross: Efficient Embedding Reduction Scheme for In-Memory
//! Computing using ReRAM-Based Crossbar"* (Lai et al., 2025).
//!
//! ReCross accelerates the DLRM embedding-reduction stage (gather + sum over a
//! sparse set of embedding rows) by computing it *inside* ReRAM crossbar arrays
//! as multiply-and-accumulate (MAC) operations, co-optimizing the
//! embeddings-to-crossbar mapping against the workload's access patterns:
//!
//! 1. **Correlation-aware embedding grouping** ([`grouping::correlation`],
//!    paper §III-B / Algorithm 1) — a co-occurrence graph built from lookup
//!    history drives greedy packing of co-accessed embeddings into the same
//!    crossbar, so one activation serves many lookups of a query.
//! 2. **Access-aware crossbar allocation** ([`allocation`], §III-C / Eq. 1) —
//!    hot crossbars are replicated with *log-scaled* copy counts to break
//!    power-law contention at bounded area overhead.
//! 3. **Energy-aware dynamic switching** ([`xbar::adc`], §III-D) — a
//!    dynamic-switch flash ADC driven by a popcount circuit serves
//!    single-embedding activations in cheap *read mode* instead of paying for
//!    a full MAC conversion.
//!
//! The crate is organised as the L3 coordinator of a three-layer stack:
//! the analog crossbar's *cost* is simulated by a NeuroSim-style circuit
//! model ([`xbar`]), while the *numerics* of the reduction run as an
//! AOT-compiled JAX/Pallas computation loaded through PJRT ([`runtime`]).
//! See `DESIGN.md` for the full inventory and experiment index.
//!
//! Above the single-pool coordinator sits the **cluster layer**
//! ([`cluster`]): a sharded serving pool that partitions the logical
//! groups across `N` shard executors (consistent hashing or a
//! co-occurrence-locality-preserving partition), runs one scheduler +
//! dynamic batcher per shard on its own thread, and serves each query
//! with an exact scatter-gather reduction merge.
//!
//! Feeding both serving paths is the **open-loop traffic engine**
//! ([`loadgen`]): seeded arrival processes stamp queries with arrival
//! times, and a simulated-clock driver measures sojourn times — queue
//! wait + batch formation + scheduled service — reporting throughput and
//! p50/p95/p99/p999 latency, bit-reproducibly.
//!
//! Cross-cutting the serving layers is the **observability plane**
//! ([`obs`]): an off-by-default metrics registry plus a sampled
//! per-query flight recorder, harvested at batch/wave seams so that
//! observation never perturbs schedules or reductions, and exported as
//! one schema-versioned JSON snapshot (`recross status --json`). On
//! top of the snapshots sits the **signal plane** ([`obs::timeseries`]
//! + [`obs::slo`]): clock-injected ticks diff snapshots into windowed
//! rings, declarative SLOs are evaluated with multi-window burn-rate
//! rules into a deterministic `recross.alerts` v1 stream
//! (`recross status --watch`), and the measured drift series feeds the
//! delta pipeline's thresholds ([`graph::DeltaParams::from_observed`]).
//!
//! The single front door to all of it is the **deployment facade**
//! ([`deploy`]): `Deployment::of(config).scheme(..).build()?` runs the
//! offline phase once, and the resulting [`deploy::Prepared`] bundle
//! backs every [`deploy::Backend`] — the live single pool, the sharded
//! pool, or the deterministic simulator — behind one object-safe trait.
//!
//! Beneath the serving tiers sits **tiered embedding storage**
//! ([`store`]): tables too large for the crossbars (or for DRAM) split
//! into a crossbar-resident hot tier chosen by Algorithm 1's frequency
//! stats, a DRAM tile cache, and a persistent cold tile image — with
//! deterministic admission/eviction driven by the drift monitor's
//! recent-query ring, modeled per-tier miss costs folded into the
//! timing twin, and reductions bit-identical to the flat store no
//! matter where a group lives.

pub mod allocation;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod deploy;
pub mod energy;
pub mod engine;
pub mod graph;
pub mod grouping;
pub mod loadgen;
pub mod metrics;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod store;
pub mod util;
pub mod workload;
pub mod xbar;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
