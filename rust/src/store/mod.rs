//! Tiered embedding storage — terabyte tables behind the crossbars.
//!
//! Production DLRM tables dwarf what crossbars (or DRAM) can hold, so
//! this subsystem splits the grouped tile set across three memory
//! classes:
//!
//! * **Hot** — crossbar-resident tiles, sized by capacity and populated
//!   from Algorithm 1's group frequencies (the stats the offline phase
//!   already computes are exactly the admission signal).
//! * **DRAM** — in-memory `Vec`-backed tile cache for the warm middle.
//! * **Cold** — the persistent on-disk tile image ([`cold::ColdTileFile`],
//!   header + per-group extents). The cold image is the *canonical,
//!   complete* copy; hot/DRAM are caches over it, so eviction is a drop
//!   and promotion is an extent decode — no writeback, ever.
//!
//! The contract that makes tiering safe to put behind the `Backend`
//! seam: **tiering changes cost, never values.** A reduction through
//! [`TieredStore::reduce`] walks items in query order through
//! `Mapping::slot_of` and accumulates with the same
//! `util::accum::add_assign_4wide` kernel as the flat
//! `EmbeddingStore::reduce_reference`, and tile bytes round-trip
//! losslessly through every tier — so results are bit-identical to the
//! flat store for any placement (property-tested in
//! `tests/tiered_store.rs`). Costs are separate: [`TieredStore::charge_query`]
//! prices the distinct tiles a query touches via [`cost::TierCostModel`],
//! and the `deploy::Tiered` backend folds those modeled nanoseconds into
//! `run_batch_timed` finish times so misses surface in sojourn/p99
//! exactly like crossbar service.
//!
//! Placement decisions ([`policy::TierPolicy`], [`TieredStore::adapt`])
//! are pure integer-keyed functions of group frequencies — initial plan
//! from the offline histogram, online replans from the `DriftMonitor`
//! recent-query ring — with ties broken by group id. Same inputs, same
//! moves: determinism here is stronger than seeded.

pub mod cold;
pub mod cost;
pub mod policy;

pub use cold::{ColdTileFile, COLD_MAGIC, COLD_VERSION};
pub use cost::TierCostModel;
pub use policy::TierPolicy;

use std::cmp::Reverse;

use crate::coordinator::EmbeddingStore;
use crate::grouping::Mapping;
use crate::util::{accum, FxHashMap};
use crate::workload::EmbeddingId;

/// The memory class a group's tile currently lives in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Tier {
    /// Crossbar-resident; service cost is the scheduler's business.
    Hot,
    /// In-memory tile cache; touches cost `TierCostModel::dram_ns`.
    Dram,
    /// Persistent tile image; touches cost `TierCostModel::cold_ns`.
    Cold,
}

/// Per-group tier placement. Groups outside the map read as cold.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TierMap {
    tiers: Vec<Tier>,
}

impl TierMap {
    pub fn new(tiers: Vec<Tier>) -> Self {
        Self { tiers }
    }

    pub fn num_groups(&self) -> usize {
        self.tiers.len()
    }

    pub fn tier(&self, group: u32) -> Tier {
        self.tiers.get(group as usize).copied().unwrap_or(Tier::Cold)
    }

    pub fn set(&mut self, group: u32, tier: Tier) {
        self.tiers[group as usize] = tier;
    }

    pub fn count(&self, tier: Tier) -> usize {
        self.tiers.iter().filter(|&&t| t == tier).count()
    }

    /// Groups currently placed in `tier`, ascending by id.
    pub fn groups_in(&self, tier: Tier) -> Vec<u32> {
        (0..self.tiers.len() as u32).filter(|&g| self.tiers[g as usize] == tier).collect()
    }
}

/// Tile-touch accounting for one query, one batch, or a whole run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TierAccess {
    /// Distinct hot tiles touched.
    pub hot_hits: u64,
    /// Distinct DRAM tiles touched.
    pub dram_hits: u64,
    /// Distinct cold tiles touched.
    pub cold_hits: u64,
    /// Modeled ns spent fetching non-hot tiles.
    pub miss_ns: f64,
}

impl TierAccess {
    pub fn accumulate(&mut self, other: &TierAccess) {
        self.hot_hits += other.hot_hits;
        self.dram_hits += other.dram_hits;
        self.cold_hits += other.cold_hits;
        self.miss_ns += other.miss_ns;
    }

    /// Total distinct tile touches across all tiers.
    pub fn total(&self) -> u64 {
        self.hot_hits + self.dram_hits + self.cold_hits
    }

    /// Fraction of tile touches served crossbar-resident (0.0 when no
    /// touches have been recorded).
    pub fn hit_rate(&self) -> f64 {
        let total = self.total();
        if total == 0 {
            0.0
        } else {
            self.hot_hits as f64 / total as f64
        }
    }
}

/// One replan's applied moves, in decision order.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TierStep {
    /// Groups promoted into the hot tier.
    pub promoted: Vec<u32>,
    /// Groups evicted from the hot tier (to DRAM, or cold under DRAM
    /// pressure).
    pub evicted: Vec<u32>,
}

/// A bounded tile arena: `Vec<f32>` slots plus a group → slot index,
/// with freed slots reused so memory stays pinned at capacity. Same
/// idiom as the cluster's `ShardStore`.
#[derive(Debug, Clone)]
struct TileCache {
    tile_len: usize,
    data: Vec<f32>,
    local: FxHashMap<u32, u32>,
    free: Vec<u32>,
}

impl TileCache {
    fn new(tile_len: usize) -> Self {
        Self {
            tile_len,
            data: Vec::new(),
            local: FxHashMap::default(),
            free: Vec::new(),
        }
    }

    fn len(&self) -> usize {
        self.local.len()
    }

    fn insert(&mut self, group: u32, tile: &[f32]) {
        debug_assert_eq!(tile.len(), self.tile_len);
        debug_assert!(!self.local.contains_key(&group), "group {group} already cached");
        let slot = match self.free.pop() {
            Some(s) => {
                let base = s as usize * self.tile_len;
                self.data[base..base + self.tile_len].copy_from_slice(tile);
                s
            }
            None => {
                let s = (self.data.len() / self.tile_len.max(1)) as u32;
                self.data.extend_from_slice(tile);
                s
            }
        };
        self.local.insert(group, slot);
    }

    fn remove(&mut self, group: u32) -> bool {
        match self.local.remove(&group) {
            Some(slot) => {
                self.free.push(slot);
                true
            }
            None => false,
        }
    }

    fn tile(&self, group: u32) -> Option<&[f32]> {
        self.local.get(&group).map(|&slot| {
            let base = slot as usize * self.tile_len;
            &self.data[base..base + self.tile_len]
        })
    }

    fn row(&self, group: u32, row: usize, dim: usize) -> Option<&[f32]> {
        self.tile(group).map(|tile| &tile[row * dim..(row + 1) * dim])
    }
}

/// Three-tier embedding store: crossbar-resident hot tiles, a DRAM tile
/// cache, and the canonical cold image, behind one reduce/charge/adapt
/// façade. See the module docs for the placement and bit-identity
/// contracts.
#[derive(Debug, Clone)]
pub struct TieredStore {
    dim: usize,
    rows: usize,
    /// Ids at or past this bound are cold-start traffic: they route to
    /// the overflow group for *costing* but contribute zero to values,
    /// exactly like the flat store's reduce.
    catalogue: usize,
    map: TierMap,
    hot: TileCache,
    dram: TileCache,
    cold: ColdTileFile,
    policy: TierPolicy,
    cost: TierCostModel,
    access: TierAccess,
    promotions: u64,
    evictions: u64,
}

impl TieredStore {
    /// Build from a flat store: `freqs` (Algorithm 1's per-group
    /// frequencies over the offline history) drive the initial
    /// placement, every tile is persisted into the cold image, and the
    /// hot/DRAM caches are filled per the plan.
    pub fn build(
        store: &EmbeddingStore,
        freqs: &[u64],
        policy: TierPolicy,
        cost: TierCostModel,
    ) -> Self {
        assert_eq!(
            freqs.len(),
            store.num_groups(),
            "frequency histogram must cover every group"
        );
        let map = policy.plan(freqs);
        let tile_len = store.rows() * store.dim();
        let mut hot = TileCache::new(tile_len);
        let mut dram = TileCache::new(tile_len);
        for (g, tile) in store.tiles() {
            match map.tier(g) {
                Tier::Hot => hot.insert(g, tile),
                Tier::Dram => dram.insert(g, tile),
                Tier::Cold => {}
            }
        }
        Self {
            dim: store.dim(),
            rows: store.rows(),
            catalogue: store.num_embeddings(),
            map,
            hot,
            dram,
            cold: ColdTileFile::from_store(store),
            policy,
            cost,
            access: TierAccess::default(),
            promotions: 0,
            evictions: 0,
        }
    }

    /// Build from a persisted cold image alone — the terabyte-table
    /// path, where no flat in-memory copy ever exists. Hot/DRAM caches
    /// are filled by decoding extents out of the image.
    pub fn from_cold(
        cold: ColdTileFile,
        catalogue: usize,
        freqs: &[u64],
        policy: TierPolicy,
        cost: TierCostModel,
    ) -> Self {
        assert_eq!(
            freqs.len(),
            cold.num_groups(),
            "frequency histogram must cover every group"
        );
        let map = policy.plan(freqs);
        let tile_len = cold.rows() * cold.dim();
        let mut hot = TileCache::new(tile_len);
        let mut dram = TileCache::new(tile_len);
        let mut tile = Vec::with_capacity(tile_len);
        for g in 0..cold.num_groups() as u32 {
            match map.tier(g) {
                Tier::Hot => {
                    cold.read_tile(g, &mut tile);
                    hot.insert(g, &tile);
                }
                Tier::Dram => {
                    cold.read_tile(g, &mut tile);
                    dram.insert(g, &tile);
                }
                Tier::Cold => {}
            }
        }
        Self {
            dim: cold.dim(),
            rows: cold.rows(),
            catalogue,
            map,
            hot,
            dram,
            cold,
            policy,
            cost,
            access: TierAccess::default(),
            promotions: 0,
            evictions: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn num_groups(&self) -> usize {
        self.map.num_groups()
    }

    pub fn num_embeddings(&self) -> usize {
        self.catalogue
    }

    pub fn map(&self) -> &TierMap {
        &self.map
    }

    pub fn policy(&self) -> &TierPolicy {
        &self.policy
    }

    pub fn cost(&self) -> &TierCostModel {
        &self.cost
    }

    pub fn tier_of(&self, group: u32) -> Tier {
        self.map.tier(group)
    }

    /// Hot-tier groups, ascending by id (the set the property tests
    /// compare against the top-frequency prefix).
    pub fn hot_groups(&self) -> Vec<u32> {
        self.map.groups_in(Tier::Hot)
    }

    /// `(hot, dram, cold)` tile occupancy.
    pub fn occupancy(&self) -> (usize, usize, usize) {
        (
            self.map.count(Tier::Hot),
            self.map.count(Tier::Dram),
            self.map.count(Tier::Cold),
        )
    }

    /// Cumulative tile-touch stats recorded by [`Self::charge_query`].
    pub fn access(&self) -> &TierAccess {
        &self.access
    }

    pub fn promotions(&self) -> u64 {
        self.promotions
    }

    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// One row of one group's tile, wherever it lives. `scratch` backs
    /// cold decodes.
    fn row_of<'a>(&'a self, group: u32, row: usize, scratch: &'a mut Vec<f32>) -> &'a [f32] {
        match self.map.tier(group) {
            Tier::Hot => self
                .hot
                .row(group, row, self.dim)
                .expect("hot tier map and cache out of sync"),
            Tier::Dram => self
                .dram
                .row(group, row, self.dim)
                .expect("dram tier map and cache out of sync"),
            Tier::Cold => {
                self.cold.read_row(group, row, scratch);
                scratch
            }
        }
    }

    /// Reduce `items` into `out` (zeroed first; `out.len()` must be
    /// `dim`). Walks items in query order through `Mapping::slot_of`
    /// and accumulates with the same 4-wide kernel as the flat store's
    /// `reduce_reference`, skipping out-of-catalogue ids — bit-identical
    /// results for any tier placement.
    pub fn reduce_into(
        &self,
        mapping: &Mapping,
        items: &[EmbeddingId],
        out: &mut [f32],
        scratch: &mut Vec<f32>,
    ) {
        debug_assert_eq!(out.len(), self.dim);
        out.fill(0.0);
        for &e in items {
            if (e as usize) >= self.catalogue {
                continue;
            }
            let slot = mapping.slot_of(e);
            let row = self.row_of(slot.group, slot.row as usize, scratch);
            accum::add_assign_4wide(out, row);
        }
    }

    /// Allocating convenience over [`Self::reduce_into`].
    pub fn reduce(&self, mapping: &Mapping, items: &[EmbeddingId]) -> Vec<f32> {
        let mut out = vec![0.0; self.dim];
        let mut scratch = Vec::with_capacity(self.dim);
        self.reduce_into(mapping, items, &mut out, &mut scratch);
        out
    }

    /// Price one query's tile traffic: each *distinct* group the query
    /// touches (one tile fetch serves every row of that group in the
    /// query) is charged its tier's modeled fetch cost. Out-of-catalogue
    /// ids route to the overflow group — the hardware still probes its
    /// tile, so cold-start traffic is charged and counted even though it
    /// contributes zero to values. Stats accumulate into
    /// [`Self::access`]; the per-query breakdown is returned.
    pub fn charge_query(
        &mut self,
        mapping: &Mapping,
        items: &[EmbeddingId],
        gscratch: &mut Vec<u32>,
    ) -> TierAccess {
        gscratch.clear();
        for &e in items {
            gscratch.push(mapping.slot_of(e).group);
        }
        gscratch.sort_unstable();
        gscratch.dedup();
        let mut acc = TierAccess::default();
        for &g in gscratch.iter() {
            let tier = self.map.tier(g);
            match tier {
                Tier::Hot => acc.hot_hits += 1,
                Tier::Dram => acc.dram_hits += 1,
                Tier::Cold => acc.cold_hits += 1,
            }
            acc.miss_ns += self.cost.fetch_ns(tier);
        }
        self.access.accumulate(&acc);
        acc
    }

    /// Apply the admission/eviction policy against recent-window group
    /// frequencies (the `DriftMonitor` ring, histogrammed by
    /// `allocation::group_frequencies`). Candidates with at least
    /// `promote_min_hits` window hits (and always at least one) are
    /// considered hottest-first; each displaces the coldest hot resident
    /// only if strictly hotter under the `(frequency, id)` key. Evicted
    /// residents fall to DRAM, or straight to cold under DRAM pressure.
    /// Pure function of `window_freqs` — same window, same moves.
    pub fn adapt(&mut self, window_freqs: &[u64]) -> TierStep {
        assert_eq!(
            window_freqs.len(),
            self.num_groups(),
            "window histogram must cover every group"
        );
        let mut step = TierStep::default();
        if self.policy.hot_capacity == 0 {
            return step;
        }
        let min_hits = self.policy.promote_min_hits.max(1);
        let mut cands: Vec<u32> = (0..self.num_groups() as u32)
            .filter(|&g| self.map.tier(g) != Tier::Hot && window_freqs[g as usize] >= min_hits)
            .collect();
        cands.sort_by_key(|&g| (Reverse(window_freqs[g as usize]), g));
        for g in cands {
            if self.map.count(Tier::Hot) < self.policy.hot_capacity {
                self.promote(g);
                step.promoted.push(g);
                continue;
            }
            let victim = self
                .map
                .groups_in(Tier::Hot)
                .into_iter()
                .min_by_key(|&h| TierPolicy::key(window_freqs, h));
            let Some(victim) = victim else { break };
            if TierPolicy::key(window_freqs, g) > TierPolicy::key(window_freqs, victim) {
                self.demote(victim);
                step.evicted.push(victim);
                self.promote(g);
                step.promoted.push(g);
            } else {
                // Candidates run hottest-first: if this one can't
                // displace the coldest resident, none after it can.
                break;
            }
        }
        self.promotions += step.promoted.len() as u64;
        self.evictions += step.evicted.len() as u64;
        step
    }

    /// Move `group` into the hot tier: from the DRAM cache if present,
    /// else decoded out of the cold image.
    fn promote(&mut self, group: u32) {
        debug_assert_ne!(self.map.tier(group), Tier::Hot);
        if let Some(tile) = self.dram.tile(group) {
            let tile = tile.to_vec();
            self.dram.remove(group);
            self.hot.insert(group, &tile);
        } else {
            let mut tile = Vec::with_capacity(self.rows * self.dim);
            self.cold.read_tile(group, &mut tile);
            self.hot.insert(group, &tile);
        }
        self.map.set(group, Tier::Hot);
    }

    /// Drop `group` out of the hot tier: into DRAM if there is room,
    /// else back to cold only (the image already holds its bytes).
    fn demote(&mut self, group: u32) {
        debug_assert_eq!(self.map.tier(group), Tier::Hot);
        let tile = self
            .hot
            .tile(group)
            .expect("hot tier map and cache out of sync")
            .to_vec();
        self.hot.remove(group);
        let dram_open =
            self.policy.dram_capacity == 0 || self.dram.len() < self.policy.dram_capacity;
        if dram_open {
            self.dram.insert(group, &tile);
            self.map.set(group, Tier::Dram);
        } else {
            self.map.set(group, Tier::Cold);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grouping::Mapping;
    use crate::workload::Query;

    fn fixture() -> (Mapping, EmbeddingStore) {
        // 8 embeddings in 4 groups of 2, plus whatever overflow packing
        // from_groups appends (none here: all ids placed).
        let m = Mapping::from_groups(
            vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]],
            2,
            8,
        );
        let s = EmbeddingStore::random(&m, 4, 2, 42);
        (m, s)
    }

    #[test]
    fn build_fills_caches_per_plan() {
        let (m, s) = fixture();
        let freqs = vec![10, 5, 2, 1];
        let t = TieredStore::build(&s, &freqs, TierPolicy::new(1, 2, 1), TierCostModel::default());
        assert_eq!(t.tier_of(0), Tier::Hot);
        assert_eq!(t.tier_of(1), Tier::Dram);
        assert_eq!(t.tier_of(2), Tier::Dram);
        assert_eq!(t.tier_of(3), Tier::Cold);
        assert_eq!(t.occupancy(), (1, 2, m.num_groups() - 3));
    }

    #[test]
    fn reduce_matches_flat_store_everywhere() {
        let (m, s) = fixture();
        let freqs = vec![10, 5, 2, 1];
        let t = TieredStore::build(&s, &freqs, TierPolicy::new(1, 1, 1), TierCostModel::default());
        // Items span hot (0,1), dram (2,3), cold (4..8), and one
        // out-of-catalogue id.
        let q = Query::new(vec![0, 2, 3, 5, 7, 99]);
        let flat = s.reduce_reference(&q.items);
        let tiered = t.reduce(&m, &q.items);
        assert_eq!(
            tiered.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            flat.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn from_cold_matches_build() {
        let (m, s) = fixture();
        let freqs = vec![10, 5, 2, 1];
        let policy = TierPolicy::new(2, 1, 1);
        let a = TieredStore::build(&s, &freqs, policy, TierCostModel::default());
        let b = TieredStore::from_cold(
            ColdTileFile::from_store(&s),
            s.num_embeddings(),
            &freqs,
            policy,
            TierCostModel::default(),
        );
        let q = Query::new(vec![1, 4, 6]);
        assert_eq!(
            a.reduce(&m, &q.items).iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.reduce(&m, &q.items).iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(a.occupancy(), b.occupancy());
    }

    #[test]
    fn charge_query_prices_distinct_tiles() {
        let (m, s) = fixture();
        let freqs = vec![10, 5, 2, 1];
        let cost = TierCostModel::new(100.0, 1000.0);
        let mut t = TieredStore::build(&s, &freqs, TierPolicy::new(1, 1, 1), cost);
        let mut scratch = Vec::new();
        // Groups: 0 (hot), 1 (dram), 2 (cold) — ids 0,1 share group 0.
        let acc = t.charge_query(&m, &[0, 1, 2, 4], &mut scratch);
        assert_eq!(acc.hot_hits, 1);
        assert_eq!(acc.dram_hits, 1);
        assert_eq!(acc.cold_hits, 1);
        assert_eq!(acc.miss_ns, 1100.0);
        assert_eq!(t.access().total(), 3);
        assert!((acc.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn adapt_promotes_hot_window_groups_deterministically() {
        let (_, s) = fixture();
        let freqs = vec![10, 5, 2, 1];
        let policy = TierPolicy::new(1, 0, 2);
        let mut a = TieredStore::build(&s, &freqs, policy, TierCostModel::default());
        let mut b = a.clone();
        // Group 3 turns hot in the recent window; group 0 goes quiet.
        let window = vec![0, 1, 0, 9];
        let step_a = a.adapt(&window);
        let step_b = b.adapt(&window);
        assert_eq!(step_a, step_b, "same window must produce same moves");
        assert_eq!(step_a.promoted, vec![3]);
        assert_eq!(step_a.evicted, vec![0]);
        assert_eq!(a.tier_of(3), Tier::Hot);
        assert_eq!(a.tier_of(0), Tier::Dram);
        assert_eq!(a.promotions(), 1);
        assert_eq!(a.evictions(), 1);
    }

    #[test]
    fn adapt_respects_hysteresis_and_ties() {
        let (_, s) = fixture();
        let freqs = vec![10, 5, 2, 1];
        let mut t =
            TieredStore::build(&s, &freqs, TierPolicy::new(1, 0, 3), TierCostModel::default());
        // Two window hits < promote_min_hits of 3: no move.
        let step = t.adapt(&[0, 2, 0, 0]);
        assert!(step.promoted.is_empty() && step.evicted.is_empty());
        // Equal frequency never displaces: ties keep the resident with
        // the smaller id already hot? Resident is 0; candidate 1 ties at
        // 4 hits — key(1) < key(0) on the id tie-break, so no move.
        let step = t.adapt(&[4, 4, 0, 0]);
        assert!(step.promoted.is_empty() && step.evicted.is_empty());
        assert_eq!(t.tier_of(0), Tier::Hot);
    }

    #[test]
    fn eviction_under_dram_pressure_falls_to_cold() {
        let (_, s) = fixture();
        let freqs = vec![10, 5, 2, 1];
        // DRAM capacity 1 and already full (group 1).
        let mut t =
            TieredStore::build(&s, &freqs, TierPolicy::new(1, 1, 1), TierCostModel::default());
        let step = t.adapt(&[0, 0, 0, 7]);
        assert_eq!(step.promoted, vec![3]);
        assert_eq!(step.evicted, vec![0]);
        assert_eq!(t.tier_of(0), Tier::Cold, "dram full: eviction drops to cold");
        // The bytes survive the round trip through cold.
        let m = fixture().0;
        let flat = s.reduce_reference(&[0, 1]);
        assert_eq!(
            t.reduce(&m, &[0, 1]).iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            flat.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn zero_hot_capacity_never_promotes() {
        let (_, s) = fixture();
        let mut t = TieredStore::build(
            &s,
            &[10, 5, 2, 1],
            TierPolicy::new(0, 0, 1),
            TierCostModel::default(),
        );
        let step = t.adapt(&[100, 100, 100, 100]);
        assert!(step.promoted.is_empty() && step.evicted.is_empty());
        assert_eq!(t.occupancy().0, 0);
    }
}
