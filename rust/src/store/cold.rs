//! The cold tier's persistent tile format: header + per-group extents +
//! raw tile data, with a clean in-memory façade.
//!
//! The cold tier is the **canonical, complete** copy of the table: every
//! group's tile is written once at build time, and the hot/DRAM tiers
//! are caches over it — eviction never writes back (embedding tables
//! are read-only at serve time), promotion decodes straight out of the
//! image. The layout is deliberately mmap-friendly (fixed header, then
//! a flat extent table, then page-aligned-in-spirit raw data) in the
//! style of codanna's persistent index segments: a reader can locate
//! any tile with two bounded lookups and no parsing beyond the header.
//!
//! Layout, all integers little-endian:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  "RXTC"
//! 4       4     version (u32, currently 1)
//! 8       4     num_groups (u32)
//! 12      4     rows per tile (u32)
//! 16      4     embedding dim (u32)
//! 20      16*G  extent table: per group { offset: u64, len: u64 },
//!               byte offsets relative to the data section
//! 20+16G  ...   data section: f32 little-endian tile contents
//! ```
//!
//! Extents are stored per group (not derived from a uniform stride) so a
//! future compressed or quantized tile encoding changes only the writer;
//! the reader already honors variable-length extents. Values round-trip
//! via `f32::to_le_bytes`/`from_le_bytes`, which is exact — reductions
//! over cold-resident groups stay **bit-identical** to the flat store.

use crate::coordinator::EmbeddingStore;
use crate::Result;

/// File magic for the cold tile format.
pub const COLD_MAGIC: [u8; 4] = *b"RXTC";
/// Current format version.
pub const COLD_VERSION: u32 = 1;

const HEADER_LEN: usize = 20;
const EXTENT_LEN: usize = 16;

/// In-memory façade over one encoded cold-tier image. Holds the parsed
/// extent table plus the raw data section; rows decode on demand.
#[derive(Debug, Clone)]
pub struct ColdTileFile {
    rows: usize,
    dim: usize,
    /// Per-group `(offset, len)` into `data`, in group order.
    extents: Vec<(u64, u64)>,
    /// The image's data section (raw little-endian f32 bytes).
    data: Vec<u8>,
}

impl ColdTileFile {
    /// Encode every tile of `store` into one image (header + extents +
    /// data). The image is self-describing; [`ColdTileFile::from_bytes`]
    /// round-trips it exactly.
    pub fn encode(store: &EmbeddingStore) -> Vec<u8> {
        let groups = store.num_groups();
        let tile_bytes = store.rows() * store.dim() * 4;
        let mut out = Vec::with_capacity(HEADER_LEN + groups * (EXTENT_LEN + tile_bytes));
        out.extend_from_slice(&COLD_MAGIC);
        out.extend_from_slice(&COLD_VERSION.to_le_bytes());
        out.extend_from_slice(&(groups as u32).to_le_bytes());
        out.extend_from_slice(&(store.rows() as u32).to_le_bytes());
        out.extend_from_slice(&(store.dim() as u32).to_le_bytes());
        for g in 0..groups {
            let off = (g * tile_bytes) as u64;
            out.extend_from_slice(&off.to_le_bytes());
            out.extend_from_slice(&(tile_bytes as u64).to_le_bytes());
        }
        for (_, tile) in store.tiles() {
            for &v in tile {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Build the façade directly from a flat store (encode + parse; the
    /// canonical in-process construction).
    pub fn from_store(store: &EmbeddingStore) -> Self {
        Self::from_bytes(Self::encode(store)).expect("self-encoded image must parse")
    }

    /// Parse an encoded image. Validates magic, version, and that every
    /// extent lies inside the data section.
    pub fn from_bytes(bytes: Vec<u8>) -> Result<Self> {
        anyhow::ensure!(bytes.len() >= HEADER_LEN, "cold image truncated at header");
        anyhow::ensure!(bytes[0..4] == COLD_MAGIC, "bad cold image magic");
        let u32_at = |off: usize| -> u32 {
            u32::from_le_bytes(bytes[off..off + 4].try_into().expect("4 bytes"))
        };
        let version = u32_at(4);
        anyhow::ensure!(
            version == COLD_VERSION,
            "cold image version {version} != supported {COLD_VERSION}"
        );
        let groups = u32_at(8) as usize;
        let rows = u32_at(12) as usize;
        let dim = u32_at(16) as usize;
        let table_end = HEADER_LEN + groups * EXTENT_LEN;
        anyhow::ensure!(bytes.len() >= table_end, "cold image truncated at extent table");
        let mut extents = Vec::with_capacity(groups);
        for g in 0..groups {
            let base = HEADER_LEN + g * EXTENT_LEN;
            let off = u64::from_le_bytes(bytes[base..base + 8].try_into().expect("8 bytes"));
            let len = u64::from_le_bytes(bytes[base + 8..base + 16].try_into().expect("8 bytes"));
            extents.push((off, len));
        }
        let data = bytes[table_end..].to_vec();
        for (g, &(off, len)) in extents.iter().enumerate() {
            let end = off.checked_add(len);
            anyhow::ensure!(
                end.is_some_and(|e| e as usize <= data.len()),
                "group {g} extent ({off}+{len}) outside data section ({} bytes)",
                data.len()
            );
            anyhow::ensure!(
                len as usize == rows * dim * 4,
                "group {g} extent len {len} != tile size {}",
                rows * dim * 4
            );
        }
        Ok(Self {
            rows,
            dim,
            extents,
            data,
        })
    }

    /// Persist the image to `path`.
    pub fn write(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_bytes())
            .map_err(|e| anyhow::anyhow!("writing cold image {}: {e}", path.display()))
    }

    /// Open a persisted image.
    pub fn open(path: &std::path::Path) -> Result<Self> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading cold image {}: {e}", path.display()))?;
        Self::from_bytes(bytes)
    }

    /// Re-encode the façade into image bytes (header + extents + data).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(HEADER_LEN + self.extents.len() * EXTENT_LEN + self.data.len());
        out.extend_from_slice(&COLD_MAGIC);
        out.extend_from_slice(&COLD_VERSION.to_le_bytes());
        out.extend_from_slice(&(self.extents.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.rows as u32).to_le_bytes());
        out.extend_from_slice(&(self.dim as u32).to_le_bytes());
        for &(off, len) in &self.extents {
            out.extend_from_slice(&off.to_le_bytes());
            out.extend_from_slice(&len.to_le_bytes());
        }
        out.extend_from_slice(&self.data);
        out
    }

    pub fn num_groups(&self) -> usize {
        self.extents.len()
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Decode one row of one group's tile into `out` (cleared first).
    pub fn read_row(&self, group: u32, row: usize, out: &mut Vec<f32>) {
        out.clear();
        let (off, _) = self.extents[group as usize];
        let base = off as usize + row * self.dim * 4;
        out.extend(
            self.data[base..base + self.dim * 4]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes"))),
        );
    }

    /// Decode one whole tile (`rows * dim` values) into `out` (cleared
    /// first) — the promotion path's fetch.
    pub fn read_tile(&self, group: u32, out: &mut Vec<f32>) {
        out.clear();
        let (off, len) = self.extents[group as usize];
        let base = off as usize;
        out.extend(
            self.data[base..base + len as usize]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes"))),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grouping::Mapping;

    fn store() -> EmbeddingStore {
        let m = Mapping::from_groups(vec![vec![2, 0], vec![1, 3]], 2, 4);
        EmbeddingStore::random(&m, 3, 2, 11)
    }

    #[test]
    fn round_trips_bit_identically() {
        let s = store();
        let img = ColdTileFile::from_bytes(ColdTileFile::encode(&s)).unwrap();
        assert_eq!(img.num_groups(), s.num_groups());
        assert_eq!(img.rows(), s.rows());
        assert_eq!(img.dim(), s.dim());
        let mut row = Vec::new();
        for g in 0..s.num_groups() as u32 {
            let tile = s.tile(g);
            for r in 0..s.rows() {
                img.read_row(g, r, &mut row);
                let want = &tile[r * s.dim()..(r + 1) * s.dim()];
                let got_bits: Vec<u32> = row.iter().map(|v| v.to_bits()).collect();
                let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got_bits, want_bits, "group {g} row {r}");
            }
        }
    }

    #[test]
    fn read_tile_matches_rows() {
        let s = store();
        let img = ColdTileFile::from_store(&s);
        let mut tile = Vec::new();
        img.read_tile(1, &mut tile);
        assert_eq!(tile.len(), s.rows() * s.dim());
        assert_eq!(
            tile.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            s.tile(1).iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn to_bytes_is_the_identity_on_parse() {
        let s = store();
        let bytes = ColdTileFile::encode(&s);
        let img = ColdTileFile::from_bytes(bytes.clone()).unwrap();
        assert_eq!(img.to_bytes(), bytes);
    }

    #[test]
    fn corrupt_images_rejected() {
        let s = store();
        let mut bytes = ColdTileFile::encode(&s);
        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(ColdTileFile::from_bytes(bad).is_err());
        // Bad version.
        let mut bad = bytes.clone();
        bad[4] = 99;
        assert!(ColdTileFile::from_bytes(bad).is_err());
        // Truncated data section.
        bytes.truncate(bytes.len() - 1);
        assert!(ColdTileFile::from_bytes(bytes).is_err());
        // Truncated header.
        assert!(ColdTileFile::from_bytes(vec![0u8; 3]).is_err());
    }

    #[test]
    fn persists_to_disk() {
        let s = store();
        let img = ColdTileFile::from_store(&s);
        let path = std::env::temp_dir().join("recross_cold_tile_test.rxtc");
        img.write(&path).unwrap();
        let back = ColdTileFile::open(&path).unwrap();
        assert_eq!(back.to_bytes(), img.to_bytes());
        let _ = std::fs::remove_file(&path);
    }
}
