//! Modeled per-tier access costs.
//!
//! The timing twin prices everything deterministically (PR 5's
//! contract), so tier misses are priced the same way: a fixed modeled
//! fetch latency per tile touched, by tier. Hot tiles are
//! crossbar-resident — their service cost is already what the scheduler
//! computes, so the hot fetch cost is zero by construction. DRAM and
//! cold fetches add modeled nanoseconds that the `Tiered` backend folds
//! into each query's finish time, which is how misses surface in
//! sojourn/p99 exactly like crossbar service does.
//!
//! Defaults are order-of-magnitude figures from the tiered-DLRM
//! literature (Software Defined Memory, UpDLRM): ~100 ns for a DRAM
//! tile touch, a few µs for a cold (file/SSD-class) touch. They are
//! config knobs (`store.dram_ns` / `store.cold_ns`), not constants.

use super::Tier;
use crate::config::StoreConfig;

/// Deterministic modeled fetch cost per tile touch, by tier.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierCostModel {
    /// Modeled ns to touch a DRAM-resident tile.
    pub dram_ns: f64,
    /// Modeled ns to touch a cold (file-resident) tile.
    pub cold_ns: f64,
}

impl TierCostModel {
    pub fn new(dram_ns: f64, cold_ns: f64) -> Self {
        assert!(dram_ns >= 0.0 && cold_ns >= 0.0, "tier costs must be non-negative");
        Self { dram_ns, cold_ns }
    }

    pub fn from_config(cfg: &StoreConfig) -> Self {
        Self::new(cfg.dram_ns, cfg.cold_ns)
    }

    /// Modeled ns to fetch one tile from `tier`. Hot is free: the
    /// crossbar schedule already prices its service.
    pub fn fetch_ns(&self, tier: Tier) -> f64 {
        match tier {
            Tier::Hot => 0.0,
            Tier::Dram => self.dram_ns,
            Tier::Cold => self.cold_ns,
        }
    }
}

impl Default for TierCostModel {
    fn default() -> Self {
        Self::from_config(&StoreConfig::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hot_is_free_and_cold_dominates() {
        let m = TierCostModel::default();
        assert_eq!(m.fetch_ns(Tier::Hot), 0.0);
        assert!(m.fetch_ns(Tier::Dram) > 0.0);
        assert!(m.fetch_ns(Tier::Cold) > m.fetch_ns(Tier::Dram));
    }
}
