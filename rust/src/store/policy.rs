//! Admission/eviction policy: which groups live in which tier.
//!
//! Placement is a pure function of group frequencies — Algorithm 1's
//! offline counts for the initial plan, the `DriftMonitor` recent-query
//! ring for online replans. The ordering contract (property-tested in
//! `tests/tiered_store.rs`) is:
//!
//! > the hot set at capacity `k` is exactly the top-`k` prefix of the
//! > global frequency order, descending by frequency with ties broken
//! > by ascending group id.
//!
//! Online, `promote_min_hits` adds hysteresis: a group must be seen at
//! least that many times in the recent window before it may displace a
//! hot resident, and it only displaces a resident that is strictly
//! colder under the same `(frequency, id)` key. Every decision is
//! integer-keyed and input-deterministic — same window, same moves.

use std::cmp::Reverse;

use super::{Tier, TierMap};
use crate::config::StoreConfig;

/// Capacity and hysteresis knobs for tier placement.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierPolicy {
    /// Hot-tier capacity in tiles (crossbar-resident groups).
    pub hot_capacity: usize,
    /// DRAM-tier capacity in tiles; `0` means unbounded (no group is
    /// forced cold by DRAM pressure), matching `offline.workers = 0`'s
    /// "no limit" convention.
    pub dram_capacity: usize,
    /// Minimum recent-window hits before a group qualifies for
    /// promotion into the hot tier.
    pub promote_min_hits: u64,
}

impl TierPolicy {
    pub fn new(hot_capacity: usize, dram_capacity: usize, promote_min_hits: u64) -> Self {
        Self {
            hot_capacity,
            dram_capacity,
            promote_min_hits,
        }
    }

    pub fn from_config(cfg: &StoreConfig) -> Self {
        Self::new(cfg.hot_tiles, cfg.dram_tiles, cfg.promote_hits)
    }

    /// Group ids ordered by `(frequency desc, id asc)` — the global
    /// frequency order every placement decision keys on.
    pub fn frequency_order(freqs: &[u64]) -> Vec<u32> {
        let mut order: Vec<u32> = (0..freqs.len() as u32).collect();
        order.sort_by_key(|&g| (Reverse(freqs[g as usize]), g));
        order
    }

    /// Initial placement from global frequencies: the top
    /// `hot_capacity` prefix of [`Self::frequency_order`] goes hot, the
    /// next `dram_capacity` (or everything remaining when unbounded)
    /// goes to DRAM, the rest stays cold.
    pub fn plan(&self, freqs: &[u64]) -> TierMap {
        let order = Self::frequency_order(freqs);
        let mut tiers = vec![Tier::Cold; freqs.len()];
        let hot_end = self.hot_capacity.min(order.len());
        let dram_end = if self.dram_capacity == 0 {
            order.len()
        } else {
            (hot_end + self.dram_capacity).min(order.len())
        };
        for &g in &order[..hot_end] {
            tiers[g as usize] = Tier::Hot;
        }
        for &g in &order[hot_end..dram_end] {
            tiers[g as usize] = Tier::Dram;
        }
        TierMap::new(tiers)
    }

    /// The promotion comparison key: a candidate displaces a resident
    /// iff `key(candidate) > key(resident)` — i.e. strictly hotter, or
    /// equally hot with a smaller group id.
    pub fn key(freqs: &[u64], group: u32) -> (u64, Reverse<u32>) {
        (freqs[group as usize], Reverse(group))
    }
}

impl Default for TierPolicy {
    fn default() -> Self {
        Self::from_config(&StoreConfig::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frequency_order_breaks_ties_by_id() {
        let freqs = vec![5, 9, 5, 0, 9];
        assert_eq!(TierPolicy::frequency_order(&freqs), vec![1, 4, 0, 2, 3]);
    }

    #[test]
    fn plan_is_the_top_prefix() {
        let freqs = vec![5, 9, 5, 0, 9];
        let map = TierPolicy::new(2, 2, 1).plan(&freqs);
        assert_eq!(map.tier(1), Tier::Hot);
        assert_eq!(map.tier(4), Tier::Hot);
        assert_eq!(map.tier(0), Tier::Dram);
        assert_eq!(map.tier(2), Tier::Dram);
        assert_eq!(map.tier(3), Tier::Cold);
    }

    #[test]
    fn unbounded_dram_leaves_nothing_cold() {
        let freqs = vec![5, 9, 5, 0, 9];
        let map = TierPolicy::new(1, 0, 1).plan(&freqs);
        assert_eq!(map.count(Tier::Hot), 1);
        assert_eq!(map.count(Tier::Dram), 4);
        assert_eq!(map.count(Tier::Cold), 0);
    }

    #[test]
    fn zero_hot_capacity_plans_no_hot_tiles() {
        let map = TierPolicy::new(0, 1, 1).plan(&[3, 1]);
        assert_eq!(map.count(Tier::Hot), 0);
        assert_eq!(map.tier(0), Tier::Dram);
        assert_eq!(map.tier(1), Tier::Cold);
    }

    #[test]
    fn key_prefers_hotter_then_smaller_id() {
        let freqs = vec![4, 7, 7];
        assert!(TierPolicy::key(&freqs, 1) > TierPolicy::key(&freqs, 0));
        assert!(TierPolicy::key(&freqs, 1) > TierPolicy::key(&freqs, 2));
    }
}
