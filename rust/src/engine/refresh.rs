//! Incremental offline phase — refresh a prepared engine in place.
//!
//! [`Engine::prepare`] recomputes the whole offline pipeline (graph →
//! grouping → replication) from scratch; under drift that is O(table)
//! work to react to an O(window) change. [`PreparedEngine`] keeps the
//! offline-phase *state* — the sliding query window, its
//! [`WindowGraph`], the mapping, and the replication plan — and exposes
//! [`PreparedEngine::refresh`], which reacts to a window slide by
//! re-deriving only what the slide touched:
//!
//! 1. [`WindowGraph::apply_window`] updates freqs/edges in O(window)
//!    and reports per-node net change ([`crate::graph::GraphDelta`]).
//! 2. Nodes past the [`DeltaParams`] thresholds mark their groups
//!    dirty; [`regroup_subset`] re-runs Algorithm 1 over exactly those
//!    groups. Clean groups keep ids and row layout bit-identically.
//! 3. [`crate::allocation::plan_replication_delta`] re-solves Eq. 1 for
//!    the dirty groups only, holding clean groups' copies fixed.
//!
//! **Identity contract** (the differential-fuzz oracle,
//! `tests/offline_delta.rs`): [`PreparedEngine::refresh_full`] — the
//! same pipeline with every node dirty — produces the *bit-identical*
//! mapping and replication plan as a fresh [`Engine::prepare`] over the
//! slid window, because each delta stage is the generalisation the full
//! stage delegates to (same code path, scoped to "everything"). The
//! graph layer is exact at any scope: per-query content-seeded pair
//! sampling makes add/retire true inverses, so the window graph always
//! equals a batch rebuild. Partial-scope refreshes trade plan
//! optimality (clean groups hold possibly-stale copies) for O(delta)
//! work — never correctness of the layout contract.

use super::{Engine, Scheme};
use crate::allocation::{self, Replication};
use crate::config::Config;
use crate::graph::{DeltaParams, WindowGraph};
use crate::grouping::{regroup_subset, GroupingDelta};
use crate::obs::{names, Obs};
use crate::workload::{Query, Trace};
use std::sync::{Arc, OnceLock};

/// What one [`PreparedEngine::refresh`] call did — the work counters the
/// delta contract is asserted on (incremental work must scale with the
/// delta, not the table).
#[derive(Debug, Clone)]
pub struct RefreshReport {
    /// True when the refresh ran at full scope (every node dirty).
    pub full: bool,
    /// Nodes whose net graph change passed the [`DeltaParams`] scope.
    pub dirty_nodes: usize,
    /// Groups whose membership was re-derived.
    pub groups_changed: usize,
    /// Groups in the refreshed mapping.
    pub groups_total: usize,
    /// Embedding rows re-placed (tile rows that moved).
    pub ids_moved: usize,
    /// Embedding rows in the catalogue.
    pub ids_total: usize,
    /// The grouping delta itself (changed group ids, moved embedding
    /// ids) — what a placement layer needs to re-install tiles.
    pub grouping: GroupingDelta,
}

/// An engine plus the offline-phase state needed to refresh it
/// incrementally when the query window slides.
#[derive(Debug)]
pub struct PreparedEngine {
    engine: Engine,
    cfg: Config,
    window: Trace,
    wgraph: WindowGraph,
    obs: Arc<Obs>,
}

impl PreparedEngine {
    /// Run the offline phase over `window` and keep the state for later
    /// refreshes. Only the correlation-grouped schemes are supported —
    /// the delta stages are defined in terms of Algorithm 1 groups.
    pub fn prepare(scheme: Scheme, window: &Trace, cfg: &Config) -> Self {
        assert!(
            matches!(
                scheme,
                Scheme::ReCross | Scheme::ReCrossNoDup | Scheme::ReCrossNoSwitch
            ),
            "incremental refresh is defined for the correlation-grouped schemes \
             (recross / recross-nodup / recross-noswitch), not {scheme:?}"
        );
        // Honor the configured worker count on this entry point too —
        // callers that skip `OfflinePhase::run` (the incremental path,
        // benches) still get the parallel substrate shaped by config.
        crate::util::par::set_default_workers(cfg.offline.workers);
        let wgraph = WindowGraph::from_trace(window);
        let engine = Engine::prepare(scheme, &wgraph.to_cograph(), window, cfg);
        Self {
            engine,
            cfg: cfg.clone(),
            window: window.clone(),
            wgraph,
            obs: Obs::disabled(),
        }
    }

    /// Attach an observability handle; refreshes record the `offline.*`
    /// metrics family on it.
    pub fn with_obs(mut self, obs: Arc<Obs>) -> Self {
        self.obs = obs;
        self
    }

    /// The live engine (mapping/replication reflect the last refresh).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// The current sliding window the offline state corresponds to.
    pub fn window(&self) -> &Trace {
        &self.window
    }

    /// The incrementally maintained affinity graph.
    pub fn window_graph(&self) -> &WindowGraph {
        &self.wgraph
    }

    /// Give up refreshability and keep just the engine.
    pub fn into_engine(self) -> Engine {
        self.engine
    }

    /// Slide the window (`added` appended, the oldest `retire` queries
    /// dropped) and refresh the offline products at the default
    /// [`DeltaParams`] scope.
    pub fn refresh(&mut self, added: &[Query], retire: usize) -> RefreshReport {
        self.refresh_impl(added, retire, Some(&DeltaParams::default()))
    }

    /// As [`PreparedEngine::refresh`] with explicit scoping thresholds.
    pub fn refresh_with(
        &mut self,
        added: &[Query],
        retire: usize,
        params: &DeltaParams,
    ) -> RefreshReport {
        self.refresh_impl(added, retire, Some(params))
    }

    /// Slide the window and re-derive **everything** through the same
    /// delta code path — the full-recompute oracle. Bit-identical to a
    /// fresh [`Engine::prepare`] over the slid window.
    pub fn refresh_full(&mut self, added: &[Query], retire: usize) -> RefreshReport {
        self.refresh_impl(added, retire, None)
    }

    fn refresh_impl(
        &mut self,
        added: &[Query],
        retire: usize,
        scope: Option<&DeltaParams>,
    ) -> RefreshReport {
        assert!(
            retire <= self.window.queries.len(),
            "cannot retire {retire} of {} window queries",
            self.window.queries.len()
        );
        // The window is a FIFO: retirement always drops the oldest
        // prefix, so the retired queries are by construction a
        // sub-multiset of what was added.
        let retired = Trace {
            num_embeddings: self.window.num_embeddings,
            queries: self.window.queries[..retire].to_vec(),
        };
        let added_trace = Trace {
            num_embeddings: self.window.num_embeddings,
            queries: added.to_vec(),
        };
        let gdelta = self.wgraph.apply_window(&added_trace, &retired);
        self.window.queries.drain(..retire);
        self.window.queries.extend_from_slice(added);

        let n = self.wgraph.num_nodes();
        let dirty: Vec<u32> = match scope {
            Some(p) => gdelta.dirty_nodes(p),
            None => (0..n as u32).collect(),
        };
        let (mapping, grouping) = regroup_subset(&self.wgraph, &self.engine.mapping, &dirty);

        // One counting pass over the slid window serves both the delta
        // re-plan and the engine's cached `group_freqs`.
        let freqs = allocation::group_frequencies(&mapping, &self.window);
        let replication = match self.engine.scheme {
            Scheme::ReCrossNoDup => {
                Replication::identity(mapping.num_groups(), self.cfg.scheme.batch_size)
            }
            _ => {
                let mut dirty_groups = vec![false; mapping.num_groups()];
                for &g in &grouping.changed_groups {
                    if let Some(flag) = dirty_groups.get_mut(g as usize) {
                        *flag = true;
                    }
                }
                allocation::plan_replication_delta(
                    &self.engine.replication,
                    &freqs,
                    &dirty_groups,
                    self.cfg.scheme.batch_size,
                    self.cfg.scheme.dup_ratio,
                )
            }
        };

        let report = RefreshReport {
            full: scope.is_none(),
            dirty_nodes: dirty.len(),
            groups_changed: grouping.changed_groups.len(),
            groups_total: mapping.num_groups(),
            ids_moved: grouping.moved_ids.len(),
            ids_total: n,
            grouping,
        };

        self.engine.mapping = mapping;
        self.engine.replication = replication;
        self.engine.group_freqs = OnceLock::from(freqs);

        if report.full {
            self.obs.incr(names::OFFLINE_FULL_REBUILDS, 1);
        } else {
            self.obs.incr(names::OFFLINE_REFRESHES, 1);
        }
        self.obs
            .incr(names::OFFLINE_GROUPS_TOUCHED, report.groups_changed as u64);
        self.obs
            .gauge_set(names::OFFLINE_GROUPS_TOTAL, report.groups_total as f64);
        self.obs.incr(names::OFFLINE_IDS_MOVED, report.ids_moved as u64);
        self.obs
            .gauge_set(names::OFFLINE_IDS_TOTAL, report.ids_total as f64);
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::CoGraph;

    fn cfg() -> Config {
        let mut cfg = Config::paper_default();
        cfg.scheme.group_size = 4;
        cfg.scheme.batch_size = 64;
        cfg
    }

    fn trace(n: u32, queries: Vec<Vec<u32>>) -> Trace {
        Trace {
            num_embeddings: n,
            queries: queries.into_iter().map(Query::new).collect(),
        }
    }

    fn base_window() -> Trace {
        let mut qs = Vec::new();
        for _ in 0..12 {
            qs.push(vec![0, 1, 2, 3]);
            qs.push(vec![4, 5, 6, 7]);
            qs.push(vec![8, 9, 10, 11]);
        }
        qs.push(vec![12, 13]);
        qs.push(vec![14, 15]);
        trace(16, qs)
    }

    fn drift_queries() -> Vec<Query> {
        (0..20).map(|_| Query::new(vec![0, 8, 12, 14])).collect()
    }

    fn assert_engines_equal(a: &Engine, b: &Engine) {
        assert_eq!(a.mapping().groups, b.mapping().groups);
        assert_eq!(a.mapping().slot, b.mapping().slot);
        assert_eq!(a.replication().copies, b.replication().copies);
        assert_eq!(
            a.replication().total_crossbars,
            b.replication().total_crossbars
        );
    }

    #[test]
    fn prepare_matches_plain_engine_prepare() {
        let w = base_window();
        let cfg = cfg();
        let pe = PreparedEngine::prepare(Scheme::ReCross, &w, &cfg);
        let oracle = Engine::prepare(Scheme::ReCross, &CoGraph::build(&w), &w, &cfg);
        assert_engines_equal(pe.engine(), &oracle);
    }

    #[test]
    fn full_refresh_matches_fresh_prepare() {
        let w = base_window();
        let cfg = cfg();
        for scheme in [Scheme::ReCross, Scheme::ReCrossNoDup, Scheme::ReCrossNoSwitch] {
            let mut pe = PreparedEngine::prepare(scheme, &w, &cfg);
            let added = drift_queries();
            let report = pe.refresh_full(&added, 10);

            let mut slid = w.clone();
            slid.queries.drain(..10);
            slid.queries.extend_from_slice(&added);
            let oracle = Engine::prepare(scheme, &CoGraph::build(&slid), &slid, &cfg);
            assert_engines_equal(pe.engine(), &oracle);
            assert!(report.full);
            assert_eq!(report.ids_total, 16);
        }
    }

    #[test]
    fn noop_slide_touches_nothing() {
        let w = base_window();
        let cfg = cfg();
        let mut pe = PreparedEngine::prepare(Scheme::ReCross, &w, &cfg);
        let before = pe.engine().clone();
        let report = pe.refresh(&[], 0);
        assert_eq!(report.groups_changed, 0);
        assert_eq!(report.ids_moved, 0);
        assert_engines_equal(pe.engine(), &before);
    }

    #[test]
    fn localized_drift_keeps_clean_groups() {
        let w = base_window();
        let cfg = cfg();
        let mut pe = PreparedEngine::prepare(Scheme::ReCross, &w, &cfg);
        let before = pe.engine().clone();
        // Hammer the cold tail only; the hot cliques must keep their
        // exact groups and replication.
        let added: Vec<Query> = (0..30).map(|_| Query::new(vec![12, 14, 15])).collect();
        let report = pe.refresh_with(&added, 0, &DeltaParams::sensitive());
        assert!(report.ids_moved < report.ids_total, "everything moved");
        for v in 0..16u32 {
            if !report.grouping.moved_ids.contains(&v) {
                assert_eq!(
                    pe.engine().mapping().slot_of(v),
                    before.mapping().slot_of(v),
                    "clean id {v} moved"
                );
            }
        }
        for g in 0..pe.engine().mapping().num_groups() as u32 {
            if !report.grouping.changed_groups.contains(&g) {
                assert_eq!(
                    pe.engine().replication().copies_of(g),
                    before.replication().copies_of(g),
                    "clean group {g} re-planned"
                );
            }
        }
    }

    #[test]
    fn window_state_tracks_slides() {
        let w = base_window();
        let cfg = cfg();
        let mut pe = PreparedEngine::prepare(Scheme::ReCross, &w, &cfg);
        let added = drift_queries();
        pe.refresh(&added, 5);
        assert_eq!(pe.window().queries.len(), w.queries.len() - 5 + added.len());
        assert_eq!(pe.window_graph().num_queries(), pe.window().queries.len());
    }

    #[test]
    #[should_panic(expected = "correlation-grouped")]
    fn naive_scheme_rejected() {
        let w = base_window();
        PreparedEngine::prepare(Scheme::Naive, &w, &cfg());
    }
}
