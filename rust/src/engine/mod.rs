//! End-to-end embedding-reduction engines.
//!
//! An engine bundles the **offline phase** (co-occurrence graph → mapping →
//! replication plan, §III-A) with the **online phase** (batch scheduling on
//! the crossbar pool). Four schemes reproduce the paper's comparisons:
//!
//! | scheme      | mapping            | duplication | dataflow            | ADC            |
//! |-------------|--------------------|-------------|---------------------|----------------|
//! | `naive`     | itemID order       | none        | in-crossbar MAC     | always MAC     |
//! | `frequency` | frequency order    | none        | in-crossbar MAC     | always MAC     |
//! | `nmars`     | itemID order       | none        | lookup + serial add | full-res sense |
//! | `recross`   | Algorithm 1        | Eq. 1 (log) | in-crossbar MAC     | dynamic switch |
//!
//! Ablation variants (`recross-nodup`, `recross-noswitch`, `recross-linear`)
//! support Fig. 10 and the design-choice ablations in DESIGN.md.

pub mod refresh;

pub use refresh::{PreparedEngine, RefreshReport};

use crate::allocation::{self, Replication};
use crate::config::Config;
use crate::graph::CoGraph;
use crate::grouping::{CorrelationMapper, FrequencyMapper, Mapper, Mapping, NaiveMapper};
use crate::sched::{ExecStats, Scheduler, Scratch};
use crate::workload::{Query, Trace};
use crate::xbar::{CircuitParams, CrossbarModel};
use std::sync::OnceLock;

/// Scheme selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Baseline: itemID mapping, full-MAC ADC, no duplication.
    Naive,
    /// Frequency-sorted mapping (Fig. 9's comparison, cite [33]).
    Frequency,
    /// nMARS: parallel in-memory lookups, sequential aggregation.
    Nmars,
    /// Full ReCross: Alg. 1 + Eq. 1 duplication + dynamic-switch ADC.
    ReCross,
    /// Ablation: ReCross without duplication (Fig. 10 "w/o dup").
    ReCrossNoDup,
    /// Ablation: ReCross without the dynamic-switch ADC.
    ReCrossNoSwitch,
    /// Ablation: ReCross with naive *linear* copy scaling instead of Eq. 1
    /// (the left pie chart of Fig. 5).
    ReCrossLinear,
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Naive => "naive",
            Scheme::Frequency => "frequency",
            Scheme::Nmars => "nmars",
            Scheme::ReCross => "recross",
            Scheme::ReCrossNoDup => "recross-nodup",
            Scheme::ReCrossNoSwitch => "recross-noswitch",
            Scheme::ReCrossLinear => "recross-linear",
        }
    }

    /// Inverse of [`Scheme::name`]: the one scheme-parsing rule every
    /// entry point (CLI, benches, configs) shares. `None` for unknown
    /// names.
    pub fn by_name(name: &str) -> Option<Scheme> {
        Self::all().into_iter().find(|s| s.name() == name)
    }

    /// Every scheme, paper baselines and ablations alike.
    pub fn all() -> [Scheme; 7] {
        [
            Scheme::Naive,
            Scheme::Frequency,
            Scheme::Nmars,
            Scheme::ReCross,
            Scheme::ReCrossNoDup,
            Scheme::ReCrossNoSwitch,
            Scheme::ReCrossLinear,
        ]
    }

    /// All paper-figure schemes (Fig. 8 comparison set).
    pub fn fig8_set() -> [Scheme; 3] {
        [Scheme::Naive, Scheme::Nmars, Scheme::ReCross]
    }

    /// Fig. 9 comparison set (activation counts).
    pub fn fig9_set() -> [Scheme; 3] {
        [Scheme::Naive, Scheme::Frequency, Scheme::ReCross]
    }
}

/// Dataflow executed on the crossbar pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Dataflow {
    /// Multi-row MAC activations, partial sums merged per query.
    Mac,
    /// nMARS: per-row lookups + sequential external aggregation.
    NmarsLookup,
}

/// A fully prepared engine: offline phase done, ready to serve batches.
#[derive(Debug, Clone)]
pub struct Engine {
    scheme: Scheme,
    mapping: Mapping,
    replication: Replication,
    model: CrossbarModel,
    dynamic_switch: bool,
    dataflow: Dataflow,
    /// Per-group activation frequencies over the preparation history,
    /// cached so downstream consumers (cluster assembly, refresh) reuse
    /// the counting pass `prepare` already paid for instead of walking
    /// the whole trace again.
    group_freqs: OnceLock<Vec<u64>>,
}

impl Engine {
    /// Run the offline phase for `scheme` on a lookup history.
    ///
    /// `graph` should be built from the *history* trace; the engine is then
    /// evaluated on a held-out trace (the paper's offline/online split).
    pub fn prepare(scheme: Scheme, graph: &CoGraph, history: &Trace, cfg: &Config) -> Self {
        let params = CircuitParams::default();
        Self::prepare_with_params(scheme, graph, history, cfg, &params)
    }

    /// As [`Engine::prepare`] with explicit circuit parameters.
    pub fn prepare_with_params(
        scheme: Scheme,
        graph: &CoGraph,
        history: &Trace,
        cfg: &Config,
        params: &CircuitParams,
    ) -> Self {
        let group_size = cfg
            .scheme
            .group_size
            .min(cfg.hardware.embeddings_per_xbar());
        let model = CrossbarModel::new(&cfg.hardware, params);

        let mapping: Mapping = match scheme {
            Scheme::Naive | Scheme::Nmars => NaiveMapper.map(graph, group_size),
            Scheme::Frequency => FrequencyMapper.map(graph, group_size),
            Scheme::ReCross
            | Scheme::ReCrossNoDup
            | Scheme::ReCrossNoSwitch
            | Scheme::ReCrossLinear => CorrelationMapper.map(graph, group_size),
        };

        let group_freqs: OnceLock<Vec<u64>> = OnceLock::new();
        let replication = match scheme {
            Scheme::ReCross | Scheme::ReCrossNoSwitch => {
                let freqs = allocation::group_frequencies(&mapping, history);
                let plan =
                    allocation::plan_replication(&freqs, cfg.scheme.batch_size, cfg.scheme.dup_ratio);
                let _ = group_freqs.set(freqs);
                plan
            }
            Scheme::ReCrossLinear => {
                let freqs = allocation::group_frequencies(&mapping, history);
                let plan = plan_linear(&freqs, cfg.scheme.batch_size, cfg.scheme.dup_ratio);
                let _ = group_freqs.set(freqs);
                plan
            }
            _ => Replication::identity(mapping.num_groups(), cfg.scheme.batch_size),
        };

        let dynamic_switch = matches!(
            scheme,
            Scheme::ReCross | Scheme::ReCrossNoDup | Scheme::ReCrossLinear
        ) && cfg.scheme.dynamic_switching
            && cfg.hardware.dynamic_switch;

        let dataflow = if scheme == Scheme::Nmars {
            Dataflow::NmarsLookup
        } else {
            Dataflow::Mac
        };

        Self {
            scheme,
            mapping,
            replication,
            model,
            dynamic_switch,
            dataflow,
            group_freqs,
        }
    }

    /// Per-group activation frequencies over the preparation history.
    ///
    /// For duplication schemes this is the exact vector `prepare` already
    /// counted (cached, not recounted); otherwise it is computed once on
    /// first use. `history` must be the same trace the engine was
    /// prepared on — the cache does not re-key on its argument.
    pub fn group_freqs(&self, history: &Trace) -> &[u64] {
        self.group_freqs
            .get_or_init(|| allocation::group_frequencies(&self.mapping, history))
    }

    pub fn scheme(&self) -> Scheme {
        self.scheme
    }

    pub fn name(&self) -> &'static str {
        self.scheme.name()
    }

    pub fn mapping(&self) -> &Mapping {
        &self.mapping
    }

    pub fn replication(&self) -> &Replication {
        &self.replication
    }

    pub fn model(&self) -> &CrossbarModel {
        &self.model
    }

    /// Whether the dynamic-switch ADC path is active for this engine.
    pub fn dynamic_switch(&self) -> bool {
        self.dynamic_switch
    }

    /// Physical crossbars used (area proxy).
    pub fn physical_crossbars(&self) -> usize {
        self.replication.total_crossbars
    }

    /// A scheduler over this engine's offline-phase products — the one
    /// blessed way to wire the four pieces together (callers used to
    /// hand-assemble `Scheduler::new(engine.mapping(), ...)`; that dance
    /// now lives here and in [`crate::deploy`] only).
    pub fn scheduler(&self) -> Scheduler<'_> {
        Scheduler::new(&self.mapping, &self.replication, &self.model, self.dynamic_switch)
    }

    /// Simulate one batch.
    pub fn run_batch(&self, queries: &[Query], scratch: &mut Scratch) -> ExecStats {
        let sched = self.scheduler();
        match self.dataflow {
            Dataflow::Mac => sched.run_batch(queries, scratch),
            Dataflow::NmarsLookup => sched.run_batch_nmars(queries, scratch),
        }
    }

    /// Simulate a whole trace in `batch_size` batches, summing stats.
    pub fn run_trace(&self, trace: &Trace, batch_size: usize) -> ExecStats {
        let mut scratch = Scratch::default();
        let mut total = ExecStats::default();
        for batch in trace.batches(batch_size) {
            let s = self.run_batch(batch, &mut scratch);
            total.accumulate(&s);
        }
        total
    }

    /// Count crossbar activations for a trace without timing simulation
    /// (Fig. 9's metric; cheaper than a full run).
    pub fn count_activations(&self, trace: &Trace) -> u64 {
        match self.dataflow {
            // nMARS activates once per lookup.
            Dataflow::NmarsLookup => trace.total_lookups() as u64,
            Dataflow::Mac => {
                let mut scratch = Vec::new();
                trace
                    .queries
                    .iter()
                    .map(|q| self.mapping.groups_touched(&q.items, &mut scratch) as u64)
                    .sum()
            }
        }
    }
}

/// Linear-scaling ablation plan (Fig. 5 left pie): copies proportional to
/// frequency share, same area budget as the log plan.
fn plan_linear(freqs: &[u64], batch_size: usize, dup_ratio: f64) -> Replication {
    let num_groups = freqs.len();
    let budget = ((num_groups as f64) * dup_ratio).floor() as usize;
    let fmax = freqs.iter().copied().max().unwrap_or(0);
    let mut copies = vec![1u32; num_groups];
    if budget == 0 || fmax == 0 {
        return Replication {
            copies,
            total_crossbars: num_groups,
            batch_size,
        };
    }
    let desired: Vec<u32> = freqs
        .iter()
        .map(|&f| allocation::linear_copies(f, fmax, batch_size as u32))
        .collect();
    let mut order: Vec<usize> = (0..num_groups).collect();
    order.sort_by_key(|&g| std::cmp::Reverse(freqs[g]));
    // Head-first grant (deliberately NOT round-robin: the point of the
    // ablation is that linear scaling dumps the whole budget on the head).
    let mut remaining = budget;
    for &g in &order {
        if remaining == 0 {
            break;
        }
        let want = (desired[g] - 1).min(remaining as u32);
        copies[g] += want;
        remaining -= want as usize;
    }
    let total = copies.iter().map(|&c| c as usize).sum();
    Replication {
        copies,
        total_crossbars: total,
        batch_size,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate, DatasetSpec};

    fn setup() -> (CoGraph, Trace, Trace, Config) {
        let spec = DatasetSpec::by_name("software").unwrap().scaled(0.1);
        let (history, eval) = generate(&spec, 600, 200, 42);
        let graph = CoGraph::build(&history);
        let mut cfg = Config::paper_default();
        cfg.scheme.batch_size = 64;
        (graph, history, eval, cfg)
    }

    #[test]
    fn group_freqs_cache_matches_direct_count() {
        // The dedup contract: the frequencies the engine caches at
        // prepare (or lazily derives) are exactly what a fresh counting
        // pass over the same trace produces — downstream layers may use
        // either interchangeably.
        let (graph, history, _eval, cfg) = setup();
        for scheme in [Scheme::ReCross, Scheme::Naive] {
            let engine = Engine::prepare(scheme, &graph, &history, &cfg);
            let direct = crate::allocation::group_frequencies(engine.mapping(), &history);
            assert_eq!(
                engine.group_freqs(&history),
                direct.as_slice(),
                "cached freqs diverge from a direct count ({scheme:?})"
            );
        }
    }

    #[test]
    fn recross_beats_naive_on_activations() {
        let (graph, history, eval, cfg) = setup();
        let naive = Engine::prepare(Scheme::Naive, &graph, &history, &cfg);
        let recross = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
        let a_naive = naive.count_activations(&eval);
        let a_re = recross.count_activations(&eval);
        assert!(
            (a_naive as f64) / (a_re as f64) > 2.0,
            "activation reduction only {}x ({a_naive} vs {a_re})",
            a_naive as f64 / a_re as f64
        );
    }

    #[test]
    fn recross_beats_baselines_on_time_and_energy() {
        let (graph, history, eval, cfg) = setup();
        let naive = Engine::prepare(Scheme::Naive, &graph, &history, &cfg);
        let nmars = Engine::prepare(Scheme::Nmars, &graph, &history, &cfg);
        let recross = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
        let bs = cfg.scheme.batch_size;
        let s_naive = naive.run_trace(&eval, bs);
        let s_nmars = nmars.run_trace(&eval, bs);
        let s_re = recross.run_trace(&eval, bs);
        assert!(
            s_re.completion_ns < s_naive.completion_ns,
            "recross {} >= naive {}",
            s_re.completion_ns,
            s_naive.completion_ns
        );
        assert!(s_re.completion_ns < s_nmars.completion_ns);
        assert!(s_re.energy_pj < s_naive.energy_pj);
        assert!(s_re.energy_pj < s_nmars.energy_pj);
    }

    #[test]
    fn frequency_between_naive_and_recross() {
        let (graph, history, eval, cfg) = setup();
        let naive = Engine::prepare(Scheme::Naive, &graph, &history, &cfg);
        let freq = Engine::prepare(Scheme::Frequency, &graph, &history, &cfg);
        let recross = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
        let a_naive = naive.count_activations(&eval);
        let a_freq = freq.count_activations(&eval);
        let a_re = recross.count_activations(&eval);
        assert!(a_re < a_freq, "recross {a_re} !< freq {a_freq}");
        assert!(a_freq <= a_naive, "freq {a_freq} !<= naive {a_naive}");
    }

    #[test]
    fn duplication_helps_completion_time() {
        let (graph, history, eval, cfg) = setup();
        let full = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
        let nodup = Engine::prepare(Scheme::ReCrossNoDup, &graph, &history, &cfg);
        let bs = cfg.scheme.batch_size;
        let s_full = full.run_trace(&eval, bs);
        let s_nodup = nodup.run_trace(&eval, bs);
        assert!(full.physical_crossbars() > nodup.physical_crossbars());
        assert!(
            s_full.completion_ns <= s_nodup.completion_ns,
            "duplication did not help: {} vs {}",
            s_full.completion_ns,
            s_nodup.completion_ns
        );
        // same activations & lookups — duplication changes placement only
        assert_eq!(s_full.lookups, s_nodup.lookups);
    }

    #[test]
    fn dynamic_switch_saves_energy_only() {
        let (graph, history, eval, cfg) = setup();
        let on = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
        let off = Engine::prepare(Scheme::ReCrossNoSwitch, &graph, &history, &cfg);
        let bs = cfg.scheme.batch_size;
        let s_on = on.run_trace(&eval, bs);
        let s_off = off.run_trace(&eval, bs);
        assert_eq!(s_on.activations, s_off.activations);
        assert!(s_on.energy_pj < s_off.energy_pj);
        assert_eq!(s_off.read_activations, 0);
        assert!(s_on.read_activations > 0);
    }

    #[test]
    fn area_budget_respected_for_all_dup_schemes() {
        let (graph, history, _eval, mut cfg) = setup();
        for ratio in [0.0, 0.05, 0.1, 0.2] {
            cfg.scheme.dup_ratio = ratio;
            for scheme in [Scheme::ReCross, Scheme::ReCrossLinear] {
                let e = Engine::prepare(scheme, &graph, &history, &cfg);
                assert!(
                    e.replication().area_overhead() <= ratio + 1e-9,
                    "{:?} at ratio {ratio}: overhead {}",
                    scheme,
                    e.replication().area_overhead()
                );
            }
        }
    }

    #[test]
    fn log_plan_spreads_budget_wider_than_linear() {
        let (graph, history, _eval, cfg) = setup();
        let log_e = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
        let lin_e = Engine::prepare(Scheme::ReCrossLinear, &graph, &history, &cfg);
        // Same budget, but Eq. 1 duplicates more distinct groups (Fig. 5).
        assert!(
            log_e.replication().duplicated_groups() >= lin_e.replication().duplicated_groups(),
            "log {} vs linear {}",
            log_e.replication().duplicated_groups(),
            lin_e.replication().duplicated_groups()
        );
    }

    #[test]
    fn scheme_names_round_trip() {
        for s in Scheme::all() {
            assert_eq!(Scheme::by_name(s.name()), Some(s), "{s:?}");
        }
        assert_eq!(Scheme::by_name("recross"), Some(Scheme::ReCross));
        assert_eq!(Scheme::by_name("ReCross"), None, "names are exact");
        assert_eq!(Scheme::by_name(""), None);
        assert_eq!(Scheme::by_name("fractal"), None);
    }

    #[test]
    fn nmars_activations_equal_lookups() {
        let (graph, history, eval, cfg) = setup();
        let nmars = Engine::prepare(Scheme::Nmars, &graph, &history, &cfg);
        assert_eq!(nmars.count_activations(&eval), eval.total_lookups() as u64);
        let stats = nmars.run_trace(&eval, cfg.scheme.batch_size);
        assert_eq!(stats.activations, eval.total_lookups() as u64);
    }
}
