//! Text report for the `cluster` CLI mode: per-shard load/stall table,
//! cross-shard fan-out histogram, and the pool-level merged simulation.

use super::partition::ReplicaPlan;
use super::shard::ShardStatus;
use crate::metrics::Histogram;
use crate::sched::ExecStats;
use crate::util::{fmt_ns, fmt_pj};
use std::fmt::Write as _;
use std::time::Duration;

/// Render the cluster serving report.
///
/// * `statuses` — one row per shard (from `ClusterHandle::shard_status`).
/// * `fanout` — distribution of distinct-shards-per-query.
/// * `merged` — shard stats merged with [`ExecStats::merge_parallel`]
///   (completion = slowest shard; energy/counters = pool totals).
/// * `wall` / `queries` — what the front-end actually served.
pub fn render(
    statuses: &[ShardStatus],
    fanout: &Histogram,
    merged: &ExecStats,
    wall: Duration,
    queries: usize,
) -> String {
    let mut s = String::new();
    let epoch = statuses.iter().map(|st| st.epoch).max().unwrap_or(0);
    let _ = writeln!(
        s,
        "=== cluster report ({} shards, epoch {epoch}) ===",
        statuses.len()
    );

    let total_acts: u64 = statuses.iter().map(|st| st.sim.activations).sum();
    let _ = writeln!(
        s,
        "{:>6} {:>8} {:>10} {:>9} {:>12} {:>12} {:>8}",
        "shard", "groups", "sub-q", "lookups", "busy", "stall", "load%"
    );
    for st in statuses {
        let share = if total_acts == 0 {
            0.0
        } else {
            100.0 * st.sim.activations as f64 / total_acts as f64
        };
        let _ = writeln!(
            s,
            "{:>6} {:>8} {:>10} {:>9} {:>12} {:>12} {:>7.1}%",
            st.shard,
            st.owned_groups,
            st.sub_queries,
            st.lookups,
            fmt_ns(st.sim.completion_ns),
            fmt_ns(st.sim.stall_ns),
            share
        );
    }

    let _ = writeln!(s, "\ncross-shard fan-out per query (mean {:.2}):", fanout.mean());
    s.push_str(&fanout.render(8, 40));

    let _ = writeln!(
        s,
        "\npool (parallel merge): completion {}, energy {}, {} activations ({} read-mode)",
        fmt_ns(merged.completion_ns),
        fmt_pj(merged.energy_pj),
        merged.activations,
        merged.read_activations
    );
    let _ = writeln!(
        s,
        "front-end: {queries} queries in {wall:.2?} ({:.0} query/s)",
        queries as f64 / wall.as_secs_f64().max(1e-9)
    );
    s
}

/// One-paragraph summary of a replica placement: how many groups have
/// cross-shard copies and how flat the expected load is, per the
/// `freq/copies`-per-copy load model.
pub fn placement_summary(replicas: &ReplicaPlan, freqs: &[u64]) -> String {
    let loads = replicas.expected_loads(freqs);
    let max = loads.iter().cloned().fold(0.0f64, f64::max);
    let mean = loads.iter().sum::<f64>() / loads.len().max(1) as f64;
    format!(
        "placement: {} of {} groups replicated across shards; expected load max/mean = {:.2} ({})",
        replicas.cross_shard_groups(),
        replicas.num_groups(),
        if mean > 0.0 { max / mean } else { 0.0 },
        loads
            .iter()
            .map(|l| format!("{l:.0}"))
            .collect::<Vec<_>>()
            .join("/")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_sections() {
        let statuses = vec![
            ShardStatus {
                shard: 0,
                owned_groups: 10,
                epoch: 0,
                sub_queries: 100,
                lookups: 900,
                batches: 4,
                sim: ExecStats {
                    completion_ns: 5_000.0,
                    energy_pj: 2_000.0,
                    activations: 300,
                    queries: 100,
                    lookups: 900,
                    ..Default::default()
                },
            },
            ShardStatus {
                shard: 1,
                owned_groups: 8,
                epoch: 0,
                sub_queries: 80,
                lookups: 700,
                batches: 4,
                sim: ExecStats {
                    completion_ns: 4_000.0,
                    energy_pj: 1_500.0,
                    activations: 200,
                    queries: 80,
                    lookups: 700,
                    ..Default::default()
                },
            },
        ];
        let mut merged = ExecStats::default();
        for st in &statuses {
            merged.merge_parallel(&st.sim);
        }
        let mut fanout = Histogram::new();
        fanout.add_n(1, 60);
        fanout.add_n(2, 40);
        let text = render(&statuses, &fanout, &merged, Duration::from_millis(12), 100);
        assert!(text.contains("cluster report (2 shards, epoch 0)"), "{text}");
        assert!(text.contains("fan-out"), "{text}");
        assert!(text.contains("100 queries"), "{text}");
        // parallel merge: completion is the max (5 µs), not the sum
        assert!(text.contains("5.00 µs"), "{text}");
    }

    #[test]
    fn placement_summary_counts_replicated_groups() {
        use crate::allocation::Replication;
        use crate::cluster::ShardPlan;
        let plan = ShardPlan::from_assignment(vec![0, 1], 2);
        let rep = Replication::from_copies(vec![2, 1], 8);
        let freqs = vec![100, 10];
        let spread = ReplicaPlan::spread(&plan, &rep, &freqs);
        let text = placement_summary(&spread, &freqs);
        assert!(text.contains("1 of 2 groups replicated"), "{text}");
    }
}
