//! Group→shard partition plans.
//!
//! A [`ShardPlan`] fixes which shard owns each logical group (crossbar).
//! Two builders:
//!
//! * [`ShardPlan::by_hash`] — stateless consistent hashing of the group id
//!   over a [`HashRing`]; what a production pool would use when no access
//!   history is available (and the only choice that stays stable as the
//!   catalogue grows).
//! * [`ShardPlan::by_locality`] — the history-driven partitioner
//!   ([`Mapping::partition_across`]): correlated groups land on the same
//!   shard so the scatter-gather fan-out per query stays low.
//!
//! The plan also answers the monitoring questions the `cluster` report
//! mode prints: per-shard load, group counts, and the cross-shard fan-out
//! distribution of a trace.

use super::hashring::HashRing;
use crate::allocation::Replication;
use crate::grouping::Mapping;
use crate::metrics::Histogram;
use crate::util::rng::splitmix64;
use crate::workload::{EmbeddingId, Trace};

/// A complete group→shard assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// Number of shards in the pool.
    pub shards: usize,
    /// Owning shard of every group, indexed by group id.
    pub shard_of_group: Vec<u32>,
}

impl ShardPlan {
    /// Wrap an explicit assignment (validates shard ids).
    pub fn from_assignment(shard_of_group: Vec<u32>, shards: usize) -> Self {
        assert!(shards > 0, "need at least one shard");
        assert!(
            shard_of_group.iter().all(|&s| (s as usize) < shards),
            "assignment references a shard >= {shards}"
        );
        Self {
            shards,
            shard_of_group,
        }
    }

    /// Consistent-hash assignment of group ids over a ring.
    pub fn by_hash(num_groups: usize, ring: &HashRing) -> Self {
        let shard_of_group = (0..num_groups as u32).map(|g| ring.owner(g as u64)).collect();
        Self {
            shards: ring.num_shards() as usize,
            shard_of_group,
        }
    }

    /// Locality-preserving assignment from lookup history
    /// (see [`Mapping::partition_across`]).
    pub fn by_locality(mapping: &Mapping, history: &Trace, shards: usize, slack: f64) -> Self {
        Self::from_assignment(mapping.partition_across(history, shards, slack), shards)
    }

    /// Owning shard of a group.
    #[inline]
    pub fn shard_of(&self, group: u32) -> u32 {
        self.shard_of_group[group as usize]
    }

    /// Number of groups covered by the plan.
    pub fn num_groups(&self) -> usize {
        self.shard_of_group.len()
    }

    /// Groups owned by one shard, ascending.
    pub fn groups_of(&self, shard: u32) -> Vec<u32> {
        self.shard_of_group
            .iter()
            .enumerate()
            .filter(|(_, &s)| s == shard)
            .map(|(g, _)| g as u32)
            .collect()
    }

    /// Groups per shard.
    pub fn group_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.shards];
        for &s in &self.shard_of_group {
            counts[s as usize] += 1;
        }
        counts
    }

    /// Owning shard of one embedding lookup — the single routing rule
    /// every scatter path (live pool, simulator, fan-out metrics) shares.
    #[inline]
    pub fn shard_of_item(&self, mapping: &Mapping, e: EmbeddingId) -> u32 {
        self.shard_of(mapping.slot_of(e).group)
    }

    /// Split a query's items into per-shard sub-lists (length = `shards`;
    /// shards the query does not touch get an empty list). Item order is
    /// preserved within each shard.
    pub fn split_items(&self, mapping: &Mapping, items: &[EmbeddingId]) -> Vec<Vec<EmbeddingId>> {
        let mut split: Vec<Vec<EmbeddingId>> = vec![Vec::new(); self.shards];
        for &e in items {
            split[self.shard_of_item(mapping, e) as usize].push(e);
        }
        split
    }

    /// Distinct shards one query touches (its scatter fan-out).
    pub fn query_fanout(
        &self,
        mapping: &Mapping,
        items: &[EmbeddingId],
        scratch: &mut Vec<u32>,
    ) -> usize {
        scratch.clear();
        scratch.extend(items.iter().map(|&e| self.shard_of_item(mapping, e)));
        scratch.sort_unstable();
        scratch.dedup();
        scratch.len()
    }

    /// Fan-out distribution over a whole trace.
    pub fn fanout_histogram(&self, mapping: &Mapping, trace: &Trace) -> Histogram {
        let mut h = Histogram::new();
        let mut scratch = Vec::new();
        for q in &trace.queries {
            if !q.is_empty() {
                h.add(self.query_fanout(mapping, &q.items, &mut scratch) as u64);
            }
        }
        h
    }

    /// Per-shard activation load over a trace (one unit per query touching
    /// any group the shard owns — the quantity shard executors serialise
    /// on).
    pub fn shard_loads(&self, mapping: &Mapping, trace: &Trace) -> Vec<u64> {
        let freqs = crate::allocation::group_frequencies(mapping, trace);
        let mut loads = vec![0u64; self.shards];
        for (g, &f) in freqs.iter().enumerate() {
            loads[self.shard_of(g as u32) as usize] += f;
        }
        loads
    }
}

/// Cross-shard replica placement: which shards hold a copy of each group.
///
/// PR 1 pinned every Eq. 1 copy inside the group's owning shard, so
/// replication could parallelise *within* a shard but never relieve a hot
/// shard. This table lifts replication to the cluster level: a hot
/// group's copies are spread across several shards
/// ([`ReplicaPlan::spread`]), and the front-end routes each activation to
/// the least-loaded holder (power-of-two-choices,
/// [`ReplicaPlan::route_p2c`]). [`ReplicaPlan::pinned`] reproduces the
/// PR 1 ownership model for comparison.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicaPlan {
    /// Number of shards in the pool.
    pub shards: usize,
    /// Shard of every copy of every group; `holders[g][0]` is the owner.
    /// A shard may appear twice when it hosts two copies.
    pub holders: Vec<Vec<u32>>,
    /// Distinct holder shards per group, ascending (the routing
    /// candidates).
    distinct: Vec<Vec<u32>>,
}

impl ReplicaPlan {
    fn finish(shards: usize, holders: Vec<Vec<u32>>) -> Self {
        let distinct = holders
            .iter()
            .map(|hs| {
                let mut d = hs.clone();
                d.sort_unstable();
                d.dedup();
                d
            })
            .collect();
        Self {
            shards,
            holders,
            distinct,
        }
    }

    /// The PR 1 ownership model: every copy of a group lives on its
    /// owning shard.
    pub fn pinned(plan: &ShardPlan, replication: &Replication) -> Self {
        assert_eq!(
            plan.num_groups(),
            replication.copies.len(),
            "replication plan does not match shard plan"
        );
        let holders = (0..plan.num_groups() as u32)
            .map(|g| vec![plan.shard_of(g); replication.copies_of(g) as usize])
            .collect();
        Self::finish(plan.shards, holders)
    }

    /// Spread each group's extra copies across shards, greedily assigning
    /// every copy to the least-loaded shard (preferring shards that do
    /// not yet hold the group). The load model matches the locality
    /// partitioner's: a group contributes `freq / copies` per copy, so
    /// the pass co-optimises with the partition's load cap instead of
    /// fighting it. Fully deterministic.
    pub fn spread(plan: &ShardPlan, replication: &Replication, freqs: &[u64]) -> Self {
        Self::spread_scoped(plan, replication, freqs, None)
    }

    /// Re-place copies for the **dirty** groups only; clean groups keep
    /// their holder lists from `prev` verbatim (their tiles stay where
    /// they are).
    ///
    /// Caller contract: `plan` keeps clean groups' owners and
    /// `replication` holds clean groups' copy counts from the previous
    /// round (the delta pipeline guarantees both); every clean group must
    /// exist in `prev`. With every group dirty this is bit-identical to
    /// [`ReplicaPlan::spread`] — same code path.
    pub fn spread_subset(
        plan: &ShardPlan,
        replication: &Replication,
        freqs: &[u64],
        prev: &ReplicaPlan,
        dirty: &[bool],
    ) -> Self {
        Self::spread_scoped(plan, replication, freqs, Some((prev, dirty)))
    }

    fn spread_scoped(
        plan: &ShardPlan,
        replication: &Replication,
        freqs: &[u64],
        scope: Option<(&ReplicaPlan, &[bool])>,
    ) -> Self {
        let n = plan.num_groups();
        assert_eq!(replication.copies.len(), n, "replication/plan mismatch");
        assert_eq!(freqs.len(), n, "frequency/plan mismatch");
        if let Some((_, dirty)) = scope {
            assert_eq!(dirty.len(), n, "dirty flags/plan mismatch");
        }
        let shards = plan.shards;
        let is_dirty = |g: usize| scope.map_or(true, |(_, d)| d[g]);
        let mut holders: Vec<Vec<u32>> = (0..n)
            .map(|g| match scope {
                Some((prev, dirty)) if !dirty[g] => prev.holders[g].clone(),
                _ => vec![plan.shard_of(g as u32)],
            })
            .collect();
        // Each shard starts with the owner copy of every dirty group it
        // owns, plus every already-placed copy of the clean groups.
        let mut load = vec![0.0f64; shards];
        for g in 0..n {
            if is_dirty(g) {
                load[plan.shard_of(g as u32) as usize] +=
                    freqs[g] as f64 / replication.copies[g].max(1) as f64;
            } else {
                let share = freqs[g] as f64 / holders[g].len().max(1) as f64;
                for &s in &holders[g] {
                    load[s as usize] += share;
                }
            }
        }
        // Hottest replicated groups place first (they move the most load).
        let mut order: Vec<usize> = (0..n)
            .filter(|&g| is_dirty(g) && replication.copies[g] > 1)
            .collect();
        order.sort_by_key(|&g| (std::cmp::Reverse(freqs[g]), g));
        for &g in &order {
            let share = freqs[g] as f64 / replication.copies[g] as f64;
            for _ in 1..replication.copies[g] {
                let mut best = 0usize;
                let mut best_key = (true, f64::INFINITY);
                for (s, &l) in load.iter().enumerate() {
                    let holds = holders[g].contains(&(s as u32));
                    // Prefer (not-yet-holding, lighter, lower id).
                    let better = match (holds, best_key.0) {
                        (false, true) => true,
                        (true, false) => false,
                        _ => l < best_key.1,
                    };
                    if better {
                        best = s;
                        best_key = (holds, l);
                    }
                }
                holders[g].push(best as u32);
                load[best] += share;
            }
        }
        Self::finish(shards, holders)
    }

    /// Number of groups covered.
    pub fn num_groups(&self) -> usize {
        self.holders.len()
    }

    /// Distinct shards holding a copy of `g`, ascending. Never empty.
    #[inline]
    pub fn distinct_holders(&self, g: u32) -> &[u32] {
        &self.distinct[g as usize]
    }

    /// Every group a shard hosts (owned or replica), ascending.
    pub fn groups_hosted_by(&self, shard: u32) -> Vec<u32> {
        (0..self.num_groups() as u32)
            .filter(|&g| self.distinct[g as usize].contains(&shard))
            .collect()
    }

    /// Groups whose copies span more than one shard.
    pub fn cross_shard_groups(&self) -> usize {
        self.distinct.iter().filter(|d| d.len() > 1).count()
    }

    /// A shard's local replica counts: how many copies of each group it
    /// hosts, clamped to >= 1 so the scheduler's replica table stays
    /// total (groups the shard does not host are never routed to it, so
    /// their phantom copy sees no traffic).
    pub fn local_replication(&self, shard: u32, batch_size: usize) -> Replication {
        let copies = self
            .holders
            .iter()
            .map(|hs| (hs.iter().filter(|&&s| s == shard).count() as u32).max(1))
            .collect();
        Replication::from_copies(copies, batch_size)
    }

    /// Expected per-shard load under this placement: each copy of a group
    /// carries `freq / copies` activations.
    pub fn expected_loads(&self, freqs: &[u64]) -> Vec<f64> {
        assert_eq!(freqs.len(), self.num_groups());
        let mut loads = vec![0.0f64; self.shards];
        for (g, hs) in self.holders.iter().enumerate() {
            let share = freqs[g] as f64 / hs.len() as f64;
            for &s in hs {
                loads[s as usize] += share;
            }
        }
        loads
    }

    /// Power-of-two-choices routing for one activation of group `g`:
    /// sample two distinct holder shards from `salt` (deterministic) and
    /// send the activation to the one with the lower current load (`loads`
    /// is a callback so the live pool can read atomic in-flight counters
    /// while the simulator reads a plain vector). Ties break toward the
    /// lower shard id.
    pub fn route_p2c<F: Fn(u32) -> u64>(&self, g: u32, salt: u64, loads: F) -> u32 {
        let hs = self.distinct_holders(g);
        if hs.len() == 1 {
            return hs[0];
        }
        let mut st = salt ^ ((g as u64) << 32) ^ 0x5EED_0F_2C;
        let i = (splitmix64(&mut st) % hs.len() as u64) as usize;
        let mut j = (splitmix64(&mut st) % (hs.len() - 1) as u64) as usize;
        if j >= i {
            j += 1;
        }
        let (a, b) = (hs[i], hs[j]);
        let (la, lb) = (loads(a), loads(b));
        if la < lb || (la == lb && a < b) {
            a
        } else {
            b
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Query;

    fn mapping_4x2() -> Mapping {
        Mapping::from_groups(
            vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]],
            2,
            8,
        )
    }

    #[test]
    fn hash_plan_covers_all_groups() {
        let ring = HashRing::new(4, 64);
        let plan = ShardPlan::by_hash(100, &ring);
        assert_eq!(plan.num_groups(), 100);
        assert!(plan.shard_of_group.iter().all(|&s| s < 4));
        // groups_of partitions exactly
        let total: usize = (0..4).map(|s| plan.groups_of(s).len()).sum();
        assert_eq!(total, 100);
        assert_eq!(plan.group_counts().iter().sum::<usize>(), 100);
    }

    #[test]
    fn hash_plan_deterministic() {
        let ring = HashRing::new(8, 64);
        assert_eq!(ShardPlan::by_hash(64, &ring), ShardPlan::by_hash(64, &ring));
    }

    #[test]
    fn fanout_counts_distinct_shards() {
        let m = mapping_4x2();
        // groups 0,1 -> shard 0; groups 2,3 -> shard 1
        let plan = ShardPlan::from_assignment(vec![0, 0, 1, 1], 2);
        let mut scratch = Vec::new();
        assert_eq!(plan.query_fanout(&m, &[0, 2], &mut scratch), 1); // g0,g1 both shard 0
        assert_eq!(plan.query_fanout(&m, &[0, 4], &mut scratch), 2); // g0 + g2
        assert_eq!(plan.query_fanout(&m, &[], &mut scratch), 0);
    }

    #[test]
    fn shard_loads_sum_to_group_frequencies() {
        let m = mapping_4x2();
        let plan = ShardPlan::from_assignment(vec![0, 1, 0, 1], 2);
        let t = Trace {
            num_embeddings: 8,
            queries: vec![Query::new(vec![0, 2, 4]), Query::new(vec![6])],
        };
        let loads = plan.shard_loads(&m, &t);
        // q0 touches g0 (s0), g1 (s1), g2 (s0); q1 touches g3 (s1).
        assert_eq!(loads, vec![2, 2]);
        let h = plan.fanout_histogram(&m, &t);
        assert_eq!(h.total(), 2);
        assert_eq!(h.count(2), 1); // q0 fans out to both shards
        assert_eq!(h.count(1), 1); // q1 stays on shard 1
    }

    #[test]
    #[should_panic(expected = "references a shard")]
    fn out_of_range_assignment_rejected() {
        ShardPlan::from_assignment(vec![0, 5], 2);
    }

    #[test]
    fn pinned_placement_keeps_copies_on_owner() {
        let plan = ShardPlan::from_assignment(vec![0, 1, 0, 1], 2);
        let rep = Replication::from_copies(vec![3, 1, 2, 1], 64);
        let rp = ReplicaPlan::pinned(&plan, &rep);
        assert_eq!(rp.holders[0], vec![0, 0, 0]);
        assert_eq!(rp.holders[1], vec![1]);
        assert_eq!(rp.distinct_holders(0), &[0]);
        assert_eq!(rp.cross_shard_groups(), 0);
        // Local replication mirrors the global plan on the owner.
        assert_eq!(rp.local_replication(0, 64).copies, vec![3, 1, 2, 1]);
        // Non-hosting shard gets phantom single copies.
        assert_eq!(rp.local_replication(1, 64).copies, vec![1, 1, 1, 1]);
    }

    #[test]
    fn spread_placement_crosses_shards_and_lowers_max_load() {
        // One scorching group owned by shard 0 with 4 copies; spreading
        // must hand copies to the other shards and flatten expected load.
        let plan = ShardPlan::from_assignment(vec![0, 1, 2, 3], 4);
        let rep = Replication::from_copies(vec![4, 1, 1, 1], 64);
        let freqs = vec![1000u64, 10, 10, 10];
        let pinned = ReplicaPlan::pinned(&plan, &rep);
        let spread = ReplicaPlan::spread(&plan, &rep, &freqs);
        assert_eq!(spread.holders[0][0], 0, "owner keeps the first copy");
        assert_eq!(spread.distinct_holders(0).len(), 4, "copies spread out");
        assert!(spread.cross_shard_groups() >= 1);
        let max = |loads: &[f64]| loads.iter().cloned().fold(0.0f64, f64::max);
        let lp = max(&pinned.expected_loads(&freqs));
        let ls = max(&spread.expected_loads(&freqs));
        assert!(ls < lp, "spread max load {ls} !< pinned {lp}");
        // Every shard hosts the hot group exactly once here.
        for s in 0..4 {
            assert_eq!(spread.local_replication(s, 64).copies[0], 1);
        }
    }

    #[test]
    fn spread_is_deterministic_and_total() {
        let plan = ShardPlan::from_assignment(vec![0, 1, 0, 1, 0, 1], 2);
        let rep = Replication::from_copies(vec![2, 2, 1, 1, 3, 1], 32);
        let freqs = vec![500, 400, 9, 8, 300, 7];
        let a = ReplicaPlan::spread(&plan, &rep, &freqs);
        let b = ReplicaPlan::spread(&plan, &rep, &freqs);
        assert_eq!(a, b);
        for g in 0..6u32 {
            assert_eq!(a.holders[g as usize].len(), rep.copies_of(g) as usize);
            assert!(a.distinct_holders(g).iter().all(|&s| (s as usize) < 2));
            assert_eq!(a.holders[g as usize][0], plan.shard_of(g));
        }
        // hosted sets cover every group at least once
        let hosted: Vec<_> = (0..2).map(|s| a.groups_hosted_by(s)).collect();
        for g in 0..6u32 {
            assert!(hosted.iter().any(|h| h.contains(&g)));
        }
    }

    #[test]
    fn spread_subset_all_dirty_matches_spread() {
        let plan = ShardPlan::from_assignment(vec![0, 1, 0, 1, 0, 1], 2);
        let rep = Replication::from_copies(vec![2, 2, 1, 1, 3, 1], 32);
        let freqs = vec![500, 400, 9, 8, 300, 7];
        let prev = ReplicaPlan::pinned(&plan, &rep); // content irrelevant at full scope
        let full = ReplicaPlan::spread(&plan, &rep, &freqs);
        let sub = ReplicaPlan::spread_subset(&plan, &rep, &freqs, &prev, &[true; 6]);
        assert_eq!(full, sub);
    }

    #[test]
    fn spread_subset_keeps_clean_holders_verbatim() {
        let plan = ShardPlan::from_assignment(vec![0, 1, 2, 3], 4);
        let rep = Replication::from_copies(vec![4, 2, 1, 1], 64);
        let freqs = vec![1000u64, 500, 10, 10];
        let prev = ReplicaPlan::spread(&plan, &rep, &freqs);
        // Only group 1 dirty, with a hotter frequency.
        let new_freqs = vec![1000u64, 2000, 10, 10];
        let dirty = [false, true, false, false];
        let sub = ReplicaPlan::spread_subset(&plan, &rep, &new_freqs, &prev, &dirty);
        for g in [0usize, 2, 3] {
            assert_eq!(sub.holders[g], prev.holders[g], "clean group {g} moved");
        }
        assert_eq!(sub.holders[1].len(), 2);
        assert_eq!(sub.holders[1][0], 1, "owner keeps the first copy");
    }

    #[test]
    fn p2c_routes_to_holders_and_prefers_lighter() {
        let plan = ShardPlan::from_assignment(vec![0, 1], 3);
        let rep = Replication::from_copies(vec![3, 1], 16);
        let freqs = vec![100, 1];
        let rp = ReplicaPlan::spread(&plan, &rep, &freqs);
        let holders = rp.distinct_holders(0).to_vec();
        assert!(holders.len() >= 2);
        // All routes land on holders; with one shard overloaded the other
        // candidates win whenever they are sampled.
        let mut loads = vec![0u64; 3];
        loads[holders[0] as usize] = 1_000_000;
        for salt in 0..200u64 {
            let s = rp.route_p2c(0, salt, |s| loads[s as usize]);
            assert!(holders.contains(&s), "routed to non-holder {s}");
        }
        let hits_heavy = (0..200u64)
            .filter(|&salt| rp.route_p2c(0, salt, |s| loads[s as usize]) == holders[0])
            .count();
        // p2c picks the heavy shard only when both samples land on it.
        assert!(hits_heavy < 60, "p2c kept hammering the loaded shard");
        // Single-holder groups route unconditionally to the owner.
        assert_eq!(rp.route_p2c(1, 7, |_| 0), 1);
    }
}
