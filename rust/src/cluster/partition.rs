//! Group→shard partition plans.
//!
//! A [`ShardPlan`] fixes which shard owns each logical group (crossbar).
//! Two builders:
//!
//! * [`ShardPlan::by_hash`] — stateless consistent hashing of the group id
//!   over a [`HashRing`]; what a production pool would use when no access
//!   history is available (and the only choice that stays stable as the
//!   catalogue grows).
//! * [`ShardPlan::by_locality`] — the history-driven partitioner
//!   ([`Mapping::partition_across`]): correlated groups land on the same
//!   shard so the scatter-gather fan-out per query stays low.
//!
//! The plan also answers the monitoring questions the `cluster` report
//! mode prints: per-shard load, group counts, and the cross-shard fan-out
//! distribution of a trace.

use super::hashring::HashRing;
use crate::grouping::Mapping;
use crate::metrics::Histogram;
use crate::workload::{EmbeddingId, Trace};

/// A complete group→shard assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// Number of shards in the pool.
    pub shards: usize,
    /// Owning shard of every group, indexed by group id.
    pub shard_of_group: Vec<u32>,
}

impl ShardPlan {
    /// Wrap an explicit assignment (validates shard ids).
    pub fn from_assignment(shard_of_group: Vec<u32>, shards: usize) -> Self {
        assert!(shards > 0, "need at least one shard");
        assert!(
            shard_of_group.iter().all(|&s| (s as usize) < shards),
            "assignment references a shard >= {shards}"
        );
        Self {
            shards,
            shard_of_group,
        }
    }

    /// Consistent-hash assignment of group ids over a ring.
    pub fn by_hash(num_groups: usize, ring: &HashRing) -> Self {
        let shard_of_group = (0..num_groups as u32).map(|g| ring.owner(g as u64)).collect();
        Self {
            shards: ring.num_shards() as usize,
            shard_of_group,
        }
    }

    /// Locality-preserving assignment from lookup history
    /// (see [`Mapping::partition_across`]).
    pub fn by_locality(mapping: &Mapping, history: &Trace, shards: usize, slack: f64) -> Self {
        Self::from_assignment(mapping.partition_across(history, shards, slack), shards)
    }

    /// Owning shard of a group.
    #[inline]
    pub fn shard_of(&self, group: u32) -> u32 {
        self.shard_of_group[group as usize]
    }

    /// Number of groups covered by the plan.
    pub fn num_groups(&self) -> usize {
        self.shard_of_group.len()
    }

    /// Groups owned by one shard, ascending.
    pub fn groups_of(&self, shard: u32) -> Vec<u32> {
        self.shard_of_group
            .iter()
            .enumerate()
            .filter(|(_, &s)| s == shard)
            .map(|(g, _)| g as u32)
            .collect()
    }

    /// Groups per shard.
    pub fn group_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.shards];
        for &s in &self.shard_of_group {
            counts[s as usize] += 1;
        }
        counts
    }

    /// Owning shard of one embedding lookup — the single routing rule
    /// every scatter path (live pool, simulator, fan-out metrics) shares.
    #[inline]
    pub fn shard_of_item(&self, mapping: &Mapping, e: EmbeddingId) -> u32 {
        self.shard_of(mapping.slot_of(e).group)
    }

    /// Split a query's items into per-shard sub-lists (length = `shards`;
    /// shards the query does not touch get an empty list). Item order is
    /// preserved within each shard.
    pub fn split_items(&self, mapping: &Mapping, items: &[EmbeddingId]) -> Vec<Vec<EmbeddingId>> {
        let mut split: Vec<Vec<EmbeddingId>> = vec![Vec::new(); self.shards];
        for &e in items {
            split[self.shard_of_item(mapping, e) as usize].push(e);
        }
        split
    }

    /// Distinct shards one query touches (its scatter fan-out).
    pub fn query_fanout(
        &self,
        mapping: &Mapping,
        items: &[EmbeddingId],
        scratch: &mut Vec<u32>,
    ) -> usize {
        scratch.clear();
        scratch.extend(items.iter().map(|&e| self.shard_of_item(mapping, e)));
        scratch.sort_unstable();
        scratch.dedup();
        scratch.len()
    }

    /// Fan-out distribution over a whole trace.
    pub fn fanout_histogram(&self, mapping: &Mapping, trace: &Trace) -> Histogram {
        let mut h = Histogram::new();
        let mut scratch = Vec::new();
        for q in &trace.queries {
            if !q.is_empty() {
                h.add(self.query_fanout(mapping, &q.items, &mut scratch) as u64);
            }
        }
        h
    }

    /// Per-shard activation load over a trace (one unit per query touching
    /// any group the shard owns — the quantity shard executors serialise
    /// on).
    pub fn shard_loads(&self, mapping: &Mapping, trace: &Trace) -> Vec<u64> {
        let freqs = crate::allocation::group_frequencies(mapping, trace);
        let mut loads = vec![0u64; self.shards];
        for (g, &f) in freqs.iter().enumerate() {
            loads[self.shard_of(g as u32) as usize] += f;
        }
        loads
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Query;

    fn mapping_4x2() -> Mapping {
        Mapping::from_groups(
            vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]],
            2,
            8,
        )
    }

    #[test]
    fn hash_plan_covers_all_groups() {
        let ring = HashRing::new(4, 64);
        let plan = ShardPlan::by_hash(100, &ring);
        assert_eq!(plan.num_groups(), 100);
        assert!(plan.shard_of_group.iter().all(|&s| s < 4));
        // groups_of partitions exactly
        let total: usize = (0..4).map(|s| plan.groups_of(s).len()).sum();
        assert_eq!(total, 100);
        assert_eq!(plan.group_counts().iter().sum::<usize>(), 100);
    }

    #[test]
    fn hash_plan_deterministic() {
        let ring = HashRing::new(8, 64);
        assert_eq!(ShardPlan::by_hash(64, &ring), ShardPlan::by_hash(64, &ring));
    }

    #[test]
    fn fanout_counts_distinct_shards() {
        let m = mapping_4x2();
        // groups 0,1 -> shard 0; groups 2,3 -> shard 1
        let plan = ShardPlan::from_assignment(vec![0, 0, 1, 1], 2);
        let mut scratch = Vec::new();
        assert_eq!(plan.query_fanout(&m, &[0, 2], &mut scratch), 1); // g0,g1 both shard 0
        assert_eq!(plan.query_fanout(&m, &[0, 4], &mut scratch), 2); // g0 + g2
        assert_eq!(plan.query_fanout(&m, &[], &mut scratch), 0);
    }

    #[test]
    fn shard_loads_sum_to_group_frequencies() {
        let m = mapping_4x2();
        let plan = ShardPlan::from_assignment(vec![0, 1, 0, 1], 2);
        let t = Trace {
            num_embeddings: 8,
            queries: vec![Query::new(vec![0, 2, 4]), Query::new(vec![6])],
        };
        let loads = plan.shard_loads(&m, &t);
        // q0 touches g0 (s0), g1 (s1), g2 (s0); q1 touches g3 (s1).
        assert_eq!(loads, vec![2, 2]);
        let h = plan.fanout_histogram(&m, &t);
        assert_eq!(h.total(), 2);
        assert_eq!(h.count(2), 1); // q0 fans out to both shards
        assert_eq!(h.count(1), 1); // q1 stays on shard 1
    }

    #[test]
    #[should_panic(expected = "references a shard")]
    fn out_of_range_assignment_rejected() {
        ShardPlan::from_assignment(vec![0, 5], 2);
    }
}
