//! Cluster front-end: spawn the shard pool, scatter queries, gather and
//! merge partial reductions.
//!
//! [`Cluster::spawn_from_parts`] starts one executor thread per shard
//! (each with its own dynamic batcher and its own slice of the embedding
//! table). A [`ClusterHandle`] is the cloneable client: it splits each
//! query's lookups by *holding* shard, dispatches the per-shard
//! sub-queries in parallel, and sums the returned partial vectors — the
//! reduction is linear, so the scatter-gather merge is exact. Partials
//! are always merged in ascending shard order, keeping the float
//! summation order deterministic for a fixed split.
//!
//! Two routing policies ([`RoutePolicy`]):
//!
//! * `Pinned` — every group's traffic goes to its owning shard (the PR 1
//!   model; replication parallelises within the shard only).
//! * `PowerOfTwo` — a group replicated across shards
//!   ([`super::ReplicaPlan::spread`]) is routed per activation to the
//!   less-loaded of two sampled holders, judged by per-shard in-flight
//!   sub-query counters. Whatever the route, each (query, group) pair is
//!   served by exactly one shard, so the merge stays exact.
//!
//! The routing state is an epoch-versioned [`RouteTable`] behind an
//! `RwLock<Arc<..>>`: [`Cluster::rebalance`] recomputes frequencies from
//! recent traffic, builds a new placement, installs each shard's new tile
//! set ([`super::shard::ShardMsg::Install`]), waits for every ack, and
//! only then swaps the table — an atomic epoch flip at a batch boundary.
//! A [`DriftMonitor`] wired into the scatter path tells the driver *when*
//! that remap is due.

use super::partition::{ReplicaPlan, ShardPlan};
use super::shard::{
    partition_store_with_replicas, spawn_shard, PoolShared, ShardExecutor, ShardMsg, ShardStatus,
    ShardStore,
};
use crate::allocation::{self, Replication};
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::drift::DriftMonitor;
use crate::coordinator::EmbeddingStore;
use crate::graph::DeltaParams;
use crate::grouping::Mapping;
use crate::obs::{names, Obs};
use crate::sched::ExecStats;
use crate::workload::{EmbeddingId, Query, Trace};
use crate::Result;
use anyhow::anyhow;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// How groups are assigned to shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionPolicy {
    /// Consistent hashing of the group id (stateless, history-free).
    Hash,
    /// Co-occurrence-locality-preserving balanced partition (needs the
    /// offline history trace).
    Locality,
}

/// How each activation picks among a group's replica-holding shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Always the owning shard (ownership-pinned replication).
    Pinned,
    /// Power-of-two-choices over per-shard in-flight counters.
    PowerOfTwo,
}

/// Deployment-time placement/routing mode of a sharded pool — the typed
/// replacement for the old `(replica_routing, rebalance)` bool pair, so
/// an impossible-looking combination can't be half-configured and every
/// `match` is forced to consider all four shapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ShardingMode {
    /// Every Eq. 1 copy lives on its group's owning shard; activations
    /// route to the owner. The static PR 1 model.
    #[default]
    Pinned,
    /// Hot-group replicas spread across shards; each activation routes by
    /// power-of-two-choices over in-flight counters.
    ReplicaRouted,
    /// Ownership-pinned routing with the drift monitor armed: stale
    /// placements trigger epoch-versioned remaps online.
    Rebalancing,
    /// Spread replicas + p2c routing *and* online rebalancing.
    RebalancingRouted,
}

impl ShardingMode {
    /// Lift the legacy CLI flag pair into the typed mode.
    pub fn from_flags(replica_routing: bool, rebalance: bool) -> Self {
        match (replica_routing, rebalance) {
            (false, false) => Self::Pinned,
            (true, false) => Self::ReplicaRouted,
            (false, true) => Self::Rebalancing,
            (true, true) => Self::RebalancingRouted,
        }
    }

    /// Does this mode spread replicas and route by power-of-two-choices?
    pub fn replica_routing(self) -> bool {
        matches!(self, Self::ReplicaRouted | Self::RebalancingRouted)
    }

    /// Does this mode arm the drift monitor for online remaps?
    pub fn rebalance(self) -> bool {
        matches!(self, Self::Rebalancing | Self::RebalancingRouted)
    }

    /// The per-activation routing rule this mode implies.
    pub fn route_policy(self) -> RoutePolicy {
        if self.replica_routing() {
            RoutePolicy::PowerOfTwo
        } else {
            RoutePolicy::Pinned
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::Pinned => "pinned",
            Self::ReplicaRouted => "replica-routed",
            Self::Rebalancing => "rebalancing",
            Self::RebalancingRouted => "rebalancing-routed",
        }
    }
}

/// Cluster assembly knobs.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Shard executors to spawn.
    pub shards: usize,
    /// Virtual nodes per shard on the hash ring (Hash policy).
    pub vnodes: u32,
    /// Group→shard assignment policy.
    pub policy: PartitionPolicy,
    /// Per-shard dynamic-batcher policy.
    pub batch: BatchPolicy,
    /// Load-balance slack for the locality partitioner.
    pub slack: f64,
    /// Placement/routing mode (pinned, replica-routed, rebalancing).
    pub mode: ShardingMode,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            shards: 4,
            vnodes: 128,
            policy: PartitionPolicy::Locality,
            batch: BatchPolicy::default(),
            slack: 0.10,
            mode: ShardingMode::Pinned,
        }
    }
}

/// One merged scatter-gather result.
#[derive(Debug, Clone)]
pub struct ClusterResponse {
    /// Position of the query in the submitted batch.
    pub id: u64,
    /// The merged reduced embedding, length `D`.
    pub reduced: Vec<f32>,
    /// Distinct shards this query touched.
    pub fanout: usize,
    /// Crossbar activations summed across shards.
    pub activations: u64,
    /// Wall clock from batch submission to this query's merge completing.
    /// Like the single-pool path, submission time is shared by the whole
    /// `reduce_many` batch, so later queries report larger values (queue +
    /// execute), and the in-order gather can add head-of-line wait on top
    /// — this is batch-position latency, not isolated service time.
    pub latency: Duration,
}

/// The epoch-versioned routing state the scatter path reads. Swapped
/// atomically (as one `Arc`) by [`Cluster::rebalance`].
#[derive(Debug, Clone)]
pub struct RouteTable {
    /// Placement epoch; bumped by every rebalance.
    pub epoch: u64,
    /// Group ownership (primary copy per group).
    pub plan: Arc<ShardPlan>,
    /// Cross-shard replica placement.
    pub replicas: Arc<ReplicaPlan>,
    /// Per-activation routing rule.
    pub policy: RoutePolicy,
}

impl RouteTable {
    /// Split a query's items into per-shard sub-lists under this table.
    /// `loads` reports a shard's current load for power-of-two-choices
    /// (atomic in-flight counters on the live path, a plain vector in the
    /// simulator); `qsalt` decorrelates the two-choice sampling across
    /// queries while keeping it deterministic.
    pub fn split_query<F: Fn(u32) -> u64>(
        &self,
        mapping: &Mapping,
        items: &[EmbeddingId],
        qsalt: u64,
        loads: F,
    ) -> Vec<Vec<EmbeddingId>> {
        match self.policy {
            // The one owner-routing rule shared with the fan-out metrics.
            RoutePolicy::Pinned => self.plan.split_items(mapping, items),
            RoutePolicy::PowerOfTwo => {
                let mut split: Vec<Vec<EmbeddingId>> = vec![Vec::new(); self.plan.shards];
                // A query's lookups of one group are one activation —
                // they must travel together; memoize the choice per group
                // (queries touch few distinct groups, linear scan wins).
                let mut chosen: Vec<(u32, u32)> = Vec::new();
                let salt = self
                    .epoch
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    .wrapping_add(qsalt);
                for &e in items {
                    let g = mapping.slot_of(e).group;
                    let s = match chosen.iter().find(|&&(cg, _)| cg == g) {
                        Some(&(_, s)) => s,
                        None => {
                            let s = self.replicas.route_p2c(g, salt, &loads);
                            chosen.push((g, s));
                            s
                        }
                    };
                    split[s as usize].push(e);
                }
                split
            }
        }
    }
}

/// Assembly options for the routed pool (see [`Cluster::spawn_routed`]).
#[derive(Debug)]
pub struct RouteOptions {
    /// Per-activation routing rule.
    pub policy: RoutePolicy,
    /// Partition policy a rebalance re-runs (`Hash` keeps the owners).
    pub partition: PartitionPolicy,
    /// Locality-partitioner slack for rebalances.
    pub slack: f64,
    /// Replication area budget a rebalance re-plans Eq. 1 under; `None`
    /// derives it from the initial plan's realized overhead.
    pub dup_ratio: Option<f64>,
    /// Armed drift monitor (None = no online staleness tracking).
    pub drift: Option<DriftMonitor>,
    /// Per-group frequencies the *initial* plan was derived from. Seeds
    /// the delta baseline so the first
    /// [`Cluster::rebalance_incremental`] can diff against it instead of
    /// falling back to full scope.
    pub baseline_freqs: Option<Vec<u64>>,
}

impl Default for RouteOptions {
    fn default() -> Self {
        Self {
            policy: RoutePolicy::Pinned,
            partition: PartitionPolicy::Locality,
            slack: 0.10,
            dup_ratio: None,
            drift: None,
            baseline_freqs: None,
        }
    }
}

/// Rebalance settings retained by a running cluster.
#[derive(Debug, Clone)]
struct RebalanceSettings {
    partition: PartitionPolicy,
    slack: f64,
    dup_ratio: f64,
}

/// What the last installed plan was derived from — the diff base for
/// [`Cluster::rebalance_incremental`]'s per-group dirty detection.
#[derive(Debug, Clone)]
struct PlanBaseline {
    /// Per-group activation frequencies behind the installed plan.
    freqs: Vec<u64>,
    /// The installed global replication plan (clean groups hold these
    /// copy counts across delta re-plans).
    replication: Replication,
}

/// What one rebalance did — the placement-side work counters.
#[derive(Debug, Clone)]
pub struct RebalanceReport {
    /// The epoch the swap installed.
    pub epoch: u64,
    /// True when the rebalance ran at full scope (no usable baseline, or
    /// invoked via [`Cluster::rebalance`]).
    pub full: bool,
    /// Groups covered by the plan.
    pub groups_total: usize,
    /// Groups whose frequency drifted past the thresholds (re-placed and
    /// re-replicated).
    pub groups_changed: usize,
    /// Shards that received a tile install this round.
    pub shards_installed: usize,
    /// Tiles (hosted groups) shipped to those shards.
    pub tiles_installed: usize,
    /// Tiles hosted across the whole cluster after the swap.
    pub tiles_total: usize,
}

/// A running sharded pool: executors + epoch-versioned routing state.
pub struct Cluster {
    shards: Vec<ShardExecutor>,
    routes: Arc<RwLock<Arc<RouteTable>>>,
    shared: Arc<PoolShared>,
    /// In-flight sub-queries per shard (the p2c load signal).
    inflight: Arc<Vec<AtomicU64>>,
    drift: Option<Arc<Mutex<DriftMonitor>>>,
    /// Full table retained for rebuilding shard tile sets on rebalance —
    /// only kept when the drift monitor is armed, so the common static
    /// pool does not hold a second copy of the whole table.
    full: Option<Arc<EmbeddingStore>>,
    /// Frequencies + replication behind the installed plan (diff base
    /// for incremental rebalances); `None` until seeded or first swap.
    last_plan: Mutex<Option<PlanBaseline>>,
    rebalance: RebalanceSettings,
    dim: usize,
    /// Metrics/trace sink shared with every minted handle
    /// ([`Cluster::attach_obs`]); disabled by default.
    obs: Arc<Obs>,
}

impl std::fmt::Debug for Cluster {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let table = self.routes();
        f.debug_struct("Cluster")
            .field("shards", &self.shards.len())
            .field("groups", &table.plan.num_groups())
            .field("epoch", &table.epoch)
            .finish()
    }
}

impl Cluster {
    /// Spawn the pool from prepared parts with ownership-pinned routing
    /// (the PR 1 model). `store` is the full table; each shard copies out
    /// only the tiles it owns.
    pub fn spawn_from_parts(
        shared: PoolShared,
        store: &EmbeddingStore,
        plan: ShardPlan,
        batch: BatchPolicy,
    ) -> Result<Self> {
        let replicas = ReplicaPlan::pinned(&plan, &shared.replication);
        Self::spawn_routed(shared, store, plan, replicas, RouteOptions::default(), batch)
    }

    /// Spawn the pool with an explicit replica placement and routing
    /// options. Each shard materialises every tile it hosts (owned +
    /// replicas) and schedules on its local replica table.
    pub fn spawn_routed(
        shared: PoolShared,
        store: &EmbeddingStore,
        plan: ShardPlan,
        replicas: ReplicaPlan,
        opts: RouteOptions,
        batch: BatchPolicy,
    ) -> Result<Self> {
        anyhow::ensure!(
            plan.num_groups() == shared.mapping.num_groups(),
            "plan covers {} groups, mapping has {}",
            plan.num_groups(),
            shared.mapping.num_groups()
        );
        anyhow::ensure!(
            replicas.num_groups() == plan.num_groups() && replicas.shards == plan.shards,
            "replica placement does not match the shard plan"
        );
        let dim = store.dim();
        let batch_size = shared.replication.batch_size;
        let dup_ratio = opts
            .dup_ratio
            .unwrap_or_else(|| shared.replication.area_overhead());
        let shared = Arc::new(shared);
        let stores = partition_store_with_replicas(store, &replicas);
        let mut shards = Vec::with_capacity(plan.shards);
        for (s, sstore) in stores.into_iter().enumerate() {
            let local = replicas.local_replication(s as u32, batch_size);
            shards.push(spawn_shard(
                s as u32,
                Arc::clone(&shared),
                sstore,
                local,
                batch.clone(),
            )?);
        }
        let inflight = Arc::new((0..plan.shards).map(|_| AtomicU64::new(0)).collect::<Vec<_>>());
        let table = RouteTable {
            epoch: 0,
            plan: Arc::new(plan),
            replicas: Arc::new(replicas),
            policy: opts.policy,
        };
        // Rebalancing rebuilds shard tile sets from the full table; only
        // pools with an armed drift monitor ever rebalance, so only they
        // pay for the retained copy.
        let full = opts.drift.as_ref().map(|_| Arc::new(store.clone()));
        let last_plan = opts.baseline_freqs.map(|freqs| PlanBaseline {
            freqs,
            replication: shared.replication.clone(),
        });
        Ok(Self {
            shards,
            routes: Arc::new(RwLock::new(Arc::new(table))),
            shared,
            inflight,
            drift: opts.drift.map(|d| Arc::new(Mutex::new(d))),
            full,
            last_plan: Mutex::new(last_plan),
            rebalance: RebalanceSettings {
                partition: opts.partition,
                slack: opts.slack,
                dup_ratio,
            },
            dim,
            obs: Obs::disabled(),
        })
    }

    /// Attach an observability handle ([`crate::obs`]): rebalances and
    /// every handle minted *after* this call record scatter-gather
    /// telemetry through it. Handles minted earlier keep the handle they
    /// were born with, so attach before calling [`Cluster::handle`].
    pub fn attach_obs(&mut self, obs: Arc<Obs>) {
        self.obs = obs;
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Snapshot of the current routing table (cheap `Arc` clone).
    pub fn routes(&self) -> Arc<RouteTable> {
        self.routes.read().expect("route lock poisoned").clone()
    }

    /// The current ownership plan.
    pub fn plan(&self) -> Arc<ShardPlan> {
        self.routes().plan.clone()
    }

    /// Current placement epoch (0 until the first rebalance).
    pub fn epoch(&self) -> u64 {
        self.routes().epoch
    }

    /// The shared pool state (mapping / global replication / cost model).
    pub fn shared(&self) -> &PoolShared {
        &self.shared
    }

    /// Epoch-versioned remap from recent traffic: recompute group
    /// frequencies, re-partition (locality policy only — hash owners are
    /// traffic-independent), re-plan Eq. 1 copies under the same area
    /// budget, spread the new copies, install every shard's new tile set,
    /// and atomically swap the routing table once all shards ack.
    ///
    /// Callers invoke this at a batch boundary (no in-flight
    /// sub-queries); a sub-query racing the swap is answered with an
    /// error, never with a wrong value — shards refuse foreign items.
    /// Returns the new epoch.
    ///
    /// This is the *full-scope* remap: every group is re-planned and
    /// every shard reinstalls its tiles (all shard status epochs equal
    /// the new epoch afterwards). It is the oracle the incremental path
    /// is checked against — both run through [`Cluster::rebalance_scoped`].
    pub fn rebalance(&self, recent: &Trace) -> Result<u64> {
        self.rebalance_scoped(recent, None).map(|r| r.epoch)
    }

    /// Delta-scoped remap: diff recent traffic's per-group frequencies
    /// against the installed plan's baseline, re-place and re-replicate
    /// only the groups whose load moved past `params`, and ship tiles
    /// only to shards whose hosted set or local replica table actually
    /// changed. Falls back to full scope when no baseline exists yet.
    ///
    /// The routing table still swaps atomically to a new epoch for
    /// everyone; shards skipped by the install keep serving their
    /// bit-identical tiles (no drain, no scheduler rebuild) but adopt
    /// the new epoch number via a [`ShardMsg::BumpEpoch`] ack before the
    /// swap, so [`ClusterHandle::shard_status`] reports one uniform
    /// epoch across the pool.
    ///
    /// The group *membership* delta is the engine layer's job
    /// ([`crate::engine::PreparedEngine::refresh`]); the live mapping is
    /// shared immutably with the shard threads, so this path owns the
    /// placement delta only.
    pub fn rebalance_incremental(
        &self,
        recent: &Trace,
        params: &DeltaParams,
    ) -> Result<RebalanceReport> {
        self.rebalance_scoped(recent, Some(params))
    }

    fn rebalance_scoped(
        &self,
        recent: &Trace,
        scope: Option<&DeltaParams>,
    ) -> Result<RebalanceReport> {
        anyhow::ensure!(!recent.queries.is_empty(), "rebalance needs recent traffic");
        let full_store = self.full.as_ref().ok_or_else(|| {
            anyhow!("rebalance requires an armed drift monitor (RouteOptions::drift)")
        })?;
        let cur = self.routes();
        let mapping = &self.shared.mapping;
        // One trace walk serves both the partitioner and the replication
        // re-plan (`GroupStats::freqs` == `allocation::group_frequencies`).
        let stats = mapping.group_stats(recent);
        let freqs = &stats.freqs;
        let num_groups = freqs.len();
        let batch_size = self.shared.replication.batch_size;

        let baseline = self
            .last_plan
            .lock()
            .expect("plan baseline poisoned")
            .clone();
        // Dirty = per-group |Δfreq| past the thresholds, judged against
        // the frequencies the installed plan was derived from. Without a
        // baseline (or at full scope) everything is dirty.
        let (dirty, full_scope) = match (scope, &baseline) {
            (Some(p), Some(base)) if base.freqs.len() == num_groups => {
                let dirty: Vec<bool> = (0..num_groups)
                    .map(|g| {
                        let change = freqs[g].abs_diff(base.freqs[g]);
                        change > p.abs_floor
                            && (change as f64) > p.rel_threshold * base.freqs[g] as f64
                    })
                    .collect();
                (dirty, false)
            }
            _ => (vec![true; num_groups], true),
        };
        let groups_changed = dirty.iter().filter(|&&d| d).count();

        let plan = match self.rebalance.partition {
            PartitionPolicy::Locality => {
                let keep = if full_scope {
                    None
                } else {
                    Some((cur.plan.shard_of_group.as_slice(), dirty.as_slice()))
                };
                ShardPlan::from_assignment(
                    mapping.partition_with(&stats, cur.plan.shards, self.rebalance.slack, keep),
                    cur.plan.shards,
                )
            }
            PartitionPolicy::Hash => (*cur.plan).clone(),
        };
        let prev_replication = baseline
            .as_ref()
            .map(|b| &b.replication)
            .unwrap_or(&self.shared.replication);
        let replication = if full_scope {
            allocation::plan_replication(freqs, batch_size, self.rebalance.dup_ratio)
        } else {
            allocation::plan_replication_delta(
                prev_replication,
                freqs,
                &dirty,
                batch_size,
                self.rebalance.dup_ratio,
            )
        };
        let replicas = match (cur.policy, full_scope) {
            (RoutePolicy::Pinned, _) => ReplicaPlan::pinned(&plan, &replication),
            (RoutePolicy::PowerOfTwo, true) => ReplicaPlan::spread(&plan, &replication, freqs),
            (RoutePolicy::PowerOfTwo, false) => {
                ReplicaPlan::spread_subset(&plan, &replication, freqs, &cur.replicas, &dirty)
            }
        };
        let epoch = cur.epoch + 1;

        // Install new tiles + local replica tables, then wait for every
        // ack before exposing the new routes. At full scope every shard
        // reinstalls; at delta scope a shard whose hosted set and local
        // replica table are both unchanged skips the install — its tiles
        // are bit-identical — and only bumps its reported epoch so the
        // pool's status rows stay uniform after the swap.
        let mut tiles_total = 0usize;
        let mut shards_installed = 0usize;
        let mut tiles_installed = 0usize;
        let mut acks = Vec::with_capacity(self.shards.len());
        for (s, exec) in self.shards.iter().enumerate() {
            let hosted = replicas.groups_hosted_by(s as u32);
            let local = replicas.local_replication(s as u32, batch_size);
            tiles_total += hosted.len();
            if !full_scope
                && hosted == cur.replicas.groups_hosted_by(s as u32)
                && local.copies == cur.replicas.local_replication(s as u32, batch_size).copies
            {
                let (atx, arx) = mpsc::channel();
                exec.tx
                    .send(ShardMsg::BumpEpoch { epoch, reply: atx })
                    .map_err(|_| anyhow!("shard {s} is down"))?;
                acks.push((s, arx));
                continue;
            }
            shards_installed += 1;
            tiles_installed += hosted.len();
            let sstore = ShardStore::from_store(full_store, &hosted);
            let (atx, arx) = mpsc::channel();
            exec.tx
                .send(ShardMsg::Install {
                    epoch,
                    store: sstore,
                    replication: local,
                    reply: atx,
                })
                .map_err(|_| anyhow!("shard {s} is down"))?;
            acks.push((s, arx));
        }
        for (s, arx) in acks {
            let got = arx
                .recv()
                .map_err(|_| anyhow!("shard {s} died during rebalance"))?;
            anyhow::ensure!(got == epoch, "shard {s} acked epoch {got}, expected {epoch}");
        }
        let table = RouteTable {
            epoch,
            plan: Arc::new(plan),
            replicas: Arc::new(replicas),
            policy: cur.policy,
        };
        *self.routes.write().expect("route lock poisoned") = Arc::new(table);
        *self.last_plan.lock().expect("plan baseline poisoned") = Some(PlanBaseline {
            freqs: stats.freqs,
            replication,
        });

        // Re-arm the drift monitor at the drifted workload's level: the
        // remap fixed the load imbalance; activations-per-lookup is a
        // property of the mapping, so the new normal is the current EMA.
        // `rebaseline` also starts the monitor's cooldown, so an
        // oscillating window cannot re-fire immediately.
        if let Some(d) = &self.drift {
            let mut m = d.lock().expect("drift lock poisoned");
            if let Some(e) = m.current() {
                if e > 0.0 {
                    m.rebaseline(e);
                }
            }
        }
        self.obs.incr(names::CLUSTER_REBALANCES, 1);
        self.obs.gauge_set(names::CLUSTER_EPOCH, epoch as f64);
        if full_scope {
            self.obs.incr(names::OFFLINE_FULL_REBUILDS, 1);
        } else {
            self.obs.incr(names::OFFLINE_REFRESHES, 1);
        }
        self.obs
            .incr(names::OFFLINE_GROUPS_TOUCHED, groups_changed as u64);
        self.obs
            .gauge_set(names::OFFLINE_GROUPS_TOTAL, num_groups as f64);
        self.obs
            .incr(names::OFFLINE_TILES_INSTALLED, tiles_installed as u64);
        self.obs
            .gauge_set(names::OFFLINE_TILES_TOTAL, tiles_total as f64);

        Ok(RebalanceReport {
            epoch,
            full: full_scope,
            groups_total: num_groups,
            groups_changed,
            shards_installed,
            tiles_installed,
            tiles_total,
        })
    }

    /// Cloneable client handle.
    pub fn handle(&self) -> ClusterHandle {
        ClusterHandle {
            txs: self.shards.iter().map(|s| s.tx.clone()).collect(),
            routes: Arc::clone(&self.routes),
            shared: Arc::clone(&self.shared),
            inflight: Arc::clone(&self.inflight),
            drift: self.drift.clone(),
            dim: self.dim,
            obs: Arc::clone(&self.obs),
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        for s in &self.shards {
            let _ = s.tx.send(ShardMsg::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(j) = s.join.take() {
                let _ = j.join();
            }
        }
    }
}

/// Cloneable scatter-gather client of a [`Cluster`].
#[derive(Clone)]
pub struct ClusterHandle {
    txs: Vec<mpsc::Sender<ShardMsg>>,
    routes: Arc<RwLock<Arc<RouteTable>>>,
    shared: Arc<PoolShared>,
    inflight: Arc<Vec<AtomicU64>>,
    drift: Option<Arc<Mutex<DriftMonitor>>>,
    dim: usize,
    obs: Arc<Obs>,
}

impl ClusterHandle {
    pub fn num_shards(&self) -> usize {
        self.txs.len()
    }

    /// Snapshot of the current routing table (cheap `Arc` clone).
    pub fn routes(&self) -> Arc<RouteTable> {
        self.routes.read().expect("route lock poisoned").clone()
    }

    /// The current ownership plan.
    pub fn plan(&self) -> Arc<ShardPlan> {
        self.routes().plan.clone()
    }

    /// Current placement epoch.
    pub fn epoch(&self) -> u64 {
        self.routes().epoch
    }

    /// Embedding dimension of merged results.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// True when the drift monitor is armed and reports the placement has
    /// gone stale (the driver should call [`Cluster::rebalance`]).
    pub fn rebalance_due(&self) -> bool {
        match &self.drift {
            Some(d) => d.lock().expect("drift lock poisoned").regroup_due(),
            None => false,
        }
    }

    /// Current drift degradation ratio (None when no monitor is armed).
    pub fn drift_degradation(&self) -> Option<f64> {
        self.drift
            .as_ref()
            .map(|d| d.lock().expect("drift lock poisoned").degradation())
    }

    /// The drift monitor's retained recent queries as a trace — the
    /// window [`Cluster::rebalance_incremental`] consumes. `None` when
    /// no monitor is armed, the monitor keeps no window, or nothing has
    /// been observed since the last rebaseline.
    pub fn drift_window(&self) -> Option<Trace> {
        self.drift.as_ref().and_then(|d| {
            d.lock()
                .expect("drift lock poisoned")
                .recent_window(self.shared.mapping.num_embeddings() as u32)
        })
    }

    /// Scatter-gather one query (blocking).
    pub fn reduce(&self, items: &[EmbeddingId]) -> Result<ClusterResponse> {
        let q = Query::new(items.to_vec());
        let mut out = self.reduce_many(std::slice::from_ref(&q))?;
        Ok(out.pop().expect("one query in, one response out"))
    }

    /// Scatter-gather a batch: all sub-queries are dispatched before any
    /// gather blocks, so shards work each other's queries concurrently.
    /// Responses come back in submission order. The whole batch routes
    /// under one routing-table snapshot (one epoch).
    pub fn reduce_many(&self, queries: &[Query]) -> Result<Vec<ClusterResponse>> {
        type PartialRx = mpsc::Receiver<crate::Result<super::ShardPartial>>;
        let t0 = Instant::now();
        let table = self.routes();
        // Scatter phase: route every query's items by holding shard. One
        // reply channel per (query, shard) sub-query keeps the gather
        // ordered by shard id — a tagged shared channel would be fewer
        // allocations but would make the float merge order depend on
        // thread timing.
        let mut pending: Vec<Vec<(u32, PartialRx)>> = Vec::with_capacity(queries.len());
        // On any failure, remember the first error but keep draining every
        // dispatched sub-query so the in-flight counters always return to
        // their pre-call values — a leaked counter would permanently skew
        // power-of-two-choices routing away from healthy shards.
        let mut first_err: Option<anyhow::Error> = None;
        'scatter: for (i, q) in queries.iter().enumerate() {
            let split = table.split_query(&self.shared.mapping, &q.items, i as u64, |s| {
                self.inflight[s as usize].load(Ordering::Relaxed)
            });
            let mut receivers = Vec::new();
            for (s, items) in split.into_iter().enumerate() {
                if items.is_empty() {
                    continue;
                }
                let (tx, rx) = mpsc::channel();
                let sent = self.txs[s].send(ShardMsg::Reduce {
                    id: i as u64,
                    items,
                    reply: tx,
                });
                if sent.is_err() {
                    first_err = Some(anyhow!("shard {s} is down"));
                    pending.push(receivers);
                    break 'scatter;
                }
                self.inflight[s].fetch_add(1, Ordering::Relaxed);
                receivers.push((s as u32, rx));
            }
            pending.push(receivers);
        }
        // Sample the p2c load signal at its peak — after the whole batch
        // scattered, before any gather decrements. Reads only; routing
        // decisions were already made.
        if self.obs.enabled() && first_err.is_none() {
            for c in self.inflight.iter() {
                self.obs
                    .observe(names::CLUSTER_INFLIGHT, c.load(Ordering::Relaxed) as f64);
            }
        }
        // Gather phase: merge partials in ascending shard order (the
        // receivers were registered in shard order) for determinism.
        let mut out = Vec::with_capacity(queries.len());
        for (i, receivers) in pending.into_iter().enumerate() {
            let fanout = receivers.len();
            let mut reduced = vec![0.0f32; self.dim];
            let mut activations = 0u64;
            for (s, rx) in receivers {
                let received = rx.recv();
                self.inflight[s as usize].fetch_sub(1, Ordering::Relaxed);
                if first_err.is_some() {
                    continue; // already failed: just drain the counters
                }
                match received {
                    Err(_) => first_err = Some(anyhow!("shard {s} dropped a sub-query")),
                    Ok(Err(e)) => first_err = Some(e),
                    Ok(Ok(partial)) => {
                        if partial.partial.len() != self.dim {
                            first_err = Some(anyhow!(
                                "shard {s} returned dim {} != {}",
                                partial.partial.len(),
                                self.dim
                            ));
                            continue;
                        }
                        for (o, &v) in reduced.iter_mut().zip(&partial.partial) {
                            *o += v;
                        }
                        activations += partial.activations;
                    }
                }
            }
            out.push(ClusterResponse {
                id: i as u64,
                reduced,
                fanout,
                activations,
                latency: t0.elapsed(),
            });
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        // Feed the drift monitor (placement staleness signal).
        if let Some(d) = &self.drift {
            let mut m = d.lock().expect("drift lock poisoned");
            for (q, r) in queries.iter().zip(&out) {
                m.observe_query(q, r.activations, q.len());
            }
        }
        // Harvest the batch's routing/fan-out telemetry from the merged
        // responses — all values the gather already computed.
        if self.obs.enabled() {
            self.obs.gauge_set(names::CLUSTER_EPOCH, table.epoch as f64);
            let route = match table.policy {
                RoutePolicy::Pinned => names::CLUSTER_ROUTE_PINNED,
                RoutePolicy::PowerOfTwo => names::CLUSTER_ROUTE_P2C,
            };
            self.obs.incr(route, out.len() as u64);
            for r in &out {
                self.obs.record_hist(names::CLUSTER_FANOUT, r.fanout as u64, 1);
                self.obs.incr(names::CLUSTER_SUBQUERIES, r.fanout as u64);
            }
            if let Some(d) = self.drift_degradation() {
                self.obs.gauge_set(names::DRIFT_DEGRADATION, d);
            }
        }
        Ok(out)
    }

    /// Snapshot every shard's cumulative status.
    pub fn shard_status(&self) -> Result<Vec<ShardStatus>> {
        let mut out = Vec::with_capacity(self.txs.len());
        for (s, tx) in self.txs.iter().enumerate() {
            let (rtx, rrx) = mpsc::channel();
            tx.send(ShardMsg::Status { reply: rtx })
                .map_err(|_| anyhow!("shard {s} is down"))?;
            out.push(rrx.recv().map_err(|_| anyhow!("shard {s} died"))?);
        }
        Ok(out)
    }

    /// Pool-level simulated cost: shards run concurrently, so completion
    /// is the max across shards ([`ExecStats::merge_parallel`]) while
    /// energy and counters sum. Shard stats only — the front-end's
    /// cross-shard merge adds are not included; see
    /// [`ClusterHandle::merged_sim_with_fanout`].
    pub fn merged_sim(&self) -> Result<ExecStats> {
        let mut total = ExecStats::default();
        for status in self.shard_status()? {
            total.merge_parallel(&status.sim);
        }
        Ok(total)
    }

    /// Pool cost from an already-taken status snapshot, plus the
    /// front-end scatter-gather merge cost, charged the same way
    /// `cluster::simulate_sharded` does: one vector add per extra shard a
    /// query touched (energy, exact from the fan-out histogram) and one
    /// `max_fanout - 1` merge chain on the critical path (completion; per
    /// gather wave — callers that issued a single `reduce_many` get
    /// exactly one wave). Takes statuses so one [`Self::shard_status`]
    /// sweep serves both the per-shard table and this total.
    pub fn merged_sim_with_fanout(
        &self,
        statuses: &[ShardStatus],
        fanout: &crate::metrics::Histogram,
    ) -> ExecStats {
        let mut total = ExecStats::default();
        for status in statuses {
            total.merge_parallel(&status.sim);
        }
        let (add_ns, add_pj) = self.shared.model.vector_add();
        let mut cross_adds = 0u64;
        let mut max_fanout = 0u64;
        for (value, count) in fanout.iter() {
            if value > 1 {
                cross_adds += (value - 1) * count;
            }
            max_fanout = max_fanout.max(value);
        }
        total.energy_pj += cross_adds as f64 * add_pj;
        if max_fanout > 1 {
            total.completion_ns += (max_fanout - 1) as f64 * add_ns;
        }
        total
    }
}
