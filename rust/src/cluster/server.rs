//! Cluster front-end: spawn the shard pool, scatter queries, gather and
//! merge partial reductions.
//!
//! [`Cluster::spawn_from_parts`] starts one executor thread per shard
//! (each with its own dynamic batcher and its own slice of the embedding
//! table). A [`ClusterHandle`] is the cloneable client: it splits each
//! query's lookups by owning shard, dispatches the per-shard sub-queries
//! in parallel, and sums the returned partial vectors — the reduction is
//! linear, so the scatter-gather merge is exact. Partials are always
//! merged in ascending shard order, keeping the float summation order
//! deterministic across runs.

use super::partition::ShardPlan;
use super::shard::{
    partition_store, spawn_shard, PoolShared, ShardExecutor, ShardMsg, ShardStatus,
};
use crate::coordinator::batcher::BatchPolicy;
use crate::coordinator::EmbeddingStore;
use crate::sched::ExecStats;
use crate::workload::{EmbeddingId, Query};
use crate::Result;
use anyhow::anyhow;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How groups are assigned to shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionPolicy {
    /// Consistent hashing of the group id (stateless, history-free).
    Hash,
    /// Co-occurrence-locality-preserving balanced partition (needs the
    /// offline history trace).
    Locality,
}

/// Cluster assembly knobs.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Shard executors to spawn.
    pub shards: usize,
    /// Virtual nodes per shard on the hash ring (Hash policy).
    pub vnodes: u32,
    /// Group→shard assignment policy.
    pub policy: PartitionPolicy,
    /// Per-shard dynamic-batcher policy.
    pub batch: BatchPolicy,
    /// Load-balance slack for the locality partitioner.
    pub slack: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            shards: 4,
            vnodes: 128,
            policy: PartitionPolicy::Locality,
            batch: BatchPolicy::default(),
            slack: 0.10,
        }
    }
}

/// One merged scatter-gather result.
#[derive(Debug, Clone)]
pub struct ClusterResponse {
    /// Position of the query in the submitted batch.
    pub id: u64,
    /// The merged reduced embedding, length `D`.
    pub reduced: Vec<f32>,
    /// Distinct shards this query touched.
    pub fanout: usize,
    /// Crossbar activations summed across shards.
    pub activations: u64,
    /// Wall clock from batch submission to this query's merge completing.
    /// Like the single-pool path, submission time is shared by the whole
    /// `reduce_many` batch, so later queries report larger values (queue +
    /// execute), and the in-order gather can add head-of-line wait on top
    /// — this is batch-position latency, not isolated service time.
    pub latency: Duration,
}

/// A running sharded pool: executors + plan.
pub struct Cluster {
    shards: Vec<ShardExecutor>,
    plan: Arc<ShardPlan>,
    shared: Arc<PoolShared>,
    dim: usize,
}

impl std::fmt::Debug for Cluster {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Cluster")
            .field("shards", &self.shards.len())
            .field("groups", &self.plan.num_groups())
            .finish()
    }
}

impl Cluster {
    /// Spawn the pool from prepared parts. `store` is the full table; each
    /// shard copies out only the tiles it owns.
    pub fn spawn_from_parts(
        shared: PoolShared,
        store: &EmbeddingStore,
        plan: ShardPlan,
        batch: BatchPolicy,
    ) -> Result<Self> {
        anyhow::ensure!(
            plan.num_groups() == shared.mapping.num_groups(),
            "plan covers {} groups, mapping has {}",
            plan.num_groups(),
            shared.mapping.num_groups()
        );
        let dim = store.dim();
        let shared = Arc::new(shared);
        let plan = Arc::new(plan);
        let stores = partition_store(store, &plan);
        let mut shards = Vec::with_capacity(plan.shards);
        for (s, sstore) in stores.into_iter().enumerate() {
            shards.push(spawn_shard(
                s as u32,
                Arc::clone(&shared),
                sstore,
                batch.clone(),
            )?);
        }
        Ok(Self {
            shards,
            plan,
            shared,
            dim,
        })
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Cloneable client handle.
    pub fn handle(&self) -> ClusterHandle {
        ClusterHandle {
            txs: self.shards.iter().map(|s| s.tx.clone()).collect(),
            plan: Arc::clone(&self.plan),
            shared: Arc::clone(&self.shared),
            dim: self.dim,
        }
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        for s in &self.shards {
            let _ = s.tx.send(ShardMsg::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(j) = s.join.take() {
                let _ = j.join();
            }
        }
    }
}

/// Cloneable scatter-gather client of a [`Cluster`].
#[derive(Clone)]
pub struct ClusterHandle {
    txs: Vec<mpsc::Sender<ShardMsg>>,
    plan: Arc<ShardPlan>,
    shared: Arc<PoolShared>,
    dim: usize,
}

impl ClusterHandle {
    pub fn num_shards(&self) -> usize {
        self.txs.len()
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Embedding dimension of merged results.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Scatter-gather one query (blocking).
    pub fn reduce(&self, items: &[EmbeddingId]) -> Result<ClusterResponse> {
        let q = Query::new(items.to_vec());
        let mut out = self.reduce_many(std::slice::from_ref(&q))?;
        Ok(out.pop().expect("one query in, one response out"))
    }

    /// Scatter-gather a batch: all sub-queries are dispatched before any
    /// gather blocks, so shards work each other's queries concurrently.
    /// Responses come back in submission order.
    pub fn reduce_many(&self, queries: &[Query]) -> Result<Vec<ClusterResponse>> {
        type PartialRx = mpsc::Receiver<crate::Result<super::ShardPartial>>;
        let t0 = Instant::now();
        // Scatter phase: route every query's items by owning shard
        // (ShardPlan::split_items is the one routing rule shared with the
        // simulator and the fan-out metrics). One reply channel per
        // (query, shard) sub-query keeps the gather ordered by shard id —
        // a tagged shared channel would be fewer allocations but would
        // make the float merge order depend on thread timing.
        let mut pending: Vec<Vec<(u32, PartialRx)>> = Vec::with_capacity(queries.len());
        for (i, q) in queries.iter().enumerate() {
            let split = self.plan.split_items(&self.shared.mapping, &q.items);
            let mut receivers = Vec::new();
            for (s, items) in split.into_iter().enumerate() {
                if items.is_empty() {
                    continue;
                }
                let (tx, rx) = mpsc::channel();
                self.txs[s]
                    .send(ShardMsg::Reduce {
                        id: i as u64,
                        items,
                        reply: tx,
                    })
                    .map_err(|_| anyhow!("shard {s} is down"))?;
                receivers.push((s as u32, rx));
            }
            pending.push(receivers);
        }
        // Gather phase: merge partials in ascending shard order (the
        // receivers were registered in shard order) for determinism.
        let mut out = Vec::with_capacity(queries.len());
        for (i, receivers) in pending.into_iter().enumerate() {
            let fanout = receivers.len();
            let mut reduced = vec![0.0f32; self.dim];
            let mut activations = 0u64;
            for (s, rx) in receivers {
                let partial = rx
                    .recv()
                    .map_err(|_| anyhow!("shard {s} dropped a sub-query"))??;
                anyhow::ensure!(
                    partial.partial.len() == self.dim,
                    "shard {s} returned dim {} != {}",
                    partial.partial.len(),
                    self.dim
                );
                for (o, &v) in reduced.iter_mut().zip(&partial.partial) {
                    *o += v;
                }
                activations += partial.activations;
            }
            out.push(ClusterResponse {
                id: i as u64,
                reduced,
                fanout,
                activations,
                latency: t0.elapsed(),
            });
        }
        Ok(out)
    }

    /// Snapshot every shard's cumulative status.
    pub fn shard_status(&self) -> Result<Vec<ShardStatus>> {
        let mut out = Vec::with_capacity(self.txs.len());
        for (s, tx) in self.txs.iter().enumerate() {
            let (rtx, rrx) = mpsc::channel();
            tx.send(ShardMsg::Status { reply: rtx })
                .map_err(|_| anyhow!("shard {s} is down"))?;
            out.push(rrx.recv().map_err(|_| anyhow!("shard {s} died"))?);
        }
        Ok(out)
    }

    /// Pool-level simulated cost: shards run concurrently, so completion
    /// is the max across shards ([`ExecStats::merge_parallel`]) while
    /// energy and counters sum. Shard stats only — the front-end's
    /// cross-shard merge adds are not included; see
    /// [`ClusterHandle::merged_sim_with_fanout`].
    pub fn merged_sim(&self) -> Result<ExecStats> {
        let mut total = ExecStats::default();
        for status in self.shard_status()? {
            total.merge_parallel(&status.sim);
        }
        Ok(total)
    }

    /// Pool cost from an already-taken status snapshot, plus the
    /// front-end scatter-gather merge cost, charged the same way
    /// `cluster::simulate_sharded` does: one vector add per extra shard a
    /// query touched (energy, exact from the fan-out histogram) and one
    /// `max_fanout - 1` merge chain on the critical path (completion; per
    /// gather wave — callers that issued a single `reduce_many` get
    /// exactly one wave). Takes statuses so one [`Self::shard_status`]
    /// sweep serves both the per-shard table and this total.
    pub fn merged_sim_with_fanout(
        &self,
        statuses: &[ShardStatus],
        fanout: &crate::metrics::Histogram,
    ) -> ExecStats {
        let mut total = ExecStats::default();
        for status in statuses {
            total.merge_parallel(&status.sim);
        }
        let (add_ns, add_pj) = self.shared.model.vector_add();
        let mut cross_adds = 0u64;
        let mut max_fanout = 0u64;
        for (value, count) in fanout.iter() {
            if value > 1 {
                cross_adds += (value - 1) * count;
            }
            max_fanout = max_fanout.max(value);
        }
        total.energy_pj += cross_adds as f64 * add_pj;
        if max_fanout > 1 {
            total.completion_ns += (max_fanout - 1) as f64 * add_ns;
        }
        total
    }
}
