//! One shard of the serving pool: partitioned store + executor thread.
//!
//! A shard hosts a subset of the logical groups — the ones it *owns* per
//! the cluster's [`super::ShardPlan`] plus any *replica tiles* the
//! cross-shard placement ([`super::ReplicaPlan`]) assigns it — and
//! materialises only those crossbar tiles ([`ShardStore`]); the embedding
//! table is genuinely partitioned, not mirrored. Its executor thread
//! mirrors the single-pool server's threading model: an `mpsc` channel
//! drained through a per-shard dynamic [`Batcher`], with the circuit cost
//! of every sub-batch simulated on its *local* replica table (the copies
//! this shard actually hosts) and accumulated locally. Because
//! sub-queries routed here only touch hosted groups, the shard's
//! `ExecStats` describe exactly the crossbars it hosts.
//!
//! A rebalance installs a new epoch via [`ShardMsg::Install`]: the shard
//! drains its queue against the old store, swaps in the new store +
//! local replica table, and acks — the front-end flips its routing table
//! only after every shard has acked, so no sub-query routed under the new
//! epoch can reach a shard still holding the old tiles.

use super::{ReplicaPlan, ShardPlan};
use crate::allocation::Replication;
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::coordinator::EmbeddingStore;
use crate::grouping::Mapping;
use crate::sched::{ExecStats, Scheduler, Scratch};
use crate::store::{TierCostModel, TierMap};
use crate::util::FxHashMap;
use crate::workload::{EmbeddingId, Query};
use crate::xbar::CrossbarModel;
use crate::util::{Clock, WallClock};
use crate::Result;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

/// Immutable pool state shared (via `Arc`) by every shard executor: the
/// global mapping/replication/cost model the offline phase produced.
#[derive(Debug)]
pub struct PoolShared {
    pub mapping: Mapping,
    pub replication: Replication,
    pub model: CrossbarModel,
    /// Whether the dynamic-switch ADC path is active.
    pub dynamic_switch: bool,
}

impl PoolShared {
    /// Snapshot a prepared engine's offline-phase products (this is what
    /// [`crate::engine::Engine::dynamic_switch`] exists for).
    pub fn from_engine(engine: &crate::engine::Engine) -> Self {
        Self {
            mapping: engine.mapping().clone(),
            replication: engine.replication().clone(),
            model: engine.model().clone(),
            dynamic_switch: engine.dynamic_switch(),
        }
    }
}

/// The slice of the embedding table one shard owns: tiles for its groups
/// only, addressed through a group→local index.
#[derive(Debug, Clone)]
pub struct ShardStore {
    dim: usize,
    rows: usize,
    /// Flat `[owned_groups, R, D]` tile data.
    tiles: Vec<f32>,
    local_of_group: FxHashMap<u32, u32>,
    /// Optional tier placement consulted before scheduling: hosted groups
    /// outside the crossbar-resident hot tier pay a modeled fetch before
    /// their tiles can serve. Reduction values are unaffected — tiering
    /// prices the walk, it never changes what the walk computes.
    tiers: Option<(TierMap, TierCostModel)>,
}

impl ShardStore {
    /// Copy the owned groups' tiles out of a full store.
    pub fn from_store(store: &EmbeddingStore, owned: &[u32]) -> Self {
        let dim = store.dim();
        let rows = store.rows();
        let mut tiles = Vec::with_capacity(owned.len() * rows * dim);
        let mut local_of_group = FxHashMap::default();
        for (i, &g) in owned.iter().enumerate() {
            local_of_group.insert(g, i as u32);
            tiles.extend_from_slice(store.tile(g));
        }
        Self {
            dim,
            rows,
            tiles,
            local_of_group,
            tiers: None,
        }
    }

    /// Attach a tier placement + cost model. Sub-batches served by this
    /// shard then stretch by the modeled fetch cost of their non-hot
    /// tiles (the deploy layer's [`crate::deploy::Tiered`] model, applied
    /// per shard).
    pub fn with_tiers(mut self, map: TierMap, cost: TierCostModel) -> Self {
        self.tiers = Some((map, cost));
        self
    }

    /// Modeled tile-fetch cost of one sub-query under the attached tier
    /// placement: each *distinct* hosted group outside the hot tier pays
    /// its tier's fetch latency once. Zero when no tiers are attached
    /// (everything crossbar-resident — the classic fully-hot pool).
    pub fn fetch_ns(
        &self,
        mapping: &Mapping,
        items: &[EmbeddingId],
        gscratch: &mut Vec<u32>,
    ) -> f64 {
        let Some((map, cost)) = &self.tiers else {
            return 0.0;
        };
        gscratch.clear();
        for &e in items {
            // slot_of routes out-of-catalogue ids to the overflow group,
            // so cold-start traffic is priced like any other tile touch.
            let group = mapping.slot_of(e).group;
            if self.owns(group) {
                gscratch.push(group);
            }
        }
        gscratch.sort_unstable();
        gscratch.dedup();
        gscratch.iter().map(|&g| cost.fetch_ns(map.tier(g))).sum()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Groups this shard owns.
    pub fn num_tiles(&self) -> usize {
        self.local_of_group.len()
    }

    pub fn owns(&self, group: u32) -> bool {
        self.local_of_group.contains_key(&group)
    }

    /// Row slice of an owned `(group, row)` slot.
    fn row(&self, group: u32, row: u16) -> Option<&[f32]> {
        let &local = self.local_of_group.get(&group)?;
        let off = (local as usize * self.rows + row as usize) * self.dim;
        Some(&self.tiles[off..off + self.dim])
    }

    /// Sum the items' rows into `out` (length `dim`). Returns `false` if
    /// any item lives outside this shard's partition — the scatter planner
    /// must never send one, so callers treat that as a routing bug.
    /// Cold-start ids beyond the catalogue have no trained embedding and
    /// contribute zero (they still cost an activation on the overflow
    /// group's crossbar, which the scheduler charges separately).
    pub fn reduce_into(&self, mapping: &Mapping, items: &[EmbeddingId], out: &mut [f32]) -> bool {
        for &e in items {
            if e as usize >= mapping.num_embeddings() {
                continue;
            }
            let slot = mapping.slot_of(e);
            match self.row(slot.group, slot.row) {
                // Blocked 4-wide accumulation (`util::accum`): identical
                // per-element sum order, so partials stay bit-identical
                // to the pre-blocked loop and to `reduce_reference`.
                Some(row) => crate::util::accum::add_assign_4wide(out, row),
                None => return false,
            }
        }
        true
    }
}

/// One scatter fan-out result from a shard.
#[derive(Debug, Clone)]
pub struct ShardPartial {
    /// Request id assigned by the scatter layer.
    pub id: u64,
    /// Partial reduction over this shard's owned lookups, length `D`.
    pub partial: Vec<f32>,
    /// Crossbar activations the sub-query cost on this shard.
    pub activations: u64,
}

/// Cumulative per-shard status snapshot (the `cluster` report's row).
#[derive(Debug, Clone)]
pub struct ShardStatus {
    pub shard: u32,
    /// Groups this shard hosts (owned + replica tiles).
    pub owned_groups: usize,
    /// Placement epoch this shard is serving (bumped by each rebalance).
    pub epoch: u64,
    /// Sub-queries served since spawn.
    pub sub_queries: u64,
    /// Embedding lookups served since spawn.
    pub lookups: u64,
    /// Batches the dynamic batcher closed.
    pub batches: u64,
    /// Circuit-simulated cost of everything served (sequential batches on
    /// this shard, so completion accumulates).
    pub sim: ExecStats,
}

pub(crate) enum ShardMsg {
    Reduce {
        id: u64,
        items: Vec<EmbeddingId>,
        reply: mpsc::Sender<Result<ShardPartial>>,
    },
    Status {
        reply: mpsc::Sender<ShardStatus>,
    },
    /// Epoch swap: drain queued work against the old tiles, then replace
    /// the hosted tile set + local replica table and ack.
    Install {
        epoch: u64,
        store: ShardStore,
        replication: Replication,
        reply: mpsc::Sender<u64>,
    },
    /// Epoch bump without a tile swap: a delta rebalance left this
    /// shard's hosted set untouched, so there is nothing to drain or
    /// rebuild — the shard just adopts the new epoch number and acks,
    /// keeping `shard_status` epochs uniform across the pool.
    BumpEpoch {
        epoch: u64,
        reply: mpsc::Sender<u64>,
    },
    Shutdown,
}

/// A running shard executor: channel + join handle.
pub(crate) struct ShardExecutor {
    pub tx: mpsc::Sender<ShardMsg>,
    pub join: Option<std::thread::JoinHandle<()>>,
}

/// Spawn one shard executor thread with its hosted tiles and *local*
/// replica table (the copies this shard actually holds).
pub(crate) fn spawn_shard(
    shard: u32,
    shared: Arc<PoolShared>,
    store: ShardStore,
    local_rep: Replication,
    policy: BatchPolicy,
) -> Result<ShardExecutor> {
    let (tx, rx) = mpsc::channel::<ShardMsg>();
    let join = std::thread::Builder::new()
        .name(format!("recross-shard-{shard}"))
        .spawn(move || shard_loop(shard, &shared, store, local_rep, &rx, policy))?;
    Ok(ShardExecutor {
        tx,
        join: Some(join),
    })
}

/// Per-thread mutable executor state.
struct ShardState {
    scratch: Scratch,
    gscratch: Vec<u32>,
    sim: ExecStats,
    epoch: u64,
    sub_queries: u64,
    lookups: u64,
    batches: u64,
}

type Pending = (u64, Vec<EmbeddingId>, mpsc::Sender<Result<ShardPartial>>);

fn shard_loop(
    shard: u32,
    shared: &PoolShared,
    store: ShardStore,
    local_rep: Replication,
    rx: &mpsc::Receiver<ShardMsg>,
    policy: BatchPolicy,
) {
    let clock = WallClock::new();
    let mut batcher: Batcher<Pending> = Batcher::new(policy);
    let mut state = ShardState {
        scratch: Scratch::default(),
        gscratch: Vec::new(),
        sim: ExecStats::default(),
        epoch: 0,
        sub_queries: 0,
        lookups: 0,
        batches: 0,
    };
    // Outer loop = one iteration per epoch: the scheduler (replica table
    // + per-row cost table) is a pure function of the local replica plan,
    // which only changes on Install — build it once per epoch, not per
    // sub-batch.
    let mut current = Some((store, local_rep));
    'epoch: while let Some((store, local_rep)) = current.take() {
        let sched = Scheduler::new(
            &shared.mapping,
            &local_rep,
            &shared.model,
            shared.dynamic_switch,
        );
        loop {
            let msg = match batcher.deadline_in(clock.now_ns()) {
                None => match rx.recv() {
                    Ok(m) => Some(m),
                    Err(_) => return, // all senders gone
                },
                Some(d) => match rx.recv_timeout(Duration::from_nanos(d)) {
                    Ok(m) => Some(m),
                    Err(mpsc::RecvTimeoutError::Timeout) => None,
                    Err(mpsc::RecvTimeoutError::Disconnected) => return,
                },
            };
            match msg {
                Some(ShardMsg::Shutdown) => return,
                Some(ShardMsg::Reduce { id, items, reply }) => {
                    batcher.push_at((id, items, reply), clock.now_ns());
                }
                Some(ShardMsg::Status { reply }) => {
                    // Flush queued work first so the snapshot is consistent.
                    while !batcher.is_empty() {
                        serve_shard_batch(&sched, shared, &store, batcher.take_batch(), &mut state);
                    }
                    let _ = reply.send(ShardStatus {
                        shard,
                        owned_groups: store.num_tiles(),
                        epoch: state.epoch,
                        sub_queries: state.sub_queries,
                        lookups: state.lookups,
                        batches: state.batches,
                        sim: state.sim.clone(),
                    });
                }
                Some(ShardMsg::Install {
                    epoch,
                    store: new_store,
                    replication,
                    reply,
                }) => {
                    // Drain everything routed under the old epoch against
                    // the old tiles, then swap — the epoch flip is atomic
                    // from the executor's point of view.
                    while !batcher.is_empty() {
                        serve_shard_batch(&sched, shared, &store, batcher.take_batch(), &mut state);
                    }
                    state.epoch = epoch;
                    let _ = reply.send(epoch);
                    current = Some((new_store, replication));
                    continue 'epoch;
                }
                Some(ShardMsg::BumpEpoch { epoch, reply }) => {
                    // No tile change — queued work stays valid and the
                    // scheduler stands; only the reported epoch moves.
                    state.epoch = epoch;
                    let _ = reply.send(epoch);
                }
                None => {}
            }
            while batcher.ready(clock.now_ns()) {
                serve_shard_batch(&sched, shared, &store, batcher.take_batch(), &mut state);
            }
        }
    }
}

fn serve_shard_batch(
    sched: &Scheduler<'_>,
    shared: &PoolShared,
    store: &ShardStore,
    batch: Vec<Pending>,
    state: &mut ShardState,
) {
    if batch.is_empty() {
        return;
    }
    // Move the owned item lists straight into queries (no clone).
    let mut queries = Vec::with_capacity(batch.len());
    let mut replies = Vec::with_capacity(batch.len());
    for (id, items, reply) in batch {
        queries.push(Query::new(items));
        replies.push((id, reply));
    }

    // Circuit cost of the sub-batch on this shard's crossbars, scheduled
    // over the *local* replica table — only the copies this shard hosts
    // can absorb its traffic.
    let sim = sched.run_batch(&queries, &mut state.scratch);
    state.sim.accumulate(&sim);
    // Tiered shards consult the tier map before the crossbars can serve:
    // non-hot tiles must be fetched first. Fetches across the sub-batch
    // overlap (DRAM/file reads pipeline against crossbar service), so
    // completion stretches by the worst single sub-query's fetch, not
    // the sum — the same composition the deploy-layer tiered twin uses.
    let mut max_fetch = 0.0f64;
    for q in &queries {
        max_fetch = max_fetch.max(store.fetch_ns(&shared.mapping, &q.items, &mut state.gscratch));
    }
    state.sim.completion_ns += max_fetch;
    state.batches += 1;

    for ((id, reply), q) in replies.into_iter().zip(queries.iter()) {
        let mut partial = vec![0.0f32; store.dim()];
        let owned = store.reduce_into(&shared.mapping, &q.items, &mut partial);
        let activations = shared.mapping.groups_touched(&q.items, &mut state.gscratch) as u64;
        state.sub_queries += 1;
        state.lookups += q.len() as u64;
        let result = if owned {
            Ok(ShardPartial {
                id,
                partial,
                activations,
            })
        } else {
            Err(anyhow::anyhow!(
                "sub-query {id} contains items outside this shard's partition"
            ))
        };
        let _ = reply.send(result);
    }
}

/// Build every shard's store from the full table per an ownership plan
/// (no cross-shard replicas).
pub fn partition_store(store: &EmbeddingStore, plan: &ShardPlan) -> Vec<ShardStore> {
    (0..plan.shards as u32)
        .map(|s| ShardStore::from_store(store, &plan.groups_of(s)))
        .collect()
}

/// Build every shard's store from the full table per a replica placement:
/// each shard materialises tiles for every group it hosts, owned or
/// replicated.
pub fn partition_store_with_replicas(
    store: &EmbeddingStore,
    replicas: &ReplicaPlan,
) -> Vec<ShardStore> {
    (0..replicas.shards as u32)
        .map(|s| ShardStore::from_store(store, &replicas.groups_hosted_by(s)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grouping::Mapping;

    fn fixture() -> (Mapping, EmbeddingStore) {
        let m = Mapping::from_groups(
            vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]],
            2,
            8,
        );
        // Integer-valued table: D=2, embedding e = [2e, 2e+1].
        let table: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let s = EmbeddingStore::from_table(&m, 2, 4, table);
        (m, s)
    }

    #[test]
    fn shard_store_holds_only_owned_tiles() {
        let (m, full) = fixture();
        let s = ShardStore::from_store(&full, &[1, 3]);
        assert_eq!(s.num_tiles(), 2);
        assert!(s.owns(1) && s.owns(3));
        assert!(!s.owns(0) && !s.owns(2));
        // group 1 row 0 = embedding 2 = [4, 5]
        assert_eq!(s.row(1, 0).unwrap(), &[4.0, 5.0]);
        assert!(s.row(0, 0).is_none());
        let _ = m;
    }

    #[test]
    fn reduce_into_matches_reference() {
        let (m, full) = fixture();
        let s = ShardStore::from_store(&full, &[0, 1]);
        let mut out = vec![0.0f32; 2];
        assert!(s.reduce_into(&m, &[0, 3], &mut out));
        assert_eq!(out, full.reduce_reference(&[0, 3]));
    }

    #[test]
    fn reduce_into_rejects_foreign_items() {
        let (m, full) = fixture();
        let s = ShardStore::from_store(&full, &[0]);
        let mut out = vec![0.0f32; 2];
        assert!(!s.reduce_into(&m, &[0, 7], &mut out));
    }

    #[test]
    fn tiered_shard_prices_cold_fetches_without_changing_values() {
        use crate::store::Tier;
        let (m, full) = fixture();
        let flat = ShardStore::from_store(&full, &[0, 1]);
        // Group 0 hot, group 1 cold; foreign groups irrelevant.
        let tiered = flat.clone().with_tiers(
            TierMap::new(vec![Tier::Hot, Tier::Cold, Tier::Hot, Tier::Hot]),
            TierCostModel::new(100.0, 2_000.0),
        );
        let mut g = Vec::new();
        // All-hot query is free; the cold tile prices once however many
        // lookups land on it; foreign groups (2, 3) are not this shard's
        // fetches to make.
        assert_eq!(tiered.fetch_ns(&m, &[0, 1], &mut g), 0.0);
        assert_eq!(tiered.fetch_ns(&m, &[0, 2, 3], &mut g), 2_000.0);
        assert_eq!(tiered.fetch_ns(&m, &[2, 2, 3], &mut g), 2_000.0);
        assert_eq!(tiered.fetch_ns(&m, &[4, 6], &mut g), 0.0);
        assert_eq!(flat.fetch_ns(&m, &[0, 2, 3], &mut g), 0.0);
        // Values are placement-independent.
        let (mut a, mut b) = (vec![0.0f32; 2], vec![0.0f32; 2]);
        assert!(flat.reduce_into(&m, &[0, 2], &mut a));
        assert!(tiered.reduce_into(&m, &[0, 2], &mut b));
        assert_eq!(a, b);
    }

    #[test]
    fn partition_store_covers_every_group() {
        let (_, full) = fixture();
        let plan = ShardPlan::from_assignment(vec![0, 1, 1, 0], 2);
        let stores = partition_store(&full, &plan);
        assert_eq!(stores.len(), 2);
        assert_eq!(stores[0].num_tiles() + stores[1].num_tiles(), 4);
        assert!(stores[0].owns(0) && stores[0].owns(3));
        assert!(stores[1].owns(1) && stores[1].owns(2));
    }
}
