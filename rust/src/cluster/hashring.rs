//! Consistent-hash ring over shard ids (ketama-style virtual nodes).
//!
//! The ring places `vnodes` points per shard on a `u64` circle; a key is
//! owned by the first point clockwise from its hashed position. Virtual
//! nodes smooth the arc-length variance, so load balance tightens as
//! `1/sqrt(vnodes)`; adding or removing a shard moves only the keys on the
//! arcs adjacent to its points (~`1/shards` of the keyspace), which is
//! what lets a serving pool resize without a full remap.
//!
//! Hashing reuses [`crate::util::fxhash`] (the crate's trusted-integer-key
//! hasher) with a SplitMix64 finalizer on top: FxHash alone is weak on
//! short sequential keys (group ids *are* sequential), and ring balance
//! needs full avalanche.

use crate::util::fxhash::FxHasher;
use crate::util::rng::splitmix64;
use std::hash::Hasher;

/// SplitMix64 step as a full-avalanche finalizer.
fn mix(h: u64) -> u64 {
    let mut state = h;
    splitmix64(&mut state)
}

/// FxHash a word sequence down to one `u64`.
fn fx(words: &[u64]) -> u64 {
    let mut h = FxHasher::default();
    for &w in words {
        h.write_u64(w);
    }
    h.finish()
}

/// Domain tag separating ring-point hashes from key hashes. FxHash
/// absorbs leading zero words (`fx([0, v]) == fx([v])`), so without a
/// nonzero salt shard 0's virtual-node points would collide *exactly*
/// with the ring positions of keys `0..vnodes`, funnelling all those
/// keys to shard 0 (measured: >2x mean load). ASCII "RING_SAL".
const RING_SALT: u64 = 0x52_49_4e_47_5f_53_41_4c;

/// Ring position of a lookup key.
#[inline]
pub fn key_point(key: u64) -> u64 {
    mix(fx(&[key]))
}

/// A consistent-hash ring mapping `u64` keys to shard ids.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// `(point, shard)` sorted by point.
    points: Vec<(u64, u32)>,
    shards: u32,
    vnodes: u32,
}

impl HashRing {
    /// Build a ring with `vnodes` virtual nodes per shard. Deterministic:
    /// the same `(shards, vnodes)` always yields the same ring.
    pub fn new(shards: u32, vnodes: u32) -> Self {
        assert!(shards > 0, "ring needs at least one shard");
        assert!(vnodes > 0, "ring needs at least one virtual node per shard");
        let mut points = Vec::with_capacity(shards as usize * vnodes as usize);
        for s in 0..shards {
            for v in 0..vnodes {
                points.push((mix(fx(&[RING_SALT, s as u64, v as u64])), s));
            }
        }
        points.sort_unstable();
        // Point collisions are ~2^-64 rare; drop duplicates so ownership
        // stays a function of the sorted point list alone.
        points.dedup_by_key(|p| p.0);
        Self {
            points,
            shards,
            vnodes,
        }
    }

    pub fn num_shards(&self) -> u32 {
        self.shards
    }

    pub fn vnodes(&self) -> u32 {
        self.vnodes
    }

    /// Total points on the ring.
    pub fn num_points(&self) -> usize {
        self.points.len()
    }

    /// Owning shard of a key: first ring point at or clockwise of the
    /// key's position, wrapping at the top of the `u64` circle.
    pub fn owner(&self, key: u64) -> u32 {
        let h = key_point(key);
        let idx = self.points.partition_point(|p| p.0 < h);
        let idx = if idx == self.points.len() { 0 } else { idx };
        self.points[idx].1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_assignment() {
        let a = HashRing::new(8, 64);
        let b = HashRing::new(8, 64);
        for key in 0..2_000u64 {
            assert_eq!(a.owner(key), b.owner(key));
        }
    }

    #[test]
    fn every_shard_owns_something() {
        let ring = HashRing::new(16, 64);
        let mut seen = vec![false; 16];
        for key in 0..10_000u64 {
            seen[ring.owner(key) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "some shard owns no keys");
    }

    #[test]
    fn balanced_within_20pct_over_64_shards() {
        // The ISSUE acceptance bound: ±20% of mean load over 64 shards.
        let shards = 64u32;
        let ring = HashRing::new(shards, 1024);
        let keys = 200_000u64;
        let mut counts = vec![0u64; shards as usize];
        for key in 0..keys {
            counts[ring.owner(key) as usize] += 1;
        }
        let mean = keys as f64 / shards as f64;
        for (s, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - mean).abs() / mean;
            assert!(
                dev <= 0.20,
                "shard {s}: {c} keys vs mean {mean:.0} ({:.1}% off)",
                dev * 100.0
            );
        }
    }

    #[test]
    fn growing_the_ring_moves_few_keys() {
        // Consistent-hashing property: adding one shard remaps ~1/(n+1)
        // of the keys, not all of them.
        let before = HashRing::new(8, 128);
        let after = HashRing::new(9, 128);
        let keys = 20_000u64;
        let moved = (0..keys)
            .filter(|&k| before.owner(k) != after.owner(k))
            .count();
        let frac = moved as f64 / keys as f64;
        assert!(frac > 0.0, "growing the ring moved nothing");
        assert!(frac < 0.30, "grew 8->9 shards but {:.0}% of keys moved", frac * 100.0);
        // Keys that moved must have moved *to the new shard*.
        for k in 0..keys {
            if before.owner(k) != after.owner(k) {
                assert_eq!(after.owner(k), 8, "key {k} moved to an old shard");
            }
        }
    }

    #[test]
    #[should_panic(expected = "at least one shard")]
    fn zero_shards_rejected() {
        HashRing::new(0, 8);
    }
}
