//! Sharded crossbar serving pool — the cluster layer above the single-pool
//! coordinator.
//!
//! The paper's pipeline (grouping → replication → dynamic-ADC scheduling)
//! manages *one* crossbar pool. A production recommender shards its
//! embedding tables across many such pools; this module adds that layer:
//!
//! ```text
//!                    ClusterHandle (scatter-gather)
//!                   /       |        \
//!            shard 0     shard 1    shard N-1        (one thread each)
//!            Batcher     Batcher     Batcher         per-shard dynamic batching
//!            Scheduler   Scheduler   Scheduler       circuit cost per sub-batch
//!            ShardStore  ShardStore  ShardStore      owned tiles only
//! ```
//!
//! * **Partitioning** ([`partition`], [`hashring`]) — logical groups are
//!   assigned to shards either by consistent hashing of the group id
//!   (stateless; reuses [`crate::util::fxhash`]) or by a co-occurrence-
//!   locality-preserving balanced partition
//!   ([`crate::grouping::Mapping::partition_across`]) that keeps
//!   correlated crossbars on one shard so query fan-out stays low.
//! * **Shard executors** ([`shard`]) — one thread per shard owning its
//!   slice of the embedding table, serving sub-queries through its own
//!   dynamic batcher and accumulating its own [`crate::sched::ExecStats`].
//! * **Scatter-gather** ([`server`]) — the front-end splits a query's
//!   lookups by owning shard, dispatches all sub-queries, then merges the
//!   partial sums in shard order. The reduction is linear, so the split
//!   is exact; shard stats combine with
//!   [`crate::sched::ExecStats::merge_parallel`] (completion = max).
//! * **Reporting** ([`report`]) — per-shard load/stall and fan-out
//!   histograms for the `recross cluster` CLI mode.

pub mod hashring;
pub mod partition;
pub mod report;
pub mod server;
pub mod shard;

pub use hashring::HashRing;
pub use partition::{ReplicaPlan, ShardPlan};
pub use server::{
    Cluster, ClusterConfig, ClusterHandle, ClusterResponse, PartitionPolicy, RebalanceReport,
    RouteOptions, RoutePolicy, RouteTable, ShardingMode,
};
pub use shard::{
    partition_store, partition_store_with_replicas, PoolShared, ShardPartial, ShardStatus,
    ShardStore,
};

use crate::config::Config;
use crate::coordinator::{DriftMonitor, EmbeddingStore, OfflinePhase};
use crate::engine::{Engine, Scheme};
use crate::sched::{ExecStats, Scheduler, Scratch};
use crate::workload::{Query, Trace};
use crate::Result;
use std::sync::Arc;

/// Everything `Cluster::build` assembles: the running pool plus the
/// reference pieces a driver needs (the held-out eval trace, the offline
/// history the partition was derived from, and the full table for
/// single-pool verification).
pub struct ClusterBundle {
    pub cluster: Cluster,
    /// Full (unsharded) store — the verification reference; shards hold
    /// their own partitioned copies.
    pub store: EmbeddingStore,
    /// Offline history trace the partition/placement was derived from.
    pub history: Trace,
    /// Held-out evaluation trace from the offline phase.
    pub eval: Trace,
}

/// Assemble and spawn a cluster from already-prepared offline products —
/// the one assembly path shared by [`Cluster::build`] and the
/// [`crate::deploy::Sharded`] backend: partition → replica placement →
/// (optional) drift baseline → spawn.
pub(crate) fn assemble_cluster(
    engine: &Engine,
    history: &Trace,
    eval: &Trace,
    store: &EmbeddingStore,
    ccfg: &ClusterConfig,
) -> Result<Cluster> {
    anyhow::ensure!(ccfg.shards > 0, "need at least one shard");
    anyhow::ensure!(ccfg.vnodes > 0, "need at least one virtual node per shard");
    // The shard executors run the in-crossbar MAC dataflow
    // (Scheduler::run_batch); nMARS's lookup + serial-aggregation
    // dataflow has no sharded implementation, so refuse it rather
    // than report MAC costs under an nMARS label.
    anyhow::ensure!(
        engine.scheme() != Scheme::Nmars,
        "the sharded pool serves the MAC dataflow; scheme {:?} is not supported here",
        engine.scheme().name()
    );
    let mapping = engine.mapping();
    let plan = match ccfg.policy {
        PartitionPolicy::Hash => ShardPlan::by_hash(
            mapping.num_groups(),
            &HashRing::new(ccfg.shards as u32, ccfg.vnodes),
        ),
        PartitionPolicy::Locality => {
            ShardPlan::by_locality(mapping, history, ccfg.shards, ccfg.slack)
        }
    };
    let shared = PoolShared::from_engine(engine);
    if ccfg.mode.replica_routing() || ccfg.mode.rebalance() {
        // One counting pass for the whole offline phase: the engine
        // caches the per-group frequencies it derived during prepare, so
        // the placement layer reuses them instead of re-walking history.
        let freqs = engine.group_freqs(history).to_vec();
        let replicas = if ccfg.mode.replica_routing() {
            ReplicaPlan::spread(&plan, &shared.replication, &freqs)
        } else {
            ReplicaPlan::pinned(&plan, &shared.replication)
        };
        let drift = if ccfg.mode.rebalance() {
            // Baseline: the mapping's activations-per-lookup on the
            // held-out eval trace (the offline validation run).
            let mut scratch = Vec::new();
            let (mut acts, mut lks) = (0u64, 0u64);
            for q in &eval.queries {
                acts += mapping.groups_touched(&q.items, &mut scratch) as u64;
                lks += q.len() as u64;
            }
            let baseline = if lks == 0 {
                1.0
            } else {
                acts as f64 / lks as f64
            };
            // Cooldown + recent-query ring arm the incremental path:
            // the ring is the delta window, the cooldown keeps an
            // oscillating workload from re-firing right after a swap.
            Some(
                DriftMonitor::new(baseline.max(1e-6), 1.3, 0.05, 128)
                    .with_cooldown(256)
                    .with_window(2048),
            )
        } else {
            None
        };
        let opts = RouteOptions {
            policy: ccfg.mode.route_policy(),
            partition: ccfg.policy,
            slack: ccfg.slack,
            dup_ratio: None,
            drift,
            baseline_freqs: Some(freqs),
        };
        Cluster::spawn_routed(shared, store, plan, replicas, opts, ccfg.batch.clone())
    } else {
        Cluster::spawn_from_parts(shared, store, plan, ccfg.batch.clone())
    }
}

impl Cluster {
    /// Offline phase → partition → replica placement → spawn, per the
    /// config. The engine's mapping/replication/cost model are shared
    /// read-only by all shards; the store is laid out once and
    /// partitioned tile-by-tile (plus replica tiles when
    /// `ccfg.mode` spreads hot groups across shards).
    ///
    /// Convenience wrapper over the [`crate::deploy`] pieces: prefer
    /// `Deployment::of(..).build()?` + [`crate::deploy::Sharded::spawn`]
    /// when you also need the prepared bundle.
    pub fn build(
        cfg: &Config,
        scheme: Scheme,
        scale: f64,
        ccfg: &ClusterConfig,
    ) -> Result<ClusterBundle> {
        // Fast-fail before the (potentially minutes-long) offline phase;
        // assemble_cluster re-checks for callers arriving with a
        // prepared engine.
        anyhow::ensure!(
            scheme != Scheme::Nmars,
            "the sharded pool serves the MAC dataflow; scheme {:?} is not supported here",
            scheme.name()
        );
        anyhow::ensure!(ccfg.shards > 0, "need at least one shard");
        anyhow::ensure!(ccfg.vnodes > 0, "need at least one virtual node per shard");
        let offline = OfflinePhase::run(cfg, scheme, scale)?;
        let store = EmbeddingStore::random(
            offline.engine.mapping(),
            cfg.hardware.embedding_dim,
            cfg.hardware.xbar_rows,
            cfg.workload.seed,
        );
        let cluster =
            assemble_cluster(&offline.engine, &offline.history, &offline.eval, &store, ccfg)?;
        Ok(ClusterBundle {
            cluster,
            store,
            history: offline.history,
            eval: offline.eval,
        })
    }
}

/// Deterministic thread-free simulation of the ownership-pinned sharded
/// pool over a trace (what `benches/fig12_sharding.rs` sweeps).
///
/// A thin wrapper over [`simulate_with_replicas`] with a
/// [`ReplicaPlan::pinned`] placement and [`RoutePolicy::Pinned`] routing
/// — the PR 1 cost model as a special case of the replica-routed one, so
/// every cost-model tweak lands in exactly one loop.
///
/// Note: `queries` in the result counts *sub-queries* (one per
/// shard a query touched), mirroring what the live shard executors see.
pub fn simulate_sharded(
    shared: &PoolShared,
    plan: &ShardPlan,
    trace: &Trace,
    batch_size: usize,
) -> ExecStats {
    let pinned = ReplicaPlan::pinned(plan, &shared.replication);
    simulate_with_replicas(shared, plan, &pinned, trace, batch_size, RoutePolicy::Pinned).stats
}

/// Result of a replica-routed cluster simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutedSim {
    /// Pool-level stats (per-batch parallel merge + front-end merge cost,
    /// identical accounting to [`simulate_sharded`]).
    pub stats: ExecStats,
    /// Sub-query activation load each shard absorbed over the trace —
    /// the imbalance metric replica routing exists to flatten.
    pub shard_loads: Vec<u64>,
}

impl RoutedSim {
    /// The hottest shard's activation load.
    pub fn max_shard_load(&self) -> u64 {
        self.shard_loads.iter().copied().max().unwrap_or(0)
    }
}

/// Deterministic thread-free simulation of the sharded pool with a
/// replica placement and routing policy — the apples-to-apples harness
/// behind the `--replica-routing` report and `benches/fig12_sharding.rs`.
///
/// Differences from [`simulate_sharded`]: each shard schedules on its
/// *local* replica table (only the copies it hosts can absorb its
/// traffic), and with [`RoutePolicy::PowerOfTwo`] every (query, group)
/// activation is routed to the less-loaded of two sampled holder shards,
/// judged by the activations pending on each shard within the current
/// batch — the deterministic stand-in for the live pool's in-flight
/// counters, which drain at every gather wave. With
/// [`RoutePolicy::Pinned`] and a [`ReplicaPlan::pinned`] placement this
/// reproduces [`simulate_sharded`]'s costs exactly.
pub fn simulate_with_replicas(
    shared: &PoolShared,
    plan: &ShardPlan,
    replicas: &ReplicaPlan,
    trace: &Trace,
    batch_size: usize,
    policy: RoutePolicy,
) -> RoutedSim {
    assert_eq!(
        plan.num_groups(),
        shared.mapping.num_groups(),
        "plan covers {} groups, mapping has {}",
        plan.num_groups(),
        shared.mapping.num_groups()
    );
    assert_eq!(
        replicas.num_groups(),
        plan.num_groups(),
        "replica placement does not match the plan"
    );
    let shards = plan.shards;
    let table = RouteTable {
        epoch: 0,
        plan: Arc::new(plan.clone()),
        replicas: Arc::new(replicas.clone()),
        policy,
    };
    // One scheduler per shard over its local replica table.
    let locals: Vec<crate::allocation::Replication> = (0..shards)
        .map(|s| replicas.local_replication(s as u32, shared.replication.batch_size))
        .collect();
    let scheds: Vec<Scheduler<'_>> = locals
        .iter()
        .map(|r| Scheduler::new(&shared.mapping, r, &shared.model, shared.dynamic_switch))
        .collect();
    let (add_ns, add_pj) = shared.model.vector_add();
    let mut scratch = Scratch::default();
    let mut gscratch: Vec<u32> = Vec::new();
    let mut total = ExecStats::default();
    let mut loads = vec![0u64; shards];
    // The routing signal: activations pending on each shard *within the
    // current batch* — the deterministic analogue of the live pool's
    // in-flight counters, which drain at every gather wave.
    let mut pending = vec![0u64; shards];
    let mut sub: Vec<Vec<Query>> = vec![Vec::new(); shards];
    let mut qsalt = 0u64;
    for batch in trace.batches(batch_size) {
        for v in &mut sub {
            v.clear();
        }
        pending.fill(0);
        let mut max_fanout = 0usize;
        for q in batch {
            let split = table.split_query(&shared.mapping, &q.items, qsalt, |s| {
                pending[s as usize]
            });
            qsalt += 1;
            let fanout = split.iter().filter(|v| !v.is_empty()).count();
            max_fanout = max_fanout.max(fanout);
            if fanout > 1 {
                // Front-end merge energy: one vector add per extra shard.
                total.energy_pj += (fanout - 1) as f64 * add_pj;
            }
            for (s, items) in split.into_iter().enumerate() {
                if !items.is_empty() {
                    let acts = shared.mapping.groups_touched(&items, &mut gscratch) as u64;
                    pending[s] += acts;
                    loads[s] += acts;
                    sub[s].push(Query::new(items));
                }
            }
        }
        let mut batch_stats = ExecStats::default();
        for (s, queries) in sub.iter().enumerate() {
            if queries.is_empty() {
                continue;
            }
            batch_stats.merge_parallel(&scheds[s].run_batch(queries, &mut scratch));
        }
        // Cross-shard merge latency on the critical path.
        if max_fanout > 1 {
            batch_stats.completion_ns += (max_fanout - 1) as f64 * add_ns;
        }
        total.accumulate(&batch_stats);
    }
    RoutedSim {
        stats: total,
        shard_loads: loads,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::Replication;
    use crate::grouping::Mapping;
    use crate::xbar::{CircuitParams, CrossbarModel};

    fn shared_2x2() -> PoolShared {
        let mapping = Mapping::from_groups(vec![vec![0, 1], vec![2, 3]], 2, 4);
        let replication = Replication::identity(2, 4);
        let model = CrossbarModel::new(
            &crate::config::HardwareConfig::default(),
            &CircuitParams::default(),
        );
        PoolShared {
            mapping,
            replication,
            model,
            dynamic_switch: true,
        }
    }

    #[test]
    fn one_shard_simulation_matches_single_pool() {
        let shared = shared_2x2();
        let trace = Trace {
            num_embeddings: 4,
            queries: vec![
                Query::new(vec![0, 1]),
                Query::new(vec![0, 2]),
                Query::new(vec![3]),
            ],
        };
        let plan = ShardPlan::from_assignment(vec![0, 0], 1);
        let sharded = simulate_sharded(&shared, &plan, &trace, 2);
        let sched = Scheduler::new(
            &shared.mapping,
            &shared.replication,
            &shared.model,
            shared.dynamic_switch,
        );
        let mut scratch = Scratch::default();
        let mut reference = ExecStats::default();
        for batch in trace.batches(2) {
            reference.accumulate(&sched.run_batch(batch, &mut scratch));
        }
        assert_eq!(sharded, reference);
    }

    #[test]
    fn sharded_split_conserves_work() {
        let shared = shared_2x2();
        let trace = Trace {
            num_embeddings: 4,
            queries: vec![Query::new(vec![0, 2]), Query::new(vec![1, 3])],
        };
        let plan = ShardPlan::from_assignment(vec![0, 1], 2);
        let stats = simulate_sharded(&shared, &plan, &trace, 2);
        // Every (query, group) pair still produces exactly one activation.
        assert_eq!(stats.activations, 4);
        assert_eq!(stats.lookups, 4);
        // Each query split into 2 sub-queries.
        assert_eq!(stats.queries, 4);
    }

    /// Hot group 0 (2 copies) owned by shard 0; cold group 1 on shard 1.
    fn skewed_fixture() -> (PoolShared, ShardPlan, Trace) {
        let mapping = Mapping::from_groups(vec![vec![0, 1], vec![2, 3]], 2, 4);
        let replication = Replication::from_copies(vec![2, 1], 4);
        let model = CrossbarModel::new(
            &crate::config::HardwareConfig::default(),
            &CircuitParams::default(),
        );
        let shared = PoolShared {
            mapping,
            replication,
            model,
            dynamic_switch: true,
        };
        let mut queries = Vec::new();
        for i in 0..64u32 {
            queries.push(Query::new(vec![i % 2])); // hammer group 0
            if i % 8 == 0 {
                queries.push(Query::new(vec![2])); // trickle to group 1
            }
        }
        let plan = ShardPlan::from_assignment(vec![0, 1], 2);
        (shared, plan, Trace { num_embeddings: 4, queries })
    }

    #[test]
    fn replica_routing_flattens_max_shard_load() {
        let (shared, plan, trace) = skewed_fixture();
        let freqs =
            crate::allocation::group_frequencies(&shared.mapping, &trace);
        let pinned_plan = ReplicaPlan::pinned(&plan, &shared.replication);
        let spread_plan = ReplicaPlan::spread(&plan, &shared.replication, &freqs);
        let pinned =
            simulate_with_replicas(&shared, &plan, &pinned_plan, &trace, 8, RoutePolicy::Pinned);
        let routed = simulate_with_replicas(
            &shared,
            &plan,
            &spread_plan,
            &trace,
            8,
            RoutePolicy::PowerOfTwo,
        );
        // Conservation first: routing changes placement, not work.
        assert_eq!(routed.stats.activations, pinned.stats.activations);
        assert_eq!(routed.stats.lookups, pinned.stats.lookups);
        assert_eq!(
            routed.shard_loads.iter().sum::<u64>(),
            pinned.shard_loads.iter().sum::<u64>()
        );
        // The point of the tentpole: the hot shard sheds load...
        assert!(
            routed.max_shard_load() < pinned.max_shard_load(),
            "routed max load {} !< pinned {}",
            routed.max_shard_load(),
            pinned.max_shard_load()
        );
        // ...without hurting simulated completion time.
        assert!(
            routed.stats.completion_ns <= pinned.stats.completion_ns * 1.0001,
            "routed completion {} worse than pinned {}",
            routed.stats.completion_ns,
            pinned.stats.completion_ns
        );
    }
}
