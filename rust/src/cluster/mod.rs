//! Sharded crossbar serving pool — the cluster layer above the single-pool
//! coordinator.
//!
//! The paper's pipeline (grouping → replication → dynamic-ADC scheduling)
//! manages *one* crossbar pool. A production recommender shards its
//! embedding tables across many such pools; this module adds that layer:
//!
//! ```text
//!                    ClusterHandle (scatter-gather)
//!                   /       |        \
//!            shard 0     shard 1    shard N-1        (one thread each)
//!            Batcher     Batcher     Batcher         per-shard dynamic batching
//!            Scheduler   Scheduler   Scheduler       circuit cost per sub-batch
//!            ShardStore  ShardStore  ShardStore      owned tiles only
//! ```
//!
//! * **Partitioning** ([`partition`], [`hashring`]) — logical groups are
//!   assigned to shards either by consistent hashing of the group id
//!   (stateless; reuses [`crate::util::fxhash`]) or by a co-occurrence-
//!   locality-preserving balanced partition
//!   ([`crate::grouping::Mapping::partition_across`]) that keeps
//!   correlated crossbars on one shard so query fan-out stays low.
//! * **Shard executors** ([`shard`]) — one thread per shard owning its
//!   slice of the embedding table, serving sub-queries through its own
//!   dynamic batcher and accumulating its own [`crate::sched::ExecStats`].
//! * **Scatter-gather** ([`server`]) — the front-end splits a query's
//!   lookups by owning shard, dispatches all sub-queries, then merges the
//!   partial sums in shard order. The reduction is linear, so the split
//!   is exact; shard stats combine with
//!   [`crate::sched::ExecStats::merge_parallel`] (completion = max).
//! * **Reporting** ([`report`]) — per-shard load/stall and fan-out
//!   histograms for the `recross cluster` CLI mode.

pub mod hashring;
pub mod partition;
pub mod report;
pub mod server;
pub mod shard;

pub use hashring::HashRing;
pub use partition::ShardPlan;
pub use server::{Cluster, ClusterConfig, ClusterHandle, ClusterResponse, PartitionPolicy};
pub use shard::{partition_store, PoolShared, ShardPartial, ShardStatus, ShardStore};

use crate::config::Config;
use crate::coordinator::{EmbeddingStore, OfflinePhase};
use crate::engine::Scheme;
use crate::sched::{ExecStats, Scheduler, Scratch};
use crate::workload::{Query, Trace};
use crate::Result;

/// Everything `Cluster::build` assembles: the running pool plus the
/// reference pieces a driver needs (the held-out eval trace and the full
/// table for single-pool verification).
pub struct ClusterBundle {
    pub cluster: Cluster,
    /// Full (unsharded) store — the verification reference; shards hold
    /// their own partitioned copies.
    pub store: EmbeddingStore,
    /// Held-out evaluation trace from the offline phase.
    pub eval: Trace,
}

impl Cluster {
    /// Offline phase → partition → spawn, per the config. The engine's
    /// mapping/replication/cost model are shared read-only by all shards;
    /// the store is laid out once and partitioned tile-by-tile.
    pub fn build(
        cfg: &Config,
        scheme: Scheme,
        scale: f64,
        ccfg: &ClusterConfig,
    ) -> Result<ClusterBundle> {
        anyhow::ensure!(ccfg.shards > 0, "need at least one shard");
        anyhow::ensure!(ccfg.vnodes > 0, "need at least one virtual node per shard");
        // The shard executors run the in-crossbar MAC dataflow
        // (Scheduler::run_batch); nMARS's lookup + serial-aggregation
        // dataflow has no sharded implementation, so refuse it rather
        // than report MAC costs under an nMARS label.
        anyhow::ensure!(
            scheme != Scheme::Nmars,
            "the sharded pool serves the MAC dataflow; scheme {:?} is not supported here",
            scheme.name()
        );
        let offline = OfflinePhase::run(cfg, scheme, scale)?;
        let mapping = offline.engine.mapping();
        let plan = match ccfg.policy {
            PartitionPolicy::Hash => ShardPlan::by_hash(
                mapping.num_groups(),
                &HashRing::new(ccfg.shards as u32, ccfg.vnodes),
            ),
            PartitionPolicy::Locality => {
                ShardPlan::by_locality(mapping, &offline.history, ccfg.shards, ccfg.slack)
            }
        };
        let store = EmbeddingStore::random(
            mapping,
            cfg.hardware.embedding_dim,
            cfg.hardware.xbar_rows,
            cfg.workload.seed,
        );
        let shared = PoolShared::from_engine(&offline.engine);
        let cluster = Cluster::spawn_from_parts(shared, &store, plan, ccfg.batch.clone())?;
        Ok(ClusterBundle {
            cluster,
            store,
            eval: offline.eval,
        })
    }
}

/// Deterministic thread-free simulation of the sharded pool over a trace
/// (what `benches/fig12_sharding.rs` sweeps).
///
/// Each batch is split into per-shard sub-batches; shards execute
/// concurrently, so the batch's stats merge with
/// [`ExecStats::merge_parallel`] and successive batches accumulate.
/// The front-end's cross-shard merge is modelled as `fanout - 1` vector
/// adds per query, serialised on the slowest query's critical path.
///
/// Note: `queries` in the result counts *sub-queries* (one per
/// shard a query touched), mirroring what the live shard executors see.
pub fn simulate_sharded(
    shared: &PoolShared,
    plan: &ShardPlan,
    trace: &Trace,
    batch_size: usize,
) -> ExecStats {
    assert_eq!(
        plan.num_groups(),
        shared.mapping.num_groups(),
        "plan covers {} groups, mapping has {}",
        plan.num_groups(),
        shared.mapping.num_groups()
    );
    let sched = Scheduler::new(
        &shared.mapping,
        &shared.replication,
        &shared.model,
        shared.dynamic_switch,
    );
    let (add_ns, add_pj) = shared.model.vector_add();
    let mut scratch = Scratch::default();
    let mut total = ExecStats::default();
    let mut sub: Vec<Vec<Query>> = vec![Vec::new(); plan.shards];
    for batch in trace.batches(batch_size) {
        for v in &mut sub {
            v.clear();
        }
        let mut max_fanout = 0usize;
        for q in batch {
            // Same routing rule as the live pool (ShardPlan::split_items).
            let split = plan.split_items(&shared.mapping, &q.items);
            let fanout = split.iter().filter(|v| !v.is_empty()).count();
            max_fanout = max_fanout.max(fanout);
            if fanout > 1 {
                // Front-end merge energy: one vector add per extra shard.
                total.energy_pj += (fanout - 1) as f64 * add_pj;
            }
            for (s, items) in split.into_iter().enumerate() {
                if !items.is_empty() {
                    sub[s].push(Query::new(items));
                }
            }
        }
        let mut batch_stats = ExecStats::default();
        for queries in &sub {
            if queries.is_empty() {
                continue;
            }
            batch_stats.merge_parallel(&sched.run_batch(queries, &mut scratch));
        }
        // Cross-shard merge latency on the critical path.
        if max_fanout > 1 {
            batch_stats.completion_ns += (max_fanout - 1) as f64 * add_ns;
        }
        total.accumulate(&batch_stats);
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::Replication;
    use crate::grouping::Mapping;
    use crate::xbar::{CircuitParams, CrossbarModel};

    fn shared_2x2() -> PoolShared {
        let mapping = Mapping::from_groups(vec![vec![0, 1], vec![2, 3]], 2, 4);
        let replication = Replication::identity(2, 4);
        let model = CrossbarModel::new(
            &crate::config::HardwareConfig::default(),
            &CircuitParams::default(),
        );
        PoolShared {
            mapping,
            replication,
            model,
            dynamic_switch: true,
        }
    }

    #[test]
    fn one_shard_simulation_matches_single_pool() {
        let shared = shared_2x2();
        let trace = Trace {
            num_embeddings: 4,
            queries: vec![
                Query::new(vec![0, 1]),
                Query::new(vec![0, 2]),
                Query::new(vec![3]),
            ],
        };
        let plan = ShardPlan::from_assignment(vec![0, 0], 1);
        let sharded = simulate_sharded(&shared, &plan, &trace, 2);
        let sched = Scheduler::new(
            &shared.mapping,
            &shared.replication,
            &shared.model,
            shared.dynamic_switch,
        );
        let mut scratch = Scratch::default();
        let mut reference = ExecStats::default();
        for batch in trace.batches(2) {
            reference.accumulate(&sched.run_batch(batch, &mut scratch));
        }
        assert_eq!(sharded, reference);
    }

    #[test]
    fn sharded_split_conserves_work() {
        let shared = shared_2x2();
        let trace = Trace {
            num_embeddings: 4,
            queries: vec![Query::new(vec![0, 2]), Query::new(vec![1, 3])],
        };
        let plan = ShardPlan::from_assignment(vec![0, 1], 2);
        let stats = simulate_sharded(&shared, &plan, &trace, 2);
        // Every (query, group) pair still produces exactly one activation.
        assert_eq!(stats.activations, 4);
        assert_eq!(stats.lookups, 4);
        // Each query split into 2 sub-queries.
        assert_eq!(stats.queries, 4);
    }
}
