//! Measurement utilities: streaming summaries, histograms, percentiles,
//! and the log-log power-law fit used to verify the paper's Fig. 2 / Fig. 4
//! distribution claims.

mod histogram;
mod powerlaw;

pub use histogram::Histogram;
pub use powerlaw::{fit_power_law, PowerLawFit};

/// Streaming summary statistics (Welford's online algorithm).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            ..Default::default()
        }
    }

    /// Add one observation.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Merge another summary (for parallel collection).
    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + d * d * self.n as f64 * other.n as f64 / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
    pub fn sum(&self) -> f64 {
        self.mean() * self.n as f64
    }
}

/// Exact percentile of a sample (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Gini coefficient of a non-negative sample — used to quantify how skewed
/// crossbar load is before/after allocation (Fig. 5's "evenness" claim).
pub fn gini(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len() as f64;
    let sum: f64 = v.iter().sum();
    if sum == 0.0 {
        return 0.0;
    }
    let weighted: f64 = v
        .iter()
        .enumerate()
        .map(|(i, &x)| (i as f64 + 1.0) * x)
        .sum();
    (2.0 * weighted) / (n * sum) - (n + 1.0) / n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.sum() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn summary_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Summary::new();
        for &x in &xs {
            all.add(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for &x in &xs[..37] {
            a.add(x);
        }
        for &x in &xs[37..] {
            b.add(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn empty_summary_is_zeroes() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.stddev(), 0.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
        assert!((percentile(&xs, 99.0) - 99.0).abs() <= 1.0);
    }

    #[test]
    fn gini_extremes() {
        // perfectly equal -> 0
        assert!(gini(&[5.0, 5.0, 5.0, 5.0]).abs() < 1e-9);
        // maximally unequal -> close to 1 for large n
        let mut xs = vec![0.0; 999];
        xs.push(1000.0);
        assert!(gini(&xs) > 0.99);
        // empty / all-zero
        assert_eq!(gini(&[]), 0.0);
        assert_eq!(gini(&[0.0, 0.0]), 0.0);
    }
}
