//! Power-law (Zipf) fit on rank–frequency data.
//!
//! The paper's Fig. 2 and Fig. 4 claim that embedding access frequency and
//! co-occurrence degree follow a power law, and that the power law
//! *persists after grouping*. We verify this quantitatively with a
//! least-squares fit of `log(freq) = c - alpha * log(rank)` plus the R² of
//! the fit, rather than eyeballing a plot.

/// Result of a rank–frequency power-law fit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerLawFit {
    /// Fitted exponent α (positive for a decaying power law).
    pub alpha: f64,
    /// Intercept `c` of the log-log linear model.
    pub intercept: f64,
    /// Coefficient of determination of the log-log fit.
    pub r_squared: f64,
    /// Number of (rank, freq) points used.
    pub points: usize,
}

impl PowerLawFit {
    /// A pragmatic "is this power-law-ish" predicate: decaying exponent and
    /// a good linear fit in log-log space.
    pub fn is_power_law(&self) -> bool {
        self.alpha > 0.3 && self.r_squared > 0.8 && self.points >= 10
    }
}

/// Fit a power law to frequency counts. `freqs` need not be sorted; zero
/// entries are ignored. Returns `None` when fewer than 3 positive points.
pub fn fit_power_law(freqs: &[u64]) -> Option<PowerLawFit> {
    let mut v: Vec<u64> = freqs.iter().copied().filter(|&f| f > 0).collect();
    if v.len() < 3 {
        return None;
    }
    v.sort_unstable_by(|a, b| b.cmp(a));
    let pts: Vec<(f64, f64)> = v
        .iter()
        .enumerate()
        .map(|(i, &f)| (((i + 1) as f64).ln(), (f as f64).ln()))
        .collect();
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return None;
    }
    let slope = (n * sxy - sx * sy) / denom;
    let intercept = (sy - slope * sx) / n;
    // R^2
    let mean_y = sy / n;
    let ss_tot: f64 = pts.iter().map(|p| (p.1 - mean_y) * (p.1 - mean_y)).sum();
    let ss_res: f64 = pts
        .iter()
        .map(|p| {
            let pred = intercept + slope * p.0;
            (p.1 - pred) * (p.1 - pred)
        })
        .sum();
    let r_squared = if ss_tot < 1e-12 {
        // A constant distribution (all frequencies equal) is perfectly
        // explained by a zero-slope line but is NOT a power law.
        0.0
    } else {
        1.0 - ss_res / ss_tot
    };
    Some(PowerLawFit {
        alpha: -slope,
        intercept,
        r_squared,
        points: pts.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{Rng, Zipf};

    #[test]
    fn recovers_zipf_exponent() {
        let z = Zipf::new(5_000, 1.1);
        let mut r = Rng::new(1);
        let mut counts = vec![0u64; 5_000];
        for _ in 0..1_000_000 {
            counts[z.sample(&mut r)] += 1;
        }
        let fit = fit_power_law(&counts).unwrap();
        assert!(fit.is_power_law(), "fit: {fit:?}");
        // Sampled tail flattens the global fit a bit; accept a window.
        assert!(
            (0.7..=1.3).contains(&fit.alpha),
            "alpha {} not near 1.1",
            fit.alpha
        );
    }

    #[test]
    fn uniform_is_not_power_law() {
        let counts = vec![100u64; 1000];
        let fit = fit_power_law(&counts).unwrap();
        assert!(!fit.is_power_law(), "uniform misdetected: {fit:?}");
    }

    #[test]
    fn too_few_points_none() {
        assert!(fit_power_law(&[5, 3]).is_none());
        assert!(fit_power_law(&[0, 0, 0]).is_none());
    }

    #[test]
    fn zeros_ignored() {
        let mut counts = vec![0u64; 100];
        for (i, c) in counts.iter_mut().enumerate().take(50) {
            *c = (1000 / (i + 1)) as u64;
        }
        let fit = fit_power_law(&counts).unwrap();
        assert_eq!(fit.points, 50);
        assert!(fit.alpha > 0.5);
    }
}
