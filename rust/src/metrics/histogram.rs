//! Integer-valued histogram with sparse storage and ASCII rendering.
//!
//! Used by the report harness to print the paper's distribution figures
//! (Fig. 2 co-occurrence degree, Fig. 4 post-grouping access counts,
//! Fig. 5 copy counts, Fig. 6 single-access shares) directly in the
//! terminal.

use std::collections::BTreeMap;

/// A histogram over `u64` values.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    counts: BTreeMap<u64, u64>,
    total: u64,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one observation of `value`.
    pub fn add(&mut self, value: u64) {
        *self.counts.entry(value).or_insert(0) += 1;
        self.total += 1;
    }

    /// Record `n` observations of `value`.
    pub fn add_n(&mut self, value: u64, n: u64) {
        if n > 0 {
            *self.counts.entry(value).or_insert(0) += n;
            self.total += n;
        }
    }

    /// Total number of observations.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Count at an exact value.
    pub fn count(&self, value: u64) -> u64 {
        self.counts.get(&value).copied().unwrap_or(0)
    }

    /// Number of distinct observed values.
    pub fn distinct(&self) -> usize {
        self.counts.len()
    }

    /// Largest observed value.
    pub fn max_value(&self) -> u64 {
        self.counts.keys().next_back().copied().unwrap_or(0)
    }

    /// Mean of the distribution.
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let s: f64 = self.counts.iter().map(|(&v, &c)| v as f64 * c as f64).sum();
        s / self.total as f64
    }

    /// Fraction of observations with `value <= x`.
    pub fn cdf(&self, x: u64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let below: u64 = self
            .counts
            .range(..=x)
            .map(|(_, &c)| c)
            .sum();
        below as f64 / self.total as f64
    }

    /// Nearest-rank percentile: the smallest observed value `v` such that
    /// at least `p`% of observations are `<= v`. `p` is in `[0, 100]`;
    /// `p = 0` returns the minimum, `p = 100` the maximum. Returns 0 for
    /// an empty histogram. Monotone non-decreasing in `p` by construction
    /// (a cumulative scan of the sorted counts).
    pub fn percentile(&self, p: f64) -> u64 {
        assert!((0.0..=100.0).contains(&p), "percentile {p} out of range");
        if self.total == 0 {
            return 0;
        }
        // Nearest-rank: ceil(p/100 * N), clamped to [1, N].
        let rank = ((p / 100.0) * self.total as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.total);
        let mut seen = 0u64;
        for (&v, &c) in &self.counts {
            seen += c;
            if seen >= rank {
                return v;
            }
        }
        self.max_value()
    }

    /// Batch percentile lookup (one cumulative scan per call site's loop
    /// is fine at histogram sizes; this is a convenience wrapper).
    pub fn percentiles(&self, ps: &[f64]) -> Vec<u64> {
        ps.iter().map(|&p| self.percentile(p)).collect()
    }

    /// One-line quantile summary (p50/p90/p99/p99.9) for reports.
    pub fn quantile_summary(&self) -> String {
        format!(
            "p50 {}  p90 {}  p99 {}  p99.9 {}",
            self.percentile(50.0),
            self.percentile(90.0),
            self.percentile(99.0),
            self.percentile(99.9)
        )
    }

    /// Iterate `(value, count)` in ascending value order.
    pub fn iter(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.counts.iter().map(|(&v, &c)| (v, c))
    }

    /// `(value, count)` pairs sorted by descending count — the "rank vs
    /// frequency" view needed for power-law plots.
    pub fn by_rank(&self) -> Vec<(u64, u64)> {
        let mut v: Vec<(u64, u64)> = self.iter().collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v
    }

    /// Render an ASCII bar chart with up to `max_buckets` log-spaced buckets
    /// and bars scaled to `width` characters.
    pub fn render(&self, max_buckets: usize, width: usize) -> String {
        if self.total == 0 {
            return "(empty histogram)\n".to_string();
        }
        let max_v = self.max_value().max(1);
        // Log-spaced bucket edges over [0, max_v].
        let mut edges: Vec<u64> = vec![0, 1];
        let mut e = 1u64;
        while e < max_v && edges.len() < max_buckets {
            e = (e as f64 * (max_v as f64).powf(1.0 / (max_buckets as f64 - 1.0)))
                .ceil()
                .max(e as f64 + 1.0) as u64;
            edges.push(e.min(max_v));
        }
        edges.dedup();
        let mut buckets: Vec<(String, u64)> = Vec::new();
        for w in edges.windows(2) {
            let (lo, hi) = (w[0], w[1]);
            let c: u64 = self
                .counts
                .range((
                    std::ops::Bound::Excluded(lo.wrapping_sub(1).min(lo)),
                    std::ops::Bound::Included(hi),
                ))
                .filter(|(&v, _)| v > lo || (lo == 0 && v == 0))
                .map(|(_, &c)| c)
                .sum();
            let label = if hi - lo <= 1 {
                format!("{hi}")
            } else {
                format!("{}-{}", lo + 1, hi)
            };
            buckets.push((label, c));
        }
        // include zero bucket if present
        if self.count(0) > 0 {
            buckets.insert(0, ("0".to_string(), self.count(0)));
        }
        let peak = buckets.iter().map(|b| b.1).max().unwrap_or(1).max(1);
        let mut out = String::new();
        for (label, c) in buckets {
            let bar = "#".repeat(((c as f64 / peak as f64) * width as f64).round() as usize);
            out.push_str(&format!("{label:>12} | {bar:<width$} {c}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_count() {
        let mut h = Histogram::new();
        h.add(3);
        h.add(3);
        h.add(7);
        h.add_n(1, 5);
        assert_eq!(h.total(), 8);
        assert_eq!(h.count(3), 2);
        assert_eq!(h.count(1), 5);
        assert_eq!(h.count(99), 0);
        assert_eq!(h.distinct(), 3);
        assert_eq!(h.max_value(), 7);
    }

    #[test]
    fn mean_and_cdf() {
        let mut h = Histogram::new();
        h.add_n(1, 2);
        h.add_n(2, 2);
        assert!((h.mean() - 1.5).abs() < 1e-12);
        assert!((h.cdf(1) - 0.5).abs() < 1e-12);
        assert!((h.cdf(2) - 1.0).abs() < 1e-12);
        assert_eq!(h.cdf(0), 0.0);
    }

    #[test]
    fn by_rank_sorted_descending() {
        let mut h = Histogram::new();
        h.add_n(10, 1);
        h.add_n(20, 5);
        h.add_n(30, 3);
        let r = h.by_rank();
        assert_eq!(r[0], (20, 5));
        assert_eq!(r[1], (30, 3));
        assert_eq!(r[2], (10, 1));
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut h = Histogram::new();
        for v in 1..=100 {
            h.add(v);
        }
        assert_eq!(h.percentile(0.0), 1);
        assert_eq!(h.percentile(1.0), 1);
        assert_eq!(h.percentile(50.0), 50);
        assert_eq!(h.percentile(99.0), 99);
        assert_eq!(h.percentile(100.0), 100);
    }

    #[test]
    fn percentile_monotone_in_quantile() {
        // Heavily skewed distribution: percentiles must never decrease
        // as the quantile grows.
        let mut h = Histogram::new();
        h.add_n(1, 900);
        h.add_n(10, 90);
        h.add_n(1_000, 9);
        h.add_n(100_000, 1);
        let ps: Vec<f64> = (0..=1000).map(|i| i as f64 / 10.0).collect();
        let qs = h.percentiles(&ps);
        for w in qs.windows(2) {
            assert!(w[1] >= w[0], "percentile not monotone: {w:?}");
        }
        assert_eq!(h.percentile(100.0), 100_000);
        assert_eq!(h.percentile(50.0), 1);
    }

    #[test]
    fn percentile_empty_and_single() {
        assert_eq!(Histogram::new().percentile(99.0), 0);
        let mut h = Histogram::new();
        h.add(7);
        for p in [0.0, 50.0, 99.9, 100.0] {
            assert_eq!(h.percentile(p), 7);
        }
        assert!(h.quantile_summary().contains("p99"));
    }

    #[test]
    fn render_nonempty() {
        let mut h = Histogram::new();
        for v in 1..100 {
            h.add_n(v, 100 / v);
        }
        let s = h.render(8, 40);
        assert!(s.lines().count() >= 2);
        assert!(s.contains('#'));
    }

    #[test]
    fn render_empty() {
        assert!(Histogram::new().render(8, 40).contains("empty"));
    }

    #[test]
    fn add_n_zero_is_noop() {
        let mut h = Histogram::new();
        h.add_n(5, 0);
        assert_eq!(h.total(), 0);
        assert_eq!(h.distinct(), 0);
    }
}
