//! Crossbar activation cost model.
//!
//! One *activation* = applying a multi-hot wordline vector to a crossbar
//! and converting all bitline currents. The cost decomposes as
//!
//! ```text
//! latency = array settle (MAC or read path)
//!         + popcount (when dynamic switching is enabled)
//!         + serialized ADC conversions (adc_share columns per ADC)
//!         + result transfer over the global bus
//! energy  = wordline drivers (per activated row)
//!         + cell evaluation (rows x cols)
//!         + ADC conversions (per column, mode-dependent comparator count)
//!         + shift/add accumulation + popcount + bus
//! ```
//!
//! The same model also prices the nMARS baseline's primitive — a full-row
//! *lookup* (single-row activation converted at full resolution, result
//! shipped out for external aggregation).

use super::adc::{AdcMode, DynamicSwitchAdc, Popcount};
use super::params::CircuitParams;
use crate::config::HardwareConfig;

/// Cost of one crossbar activation. `latency_ns` covers the in-crossbar
/// path (array + popcount + conversions); the result transfer is scheduled
/// separately on the shared global bus ([`CrossbarModel::bus_flit_ns`]) —
/// the scheduler owns that contention.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActivationCost {
    pub latency_ns: f64,
    pub energy_pj: f64,
    pub mode: AdcMode,
    /// Bus flits the result occupies on a global-bus channel.
    pub bus_flits: u32,
}

/// Circuit-level crossbar model shared by all engines.
#[derive(Debug, Clone)]
pub struct CrossbarModel {
    hw: HardwareConfig,
    p: CircuitParams,
    adc: DynamicSwitchAdc,
    popcount: Popcount,
    /// Result bits produced by one activation (cols x adc_bits).
    result_bits: usize,
}

impl CrossbarModel {
    /// Construct after validating the hardware config, returning a typed
    /// error instead of panicking. Validation here is load-bearing for
    /// the scheduler: its slot tables are sized by `bus_channels()` and
    /// `least_loaded`-style selection over an empty table would index out
    /// of bounds, so a config with `bus_channels == 0` (or any other
    /// [`HardwareConfig::validate`] violation) must be rejected before a
    /// model can exist. Prefer this over [`CrossbarModel::new`] anywhere
    /// the config comes from user input (CLI flags, TOML overrides).
    pub fn try_new(hw: &HardwareConfig, p: &CircuitParams) -> crate::Result<Self> {
        hw.validate()?;
        Ok(Self {
            adc: DynamicSwitchAdc::new(hw.adc_bits, hw.read_mode_bits, p),
            popcount: Popcount::new(p),
            result_bits: hw.xbar_cols * hw.adc_bits as usize,
            hw: hw.clone(),
            p: p.clone(),
        })
    }

    /// As [`CrossbarModel::try_new`], panicking on an invalid config.
    /// Convenient for paper-default and test configs that are known-good.
    pub fn new(hw: &HardwareConfig, p: &CircuitParams) -> Self {
        Self::try_new(hw, p).expect("invalid hardware config")
    }

    pub fn hw(&self) -> &HardwareConfig {
        &self.hw
    }

    pub fn params(&self) -> &CircuitParams {
        &self.p
    }

    /// Serial ADC rounds to convert all columns (`adc_share` columns per
    /// ADC, converted back-to-back).
    fn conversion_rounds(&self) -> usize {
        self.hw.adc_share
    }

    /// Bus flits for one activation's result.
    fn result_flits(&self, bits: usize) -> usize {
        bits.div_ceil(self.hw.bus_width_bits)
    }

    /// Cost of activating `rows` wordlines of one crossbar.
    ///
    /// `dynamic_switch` selects the paper's ADC policy: when enabled and
    /// `rows <= 1`, the conversion runs in gated read mode.
    pub fn activation(&self, rows: usize, dynamic_switch: bool) -> ActivationCost {
        assert!(rows <= self.hw.xbar_rows, "{rows} rows > crossbar height");
        let cols = self.hw.xbar_cols;
        let popcount = rows.min(u32::MAX as usize) as u32;

        // ADC conversion: one per column; mode per the dynamic switch.
        let conv = if dynamic_switch {
            self.adc.convert(popcount)
        } else {
            self.adc.convert(2) // force MAC mode
        };

        // --- latency (in-crossbar; bus transfer scheduled separately) ---
        let array_ns = match conv.mode {
            AdcMode::Mac => self.p.array_mac_ns,
            AdcMode::Read => self.p.array_read_ns,
        };
        let mut latency =
            array_ns + self.conversion_rounds() as f64 * self.p.adc_conv_ns;
        if dynamic_switch {
            latency += self.popcount.latency_ns;
        }

        // --- energy ---
        let mut energy = rows as f64 * self.p.wordline_energy_pj
            + (rows * cols) as f64 * self.p.cell_energy_pj
            + cols as f64 * conv.energy_pj
            + cols as f64 * self.p.shift_add_pj
            + self.result_bits as f64 * self.p.bus_pj_per_bit;
        if dynamic_switch {
            energy += self.popcount.energy_pj;
        }

        ActivationCost {
            latency_ns: latency,
            energy_pj: energy,
            mode: conv.mode,
            bus_flits: self.result_flits(self.result_bits) as u32,
        }
    }

    /// nMARS primitive: read one embedding row out of the crossbar (the
    /// fabric performs lookups in-memory but aggregates *outside*, so
    /// every looked-up row is a separate sense + transfer). The row's
    /// stored bits are sensed through the cheap low-resolution path
    /// (energy like read mode), but the conversion schedule — and hence
    /// latency — matches the shared flash ADC pipeline.
    pub fn row_lookup(&self) -> ActivationCost {
        let cols = self.hw.xbar_cols;
        let conv = self.adc.convert(1); // single-row sense, gated ladder
        let latency =
            self.p.array_read_ns + self.conversion_rounds() as f64 * self.p.adc_conv_ns;
        let energy = self.p.wordline_energy_pj
            + cols as f64 * self.p.cell_energy_pj
            + cols as f64 * conv.energy_pj
            + self.result_bits as f64 * self.p.bus_pj_per_bit;
        ActivationCost {
            latency_ns: latency,
            energy_pj: energy,
            mode: AdcMode::Read,
            bus_flits: self.result_flits(self.result_bits) as u32,
        }
    }

    /// Global-bus time for one flit (the scheduler's shared-channel cost).
    pub fn bus_flit_ns(&self) -> f64 {
        self.p.bus_flit_ns
    }

    /// Number of independent global-bus channels.
    pub fn bus_channels(&self) -> usize {
        self.hw.bus_channels
    }

    /// One-time programming cost of writing `num_crossbars` full crossbars
    /// (the offline phase's mapping load; duplication pays this for every
    /// extra replica — the other side of Fig. 10's area/benefit tradeoff).
    /// Returns `(ns, pJ)`: rows are programmed row-serially.
    pub fn programming_cost(&self, num_crossbars: usize) -> (f64, f64) {
        let cells = (self.hw.xbar_rows * self.hw.xbar_cols) as f64;
        let ns = num_crossbars as f64 * self.hw.xbar_rows as f64 * self.p.row_write_ns;
        let pj = num_crossbars as f64 * cells * self.p.cell_write_pj;
        (ns, pj)
    }

    /// External vector add (digital aggregation of two partial results) —
    /// used by nMARS per looked-up row and by every engine to merge
    /// partial sums across crossbars.
    pub fn vector_add(&self) -> (f64, f64) {
        (self.p.vec_add_ns, self.p.vec_add_pj)
    }

    /// Energy ratio between a MAC-mode and read-mode activation — the
    /// dynamic switch's per-activation saving (paper §IV-B).
    pub fn read_mode_saving_ratio(&self) -> f64 {
        let mac = self.activation(2, true).energy_pj;
        let read = self.activation(1, true).energy_pj;
        mac / read
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CrossbarModel {
        CrossbarModel::new(&HardwareConfig::default(), &CircuitParams::default())
    }

    #[test]
    fn read_mode_cheaper_and_not_slower() {
        let m = model();
        let mac = m.activation(8, true);
        let read = m.activation(1, true);
        assert_eq!(mac.mode, AdcMode::Mac);
        assert_eq!(read.mode, AdcMode::Read);
        assert!(read.energy_pj < mac.energy_pj / 2.0);
        // The dynamic switch keeps flash conversion speed: read mode is
        // slightly faster (array settle) but the same order of magnitude.
        assert!(read.latency_ns <= mac.latency_ns);
        assert!(read.latency_ns > mac.latency_ns * 0.5);
    }

    #[test]
    fn dynamic_switch_off_forces_mac() {
        let m = model();
        let a = m.activation(1, false);
        assert_eq!(a.mode, AdcMode::Mac);
        // and costs more than the switched version
        assert!(a.energy_pj > m.activation(1, true).energy_pj);
    }

    #[test]
    fn energy_monotonic_in_rows() {
        let m = model();
        let e1 = m.activation(2, true).energy_pj;
        let e2 = m.activation(32, true).energy_pj;
        let e3 = m.activation(64, true).energy_pj;
        assert!(e1 < e2 && e2 < e3);
    }

    #[test]
    fn mac_amortizes_versus_lookups() {
        // Core premise: one 8-row MAC activation is cheaper than 8
        // separate row lookups + 7 adds (the nMARS dataflow), and needs
        // 8x fewer bus transfers.
        let m = model();
        let mac = m.activation(8, true);
        let lk = m.row_lookup();
        let (add_ns, add_pj) = m.vector_add();
        let nmars_e = 8.0 * lk.energy_pj + 7.0 * add_pj;
        let nmars_t = lk.latency_ns + 7.0 * add_ns; // reads pipelined, adds serial
        assert!(mac.energy_pj < nmars_e / 1.5, "{} vs {}", mac.energy_pj, nmars_e);
        assert!(mac.latency_ns < nmars_t * 3.0); // latency same ballpark
        assert_eq!(mac.bus_flits, lk.bus_flits); // 1 transfer vs 8
    }

    #[test]
    #[should_panic(expected = "rows")]
    fn too_many_rows_panics() {
        model().activation(65, true);
    }

    #[test]
    fn zero_bus_channels_rejected_with_typed_error() {
        // Regression: a channel-less config must die at model
        // construction with a typed error, not reach the scheduler —
        // whose bus table selection would otherwise scan (or tree-query)
        // an empty slot table and index out of bounds.
        let hw = HardwareConfig {
            bus_channels: 0,
            ..Default::default()
        };
        let err = CrossbarModel::try_new(&hw, &CircuitParams::default())
            .expect_err("bus_channels == 0 must be rejected");
        assert!(
            err.to_string().contains("bus channel"),
            "unhelpful error: {err}"
        );
    }

    #[test]
    fn try_new_accepts_valid_configs() {
        let m = CrossbarModel::try_new(&HardwareConfig::default(), &CircuitParams::default())
            .expect("paper default must validate");
        assert_eq!(m.bus_channels(), 16);
    }

    #[test]
    fn programming_cost_scales_linearly() {
        let m = model();
        let (ns1, pj1) = m.programming_cost(1);
        let (ns10, pj10) = m.programming_cost(10);
        assert!(ns1 > 0.0 && pj1 > 0.0);
        assert!((ns10 - 10.0 * ns1).abs() < 1e-6);
        assert!((pj10 - 10.0 * pj1).abs() < 1e-6);
        // one 64x64 crossbar = 4096 cells * 2 pJ
        assert!((pj1 - 4096.0 * 2.0).abs() < 1e-6);
    }

    #[test]
    fn programming_amortizes_over_batches() {
        // The one-time write cost of a 10%-duplication plan must be small
        // versus the steady-state energy of even a handful of batches —
        // the justification for ignoring it in Fig. 8's steady state.
        let m = model();
        let (_, write_pj) = m.programming_cost(100); // 100 extra crossbars
        let act = m.activation(4, true);
        let per_batch = 2000.0 * act.energy_pj; // ~2k activations/batch
        assert!(write_pj < 10.0 * per_batch, "write {write_pj} vs batch {per_batch}");
    }

    #[test]
    fn saving_ratio_substantial() {
        // 6-bit vs 3-bit comparator ladders: the per-activation ADC energy
        // drops by ~63/7 in read mode; diluted by fixed costs the overall
        // activation saving should still be >2x.
        assert!(model().read_mode_saving_ratio() > 2.0);
    }
}
