//! ADC models: conventional flash ADC and the paper's dynamic-switch ADC
//! (§III-D, Fig. 7).
//!
//! A flash ADC resolves `n` bits with `2^n - 1` parallel comparators —
//! fastest architecture, exponentially power-hungry in resolution. The
//! dynamic-switch design adds a MAC-enable signal derived from a popcount
//! over the activated wordlines:
//!
//! * popcount > 1 → **MAC mode**: all `2^n - 1` comparators fire
//!   (full-resolution conversion of the analog bitline sum);
//! * popcount == 1 → **read mode**: the stored value is a single cell's
//!   level, so only the low `read_mode_bits` of the ladder are needed —
//!   `2^r - 1` comparators fire and the rest are gated off.
//!
//! With the paper's 6-bit ADC and 3-bit read path this removes
//! `63 - 7 = 56` comparator firings per conversion, the "100% per-ADC
//! energy reduction for MAC operations when a single embedding is
//! required" §IV-B describes (the MAC-specific energy vanishes; only the
//! cheap read path remains).

use super::params::CircuitParams;

/// Which conversion path an activation used.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AdcMode {
    /// Full-resolution MAC conversion.
    Mac,
    /// Gated single-row read conversion.
    Read,
}

impl AdcMode {
    /// Stable lowercase label (metric names, reports).
    pub fn name(self) -> &'static str {
        match self {
            Self::Mac => "mac",
            Self::Read => "read",
        }
    }
}

/// Cost of one ADC conversion.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdcCost {
    pub latency_ns: f64,
    pub energy_pj: f64,
    pub mode: AdcMode,
}

/// Conventional flash ADC: always full resolution.
#[derive(Debug, Clone)]
pub struct FlashAdc {
    bits: u32,
    conv_ns: f64,
    comparator_pj: f64,
    encoder_pj: f64,
}

impl FlashAdc {
    pub fn new(bits: u32, p: &CircuitParams) -> Self {
        assert!(bits >= 1 && bits <= 12, "flash ADC beyond 12 bits is impractical");
        Self {
            bits,
            conv_ns: p.adc_conv_ns,
            comparator_pj: p.comparator_energy_pj,
            encoder_pj: p.adc_encoder_pj,
        }
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Comparators in the ladder (`2^bits - 1`).
    pub fn comparators(&self) -> u64 {
        (1u64 << self.bits) - 1
    }

    /// Cost of one conversion (always MAC-mode full resolution).
    pub fn convert(&self) -> AdcCost {
        AdcCost {
            latency_ns: self.conv_ns,
            energy_pj: self.comparators() as f64 * self.comparator_pj + self.encoder_pj,
            mode: AdcMode::Mac,
        }
    }
}

/// The paper's dynamic-switch ADC: a flash ladder whose upper comparators
/// are gated by the popcount-derived MAC-enable signal.
#[derive(Debug, Clone)]
pub struct DynamicSwitchAdc {
    full: FlashAdc,
    read_bits: u32,
}

impl DynamicSwitchAdc {
    pub fn new(bits: u32, read_bits: u32, p: &CircuitParams) -> Self {
        assert!(read_bits >= 1 && read_bits <= bits);
        Self {
            full: FlashAdc::new(bits, p),
            read_bits,
        }
    }

    pub fn bits(&self) -> u32 {
        self.full.bits()
    }

    pub fn read_bits(&self) -> u32 {
        self.read_bits
    }

    /// Comparators active in read mode (`2^read_bits - 1`).
    pub fn read_comparators(&self) -> u64 {
        (1u64 << self.read_bits) - 1
    }

    /// Cost of one conversion given the wordline popcount.
    pub fn convert(&self, popcount: u32) -> AdcCost {
        if popcount <= 1 {
            AdcCost {
                latency_ns: self.full.conv_ns,
                energy_pj: self.read_comparators() as f64 * self.full.comparator_pj
                    + self.full.encoder_pj,
                mode: AdcMode::Read,
            }
        } else {
            self.full.convert()
        }
    }

    /// Energy saved versus an always-MAC flash conversion, in pJ.
    pub fn read_mode_saving_pj(&self) -> f64 {
        (self.full.comparators() - self.read_comparators()) as f64 * self.full.comparator_pj
    }
}

/// Popcount circuit (the mode selector of Fig. 7): counts activated
/// wordlines. Cost constants from the paper's reference [32].
#[derive(Debug, Clone, Copy)]
pub struct Popcount {
    pub latency_ns: f64,
    pub energy_pj: f64,
}

impl Popcount {
    pub fn new(p: &CircuitParams) -> Self {
        Self {
            latency_ns: p.popcount_ns,
            energy_pj: p.popcount_pj,
        }
    }

    /// Count set bits in a wordline mask (the hardware does this in one
    /// adder-tree pass; the simulator just popcounts the words).
    pub fn count(mask: &[u64]) -> u32 {
        mask.iter().map(|w| w.count_ones()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> CircuitParams {
        CircuitParams::default()
    }

    #[test]
    fn comparator_counts() {
        let adc = FlashAdc::new(6, &p());
        assert_eq!(adc.comparators(), 63);
        let ds = DynamicSwitchAdc::new(6, 3, &p());
        assert_eq!(ds.read_comparators(), 7);
    }

    #[test]
    fn read_mode_much_cheaper() {
        let ds = DynamicSwitchAdc::new(6, 3, &p());
        let mac = ds.convert(5);
        let read = ds.convert(1);
        assert_eq!(mac.mode, AdcMode::Mac);
        assert_eq!(read.mode, AdcMode::Read);
        // 63 vs 7 comparators: ~8x cheaper ignoring the fixed encoder.
        assert!(read.energy_pj < mac.energy_pj / 3.0);
        // Same conversion latency — the paper keeps flash speed.
        assert_eq!(read.latency_ns, mac.latency_ns);
    }

    #[test]
    fn popcount_zero_also_read_mode() {
        // A degenerate empty activation must not pay MAC energy.
        let ds = DynamicSwitchAdc::new(6, 3, &p());
        assert_eq!(ds.convert(0).mode, AdcMode::Read);
    }

    #[test]
    fn saving_matches_comparator_delta() {
        let ds = DynamicSwitchAdc::new(6, 3, &p());
        let expect = (63 - 7) as f64 * p().comparator_energy_pj;
        assert!((ds.read_mode_saving_pj() - expect).abs() < 1e-12);
        let mac = ds.convert(2).energy_pj;
        let read = ds.convert(1).energy_pj;
        assert!((mac - read - ds.read_mode_saving_pj()).abs() < 1e-12);
    }

    #[test]
    fn mode_names_are_stable() {
        assert_eq!(AdcMode::Mac.name(), "mac");
        assert_eq!(AdcMode::Read.name(), "read");
    }

    #[test]
    fn popcount_counts_bits() {
        assert_eq!(Popcount::count(&[0]), 0);
        assert_eq!(Popcount::count(&[0b1011]), 3);
        assert_eq!(Popcount::count(&[u64::MAX, 1]), 65);
    }

    #[test]
    #[should_panic]
    fn read_bits_cannot_exceed_bits() {
        DynamicSwitchAdc::new(4, 5, &p());
    }
}
