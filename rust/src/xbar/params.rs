//! Circuit-level latency / energy constants (22 nm class).
//!
//! The paper evaluates with NeuroSim on 22 nm technology; NeuroSim itself
//! is not available here, so this table is assembled from the published
//! component-level numbers the IMC literature (and NeuroSim's own device
//! files) converge on:
//!
//! * ReRAM crossbar read path (wordline driver + array settle):
//!   ISAAC (Shafiee et al., ISCA'16) budgets 100 ns for a full
//!   128x128 crossbar read cycle; a 64x64 array settles in ~50 ns.
//! * Flash ADC: conversion is one comparator stage + encoder, ~1 ns at
//!   GHz-class clocking (Razavi, "The Flash ADC"); energy scales with the
//!   comparator count `2^bits - 1` at ~50 fJ per comparison at 22 nm.
//! * 8:1 column multiplexing (ISAAC-style ADC sharing) serialises 64
//!   bitlines onto 8 ADCs.
//! * DAC / wordline driver: 1-bit drivers, ~0.5 pJ per activated row.
//! * Popcount over 64 wordline bits: adder-tree, 1 cycle, ~0.3 pJ
//!   (Choi et al., Electronics'21 — the paper's popcount reference [32]).
//! * Digital adder for nMARS-style external aggregation: 16-lane 8-bit
//!   vector add, ~1 cycle, ~2 pJ.
//! * DRAM access energy for the CPU comparison: ~20 pJ/bit DDR4 array +
//!   I/O (Fig. 11's CPU baseline fetches each embedding over DDR).
//!
//! All figures are *internally consistent* estimates — the paper's results
//! are ratios between schemes sharing this same table, which is what the
//! reproduction must preserve (DESIGN.md §Substitutions).

/// Latency/energy constants for the in-memory datapath.
#[derive(Debug, Clone, PartialEq)]
pub struct CircuitParams {
    // --- crossbar array ---
    /// Array settle + sense time for a MAC evaluation (ns).
    pub array_mac_ns: f64,
    /// Array settle + sense for a single-row read (ns). Slightly faster
    /// (one wordline, no multi-row summation settling) but the same order:
    /// the dynamic-switch ADC keeps *flash conversion speed* in read mode
    /// — the paper's §III-D saves energy, not latency.
    pub array_read_ns: f64,
    /// Energy per activated cell during evaluation (pJ).
    pub cell_energy_pj: f64,
    /// Wordline driver (1-bit DAC) energy per activated row (pJ).
    pub wordline_energy_pj: f64,

    // --- ADC ---
    /// One flash-ADC conversion (ns).
    pub adc_conv_ns: f64,
    /// Energy per comparator per conversion (pJ).
    pub comparator_energy_pj: f64,
    /// Encoder + latch overhead per conversion (pJ).
    pub adc_encoder_pj: f64,

    // --- digital periphery ---
    /// Popcount over the wordline vector: latency (ns).
    pub popcount_ns: f64,
    /// Popcount energy (pJ).
    pub popcount_pj: f64,
    /// Shift-and-add / accumulation per ADC sample (pJ).
    pub shift_add_pj: f64,
    /// Vector adder for external (nMARS-style) aggregation: latency (ns).
    pub vec_add_ns: f64,
    /// Vector adder energy (pJ).
    pub vec_add_pj: f64,

    // --- interconnect ---
    /// Bus transfer per bit (pJ).
    pub bus_pj_per_bit: f64,
    /// Bus latency per `bus_width` flit (ns).
    pub bus_flit_ns: f64,

    // --- programming (one-time, offline phase) ---
    /// SET/RESET energy per ReRAM cell write (pJ). ~2 pJ/cell at 22 nm
    /// (Wong et al., metal-oxide RRAM survey) — duplicated crossbars pay
    /// this once when the mapping is loaded.
    pub cell_write_pj: f64,
    /// Write pulse time per row program operation (ns).
    pub row_write_ns: f64,
}

impl Default for CircuitParams {
    fn default() -> Self {
        Self {
            array_mac_ns: 50.0,
            array_read_ns: 45.0,
            cell_energy_pj: 0.005,
            wordline_energy_pj: 0.5,
            adc_conv_ns: 1.0,
            comparator_energy_pj: 0.05,
            adc_encoder_pj: 0.2,
            popcount_ns: 1.0,
            popcount_pj: 0.3,
            shift_add_pj: 0.1,
            vec_add_ns: 1.0,
            vec_add_pj: 2.0,
            bus_pj_per_bit: 0.05,
            bus_flit_ns: 2.0,
            cell_write_pj: 2.0,
            row_write_ns: 100.0,
        }
    }
}

/// Host-side (von Neumann) energy constants for the Fig. 11 comparison.
#[derive(Debug, Clone, PartialEq)]
pub struct HostParams {
    /// DRAM energy per bit moved (array + I/O), pJ.
    pub dram_pj_per_bit: f64,
    /// CPU core energy per embedding-vector accumulate (pJ): load/add
    /// pipeline at a few hundred pJ per 16-lane vector op including cache
    /// traffic (derived from MERCI's measured package power per lookup).
    pub cpu_accum_pj: f64,
    /// PCIe transfer energy per bit for the CPU→GPU path (pJ).
    pub pcie_pj_per_bit: f64,
    /// GPU core energy per embedding-vector accumulate (pJ). The GPU sums
    /// faster but burns static + HBM power; per useful lookup it is *less*
    /// efficient for this memory-bound kernel (the paper measures the
    /// CPU-GPU platform ~3x worse than CPU-only).
    pub gpu_accum_pj: f64,
    /// Host DRAM random-access latency per lookup (ns) — CPU model.
    pub dram_access_ns: f64,
}

impl Default for HostParams {
    fn default() -> Self {
        Self {
            dram_pj_per_bit: 20.0,
            cpu_accum_pj: 600.0,
            pcie_pj_per_bit: 60.0,
            gpu_accum_pj: 400.0,
            dram_access_ns: 80.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_positive_and_ordered() {
        let p = CircuitParams::default();
        assert!(p.array_read_ns < p.array_mac_ns, "read must be faster than MAC");
        for v in [
            p.cell_write_pj,
            p.row_write_ns,
            p.array_mac_ns,
            p.array_read_ns,
            p.cell_energy_pj,
            p.wordline_energy_pj,
            p.adc_conv_ns,
            p.comparator_energy_pj,
            p.adc_encoder_pj,
            p.popcount_ns,
            p.popcount_pj,
            p.shift_add_pj,
            p.vec_add_ns,
            p.vec_add_pj,
            p.bus_pj_per_bit,
            p.bus_flit_ns,
        ] {
            assert!(v > 0.0);
        }
    }

    #[test]
    fn host_dram_dominates_crossbar_cell() {
        // The premise of in-memory computing: moving a bit over DDR costs
        // orders of magnitude more than evaluating a cell in place.
        let c = CircuitParams::default();
        let h = HostParams::default();
        assert!(h.dram_pj_per_bit > 100.0 * c.cell_energy_pj);
    }
}
