//! ReRAM crossbar circuit model (NeuroSim-style, 22 nm class).
//!
//! * [`params`] — component latency/energy constants with provenance notes.
//! * [`adc`] — flash ADC, the paper's dynamic-switch ADC (§III-D), and the
//!   popcount mode selector.
//! * [`array`] — per-activation cost model combining array, ADC, popcount,
//!   accumulation, and bus, shared by every engine so that scheme
//!   comparisons are apples-to-apples.

pub mod adc;
pub mod array;
pub mod params;

pub use adc::{AdcCost, AdcMode, DynamicSwitchAdc, FlashAdc, Popcount};
pub use array::{ActivationCost, CrossbarModel};
pub use params::{CircuitParams, HostParams};
