//! Seeded arrival processes: *when* queries hit the front-end.
//!
//! Every process emits a monotone non-decreasing stream of absolute
//! arrival timestamps (ns on the simulated clock), fully determined by
//! `(kind, rate, seed)` — the open-loop driver is bit-reproducible
//! end-to-end. Three synthetic shapes plus trace replay:
//!
//! * **Poisson** — exponential inter-arrival gaps at a constant rate; the
//!   memoryless baseline every queueing result is stated against.
//! * **Bursty** — a two-state Markov-modulated Poisson process (MMPP
//!   on/off): bursts at `1/duty` times the nominal rate separated by
//!   silent gaps, with exponentially distributed sojourns in both states.
//!   Long-run mean rate equals the nominal rate; short-run load is what
//!   stresses the batcher and the replica router.
//! * **Diurnal** — a sinusoidally modulated Poisson process (a compressed
//!   day): `λ(t) = rate · (1 + depth · sin(2πt/period))`, sampled exactly
//!   by Lewis–Shedler thinning against `λmax`.
//! * **Replay** — timestamps recorded in a v2 trace
//!   ([`crate::workload::TimedTrace`]).

use crate::util::Rng;
use crate::workload::{TimedTrace, Trace};

/// Nanoseconds per second (the rate unit conversion).
const NS_PER_SEC: f64 = 1e9;

/// Bursty (MMPP on/off) defaults: fraction of time spent in the ON state…
const BURSTY_DUTY: f64 = 0.25;
/// …and mean ON-state duration, in units of `1/rate` (nominal mean gaps).
const BURSTY_MEAN_ON_GAPS: f64 = 20.0;

/// Diurnal defaults: modulation depth and period in nominal mean gaps.
const DIURNAL_DEPTH: f64 = 0.8;
const DIURNAL_PERIOD_GAPS: f64 = 2_000.0;

/// Which synthetic arrival process to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    Poisson,
    Bursty,
    Diurnal,
}

impl ArrivalKind {
    /// Parse a CLI name (`poisson | bursty | diurnal`).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "poisson" => Some(Self::Poisson),
            "bursty" => Some(Self::Bursty),
            "diurnal" => Some(Self::Diurnal),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Poisson => "poisson",
            Self::Bursty => "bursty",
            Self::Diurnal => "diurnal",
        }
    }
}

#[derive(Debug)]
enum State {
    Poisson {
        /// Mean inter-arrival gap, ns.
        gap_ns: f64,
    },
    Bursty {
        /// Mean gap *within* a burst (`duty · nominal_gap`), ns.
        gap_on_ns: f64,
        mean_on_ns: f64,
        mean_off_ns: f64,
        /// Absolute end of the current ON period, ns.
        on_until_ns: f64,
    },
    Diurnal {
        /// Nominal rate, arrivals per ns.
        rate_ns: f64,
        depth: f64,
        period_ns: f64,
    },
    Replay {
        ts: Vec<u64>,
        next: usize,
    },
}

/// A stream of arrival timestamps. Construct via [`Arrivals::poisson`],
/// [`Arrivals::bursty`], [`Arrivals::diurnal`], [`Arrivals::replay`], or
/// [`Arrivals::from_kind`]; pull with [`Arrivals::next_ns`].
#[derive(Debug)]
pub struct Arrivals {
    state: State,
    rng: Rng,
    /// Current absolute time, ns (f64: gaps compose exactly the same way
    /// on every platform, and 2^53 ns ≈ 104 days dwarfs any drive).
    t_ns: f64,
}

impl Arrivals {
    /// Constant-rate Poisson arrivals at `rate_qps` queries/second.
    pub fn poisson(rate_qps: f64, seed: u64) -> Self {
        assert!(rate_qps > 0.0, "arrival rate must be positive");
        Self {
            state: State::Poisson {
                gap_ns: NS_PER_SEC / rate_qps,
            },
            rng: Rng::new(seed ^ 0xA881_7A15_0000_0001),
            t_ns: 0.0,
        }
    }

    /// MMPP on/off bursts with long-run mean rate `rate_qps`.
    pub fn bursty(rate_qps: f64, seed: u64) -> Self {
        assert!(rate_qps > 0.0, "arrival rate must be positive");
        let nominal_gap = NS_PER_SEC / rate_qps;
        let mean_on_ns = BURSTY_MEAN_ON_GAPS * nominal_gap;
        // duty = on / (on + off)  =>  off = on · (1 - duty) / duty.
        let mean_off_ns = mean_on_ns * (1.0 - BURSTY_DUTY) / BURSTY_DUTY;
        let mut rng = Rng::new(seed ^ 0xA881_7A15_0000_0002);
        let first_on = exp_sample(&mut rng, mean_on_ns);
        Self {
            state: State::Bursty {
                gap_on_ns: nominal_gap * BURSTY_DUTY,
                mean_on_ns,
                mean_off_ns,
                on_until_ns: first_on,
            },
            rng,
            t_ns: 0.0,
        }
    }

    /// Sinusoidally rate-modulated Poisson arrivals (compressed diurnal
    /// cycle) with time-average rate `rate_qps`.
    pub fn diurnal(rate_qps: f64, seed: u64) -> Self {
        assert!(rate_qps > 0.0, "arrival rate must be positive");
        let nominal_gap = NS_PER_SEC / rate_qps;
        Self {
            state: State::Diurnal {
                rate_ns: rate_qps / NS_PER_SEC,
                depth: DIURNAL_DEPTH,
                period_ns: DIURNAL_PERIOD_GAPS * nominal_gap,
            },
            rng: Rng::new(seed ^ 0xA881_7A15_0000_0003),
            t_ns: 0.0,
        }
    }

    /// Replay recorded timestamps (must be non-decreasing; validated).
    pub fn replay(ts: Vec<u64>) -> Self {
        assert!(
            ts.windows(2).all(|w| w[0] <= w[1]),
            "replay timestamps must be non-decreasing"
        );
        Self {
            state: State::Replay { ts, next: 0 },
            rng: Rng::new(0),
            t_ns: 0.0,
        }
    }

    /// Dispatch on a parsed [`ArrivalKind`].
    pub fn from_kind(kind: ArrivalKind, rate_qps: f64, seed: u64) -> Self {
        match kind {
            ArrivalKind::Poisson => Self::poisson(rate_qps, seed),
            ArrivalKind::Bursty => Self::bursty(rate_qps, seed),
            ArrivalKind::Diurnal => Self::diurnal(rate_qps, seed),
        }
    }

    /// Next absolute arrival timestamp, ns. Monotone non-decreasing.
    ///
    /// Panics when a replay stream is exhausted — the caller decides how
    /// many arrivals it needs ([`Arrivals::take`]) and a replay source by
    /// construction carries exactly its trace's query count.
    pub fn next_ns(&mut self) -> u64 {
        match &mut self.state {
            State::Poisson { gap_ns } => {
                self.t_ns += exp_sample(&mut self.rng, *gap_ns);
                self.t_ns as u64
            }
            State::Bursty {
                gap_on_ns,
                mean_on_ns,
                mean_off_ns,
                on_until_ns,
            } => {
                loop {
                    let gap = exp_sample(&mut self.rng, *gap_on_ns);
                    if self.t_ns + gap <= *on_until_ns {
                        self.t_ns += gap;
                        break;
                    }
                    // The burst ends before this arrival lands: jump over
                    // the OFF sojourn into the next ON period and redraw
                    // (exact by memorylessness of the exponential).
                    let off = exp_sample(&mut self.rng, *mean_off_ns);
                    self.t_ns = *on_until_ns + off;
                    *on_until_ns = self.t_ns + exp_sample(&mut self.rng, *mean_on_ns);
                }
                self.t_ns as u64
            }
            State::Diurnal {
                rate_ns,
                depth,
                period_ns,
            } => {
                // Lewis–Shedler thinning against λmax = rate · (1+depth).
                let lam_max = *rate_ns * (1.0 + *depth);
                loop {
                    self.t_ns += exp_sample(&mut self.rng, 1.0 / lam_max);
                    let phase = std::f64::consts::TAU * self.t_ns / *period_ns;
                    let lam = *rate_ns * (1.0 + *depth * phase.sin());
                    if self.rng.next_f64() * lam_max < lam {
                        break;
                    }
                }
                self.t_ns as u64
            }
            State::Replay { ts, next } => {
                let t = *ts
                    .get(*next)
                    .unwrap_or_else(|| panic!("replay exhausted after {} arrivals", ts.len()));
                *next += 1;
                t
            }
        }
    }

    /// The next `n` arrival timestamps.
    pub fn take(&mut self, n: usize) -> Vec<u64> {
        (0..n).map(|_| self.next_ns()).collect()
    }

    /// Stamp a trace's queries with this process's arrivals, producing a
    /// replayable v2 timed trace.
    pub fn stamp(&mut self, trace: Trace) -> TimedTrace {
        let ts = self.take(trace.queries.len());
        TimedTrace::new(trace, ts).expect("arrival streams are monotone by construction")
    }
}

/// Exponential sample with the given mean (inverse-CDF; `1-U ∈ (0, 1]`
/// keeps `ln` finite).
fn exp_sample(rng: &mut Rng, mean: f64) -> f64 {
    -(1.0 - rng.next_f64()).ln() * mean
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mean_gap(ts: &[u64]) -> f64 {
        assert!(ts.len() > 1);
        (ts[ts.len() - 1] - ts[0]) as f64 / (ts.len() - 1) as f64
    }

    #[test]
    fn processes_are_seed_deterministic() {
        for kind in [ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Diurnal] {
            let a = Arrivals::from_kind(kind, 50_000.0, 7).take(500);
            let b = Arrivals::from_kind(kind, 50_000.0, 7).take(500);
            assert_eq!(a, b, "{kind:?} not reproducible");
            let c = Arrivals::from_kind(kind, 50_000.0, 8).take(500);
            assert_ne!(a, c, "{kind:?} ignores its seed");
        }
    }

    #[test]
    fn arrivals_are_monotone() {
        for kind in [ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Diurnal] {
            let ts = Arrivals::from_kind(kind, 1_000_000.0, 3).take(5_000);
            assert!(
                ts.windows(2).all(|w| w[0] <= w[1]),
                "{kind:?} emitted regressing timestamps"
            );
        }
    }

    #[test]
    fn poisson_mean_rate_matches() {
        let rate = 1_000_000.0; // 1M qps -> 1000 ns mean gap
        let ts = Arrivals::poisson(rate, 42).take(20_000);
        let gap = mean_gap(&ts);
        assert!((gap - 1_000.0).abs() < 50.0, "mean gap {gap} ns");
    }

    #[test]
    fn bursty_long_run_rate_matches_but_bursts_run_hotter() {
        let rate = 1_000_000.0;
        let ts = Arrivals::bursty(rate, 42).take(50_000);
        let gap = mean_gap(&ts);
        // Long-run mean within 25% of nominal (burst-level variance is
        // the point of the process, so the tolerance is loose).
        assert!(
            (gap - 1_000.0).abs() < 250.0,
            "bursty long-run mean gap {gap} ns"
        );
        // Within-burst gaps are duty-fraction of nominal: the median gap
        // must be far below the nominal mean gap.
        let mut gaps: Vec<u64> = ts.windows(2).map(|w| w[1] - w[0]).collect();
        gaps.sort_unstable();
        let median = gaps[gaps.len() / 2] as f64;
        assert!(median < 500.0, "median intra-burst gap {median} ns");
    }

    #[test]
    fn diurnal_time_average_rate_matches() {
        let rate = 1_000_000.0;
        // ~10 full cycles (period = 2000 gaps) so the sine averages out.
        let ts = Arrivals::diurnal(rate, 42).take(20_000);
        let gap = mean_gap(&ts);
        assert!((gap - 1_000.0).abs() < 150.0, "diurnal mean gap {gap} ns");
    }

    #[test]
    fn diurnal_rate_actually_oscillates() {
        let ts = Arrivals::diurnal(1_000_000.0, 9).take(20_000);
        // Count arrivals in consecutive windows of a half-period each:
        // peak-to-trough ratio must show the modulation.
        let half_period = 1_000_000.0; // 1000 gaps of 1000 ns
        let mut counts = vec![0usize; 1 + (ts[ts.len() - 1] as f64 / half_period) as usize];
        for &t in &ts {
            counts[(t as f64 / half_period) as usize] += 1;
        }
        let full: Vec<usize> = counts[..counts.len().saturating_sub(1)].to_vec();
        let max = full.iter().copied().max().unwrap();
        let min = full.iter().copied().min().unwrap().max(1);
        assert!(
            max as f64 / min as f64 > 1.5,
            "no visible modulation: windows {full:?}"
        );
    }

    #[test]
    fn replay_returns_exactly_the_recorded_stream() {
        let mut a = Arrivals::replay(vec![5, 5, 9, 30]);
        assert_eq!(a.take(4), vec![5, 5, 9, 30]);
    }

    #[test]
    #[should_panic(expected = "replay exhausted")]
    fn replay_panics_past_the_end() {
        Arrivals::replay(vec![1]).take(2);
    }

    #[test]
    fn stamp_produces_a_valid_timed_trace() {
        use crate::workload::Query;
        let trace = Trace {
            num_embeddings: 10,
            queries: vec![Query::new(vec![1]), Query::new(vec![2, 3])],
        };
        let tt = Arrivals::poisson(10_000.0, 1).stamp(trace.clone());
        assert_eq!(tt.trace, trace);
        let ts = tt.arrivals_ns.unwrap();
        assert_eq!(ts.len(), 2);
        assert!(ts[0] <= ts[1]);
    }

    #[test]
    fn kind_names_roundtrip() {
        for kind in [ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Diurnal] {
            assert_eq!(ArrivalKind::by_name(kind.name()), Some(kind));
        }
        assert_eq!(ArrivalKind::by_name("closed"), None);
    }
}
