//! Open-loop driver: timestamped queries in, tail-latency telemetry out.
//!
//! The closed-loop harnesses (`Engine::run_trace`, `simulate_sharded`)
//! feed pre-formed batches and report batch completion time — there is no
//! notion of *offered load* or *queueing delay*. This driver runs the
//! serving stack on **simulated time**: queries arrive at the timestamps
//! an arrival process ([`super::arrival`]) produced, pass through the
//! exact dynamic-batching policy the live executors run
//! ([`crate::coordinator::Batcher`], now clock-injected), and are served
//! by the existing discrete-event crossbar model
//! ([`crate::sched::Scheduler::run_batch_timed`]). No threads, no wall
//! clock: the same `(queries, arrivals, policy)` input always produces
//! bit-identical output. Because every batch funnels through
//! `run_batch_timed`, the driver inherits the scheduler's data-oriented
//! hot path (O(log C) slot selection, sort-free run decomposition — see
//! [`crate::sched::minslot`]) for free, and inherits it *safely*: the
//! optimized scheduler is differentially fuzzed to be bit-identical to
//! `sched::reference`, so every sojourn percentile this driver reports
//! is unchanged by the rewrite.
//!
//! Sojourn decomposition for a query arriving at `t_a`, whose batch
//! closes at `t_c` and whose in-batch service finishes `f` ns after the
//! batch starts:
//!
//! ```text
//! sojourn = (t_c - t_a)              queue wait + batch formation wait
//!         + f                        scheduled crossbar service
//!         [+ (fanout-1) · add_ns]    cross-shard merge (sharded backend)
//! ```
//!
//! `t_c` already folds in executor backpressure: a batch cannot close
//! while the (serial) executor is still serving the previous one, so at
//! offered loads past capacity the queue — and the tail — grow without
//! bound. That hockey-stick is exactly what `benches/fig13_latency.rs`
//! sweeps.

use crate::cluster::{PoolShared, ReplicaPlan, ShardPlan};
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::metrics::percentile;
use crate::sched::{ExecStats, Scheduler, Scratch};
use crate::workload::Query;

/// Per-executor (shard) load telemetry.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardLoad {
    pub shard: u32,
    /// Sub-queries this executor served (= queries, single pool).
    pub sub_queries: u64,
    /// Batches its batcher closed.
    pub batches: u64,
    /// Simulated time spent serving, ns.
    pub busy_ns: f64,
    /// Peak queued sub-queries observed at a batch close.
    pub max_backlog: usize,
    /// Time-averaged sub-queries in system (Little's law:
    /// Σ sub-sojourn / horizon).
    pub mean_backlog: f64,
    /// `(close time ns, queued depth)` at every batch close — the
    /// backlog-over-time series the report can render.
    pub backlog_samples: Vec<(f64, usize)>,
}

impl ShardLoad {
    /// Fraction of the horizon this executor spent serving.
    pub fn utilization(&self, horizon_ns: f64) -> f64 {
        if horizon_ns <= 0.0 {
            0.0
        } else {
            (self.busy_ns / horizon_ns).min(1.0)
        }
    }
}

/// Result of one open-loop drive.
#[derive(Debug, Clone, PartialEq)]
pub struct OpenLoopReport {
    /// Per-query sojourn time (finish − arrival), ns, in arrival order.
    pub sojourn_ns: Vec<f64>,
    /// Service-side accounting: counters sum over everything served;
    /// `completion_ns` accumulates per executor and maxes across shards
    /// (the executors run concurrently).
    pub stats: ExecStats,
    /// Last query finish time, ns (the simulated makespan).
    pub horizon_ns: f64,
    /// Offered load implied by the arrival stamps, queries/second.
    pub offered_qps: f64,
    /// One entry per executor (a single entry for the single pool).
    pub shards: Vec<ShardLoad>,
}

impl OpenLoopReport {
    pub fn queries(&self) -> usize {
        self.sojourn_ns.len()
    }

    /// Sojourn percentile, ns (nearest-rank over the exact sample).
    pub fn percentile_ns(&self, p: f64) -> f64 {
        percentile(&self.sojourn_ns, p)
    }

    pub fn mean_sojourn_ns(&self) -> f64 {
        if self.sojourn_ns.is_empty() {
            0.0
        } else {
            self.sojourn_ns.iter().sum::<f64>() / self.sojourn_ns.len() as f64
        }
    }

    /// Achieved throughput over the makespan, queries/second.
    pub fn throughput_qps(&self) -> f64 {
        if self.horizon_ns <= 0.0 {
            0.0
        } else {
            self.queries() as f64 / (self.horizon_ns / 1e9)
        }
    }

    /// Time-averaged queries in system (Little's law: L = Σ sojourn / T).
    pub fn mean_queue_depth(&self) -> f64 {
        if self.horizon_ns <= 0.0 {
            0.0
        } else {
            self.sojourn_ns.iter().sum::<f64>() / self.horizon_ns
        }
    }

    /// Total batches closed across executors.
    pub fn batches(&self) -> u64 {
        self.shards.iter().map(|s| s.batches).sum()
    }
}

/// Open-loop drive of the **single-pool** path: one serial executor, one
/// dynamic batcher, the scheduler's discrete-event service model.
///
/// `arrivals_ns` must be non-decreasing and aligned with `queries`.
pub fn drive_single(
    sched: &Scheduler<'_>,
    queries: &[Query],
    arrivals_ns: &[u64],
    policy: &BatchPolicy,
) -> OpenLoopReport {
    check_arrivals(queries.len(), arrivals_ns);
    let n = queries.len();
    // Empty queries are dropped at the front door (nothing to serve),
    // exactly as the sharded backend's scatter drops them — the two
    // backends must account identical traffic identically.
    let arr: Vec<(u64, usize)> = arrivals_ns
        .iter()
        .copied()
        .zip(0..n)
        .filter(|&(_, i)| !queries[i].is_empty())
        .collect();
    let mut finish = vec![0.0f64; n];
    let mut stats = ExecStats::default();
    let mut scratch = Scratch::default();
    let mut rel = Vec::new();
    let qstats = simulate_executor(&arr, policy, &mut finish, |batch| {
        let qs: Vec<Query> = batch.iter().map(|&i| queries[i].clone()).collect();
        let s = sched.run_batch_timed(&qs, &mut scratch, &mut rel);
        stats.accumulate(&s);
        (s.completion_ns, rel.clone())
    });
    let sojourn: Vec<f64> = finish
        .iter()
        .zip(arrivals_ns)
        .zip(queries)
        .map(|((&f, &a), q)| if q.is_empty() { 0.0 } else { f - a as f64 })
        .collect();
    let horizon = qstats.horizon_ns;
    let shard = ShardLoad {
        shard: 0,
        sub_queries: arr.len() as u64,
        batches: qstats.batches,
        busy_ns: qstats.busy_ns,
        max_backlog: qstats.max_backlog,
        mean_backlog: if horizon > 0.0 {
            sojourn.iter().sum::<f64>() / horizon
        } else {
            0.0
        },
        backlog_samples: qstats.backlog_samples,
    };
    OpenLoopReport {
        offered_qps: offered_qps(arrivals_ns),
        sojourn_ns: sojourn,
        stats,
        horizon_ns: horizon,
        shards: vec![shard],
    }
}

/// Open-loop drive of the **sharded** path: the front-end splits every
/// query by owning shard the instant it arrives (ownership-pinned
/// routing, the deterministic twin of `cluster::server`'s scatter), each
/// shard runs its own dynamic batcher + serial executor over its local
/// replica table, and a query completes when its last sub-query finishes
/// plus one merge add per extra shard touched.
pub fn drive_sharded(
    shared: &PoolShared,
    plan: &ShardPlan,
    queries: &[Query],
    arrivals_ns: &[u64],
    policy: &BatchPolicy,
) -> OpenLoopReport {
    check_arrivals(queries.len(), arrivals_ns);
    assert_eq!(
        plan.num_groups(),
        shared.mapping.num_groups(),
        "plan covers {} groups, mapping has {}",
        plan.num_groups(),
        shared.mapping.num_groups()
    );
    let n = queries.len();
    let shards = plan.shards;
    let replicas = ReplicaPlan::pinned(plan, &shared.replication);
    let locals: Vec<crate::allocation::Replication> = (0..shards)
        .map(|s| replicas.local_replication(s as u32, shared.replication.batch_size))
        .collect();
    let scheds: Vec<Scheduler<'_>> = locals
        .iter()
        .map(|r| Scheduler::new(&shared.mapping, r, &shared.model, shared.dynamic_switch))
        .collect();
    let (add_ns, add_pj) = shared.model.vector_add();

    // Scatter: split every query at its arrival instant.
    let mut sub_queries: Vec<Vec<Query>> = vec![Vec::new(); shards];
    let mut sub_arrivals: Vec<Vec<(u64, usize)>> = vec![Vec::new(); shards];
    // (shard, local index) of every sub-query of each query.
    let mut subs_of_query: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n];
    for (qi, q) in queries.iter().enumerate() {
        for (s, items) in plan.split_items(&shared.mapping, &q.items).into_iter().enumerate() {
            if items.is_empty() {
                continue;
            }
            let li = sub_queries[s].len();
            sub_arrivals[s].push((arrivals_ns[qi], li));
            sub_queries[s].push(Query::new(items));
            subs_of_query[qi].push((s, li));
        }
    }

    // Each shard's executor runs independently: its batch boundaries
    // depend only on its own arrivals and its own backlog.
    let mut stats = ExecStats::default();
    let mut shard_loads = Vec::with_capacity(shards);
    let mut sub_finish: Vec<Vec<f64>> = Vec::with_capacity(shards);
    let mut horizon = 0.0f64;
    let mut scratch = Scratch::default();
    let mut rel = Vec::new();
    for s in 0..shards {
        let mut finish = vec![0.0f64; sub_queries[s].len()];
        let mut local_stats = ExecStats::default();
        let qstats = simulate_executor(&sub_arrivals[s], policy, &mut finish, |batch| {
            let qs: Vec<Query> = batch.iter().map(|&i| sub_queries[s][i].clone()).collect();
            let st = scheds[s].run_batch_timed(&qs, &mut scratch, &mut rel);
            local_stats.accumulate(&st);
            (st.completion_ns, rel.clone())
        });
        stats.merge_parallel(&local_stats);
        let sub_sojourn: f64 = sub_arrivals[s]
            .iter()
            .map(|&(a, li)| finish[li] - a as f64)
            .sum();
        shard_loads.push(ShardLoad {
            shard: s as u32,
            sub_queries: sub_queries[s].len() as u64,
            batches: qstats.batches,
            busy_ns: qstats.busy_ns,
            max_backlog: qstats.max_backlog,
            // Little's-law numerator for now; divided by the global
            // horizon once the gather pass below has fixed it.
            mean_backlog: sub_sojourn,
            backlog_samples: qstats.backlog_samples,
        });
        horizon = horizon.max(qstats.horizon_ns);
        sub_finish.push(finish);
    }

    // Gather: a query completes when its last sub-query does, plus one
    // front-end merge add per extra shard (same accounting as
    // `cluster::simulate_with_replicas`).
    let mut sojourn = Vec::with_capacity(n);
    for (qi, subs) in subs_of_query.iter().enumerate() {
        let a = arrivals_ns[qi] as f64;
        if subs.is_empty() {
            sojourn.push(0.0); // empty query: nothing to serve
            continue;
        }
        let mut f = subs
            .iter()
            .map(|&(s, li)| sub_finish[s][li])
            .fold(f64::NEG_INFINITY, f64::max);
        if subs.len() > 1 {
            f += (subs.len() - 1) as f64 * add_ns;
            stats.energy_pj += (subs.len() - 1) as f64 * add_pj;
        }
        horizon = horizon.max(f);
        sojourn.push(f - a);
    }
    for sl in &mut shard_loads {
        sl.mean_backlog = if horizon > 0.0 {
            sl.mean_backlog / horizon
        } else {
            0.0
        };
    }
    OpenLoopReport {
        offered_qps: offered_qps(arrivals_ns),
        sojourn_ns: sojourn,
        stats,
        horizon_ns: horizon,
        shards: shard_loads,
    }
}

fn check_arrivals(num_queries: usize, arrivals_ns: &[u64]) {
    assert_eq!(
        num_queries,
        arrivals_ns.len(),
        "one arrival timestamp per query"
    );
    assert!(
        arrivals_ns.windows(2).all(|w| w[0] <= w[1]),
        "arrival timestamps must be non-decreasing"
    );
}

fn offered_qps(arrivals_ns: &[u64]) -> f64 {
    match (arrivals_ns.first(), arrivals_ns.last()) {
        (Some(&a), Some(&b)) if b > a => {
            (arrivals_ns.len() - 1) as f64 / ((b - a) as f64 / 1e9)
        }
        // Two or more arrivals at one instant is an unbounded burst, not
        // idle traffic.
        (Some(_), Some(_)) if arrivals_ns.len() > 1 => f64::INFINITY,
        _ => 0.0,
    }
}

/// Aggregates one simulated executor produced.
struct ExecutorStats {
    batches: u64,
    busy_ns: f64,
    max_backlog: usize,
    /// Final executor-free time = last batch's finish.
    horizon_ns: f64,
    backlog_samples: Vec<(f64, usize)>,
}

/// Simulate one serial executor behind a dynamic batcher on virtual time.
///
/// `arrivals` is `(arrival_ns, item id)`, sorted by time. `serve` is
/// called once per closed batch with the item ids, and returns the
/// batch's total service duration plus each item's finish offset within
/// it; absolute finish times land in `finish_ns[item]`.
///
/// Batch-close rule (identical to the live executor loop): a batch
/// closes at the earliest time `t ≥ executor_free` at which the queue
/// holds `max_batch` requests or the oldest has waited `max_wait` —
/// arrivals up to `t` join the queue first, exactly as the live loop's
/// channel drain would deliver them.
fn simulate_executor<F>(
    arrivals: &[(u64, usize)],
    policy: &BatchPolicy,
    finish_ns: &mut [f64],
    mut serve: F,
) -> ExecutorStats
where
    F: FnMut(&[usize]) -> (f64, Vec<f64>),
{
    debug_assert!(arrivals.windows(2).all(|w| w[0].0 <= w[1].0));
    let mut batcher: Batcher<usize> = Batcher::new(policy.clone());
    let mut i = 0usize;
    let mut free_at = 0.0f64;
    let mut out = ExecutorStats {
        batches: 0,
        busy_ns: 0.0,
        max_backlog: 0,
        horizon_ns: 0.0,
        backlog_samples: Vec::new(),
    };
    while i < arrivals.len() || !batcher.is_empty() {
        if batcher.is_empty() {
            // Idle executor: sleep until the next arrival.
            let (t, id) = arrivals[i];
            batcher.push_at(id, t);
            i += 1;
        }
        // Settle the close time: every arrival at or before the current
        // close candidate joins the queue first, which can only pull the
        // candidate earlier (size trigger) — never push it later.
        let t_close = loop {
            let ready = batcher.ready_at().expect("queue is non-empty") as f64;
            let cand = ready.max(free_at);
            match arrivals.get(i) {
                Some(&(t, id)) if (t as f64) <= cand => {
                    batcher.push_at(id, t);
                    i += 1;
                }
                _ => break cand,
            }
        };
        out.max_backlog = out.max_backlog.max(batcher.len());
        out.backlog_samples.push((t_close, batcher.len()));
        let batch = batcher.take_batch();
        let (busy, rel) = serve(&batch);
        assert_eq!(rel.len(), batch.len(), "one finish offset per batch item");
        for (&id, &r) in batch.iter().zip(&rel) {
            finish_ns[id] = t_close + r;
        }
        free_at = t_close + busy;
        out.busy_ns += busy;
        out.batches += 1;
        out.horizon_ns = out.horizon_ns.max(free_at);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::Replication;
    use crate::config::HardwareConfig;
    use crate::grouping::Mapping;
    use crate::loadgen::arrival::Arrivals;
    use crate::xbar::{CircuitParams, CrossbarModel};
    use std::time::Duration;

    fn model() -> CrossbarModel {
        CrossbarModel::new(&HardwareConfig::default(), &CircuitParams::default())
    }

    fn mapping_2x2() -> Mapping {
        Mapping::from_groups(vec![vec![0, 1], vec![2, 3]], 2, 4)
    }

    fn policy(max_batch: usize, wait_us: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(wait_us),
        }
    }

    fn some_queries(n: usize) -> Vec<Query> {
        (0..n)
            .map(|i| Query::new(vec![(i % 4) as u32, ((i + 1) % 4) as u32]))
            .collect()
    }

    #[test]
    fn zero_load_sojourn_is_pure_service_time() {
        // Arrivals light-years apart + max_wait 0: every query is served
        // alone the instant it arrives, so sojourn == single-query batch
        // service time and p99 collapses to pure service.
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let sched = Scheduler::new(&map, &rep, &m, true);
        let queries = some_queries(32);
        let arrivals: Vec<u64> = (0..32).map(|i| i as u64 * 1_000_000_000).collect();
        let report = drive_single(&sched, &queries, &arrivals, &policy(8, 0));
        let mut scratch = Scratch::default();
        // Tolerance: adding a ~1e10 ns arrival timestamp and subtracting
        // it back costs a few µ-ulps, never more than 1e-3 ns here.
        for (q, &soj) in queries.iter().zip(&report.sojourn_ns) {
            let solo = sched.run_batch(std::slice::from_ref(q), &mut scratch);
            assert!(
                (soj - solo.completion_ns).abs() < 1e-3,
                "sojourn {soj} != solo service {}",
                solo.completion_ns
            );
        }
        let max_solo = queries
            .iter()
            .map(|q| sched.run_batch(std::slice::from_ref(q), &mut scratch).completion_ns)
            .fold(0.0f64, f64::max);
        assert!((report.percentile_ns(99.0) - max_solo).abs() < 1e-3);
        // One query per batch, no backlog beyond 1.
        assert_eq!(report.batches(), 32);
        assert_eq!(report.shards[0].max_backlog, 1);
        assert!(report.mean_queue_depth() < 1e-3);
    }

    #[test]
    fn drive_is_deterministic() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let sched = Scheduler::new(&map, &rep, &m, true);
        let queries = some_queries(256);
        let arrivals = Arrivals::poisson(5_000_000.0, 11).take(256);
        let a = drive_single(&sched, &queries, &arrivals, &policy(16, 2_000));
        let b = drive_single(&sched, &queries, &arrivals, &policy(16, 2_000));
        assert_eq!(a, b, "open-loop drive must be bit-reproducible");
    }

    #[test]
    fn saturation_blows_up_the_tail() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let sched = Scheduler::new(&map, &rep, &m, true);
        let queries = some_queries(512);
        let slow = Arrivals::poisson(1_000.0, 3).take(512); // ~idle
        let fast = Arrivals::poisson(1e9, 3).take(512); // far past capacity
        // max_wait 0 so the idle baseline is pure service time, not
        // batch-formation wait.
        let p = policy(16, 0);
        let low = drive_single(&sched, &queries, &slow, &p);
        let high = drive_single(&sched, &queries, &fast, &p);
        assert!(
            high.percentile_ns(99.0) > 10.0 * low.percentile_ns(99.0),
            "p99 {} !>> {}",
            high.percentile_ns(99.0),
            low.percentile_ns(99.0)
        );
        assert!(high.mean_queue_depth() > low.mean_queue_depth());
        // Conservation either way.
        assert_eq!(low.stats.queries, 512);
        assert_eq!(high.stats.queries, 512);
    }

    #[test]
    fn percentiles_are_monotone_in_the_quantile() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let sched = Scheduler::new(&map, &rep, &m, true);
        let queries = some_queries(300);
        let arrivals = Arrivals::bursty(50_000_000.0, 5).take(300);
        let report = drive_single(&sched, &queries, &arrivals, &policy(8, 500));
        let ps = [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0];
        let qs: Vec<f64> = ps.iter().map(|&p| report.percentile_ns(p)).collect();
        for w in qs.windows(2) {
            assert!(w[1] >= w[0], "percentiles regress: {qs:?}");
        }
    }

    #[test]
    fn sharded_drive_conserves_work_and_merges_fanout() {
        let shared = PoolShared {
            mapping: mapping_2x2(),
            replication: Replication::identity(2, 4),
            model: model(),
            dynamic_switch: true,
        };
        let plan = ShardPlan::from_assignment(vec![0, 1], 2);
        // Every query touches both groups -> fanout 2 everywhere.
        let queries: Vec<Query> = (0..64).map(|_| Query::new(vec![0, 2])).collect();
        let arrivals = Arrivals::poisson(2_000_000.0, 7).take(64);
        let report = drive_sharded(&shared, &plan, &queries, &arrivals, &policy(8, 1_000));
        assert_eq!(report.queries(), 64);
        assert_eq!(report.shards.len(), 2);
        // Each query produced one sub-query per shard.
        assert_eq!(report.shards[0].sub_queries, 64);
        assert_eq!(report.shards[1].sub_queries, 64);
        assert_eq!(report.stats.lookups, 128);
        // Sojourn includes at least the single-item service + merge add.
        let (add_ns, _) = shared.model.vector_add();
        let act = shared.model.activation(1, true);
        let flit = shared.model.bus_flit_ns();
        let floor = act.latency_ns + flit + add_ns;
        assert!(report.sojourn_ns.iter().all(|&s| s >= floor - 1e-9));
        // Deterministic across runs.
        let again = drive_sharded(&shared, &plan, &queries, &arrivals, &policy(8, 1_000));
        assert_eq!(report, again);
    }

    #[test]
    fn sharding_relieves_an_overloaded_executor() {
        // max_batch = 1 makes the serial executor the bottleneck: the
        // single pool serves 256 one-query batches back-to-back, while
        // two shards serve two independent 128-query streams
        // concurrently — the saturated tail must drop by roughly half.
        let shared = PoolShared {
            mapping: mapping_2x2(),
            replication: Replication::identity(2, 4),
            model: model(),
            dynamic_switch: true,
        };
        let queries: Vec<Query> = (0..256)
            .map(|i| Query::new(vec![(i % 2) as u32 * 2])) // alternate groups
            .collect();
        let arrivals = Arrivals::poisson(2e8, 13).take(256);
        let p = policy(1, 0);
        let one = ShardPlan::from_assignment(vec![0, 0], 1);
        let two = ShardPlan::from_assignment(vec![0, 1], 2);
        let r1 = drive_sharded(&shared, &one, &queries, &arrivals, &p);
        let r2 = drive_sharded(&shared, &two, &queries, &arrivals, &p);
        assert!(
            r2.percentile_ns(99.0) < 0.75 * r1.percentile_ns(99.0),
            "2-shard p99 {} !< 0.75 x 1-shard {}",
            r2.percentile_ns(99.0),
            r1.percentile_ns(99.0)
        );
        // Same total work either way.
        assert_eq!(r1.stats.lookups, r2.stats.lookups);
        assert_eq!(r1.stats.activations, r2.stats.activations);
    }

    #[test]
    fn backpressure_batches_back_to_back() {
        // All 64 queries arrive at t=0 with max_batch 16: the executor
        // must serve 4 back-to-back batches, later batches waiting on
        // earlier ones (free_at), so sojourns strictly stratify.
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let sched = Scheduler::new(&map, &rep, &m, true);
        let queries = some_queries(64);
        let arrivals = vec![0u64; 64];
        let report = drive_single(&sched, &queries, &arrivals, &policy(16, 0));
        assert_eq!(report.batches(), 4);
        // The last batch's queries waited for three service rounds.
        let first_batch_max = report.sojourn_ns[..16].iter().cloned().fold(0.0, f64::max);
        let last_batch_min = report.sojourn_ns[48..]
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        assert!(last_batch_min > first_batch_max);
        assert_eq!(report.shards[0].max_backlog, 64);
    }
}
