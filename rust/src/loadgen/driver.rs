//! Open-loop driver: timestamped queries in, tail-latency telemetry out.
//!
//! The closed-loop harnesses (`Engine::run_trace`, `simulate_sharded`)
//! feed pre-formed batches and report batch completion time — there is no
//! notion of *offered load* or *queueing delay*. This driver runs a
//! serving [`Backend`] on **simulated time**: queries arrive at the
//! timestamps an arrival process ([`super::arrival`]) produced, are
//! scattered to the backend's executors ([`Backend::scatter`]), pass
//! through the exact dynamic-batching policy the live executors run
//! ([`crate::coordinator::Batcher`], clock-injected), and are served by
//! the backend's discrete-event timing twin
//! ([`Backend::run_batch_timed`]). No threads, no wall clock: the same
//! `(backend, queries, arrivals, policy)` input always produces
//! bit-identical output.
//!
//! One [`drive`] serves every backend — the single pool is simply the
//! one-executor case, so the old `drive_single`/`drive_sharded` pair
//! collapsed into it (both remain as deprecated shims for one release).
//!
//! Sojourn decomposition for a query arriving at `t_a`, whose batch
//! closes at `t_c` and whose in-batch service finishes `f` ns after the
//! batch starts:
//!
//! ```text
//! sojourn = (t_c - t_a)              queue wait + batch formation wait
//!         + f                        scheduled crossbar service
//!         [+ (fanout-1) · add_ns]    cross-executor merge
//! ```
//!
//! `t_c` already folds in executor backpressure: a batch cannot close
//! while the (serial) executor is still serving the previous one, so at
//! offered loads past capacity the queue — and the tail — grow without
//! bound. That hockey-stick is exactly what `benches/fig13_latency.rs`
//! sweeps.

use crate::cluster::{PoolShared, ShardPlan};
use crate::coordinator::batcher::{BatchPolicy, Batcher};
use crate::deploy::{Backend, BackendStatus, Reduction, SimBackend};
use crate::metrics::{percentile, Summary};
use crate::obs::{names, Stage};
use crate::sched::{ExecStats, Scheduler, Scratch};
use crate::workload::{EmbeddingId, Query};

/// Per-executor (shard) load telemetry.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardLoad {
    pub shard: u32,
    /// Sub-queries this executor served (= queries, single pool).
    pub sub_queries: u64,
    /// Batches its batcher closed.
    pub batches: u64,
    /// Simulated time spent serving, ns.
    pub busy_ns: f64,
    /// Peak queued sub-queries observed at a batch close.
    pub max_backlog: usize,
    /// Time-averaged sub-queries in system (Little's law:
    /// Σ sub-sojourn / horizon).
    pub mean_backlog: f64,
    /// `(close time ns, queued depth)` at every batch close — the
    /// backlog-over-time series the report can render.
    pub backlog_samples: Vec<(f64, usize)>,
}

impl ShardLoad {
    /// Fraction of the horizon this executor spent serving, clamped to
    /// `[0, 1]`.
    ///
    /// A non-positive horizon (an empty drive, or a degenerate caller
    /// passing `0.0` / a negative span / `NEG_INFINITY`) reports `0.0`
    /// utilization rather than dividing by it — an executor that never
    /// had a horizon to be busy over was never busy.
    pub fn utilization(&self, horizon_ns: f64) -> f64 {
        if horizon_ns <= 0.0 {
            0.0
        } else {
            (self.busy_ns / horizon_ns).min(1.0)
        }
    }
}

/// Result of one open-loop drive.
#[derive(Debug, Clone, PartialEq)]
pub struct OpenLoopReport {
    /// Per-query sojourn time (finish − arrival), ns, in arrival order.
    pub sojourn_ns: Vec<f64>,
    /// Arrival timestamps, ns, aligned with `sojourn_ns` — copied from
    /// the drive's input so [`OpenLoopReport::windows`] can re-slice the
    /// run into time windows after the fact.
    pub arrivals_ns: Vec<u64>,
    /// Service-side accounting: counters sum over everything served;
    /// `completion_ns` accumulates per executor and maxes across shards
    /// (the executors run concurrently).
    pub stats: ExecStats,
    /// Last query finish time, ns (the simulated makespan).
    pub horizon_ns: f64,
    /// Offered load implied by the arrival stamps, queries/second.
    pub offered_qps: f64,
    /// One entry per executor (a single entry for the single pool).
    pub shards: Vec<ShardLoad>,
}

impl OpenLoopReport {
    pub fn queries(&self) -> usize {
        self.sojourn_ns.len()
    }

    /// Sojourn percentile, ns (nearest-rank over the exact sample).
    pub fn percentile_ns(&self, p: f64) -> f64 {
        percentile(&self.sojourn_ns, p)
    }

    pub fn mean_sojourn_ns(&self) -> f64 {
        if self.sojourn_ns.is_empty() {
            0.0
        } else {
            self.sojourn_ns.iter().sum::<f64>() / self.sojourn_ns.len() as f64
        }
    }

    /// Achieved throughput over the makespan, queries/second.
    ///
    /// A zero-query drive (or one whose only queries were empty, leaving
    /// the makespan at zero) reports `0.0` rather than `0/0 = NaN` —
    /// nothing was achieved over no time.
    pub fn throughput_qps(&self) -> f64 {
        if self.horizon_ns <= 0.0 {
            0.0
        } else {
            self.queries() as f64 / (self.horizon_ns / 1e9)
        }
    }

    /// Time-averaged queries in system (Little's law: L = Σ sojourn / T).
    ///
    /// With a zero makespan there was no interval to average over:
    /// reports `0.0` instead of dividing by zero.
    pub fn mean_queue_depth(&self) -> f64 {
        if self.horizon_ns <= 0.0 {
            0.0
        } else {
            self.sojourn_ns.iter().sum::<f64>() / self.horizon_ns
        }
    }

    /// Total batches closed across executors.
    pub fn batches(&self) -> u64 {
        self.shards.iter().map(|s| s.batches).sum()
    }

    /// Re-slice the drive into fixed-width arrival windows.
    ///
    /// Window `i` covers arrivals in `[i·window_ns, (i+1)·window_ns)`;
    /// the result spans the first to the last occupied window
    /// contiguously, so lulls inside the run appear as empty windows
    /// (their percentiles read 0 by [`percentile`]'s empty-slice
    /// contract) rather than silently vanishing from the timeline. A
    /// zero-query drive has no timeline and returns no windows. This is
    /// a pure view of the report — the watch loop feeds one window per
    /// tick into the SLO tracker ([`crate::obs::Watcher`]), and tests
    /// use it to localise an injected overload phase.
    pub fn windows(&self, window_ns: u64) -> Vec<ReportWindow> {
        assert!(window_ns > 0, "window width must be positive");
        let (Some(&first), Some(&last)) = (self.arrivals_ns.first(), self.arrivals_ns.last())
        else {
            return Vec::new();
        };
        let lo = first / window_ns;
        let hi = last / window_ns;
        let mut out: Vec<ReportWindow> = (lo..=hi)
            .map(|index| ReportWindow {
                index,
                start_ns: index * window_ns,
                end_ns: (index + 1) * window_ns,
                sojourn_ns: Vec::new(),
            })
            .collect();
        for (&a, &s) in self.arrivals_ns.iter().zip(&self.sojourn_ns) {
            out[(a / window_ns - lo) as usize].sojourn_ns.push(s);
        }
        out
    }
}

/// One fixed-width arrival window of an [`OpenLoopReport`] — the
/// per-tick sub-report the watch loop turns into `loadgen.*` gauges.
#[derive(Debug, Clone, PartialEq)]
pub struct ReportWindow {
    /// Window ordinal: `start_ns / window_ns`.
    pub index: u64,
    /// Window start, ns (inclusive).
    pub start_ns: u64,
    /// Window end, ns (exclusive).
    pub end_ns: u64,
    /// Sojourns of the queries that *arrived* in this window, ns, in
    /// arrival order.
    pub sojourn_ns: Vec<f64>,
}

impl ReportWindow {
    pub fn queries(&self) -> usize {
        self.sojourn_ns.len()
    }

    /// Sojourn percentile, ns (nearest-rank; 0.0 for an empty window).
    pub fn percentile_ns(&self, p: f64) -> f64 {
        percentile(&self.sojourn_ns, p)
    }

    pub fn mean_sojourn_ns(&self) -> f64 {
        if self.sojourn_ns.is_empty() {
            0.0
        } else {
            self.sojourn_ns.iter().sum::<f64>() / self.sojourn_ns.len() as f64
        }
    }

    /// Arrival rate over the window span, queries/second.
    pub fn arrival_qps(&self) -> f64 {
        let span_ns = (self.end_ns - self.start_ns) as f64;
        if span_ns <= 0.0 {
            0.0
        } else {
            self.queries() as f64 / (span_ns / 1e9)
        }
    }
}

/// Open-loop drive of any [`Backend`] on simulated time.
///
/// The front-end splits every query by executor the instant it arrives
/// ([`Backend::scatter`] — ownership-pinned, the deterministic twin of
/// the live scatter), each executor runs its own dynamic batcher + a
/// serial discrete-event service loop ([`Backend::run_batch_timed`]),
/// and a query completes when its last sub-query finishes plus one merge
/// add per extra executor touched ([`Backend::merge_cost`]). Empty
/// queries are dropped at the front door (sojourn 0). `arrivals_ns` must
/// be non-decreasing and aligned with `queries`.
pub fn drive(
    backend: &dyn Backend,
    queries: &[Query],
    arrivals_ns: &[u64],
    policy: &BatchPolicy,
) -> OpenLoopReport {
    check_arrivals(queries.len(), arrivals_ns);
    let n = queries.len();
    let shards = backend.executors();
    assert!(shards > 0, "backend reports zero executors");
    let (add_ns, add_pj) = backend.merge_cost();
    // Observability rides along when the backend carries an *enabled*
    // handle: the driver records batcher / span / fan-out telemetry on
    // the same registry the live executors use, so sim and live runs
    // emit one schema. Everything recorded is read off values this
    // function computes anyway — the drive's output is bit-identical
    // with recording on or off (tests/obs_integration.rs pins this).
    let obs = backend.obs().cloned();
    let recording = obs.as_ref().map_or(false, |o| o.enabled());

    // Scatter: split every query at its arrival instant.
    let mut sub_queries: Vec<Vec<Query>> = vec![Vec::new(); shards];
    let mut sub_arrivals: Vec<Vec<(u64, usize)>> = vec![Vec::new(); shards];
    // (executor, local index) of every sub-query of each query.
    let mut subs_of_query: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n];
    // Global query index of each sub-query (span labels; recording only).
    let mut sub_qi: Vec<Vec<usize>> = vec![Vec::new(); shards];
    for (qi, q) in queries.iter().enumerate() {
        if q.is_empty() {
            continue; // nothing to serve
        }
        let split = backend.scatter(&q.items);
        debug_assert_eq!(split.len(), shards, "scatter width != executors");
        for (s, items) in split.into_iter().enumerate() {
            if items.is_empty() {
                continue;
            }
            let li = sub_queries[s].len();
            sub_arrivals[s].push((arrivals_ns[qi], li));
            sub_queries[s].push(Query::new(items));
            subs_of_query[qi].push((s, li));
            if recording {
                sub_qi[s].push(qi);
            }
        }
    }

    // Each executor runs independently: its batch boundaries depend only
    // on its own arrivals and its own backlog.
    let mut stats = ExecStats::default();
    let mut shard_loads = Vec::with_capacity(shards);
    let mut sub_finish: Vec<Vec<f64>> = Vec::with_capacity(shards);
    let mut horizon = 0.0f64;
    let mut scratch = Scratch::default();
    let mut rel = Vec::new();
    for s in 0..shards {
        let mut finish = vec![0.0f64; sub_queries[s].len()];
        let mut local_stats = ExecStats::default();
        // Per-shard formation-wait accumulator, merged into the shared
        // registry once per shard (Summary::merge) instead of locking
        // per sub-query.
        let mut wait_local = Summary::new();
        let qstats = simulate_executor(&sub_arrivals[s], policy, &mut finish, |t_close, batch| {
            let qs: Vec<Query> = batch.iter().map(|&i| sub_queries[s][i].clone()).collect();
            let st = backend.run_batch_timed(s, &qs, &mut scratch, &mut rel);
            local_stats.accumulate(&st);
            if recording {
                let o = obs.as_ref().expect("recording implies a handle");
                for (&li, &r) in batch.iter().zip(&rel) {
                    let (arr, _) = sub_arrivals[s][li];
                    wait_local.add(t_close - arr as f64);
                    let qid = sub_qi[s][li] as u64;
                    if o.sampled(qid) {
                        o.span(Stage::Enqueue, qid, s as u32, arr, t_close as u64);
                        o.span(
                            Stage::Execute,
                            qid,
                            s as u32,
                            t_close as u64,
                            (t_close + r) as u64,
                        );
                    }
                }
            }
            (st.completion_ns, rel.clone())
        });
        stats.merge_parallel(&local_stats);
        if recording {
            let o = obs.as_ref().expect("recording implies a handle");
            o.merge_summary(names::BATCHER_WAIT_NS, &wait_local);
            for &(_, depth) in &qstats.backlog_samples {
                o.observe(names::BATCHER_QUEUE_DEPTH, depth as f64);
                o.record_hist(
                    names::BATCHER_BATCH_SIZE,
                    depth.min(policy.max_batch) as u64,
                    1,
                );
                o.incr(
                    if depth >= policy.max_batch {
                        names::BATCHER_CLOSE_SIZE
                    } else {
                        names::BATCHER_CLOSE_DEADLINE
                    },
                    1,
                );
            }
        }
        let sub_sojourn: f64 = sub_arrivals[s]
            .iter()
            .map(|&(a, li)| finish[li] - a as f64)
            .sum();
        shard_loads.push(ShardLoad {
            shard: s as u32,
            sub_queries: sub_queries[s].len() as u64,
            batches: qstats.batches,
            busy_ns: qstats.busy_ns,
            max_backlog: qstats.max_backlog,
            // Little's-law numerator for now; divided by the global
            // horizon once the gather pass below has fixed it.
            mean_backlog: sub_sojourn,
            backlog_samples: qstats.backlog_samples,
        });
        horizon = horizon.max(qstats.horizon_ns);
        sub_finish.push(finish);
    }

    // Gather: a query completes when its last sub-query does, plus one
    // front-end merge add per extra executor (same accounting as
    // `cluster::simulate_with_replicas`).
    let mut sojourn = Vec::with_capacity(n);
    for (qi, subs) in subs_of_query.iter().enumerate() {
        let a = arrivals_ns[qi] as f64;
        if subs.is_empty() {
            sojourn.push(0.0); // empty query: nothing to serve
            continue;
        }
        let mut f = subs
            .iter()
            .map(|&(s, li)| sub_finish[s][li])
            .fold(f64::NEG_INFINITY, f64::max);
        let served = f;
        if subs.len() > 1 {
            f += (subs.len() - 1) as f64 * add_ns;
            stats.energy_pj += (subs.len() - 1) as f64 * add_pj;
        }
        if recording && shards > 1 {
            // The twin's scatter is ownership-pinned by contract.
            let o = obs.as_ref().expect("recording implies a handle");
            o.record_hist(names::CLUSTER_FANOUT, subs.len() as u64, 1);
            o.incr(names::CLUSTER_SUBQUERIES, subs.len() as u64);
            o.incr(names::CLUSTER_ROUTE_PINNED, 1);
            if subs.len() > 1 && o.sampled(qi as u64) {
                o.span(Stage::Merge, qi as u64, 0, served as u64, f as u64);
            }
        }
        horizon = horizon.max(f);
        sojourn.push(f - a);
    }
    for sl in &mut shard_loads {
        sl.mean_backlog = if horizon > 0.0 {
            sl.mean_backlog / horizon
        } else {
            0.0
        };
    }
    OpenLoopReport {
        offered_qps: offered_qps(arrivals_ns),
        sojourn_ns: sojourn,
        arrivals_ns: arrivals_ns.to_vec(),
        stats,
        horizon_ns: horizon,
        shards: shard_loads,
    }
}

/// Timing-only adapter so the deprecated [`drive_single`] shim can keep
/// its bare-`Scheduler` signature.
struct SchedulerBackend<'s, 'a>(&'s Scheduler<'a>);

impl Backend for SchedulerBackend<'_, '_> {
    fn name(&self) -> &str {
        "single-pool"
    }

    fn executors(&self) -> usize {
        1
    }

    fn scatter(&self, items: &[EmbeddingId]) -> Vec<Vec<EmbeddingId>> {
        vec![items.to_vec()]
    }

    fn run_batch_timed(
        &self,
        _executor: usize,
        queries: &[Query],
        scratch: &mut Scratch,
        finish_rel: &mut Vec<f64>,
    ) -> ExecStats {
        self.0.run_batch_timed(queries, scratch, finish_rel)
    }

    fn merge_cost(&self) -> (f64, f64) {
        self.0.model().vector_add()
    }

    fn reduce_many(&self, _queries: &[Query]) -> crate::Result<Vec<Reduction>> {
        anyhow::bail!("a bare scheduler is timing-only; use a deploy backend to reduce")
    }

    fn status(&self) -> crate::Result<Vec<BackendStatus>> {
        anyhow::bail!("a bare scheduler keeps no serving counters")
    }
}

/// Open-loop drive of the **single-pool** path.
#[deprecated(
    since = "0.2.0",
    note = "build a deploy backend (e.g. Prepared::sim()) and call loadgen::drive"
)]
pub fn drive_single(
    sched: &Scheduler<'_>,
    queries: &[Query],
    arrivals_ns: &[u64],
    policy: &BatchPolicy,
) -> OpenLoopReport {
    drive(&SchedulerBackend(sched), queries, arrivals_ns, policy)
}

/// Open-loop drive of the **sharded** path (ownership-pinned scatter).
#[deprecated(
    since = "0.2.0",
    note = "build a deploy backend (e.g. Prepared::sim_sharded()) and call loadgen::drive"
)]
pub fn drive_sharded(
    shared: &PoolShared,
    plan: &ShardPlan,
    queries: &[Query],
    arrivals_ns: &[u64],
    policy: &BatchPolicy,
) -> OpenLoopReport {
    drive(
        &SimBackend::sharded(shared, plan.clone()),
        queries,
        arrivals_ns,
        policy,
    )
}

fn check_arrivals(num_queries: usize, arrivals_ns: &[u64]) {
    assert_eq!(
        num_queries,
        arrivals_ns.len(),
        "one arrival timestamp per query"
    );
    assert!(
        arrivals_ns.windows(2).all(|w| w[0] <= w[1]),
        "arrival timestamps must be non-decreasing"
    );
}

/// Offered load implied by the arrival stamps, queries/second.
///
/// Edge behavior, by span of the stamps:
/// * empty or single-arrival stream → `0.0` (no interval ⇒ no rate);
/// * `n > 1` arrivals all at one instant → `INFINITY` (an unbounded
///   burst, not idle traffic);
/// * otherwise the `n−1` inter-arrival gaps over the first→last span.
fn offered_qps(arrivals_ns: &[u64]) -> f64 {
    match (arrivals_ns.first(), arrivals_ns.last()) {
        (Some(&a), Some(&b)) if b > a => {
            (arrivals_ns.len() - 1) as f64 / ((b - a) as f64 / 1e9)
        }
        // Two or more arrivals at one instant is an unbounded burst, not
        // idle traffic.
        (Some(_), Some(_)) if arrivals_ns.len() > 1 => f64::INFINITY,
        _ => 0.0,
    }
}

/// Aggregates one simulated executor produced.
struct ExecutorStats {
    batches: u64,
    busy_ns: f64,
    max_backlog: usize,
    /// Final executor-free time = last batch's finish.
    horizon_ns: f64,
    backlog_samples: Vec<(f64, usize)>,
}

/// Simulate one serial executor behind a dynamic batcher on virtual time.
///
/// `arrivals` is `(arrival_ns, item id)`, sorted by time. `serve` is
/// called once per closed batch with the close time and the item ids,
/// and returns the batch's total service duration plus each item's
/// finish offset within it; absolute finish times land in
/// `finish_ns[item]`.
///
/// Batch-close rule (identical to the live executor loop): a batch
/// closes at the earliest time `t ≥ executor_free` at which the queue
/// holds `max_batch` requests or the oldest has waited `max_wait` —
/// arrivals up to `t` join the queue first, exactly as the live loop's
/// channel drain would deliver them.
fn simulate_executor<F>(
    arrivals: &[(u64, usize)],
    policy: &BatchPolicy,
    finish_ns: &mut [f64],
    mut serve: F,
) -> ExecutorStats
where
    F: FnMut(f64, &[usize]) -> (f64, Vec<f64>),
{
    debug_assert!(arrivals.windows(2).all(|w| w[0].0 <= w[1].0));
    let mut batcher: Batcher<usize> = Batcher::new(policy.clone());
    let mut i = 0usize;
    let mut free_at = 0.0f64;
    let mut out = ExecutorStats {
        batches: 0,
        busy_ns: 0.0,
        max_backlog: 0,
        horizon_ns: 0.0,
        backlog_samples: Vec::new(),
    };
    while i < arrivals.len() || !batcher.is_empty() {
        if batcher.is_empty() {
            // Idle executor: sleep until the next arrival.
            let (t, id) = arrivals[i];
            batcher.push_at(id, t);
            i += 1;
        }
        // Settle the close time: every arrival at or before the current
        // close candidate joins the queue first, which can only pull the
        // candidate earlier (size trigger) — never push it later.
        let t_close = loop {
            let ready = batcher.ready_at().expect("queue is non-empty") as f64;
            let cand = ready.max(free_at);
            match arrivals.get(i) {
                Some(&(t, id)) if (t as f64) <= cand => {
                    batcher.push_at(id, t);
                    i += 1;
                }
                _ => break cand,
            }
        };
        out.max_backlog = out.max_backlog.max(batcher.len());
        out.backlog_samples.push((t_close, batcher.len()));
        let batch = batcher.take_batch();
        let (busy, rel) = serve(t_close, &batch);
        assert_eq!(rel.len(), batch.len(), "one finish offset per batch item");
        for (&id, &r) in batch.iter().zip(&rel) {
            finish_ns[id] = t_close + r;
        }
        free_at = t_close + busy;
        out.busy_ns += busy;
        out.batches += 1;
        out.horizon_ns = out.horizon_ns.max(free_at);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::allocation::Replication;
    use crate::config::HardwareConfig;
    use crate::grouping::Mapping;
    use crate::loadgen::arrival::Arrivals;
    use crate::xbar::{CircuitParams, CrossbarModel};
    use std::time::Duration;

    fn model() -> CrossbarModel {
        CrossbarModel::new(&HardwareConfig::default(), &CircuitParams::default())
    }

    fn mapping_2x2() -> Mapping {
        Mapping::from_groups(vec![vec![0, 1], vec![2, 3]], 2, 4)
    }

    fn policy(max_batch: usize, wait_us: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_micros(wait_us),
        }
    }

    fn some_queries(n: usize) -> Vec<Query> {
        (0..n)
            .map(|i| Query::new(vec![(i % 4) as u32, ((i + 1) % 4) as u32]))
            .collect()
    }

    #[test]
    fn zero_load_sojourn_is_pure_service_time() {
        // Arrivals light-years apart + max_wait 0: every query is served
        // alone the instant it arrives, so sojourn == single-query batch
        // service time and p99 collapses to pure service.
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let backend = SimBackend::from_parts(&map, &rep, &m, true);
        let sched = Scheduler::new(&map, &rep, &m, true);
        let queries = some_queries(32);
        let arrivals: Vec<u64> = (0..32).map(|i| i as u64 * 1_000_000_000).collect();
        let report = drive(&backend, &queries, &arrivals, &policy(8, 0));
        let mut scratch = Scratch::default();
        // Tolerance: adding a ~1e10 ns arrival timestamp and subtracting
        // it back costs a few µ-ulps, never more than 1e-3 ns here.
        for (q, &soj) in queries.iter().zip(&report.sojourn_ns) {
            let solo = sched.run_batch(std::slice::from_ref(q), &mut scratch);
            assert!(
                (soj - solo.completion_ns).abs() < 1e-3,
                "sojourn {soj} != solo service {}",
                solo.completion_ns
            );
        }
        let max_solo = queries
            .iter()
            .map(|q| sched.run_batch(std::slice::from_ref(q), &mut scratch).completion_ns)
            .fold(0.0f64, f64::max);
        assert!((report.percentile_ns(99.0) - max_solo).abs() < 1e-3);
        // One query per batch, no backlog beyond 1.
        assert_eq!(report.batches(), 32);
        assert_eq!(report.shards[0].max_backlog, 1);
        assert!(report.mean_queue_depth() < 1e-3);
    }

    #[test]
    fn drive_is_deterministic() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let backend = SimBackend::from_parts(&map, &rep, &m, true);
        let queries = some_queries(256);
        let arrivals = Arrivals::poisson(5_000_000.0, 11).take(256);
        let a = drive(&backend, &queries, &arrivals, &policy(16, 2_000));
        let b = drive(&backend, &queries, &arrivals, &policy(16, 2_000));
        assert_eq!(a, b, "open-loop drive must be bit-reproducible");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_match_the_unified_drive_exactly() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let queries = some_queries(200);
        let arrivals = Arrivals::poisson(50_000_000.0, 9).take(200);
        let p = policy(8, 500);
        // Single pool: shim == SimBackend path, bit-for-bit.
        let sched = Scheduler::new(&map, &rep, &m, true);
        let via_shim = drive_single(&sched, &queries, &arrivals, &p);
        let backend = SimBackend::from_parts(&map, &rep, &m, true);
        let via_drive = drive(&backend, &queries, &arrivals, &p);
        assert_eq!(via_shim, via_drive);
        // Sharded: shim == SimBackend::sharded path, bit-for-bit.
        let shared = PoolShared {
            mapping: mapping_2x2(),
            replication: Replication::identity(2, 4),
            model: model(),
            dynamic_switch: true,
        };
        let plan = ShardPlan::from_assignment(vec![0, 1], 2);
        let s_shim = drive_sharded(&shared, &plan, &queries, &arrivals, &p);
        let s_backend = SimBackend::sharded(&shared, plan.clone());
        let s_drive = drive(&s_backend, &queries, &arrivals, &p);
        assert_eq!(s_shim, s_drive);
    }

    #[test]
    fn saturation_blows_up_the_tail() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let backend = SimBackend::from_parts(&map, &rep, &m, true);
        let queries = some_queries(512);
        let slow = Arrivals::poisson(1_000.0, 3).take(512); // ~idle
        let fast = Arrivals::poisson(1e9, 3).take(512); // far past capacity
        // max_wait 0 so the idle baseline is pure service time, not
        // batch-formation wait.
        let p = policy(16, 0);
        let low = drive(&backend, &queries, &slow, &p);
        let high = drive(&backend, &queries, &fast, &p);
        assert!(
            high.percentile_ns(99.0) > 10.0 * low.percentile_ns(99.0),
            "p99 {} !>> {}",
            high.percentile_ns(99.0),
            low.percentile_ns(99.0)
        );
        assert!(high.mean_queue_depth() > low.mean_queue_depth());
        // Conservation either way.
        assert_eq!(low.stats.queries, 512);
        assert_eq!(high.stats.queries, 512);
    }

    #[test]
    fn percentiles_are_monotone_in_the_quantile() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let backend = SimBackend::from_parts(&map, &rep, &m, true);
        let queries = some_queries(300);
        let arrivals = Arrivals::bursty(50_000_000.0, 5).take(300);
        let report = drive(&backend, &queries, &arrivals, &policy(8, 500));
        let ps = [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0];
        let qs: Vec<f64> = ps.iter().map(|&p| report.percentile_ns(p)).collect();
        for w in qs.windows(2) {
            assert!(w[1] >= w[0], "percentiles regress: {qs:?}");
        }
    }

    #[test]
    fn sharded_drive_conserves_work_and_merges_fanout() {
        let shared = PoolShared {
            mapping: mapping_2x2(),
            replication: Replication::identity(2, 4),
            model: model(),
            dynamic_switch: true,
        };
        let plan = ShardPlan::from_assignment(vec![0, 1], 2);
        let backend = SimBackend::sharded(&shared, plan);
        // Every query touches both groups -> fanout 2 everywhere.
        let queries: Vec<Query> = (0..64).map(|_| Query::new(vec![0, 2])).collect();
        let arrivals = Arrivals::poisson(2_000_000.0, 7).take(64);
        let report = drive(&backend, &queries, &arrivals, &policy(8, 1_000));
        assert_eq!(report.queries(), 64);
        assert_eq!(report.shards.len(), 2);
        // Each query produced one sub-query per shard.
        assert_eq!(report.shards[0].sub_queries, 64);
        assert_eq!(report.shards[1].sub_queries, 64);
        assert_eq!(report.stats.lookups, 128);
        // Sojourn includes at least the single-item service + merge add.
        let (add_ns, _) = shared.model.vector_add();
        let act = shared.model.activation(1, true);
        let flit = shared.model.bus_flit_ns();
        let floor = act.latency_ns + flit + add_ns;
        assert!(report.sojourn_ns.iter().all(|&s| s >= floor - 1e-9));
        // Deterministic across runs.
        let again = drive(&backend, &queries, &arrivals, &policy(8, 1_000));
        assert_eq!(report, again);
    }

    #[test]
    fn sharding_relieves_an_overloaded_executor() {
        // max_batch = 1 makes the serial executor the bottleneck: the
        // single pool serves 256 one-query batches back-to-back, while
        // two shards serve two independent 128-query streams
        // concurrently — the saturated tail must drop by roughly half.
        let shared = PoolShared {
            mapping: mapping_2x2(),
            replication: Replication::identity(2, 4),
            model: model(),
            dynamic_switch: true,
        };
        let queries: Vec<Query> = (0..256)
            .map(|i| Query::new(vec![(i % 2) as u32 * 2])) // alternate groups
            .collect();
        let arrivals = Arrivals::poisson(2e8, 13).take(256);
        let p = policy(1, 0);
        let one = SimBackend::sharded(&shared, ShardPlan::from_assignment(vec![0, 0], 1));
        let two = SimBackend::sharded(&shared, ShardPlan::from_assignment(vec![0, 1], 2));
        let r1 = drive(&one, &queries, &arrivals, &p);
        let r2 = drive(&two, &queries, &arrivals, &p);
        assert!(
            r2.percentile_ns(99.0) < 0.75 * r1.percentile_ns(99.0),
            "2-shard p99 {} !< 0.75 x 1-shard {}",
            r2.percentile_ns(99.0),
            r1.percentile_ns(99.0)
        );
        // Same total work either way.
        assert_eq!(r1.stats.lookups, r2.stats.lookups);
        assert_eq!(r1.stats.activations, r2.stats.activations);
    }

    #[test]
    fn backpressure_batches_back_to_back() {
        // All 64 queries arrive at t=0 with max_batch 16: the executor
        // must serve 4 back-to-back batches, later batches waiting on
        // earlier ones (free_at), so sojourns strictly stratify.
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let backend = SimBackend::from_parts(&map, &rep, &m, true);
        let queries = some_queries(64);
        let arrivals = vec![0u64; 64];
        let report = drive(&backend, &queries, &arrivals, &policy(16, 0));
        assert_eq!(report.batches(), 4);
        // The last batch's queries waited for three service rounds.
        let first_batch_max = report.sojourn_ns[..16].iter().cloned().fold(0.0, f64::max);
        let last_batch_min = report.sojourn_ns[48..]
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        assert!(last_batch_min > first_batch_max);
        assert_eq!(report.shards[0].max_backlog, 64);
    }

    #[test]
    fn sim_backend_reduce_many_needs_a_store_and_is_exact() {
        use crate::coordinator::EmbeddingStore;
        let shared = PoolShared {
            mapping: mapping_2x2(),
            replication: Replication::identity(2, 4),
            model: model(),
            dynamic_switch: true,
        };
        let timing_only = SimBackend::single(&shared);
        assert!(timing_only.reduce_many(&[Query::new(vec![0])]).is_err());
        // Integer table: D=2, embedding e = [2e, 2e+1].
        let table: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let store = EmbeddingStore::from_table(&shared.mapping, 2, 4, table);
        let plan = ShardPlan::from_assignment(vec![0, 1], 2);
        let backend = SimBackend::sharded(&shared, plan).with_store(&store);
        let out = backend
            .reduce_many(&[Query::new(vec![0, 2]), Query::new(vec![1])])
            .unwrap();
        assert_eq!(out[0].reduced, store.reduce_reference(&[0, 2]));
        assert_eq!(out[0].fanout, 2);
        assert_eq!(out[1].reduced, store.reduce_reference(&[1]));
        assert_eq!(out[1].fanout, 1);
    }

    #[test]
    fn utilization_guards_non_positive_horizon() {
        let sl = ShardLoad {
            shard: 0,
            sub_queries: 0,
            batches: 0,
            busy_ns: 5.0,
            max_backlog: 0,
            mean_backlog: 0.0,
            backlog_samples: Vec::new(),
        };
        // Degenerate horizons: never divide, always 0.0.
        assert_eq!(sl.utilization(0.0), 0.0);
        assert_eq!(sl.utilization(-1.0), 0.0);
        assert_eq!(sl.utilization(f64::NEG_INFINITY), 0.0);
        // Healthy horizons: the plain ratio, capped at 1.
        assert_eq!(sl.utilization(10.0), 0.5);
        assert_eq!(sl.utilization(2.5), 1.0);
    }

    #[test]
    fn report_edge_cases_on_zero_queries() {
        let empty = OpenLoopReport {
            sojourn_ns: Vec::new(),
            arrivals_ns: Vec::new(),
            stats: ExecStats::default(),
            horizon_ns: 0.0,
            offered_qps: 0.0,
            shards: Vec::new(),
        };
        assert_eq!(empty.queries(), 0);
        assert_eq!(empty.throughput_qps(), 0.0);
        assert_eq!(empty.mean_queue_depth(), 0.0);
        assert_eq!(empty.mean_sojourn_ns(), 0.0);
        // Nearest-rank over an empty sample is 0.0 by percentile()'s
        // own empty-slice contract.
        assert_eq!(empty.percentile_ns(99.0), 0.0);
        assert_eq!(empty.batches(), 0);
        // No timeline, no windows.
        assert!(empty.windows(1_000).is_empty());
    }

    #[test]
    fn windows_partition_queries_and_keep_lulls() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let backend = SimBackend::from_parts(&map, &rep, &m, true);
        // 6 queries: a pair in window 1, a lull across windows 2-3, a
        // quad in window 4 (1 ms windows).
        let queries = some_queries(6);
        let arrivals: Vec<u64> =
            vec![1_100_000, 1_900_000, 4_000_000, 4_200_000, 4_400_000, 4_600_000];
        let report = drive(&backend, &queries, &arrivals, &policy(4, 100));
        let ws = report.windows(1_000_000);
        // Contiguous indexes 1..=4, lull windows present but empty.
        assert_eq!(ws.iter().map(|w| w.index).collect::<Vec<_>>(), vec![1, 2, 3, 4]);
        assert_eq!(ws[0].queries(), 2);
        assert_eq!(ws[1].queries(), 0);
        assert_eq!(ws[2].queries(), 0);
        assert_eq!(ws[3].queries(), 4);
        assert_eq!(ws[0].start_ns, 1_000_000);
        assert_eq!(ws[0].end_ns, 2_000_000);
        // Windows partition the report: same sojourns, same order.
        let regathered: Vec<f64> = ws.iter().flat_map(|w| w.sojourn_ns.clone()).collect();
        assert_eq!(regathered, report.sojourn_ns);
        // Empty windows read zero percentiles and rates; occupied ones
        // agree with a direct nearest-rank over their slice.
        assert_eq!(ws[1].percentile_ns(99.0), 0.0);
        assert_eq!(ws[1].arrival_qps(), 0.0);
        assert_eq!(ws[3].percentile_ns(50.0), percentile(&report.sojourn_ns[2..], 50.0));
        assert!((ws[3].arrival_qps() - 4_000.0).abs() < 1e-9);
        let mean_tail = report.sojourn_ns[2..].iter().sum::<f64>() / 4.0;
        assert!((ws[3].mean_sojourn_ns() - mean_tail).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "window width must be positive")]
    fn windows_reject_zero_width() {
        let report = OpenLoopReport {
            sojourn_ns: vec![1.0],
            arrivals_ns: vec![0],
            stats: ExecStats::default(),
            horizon_ns: 1.0,
            offered_qps: 0.0,
            shards: Vec::new(),
        };
        report.windows(0);
    }

    #[test]
    fn offered_qps_classifies_bursts_and_idle() {
        let m = model();
        let map = mapping_2x2();
        let rep = Replication::identity(2, 4);
        let backend = SimBackend::from_parts(&map, &rep, &m, true);
        let p = policy(8, 0);
        // No arrivals / one arrival: no interval, rate 0.
        let none = drive(&backend, &[], &[], &p);
        assert_eq!(none.offered_qps, 0.0);
        let one = drive(&backend, &some_queries(1), &[5], &p);
        assert_eq!(one.offered_qps, 0.0);
        // Same-instant burst of n > 1: unbounded offered load.
        let burst = drive(&backend, &some_queries(3), &[7, 7, 7], &p);
        assert_eq!(burst.offered_qps, f64::INFINITY);
        // One query per second: 1 qps.
        let paced = drive(&backend, &some_queries(2), &[0, 1_000_000_000], &p);
        assert!((paced.offered_qps - 1.0).abs() < 1e-12);
    }
}
