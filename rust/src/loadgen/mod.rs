//! Open-loop traffic engine: arrival processes, simulated-time batching,
//! and tail-latency telemetry.
//!
//! Everything below the cluster layer evaluates *closed-loop*: pre-formed
//! batches in, batch completion time out. Serving millions of users is an
//! *open-loop* problem — requests arrive on their own schedule whether or
//! not the pool is keeping up, and the metrics that matter are offered
//! load, queueing delay, and the latency tail (RecNMP and UpDLRM frame
//! recommendation inference exactly this way). This module supplies that
//! vocabulary:
//!
//! * [`arrival`] — seeded arrival processes (Poisson, bursty MMPP on/off,
//!   diurnal-modulated, trace replay) stamping each query with an arrival
//!   timestamp; persisted via the v2 trace format
//!   ([`crate::workload::TimedTrace`]).
//! * [`driver`] — an open-loop driver on the **simulated clock**: the
//!   live dynamic-batching policy ([`crate::coordinator::Batcher`],
//!   clock-injected) decides batch boundaries, the backend's
//!   discrete-event timing twin
//!   ([`crate::deploy::Backend::run_batch_timed`]) supplies per-query
//!   service times, and the driver composes them into sojourn times —
//!   queue wait + batch-formation wait + scheduled service — for any
//!   [`crate::deploy::Backend`] through the one [`drive`] entry point.
//!   No threads, no wall clock: bit-reproducible by construction.
//!
//! Entry points: `recross serve --arrivals poisson|bursty|diurnal --rate R`
//! and `benches/fig13_latency.rs` (offered load → p99 hockey-stick).

pub mod arrival;
pub mod driver;

pub use arrival::{ArrivalKind, Arrivals};
pub use driver::{drive, OpenLoopReport, ReportWindow, ShardLoad};
#[allow(deprecated)]
pub use driver::{drive_sharded, drive_single};
