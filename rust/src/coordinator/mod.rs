//! L3 serving coordinator.
//!
//! The paper's system contribution wired as a serving stack:
//!
//! * [`store`] — the embedding table in its crossbar layout (the offline
//!   phase's ③/④ output materialised),
//! * [`planner`] — query → crossbar reduce passes (the online phase's Ⓑ
//!   operation selection, numerically),
//! * [`batcher`] — dynamic batching policy,
//! * [`server`] — executor thread owning the PJRT runtime + engine;
//!   request router and response fan-out.
//!
//! [`build_pipeline`] assembles everything from a [`Config`]: generate /
//! load the workload history, run the offline phase (graph → Algorithm 1 →
//! Eq. 1), lay out the store, load the artifacts.

pub mod batcher;
pub mod drift;
pub mod planner;
pub mod server;
pub mod store;

pub use batcher::{BatchPolicy, Batcher};
pub use drift::DriftMonitor;
pub use planner::{Planner, ReducePass};
pub use server::{
    Pipeline, PipelineStatus, Request, Response, Server, ServerHandle, ShardedServerHandle,
};
pub use store::EmbeddingStore;

use crate::config::Config;
use crate::engine::{Engine, Scheme};
use crate::graph::CoGraph;
use crate::runtime::Runtime;
use crate::workload::{generate, DatasetSpec, Trace};
use crate::Result;
use anyhow::Context;

/// Offline phase bundle: everything the serving pipeline needs that does
/// not depend on PJRT (so it can be prepared on any thread).
#[derive(Debug)]
pub struct OfflinePhase {
    pub engine: Engine,
    pub history: Trace,
    pub eval: Trace,
}

impl OfflinePhase {
    /// Run the offline phase for `scheme` per the config's workload.
    /// `scale` shrinks the dataset (1.0 = paper scale).
    pub fn run(cfg: &Config, scheme: Scheme, scale: f64) -> Result<Self> {
        // Thread the configured worker count into the data-parallel
        // substrate before any counting pass runs. Output is
        // bit-identical for every width, so this only shapes wall-clock.
        crate::util::par::set_default_workers(cfg.offline.workers);
        let spec = DatasetSpec::by_name(&cfg.workload.dataset)
            .with_context(|| format!("unknown dataset {:?}", cfg.workload.dataset))?
            .scaled(scale);
        let (history, eval) = generate(
            &spec,
            cfg.workload.history_queries,
            cfg.workload.eval_queries,
            cfg.workload.seed,
        );
        let graph = CoGraph::build(&history);
        let engine = Engine::prepare(scheme, &graph, &history, cfg);
        Ok(Self {
            engine,
            history,
            eval,
        })
    }
}

/// Build a full pipeline on the current thread (PJRT runtime included).
pub fn build_pipeline(cfg: &Config, scheme: Scheme, scale: f64) -> Result<Pipeline> {
    let offline = OfflinePhase::run(cfg, scheme, scale)?;
    build_pipeline_from(cfg, offline)
}

/// Build a pipeline from an already-run offline phase.
pub fn build_pipeline_from(cfg: &Config, offline: OfflinePhase) -> Result<Pipeline> {
    build_pipeline_with_store(cfg, offline, None)
}

/// Build a pipeline from an already-run offline phase and an optional
/// explicit embedding table (e.g. one installed on a
/// [`crate::deploy::Prepared`]). `None` lays out the deterministic
/// random table per the artifact manifest; `Some` tables are validated
/// against the manifest dims by [`Pipeline::new`] — a mismatched table
/// is an error, never silently replaced.
pub fn build_pipeline_with_store(
    cfg: &Config,
    offline: OfflinePhase,
    store: Option<EmbeddingStore>,
) -> Result<Pipeline> {
    let runtime = Runtime::load(&cfg.artifacts_dir)?;
    let m = runtime.manifest();
    let store = match store {
        Some(s) => s,
        None => EmbeddingStore::random(
            offline.engine.mapping(),
            m.embed_dim,
            m.xbar_rows,
            cfg.workload.seed,
        ),
    };
    Pipeline::new(runtime, offline.engine, store, cfg.workload.seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offline_phase_builds_engine() {
        let mut cfg = Config::paper_default();
        cfg.workload.history_queries = 200;
        cfg.workload.eval_queries = 50;
        let off = OfflinePhase::run(&cfg, Scheme::ReCross, 0.02).unwrap();
        assert_eq!(off.engine.name(), "recross");
        assert_eq!(off.history.queries.len(), 200);
        assert!(off.engine.mapping().num_groups() > 0);
    }

    #[test]
    fn unknown_dataset_rejected() {
        let mut cfg = Config::paper_default();
        cfg.workload.dataset = "books".into();
        assert!(OfflinePhase::run(&cfg, Scheme::Naive, 0.1).is_err());
    }
}
