//! Dynamic batcher: collects single inference requests into batches.
//!
//! Policy (vLLM-router-style, sized for this model's artifact batches):
//! a batch closes when it reaches `max_batch` requests OR the oldest
//! queued request has waited `max_wait`. The serving loop then pads the
//! batch up to the nearest compiled batch size.
//!
//! Time is an **injected** `u64` nanosecond timeline
//! ([`crate::util::Clock`]): the live executor threads pass a
//! [`crate::util::WallClock`]'s readings, while tests and the open-loop
//! simulated-time driver ([`crate::loadgen`]) pass virtual timestamps —
//! the close-on-deadline policy is deterministic and unit-testable, and
//! the exact same code decides batch boundaries in both worlds.

use std::collections::VecDeque;
use std::time::Duration;

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Close a batch at this many requests.
    pub max_batch: usize,
    /// ... or when the oldest request has waited this long.
    pub max_wait: Duration,
}

impl BatchPolicy {
    /// The wait deadline on the nanosecond timeline.
    pub fn max_wait_ns(&self) -> u64 {
        self.max_wait.as_nanos().min(u64::MAX as u128) as u64
    }

    /// Build the policy from the configured wait window
    /// (`scheme.max_wait_us` — one knob for the live servers and the
    /// open-loop simulator alike) and a caller-chosen batch cap.
    pub fn from_config(cfg: &crate::config::Config, max_batch: usize) -> Self {
        Self {
            max_batch,
            max_wait: Duration::from_micros(cfg.scheme.max_wait_us),
        }
    }
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// An accumulating batch former. Generic over the request type so it is
/// testable without the serving stack.
///
/// Callers supply every timestamp explicitly (from whatever
/// [`crate::util::Clock`] they injected) and must keep them monotone
/// non-decreasing across pushes and queries.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    max_wait_ns: u64,
    queue: VecDeque<(T, u64)>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch > 0);
        let max_wait_ns = policy.max_wait_ns();
        Self {
            policy,
            max_wait_ns,
            queue: VecDeque::new(),
        }
    }

    /// Enqueue one request with its arrival time (ns on the injected
    /// clock's timeline).
    pub fn push_at(&mut self, req: T, now_ns: u64) {
        self.queue.push_back((req, now_ns));
    }

    /// Queued requests.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Earliest time at which the *current* queue contents satisfy the
    /// close policy: the size trigger fires at the arrival of the
    /// `max_batch`-th request, the wait trigger at `oldest + max_wait` —
    /// whichever comes first. `None` when empty. (New pushes can only
    /// pull this earlier, never later.)
    pub fn ready_at(&self) -> Option<u64> {
        let &(_, t0) = self.queue.front()?;
        let deadline = t0.saturating_add(self.max_wait_ns);
        match self.queue.get(self.policy.max_batch - 1) {
            Some(&(_, t_full)) => Some(deadline.min(t_full)),
            None => Some(deadline),
        }
    }

    /// Should a batch close *now*?
    pub fn ready(&self, now_ns: u64) -> bool {
        self.ready_at().is_some_and(|t| t <= now_ns)
    }

    /// Time until the close policy would fire, ns (None when empty;
    /// zero when already ready).
    pub fn deadline_in(&self, now_ns: u64) -> Option<u64> {
        self.ready_at().map(|t| t.saturating_sub(now_ns))
    }

    /// The close policy this batcher was built with. Lets instrumented
    /// call sites classify a close as size-triggered
    /// (`len() >= policy().max_batch` at close time) vs deadline-
    /// triggered without carrying the policy separately.
    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Pop up to `max_batch` requests as one batch (empty vec if none).
    pub fn take_batch(&mut self) -> Vec<T> {
        let n = self.queue.len().min(self.policy.max_batch);
        self.queue.drain(..n).map(|(r, _)| r).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: u64 = 1_000_000;

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
        }
    }

    #[test]
    fn closes_on_size() {
        let mut b = Batcher::new(policy(3, 1000));
        for i in 0..3 {
            b.push_at(i, i as u64);
        }
        assert!(b.ready(2));
        assert_eq!(b.ready_at(), Some(2)); // third arrival filled it
        assert_eq!(b.take_batch(), vec![0, 1, 2]);
        assert!(b.is_empty());
    }

    #[test]
    fn closes_on_deadline() {
        let mut b = Batcher::new(policy(100, 5));
        b.push_at(7, 0);
        assert!(!b.ready(0));
        assert!(!b.ready(5 * MS - 1));
        assert!(b.ready(5 * MS));
        assert_eq!(b.ready_at(), Some(5 * MS));
        assert_eq!(b.take_batch(), vec![7]);
    }

    #[test]
    fn deadline_tracks_oldest_not_newest() {
        let mut b = Batcher::new(policy(100, 5));
        b.push_at(1, 0);
        b.push_at(2, 4 * MS);
        // The second push must not extend the oldest request's deadline.
        assert_eq!(b.ready_at(), Some(5 * MS));
    }

    #[test]
    fn size_trigger_beats_later_deadline() {
        let mut b = Batcher::new(policy(2, 1000));
        b.push_at(1, 10);
        b.push_at(2, 20);
        // Full at t=20, long before the t=10+1s wait deadline.
        assert_eq!(b.ready_at(), Some(20));
    }

    #[test]
    fn empty_never_ready() {
        let b: Batcher<u32> = Batcher::new(policy(1, 0));
        assert!(!b.ready(u64::MAX));
        assert!(b.ready_at().is_none());
        assert!(b.deadline_in(0).is_none());
    }

    #[test]
    fn take_batch_caps_at_max() {
        let mut b = Batcher::new(policy(2, 0));
        for i in 0..5 {
            b.push_at(i, 0);
        }
        assert_eq!(b.take_batch(), vec![0, 1]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.take_batch(), vec![2, 3]);
        assert_eq!(b.take_batch(), vec![4]);
    }

    #[test]
    fn deadline_counts_down() {
        let mut b = Batcher::new(policy(10, 10));
        b.push_at(1, 0);
        assert_eq!(b.deadline_in(4 * MS), Some(6 * MS));
        // Past the deadline it clamps to zero instead of underflowing.
        assert_eq!(b.deadline_in(11 * MS), Some(0));
    }

    #[test]
    fn zero_wait_closes_immediately() {
        let mut b = Batcher::new(policy(100, 0));
        b.push_at(9, 42);
        assert!(b.ready(42));
        assert_eq!(b.ready_at(), Some(42));
    }

    #[test]
    fn policy_accessor_reflects_construction() {
        let b: Batcher<u8> = Batcher::new(policy(7, 3));
        assert_eq!(b.policy().max_batch, 7);
        assert_eq!(b.policy().max_wait_ns(), 3 * MS);
    }

    #[test]
    fn simclock_drives_the_deadline_deterministically() {
        use crate::util::{Clock, SimClock};
        let clock = SimClock::new();
        let mut b = Batcher::new(policy(100, 5));
        b.push_at('a', clock.now_ns());
        clock.advance(3 * MS);
        b.push_at('b', clock.now_ns());
        assert!(!b.ready(clock.now_ns()));
        clock.advance(2 * MS); // oldest has now waited exactly max_wait
        assert!(b.ready(clock.now_ns()));
        assert_eq!(b.take_batch(), vec!['a', 'b']);
    }
}
