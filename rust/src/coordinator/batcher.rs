//! Dynamic batcher: collects single inference requests into batches.
//!
//! Policy (vLLM-router-style, sized for this model's artifact batches):
//! a batch closes when it reaches `max_batch` requests OR the oldest
//! queued request has waited `max_wait`. The serving loop then pads the
//! batch up to the nearest compiled batch size.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Close a batch at this many requests.
    pub max_batch: usize,
    /// ... or when the oldest request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
        }
    }
}

/// An accumulating batch former. Generic over the request type so it is
/// testable without the serving stack.
#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    queue: VecDeque<(T, Instant)>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch > 0);
        Self {
            policy,
            queue: VecDeque::new(),
        }
    }

    /// Enqueue one request (records arrival time).
    pub fn push(&mut self, req: T) {
        self.queue.push_back((req, Instant::now()));
    }

    /// Enqueue with an explicit arrival instant (deterministic tests).
    pub fn push_at(&mut self, req: T, at: Instant) {
        self.queue.push_back((req, at));
    }

    /// Queued requests.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Should a batch close *now*?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.queue.front() {
            Some((_, t0)) => now.duration_since(*t0) >= self.policy.max_wait,
            None => false,
        }
    }

    /// Time until the wait deadline would fire (None when empty).
    pub fn deadline_in(&self, now: Instant) -> Option<Duration> {
        self.queue.front().map(|(_, t0)| {
            self.policy
                .max_wait
                .saturating_sub(now.duration_since(*t0))
        })
    }

    /// Pop up to `max_batch` requests as one batch (empty vec if none).
    pub fn take_batch(&mut self) -> Vec<T> {
        let n = self.queue.len().min(self.policy.max_batch);
        self.queue.drain(..n).map(|(r, _)| r).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(max_batch: usize, wait_ms: u64) -> BatchPolicy {
        BatchPolicy {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
        }
    }

    #[test]
    fn closes_on_size() {
        let mut b = Batcher::new(policy(3, 1000));
        let now = Instant::now();
        for i in 0..3 {
            b.push_at(i, now);
        }
        assert!(b.ready(now));
        assert_eq!(b.take_batch(), vec![0, 1, 2]);
        assert!(b.is_empty());
    }

    #[test]
    fn closes_on_deadline() {
        let mut b = Batcher::new(policy(100, 5));
        let t0 = Instant::now();
        b.push_at(7, t0);
        assert!(!b.ready(t0));
        assert!(b.ready(t0 + Duration::from_millis(6)));
        assert_eq!(b.take_batch(), vec![7]);
    }

    #[test]
    fn empty_never_ready() {
        let b: Batcher<u32> = Batcher::new(policy(1, 0));
        assert!(!b.ready(Instant::now()));
        assert!(b.deadline_in(Instant::now()).is_none());
    }

    #[test]
    fn take_batch_caps_at_max() {
        let mut b = Batcher::new(policy(2, 0));
        let now = Instant::now();
        for i in 0..5 {
            b.push_at(i, now);
        }
        assert_eq!(b.take_batch(), vec![0, 1]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.take_batch(), vec![2, 3]);
        assert_eq!(b.take_batch(), vec![4]);
    }

    #[test]
    fn deadline_counts_down() {
        let mut b = Batcher::new(policy(10, 10));
        let t0 = Instant::now();
        b.push_at(1, t0);
        let d = b.deadline_in(t0 + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(6));
    }
}
