//! Embedding store: the master embedding table and its crossbar-resident
//! layout.
//!
//! The offline phase (`make artifacts` + [`crate::grouping`]) decides which
//! embedding lives in which crossbar row; this store materialises that
//! layout so the online path can gather the tile contents a reduce call
//! needs with plain `memcpy`s. It also provides the pure-rust reference
//! reduction used to verify the PJRT path end-to-end.

use crate::grouping::Mapping;
use crate::util::Rng;
use crate::workload::EmbeddingId;

/// Master table + crossbar-layout view.
#[derive(Debug, Clone)]
pub struct EmbeddingStore {
    /// Embedding dimension D.
    dim: usize,
    /// Crossbar rows R.
    rows: usize,
    /// Flat master table `[n, D]`.
    table: Vec<f32>,
    /// Flat crossbar tiles `[num_groups, R, D]`, gathered per the mapping.
    tiles: Vec<f32>,
    num_groups: usize,
}

impl EmbeddingStore {
    /// Build a deterministic random table laid out per `mapping`.
    ///
    /// Values are small (~N(0, 0.05)) as trained embedding tables are.
    pub fn random(mapping: &Mapping, dim: usize, rows: usize, seed: u64) -> Self {
        let n = mapping.num_embeddings();
        let mut rng = Rng::new(seed ^ EMB_SEED_SALT);
        let table: Vec<f32> = (0..n * dim).map(|_| (rng.normal() * 0.05) as f32).collect();
        Self::from_table(mapping, dim, rows, table)
    }

    /// Build from an explicit master table (`[n, D]` row-major).
    pub fn from_table(mapping: &Mapping, dim: usize, rows: usize, table: Vec<f32>) -> Self {
        let n = mapping.num_embeddings();
        assert_eq!(table.len(), n * dim, "table size mismatch");
        assert!(
            mapping.group_size <= rows,
            "mapping group_size {} exceeds crossbar rows {rows}",
            mapping.group_size
        );
        let num_groups = mapping.num_groups();
        let mut tiles = vec![0.0f32; num_groups * rows * dim];
        for (g, members) in mapping.groups.iter().enumerate() {
            for (r, &e) in members.iter().enumerate() {
                let src = e as usize * dim;
                let dst = (g * rows + r) * dim;
                tiles[dst..dst + dim].copy_from_slice(&table[src..src + dim]);
            }
        }
        Self {
            dim,
            rows,
            table,
            tiles,
            num_groups,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn num_groups(&self) -> usize {
        self.num_groups
    }

    /// Embeddings in the master table (the catalogue size this store
    /// was laid out for).
    pub fn num_embeddings(&self) -> usize {
        self.table.len() / self.dim.max(1)
    }

    /// One embedding vector from the master table.
    pub fn embedding(&self, e: EmbeddingId) -> &[f32] {
        let off = e as usize * self.dim;
        &self.table[off..off + self.dim]
    }

    /// One crossbar tile's contents, `[R, D]` row-major.
    pub fn tile(&self, group: u32) -> &[f32] {
        let off = group as usize * self.rows * self.dim;
        &self.tiles[off..off + self.rows * self.dim]
    }

    /// Iterate `(group, tile)` pairs in group order — the extraction seam
    /// the tiered store pulls from: `crate::store::ColdTileFile` encodes
    /// these tiles into its persistent image, and the hot/DRAM caches are
    /// filled from the same walk.
    pub fn tiles(&self) -> impl Iterator<Item = (u32, &[f32])> + '_ {
        (0..self.num_groups as u32).map(move |g| (g, self.tile(g)))
    }

    /// Reference reduction: plain sum of the queried embeddings from the
    /// master table (bypasses the crossbar layout entirely). Cold-start
    /// ids beyond the catalogue contribute zero, matching the serving
    /// paths' untrained-embedding semantics.
    pub fn reduce_reference(&self, items: &[EmbeddingId]) -> Vec<f32> {
        let n = self.table.len() / self.dim.max(1);
        let mut out = vec![0.0f32; self.dim];
        for &e in items {
            if (e as usize) >= n {
                continue;
            }
            // Blocked 4-wide accumulation: same per-element sum order as
            // the scalar loop, so the result is bit-identical.
            crate::util::accum::add_assign_4wide(&mut out, self.embedding(e));
        }
        out
    }

    /// Quantize the store to `bits`-bit symmetric fixed point — the
    /// precision actually programmed into the ReRAM cells (Table I: 8-bit
    /// weights across 2-bit cells). Returns the quantized store and the
    /// scale factor (LSB value); dequantized values are `q * scale`.
    ///
    /// **Contract:** `mapping` must describe the same catalogue this
    /// store was built from — the quantized table is re-tiled per
    /// `mapping`, so a mapping over a different embedding count would
    /// silently gather the wrong rows (or truncate the table). Asserted
    /// here as `mapping.num_embeddings() * dim == table.len()`; callers
    /// that re-map (e.g. after a rebalance) must quantize against the
    /// *new* mapping only once the store has been rebuilt for it.
    pub fn quantized(&self, mapping: &crate::grouping::Mapping, bits: u32) -> (Self, f32) {
        assert!((2..=16).contains(&bits), "unsupported weight width {bits}");
        assert_eq!(
            mapping.num_embeddings() * self.dim,
            self.table.len(),
            "mapping ({} embeddings) inconsistent with the store this was built from \
             ({} x dim {})",
            mapping.num_embeddings(),
            self.table.len() / self.dim.max(1),
            self.dim
        );
        let qmax = ((1i32 << (bits - 1)) - 1) as f32;
        let absmax = self
            .table
            .iter()
            .fold(0.0f32, |acc, &x| acc.max(x.abs()))
            .max(f32::MIN_POSITIVE);
        let scale = absmax / qmax;
        let table: Vec<f32> = self
            .table
            .iter()
            .map(|&x| (x / scale).round().clamp(-qmax - 1.0, qmax) * scale)
            .collect();
        (
            Self::from_table(mapping, self.dim, self.rows, table),
            scale,
        )
    }

    /// Worst-case absolute reduction error for a `k`-lookup query at the
    /// given quantization scale: `k * scale / 2` (each row contributes at
    /// most half an LSB).
    pub fn quantization_error_bound(scale: f32, lookups: usize) -> f32 {
        0.5 * scale * lookups as f32
    }
}

/// Seed salt so the store's RNG stream is independent of the trace RNG.
const EMB_SEED_SALT: u64 = 0x0E1B_ED00_5EED_0001;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grouping::Mapping;

    fn mapping() -> Mapping {
        Mapping::from_groups(vec![vec![2, 0], vec![1, 3]], 2, 4)
    }

    #[test]
    fn tiles_follow_mapping() {
        let m = mapping();
        let table: Vec<f32> = (0..4 * 3).map(|i| i as f32).collect(); // D=3
        let s = EmbeddingStore::from_table(&m, 3, 2, table);
        // group 0 row 0 = embedding 2 -> [6,7,8]
        assert_eq!(&s.tile(0)[0..3], &[6.0, 7.0, 8.0]);
        // group 0 row 1 = embedding 0 -> [0,1,2]
        assert_eq!(&s.tile(0)[3..6], &[0.0, 1.0, 2.0]);
        // group 1 row 0 = embedding 1 -> [3,4,5]
        assert_eq!(&s.tile(1)[0..3], &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn unused_rows_zero() {
        let m = Mapping::from_groups(vec![vec![0]], 1, 1);
        let s = EmbeddingStore::from_table(&m, 2, 4, vec![1.0, 2.0]);
        // rows 1..4 of the tile are zero-padded
        assert_eq!(&s.tile(0)[2..8], &[0.0; 6]);
    }

    #[test]
    fn reference_reduce_sums() {
        let m = mapping();
        let table: Vec<f32> = (0..4 * 2).map(|i| i as f32).collect(); // D=2
        let s = EmbeddingStore::from_table(&m, 2, 2, table);
        // emb0=[0,1], emb3=[6,7] -> [6,8]
        assert_eq!(s.reduce_reference(&[0, 3]), vec![6.0, 8.0]);
        assert_eq!(s.reduce_reference(&[]), vec![0.0, 0.0]);
    }

    #[test]
    fn quantized_reduction_within_bound() {
        let m = Mapping::from_groups(vec![vec![0, 1], vec![2, 3]], 2, 4);
        let s = EmbeddingStore::random(&m, 16, 2, 7);
        let (q, scale) = s.quantized(&m, 8);
        assert!(scale > 0.0);
        let items = vec![0, 1, 2, 3];
        let exact = s.reduce_reference(&items);
        let quant = q.reduce_reference(&items);
        let bound = EmbeddingStore::quantization_error_bound(scale, items.len());
        for (a, b) in exact.iter().zip(&quant) {
            assert!(
                (a - b).abs() <= bound + 1e-6,
                "error {} exceeds bound {bound}",
                (a - b).abs()
            );
        }
    }

    #[test]
    fn quantized_values_on_grid() {
        let m = Mapping::from_groups(vec![vec![0, 1]], 2, 2);
        let s = EmbeddingStore::from_table(&m, 2, 2, vec![0.11, -0.5, 0.37, 0.02]);
        let (q, scale) = s.quantized(&m, 8);
        for &v in q.embedding(0).iter().chain(q.embedding(1)) {
            let steps = v / scale;
            assert!((steps - steps.round()).abs() < 1e-4, "off-grid value {v}");
        }
    }

    #[test]
    fn coarser_quantization_larger_error() {
        let m = Mapping::from_groups(vec![vec![0, 1], vec![2, 3]], 2, 4);
        let s = EmbeddingStore::random(&m, 16, 2, 9);
        let items = vec![0, 1, 2, 3];
        let exact = s.reduce_reference(&items);
        let err = |bits: u32| -> f32 {
            let (q, _) = s.quantized(&m, bits);
            q.reduce_reference(&items)
                .iter()
                .zip(&exact)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max)
        };
        assert!(err(4) >= err(8), "4-bit {} vs 8-bit {}", err(4), err(8));
    }

    #[test]
    #[should_panic(expected = "inconsistent with the store")]
    fn quantized_rejects_foreign_mapping() {
        // Regression: quantizing against a mapping for a different
        // catalogue used to re-tile garbage; now it dies loudly.
        let m4 = Mapping::from_groups(vec![vec![0, 1], vec![2, 3]], 2, 4);
        let s = EmbeddingStore::random(&m4, 8, 2, 3);
        let m2 = Mapping::from_groups(vec![vec![0, 1]], 2, 2);
        let _ = s.quantized(&m2, 8);
    }

    #[test]
    fn random_is_deterministic_and_small() {
        let m = mapping();
        let a = EmbeddingStore::random(&m, 8, 2, 1);
        let b = EmbeddingStore::random(&m, 8, 2, 1);
        assert_eq!(a.table, b.table);
        let max = a.table.iter().fold(0.0f32, |acc, &x| acc.max(x.abs()));
        assert!(max < 1.0, "embedding magnitude {max}");
    }
}
