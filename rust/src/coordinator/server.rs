//! Serving front-end: request router + dynamic batcher + inference
//! pipeline over the PJRT runtime and the crossbar cost model.
//!
//! Threading model: PJRT handles are not assumed `Send`, so one executor
//! thread *creates and owns* the whole pipeline (runtime, store, mapping)
//! and serves a `std::sync::mpsc` request channel; the dynamic batcher
//! amortises artifact invocations. Clients talk through a cloneable
//! [`ServerHandle`].
//!
//! Per batch the pipeline:
//! 1. plans every query into crossbar reduce passes ([`super::planner`]),
//! 2. executes the passes on the `reduce_b1` artifact and sums partials
//!    (linearity makes chunking exact),
//! 3. pads the batch to the nearest compiled size and runs `dlrm_head_b*`
//!    for the dense path,
//! 4. attaches the circuit-simulated cost of the same batch
//!    ([`crate::engine::Engine::run_batch`]) so every response carries both
//!    *numerics* (logit) and *hardware cost* (ns/pJ on the crossbar pool).

use super::batcher::{BatchPolicy, Batcher};
use super::drift::DriftMonitor;
use super::planner::Planner;
use super::store::EmbeddingStore;
use crate::engine::Engine;
use crate::obs::{names, Obs, Stage};
use crate::runtime::{DlrmParams, Runtime};
use crate::sched::{ExecStats, Scratch};
use crate::util::{Clock, WallClock};
use crate::workload::Query;
use crate::Result;
use anyhow::anyhow;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Recent-query ring capacity on the pipeline's drift monitor. Feeding
/// the ring here (not just the drift counters) is what lets downstream
/// consumers — incremental regrouping and tier admission — see the
/// traffic this pipeline actually served, overflow-group cold starts
/// included.
const DRIFT_RING_CAPACITY: usize = 2_048;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Dense features, length = manifest.dense_features.
    pub dense: Vec<f32>,
    /// Sparse lookups (embedding ids).
    pub items: Vec<u32>,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Click logit from the DLRM head.
    pub logit: f32,
    /// The reduced embedding (exposed for verification).
    pub reduced: Vec<f32>,
    /// Crossbar activations this query cost on the simulated pool.
    pub activations: u64,
    /// Wall-clock service latency (queue + execute).
    pub latency: Duration,
}

/// Cumulative status snapshot of a running single-pool server — the
/// coordinator's answer to the cluster layer's
/// [`crate::cluster::ShardStatus`], served through
/// [`ServerHandle::status`].
#[derive(Debug, Clone)]
pub struct PipelineStatus {
    /// Queries served since spawn.
    pub queries: u64,
    /// Embedding lookups served since spawn.
    pub lookups: u64,
    /// Batches the dynamic batcher closed.
    pub batches: u64,
    /// Circuit-simulated cost of everything served (sequential batches on
    /// one executor, so completion accumulates).
    pub sim: ExecStats,
    /// Current drift degradation ratio (mapping staleness signal).
    pub drift_degradation: f64,
}

/// The synchronous inference pipeline (one per executor thread).
pub struct Pipeline {
    runtime: Runtime,
    engine: Engine,
    store: EmbeddingStore,
    params: DlrmParams,
    /// Scratch for the circuit simulation.
    scratch: Scratch,
    /// Reusable tile gather buffer.
    tile_buf: Vec<f32>,
    /// Batches served since start.
    batches: u64,
    /// Batch-level circuit stats accumulated since start.
    pub sim_stats: ExecStats,
    /// Online staleness monitor (activations-per-lookup EMA vs the
    /// offline-phase baseline); `drift().regroup_due()` tells the operator
    /// the mapping has gone stale and the offline phase should re-run.
    drift: DriftMonitor,
    /// Metrics/trace sink shared with the owning backend's clients
    /// ([`Pipeline::with_obs`]); disabled by default.
    obs: Arc<Obs>,
}

impl std::fmt::Debug for Pipeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pipeline")
            .field("engine", &self.engine.name())
            .field("groups", &self.store.num_groups())
            .finish()
    }
}

impl Pipeline {
    /// Assemble a pipeline. `engine` carries the offline-phase mapping;
    /// the store is laid out to match it.
    pub fn new(runtime: Runtime, engine: Engine, store: EmbeddingStore, seed: u64) -> Result<Self> {
        let manifest = runtime.manifest().clone();
        anyhow::ensure!(
            store.dim() == manifest.embed_dim,
            "store dim {} != artifact embed_dim {}",
            store.dim(),
            manifest.embed_dim
        );
        anyhow::ensure!(
            store.rows() == manifest.xbar_rows,
            "store rows {} != artifact xbar_rows {}",
            store.rows(),
            manifest.xbar_rows
        );
        let params = DlrmParams::init(&manifest, seed);
        params.validate(&manifest)?;
        Ok(Self {
            runtime,
            engine,
            store,
            params,
            scratch: Scratch::default(),
            tile_buf: Vec::new(),
            batches: 0,
            sim_stats: ExecStats::default(),
            // Baseline = the mapping's ideal activations-per-lookup is not
            // known until traffic flows; seed with 1 activation per ~8
            // lookups (a healthy grouped mapping) and let rebaseline()
            // correct it after the offline validation run. The ring
            // window feeds regroup/tier-admission stats (the cluster
            // drift loop uses the same capacity).
            drift: DriftMonitor::with_baseline(0.125).with_window(DRIFT_RING_CAPACITY),
            obs: Obs::disabled(),
        })
    }

    /// Attach an observability handle ([`crate::obs`]): every served
    /// batch harvests scheduler / crossbar / ADC / energy metrics and
    /// the executor loop records batcher telemetry + sampled spans.
    pub fn with_obs(mut self, obs: Arc<Obs>) -> Self {
        self.obs = obs;
        self
    }

    /// The attached observability handle (disabled unless
    /// [`Pipeline::with_obs`] was called).
    pub fn obs(&self) -> &Arc<Obs> {
        &self.obs
    }

    /// The drift monitor (read-only view for operators/metrics).
    pub fn drift(&self) -> &DriftMonitor {
        &self.drift
    }

    /// Cumulative status snapshot (counters live in the sim stats).
    pub fn status(&self) -> PipelineStatus {
        PipelineStatus {
            queries: self.sim_stats.queries,
            lookups: self.sim_stats.lookups,
            batches: self.batches,
            sim: self.sim_stats.clone(),
            drift_degradation: self.drift.degradation(),
        }
    }

    /// Re-arm the drift monitor with a measured baseline
    /// (activations per lookup from an offline validation run).
    pub fn set_drift_baseline(&mut self, activations_per_lookup: f64) {
        self.drift =
            DriftMonitor::with_baseline(activations_per_lookup).with_window(DRIFT_RING_CAPACITY);
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn store(&self) -> &EmbeddingStore {
        &self.store
    }

    /// Reduce one query through the crossbar artifact (chunked passes).
    pub fn reduce_query(&mut self, query: &Query) -> Result<Vec<f32>> {
        let m = self.runtime.manifest();
        let dim = m.embed_dim;
        let planner = Planner::new(self.engine.mapping(), &self.store, m.tiles);
        let mut total = vec![0.0f32; dim];
        for pass in planner.plan(query) {
            planner.gather_tiles(&pass, &mut self.tile_buf);
            let out = self.runtime.reduce(1, &pass.masks, &self.tile_buf)?;
            anyhow::ensure!(out.len() == dim, "reduce output {} != {dim}", out.len());
            for (t, &v) in total.iter_mut().zip(&out) {
                *t += v;
            }
        }
        Ok(total)
    }

    /// Serve one batch end-to-end. Returns responses in request order.
    pub fn infer_batch(&mut self, requests: &[Request], queued_since: &[Instant]) -> Result<Vec<Response>> {
        anyhow::ensure!(!requests.is_empty(), "empty batch");
        let m = self.runtime.manifest().clone();
        let n = requests.len();

        // 1+2: per-query crossbar reduction.
        let queries: Vec<Query> = requests.iter().map(|r| Query::new(r.items.clone())).collect();
        let mut reduced_flat = Vec::with_capacity(n * m.embed_dim);
        for q in &queries {
            reduced_flat.extend(self.reduce_query(q)?);
        }

        // 3: batched DLRM head, padded to the nearest compiled size.
        let exec_b = self.runtime.pick_batch(n);
        let mut dense_flat = vec![0.0f32; exec_b * m.dense_features];
        for (i, r) in requests.iter().enumerate() {
            anyhow::ensure!(
                r.dense.len() == m.dense_features,
                "request {} dense len {} != {}",
                r.id,
                r.dense.len(),
                m.dense_features
            );
            dense_flat[i * m.dense_features..(i + 1) * m.dense_features].copy_from_slice(&r.dense);
        }
        reduced_flat.resize(exec_b * m.embed_dim, 0.0);
        let logits = self
            .runtime
            .dlrm_head(exec_b, &dense_flat, &reduced_flat, &self.params)?;
        anyhow::ensure!(logits.len() >= n, "head returned {} logits", logits.len());

        // 4: circuit-level cost of this batch on the crossbar pool.
        let sim = self.engine.run_batch(&queries, &mut self.scratch);
        self.sim_stats.accumulate(&sim);
        self.batches += 1;
        // Harvest at the batch seam — the cost is already computed.
        self.obs.record_exec(&sim);

        // 5: feed the drift monitor (mapping staleness signal).
        let mut drift_scratch = Vec::new();
        for q in &queries {
            let acts = self
                .engine
                .mapping()
                .groups_touched(&q.items, &mut drift_scratch) as u64;
            // Ring-feeding observe: cold-start ids route to the overflow
            // group via slot_of, so previously-unseen traffic is counted
            // in the recent window (and thus in tier-admission stats)
            // instead of being invisible to the policy.
            self.drift.observe_query(q, acts, q.len());
        }
        self.obs
            .gauge_set(names::DRIFT_DEGRADATION, self.drift.degradation());

        let now = Instant::now();
        let mut scratch = Vec::new();
        Ok(requests
            .iter()
            .enumerate()
            .map(|(i, r)| Response {
                id: r.id,
                logit: logits[i],
                reduced: reduced_flat[i * m.embed_dim..(i + 1) * m.embed_dim].to_vec(),
                activations: self
                    .engine
                    .mapping()
                    .groups_touched(&queries[i].items, &mut scratch) as u64,
                latency: now.duration_since(queued_since.get(i).copied().unwrap_or(now)),
            })
            .collect())
    }
}

enum Msg {
    Infer(Request, Instant, mpsc::Sender<Result<Response>>),
    Status(mpsc::Sender<PipelineStatus>),
    Shutdown,
}

/// Handle to a running server; cloneable across client threads.
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
}

impl ServerHandle {
    /// Blocking single-request inference.
    pub fn infer(&self, req: Request) -> Result<Response> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Infer(req, Instant::now(), tx))
            .map_err(|_| anyhow!("server is down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped request"))?
    }

    /// Cumulative status snapshot of the executor: everything served so
    /// far (responses already delivered). Requests still queued behind
    /// the dynamic batcher are not counted and are *not* flushed — a
    /// status poll never changes batch boundaries.
    pub fn status(&self) -> Result<PipelineStatus> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Status(tx))
            .map_err(|_| anyhow!("server is down"))?;
        rx.recv().map_err(|_| anyhow!("server dropped status request"))
    }

    /// Fire-and-collect: submit many requests, wait for all responses.
    pub fn infer_many(&self, reqs: Vec<Request>) -> Result<Vec<Response>> {
        let mut rxs = Vec::with_capacity(reqs.len());
        let now = Instant::now();
        for r in reqs {
            let (tx, rx) = mpsc::channel();
            self.tx
                .send(Msg::Infer(r, now, tx))
                .map_err(|_| anyhow!("server is down"))?;
            rxs.push(rx);
        }
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow!("server dropped request"))?)
            .collect()
    }
}

/// Scatter-gather front-end over a sharded crossbar pool
/// ([`crate::cluster`]).
///
/// Speaks the same [`Request`]/[`Response`] vocabulary as
/// [`ServerHandle`], but the embedding reduction is served cooperatively
/// by `N` shard executors (each with its own dynamic batcher) and merged
/// exactly — linearity makes the scatter-gather split lossless. The DLRM
/// head is *not* evaluated on this path: the head runs on a per-node PJRT
/// runtime, while the sharded pool is the reduction tier, so `logit` is
/// `NaN` by construction.
#[derive(Clone)]
pub struct ShardedServerHandle {
    inner: crate::cluster::ClusterHandle,
}

impl ShardedServerHandle {
    pub fn new(inner: crate::cluster::ClusterHandle) -> Self {
        Self { inner }
    }

    /// The underlying cluster client (for per-shard status queries).
    pub fn cluster(&self) -> &crate::cluster::ClusterHandle {
        &self.inner
    }

    fn response(req_id: u64, r: crate::cluster::ClusterResponse) -> Response {
        Response {
            id: req_id,
            logit: f32::NAN,
            reduced: r.reduced,
            activations: r.activations,
            latency: r.latency,
        }
    }

    /// Blocking single-request reduction across the shard pool.
    pub fn infer(&self, req: Request) -> Result<Response> {
        let r = self.inner.reduce(&req.items)?;
        Ok(Self::response(req.id, r))
    }

    /// Scatter-gather many requests; responses in request order.
    pub fn infer_many(&self, reqs: Vec<Request>) -> Result<Vec<Response>> {
        // Requests are owned, so move the item lists into queries
        // instead of cloning them (the dense path is not served here).
        let mut ids = Vec::with_capacity(reqs.len());
        let mut queries = Vec::with_capacity(reqs.len());
        for r in reqs {
            ids.push(r.id);
            queries.push(Query::new(r.items));
        }
        let results = self.inner.reduce_many(&queries)?;
        Ok(ids
            .into_iter()
            .zip(results)
            .map(|(id, r)| Self::response(id, r))
            .collect())
    }
}

/// A running server: executor thread + handle.
pub struct Server {
    handle: ServerHandle,
    join: Option<std::thread::JoinHandle<()>>,
    shutdown_tx: mpsc::Sender<Msg>,
}

impl Server {
    /// Spawn the executor thread. `make_pipeline` runs *on* that thread
    /// (PJRT handles never cross threads).
    pub fn spawn<F>(policy: BatchPolicy, make_pipeline: F) -> Result<Self>
    where
        F: FnOnce() -> Result<Pipeline> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("recross-executor".into())
            .spawn(move || {
                let mut pipeline = match make_pipeline() {
                    Ok(p) => {
                        let _ = ready_tx.send(Ok(()));
                        p
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                executor_loop(&mut pipeline, rx, policy);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during startup"))??;
        Ok(Self {
            handle: ServerHandle { tx: tx.clone() },
            join: Some(join),
            shutdown_tx: tx,
        })
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.shutdown_tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// The executor loop: drain the channel through the dynamic batcher.
/// The batcher runs on an injected [`WallClock`] here; the open-loop
/// driver ([`crate::loadgen`]) runs the identical policy on virtual time.
fn executor_loop(pipeline: &mut Pipeline, rx: mpsc::Receiver<Msg>, policy: BatchPolicy) {
    type Pending = (Request, Instant, mpsc::Sender<Result<Response>>);
    let clock = WallClock::new();
    let obs = Arc::clone(pipeline.obs());
    let mut batcher: Batcher<Pending> = Batcher::new(policy);
    loop {
        // Wait for work (or a deadline if requests are queued).
        let msg = match batcher.deadline_in(clock.now_ns()) {
            None => match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => return, // all senders gone
            },
            Some(d) => match rx.recv_timeout(Duration::from_nanos(d)) {
                Ok(m) => Some(m),
                Err(mpsc::RecvTimeoutError::Timeout) => None,
                Err(mpsc::RecvTimeoutError::Disconnected) => return,
            },
        };
        match msg {
            Some(Msg::Shutdown) => return,
            Some(Msg::Infer(req, at, resp_tx)) => {
                // The wait deadline counts from when the client *sent* the
                // request, mapped onto the executor clock's timeline.
                let at_ns = clock.instant_ns(at);
                batcher.push_at((req, at, resp_tx), at_ns);
            }
            Some(Msg::Status(reply)) => {
                // Report what has been *served* so far — queued requests
                // keep their batch-formation window; a status poll must
                // never change batch boundaries.
                let _ = reply.send(pipeline.status());
            }
            None => {}
        }
        // Serve every ready batch. The instrumentation reads the close
        // decision *after* the policy made it (depth at close, trigger
        // classification, per-request formation wait) — batch boundaries
        // are identical with observability on or off.
        while batcher.ready(clock.now_ns()) {
            let close_ns = clock.now_ns();
            let depth = batcher.len();
            let size_close = depth >= batcher.policy().max_batch;
            let batch = batcher.take_batch();
            let mut sampled: Vec<u64> = Vec::new();
            if obs.enabled() {
                obs.observe(names::BATCHER_QUEUE_DEPTH, depth as f64);
                obs.record_hist(names::BATCHER_BATCH_SIZE, batch.len() as u64, 1);
                obs.incr(
                    if size_close {
                        names::BATCHER_CLOSE_SIZE
                    } else {
                        names::BATCHER_CLOSE_DEADLINE
                    },
                    1,
                );
                for (req, at, _) in &batch {
                    let at_ns = clock.instant_ns(*at);
                    obs.observe(
                        names::BATCHER_WAIT_NS,
                        close_ns.saturating_sub(at_ns) as f64,
                    );
                    if obs.sampled(req.id) {
                        obs.span(Stage::Enqueue, req.id, 0, at_ns, close_ns);
                        sampled.push(req.id);
                    }
                }
            }
            serve_batch(pipeline, batch);
            if !sampled.is_empty() {
                let end_ns = clock.now_ns();
                for id in sampled {
                    obs.span(Stage::Execute, id, 0, close_ns, end_ns);
                }
            }
        }
    }
}

fn serve_batch(
    pipeline: &mut Pipeline,
    batch: Vec<(Request, Instant, mpsc::Sender<Result<Response>>)>,
) {
    if batch.is_empty() {
        return;
    }
    let (reqs, rest): (Vec<Request>, Vec<(Instant, mpsc::Sender<Result<Response>>)>) = batch
        .into_iter()
        .map(|(r, t, tx)| (r, (t, tx)))
        .unzip();
    let since: Vec<Instant> = rest.iter().map(|(t, _)| *t).collect();
    match pipeline.infer_batch(&reqs, &since) {
        Ok(responses) => {
            for (resp, (_, tx)) in responses.into_iter().zip(rest) {
                let _ = tx.send(Ok(resp));
            }
        }
        Err(e) => {
            // Fan the error out to every caller in the batch.
            let msg = format!("{e:#}");
            for (_, tx) in rest {
                let _ = tx.send(Err(anyhow!("{msg}")));
            }
        }
    }
}
