//! Workload-drift monitor: detects when the offline-phase mapping has
//! gone stale.
//!
//! The offline phase optimizes the mapping for the *history* distribution;
//! recommendation workloads drift (new items, shifting popularity). The
//! cheapest online staleness signal is the one the mapping directly
//! controls: **crossbar activations per lookup**. When its exponential
//! moving average degrades by more than `threshold` over the baseline the
//! offline phase achieved, the monitor reports that a regroup is due —
//! the serving layer can then rebuild the co-occurrence graph from recent
//! traffic and swap mappings at a batch boundary.

//! Two serving-loop affordances ride on top of the detector:
//!
//! * **Hysteresis** ([`DriftMonitor::with_cooldown`]): after a
//!   [`DriftMonitor::rebaseline`], `regroup_due` is suppressed until a
//!   cooldown's worth of *fresh* queries has been observed, so an
//!   oscillating window (or a swap that only partially helped) cannot
//!   re-fire a rebalance immediately after the last one landed.
//! * **Recent-query ring** ([`DriftMonitor::with_window`]): the monitor
//!   retains the last N observed queries, which is exactly the window
//!   the incremental offline path (`PreparedEngine::refresh`,
//!   `Cluster::rebalance_incremental`) consumes — the drift signal and
//!   the delta input come from the same stream.

use crate::workload::{Query, Trace};
use std::collections::VecDeque;

/// Online drift detector over activations-per-lookup.
#[derive(Debug, Clone)]
pub struct DriftMonitor {
    /// EMA smoothing factor in (0, 1]; higher = more reactive.
    alpha: f64,
    /// Baseline activations-per-lookup from the offline validation run.
    baseline: f64,
    /// Degradation ratio that triggers (e.g. 1.3 = 30% worse).
    threshold: f64,
    ema: Option<f64>,
    observed_queries: u64,
    /// Minimum queries before the monitor may trigger (EMA warm-up).
    warmup: u64,
    /// Post-rebaseline trigger suppression (queries); 0 = no hysteresis.
    cooldown: u64,
    /// True once a rebaseline has occurred: the trigger floor is then
    /// `max(warmup, cooldown)` fresh queries (equivalent to `warmup`
    /// again once the cooldown has been served).
    cooling: bool,
    /// Capacity of the recent-query ring; 0 = keep none.
    window_capacity: usize,
    recent: VecDeque<Query>,
}

impl DriftMonitor {
    /// `baseline` — activations per lookup measured on the validation
    /// trace right after the offline phase (e.g. `stats.activations as
    /// f64 / stats.lookups as f64`).
    pub fn new(baseline: f64, threshold: f64, alpha: f64, warmup: u64) -> Self {
        assert!(baseline > 0.0, "baseline must be positive");
        assert!(threshold > 1.0, "threshold is a degradation ratio > 1");
        assert!(alpha > 0.0 && alpha <= 1.0);
        Self {
            alpha,
            baseline,
            threshold,
            ema: None,
            observed_queries: 0,
            warmup,
            cooldown: 0,
            cooling: false,
            window_capacity: 0,
            recent: VecDeque::new(),
        }
    }

    /// Require at least `queries` fresh observations after each
    /// [`DriftMonitor::rebaseline`] before `regroup_due` may fire again
    /// (effective minimum is `max(warmup, cooldown)` while cooling).
    pub fn with_cooldown(mut self, queries: u64) -> Self {
        self.cooldown = queries;
        self
    }

    /// Keep the last `capacity` observed queries for the delta path;
    /// see [`DriftMonitor::recent_window`].
    pub fn with_window(mut self, capacity: usize) -> Self {
        self.window_capacity = capacity;
        self.recent = VecDeque::with_capacity(capacity);
        self
    }

    /// Defaults tuned for batch-256 serving: 30% degradation over a
    /// 1000-query warm-up with a reactive-but-stable EMA.
    pub fn with_baseline(baseline: f64) -> Self {
        Self::new(baseline, 1.3, 0.02, 1_000)
    }

    /// Record one served query.
    pub fn observe(&mut self, activations: u64, lookups: usize) {
        if lookups == 0 {
            return;
        }
        let x = activations as f64 / lookups as f64;
        self.ema = Some(match self.ema {
            None => x,
            Some(e) => e + self.alpha * (x - e),
        });
        self.observed_queries += 1;
    }

    /// [`DriftMonitor::observe`] plus ring retention: remembers `q` (up
    /// to the configured window capacity) so the incremental offline
    /// path can regroup from the same traffic that tripped the signal.
    pub fn observe_query(&mut self, q: &Query, activations: u64, lookups: usize) {
        if self.window_capacity > 0 {
            if self.recent.len() == self.window_capacity {
                self.recent.pop_front();
            }
            self.recent.push_back(q.clone());
        }
        self.observe(activations, lookups);
    }

    /// The retained recent queries as a trace over an `num_embeddings`
    /// catalogue — the window [`crate::engine::PreparedEngine::refresh`]
    /// and `Cluster::rebalance_incremental` consume. `None` when nothing
    /// is retained (no capacity configured, or right after a
    /// rebaseline).
    pub fn recent_window(&self, num_embeddings: u32) -> Option<Trace> {
        if self.recent.is_empty() {
            return None;
        }
        Some(Trace {
            num_embeddings,
            queries: self.recent.iter().cloned().collect(),
        })
    }

    /// Current EMA of activations per lookup (None before first sample).
    pub fn current(&self) -> Option<f64> {
        self.ema
    }

    /// Degradation ratio vs baseline (1.0 = as good as offline).
    pub fn degradation(&self) -> f64 {
        match self.ema {
            Some(e) => e / self.baseline,
            None => 1.0,
        }
    }

    /// True when the mapping is stale and a regroup is recommended.
    ///
    /// While cooling (between a [`DriftMonitor::rebaseline`] and the end
    /// of its cooldown) the trigger needs `max(warmup, cooldown)` fresh
    /// queries instead of just `warmup` — back-to-back rebalances on an
    /// oscillating window are suppressed by construction.
    pub fn regroup_due(&self) -> bool {
        let min_queries = if self.cooling {
            self.warmup.max(self.cooldown)
        } else {
            self.warmup
        };
        self.observed_queries >= min_queries && self.degradation() >= self.threshold
    }

    /// Queries observed since the last (re)baseline.
    pub fn observed_queries(&self) -> u64 {
        self.observed_queries
    }

    /// The configured degradation threshold (ratio > 1).
    pub fn threshold(&self) -> f64 {
        self.threshold
    }

    /// The baseline activations-per-lookup the monitor compares against.
    pub fn baseline(&self) -> f64 {
        self.baseline
    }

    /// Reset after a regroup with the new baseline.
    ///
    /// Semantics: the EMA and the query counter restart from zero (the
    /// old distribution's samples are meaningless against the new
    /// layout), the recent-query ring is cleared (the next trigger must
    /// hand only post-swap traffic to the delta path), and the monitor
    /// enters its cooldown — `regroup_due` stays false until
    /// `max(warmup, cooldown)` fresh queries have been observed, even if
    /// they are immediately as bad as before.
    pub fn rebaseline(&mut self, baseline: f64) {
        assert!(baseline > 0.0);
        self.baseline = baseline;
        self.ema = None;
        self.observed_queries = 0;
        self.recent.clear();
        self.cooling = self.cooldown > 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_workload_never_triggers() {
        let mut m = DriftMonitor::new(2.0, 1.3, 0.05, 100);
        for _ in 0..5_000 {
            m.observe(20, 10); // exactly baseline
        }
        assert!(!m.regroup_due());
        assert!((m.degradation() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn drifted_workload_triggers_after_warmup() {
        let mut m = DriftMonitor::new(2.0, 1.3, 0.05, 100);
        // 2x worse than baseline.
        for i in 0..1_000 {
            m.observe(40, 10);
            if i < 99 {
                assert!(!m.regroup_due(), "triggered during warmup at {i}");
            }
        }
        assert!(m.regroup_due());
        assert!(m.degradation() > 1.9);
    }

    #[test]
    fn ema_recovers_when_drift_passes() {
        let mut m = DriftMonitor::new(2.0, 1.3, 0.1, 10);
        for _ in 0..200 {
            m.observe(40, 10);
        }
        assert!(m.regroup_due());
        for _ in 0..500 {
            m.observe(20, 10);
        }
        assert!(!m.regroup_due(), "EMA should have recovered");
    }

    #[test]
    fn rebaseline_resets() {
        let mut m = DriftMonitor::new(2.0, 1.3, 0.1, 10);
        for _ in 0..100 {
            m.observe(40, 10);
        }
        assert!(m.regroup_due());
        m.rebaseline(4.0);
        assert!(!m.regroup_due());
        assert_eq!(m.current(), None);
    }

    #[test]
    fn oscillating_window_respects_cooldown() {
        // Reactive EMA so degradation registers immediately; warmup 10,
        // cooldown 500.
        let mut m = DriftMonitor::new(2.0, 1.3, 0.5, 10).with_cooldown(500);
        for _ in 0..20 {
            m.observe(40, 10);
        }
        assert!(m.regroup_due(), "first trigger gated by warmup only");
        m.rebaseline(2.0);
        assert!(!m.regroup_due());
        // The window oscillates right back to bad traffic: the monitor
        // must NOT re-fire until the cooldown's worth of fresh queries.
        for i in 0..499 {
            m.observe(40, 10);
            assert!(!m.regroup_due(), "re-fired during cooldown at {i}");
        }
        m.observe(40, 10);
        assert!(m.regroup_due(), "persistent drift must re-fire after cooldown");
    }

    #[test]
    fn recent_window_keeps_last_capacity_queries() {
        let mut m = DriftMonitor::new(2.0, 1.3, 0.5, 10).with_window(3);
        assert!(m.recent_window(8).is_none());
        for i in 0..5u32 {
            m.observe_query(&Query::new(vec![i]), 1, 1);
        }
        let t = m.recent_window(8).unwrap();
        assert_eq!(t.num_embeddings, 8);
        let items: Vec<u32> = t.queries.iter().map(|q| q.items[0]).collect();
        assert_eq!(items, vec![2, 3, 4], "ring keeps the newest queries");
        assert_eq!(m.observed_queries(), 5);
        m.rebaseline(2.0);
        assert!(m.recent_window(8).is_none(), "ring cleared on rebaseline");
    }

    #[test]
    fn empty_queries_ignored() {
        let mut m = DriftMonitor::with_baseline(2.0);
        m.observe(0, 0);
        assert_eq!(m.current(), None);
    }

    #[test]
    fn detects_real_mapping_staleness() {
        // End-to-end: an engine prepared on one catalogue layout serves a
        // *differently seeded* catalogue (new co-purchase structure) —
        // activations per lookup must degrade enough to trigger.
        use crate::config::Config;
        use crate::engine::{Engine, Scheme};
        use crate::graph::CoGraph;
        use crate::workload::{generate, DatasetSpec};
        let spec = DatasetSpec::by_name("software").unwrap().scaled(0.05);
        let (history, eval) = generate(&spec, 1_500, 300, 42);
        let (_, drifted) = generate(&spec, 1_500, 300, 999); // new structure
        let cfg = Config::paper_default();
        let graph = CoGraph::build(&history);
        let engine = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);

        let base_stats = engine.run_trace(&eval, 256);
        let baseline = base_stats.activations as f64 / base_stats.lookups as f64;
        let mut m = DriftMonitor::new(baseline, 1.3, 0.05, 50);

        let mut scratch = Vec::new();
        for q in &drifted.queries {
            let acts = engine.mapping().groups_touched(&q.items, &mut scratch) as u64;
            m.observe(acts, q.len());
        }
        assert!(
            m.regroup_due(),
            "drifted catalogue not detected: degradation {:.2}",
            m.degradation()
        );
    }
}
