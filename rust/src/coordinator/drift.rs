//! Workload-drift monitor: detects when the offline-phase mapping has
//! gone stale.
//!
//! The offline phase optimizes the mapping for the *history* distribution;
//! recommendation workloads drift (new items, shifting popularity). The
//! cheapest online staleness signal is the one the mapping directly
//! controls: **crossbar activations per lookup**. When its exponential
//! moving average degrades by more than `threshold` over the baseline the
//! offline phase achieved, the monitor reports that a regroup is due —
//! the serving layer can then rebuild the co-occurrence graph from recent
//! traffic and swap mappings at a batch boundary.

/// Online drift detector over activations-per-lookup.
#[derive(Debug, Clone)]
pub struct DriftMonitor {
    /// EMA smoothing factor in (0, 1]; higher = more reactive.
    alpha: f64,
    /// Baseline activations-per-lookup from the offline validation run.
    baseline: f64,
    /// Degradation ratio that triggers (e.g. 1.3 = 30% worse).
    threshold: f64,
    ema: Option<f64>,
    observed_queries: u64,
    /// Minimum queries before the monitor may trigger (EMA warm-up).
    warmup: u64,
}

impl DriftMonitor {
    /// `baseline` — activations per lookup measured on the validation
    /// trace right after the offline phase (e.g. `stats.activations as
    /// f64 / stats.lookups as f64`).
    pub fn new(baseline: f64, threshold: f64, alpha: f64, warmup: u64) -> Self {
        assert!(baseline > 0.0, "baseline must be positive");
        assert!(threshold > 1.0, "threshold is a degradation ratio > 1");
        assert!(alpha > 0.0 && alpha <= 1.0);
        Self {
            alpha,
            baseline,
            threshold,
            ema: None,
            observed_queries: 0,
            warmup,
        }
    }

    /// Defaults tuned for batch-256 serving: 30% degradation over a
    /// 1000-query warm-up with a reactive-but-stable EMA.
    pub fn with_baseline(baseline: f64) -> Self {
        Self::new(baseline, 1.3, 0.02, 1_000)
    }

    /// Record one served query.
    pub fn observe(&mut self, activations: u64, lookups: usize) {
        if lookups == 0 {
            return;
        }
        let x = activations as f64 / lookups as f64;
        self.ema = Some(match self.ema {
            None => x,
            Some(e) => e + self.alpha * (x - e),
        });
        self.observed_queries += 1;
    }

    /// Current EMA of activations per lookup (None before first sample).
    pub fn current(&self) -> Option<f64> {
        self.ema
    }

    /// Degradation ratio vs baseline (1.0 = as good as offline).
    pub fn degradation(&self) -> f64 {
        match self.ema {
            Some(e) => e / self.baseline,
            None => 1.0,
        }
    }

    /// True when the mapping is stale and a regroup is recommended.
    pub fn regroup_due(&self) -> bool {
        self.observed_queries >= self.warmup && self.degradation() >= self.threshold
    }

    /// Queries observed since the last (re)baseline.
    pub fn observed_queries(&self) -> u64 {
        self.observed_queries
    }

    /// The configured degradation threshold (ratio > 1).
    pub fn threshold(&self) -> f64 {
        self.threshold
    }

    /// The baseline activations-per-lookup the monitor compares against.
    pub fn baseline(&self) -> f64 {
        self.baseline
    }

    /// Reset after a regroup with the new baseline.
    pub fn rebaseline(&mut self, baseline: f64) {
        assert!(baseline > 0.0);
        self.baseline = baseline;
        self.ema = None;
        self.observed_queries = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_workload_never_triggers() {
        let mut m = DriftMonitor::new(2.0, 1.3, 0.05, 100);
        for _ in 0..5_000 {
            m.observe(20, 10); // exactly baseline
        }
        assert!(!m.regroup_due());
        assert!((m.degradation() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn drifted_workload_triggers_after_warmup() {
        let mut m = DriftMonitor::new(2.0, 1.3, 0.05, 100);
        // 2x worse than baseline.
        for i in 0..1_000 {
            m.observe(40, 10);
            if i < 99 {
                assert!(!m.regroup_due(), "triggered during warmup at {i}");
            }
        }
        assert!(m.regroup_due());
        assert!(m.degradation() > 1.9);
    }

    #[test]
    fn ema_recovers_when_drift_passes() {
        let mut m = DriftMonitor::new(2.0, 1.3, 0.1, 10);
        for _ in 0..200 {
            m.observe(40, 10);
        }
        assert!(m.regroup_due());
        for _ in 0..500 {
            m.observe(20, 10);
        }
        assert!(!m.regroup_due(), "EMA should have recovered");
    }

    #[test]
    fn rebaseline_resets() {
        let mut m = DriftMonitor::new(2.0, 1.3, 0.1, 10);
        for _ in 0..100 {
            m.observe(40, 10);
        }
        assert!(m.regroup_due());
        m.rebaseline(4.0);
        assert!(!m.regroup_due());
        assert_eq!(m.current(), None);
    }

    #[test]
    fn empty_queries_ignored() {
        let mut m = DriftMonitor::with_baseline(2.0);
        m.observe(0, 0);
        assert_eq!(m.current(), None);
    }

    #[test]
    fn detects_real_mapping_staleness() {
        // End-to-end: an engine prepared on one catalogue layout serves a
        // *differently seeded* catalogue (new co-purchase structure) —
        // activations per lookup must degrade enough to trigger.
        use crate::config::Config;
        use crate::engine::{Engine, Scheme};
        use crate::graph::CoGraph;
        use crate::workload::{generate, DatasetSpec};
        let spec = DatasetSpec::by_name("software").unwrap().scaled(0.05);
        let (history, eval) = generate(&spec, 1_500, 300, 42);
        let (_, drifted) = generate(&spec, 1_500, 300, 999); // new structure
        let cfg = Config::paper_default();
        let graph = CoGraph::build(&history);
        let engine = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);

        let base_stats = engine.run_trace(&eval, 256);
        let baseline = base_stats.activations as f64 / base_stats.lookups as f64;
        let mut m = DriftMonitor::new(baseline, 1.3, 0.05, 50);

        let mut scratch = Vec::new();
        for q in &drifted.queries {
            let acts = engine.mapping().groups_touched(&q.items, &mut scratch) as u64;
            m.observe(acts, q.len());
        }
        assert!(
            m.regroup_due(),
            "drifted catalogue not detected: degradation {:.2}",
            m.degradation()
        );
    }
}
