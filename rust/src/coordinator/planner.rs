//! Reduction planner: turns a query into concrete PJRT reduce calls.
//!
//! The `reduce_b{B}` artifact has a fixed tile capacity `T` (crossbars per
//! call). A query touching `k` crossbars is planned as `ceil(k/T)` *passes*;
//! each pass gathers up to `T` tile contents plus the matching wordline
//! masks, and the pass results are summed (the reduction is linear, so
//! splitting is exact — verified in the integration tests).
//!
//! This is the numeric twin of the scheduler's activation sets: the same
//! `(group, rows)` decomposition drives both the circuit-cost simulation
//! and the actual PJRT execution.

use super::store::EmbeddingStore;
use crate::grouping::Mapping;
use crate::workload::Query;

/// One reduce-artifact invocation worth of work for one query.
#[derive(Debug, Clone, PartialEq)]
pub struct ReducePass {
    /// Groups gathered into this pass's tile slots (<= T of them).
    pub groups: Vec<u32>,
    /// Wordline mask per tile slot, `[T, R]` flattened; zero-padded slots.
    pub masks: Vec<f32>,
}

/// Planner bound to a mapping + store + artifact tile capacity.
#[derive(Debug)]
pub struct Planner<'a> {
    mapping: &'a Mapping,
    store: &'a EmbeddingStore,
    /// Tile slots per reduce call (artifact `T`).
    tiles_per_call: usize,
}

impl<'a> Planner<'a> {
    pub fn new(mapping: &'a Mapping, store: &'a EmbeddingStore, tiles_per_call: usize) -> Self {
        assert!(tiles_per_call > 0);
        Self {
            mapping,
            store,
            tiles_per_call,
        }
    }

    /// Plan one query into passes. Cold-start ids beyond the catalogue
    /// have no stored row and are skipped (their reduction contribution
    /// is the zero vector of an untrained embedding).
    pub fn plan(&self, query: &Query) -> Vec<ReducePass> {
        let rows = self.store.rows();
        // (group, row) pairs, grouped.
        let mut slots: Vec<(u32, u16)> = query
            .items
            .iter()
            .filter(|&&e| (e as usize) < self.mapping.num_embeddings())
            .map(|&e| {
                let s = self.mapping.slot_of(e);
                (s.group, s.row)
            })
            .collect();
        slots.sort_unstable();

        let mut passes = Vec::new();
        let mut i = 0;
        while i < slots.len() {
            let mut groups = Vec::with_capacity(self.tiles_per_call);
            let mut masks = vec![0.0f32; self.tiles_per_call * rows];
            while i < slots.len() && groups.len() < self.tiles_per_call {
                let g = slots[i].0;
                let slot_idx = groups.len();
                groups.push(g);
                while i < slots.len() && slots[i].0 == g {
                    masks[slot_idx * rows + slots[i].1 as usize] = 1.0;
                    i += 1;
                }
            }
            passes.push(ReducePass { groups, masks });
        }
        passes
    }

    /// Gather the tile contents for a pass, `[T, R, D]` flattened with
    /// zero padding for unused slots. `out` is resized as needed so the
    /// hot loop can reuse one buffer.
    pub fn gather_tiles(&self, pass: &ReducePass, out: &mut Vec<f32>) {
        let rows = self.store.rows();
        let dim = self.store.dim();
        let tile_elems = rows * dim;
        out.clear();
        out.resize(self.tiles_per_call * tile_elems, 0.0);
        for (slot, &g) in pass.groups.iter().enumerate() {
            out[slot * tile_elems..(slot + 1) * tile_elems].copy_from_slice(self.store.tile(g));
        }
    }

    /// Total crossbar activations this query costs (== number of gathered
    /// tile slots across passes; the scheduler counts the same quantity).
    pub fn activations(&self, query: &Query) -> usize {
        let mut scratch = Vec::new();
        self.mapping.groups_touched(&query.items, &mut scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grouping::Mapping;

    fn setup() -> (Mapping, EmbeddingStore) {
        // 8 embeddings, 4 groups of 2, D=2, R=4 (padded rows).
        let m = Mapping::from_groups(
            vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]],
            2,
            8,
        );
        let table: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let s = EmbeddingStore::from_table(&m, 2, 4, table);
        (m, s)
    }

    #[test]
    fn single_pass_when_fits() {
        let (m, s) = setup();
        let p = Planner::new(&m, &s, 2);
        let passes = p.plan(&Query::new(vec![0, 1, 2]));
        assert_eq!(passes.len(), 1);
        assert_eq!(passes[0].groups, vec![0, 1]);
        // slot 0 rows 0,1 set (emb 0,1); slot 1 row 0 set (emb 2).
        assert_eq!(passes[0].masks[0], 1.0);
        assert_eq!(passes[0].masks[1], 1.0);
        assert_eq!(passes[0].masks[4], 1.0);
        assert_eq!(passes[0].masks[5], 0.0);
    }

    #[test]
    fn chunks_over_capacity() {
        let (m, s) = setup();
        let p = Planner::new(&m, &s, 2);
        let passes = p.plan(&Query::new(vec![0, 2, 4, 6]));
        assert_eq!(passes.len(), 2);
        assert_eq!(passes[0].groups, vec![0, 1]);
        assert_eq!(passes[1].groups, vec![2, 3]);
    }

    #[test]
    fn gather_pads_unused_slots() {
        let (m, s) = setup();
        let p = Planner::new(&m, &s, 2);
        let passes = p.plan(&Query::new(vec![0]));
        let mut tiles = Vec::new();
        p.gather_tiles(&passes[0], &mut tiles);
        assert_eq!(tiles.len(), 2 * 4 * 2); // T*R*D
        // slot 0 row 0 = emb 0 = [0,1]
        assert_eq!(&tiles[0..2], &[0.0, 1.0]);
        // slot 1 entirely zero
        assert!(tiles[8..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn mask_weighted_sum_equals_reference() {
        // The planned masks applied to gathered tiles must equal the
        // reference reduction (the rust-side mirror of the PJRT path).
        let (m, s) = setup();
        let p = Planner::new(&m, &s, 2);
        let q = Query::new(vec![1, 3, 4, 7]);
        let mut total = vec![0.0f32; s.dim()];
        let mut tiles = Vec::new();
        for pass in p.plan(&q) {
            p.gather_tiles(&pass, &mut tiles);
            // manual mask @ tiles
            for t in 0..2 {
                for r in 0..4 {
                    let w = pass.masks[t * 4 + r];
                    if w != 0.0 {
                        for d in 0..2 {
                            total[d] += w * tiles[(t * 4 + r) * 2 + d];
                        }
                    }
                }
            }
        }
        assert_eq!(total, s.reduce_reference(&q.items));
    }

    #[test]
    fn activations_match_groups_touched() {
        let (m, s) = setup();
        let p = Planner::new(&m, &s, 2);
        assert_eq!(p.activations(&Query::new(vec![0, 1])), 1);
        assert_eq!(p.activations(&Query::new(vec![0, 2, 4, 6])), 4);
    }
}
