//! Host-platform energy models for the Fig. 11 comparison.
//!
//! The paper measures a CPU-only platform (i7-10700F, MERCI's energy
//! profiler) and a CPU+GPU platform (RTX 3090, NVML) running the same
//! embedding reductions, and reports ReCross beating them by ~363x and
//! ~1144x on energy. Neither machine nor profiler is available here, so
//! both platforms are modelled analytically from first principles
//! (DESIGN.md §Substitutions): embedding reduction is memory-bound, so
//! energy is dominated by data movement —
//!
//! * **CPU-only**: every lookup moves one embedding vector over DDR4 and
//!   accumulates it in core. `E = bits * dram_pj_per_bit + cpu_accum_pj`.
//! * **CPU+GPU**: embeddings live in host memory (the 4 TB-scale tables of
//!   real DLRMs do not fit in VRAM); each lookup additionally crosses
//!   PCIe, then the GPU accumulates. The GPU's higher idle/static draw per
//!   useful op makes the combined platform *less* efficient for this
//!   memory-bound stage — matching the paper's CPU+GPU < CPU-only result.

use crate::workload::Trace;
use crate::xbar::HostParams;

/// Energy/time estimate for a host platform run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostStats {
    pub energy_pj: f64,
    pub time_ns: f64,
    pub lookups: u64,
}

impl HostStats {
    pub fn pj_per_lookup(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.energy_pj / self.lookups as f64
        }
    }
}

/// Which host platform to model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostPlatform {
    /// CPU-only (the paper's i7-10700F + MERCI profiler setup).
    CpuOnly,
    /// CPU + discrete GPU over PCIe (the paper's RTX 3090 setup).
    CpuGpu,
}

impl HostPlatform {
    pub fn name(&self) -> &'static str {
        match self {
            HostPlatform::CpuOnly => "cpu",
            HostPlatform::CpuGpu => "cpu+gpu",
        }
    }
}

/// Analytical host energy model.
#[derive(Debug, Clone)]
pub struct HostModel {
    p: HostParams,
    /// Bits per embedding vector as stored in host memory (fp32 elements —
    /// hosts don't get the crossbar's 8-bit quantization for free).
    vector_bits: f64,
}

impl HostModel {
    /// `embedding_dim` — features per embedding (host side stores fp32).
    pub fn new(p: &HostParams, embedding_dim: usize) -> Self {
        Self {
            p: p.clone(),
            vector_bits: (embedding_dim * 32) as f64,
        }
    }

    /// Energy of one lookup on a platform.
    pub fn lookup_pj(&self, platform: HostPlatform) -> f64 {
        let dram = self.vector_bits * self.p.dram_pj_per_bit;
        match platform {
            HostPlatform::CpuOnly => dram + self.p.cpu_accum_pj,
            HostPlatform::CpuGpu => {
                dram + self.vector_bits * self.p.pcie_pj_per_bit + self.p.gpu_accum_pj
            }
        }
    }

    /// Run a whole trace. Time model: CPU lookups are serial DRAM random
    /// accesses with modest MLP overlap (4 in flight); the GPU path
    /// overlaps better (16) but pays PCIe latency per batch — both remain
    /// orders of magnitude above the crossbar, as the paper observes.
    pub fn run_trace(&self, trace: &Trace, platform: HostPlatform) -> HostStats {
        let lookups = trace.total_lookups() as u64;
        let energy_pj = lookups as f64 * self.lookup_pj(platform);
        let overlap = match platform {
            HostPlatform::CpuOnly => 4.0,
            HostPlatform::CpuGpu => 16.0,
        };
        let time_ns = lookups as f64 * self.p.dram_access_ns / overlap;
        HostStats {
            energy_pj,
            time_ns,
            lookups,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Query, Trace};

    fn trace() -> Trace {
        Trace {
            num_embeddings: 10,
            queries: vec![Query::new(vec![0, 1, 2]), Query::new(vec![3, 4])],
        }
    }

    #[test]
    fn gpu_platform_less_efficient_per_lookup() {
        let m = HostModel::new(&HostParams::default(), 16);
        assert!(m.lookup_pj(HostPlatform::CpuGpu) > m.lookup_pj(HostPlatform::CpuOnly));
    }

    #[test]
    fn energy_scales_with_lookups() {
        let m = HostModel::new(&HostParams::default(), 16);
        let s = m.run_trace(&trace(), HostPlatform::CpuOnly);
        assert_eq!(s.lookups, 5);
        assert!((s.energy_pj - 5.0 * m.lookup_pj(HostPlatform::CpuOnly)).abs() < 1e-9);
        assert!(s.pj_per_lookup() > 0.0);
    }

    #[test]
    fn host_orders_of_magnitude_above_crossbar_cell() {
        // Fig. 11 sanity: one host lookup must cost >> one crossbar
        // activation (hundreds of pJ vs the ~10 nJ DDR fetch).
        use crate::config::HardwareConfig;
        use crate::xbar::{CircuitParams, CrossbarModel};
        let host = HostModel::new(&HostParams::default(), 16);
        let xbar = CrossbarModel::new(&HardwareConfig::default(), &CircuitParams::default());
        let mac = xbar.activation(8, true); // one activation serves ~8 lookups
        let host_8 = 8.0 * host.lookup_pj(HostPlatform::CpuOnly);
        assert!(
            host_8 > 20.0 * mac.energy_pj,
            "host {host_8} pJ vs crossbar {} pJ",
            mac.energy_pj
        );
    }
}
