//! The [`Backend`] trait and its three implementations.
//!
//! A backend is *where a reduction runs*: the live single-pool server
//! ([`SinglePool`], one executor thread + PJRT numerics), the live
//! sharded pool ([`Sharded`], scatter-gather over N executor threads),
//! or the thread-free deterministic simulator ([`SimBackend`], the
//! discrete-event path the open-loop driver measures). All three speak
//! one object-safe vocabulary, so callers hold a `&dyn Backend` and the
//! choice becomes a deployment-time knob — exactly how RecNMP-style
//! serving stacks treat their memory tiers.
//!
//! Every backend also exposes its **deterministic timing twin** through
//! [`Backend::run_batch_timed`]: the discrete-event cost of a batch on
//! one executor's local replica table. That is what lets
//! [`crate::loadgen::drive`] measure any backend — live or simulated —
//! on virtual time, bit-reproducibly.

use crate::allocation::Replication;
use crate::cluster::{
    self, Cluster, ClusterConfig, ClusterHandle, PoolShared, ShardPlan, ShardingMode,
};
use crate::coordinator::{
    build_pipeline_with_store, BatchPolicy, EmbeddingStore, Request, Server, ServerHandle,
};
use crate::engine::{Engine, Scheme};
use crate::grouping::Mapping;
use crate::obs::{names, Alert, MetricsSnapshot, Obs};
use crate::sched::{ExecStats, Scheduler, Scratch};
use crate::workload::{EmbeddingId, Query};
use crate::xbar::CrossbarModel;
use crate::Result;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One reduced query, backend-agnostic: the vocabulary shared by the
/// live single pool's responses, the cluster's scatter-gather merges,
/// and the simulator's reference reductions.
#[derive(Debug, Clone)]
pub struct Reduction {
    /// Position of the query in the submitted batch.
    pub id: u64,
    /// The reduced embedding, length `D`.
    pub reduced: Vec<f32>,
    /// Crossbar activations the query cost (summed across executors).
    pub activations: u64,
    /// Distinct executors the query touched (1 on the single pool).
    pub fanout: usize,
    /// Wall-clock latency (zero on simulated backends).
    pub latency: Duration,
}

/// Cumulative per-executor status snapshot, backend-agnostic.
#[derive(Debug, Clone)]
pub struct BackendStatus {
    pub executor: u32,
    /// Logical groups this executor hosts (owned + replicas).
    pub hosted_groups: usize,
    /// Placement epoch (always 0 outside rebalancing pools).
    pub epoch: u64,
    /// (Sub-)queries served since spawn.
    pub queries: u64,
    /// Embedding lookups served since spawn.
    pub lookups: u64,
    /// Batches the executor's dynamic batcher closed.
    pub batches: u64,
    /// Circuit-simulated cost of everything served.
    pub sim: ExecStats,
}

/// A serving backend: N executors that reduce embedding queries.
///
/// Object-safe by design — entry points hold `&dyn Backend` and stay
/// agnostic of where the reduction runs. The contract:
///
/// * [`Backend::scatter`] and [`Backend::run_batch_timed`] together form
///   the backend's *deterministic timing twin*: scatter is
///   ownership-pinned (the reproducible stand-in for any load-adaptive
///   routing the live path does), and `run_batch_timed` prices one batch
///   on one executor's **local** replica table via the discrete-event
///   scheduler. Both are pure functions of the backend's configuration —
///   no wall clock, no thread timing.
/// * [`Backend::reduce_many`] serves real numerics (and may be
///   load-adaptive, threaded, or PJRT-backed); responses always come
///   back in submission order and merge partials in ascending executor
///   order, so the float summation order is deterministic for a fixed
///   scatter.
/// * [`Backend::status`] reports one row per executor.
pub trait Backend {
    /// Short human-readable backend label (for reports).
    fn name(&self) -> &str;

    /// Independent executors (dynamic batchers) this backend runs.
    fn executors(&self) -> usize;

    /// Split a query's items into per-executor sub-lists (length =
    /// [`Backend::executors`]; untouched executors get an empty list,
    /// item order is preserved within each executor).
    fn scatter(&self, items: &[EmbeddingId]) -> Vec<Vec<EmbeddingId>>;

    /// Discrete-event cost of one batch on `executor`'s local replica
    /// table. Pushes each query's finish offset (ns relative to batch
    /// start) into `finish_rel`, one entry per query in order.
    fn run_batch_timed(
        &self,
        executor: usize,
        queries: &[Query],
        scratch: &mut Scratch,
        finish_rel: &mut Vec<f64>,
    ) -> ExecStats;

    /// `(ns, pJ)` charged per extra executor merged at the front end
    /// (one digital vector add per partial beyond the first).
    fn merge_cost(&self) -> (f64, f64);

    /// Reduce a batch of queries; responses in submission order.
    fn reduce_many(&self, queries: &[Query]) -> Result<Vec<Reduction>>;

    /// Cumulative status, one row per executor. Stateless backends (the
    /// simulator) report zeroed counters — a drive's accounting lives in
    /// its [`crate::loadgen::OpenLoopReport`], not here.
    fn status(&self) -> Result<Vec<BackendStatus>>;

    /// The observability handle attached to this backend, if any.
    /// Backends that support [`crate::obs`] override this; the default
    /// (no handle) keeps the trait object-safe and implementors free of
    /// obs plumbing.
    fn obs(&self) -> Option<&Arc<Obs>> {
        None
    }

    /// One schema-versioned metrics snapshot for this backend: the
    /// `status.*` counters distilled from [`Backend::status`] (summed
    /// across executors), merged with everything the attached [`Obs`]
    /// handle recorded. The two families stay under distinct prefixes —
    /// on live backends the executor counters and the obs harvest cover
    /// the *same* batches, so folding them into one name would double
    /// count. Every backend emits the same `recross.metrics` schema, so
    /// sim and live snapshots are directly diffable.
    fn metrics(&self) -> Result<MetricsSnapshot> {
        let mut snap = MetricsSnapshot::new(self.name());
        let mut energy_pj = 0.0f64;
        let mut epoch = 0u64;
        let mut counter = |name: &str, by: u64| {
            *snap.counters.entry(name.to_string()).or_insert(0) += by;
        };
        for row in self.status()? {
            counter("status.queries", row.queries);
            counter("status.lookups", row.lookups);
            counter("status.batches", row.batches);
            counter("status.activations", row.sim.activations);
            counter("status.single_row", row.sim.single_row_activations);
            counter("status.adc_mac", row.sim.mac_activations);
            counter("status.adc_read", row.sim.read_activations);
            energy_pj += row.sim.energy_pj;
            epoch = epoch.max(row.epoch);
        }
        snap.gauges.insert("status.energy_pj".to_string(), energy_pj);
        snap.gauges.insert("status.epoch".to_string(), epoch as f64);
        if let Some(obs) = self.obs() {
            snap.merge(&obs.snapshot(self.name()));
        }
        Ok(snap)
    }

    /// Alerts this backend has raised on its own behalf. The default is
    /// empty: backends are passive metric sources, and SLO evaluation
    /// lives in the watch loop's [`crate::obs::Watcher`], which diffs
    /// [`Backend::metrics`] snapshots externally. A backend with an
    /// embedded tracker (e.g. a future autoscaler) overrides this to
    /// surface its own `recross.alerts` v1 events; the default keeps the
    /// trait object-safe and implementors alert-free.
    fn alerts(&self) -> Vec<Alert> {
        Vec::new()
    }
}

fn zero_status(executor: u32, hosted_groups: usize) -> BackendStatus {
    BackendStatus {
        executor,
        hosted_groups,
        epoch: 0,
        queries: 0,
        lookups: 0,
        batches: 0,
        sim: ExecStats::default(),
    }
}

// ---------------------------------------------------------------------
// SimBackend: the thread-free deterministic twin.
// ---------------------------------------------------------------------

/// The deterministic discrete-event backend: no threads, no wall clock,
/// no PJRT. This is what the open-loop driver ([`crate::loadgen::drive`])
/// measures, and what benches sweep. Borrow-built from a prepared
/// deployment ([`super::Prepared::sim`] /
/// [`super::Prepared::sim_sharded`]), an [`Engine`], or raw parts.
///
/// Numerics are optional: attach a table with
/// [`SimBackend::with_store`] and [`SimBackend::reduce_many`] serves the
/// exact reference reduction (per-executor partials merged in ascending
/// executor order, mirroring the live cluster's gather); without a store
/// it reports an error — the backend is timing-only.
#[derive(Debug)]
pub struct SimBackend<'a> {
    mapping: &'a Mapping,
    /// Global replica table — the single executor's schedule domain.
    replication: &'a Replication,
    model: &'a CrossbarModel,
    dynamic_switch: bool,
    /// Sharded layout; `None` = one executor over the global table.
    plan: Option<ShardPlan>,
    /// Per-executor local replica tables (ownership-pinned; sharded only).
    locals: Vec<Replication>,
    store: Option<&'a EmbeddingStore>,
    label: String,
    /// Metrics/trace sink; `None` (the default) costs nothing.
    obs: Option<Arc<Obs>>,
}

impl<'a> SimBackend<'a> {
    /// Single-executor simulator over explicit offline products.
    pub fn from_parts(
        mapping: &'a Mapping,
        replication: &'a Replication,
        model: &'a CrossbarModel,
        dynamic_switch: bool,
    ) -> Self {
        assert_eq!(
            mapping.num_groups(),
            replication.copies.len(),
            "replication plan does not match mapping"
        );
        Self {
            mapping,
            replication,
            model,
            dynamic_switch,
            plan: None,
            locals: Vec::new(),
            store: None,
            label: "sim".to_string(),
            obs: None,
        }
    }

    /// Single-executor simulator over a prepared engine. (The four-accessor
    /// wiring the rest of the crate used to hand-roll lives here and in
    /// [`Engine::scheduler`] only.)
    ///
    /// Panics on an nMARS engine: the timed discrete-event path prices
    /// the MAC dataflow only, and MAC costs must never be reported
    /// under an nMARS label. ([`super::Prepared::sim`] returns the same
    /// refusal as a graceful `Err`.)
    pub fn of_engine(engine: &'a Engine) -> Self {
        assert!(
            engine.scheme() != Scheme::Nmars,
            "the timing twin serves the MAC dataflow; scheme {:?} is not supported here",
            engine.scheme().name()
        );
        Self::from_parts(
            engine.mapping(),
            engine.replication(),
            engine.model(),
            engine.dynamic_switch(),
        )
    }

    /// Single-executor simulator over a shared pool snapshot.
    pub fn single(shared: &'a PoolShared) -> Self {
        Self::from_parts(
            &shared.mapping,
            &shared.replication,
            &shared.model,
            shared.dynamic_switch,
        )
    }

    /// Sharded simulator over a shared pool snapshot: one executor per
    /// shard of `plan`, each scheduling on its ownership-pinned local
    /// replica table (the deterministic twin of the live sharded pool).
    pub fn sharded(shared: &'a PoolShared, plan: ShardPlan) -> Self {
        Self::single(shared).into_sharded(plan)
    }

    /// Turn a single-executor simulator into the `plan`-sharded one.
    pub fn into_sharded(mut self, plan: ShardPlan) -> Self {
        assert_eq!(
            plan.num_groups(),
            self.mapping.num_groups(),
            "plan covers {} groups, mapping has {}",
            plan.num_groups(),
            self.mapping.num_groups()
        );
        let pinned = crate::cluster::ReplicaPlan::pinned(&plan, self.replication);
        self.locals = (0..plan.shards)
            .map(|s| pinned.local_replication(s as u32, self.replication.batch_size))
            .collect();
        self.label = format!("sim-sharded({})", plan.shards);
        self.plan = Some(plan);
        self
    }

    /// Attach an embedding table so [`Backend::reduce_many`] can serve
    /// exact reference reductions.
    ///
    /// **Contract** (the same one [`super::Prepared::install_store`]
    /// and `EmbeddingStore::quantized` document): the store must have
    /// been laid out for *this* backend's mapping. Catalogue-size and
    /// group-count mismatches are rejected here; equal-sized stores
    /// tiled by a different mapping cannot be detected cheaply and
    /// remain the caller's responsibility.
    pub fn with_store(mut self, store: &'a EmbeddingStore) -> Self {
        assert_eq!(
            store.num_groups(),
            self.mapping.num_groups(),
            "store covers {} groups, mapping has {}",
            store.num_groups(),
            self.mapping.num_groups()
        );
        assert_eq!(
            store.num_embeddings(),
            self.mapping.num_embeddings(),
            "store holds {} embeddings, mapping catalogues {}",
            store.num_embeddings(),
            self.mapping.num_embeddings()
        );
        self.store = Some(store);
        self
    }

    /// Attach an observability handle: timed batches harvest scheduler /
    /// crossbar / ADC / energy metrics through it, and the open-loop
    /// driver ([`crate::loadgen::drive`]) picks it up via
    /// [`Backend::obs`] to record batcher and span telemetry on the
    /// same registry. A disabled handle records nothing.
    pub fn with_obs(mut self, obs: Arc<Obs>) -> Self {
        self.obs = Some(obs);
        self
    }

    fn executor_replication(&self, executor: usize) -> &Replication {
        match self.plan {
            None => self.replication,
            Some(_) => &self.locals[executor],
        }
    }
}

impl Backend for SimBackend<'_> {
    fn name(&self) -> &str {
        &self.label
    }

    fn executors(&self) -> usize {
        self.plan.as_ref().map_or(1, |p| p.shards)
    }

    fn scatter(&self, items: &[EmbeddingId]) -> Vec<Vec<EmbeddingId>> {
        match &self.plan {
            None => vec![items.to_vec()],
            Some(plan) => plan.split_items(self.mapping, items),
        }
    }

    fn run_batch_timed(
        &self,
        executor: usize,
        queries: &[Query],
        scratch: &mut Scratch,
        finish_rel: &mut Vec<f64>,
    ) -> ExecStats {
        // The scheduler is a pure function of (mapping, replicas, model);
        // rebuilding it per batch costs O(groups) — the same order as the
        // batch's own busy-table reset — and keeps the backend borrow-only.
        let sched = Scheduler::new(
            self.mapping,
            self.executor_replication(executor),
            self.model,
            self.dynamic_switch,
        );
        match &self.obs {
            Some(obs) if obs.enabled() => {
                // Harvest at the batch seam: every recorded value is one
                // the schedule already computed, so the schedule itself
                // is bit-identical with recording on or off.
                let (busy_flat, bus_flat) = sched.uses_flat_tables();
                let before = scratch.comparisons();
                let st = sched.run_batch_timed(queries, scratch, finish_rel);
                obs.record_exec(&st);
                obs.incr(
                    names::SCHED_COMPARISONS,
                    scratch.comparisons().saturating_sub(before),
                );
                for flat in [busy_flat, bus_flat] {
                    obs.incr(
                        if flat {
                            names::SCHED_PATH_FLAT
                        } else {
                            names::SCHED_PATH_TREE
                        },
                        1,
                    );
                }
                st
            }
            _ => sched.run_batch_timed(queries, scratch, finish_rel),
        }
    }

    fn merge_cost(&self) -> (f64, f64) {
        self.model.vector_add()
    }

    fn reduce_many(&self, queries: &[Query]) -> Result<Vec<Reduction>> {
        let store = self.store.ok_or_else(|| {
            anyhow::anyhow!(
                "this SimBackend is timing-only; attach a table with with_store() to reduce"
            )
        })?;
        let mut out = Vec::with_capacity(queries.len());
        let mut gscratch = Vec::new();
        for (i, q) in queries.iter().enumerate() {
            let mut reduced = vec![0.0f32; store.dim()];
            let mut activations = 0u64;
            let mut fanout = 0usize;
            // Per-executor partials merged in ascending executor order —
            // the same float summation order as the live cluster gather.
            for items in self.scatter(&q.items) {
                if items.is_empty() {
                    continue;
                }
                fanout += 1;
                activations += self.mapping.groups_touched(&items, &mut gscratch) as u64;
                let partial = store.reduce_reference(&items);
                for (o, &v) in reduced.iter_mut().zip(&partial) {
                    *o += v;
                }
            }
            out.push(Reduction {
                id: i as u64,
                reduced,
                activations,
                fanout,
                latency: Duration::ZERO,
            });
        }
        Ok(out)
    }

    fn status(&self) -> Result<Vec<BackendStatus>> {
        // The simulator is stateless across calls: counters are always
        // zero (each drive's accounting is in its OpenLoopReport) and
        // placement is ownership-pinned, so each executor hosts exactly
        // the groups it owns.
        Ok(match &self.plan {
            None => vec![zero_status(0, self.mapping.num_groups())],
            Some(plan) => plan
                .group_counts()
                .into_iter()
                .enumerate()
                .map(|(s, n)| zero_status(s as u32, n))
                .collect(),
        })
    }

    fn obs(&self) -> Option<&Arc<Obs>> {
        self.obs.as_ref()
    }
}

// ---------------------------------------------------------------------
// SinglePool: the live single-pool server (PJRT numerics).
// ---------------------------------------------------------------------

/// The live single-pool backend: one executor thread owning the whole
/// pipeline (PJRT runtime + engine + store) behind a dynamic batcher.
/// Requires AOT artifacts; spawn via [`SinglePool::spawn`].
pub struct SinglePool {
    server: Server,
    shared: PoolShared,
    scheme: Scheme,
    dense_features: usize,
    /// Shared with the executor thread's pipeline: the executor records,
    /// clients snapshot. Disabled unless `config.obs.enabled`.
    obs: Arc<Obs>,
}

impl SinglePool {
    /// Spawn the executor thread from a prepared deployment. The offline
    /// phase is **not** re-run: the prepared engine moves onto the
    /// executor thread (PJRT handles are created there and never cross
    /// threads).
    pub fn spawn(prepared: super::Prepared, policy: BatchPolicy) -> Result<Self> {
        crate::runtime::require_artifacts(&prepared.config().artifacts_dir)?;
        let shared = PoolShared::from_engine(prepared.engine());
        let scheme = prepared.scheme();
        let dense_features = prepared.config().workload.dense_features;
        let obs = Obs::from_config(&prepared.config().obs);
        let (cfg, offline, store) = prepared.into_offline();
        let pipe_obs = Arc::clone(&obs);
        let server = Server::spawn(policy, move || {
            build_pipeline_with_store(&cfg, offline, store).map(|p| p.with_obs(pipe_obs))
        })?;
        Ok(Self {
            server,
            shared,
            scheme,
            dense_features,
            obs,
        })
    }

    /// The full request/response client (dense features + logits); the
    /// [`Backend`] impl covers the reduce-only vocabulary.
    pub fn handle(&self) -> ServerHandle {
        self.server.handle()
    }

    /// Dense features each request must carry (from the config).
    pub fn dense_features(&self) -> usize {
        self.dense_features
    }
}

impl Backend for SinglePool {
    fn name(&self) -> &str {
        "single-pool"
    }

    fn executors(&self) -> usize {
        1
    }

    fn scatter(&self, items: &[EmbeddingId]) -> Vec<Vec<EmbeddingId>> {
        vec![items.to_vec()]
    }

    fn run_batch_timed(
        &self,
        executor: usize,
        queries: &[Query],
        scratch: &mut Scratch,
        finish_rel: &mut Vec<f64>,
    ) -> ExecStats {
        // The live nMARS demo is a supported closed-loop path, but the
        // timed discrete-event loop prices MAC only — refuse rather
        // than report MAC costs under an nMARS label.
        assert!(
            self.scheme != Scheme::Nmars,
            "the timing twin serves the MAC dataflow; scheme {:?} is not supported here",
            self.scheme.name()
        );
        // The timing twin is exactly the single-executor simulator over
        // the shared pool snapshot — one wiring, not a second copy.
        SimBackend::single(&self.shared).run_batch_timed(executor, queries, scratch, finish_rel)
    }

    fn merge_cost(&self) -> (f64, f64) {
        self.shared.model.vector_add()
    }

    fn reduce_many(&self, queries: &[Query]) -> Result<Vec<Reduction>> {
        let reqs: Vec<Request> = queries
            .iter()
            .enumerate()
            .map(|(i, q)| Request {
                id: i as u64,
                dense: vec![0.0; self.dense_features],
                items: q.items.clone(),
            })
            .collect();
        Ok(self
            .handle()
            .infer_many(reqs)?
            .into_iter()
            .map(|r| Reduction {
                id: r.id,
                reduced: r.reduced,
                activations: r.activations,
                fanout: 1,
                latency: r.latency,
            })
            .collect())
    }

    fn status(&self) -> Result<Vec<BackendStatus>> {
        let s = self.handle().status()?;
        Ok(vec![BackendStatus {
            executor: 0,
            hosted_groups: self.shared.mapping.num_groups(),
            epoch: 0,
            queries: s.queries,
            lookups: s.lookups,
            batches: s.batches,
            sim: s.sim,
        }])
    }

    fn obs(&self) -> Option<&Arc<Obs>> {
        Some(&self.obs)
    }
}

// ---------------------------------------------------------------------
// Sharded: the live scatter-gather pool.
// ---------------------------------------------------------------------

/// The timing twin's view of one placement epoch: the ownership plan
/// the scatter pins to and the matching per-executor pinned local
/// replica tables. Kept together so one snapshot's plan and locals
/// always share an epoch. (A rebalance racing a long timed drive can
/// still flip the epoch *between* snapshots; mispricing is bounded to
/// phantom single copies via `local_replication`'s `.max(1)` clamp —
/// driving the twin concurrently with rebalances is not a supported
/// measurement.)
struct TwinSnapshot {
    epoch: u64,
    plan: Arc<ShardPlan>,
    locals: Arc<Vec<Replication>>,
}

/// The live sharded backend: N executor threads, each owning its slice
/// of the table behind its own dynamic batcher, fronted by the
/// scatter-gather client. Placement/routing behaviour is the typed
/// [`ShardingMode`] (pinned / replica-routed / rebalancing), not a pair
/// of bools. Spawn via [`Sharded::spawn`].
pub struct Sharded {
    cluster: Cluster,
    handle: ClusterHandle,
    mode: ShardingMode,
    label: String,
    /// Shared with the cluster and every minted handle: scatter-gather
    /// clients record, callers snapshot. Disabled unless
    /// `config.obs.enabled`.
    obs: Arc<Obs>,
    /// Per-epoch timing-twin snapshot, cached so
    /// [`Backend::run_batch_timed`] does not rebuild O(groups) local
    /// tables every batch (the per-sub-batch rebuild PR 2 removed from
    /// the shard executors). Refreshed lazily after an epoch swap.
    twin: Mutex<TwinSnapshot>,
}

impl Sharded {
    /// Partition the prepared deployment's table per `ccfg` and spawn
    /// the shard executors. The offline phase is reused, not re-run; the
    /// prepared bundle stays borrowed so the caller keeps its traces for
    /// driving and verification.
    pub fn spawn(prepared: &super::Prepared, ccfg: &ClusterConfig) -> Result<Self> {
        let obs = Obs::from_config(&prepared.config().obs);
        let mut cluster = cluster::assemble_cluster(
            prepared.engine(),
            prepared.history(),
            prepared.eval(),
            prepared.store(),
            ccfg,
        )?;
        // Attach before minting any handle so every scatter-gather
        // client shares the sink.
        cluster.attach_obs(Arc::clone(&obs));
        let handle = cluster.handle();
        let table = handle.routes();
        let twin = Mutex::new(Self::twin_snapshot(&cluster, &table));
        Ok(Self {
            cluster,
            handle,
            mode: ccfg.mode,
            label: format!("sharded({})", ccfg.shards),
            obs,
            twin,
        })
    }

    /// Build the timing twin's view of one routing-table snapshot.
    ///
    /// The locals come from the **ownership-pinned** placement over the
    /// epoch's plan — not the live spread placement — because the
    /// twin's scatter is pinned too ([`Backend::scatter`]): pricing an
    /// owner's batches on a spread table whose copies never receive
    /// pinned traffic would systematically inflate the twin's tails and
    /// break `drive(&Sharded) == drive(&SimBackend::sharded)` for the
    /// same plan.
    fn twin_snapshot(cluster: &Cluster, table: &crate::cluster::RouteTable) -> TwinSnapshot {
        let shared = cluster.shared();
        let pinned = crate::cluster::ReplicaPlan::pinned(&table.plan, &shared.replication);
        let locals: Vec<Replication> = (0..cluster.num_shards())
            .map(|s| pinned.local_replication(s as u32, shared.replication.batch_size))
            .collect();
        TwinSnapshot {
            epoch: table.epoch,
            plan: Arc::clone(&table.plan),
            locals: Arc::new(locals),
        }
    }

    /// Check the routing table for an epoch flip and return the current
    /// `(plan, locals)` snapshot. Called per *batch* (run_batch_timed),
    /// where the routing-table read is amortised; the per-*query*
    /// scatter reads the cached snapshot without touching the routing
    /// lock ([`Sharded::twin_plan`]).
    fn refresh_twin(&self) -> (Arc<ShardPlan>, Arc<Vec<Replication>>) {
        let table = self.handle.routes();
        let mut cached = self.twin.lock().expect("twin lock poisoned");
        if cached.epoch != table.epoch {
            *cached = Self::twin_snapshot(&self.cluster, &table);
        }
        (Arc::clone(&cached.plan), Arc::clone(&cached.locals))
    }

    /// The cached snapshot's plan, with no routing-table access — the
    /// scatter hot path (one call per query) pays a single mutex lock.
    /// The snapshot advances at batch boundaries via
    /// [`Sharded::refresh_twin`].
    fn twin_plan(&self) -> Arc<ShardPlan> {
        Arc::clone(&self.twin.lock().expect("twin lock poisoned").plan)
    }

    /// The running cluster (plan, epoch, rebalance entry point).
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// The cloneable scatter-gather client.
    pub fn handle(&self) -> ClusterHandle {
        self.handle.clone()
    }

    /// The configured placement/routing mode.
    pub fn mode(&self) -> ShardingMode {
        self.mode
    }

    /// Unwrap into the bare cluster (legacy [`Cluster::build`] callers).
    pub fn into_cluster(self) -> Cluster {
        self.cluster
    }
}

impl Backend for Sharded {
    fn name(&self) -> &str {
        &self.label
    }

    fn executors(&self) -> usize {
        self.cluster.num_shards()
    }

    /// Ownership-pinned scatter — the deterministic twin. The live
    /// [`Backend::reduce_many`] path may route replicated groups by
    /// power-of-two-choices; the timing twin pins them so identical
    /// inputs always price identically.
    fn scatter(&self, items: &[EmbeddingId]) -> Vec<Vec<EmbeddingId>> {
        self.twin_plan()
            .split_items(&self.cluster.shared().mapping, items)
    }

    fn run_batch_timed(
        &self,
        executor: usize,
        queries: &[Query],
        scratch: &mut Scratch,
        finish_rel: &mut Vec<f64>,
    ) -> ExecStats {
        let shared = self.cluster.shared();
        // The executor's schedule domain is its *local* pinned replica
        // table under the current placement epoch (cached across
        // batches, coherent with the scatter's plan).
        let (_, locals) = self.refresh_twin();
        Scheduler::new(
            &shared.mapping,
            &locals[executor],
            &shared.model,
            shared.dynamic_switch,
        )
        .run_batch_timed(queries, scratch, finish_rel)
    }

    fn merge_cost(&self) -> (f64, f64) {
        self.cluster.shared().model.vector_add()
    }

    fn reduce_many(&self, queries: &[Query]) -> Result<Vec<Reduction>> {
        Ok(self
            .handle
            .reduce_many(queries)?
            .into_iter()
            .map(|r| Reduction {
                id: r.id,
                reduced: r.reduced,
                activations: r.activations,
                fanout: r.fanout,
                latency: r.latency,
            })
            .collect())
    }

    fn status(&self) -> Result<Vec<BackendStatus>> {
        Ok(self
            .handle
            .shard_status()?
            .into_iter()
            .map(|s| BackendStatus {
                executor: s.shard,
                // ShardStatus::owned_groups counts the shard's
                // materialised tiles — owned *and* replicas — despite
                // its legacy name.
                hosted_groups: s.owned_groups,
                epoch: s.epoch,
                queries: s.sub_queries,
                lookups: s.lookups,
                batches: s.batches,
                sim: s.sim,
            })
            .collect())
    }

    fn obs(&self) -> Option<&Arc<Obs>> {
        Some(&self.obs)
    }
}
