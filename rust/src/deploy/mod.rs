//! The deployment facade: **one builder, one [`Backend`] trait, every
//! entry point a thin client**.
//!
//! Before this module existed, every consumer of the serving stack (the
//! CLI, benches, tests, examples) hand-assembled the same pipeline —
//! `Config` overrides → offline phase → four-accessor scheduler wiring →
//! pool sharing → shard planning — with subtly different defaults per
//! call site. `deploy` makes that one typed flow:
//!
//! 1. [`Deployment`] — the builder. Point it at a [`Config`], choose a
//!    [`Scheme`] and scale, and [`Deployment::build`] runs the offline
//!    phase (co-occurrence graph → Algorithm 1 grouping → Eq. 1
//!    replication) exactly once.
//! 2. [`Prepared`] — the resulting bundle: the engine, the history/eval
//!    traces the placement was derived from, and the lazily-materialised
//!    embedding table. Everything downstream borrows from here.
//! 3. [`Backend`] — the object-safe serving interface with three
//!    implementations: [`SinglePool`] (live, PJRT numerics),
//!    [`Sharded`] (live scatter-gather pool, [`ShardingMode`]-typed
//!    placement), and [`SimBackend`] (the deterministic discrete-event
//!    path [`crate::loadgen::drive`] measures).
//!
//! Configuration precedence is a single chain (see [`crate::config`]):
//! built-in defaults < TOML file < explicitly passed CLI flags
//! ([`Config::overlay_cli`]) < programmatic overrides
//! ([`Deployment::workload`] and friends).
//!
//! ```no_run
//! use recross::config::Config;
//! use recross::deploy::Deployment;
//! use recross::engine::Scheme;
//! use recross::loadgen::{drive, Arrivals};
//!
//! # fn main() -> anyhow::Result<()> {
//! let prepared = Deployment::of(Config::open_loop_default())
//!     .scheme(Scheme::ReCross)
//!     .scale(0.05)
//!     .build()?;
//! // Deterministic timing of open-loop traffic on the simulated backend:
//! let backend = prepared.sim()?;
//! let queries = &prepared.eval().queries;
//! let arrivals = Arrivals::poisson(50_000.0, 7).take(queries.len());
//! let report = drive(&backend, queries, &arrivals, &prepared.batch_policy(32));
//! println!("p99 = {} ns", report.percentile_ns(99.0));
//! # Ok(()) }
//! ```

pub mod backend;
pub mod tiered;

pub use backend::{Backend, BackendStatus, Reduction, Sharded, SimBackend, SinglePool};
pub use crate::cluster::ShardingMode;
pub use tiered::Tiered;

use crate::cluster::ShardPlan;
use crate::config::{Config, WorkloadConfig};
use crate::coordinator::{
    build_pipeline_with_store, BatchPolicy, EmbeddingStore, OfflinePhase, Pipeline,
};
use crate::engine::{Engine, Scheme};
use crate::sched::Scheduler;
use crate::workload::Trace;
use crate::Result;
use std::sync::OnceLock;

/// Builder for a prepared serving deployment. See the [module
/// docs](self) for the full lifecycle.
#[derive(Debug, Clone)]
pub struct Deployment {
    cfg: Config,
    scheme: Scheme,
    scale: f64,
}

impl Deployment {
    /// Start from a configuration (already TOML/CLI-overlaid if the
    /// caller wants those layers). Defaults: [`Scheme::ReCross`] at
    /// paper scale (1.0).
    pub fn of(cfg: Config) -> Self {
        Self {
            cfg,
            scheme: Scheme::ReCross,
            scale: 1.0,
        }
    }

    /// Select the serving scheme (mapping + replication + ADC policy).
    pub fn scheme(mut self, scheme: Scheme) -> Self {
        self.scheme = scheme;
        self
    }

    /// Shrink the dataset (1.0 = paper size).
    pub fn scale(mut self, scale: f64) -> Self {
        self.scale = scale;
        self
    }

    /// Programmatically replace the workload section — the top layer of
    /// the config precedence chain.
    pub fn workload(mut self, workload: WorkloadConfig) -> Self {
        self.cfg.workload = workload;
        self
    }

    /// Validate the config and run the offline phase once.
    pub fn build(self) -> Result<Prepared> {
        self.cfg.validate()?;
        anyhow::ensure!(
            self.scale > 0.0,
            "deployment scale must be positive, got {}",
            self.scale
        );
        let offline = OfflinePhase::run(&self.cfg, self.scheme, self.scale)?;
        Ok(Prepared {
            cfg: self.cfg,
            scale: self.scale,
            offline,
            store: OnceLock::new(),
        })
    }
}

/// A built deployment: the offline phase's products, ready to back any
/// [`Backend`]. Owns the engine, the history/eval traces, and the
/// (lazily materialised) embedding table.
#[derive(Debug)]
pub struct Prepared {
    cfg: Config,
    scale: f64,
    offline: OfflinePhase,
    /// Lazily-built embedding table (or one installed by
    /// [`Prepared::install_store`]); the offline phase itself never
    /// needs the numerics.
    store: OnceLock<EmbeddingStore>,
}

impl Prepared {
    /// The configuration this deployment was built from.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// The dataset scale the offline phase ran at.
    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// The serving scheme.
    pub fn scheme(&self) -> Scheme {
        self.offline.engine.scheme()
    }

    /// The prepared engine (mapping, replication, cost model).
    pub fn engine(&self) -> &Engine {
        &self.offline.engine
    }

    /// The lookup history the offline phase learned from.
    pub fn history(&self) -> &Trace {
        &self.offline.history
    }

    /// The held-out evaluation trace.
    pub fn eval(&self) -> &Trace {
        &self.offline.eval
    }

    /// A scheduler over the engine's offline products (the blessed
    /// replacement for the four-accessor `Scheduler::new` dance).
    pub fn scheduler(&self) -> Scheduler<'_> {
        self.offline.engine.scheduler()
    }

    /// The configured dynamic-batcher policy (`scheme.max_wait_us`) with
    /// a caller-chosen batch cap.
    pub fn batch_policy(&self, max_batch: usize) -> BatchPolicy {
        BatchPolicy::from_config(&self.cfg, max_batch)
    }

    /// The embedding table, laid out per the mapping. Built on first use
    /// (deterministic in `workload.seed`) unless
    /// [`Prepared::install_store`] supplied one.
    pub fn store(&self) -> &EmbeddingStore {
        self.store.get_or_init(|| {
            EmbeddingStore::random(
                self.offline.engine.mapping(),
                self.cfg.hardware.embedding_dim,
                self.cfg.hardware.xbar_rows,
                self.cfg.workload.seed,
            )
        })
    }

    /// Install an explicit embedding table (trained weights, an
    /// integer-valued test table, ...) instead of the deterministic
    /// random one. Fails if a table was already materialised.
    ///
    /// **Contract:** the store must have been laid out for this
    /// deployment's mapping. Catalogue/group/dimension mismatches are
    /// rejected; an equal-sized store tiled by a *different* mapping
    /// cannot be detected cheaply and remains the caller's
    /// responsibility (the same contract `EmbeddingStore::quantized`
    /// documents).
    pub fn install_store(&self, store: EmbeddingStore) -> Result<()> {
        let mapping = self.offline.engine.mapping();
        anyhow::ensure!(
            store.num_groups() == mapping.num_groups(),
            "store covers {} groups, mapping has {}",
            store.num_groups(),
            mapping.num_groups()
        );
        anyhow::ensure!(
            store.num_embeddings() == mapping.num_embeddings(),
            "store holds {} embeddings, mapping catalogues {}",
            store.num_embeddings(),
            mapping.num_embeddings()
        );
        anyhow::ensure!(
            store.dim() == self.cfg.hardware.embedding_dim,
            "store dim {} != configured embedding_dim {}",
            store.dim(),
            self.cfg.hardware.embedding_dim
        );
        self.store
            .set(store)
            .map_err(|_| anyhow::anyhow!("embedding table already materialised"))
    }

    /// The deterministic single-executor simulator backend.
    ///
    /// Errors on [`Scheme::Nmars`]: the discrete-event driver serves the
    /// MAC dataflow only.
    pub fn sim(&self) -> Result<SimBackend<'_>> {
        self.ensure_mac("the open-loop driver")?;
        Ok(SimBackend::of_engine(&self.offline.engine))
    }

    /// The deterministic sharded simulator backend: `shards` executors
    /// over a locality partition of the offline history (`slack` is the
    /// partitioner's balance slack).
    pub fn sim_sharded(&self, shards: usize, slack: f64) -> Result<SimBackend<'_>> {
        self.ensure_mac("the open-loop driver")?;
        anyhow::ensure!(shards > 0, "need at least one shard");
        anyhow::ensure!(slack >= 0.0, "slack must be non-negative");
        let plan = ShardPlan::by_locality(
            self.offline.engine.mapping(),
            &self.offline.history,
            shards,
            slack,
        );
        Ok(SimBackend::of_engine(&self.offline.engine).into_sharded(plan))
    }

    /// The deterministic sharded simulator over an explicit plan.
    pub fn sim_with_plan(&self, plan: ShardPlan) -> Result<SimBackend<'_>> {
        self.ensure_mac("the open-loop driver")?;
        Ok(SimBackend::of_engine(&self.offline.engine).into_sharded(plan))
    }

    /// The deterministic tiered backend ([`Tiered`]): the
    /// single-executor simulator over a [`crate::store::TieredStore`]
    /// sized by `config.store`, with the hot tier seeded from Algorithm
    /// 1's group frequencies over the offline history and per-tier miss
    /// costs folded into the timing twin. Reductions stay bit-identical
    /// to [`Prepared::sim`]'s; only costs change.
    pub fn sim_tiered(&self) -> Result<Tiered<'_>> {
        self.ensure_mac("the open-loop driver")?;
        let mapping = self.offline.engine.mapping();
        let freqs = crate::allocation::group_frequencies(mapping, &self.offline.history);
        let store = crate::store::TieredStore::build(
            self.store(),
            &freqs,
            crate::store::TierPolicy::from_config(&self.cfg.store),
            crate::store::TierCostModel::from_config(&self.cfg.store),
        );
        Ok(Tiered::new(
            SimBackend::of_engine(&self.offline.engine),
            mapping,
            store,
            self.cfg.store.replan_batches,
        ))
    }

    fn ensure_mac(&self, who: &str) -> Result<()> {
        anyhow::ensure!(
            self.scheme() != Scheme::Nmars,
            "{who} serves the MAC dataflow; scheme {:?} is not supported here",
            self.scheme().name()
        );
        Ok(())
    }

    /// Consume into the pieces the live single-pool server moves onto
    /// its executor thread. The third element is any table the caller
    /// installed ([`Prepared::install_store`]) or that was already
    /// materialised — live pipelines must honor it, never silently
    /// rebuild a random one over it.
    pub fn into_offline(self) -> (Config, OfflinePhase, Option<EmbeddingStore>) {
        (self.cfg, self.offline, self.store.into_inner())
    }

    /// Consume into `(config, offline, store)`, materialising the store
    /// if it never was (legacy [`crate::cluster::Cluster::build`]-style
    /// bundles).
    pub fn into_bundle_parts(self) -> (Config, OfflinePhase, EmbeddingStore) {
        // Touch the lazy cell so into_inner always has a value.
        let _ = self.store();
        let store = self.store.into_inner().expect("store just materialised");
        (self.cfg, self.offline, store)
    }

    /// Build the synchronous inference pipeline on the current thread
    /// (PJRT runtime included; requires artifacts). An installed table
    /// is honored (and validated against the artifact manifest).
    pub fn into_pipeline(self) -> Result<Pipeline> {
        let (cfg, offline, store) = self.into_offline();
        build_pipeline_with_store(&cfg, offline, store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> Config {
        let mut cfg = Config::paper_default();
        cfg.workload.history_queries = 300;
        cfg.workload.eval_queries = 60;
        cfg
    }

    #[test]
    fn builder_runs_offline_once_and_exposes_products() {
        let prepared = Deployment::of(tiny_cfg())
            .scheme(Scheme::ReCross)
            .scale(0.02)
            .build()
            .unwrap();
        assert_eq!(prepared.scheme(), Scheme::ReCross);
        assert_eq!(prepared.history().queries.len(), 300);
        assert_eq!(prepared.eval().queries.len(), 60);
        assert!(prepared.engine().mapping().num_groups() > 0);
        // The scheduler is buildable and serves a batch.
        let mut scratch = crate::sched::Scratch::default();
        let stats = prepared
            .scheduler()
            .run_batch(&prepared.eval().queries[..8], &mut scratch);
        assert_eq!(stats.queries, 8);
    }

    #[test]
    fn workload_override_is_the_top_layer() {
        let mut w = tiny_cfg().workload;
        w.dataset = "automotive".to_string();
        let prepared = Deployment::of(tiny_cfg())
            .workload(w)
            .scale(0.02)
            .build()
            .unwrap();
        assert_eq!(prepared.config().workload.dataset, "automotive");
    }

    #[test]
    fn nmars_is_refused_by_the_sim_backends() {
        let prepared = Deployment::of(tiny_cfg())
            .scheme(Scheme::Nmars)
            .scale(0.02)
            .build()
            .unwrap();
        assert!(prepared.sim().is_err());
        assert!(prepared.sim_sharded(2, 0.10).is_err());
    }

    #[test]
    fn invalid_builds_are_rejected() {
        assert!(Deployment::of(tiny_cfg()).scale(0.0).build().is_err());
        let mut cfg = tiny_cfg();
        cfg.workload.dataset = "books".into();
        assert!(Deployment::of(cfg).scale(0.02).build().is_err());
    }

    #[test]
    fn tiered_backend_matches_flat_values_and_prices_misses() {
        let mut cfg = tiny_cfg();
        cfg.store.hot_tiles = 1;
        cfg.store.dram_tiles = 1;
        let prepared = Deployment::of(cfg).scale(0.02).build().unwrap();
        let tiered = prepared.sim_tiered().unwrap();
        let flat = prepared.sim().unwrap().with_store(prepared.store());
        let queries = &prepared.eval().queries[..16];
        let a = tiered.reduce_many(queries).unwrap();
        let b = flat.reduce_many(queries).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                x.reduced.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                y.reduced.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "tiering changed values"
            );
        }
        // With a 1-tile hot tier the timed batch pays modeled fetches on
        // top of the identical crossbar schedule.
        let mut scratch = crate::sched::Scratch::default();
        let (mut f1, mut f2) = (Vec::new(), Vec::new());
        let st_flat = flat.run_batch_timed(0, queries, &mut scratch, &mut f1);
        let st_tier = tiered.run_batch_timed(0, queries, &mut scratch, &mut f2);
        assert!(st_tier.completion_ns >= st_flat.completion_ns);
        assert!(tiered.access().total() > 0);
        // Nmars is refused like every other sim constructor.
        let nm = Deployment::of(tiny_cfg())
            .scheme(Scheme::Nmars)
            .scale(0.02)
            .build()
            .unwrap();
        assert!(nm.sim_tiered().is_err());
    }

    #[test]
    fn store_is_lazy_and_installable_once() {
        let prepared = Deployment::of(tiny_cfg()).scale(0.02).build().unwrap();
        let dim = prepared.config().hardware.embedding_dim;
        assert_eq!(prepared.store().dim(), dim);
        // Already materialised -> install fails.
        let other = EmbeddingStore::random(prepared.engine().mapping(), dim, 64, 1);
        assert!(prepared.install_store(other).is_err());
    }
}
