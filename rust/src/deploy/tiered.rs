//! The [`Tiered`] backend: the deterministic simulator over a
//! [`TieredStore`] — capacity-constrained serving where misses cost
//! modeled time.
//!
//! Timing composes, it is not forked: the inner single-executor
//! [`SimBackend`] prices the crossbar schedule exactly as the untiered
//! path does, then each query's *distinct-tile* fetch cost
//! ([`TieredStore::charge_query`]) is added to its finish offset. The
//! batch's completion stretches by the **maximum** per-query fetch cost,
//! not the sum — tile fetches for different queries overlap (DRAM and
//! file reads pipeline against crossbar service), but a query cannot
//! finish before its own tiles arrived. With every touched group hot,
//! both adjustments are zero and the backend is ns-for-ns identical to
//! [`super::Prepared::sim`].
//!
//! Every served query also lands in a `DriftMonitor` recent-query ring —
//! including cold-start ids that `Mapping::slot_of` routes to the
//! overflow group, so a flood of previously-unseen traffic is *visible*
//! to admission instead of silently thrashing the cold tier. Every
//! `replan_batches` batches the ring is histogrammed
//! (`allocation::group_frequencies`) and [`TieredStore::adapt`] applies
//! its deterministic promotion/eviction pass.
//!
//! Values are the tiered store's reductions — bit-identical to the flat
//! store by the [`crate::store`] contract — so `reduce_many` agrees with
//! every other backend while the timing twin prices the tier walk.

use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::backend::{Backend, BackendStatus, Reduction};
use super::SimBackend;
use crate::coordinator::DriftMonitor;
use crate::grouping::Mapping;
use crate::obs::{names, Obs};
use crate::sched::{ExecStats, Scratch};
use crate::store::{Tier, TierAccess, TieredStore};
use crate::workload::{EmbeddingId, Query};
use crate::Result;

/// Recent-query ring capacity backing tier admission — the same window
/// the cluster's drift loop uses.
const TIER_RING_CAPACITY: usize = 2_048;

/// Mutable serving state behind the `&self` backend surface (the
/// [`super::Sharded`] twin-snapshot `Mutex` precedent): the tier map and
/// caches evolve as batches are served, but `run_batch_timed` is `&self`
/// by trait contract.
struct TierState {
    store: TieredStore,
    /// Ring provider only — replans consume `recent_window`; drift
    /// *detection* stays the pipeline/cluster monitors' business.
    monitor: DriftMonitor,
    batches_since_replan: usize,
    gscratch: Vec<u32>,
}

/// The tiered deterministic backend. Build via
/// [`super::Prepared::sim_tiered`] or [`Tiered::new`].
pub struct Tiered<'a> {
    inner: SimBackend<'a>,
    mapping: &'a Mapping,
    replan_batches: usize,
    label: String,
    state: Mutex<TierState>,
    obs: Option<Arc<Obs>>,
}

impl<'a> Tiered<'a> {
    /// Wrap a single-executor simulator with a tiered store. `inner`
    /// must be the unsharded twin (one executor): the tier walk prices
    /// whole-query tile traffic, which a sharded scatter would split.
    pub fn new(
        inner: SimBackend<'a>,
        mapping: &'a Mapping,
        store: TieredStore,
        replan_batches: usize,
    ) -> Self {
        assert_eq!(inner.executors(), 1, "Tiered wraps the single-executor twin");
        assert_eq!(
            store.num_groups(),
            mapping.num_groups(),
            "tiered store covers {} groups, mapping has {}",
            store.num_groups(),
            mapping.num_groups()
        );
        let label = format!("tiered(hot={})", store.policy().hot_capacity);
        // Baseline/threshold are irrelevant here (no rebaseline, no
        // regroup signal consumed) — the monitor is the ring.
        let monitor = DriftMonitor::with_baseline(0.125).with_window(TIER_RING_CAPACITY);
        Self {
            inner,
            mapping,
            replan_batches: replan_batches.max(1),
            label,
            state: Mutex::new(TierState {
                store,
                monitor,
                batches_since_replan: 0,
                gscratch: Vec::new(),
            }),
            obs: None,
        }
    }

    /// Attach an observability handle to both the tier walk (the
    /// `store.*` family) and the inner scheduler harvest.
    pub fn with_obs(mut self, obs: Arc<Obs>) -> Self {
        self.inner = self.inner.with_obs(Arc::clone(&obs));
        self.obs = Some(obs);
        self
    }

    /// Current tier of one group.
    pub fn tier_of(&self, group: u32) -> Tier {
        self.state.lock().expect("tier state lock poisoned").store.tier_of(group)
    }

    /// `(hot, dram, cold)` tile occupancy.
    pub fn occupancy(&self) -> (usize, usize, usize) {
        self.state.lock().expect("tier state lock poisoned").store.occupancy()
    }

    /// Hot-tier groups, ascending by id.
    pub fn hot_groups(&self) -> Vec<u32> {
        self.state.lock().expect("tier state lock poisoned").store.hot_groups()
    }

    /// Cumulative tile-touch stats since construction.
    pub fn access(&self) -> TierAccess {
        *self.state.lock().expect("tier state lock poisoned").store.access()
    }

    /// `(promotions, evictions)` applied since construction.
    pub fn moves(&self) -> (u64, u64) {
        let st = self.state.lock().expect("tier state lock poisoned");
        (st.store.promotions(), st.store.evictions())
    }
}

impl Backend for Tiered<'_> {
    fn name(&self) -> &str {
        &self.label
    }

    fn executors(&self) -> usize {
        1
    }

    fn scatter(&self, items: &[EmbeddingId]) -> Vec<Vec<EmbeddingId>> {
        vec![items.to_vec()]
    }

    fn run_batch_timed(
        &self,
        executor: usize,
        queries: &[Query],
        scratch: &mut Scratch,
        finish_rel: &mut Vec<f64>,
    ) -> ExecStats {
        let mut st = self.inner.run_batch_timed(executor, queries, scratch, finish_rel);
        let state = &mut *self.state.lock().expect("tier state lock poisoned");
        let base = finish_rel.len() - queries.len();
        let mut batch = TierAccess::default();
        let mut max_fetch_ns = 0.0f64;
        for (i, q) in queries.iter().enumerate() {
            let acc = state.store.charge_query(self.mapping, &q.items, &mut state.gscratch);
            // A query's tiles must arrive before it can finish...
            finish_rel[base + i] += acc.miss_ns;
            // ...but fetches for different queries overlap, so the batch
            // stretches by the worst single query's fetch, not the sum.
            max_fetch_ns = max_fetch_ns.max(acc.miss_ns);
            batch.accumulate(&acc);
            // Feed the admission ring — including cold-start ids the
            // mapping routes to the overflow group, which charge_query
            // already counted as a touch of that group's tile.
            state.monitor.observe_query(q, acc.total(), q.len());
        }
        st.completion_ns += max_fetch_ns;
        state.batches_since_replan += 1;
        let mut replanned = None;
        if state.batches_since_replan >= self.replan_batches {
            state.batches_since_replan = 0;
            if let Some(window) = state.monitor.recent_window(self.mapping.num_embeddings() as u32)
            {
                let freqs = crate::allocation::group_frequencies(self.mapping, &window);
                replanned = Some(state.store.adapt(&freqs));
            }
        }
        if let Some(obs) = &self.obs {
            obs.incr(names::STORE_HOT_HITS, batch.hot_hits);
            obs.incr(names::STORE_DRAM_HITS, batch.dram_hits);
            obs.incr(names::STORE_COLD_HITS, batch.cold_hits);
            obs.observe(names::STORE_MISS_NS, batch.miss_ns);
            if let Some(step) = &replanned {
                obs.incr(names::STORE_REPLANS, 1);
                obs.incr(names::STORE_PROMOTIONS, step.promoted.len() as u64);
                obs.incr(names::STORE_EVICTIONS, step.evicted.len() as u64);
            }
            let (hot, dram, cold) = state.store.occupancy();
            obs.gauge_set(names::STORE_HOT_TILES, hot as f64);
            obs.gauge_set(names::STORE_DRAM_TILES, dram as f64);
            obs.gauge_set(names::STORE_COLD_TILES, cold as f64);
        }
        st
    }

    fn merge_cost(&self) -> (f64, f64) {
        self.inner.merge_cost()
    }

    fn reduce_many(&self, queries: &[Query]) -> Result<Vec<Reduction>> {
        let state = &mut *self.state.lock().expect("tier state lock poisoned");
        let mut out = Vec::with_capacity(queries.len());
        let mut scratch = Vec::with_capacity(state.store.dim());
        for (i, q) in queries.iter().enumerate() {
            let mut reduced = vec![0.0f32; state.store.dim()];
            state
                .store
                .reduce_into(self.mapping, &q.items, &mut reduced, &mut scratch);
            let activations = self.mapping.groups_touched(&q.items, &mut state.gscratch) as u64;
            out.push(Reduction {
                id: i as u64,
                reduced,
                activations,
                fanout: 1,
                latency: Duration::ZERO,
            });
        }
        Ok(out)
    }

    fn status(&self) -> Result<Vec<BackendStatus>> {
        // One executor; "hosted" = crossbar-resident (hot) tiles. Serve
        // counters stay zero like every simulator backend — a drive's
        // accounting is its OpenLoopReport, and the tier counters live
        // in the store.* metrics family.
        let hot = self.occupancy().0;
        Ok(vec![BackendStatus {
            executor: 0,
            hosted_groups: hot,
            epoch: 0,
            queries: 0,
            lookups: 0,
            batches: 0,
            sim: ExecStats::default(),
        }])
    }

    fn obs(&self) -> Option<&Arc<Obs>> {
        self.obs.as_ref()
    }
}
