//! DLRM embedding-lookup workloads.
//!
//! The paper evaluates on five Amazon Review categories (Table I). The raw
//! dataset is not redistributable, so [`gen`] synthesizes traces whose
//! *statistical structure* matches what the paper measures and what the
//! ReCross algorithms actually consume:
//!
//! * item popularity follows a power law (Fig. 2),
//! * co-occurrence degree follows a power law (Fig. 2),
//! * queries draw most items from coherent co-purchase communities plus a
//!   long random tail (this is what makes grouping effective and produces
//!   the single-embedding activations of Fig. 6),
//! * per-dataset scale and mean lookups-per-query match Table I.
//!
//! See DESIGN.md §Substitutions for the fidelity argument.

pub mod gen;
pub mod spec;
pub mod trace;

pub use gen::{generate, Generator};
pub use spec::{DatasetSpec, AMAZON_DATASETS};
pub use trace::{TimedTrace, Trace};

/// Identifier of one embedding row (an item).
pub type EmbeddingId = u32;

/// One recommendation inference request: the set of embedding rows to
/// gather and sum (the paper's "embedding reduction" input).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Query {
    /// Looked-up embedding ids. May contain the paper's observed skew but
    /// never duplicates (a multi-hot vector has 0/1 entries).
    pub items: Vec<EmbeddingId>,
}

impl Query {
    /// Construct, deduplicating and sorting the item set.
    pub fn new(mut items: Vec<EmbeddingId>) -> Self {
        items.sort_unstable();
        items.dedup();
        Self { items }
    }

    /// Number of embedding lookups in this query.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// A batch of queries processed together (the paper evaluates batch 256).
#[derive(Debug, Clone)]
pub struct Batch<'a> {
    pub queries: &'a [Query],
}

impl<'a> Batch<'a> {
    pub fn new(queries: &'a [Query]) -> Self {
        Self { queries }
    }

    pub fn len(&self) -> usize {
        self.queries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queries.is_empty()
    }

    /// Total lookups across the batch.
    pub fn total_lookups(&self) -> usize {
        self.queries.iter().map(|q| q.len()).sum()
    }
}

/// Per-embedding access frequency over a trace.
pub fn access_frequencies(trace: &Trace) -> Vec<u64> {
    let mut freq = vec![0u64; trace.num_embeddings as usize];
    for q in &trace.queries {
        for &it in &q.items {
            freq[it as usize] += 1;
        }
    }
    freq
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_dedups_and_sorts() {
        let q = Query::new(vec![5, 1, 5, 3, 1]);
        assert_eq!(q.items, vec![1, 3, 5]);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn batch_totals() {
        let qs = vec![Query::new(vec![1, 2]), Query::new(vec![3])];
        let b = Batch::new(&qs);
        assert_eq!(b.len(), 2);
        assert_eq!(b.total_lookups(), 3);
    }

    #[test]
    fn frequencies_counted() {
        let t = Trace {
            num_embeddings: 4,
            queries: vec![Query::new(vec![0, 1]), Query::new(vec![1, 3])],
        };
        assert_eq!(access_frequencies(&t), vec![1, 2, 0, 1]);
    }
}
