//! On-disk trace format.
//!
//! A compact little-endian binary layout so full-scale traces (1M-item
//! catalogues, 20k queries × ~100 lookups) round-trip quickly:
//!
//! ```text
//! magic  b"RXTR"           4 bytes
//! version u32              1 or 2
//! flags   u32              v2 only; bit 0 = per-query timestamps present
//! num_embeddings u32
//! num_queries u64
//! per query: [arrival_ns u64 when flagged,] len u32, len * u32 item ids
//! ```
//!
//! Version 1 is the original closed-loop format (queries only). Version 2
//! adds an optional per-query **arrival timestamp** (ns on the simulated
//! clock, non-decreasing) so open-loop traffic — recorded or synthesized
//! by [`crate::loadgen::arrival`] — replays bit-identically. [`Trace`]
//! readers accept both versions (timestamps are skipped); [`TimedTrace`]
//! preserves them.

use super::Query;
use crate::Result;
use anyhow::{bail, ensure, Context};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"RXTR";
const VERSION_V1: u32 = 1;
const VERSION_V2: u32 = 2;
/// v2 flag bit: each query is preceded by its arrival timestamp.
const FLAG_TIMESTAMPS: u32 = 1;

/// A workload trace: the embedding-table size plus an ordered list of
/// queries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Trace {
    pub num_embeddings: u32,
    pub queries: Vec<Query>,
}

/// A trace with per-query arrival timestamps — the open-loop vocabulary:
/// *when* each query hits the front-end, not just what it looks up.
/// `arrivals_ns` is `None` when the source carried no timing (a v1 file),
/// in which case a driver must synthesize arrivals
/// ([`crate::loadgen::arrival`]).
#[derive(Debug, Clone, PartialEq)]
pub struct TimedTrace {
    pub trace: Trace,
    /// Arrival time of each query, ns on the simulated clock,
    /// non-decreasing; same length as `trace.queries`.
    pub arrivals_ns: Option<Vec<u64>>,
}

impl Trace {
    /// Total lookups across all queries.
    pub fn total_lookups(&self) -> usize {
        self.queries.iter().map(|q| q.len()).sum()
    }

    /// Mean lookups per query.
    pub fn mean_lookups(&self) -> f64 {
        if self.queries.is_empty() {
            0.0
        } else {
            self.total_lookups() as f64 / self.queries.len() as f64
        }
    }

    /// Iterate fixed-size batches (the last batch may be short).
    pub fn batches(&self, batch_size: usize) -> impl Iterator<Item = &[Query]> {
        self.queries.chunks(batch_size)
    }

    /// Serialize to a writer (version-1 layout: no timestamps).
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&VERSION_V1.to_le_bytes())?;
        write_body(w, self, None)
    }

    /// Deserialize from a reader. Accepts version 1 and version 2 files;
    /// v2 timestamps, if present, are dropped (use
    /// [`TimedTrace::read_from`] to keep them).
    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        Ok(read_any(r)?.trace)
    }

    /// Save to a file path.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        let mut w = BufWriter::new(f);
        self.write_to(&mut w)?;
        w.flush()?;
        Ok(())
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {:?}", path.as_ref()))?;
        Self::read_from(&mut BufReader::new(f))
    }
}

impl TimedTrace {
    /// Wrap a plain trace with explicit arrival times (validated).
    pub fn new(trace: Trace, arrivals_ns: Vec<u64>) -> Result<Self> {
        validate_arrivals(&arrivals_ns, trace.queries.len())?;
        Ok(Self {
            trace,
            arrivals_ns: Some(arrivals_ns),
        })
    }

    /// A trace with no timing information (reads back as such).
    pub fn untimed(trace: Trace) -> Self {
        Self {
            trace,
            arrivals_ns: None,
        }
    }

    /// Serialize in the version-2 layout (timestamps included when
    /// present).
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        if let Some(ts) = &self.arrivals_ns {
            validate_arrivals(ts, self.trace.queries.len())?;
        }
        w.write_all(MAGIC)?;
        w.write_all(&VERSION_V2.to_le_bytes())?;
        let flags = if self.arrivals_ns.is_some() {
            FLAG_TIMESTAMPS
        } else {
            0
        };
        w.write_all(&flags.to_le_bytes())?;
        write_body(w, &self.trace, self.arrivals_ns.as_deref())
    }

    /// Deserialize. A v1 file yields `arrivals_ns = None`.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        read_any(r)
    }

    /// Save to a file path (always version 2).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        let mut w = BufWriter::new(f);
        self.write_to(&mut w)?;
        w.flush()?;
        Ok(())
    }

    /// Load from a file path (v1 or v2).
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {:?}", path.as_ref()))?;
        Self::read_from(&mut BufReader::new(f))
    }
}

fn validate_arrivals(ts: &[u64], num_queries: usize) -> Result<()> {
    ensure!(
        ts.len() == num_queries,
        "{} timestamps for {num_queries} queries",
        ts.len()
    );
    ensure!(
        ts.windows(2).all(|w| w[0] <= w[1]),
        "arrival timestamps must be non-decreasing"
    );
    Ok(())
}

/// Shared body writer: header fields after the version word, then the
/// per-query records (timestamp-prefixed when `arrivals` is given).
fn write_body<W: Write>(w: &mut W, trace: &Trace, arrivals: Option<&[u64]>) -> Result<()> {
    w.write_all(&trace.num_embeddings.to_le_bytes())?;
    w.write_all(&(trace.queries.len() as u64).to_le_bytes())?;
    for (i, q) in trace.queries.iter().enumerate() {
        if let Some(ts) = arrivals {
            w.write_all(&ts[i].to_le_bytes())?;
        }
        w.write_all(&(q.items.len() as u32).to_le_bytes())?;
        for &it in &q.items {
            w.write_all(&it.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Shared reader for both versions.
fn read_any<R: Read>(r: &mut R) -> Result<TimedTrace> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic).context("reading trace magic")?;
    if &magic != MAGIC {
        bail!("not a ReCross trace file (bad magic {magic:?})");
    }
    let version = read_u32(r)?;
    let flags = match version {
        VERSION_V1 => 0,
        VERSION_V2 => {
            let f = read_u32(r)?;
            if f & !FLAG_TIMESTAMPS != 0 {
                bail!("trace v2 carries unknown flags {f:#x}");
            }
            f
        }
        other => bail!("unsupported trace version {other}"),
    };
    let timestamped = flags & FLAG_TIMESTAMPS != 0;
    let num_embeddings = read_u32(r)?;
    let num_queries = read_u64(r)?;
    // Sanity cap: refuse absurd files instead of OOMing.
    if num_queries > 100_000_000 {
        bail!("trace declares {num_queries} queries; refusing");
    }
    let mut queries = Vec::with_capacity(num_queries as usize);
    let mut arrivals = timestamped.then(|| Vec::with_capacity(num_queries as usize));
    for _ in 0..num_queries {
        if let Some(ts) = arrivals.as_mut() {
            let t = read_u64(r)?;
            if let Some(&prev) = ts.last() {
                if t < prev {
                    bail!("arrival timestamps regress ({t} after {prev})");
                }
            }
            ts.push(t);
        }
        let len = read_u32(r)? as usize;
        let mut items = Vec::with_capacity(len);
        for _ in 0..len {
            let it = read_u32(r)?;
            if it >= num_embeddings {
                bail!("item id {it} out of range (table size {num_embeddings})");
            }
            items.push(it);
        }
        queries.push(Query::new(items));
    }
    Ok(TimedTrace {
        trace: Trace {
            num_embeddings,
            queries,
        },
        arrivals_ns: arrivals,
    })
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        Trace {
            num_embeddings: 100,
            queries: vec![
                Query::new(vec![1, 5, 9]),
                Query::new(vec![42]),
                Query::new(vec![0, 99]),
            ],
        }
    }

    #[test]
    fn roundtrip_in_memory() {
        let t = sample();
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        let back = Trace::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_file() {
        let t = sample();
        let path = std::env::temp_dir().join("recross_trace_test.rxtr");
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(t, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = Vec::new();
        sample().write_to(&mut buf).unwrap();
        buf[0] = b'X';
        assert!(Trace::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn out_of_range_item_rejected() {
        let t = Trace {
            num_embeddings: 100,
            queries: vec![Query::new(vec![5])],
        };
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        // Patch num_embeddings down to 3 so item 5 is out of range.
        buf[8..12].copy_from_slice(&3u32.to_le_bytes());
        assert!(Trace::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn truncated_file_rejected() {
        let mut buf = Vec::new();
        sample().write_to(&mut buf).unwrap();
        buf.truncate(buf.len() - 2);
        assert!(Trace::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn stats_and_batches() {
        let t = sample();
        assert_eq!(t.total_lookups(), 6);
        assert!((t.mean_lookups() - 2.0).abs() < 1e-12);
        let batches: Vec<_> = t.batches(2).collect();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].len(), 2);
        assert_eq!(batches[1].len(), 1);
    }

    // --- trace format v2 ---------------------------------------------------

    #[test]
    fn v2_roundtrips_timestamps() {
        let tt = TimedTrace::new(sample(), vec![0, 1_000, 5_000]).unwrap();
        let mut buf = Vec::new();
        tt.write_to(&mut buf).unwrap();
        let back = TimedTrace::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(tt, back);
        assert_eq!(back.arrivals_ns.as_deref(), Some(&[0, 1_000, 5_000][..]));
    }

    #[test]
    fn v2_file_roundtrip() {
        let tt = TimedTrace::new(sample(), vec![7, 7, 9]).unwrap();
        let path = std::env::temp_dir().join("recross_trace_v2_test.rxtr");
        tt.save(&path).unwrap();
        let back = TimedTrace::load(&path).unwrap();
        assert_eq!(tt, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn v1_bytes_still_parse_as_timed_with_no_arrivals() {
        // A pre-existing v1 file must stay readable by both entry points.
        let t = sample();
        let mut v1_bytes = Vec::new();
        t.write_to(&mut v1_bytes).unwrap();
        assert_eq!(&v1_bytes[4..8], &1u32.to_le_bytes());
        let timed = TimedTrace::read_from(&mut v1_bytes.as_slice()).unwrap();
        assert_eq!(timed.trace, t);
        assert!(timed.arrivals_ns.is_none());
        assert_eq!(Trace::read_from(&mut v1_bytes.as_slice()).unwrap(), t);
    }

    #[test]
    fn plain_reader_accepts_v2_and_drops_timestamps() {
        let tt = TimedTrace::new(sample(), vec![1, 2, 3]).unwrap();
        let mut buf = Vec::new();
        tt.write_to(&mut buf).unwrap();
        let plain = Trace::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(plain, sample());
    }

    #[test]
    fn v2_untimed_reads_back_untimed() {
        let tt = TimedTrace::untimed(sample());
        let mut buf = Vec::new();
        tt.write_to(&mut buf).unwrap();
        let back = TimedTrace::read_from(&mut buf.as_slice()).unwrap();
        assert!(back.arrivals_ns.is_none());
        assert_eq!(back.trace, sample());
    }

    #[test]
    fn regressing_timestamps_rejected() {
        assert!(TimedTrace::new(sample(), vec![5, 3, 9]).is_err());
        assert!(TimedTrace::new(sample(), vec![1, 2]).is_err()); // length
        // And on the wire: a hand-corrupted v2 file must not load.
        let tt = TimedTrace::new(sample(), vec![0, 10, 20]).unwrap();
        let mut buf = Vec::new();
        tt.write_to(&mut buf).unwrap();
        // Second query's timestamp lives right after the first record:
        // header (4+4+4+4+8) + ts(8) + len(4) + 3 items (12) = 48.
        buf[48..56].copy_from_slice(&0u64.to_le_bytes());
        // First ts = 0, second patched to 0 — still fine; patch first to 9.
        buf[24..32].copy_from_slice(&9u64.to_le_bytes());
        assert!(TimedTrace::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn unknown_version_and_flags_rejected() {
        let mut buf = Vec::new();
        sample().write_to(&mut buf).unwrap();
        buf[4..8].copy_from_slice(&3u32.to_le_bytes());
        assert!(Trace::read_from(&mut buf.as_slice()).is_err());

        let mut buf2 = Vec::new();
        TimedTrace::untimed(sample()).write_to(&mut buf2).unwrap();
        buf2[8..12].copy_from_slice(&0xFFu32.to_le_bytes());
        assert!(TimedTrace::read_from(&mut buf2.as_slice()).is_err());
    }
}
