//! On-disk trace format.
//!
//! A compact little-endian binary layout so full-scale traces (1M-item
//! catalogues, 20k queries × ~100 lookups) round-trip quickly:
//!
//! ```text
//! magic  b"RXTR"           4 bytes
//! version u32              currently 1
//! num_embeddings u32
//! num_queries u64
//! per query: len u32, then len * u32 item ids
//! ```

use super::Query;
use crate::Result;
use anyhow::{bail, Context};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"RXTR";
const VERSION: u32 = 1;

/// A workload trace: the embedding-table size plus an ordered list of
/// queries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Trace {
    pub num_embeddings: u32,
    pub queries: Vec<Query>,
}

impl Trace {
    /// Total lookups across all queries.
    pub fn total_lookups(&self) -> usize {
        self.queries.iter().map(|q| q.len()).sum()
    }

    /// Mean lookups per query.
    pub fn mean_lookups(&self) -> f64 {
        if self.queries.is_empty() {
            0.0
        } else {
            self.total_lookups() as f64 / self.queries.len() as f64
        }
    }

    /// Iterate fixed-size batches (the last batch may be short).
    pub fn batches(&self, batch_size: usize) -> impl Iterator<Item = &[Query]> {
        self.queries.chunks(batch_size)
    }

    /// Serialize to a writer.
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&self.num_embeddings.to_le_bytes())?;
        w.write_all(&(self.queries.len() as u64).to_le_bytes())?;
        for q in &self.queries {
            w.write_all(&(q.items.len() as u32).to_le_bytes())?;
            for &it in &q.items {
                w.write_all(&it.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Deserialize from a reader.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic).context("reading trace magic")?;
        if &magic != MAGIC {
            bail!("not a ReCross trace file (bad magic {magic:?})");
        }
        let version = read_u32(r)?;
        if version != VERSION {
            bail!("unsupported trace version {version}");
        }
        let num_embeddings = read_u32(r)?;
        let num_queries = read_u64(r)?;
        // Sanity cap: refuse absurd files instead of OOMing.
        if num_queries > 100_000_000 {
            bail!("trace declares {num_queries} queries; refusing");
        }
        let mut queries = Vec::with_capacity(num_queries as usize);
        for _ in 0..num_queries {
            let len = read_u32(r)? as usize;
            let mut items = Vec::with_capacity(len);
            for _ in 0..len {
                let it = read_u32(r)?;
                if it >= num_embeddings {
                    bail!("item id {it} out of range (table size {num_embeddings})");
                }
                items.push(it);
            }
            queries.push(Query::new(items));
        }
        Ok(Self {
            num_embeddings,
            queries,
        })
    }

    /// Save to a file path.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {:?}", path.as_ref()))?;
        let mut w = BufWriter::new(f);
        self.write_to(&mut w)?;
        w.flush()?;
        Ok(())
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {:?}", path.as_ref()))?;
        Self::read_from(&mut BufReader::new(f))
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        Trace {
            num_embeddings: 100,
            queries: vec![
                Query::new(vec![1, 5, 9]),
                Query::new(vec![42]),
                Query::new(vec![0, 99]),
            ],
        }
    }

    #[test]
    fn roundtrip_in_memory() {
        let t = sample();
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        let back = Trace::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_file() {
        let t = sample();
        let path = std::env::temp_dir().join("recross_trace_test.rxtr");
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(t, back);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut buf = Vec::new();
        sample().write_to(&mut buf).unwrap();
        buf[0] = b'X';
        assert!(Trace::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn out_of_range_item_rejected() {
        let t = Trace {
            num_embeddings: 100,
            queries: vec![Query::new(vec![5])],
        };
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        // Patch num_embeddings down to 3 so item 5 is out of range.
        buf[8..12].copy_from_slice(&3u32.to_le_bytes());
        assert!(Trace::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn truncated_file_rejected() {
        let mut buf = Vec::new();
        sample().write_to(&mut buf).unwrap();
        buf.truncate(buf.len() - 2);
        assert!(Trace::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn stats_and_batches() {
        let t = sample();
        assert_eq!(t.total_lookups(), 6);
        assert!((t.mean_lookups() - 2.0).abs() < 1e-12);
        let batches: Vec<_> = t.batches(2).collect();
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].len(), 2);
        assert_eq!(batches[1].len(), 1);
    }
}
