//! Dataset specifications calibrated to the paper's Table I.
//!
//! Each spec carries the published scale (`num_embeddings`) and mean
//! lookups per query (`avg_lookups`, the Table I "Avg. Lat" column), plus
//! the generator parameters that shape the synthetic trace:
//!
//! * `alpha_pop` — Zipf exponent for cluster popularity. All datasets are
//!   power-law (Fig. 2); larger α means a hotter head.
//! * `cluster_size` — mean size of a co-purchase community. Communities
//!   wider than the 64-row crossbar force groups to split, diluting the
//!   benefit of grouping (this is visible in the paper: software — the
//!   smallest dataset — gains least).
//! * `p_tail` — probability that a lookup is an uncorrelated long-tail
//!   item rather than a community item. Tail lookups land alone in a
//!   crossbar and become the single-embedding activations of Fig. 6
//!   (25.9% on software vs 53.5% on automotive implies automotive has a
//!   much heavier uncorrelated tail).
//! * `p_secondary` — probability that a community lookup comes from a
//!   correlated *secondary* community instead of the primary one.

/// Generator parameters for one dataset.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetSpec {
    /// Canonical lowercase name.
    pub name: &'static str,
    /// Embedding-table rows (Table I "# of Embedding").
    pub num_embeddings: u32,
    /// Mean lookups per query (Table I "Avg. Lat").
    pub avg_lookups: f64,
    /// Zipf exponent of community popularity.
    pub alpha_pop: f64,
    /// Mean co-purchase community size.
    pub cluster_size: usize,
    /// Probability of an uncorrelated tail lookup.
    pub p_tail: f64,
    /// Probability a community lookup uses the secondary community.
    pub p_secondary: f64,
}

/// The five Amazon Review categories of Table I.
pub const AMAZON_DATASETS: [DatasetSpec; 5] = [
    DatasetSpec {
        name: "software",
        num_embeddings: 26_815,
        avg_lookups: 41.32,
        alpha_pop: 0.85,
        cluster_size: 48,
        p_tail: 0.03,
        p_secondary: 0.20,
    },
    DatasetSpec {
        name: "office_products",
        num_embeddings: 315_644,
        avg_lookups: 64.088,
        alpha_pop: 0.95,
        cluster_size: 56,
        p_tail: 0.05,
        p_secondary: 0.15,
    },
    DatasetSpec {
        name: "electronics",
        num_embeddings: 786_868,
        avg_lookups: 55.746,
        alpha_pop: 1.00,
        cluster_size: 56,
        p_tail: 0.07,
        p_secondary: 0.12,
    },
    DatasetSpec {
        name: "automotive",
        num_embeddings: 932_019,
        avg_lookups: 42.26,
        alpha_pop: 1.05,
        cluster_size: 40,
        p_tail: 0.14,
        p_secondary: 0.10,
    },
    DatasetSpec {
        name: "sports",
        num_embeddings: 962_876,
        avg_lookups: 96.019,
        alpha_pop: 1.00,
        cluster_size: 64,
        p_tail: 0.08,
        p_secondary: 0.12,
    },
];

impl DatasetSpec {
    /// Look up a spec by (case-insensitive) name.
    pub fn by_name(name: &str) -> Option<&'static DatasetSpec> {
        let lower = name.to_ascii_lowercase();
        AMAZON_DATASETS.iter().find(|d| d.name == lower)
    }

    /// A proportionally scaled-down copy (for tests and quick runs):
    /// `scale` in (0, 1] shrinks the embedding table while keeping the
    /// distributional parameters identical.
    pub fn scaled(&self, scale: f64) -> DatasetSpec {
        assert!(scale > 0.0 && scale <= 1.0, "scale {scale} outside (0,1]");
        DatasetSpec {
            num_embeddings: ((self.num_embeddings as f64 * scale).round() as u32).max(256),
            ..self.clone()
        }
    }

    /// All dataset names, evaluation order of the paper's figures.
    pub fn names() -> Vec<&'static str> {
        AMAZON_DATASETS.iter().map(|d| d.name).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_scales_match_paper() {
        assert_eq!(DatasetSpec::by_name("software").unwrap().num_embeddings, 26_815);
        assert_eq!(
            DatasetSpec::by_name("office_products").unwrap().num_embeddings,
            315_644
        );
        assert_eq!(
            DatasetSpec::by_name("electronics").unwrap().num_embeddings,
            786_868
        );
        assert_eq!(
            DatasetSpec::by_name("automotive").unwrap().num_embeddings,
            932_019
        );
        assert_eq!(DatasetSpec::by_name("sports").unwrap().num_embeddings, 962_876);
    }

    #[test]
    fn table1_avg_lookups_match_paper() {
        let avg: Vec<f64> = AMAZON_DATASETS.iter().map(|d| d.avg_lookups).collect();
        assert_eq!(avg, vec![41.32, 64.088, 55.746, 42.26, 96.019]);
    }

    #[test]
    fn lookup_case_insensitive_and_missing() {
        assert!(DatasetSpec::by_name("SPORTS").is_some());
        assert!(DatasetSpec::by_name("books").is_none());
    }

    #[test]
    fn scaled_preserves_params() {
        let d = DatasetSpec::by_name("sports").unwrap().scaled(0.01);
        assert_eq!(d.num_embeddings, 9_629);
        assert_eq!(d.avg_lookups, 96.019);
        assert_eq!(d.p_tail, 0.08);
    }

    #[test]
    fn scaled_floors_at_minimum() {
        let d = DatasetSpec::by_name("software").unwrap().scaled(0.000_001);
        assert!(d.num_embeddings >= 256);
    }
}
