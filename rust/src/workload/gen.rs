//! Synthetic Amazon-Review-like trace generator.
//!
//! Model: items are partitioned into *co-purchase communities* (clusters).
//! A query is built by
//!
//! 1. drawing a **primary community** from a Zipf distribution over
//!    communities (popular categories are queried more — the paper's
//!    power-law access frequency),
//! 2. drawing a correlated **secondary community** (a deterministic
//!    neighbor of the primary, modelling cross-category correlations),
//! 3. drawing `len ~ max(1, Poisson(avg_lookups))` items, each of which is
//!    * with probability `p_tail`: an *uncorrelated* long-tail item sampled
//!      uniformly (these become Fig. 6's single-embedding activations),
//!    * else with probability `p_secondary`: a Zipf draw within the
//!      secondary community,
//!    * else: a Zipf draw within the primary community.
//!
//! Item ids are assigned by a seeded permutation, so "naive mapping by
//! itemID" (the paper's baseline) sees communities scattered across
//! crossbars exactly as a hash-assigned catalogue would.

use super::spec::DatasetSpec;
use super::{Query, Trace};
use crate::util::{Rng, Zipf};

/// Reusable generator: holds the community structure so that *history* and
/// *evaluation* traces share the same underlying catalogue (the offline
/// phase must generalise from history to eval, as in the paper).
#[derive(Debug)]
pub struct Generator {
    spec: DatasetSpec,
    /// Item ids of each community (already permuted).
    communities: Vec<Vec<u32>>,
    /// Zipf over communities.
    community_zipf: Zipf,
    /// Zipf within a community of the maximum size (prefix used for
    /// smaller ones — avoids one table per community).
    intra_zipf: Zipf,
    /// Catalogue size for uniform tail draws. Tail lookups are
    /// *uncorrelated* one-off interactions (the paper's single-embedding
    /// accesses): drawing them uniformly keeps them out of the hot
    /// co-occurrence structure, matching Fig. 4b's observation that even
    /// the hottest post-grouping crossbar sees only ~21 accesses per
    /// batch of 256.
    tail_n: usize,
    /// Permutation from "semantic" item index to public item id.
    perm: Vec<u32>,
}

impl Generator {
    /// Build the catalogue for a dataset. `seed` fixes the community
    /// structure; traces drawn later use their own seeds.
    pub fn new(spec: &DatasetSpec, seed: u64) -> Self {
        let n = spec.num_embeddings as usize;
        let mut rng = Rng::new(seed ^ 0xC0FF_EE00_D15E_A5E5);

        // Seeded permutation: semantic index -> public item id.
        let mut perm: Vec<u32> = (0..n as u32).collect();
        rng.shuffle(&mut perm);

        // Partition semantic indices into communities with sizes jittered
        // around `cluster_size` (uniform in [size/2, 3*size/2]).
        let mut communities = Vec::new();
        let mut next = 0usize;
        while next < n {
            let lo = (spec.cluster_size / 2).max(4);
            let hi = spec.cluster_size + spec.cluster_size / 2;
            let size = rng.range(lo as u64, hi as u64) as usize;
            let end = (next + size).min(n);
            communities.push(perm[next..end].to_vec());
            next = end;
        }

        let max_comm = communities.iter().map(Vec::len).max().unwrap_or(1);
        Self {
            community_zipf: Zipf::new(communities.len(), spec.alpha_pop),
            intra_zipf: Zipf::new(max_comm, 0.8),
            tail_n: n,
            communities,
            perm,
            spec: spec.clone(),
        }
    }

    /// Number of communities in the catalogue.
    pub fn num_communities(&self) -> usize {
        self.communities.len()
    }

    /// The dataset spec this generator was built from.
    pub fn spec(&self) -> &DatasetSpec {
        &self.spec
    }

    /// Draw an item from a community with intra-community Zipf skew.
    fn draw_from_community(&self, comm: usize, rng: &mut Rng) -> u32 {
        let items = &self.communities[comm];
        // Rejection against the shared max-size Zipf: resample until the
        // rank fits this community. Head-heavy, so few iterations.
        loop {
            let r = self.intra_zipf.sample(rng);
            if r < items.len() {
                return items[r];
            }
        }
    }

    /// Deterministic correlated neighbor of a community.
    fn secondary_of(&self, comm: usize) -> usize {
        // Popular communities correlate with other popular communities:
        // neighbor in popularity rank, wrapping.
        (comm + 1) % self.communities.len()
    }

    /// Generate one query. Lookups within one query are distinct (a
    /// multi-hot wordline vector has 0/1 entries), so draws are rejected
    /// until the target length is reached, with an attempt cap for
    /// pathological cases (tiny communities).
    pub fn query(&self, rng: &mut Rng) -> Query {
        let primary = self.community_zipf.sample(rng);
        let secondary = self.secondary_of(primary);
        let len = rng.poisson(self.spec.avg_lookups).max(1) as usize;
        let mut seen = crate::util::FxHashSet::default();
        seen.reserve(len * 2);
        let mut items = Vec::with_capacity(len);
        let mut attempts = 0usize;
        let max_attempts = len * 20 + 64;
        while items.len() < len && attempts < max_attempts {
            attempts += 1;
            let item = if rng.chance(self.spec.p_tail) {
                self.perm[rng.index(self.tail_n)]
            } else if rng.chance(self.spec.p_secondary) {
                self.draw_from_community(secondary, rng)
            } else {
                self.draw_from_community(primary, rng)
            };
            if seen.insert(item) {
                items.push(item);
            }
        }
        Query::new(items)
    }

    /// Generate a trace of `num_queries` queries with its own seed.
    pub fn trace(&self, num_queries: usize, seed: u64) -> Trace {
        let mut rng = Rng::new(seed);
        let queries = (0..num_queries).map(|_| self.query(&mut rng)).collect();
        Trace {
            num_embeddings: self.spec.num_embeddings,
            queries,
        }
    }
}

/// Convenience: build a generator and produce `(history, eval)` traces with
/// derived seeds, the standard experiment setup.
pub fn generate(
    spec: &DatasetSpec,
    history_queries: usize,
    eval_queries: usize,
    seed: u64,
) -> (Trace, Trace) {
    let g = Generator::new(spec, seed);
    let history = g.trace(history_queries, seed.wrapping_add(1));
    let eval = g.trace(eval_queries, seed.wrapping_add(2));
    (history, eval)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::fit_power_law;
    use crate::workload::access_frequencies;

    fn small_spec() -> DatasetSpec {
        DatasetSpec::by_name("software").unwrap().scaled(0.2)
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = small_spec();
        let (h1, _) = generate(&spec, 50, 10, 7);
        let (h2, _) = generate(&spec, 50, 10, 7);
        assert_eq!(h1.queries, h2.queries);
    }

    #[test]
    fn seeds_change_trace() {
        let spec = small_spec();
        let (h1, _) = generate(&spec, 50, 10, 7);
        let (h2, _) = generate(&spec, 50, 10, 8);
        assert_ne!(h1.queries, h2.queries);
    }

    #[test]
    fn items_in_range_and_nonempty() {
        let spec = small_spec();
        let (h, e) = generate(&spec, 200, 50, 1);
        for q in h.queries.iter().chain(e.queries.iter()) {
            assert!(!q.is_empty());
            assert!(q.items.iter().all(|&i| i < spec.num_embeddings));
        }
    }

    #[test]
    fn mean_query_length_tracks_spec() {
        let spec = small_spec();
        let g = Generator::new(&spec, 3);
        let t = g.trace(2_000, 4);
        let mean =
            t.queries.iter().map(|q| q.len() as f64).sum::<f64>() / t.queries.len() as f64;
        // Dedup within a query shaves a little off the Poisson mean.
        assert!(
            (spec.avg_lookups * 0.75..=spec.avg_lookups * 1.05).contains(&mean),
            "mean lookups {mean} vs spec {}",
            spec.avg_lookups
        );
    }

    #[test]
    fn access_frequency_is_power_law() {
        // The paper's Fig. 2 premise: generated frequencies must be
        // power-law distributed.
        let spec = small_spec();
        let g = Generator::new(&spec, 5);
        let t = g.trace(3_000, 6);
        let freq = access_frequencies(&t);
        let fit = fit_power_law(&freq).expect("enough points");
        assert!(fit.is_power_law(), "fit {fit:?}");
    }

    #[test]
    fn history_and_eval_share_structure() {
        // Hot items of the history must be hot in eval: grouping must
        // generalise. Compare top-100 overlap.
        let spec = small_spec();
        let (h, e) = generate(&spec, 2_000, 2_000, 11);
        let top = |t: &Trace| {
            let f = access_frequencies(t);
            let mut idx: Vec<usize> = (0..f.len()).collect();
            idx.sort_by_key(|&i| std::cmp::Reverse(f[i]));
            idx[..100].iter().copied().collect::<std::collections::HashSet<_>>()
        };
        let overlap = top(&h).intersection(&top(&e)).count();
        assert!(overlap >= 60, "top-100 overlap only {overlap}");
    }

    #[test]
    fn communities_cover_catalogue() {
        let spec = small_spec();
        let g = Generator::new(&spec, 9);
        let total: usize = (0..g.num_communities())
            .map(|c| g.communities[c].len())
            .sum();
        assert_eq!(total, spec.num_embeddings as usize);
    }
}
