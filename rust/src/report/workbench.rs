//! Workbench: caches per-dataset traces/graphs/engines so a multi-figure
//! report run prepares each dataset exactly once.

use crate::config::Config;
use crate::engine::{Engine, Scheme};
use crate::graph::CoGraph;
use crate::sched::ExecStats;
use crate::workload::{generate, DatasetSpec, Trace};
use std::collections::HashMap;

/// Prepared data for one dataset.
#[derive(Debug)]
pub struct DatasetData {
    pub spec: DatasetSpec,
    pub history: Trace,
    pub eval: Trace,
    pub graph: CoGraph,
}

/// The report workbench.
pub struct Workbench {
    scale: f64,
    history_queries: usize,
    eval_queries: usize,
    group_size: usize,
    seed: u64,
    cfg: Config,
    datasets: HashMap<String, DatasetData>,
    engines: HashMap<(String, Scheme, u64), Engine>,
}

impl Workbench {
    /// `scale` shrinks Table I's embedding counts; `history`/`eval` set
    /// trace lengths; `group_size` is the crossbar row count.
    pub fn new(scale: f64, history: usize, eval: usize, group_size: usize, seed: u64) -> Self {
        let mut cfg = Config::paper_default();
        cfg.scheme.group_size = group_size;
        cfg.workload.history_queries = history;
        cfg.workload.eval_queries = eval;
        cfg.workload.seed = seed;
        Self {
            scale,
            history_queries: history,
            eval_queries: eval,
            group_size,
            seed,
            cfg,
            datasets: HashMap::new(),
            engines: HashMap::new(),
        }
    }

    /// Paper-default workbench at a given scale.
    pub fn at_scale(scale: f64) -> Self {
        // History/eval sized so sub-scale runs stay statistically stable.
        Self::new(scale, 4_000, 1_024, 64, 42)
    }

    pub fn scale(&self) -> f64 {
        self.scale
    }
    pub fn seed(&self) -> u64 {
        self.seed
    }
    pub fn group_size(&self) -> usize {
        self.group_size
    }
    pub fn batch_size(&self) -> usize {
        self.cfg.scheme.batch_size
    }
    pub fn embedding_dim(&self) -> usize {
        self.cfg.hardware.embedding_dim
    }
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    /// Prepare (or fetch cached) traces + graph for a dataset.
    pub fn dataset(&mut self, name: &str) -> &DatasetData {
        if !self.datasets.contains_key(name) {
            let spec = DatasetSpec::by_name(name)
                .unwrap_or_else(|| panic!("unknown dataset {name}"))
                .scaled(self.scale);
            let (history, eval) =
                generate(&spec, self.history_queries, self.eval_queries, self.seed);
            let graph = CoGraph::build(&history);
            self.datasets.insert(
                name.to_string(),
                DatasetData {
                    spec,
                    history,
                    eval,
                    graph,
                },
            );
        }
        &self.datasets[name]
    }

    /// Prepare (or fetch cached) an engine. Engines are additionally keyed
    /// by the dup-ratio in millis so Fig. 10 sweeps don't collide.
    fn engine(&mut self, name: &str, scheme: Scheme, dup_ratio: f64) -> &Engine {
        let key = (name.to_string(), scheme, (dup_ratio * 1000.0) as u64);
        if !self.engines.contains_key(&key) {
            self.dataset(name); // ensure cached
            let data = &self.datasets[name];
            let mut cfg = self.cfg.clone();
            cfg.scheme.dup_ratio = dup_ratio;
            let engine = Engine::prepare(scheme, &data.graph, &data.history, &cfg);
            self.engines.insert(key.clone(), engine);
        }
        &self.engines[&key]
    }

    /// Run several schemes over a dataset's eval trace.
    pub fn compare<I: IntoIterator<Item = Scheme>>(
        &mut self,
        name: &str,
        schemes: I,
    ) -> HashMap<Scheme, ExecStats> {
        let dup = self.cfg.scheme.dup_ratio;
        let batch = self.cfg.scheme.batch_size;
        schemes
            .into_iter()
            .map(|sc| {
                self.engine(name, sc, dup);
                let key = (name.to_string(), sc, (dup * 1000.0) as u64);
                let eval = &self.datasets[name].eval;
                let stats = self.engines[&key].run_trace(eval, batch);
                (sc, stats)
            })
            .collect()
    }

    /// Activation counts for several schemes (Fig. 9's cheap metric).
    pub fn activations<I: IntoIterator<Item = Scheme>>(
        &mut self,
        name: &str,
        schemes: I,
    ) -> HashMap<Scheme, u64> {
        let dup = self.cfg.scheme.dup_ratio;
        schemes
            .into_iter()
            .map(|sc| {
                self.engine(name, sc, dup);
                let key = (name.to_string(), sc, (dup * 1000.0) as u64);
                let eval = &self.datasets[name].eval;
                (sc, self.engines[&key].count_activations(eval))
            })
            .collect()
    }

    /// ReCross at several duplication ratios (Fig. 10).
    pub fn dup_sweep(&mut self, name: &str, ratios: &[f64]) -> Vec<ExecStats> {
        let batch = self.cfg.scheme.batch_size;
        ratios
            .iter()
            .map(|&r| {
                self.engine(name, Scheme::ReCross, r);
                let key = (name.to_string(), Scheme::ReCross, (r * 1000.0) as u64);
                let eval = &self.datasets[name].eval;
                self.engines[&key].run_trace(eval, batch)
            })
            .collect()
    }

    /// Physical crossbars an engine uses (area proxy for ablations).
    pub fn physical_crossbars(&mut self, name: &str, scheme: Scheme) -> usize {
        let dup = self.cfg.scheme.dup_ratio;
        self.engine(name, scheme, dup);
        let key = (name.to_string(), scheme, (dup * 1000.0) as u64);
        self.engines[&key].physical_crossbars()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_cached_once() {
        let mut wb = Workbench::new(0.01, 100, 40, 64, 1);
        let n1 = wb.dataset("software").history.queries.len();
        let n2 = wb.dataset("software").history.queries.len();
        assert_eq!(n1, n2);
        assert_eq!(wb.datasets.len(), 1);
    }

    #[test]
    fn compare_covers_schemes() {
        let mut wb = Workbench::new(0.01, 150, 50, 64, 2);
        let r = wb.compare("software", [Scheme::Naive, Scheme::ReCross]);
        assert_eq!(r.len(), 2);
        assert!(r[&Scheme::Naive].completion_ns > 0.0);
        assert!(r[&Scheme::ReCross].completion_ns > 0.0);
    }

    #[test]
    fn dup_sweep_monotone_area() {
        let mut wb = Workbench::new(0.01, 150, 50, 64, 3);
        let _ = wb.dup_sweep("software", &[0.0, 0.1]);
        let x0 = wb.physical_crossbars("software", Scheme::ReCrossNoDup);
        wb.cfg.scheme.dup_ratio = 0.1;
        let x1 = wb.physical_crossbars("software", Scheme::ReCross);
        assert!(x1 >= x0);
    }
}
