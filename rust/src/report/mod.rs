//! Report harness: regenerates every table and figure of the paper's
//! evaluation (§IV) as terminal tables.
//!
//! Each `fig*` function returns the formatted report string (so tests can
//! assert on content) and is wired to both the `recross report` CLI
//! subcommand and a criterion-style bench target. The DESIGN.md experiment
//! index maps figure ↔ function ↔ bench.
//!
//! Scale: `scale=1.0` reproduces Table I sizes (~1M embeddings). Reports
//! default to a documented sub-scale so a laptop run finishes in minutes;
//! the *ratios* (who wins, by how much) are stable across scale, which is
//! what the reproduction must preserve.

mod workbench;

pub use workbench::Workbench;

use crate::allocation::{self, group_frequencies};
use crate::energy::{HostModel, HostPlatform};
use crate::engine::Scheme;
use crate::grouping::{CorrelationMapper, Mapper};
use crate::metrics::{fit_power_law, gini, Histogram};
use crate::workload::{DatasetSpec, AMAZON_DATASETS};
use crate::xbar::HostParams;

/// Table I: hardware + dataset configuration.
pub fn table1() -> String {
    let mut s = String::new();
    s.push_str("TABLE I — Hardware and dataset configurations\n\n");
    s.push_str("  Component          Specification\n");
    s.push_str("  -----------------  -------------------\n");
    s.push_str("  Crossbar           64 x 64; 2-bit/cell\n");
    s.push_str("  Tile               256 x 256\n");
    s.push_str("  ADC                6 bits (dynamic-switch, 3-bit read path)\n");
    s.push_str("  Global Bus Width   512b\n\n");
    s.push_str(&format!(
        "  {:<17} {:>14} {:>10}\n",
        "Dataset", "# Embedding", "Avg. Lkp"
    ));
    s.push_str("  -----------------  ------------  ----------\n");
    for d in &AMAZON_DATASETS {
        s.push_str(&format!(
            "  {:<17} {:>14} {:>10.3}\n",
            d.name, d.num_embeddings, d.avg_lookups
        ));
    }
    s
}

/// Fig. 2: co-occurrence degree distribution (power law) per dataset.
pub fn fig2(wb: &mut Workbench) -> String {
    let mut s = String::new();
    s.push_str("FIG 2 — Number of correlated embeddings (co-occurrence degree)\n");
    s.push_str(&format!("(scale {}, seed {})\n\n", wb.scale(), wb.seed()));
    for name in DatasetSpec::names() {
        let data = wb.dataset(name);
        let degrees = data.graph.degrees();
        let fit = fit_power_law(&degrees);
        let mut h = Histogram::new();
        for &d in &degrees {
            h.add(d);
        }
        s.push_str(&format!(
            "--- {name}: {} embeddings, {} edges ---\n",
            data.graph.num_nodes(),
            data.graph.num_edges()
        ));
        match fit {
            Some(f) => s.push_str(&format!(
                "power-law fit: alpha={:.2} R^2={:.3} -> {}\n",
                f.alpha,
                f.r_squared,
                if f.is_power_law() { "POWER-LAW (matches paper)" } else { "NOT power-law" }
            )),
            None => s.push_str("power-law fit: insufficient data\n"),
        }
        s.push_str(&h.render(10, 40));
        s.push('\n');
    }
    s
}

/// Fig. 4: crossbar access distribution *after* grouping, single queries
/// and batch-256, showing the power law persists.
pub fn fig4(wb: &mut Workbench) -> String {
    let mut s = String::new();
    s.push_str("FIG 4 — Access distribution after correlation-aware grouping\n\n");
    let group_size = wb.group_size();
    let batch = wb.batch_size();
    for name in ["software", "automotive"] {
        let data = wb.dataset(name);
        let mapping = CorrelationMapper.map(&data.graph, group_size);
        let freqs = group_frequencies(&mapping, &data.eval);
        let fit = fit_power_law(&freqs);
        s.push_str(&format!("--- {name}: {} groups ---\n", mapping.num_groups()));
        if let Some(f) = fit {
            s.push_str(&format!(
                "group-access power-law: alpha={:.2} R^2={:.3} -> {}\n",
                f.alpha,
                f.r_squared,
                if f.is_power_law() { "persists (matches paper)" } else { "flattened" }
            ));
        }
        // Batch-level concurrent demand: max accesses to one group within
        // one batch of 256 (paper: max ~21 for automotive, << batch size).
        let mut batch_max = 0u64;
        let mut scratch = Vec::new();
        for chunk in data.eval.batches(batch) {
            let mut per_group = std::collections::HashMap::new();
            for q in chunk {
                scratch.clear();
                scratch.extend(q.items.iter().map(|&e| mapping.slot_of(e).group));
                scratch.sort_unstable();
                scratch.dedup();
                for &g in &scratch {
                    *per_group.entry(g).or_insert(0u64) += 1;
                }
            }
            batch_max = batch_max.max(per_group.values().copied().max().unwrap_or(0));
        }
        s.push_str(&format!(
            "max per-batch accesses to one crossbar: {batch_max} (batch {batch}) — far below batch size, as the paper observes\n\n"
        ));
    }
    s
}

/// Fig. 5: distribution of copy counts, linear scaling vs Eq. 1.
pub fn fig5(wb: &mut Workbench) -> String {
    let mut s = String::new();
    s.push_str("FIG 5 — Copies per crossbar: linear scaling vs log scaling (Eq. 1)\n\n");
    let group_size = wb.group_size();
    let batch = wb.batch_size();
    let data = wb.dataset("automotive");
    let mapping = CorrelationMapper.map(&data.graph, group_size);
    let freqs = group_frequencies(&mapping, &data.history);
    let total: u64 = freqs.iter().sum();
    let fmax = freqs.iter().copied().max().unwrap_or(1);

    let mut lin = Histogram::new();
    let mut log = Histogram::new();
    for &f in &freqs {
        lin.add(allocation::linear_copies(f, fmax, batch as u32) as u64);
        log.add(allocation::log_scaled_copies(f, total, batch) as u64);
    }
    let lin_dup = freqs
        .iter()
        .filter(|&&f| allocation::linear_copies(f, fmax, batch as u32) > 1)
        .count();
    let log_dup = freqs
        .iter()
        .filter(|&&f| allocation::log_scaled_copies(f, total, batch) > 1)
        .count();
    let lin_gini = gini(&lin_copies_vec(&freqs, fmax, batch));
    let log_gini = gini(&log_copies_vec(&freqs, total, batch));
    s.push_str(&format!(
        "groups: {}   linear: {} duplicated (gini {:.3})   log: {} duplicated (gini {:.3})\n",
        freqs.len(),
        lin_dup,
        lin_gini,
        log_dup,
        log_gini
    ));
    s.push_str(&format!(
        "-> log scaling duplicates {}x more groups with a {}% flatter copy distribution (the paper's 'evenness')\n\n",
        if lin_dup == 0 { log_dup } else { log_dup / lin_dup.max(1) },
        (((lin_gini - log_gini) / lin_gini.max(1e-9)) * 100.0).round()
    ));
    s.push_str("linear copies histogram:\n");
    s.push_str(&lin.render(8, 40));
    s.push_str("\nlog (Eq. 1) copies histogram:\n");
    s.push_str(&log.render(8, 40));
    s
}

fn lin_copies_vec(freqs: &[u64], fmax: u64, batch: usize) -> Vec<f64> {
    freqs
        .iter()
        .map(|&f| allocation::linear_copies(f, fmax, batch as u32) as f64)
        .collect()
}

fn log_copies_vec(freqs: &[u64], total: u64, batch: usize) -> Vec<f64> {
    freqs
        .iter()
        .map(|&f| allocation::log_scaled_copies(f, total, batch) as f64)
        .collect()
}

/// Fig. 6: share of crossbar activations touching a single embedding, per
/// group size (paper: avg 25.9% software, 53.5% automotive).
pub fn fig6(wb: &mut Workbench) -> String {
    let mut s = String::new();
    s.push_str("FIG 6 — Single-embedding activations vs group size\n\n");
    s.push_str(&format!(
        "  {:<17} {:>8} {:>8} {:>8}\n",
        "dataset", "g=16", "g=32", "g=64"
    ));
    for name in DatasetSpec::names() {
        let data = wb.dataset(name);
        let mut row = format!("  {name:<17} ");
        for gs in [16usize, 32, 64] {
            let mapping = CorrelationMapper.map(&data.graph, gs);
            let mut single = 0u64;
            let mut total = 0u64;
            let mut scratch: Vec<u32> = Vec::new();
            for q in &data.eval.queries {
                scratch.clear();
                scratch.extend(q.items.iter().map(|&e| mapping.slot_of(e).group));
                scratch.sort_unstable();
                let mut i = 0;
                while i < scratch.len() {
                    let g = scratch[i];
                    let mut rows = 0;
                    while i < scratch.len() && scratch[i] == g {
                        rows += 1;
                        i += 1;
                    }
                    total += 1;
                    if rows == 1 {
                        single += 1;
                    }
                }
            }
            row.push_str(&format!("{:>7.1}% ", 100.0 * single as f64 / total.max(1) as f64));
        }
        s.push_str(&row);
        s.push('\n');
    }
    s.push_str("\npaper reference (g=64): software 25.9%, automotive 53.5%\n");
    s
}

/// Fig. 8: normalized speedup + energy efficiency vs naive and nMARS.
pub fn fig8(wb: &mut Workbench) -> String {
    let mut s = String::new();
    s.push_str("FIG 8 — Overall performance: ReCross vs naive vs nMARS\n");
    s.push_str("(normalized to naive; higher is better)\n\n");
    s.push_str(&format!(
        "  {:<17} {:>12} {:>12} {:>14} {:>14}\n",
        "dataset", "speedup/nv", "speedup/nm", "energy-eff/nv", "energy-eff/nm"
    ));
    let mut agg = [0.0f64; 4];
    let mut n = 0.0;
    for name in DatasetSpec::names() {
        let r = wb.compare(name, Scheme::fig8_set());
        let t_nv = r[&Scheme::Naive].completion_ns;
        let t_nm = r[&Scheme::Nmars].completion_ns;
        let t_re = r[&Scheme::ReCross].completion_ns;
        let e_nv = r[&Scheme::Naive].energy_pj;
        let e_nm = r[&Scheme::Nmars].energy_pj;
        let e_re = r[&Scheme::ReCross].energy_pj;
        let row = [t_nv / t_re, t_nm / t_re, e_nv / e_re, e_nm / e_re];
        s.push_str(&format!(
            "  {:<17} {:>11.2}x {:>11.2}x {:>13.2}x {:>13.2}x\n",
            name, row[0], row[1], row[2], row[3]
        ));
        for (a, v) in agg.iter_mut().zip(row) {
            *a += v;
        }
        n += 1.0;
    }
    s.push_str(&format!(
        "  {:<17} {:>11.2}x {:>11.2}x {:>13.2}x {:>13.2}x\n",
        "AVERAGE",
        agg[0] / n,
        agg[1] / n,
        agg[2] / n,
        agg[3] / n
    ));
    s.push_str("\npaper: speedup 2.58-6.85x vs naive (avg 5.2x), 2.60-5.48x vs nMARS (avg 3.97x);\n");
    s.push_str("       energy  3.60-12.55x vs naive (avg 8.4x), 1.39-3.65x vs nMARS (avg 6.1x*)\n");
    s.push_str("       (*abstract quotes 6.1x; per-workload numbers in §IV-B give avg 2.35x)\n");
    s
}

/// Fig. 9: crossbar activations, naive vs frequency vs ReCross.
pub fn fig9(wb: &mut Workbench) -> String {
    let mut s = String::new();
    s.push_str("FIG 9 — Crossbar activations (lower is better)\n\n");
    s.push_str(&format!(
        "  {:<17} {:>12} {:>12} {:>12} {:>9} {:>9}\n",
        "dataset", "naive", "frequency", "recross", "nv/re", "fq/re"
    ));
    for name in DatasetSpec::names() {
        let a = wb.activations(name, Scheme::fig9_set());
        let nv = a[&Scheme::Naive] as f64;
        let fq = a[&Scheme::Frequency] as f64;
        let re = a[&Scheme::ReCross] as f64;
        s.push_str(&format!(
            "  {:<17} {:>12} {:>12} {:>12} {:>8.2}x {:>8.2}x\n",
            name,
            a[&Scheme::Naive],
            a[&Scheme::Frequency],
            a[&Scheme::ReCross],
            nv / re,
            fq / re
        ));
    }
    s.push_str("\npaper: up to 8.79x fewer than naive, up to 5.27x fewer than frequency-based\n");
    s
}

/// Fig. 10: duplication-ratio sweep (0/5/10/20% area overhead).
pub fn fig10(wb: &mut Workbench) -> String {
    let ratios = [0.0, 0.05, 0.10, 0.20];
    let mut s = String::new();
    s.push_str("FIG 10 — Access-aware allocation: duplication-ratio sweep\n");
    s.push_str("(speedup & energy-efficiency vs naive; Dup-0% = grouping only)\n\n");
    s.push_str(&format!(
        "  {:<17} {:>10} {:>10} {:>10} {:>10}   {:>10} {:>10} {:>10} {:>10}\n",
        "", "t-0%", "t-5%", "t-10%", "t-20%", "e-0%", "e-5%", "e-10%", "e-20%"
    ));
    for name in DatasetSpec::names() {
        let sweep = wb.dup_sweep(name, &ratios);
        let base = wb.compare(name, [Scheme::Naive]);
        let t_nv = base[&Scheme::Naive].completion_ns;
        let e_nv = base[&Scheme::Naive].energy_pj;
        let mut row = format!("  {name:<17} ");
        for st in &sweep {
            row.push_str(&format!("{:>9.2}x ", t_nv / st.completion_ns));
        }
        row.push_str("  ");
        for st in &sweep {
            row.push_str(&format!("{:>9.2}x ", e_nv / st.energy_pj));
        }
        s.push_str(&row);
        s.push('\n');
    }
    s.push_str("\npaper: gains converge as duplication grows; dense workloads still gain at 20%\n");
    s
}

/// Fig. 11: energy efficiency vs CPU-only and CPU+GPU platforms.
pub fn fig11(wb: &mut Workbench) -> String {
    let mut s = String::new();
    s.push_str("FIG 11 — Energy efficiency vs host platforms (x better than host)\n\n");
    s.push_str(&format!(
        "  {:<17} {:>12} {:>12}\n",
        "dataset", "vs CPU", "vs CPU+GPU"
    ));
    let mut acc = [0.0f64; 2];
    let mut n = 0.0;
    let embed_dim = wb.embedding_dim();
    for name in DatasetSpec::names() {
        let host = HostModel::new(&HostParams::default(), embed_dim);
        let data = wb.dataset(name);
        let cpu = host.run_trace(&data.eval, HostPlatform::CpuOnly);
        let gpu = host.run_trace(&data.eval, HostPlatform::CpuGpu);
        let re = wb.compare(name, [Scheme::ReCross]);
        let e_re = re[&Scheme::ReCross].energy_pj;
        let r_cpu = cpu.energy_pj / e_re;
        let r_gpu = gpu.energy_pj / e_re;
        s.push_str(&format!("  {name:<17} {r_cpu:>11.0}x {r_gpu:>11.0}x\n"));
        acc[0] += r_cpu;
        acc[1] += r_gpu;
        n += 1.0;
    }
    s.push_str(&format!(
        "  {:<17} {:>11.0}x {:>11.0}x\n",
        "AVERAGE",
        acc[0] / n,
        acc[1] / n
    ));
    s.push_str("\npaper: avg 363x vs CPU-only, 1144x vs CPU+GPU\n");
    s
}

/// Run every report (the `report all` subcommand).
pub fn all(wb: &mut Workbench) -> String {
    let mut s = String::new();
    s.push_str(&table1());
    s.push('\n');
    for f in [fig2, fig4, fig5, fig6, fig8, fig9, fig10, fig11] {
        s.push_str(&f(wb));
        s.push('\n');
    }
    s
}

/// Ablation table for DESIGN.md's design-choice analysis: full ReCross vs
/// each component disabled.
pub fn ablation(wb: &mut Workbench, dataset: &str) -> String {
    let schemes = [
        Scheme::ReCross,
        Scheme::ReCrossNoDup,
        Scheme::ReCrossNoSwitch,
        Scheme::ReCrossLinear,
        Scheme::Naive,
    ];
    let r = wb.compare(dataset, schemes);
    let base_t = r[&Scheme::Naive].completion_ns;
    let base_e = r[&Scheme::Naive].energy_pj;
    let mut s = String::new();
    s.push_str(&format!("ABLATION — {dataset}\n\n"));
    s.push_str(&format!(
        "  {:<18} {:>10} {:>12} {:>12} {:>10} {:>12} {:>12} {:>12}\n",
        "variant", "speedup", "energy-eff", "activations", "xbars", "compl_us", "stall_us", "bus_us"
    ));
    for sc in schemes {
        let st = &r[&sc];
        s.push_str(&format!(
            "  {:<18} {:>9.2}x {:>11.2}x {:>12} {:>10} {:>12.2} {:>12.2} {:>12.2}\n",
            sc.name(),
            base_t / st.completion_ns,
            base_e / st.energy_pj,
            st.activations,
            wb.physical_crossbars(dataset, sc),
            st.completion_ns / 1e3,
            st.stall_ns / 1e3,
            st.bus_wait_ns / 1e3,
        ));
    }
    // One-time programming overhead of the duplication plan (the other
    // side of the area tradeoff; amortized over the mapping's lifetime).
    let model = crate::xbar::CrossbarModel::new(
        &wb.config().hardware,
        &crate::xbar::CircuitParams::default(),
    );
    let extra = wb
        .physical_crossbars(dataset, Scheme::ReCross)
        .saturating_sub(wb.physical_crossbars(dataset, Scheme::ReCrossNoDup));
    let (w_ns, w_pj) = model.programming_cost(extra);
    s.push_str(&format!(
        "\n  one-time duplication programming: {extra} extra crossbars, {:.1} µs / {:.1} nJ (amortized over the mapping lifetime)\n",
        w_ns / 1e3,
        w_pj / 1e3
    ));
    s
}

/// Look up a report function by CLI name.
#[allow(clippy::type_complexity)]
pub fn by_name(name: &str) -> Option<fn(&mut Workbench) -> String> {
    Some(match name {
        "fig2" => fig2,
        "fig4" => fig4,
        "fig5" => fig5,
        "fig6" => fig6,
        "fig8" => fig8,
        "fig9" => fig9,
        "fig10" => fig10,
        "fig11" => fig11,
        "all" => all,
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wb() -> Workbench {
        // Tiny scale so report tests stay fast.
        Workbench::new(0.01, 300, 128, 64, 42)
    }

    #[test]
    fn table1_lists_all_datasets() {
        let t = table1();
        for d in DatasetSpec::names() {
            assert!(t.contains(d), "missing {d}");
        }
        assert!(t.contains("932019") || t.contains("932,019") || t.contains("932019"));
    }

    #[test]
    fn fig8_reports_wins() {
        let mut wb = wb();
        let s = fig8(&mut wb);
        assert!(s.contains("AVERAGE"));
        // every dataset row present
        for d in DatasetSpec::names() {
            assert!(s.contains(d));
        }
    }

    #[test]
    fn fig9_reports_reduction() {
        let mut wb = wb();
        let s = fig9(&mut wb);
        assert!(s.contains("recross"));
        assert!(s.contains('x'));
    }

    #[test]
    fn by_name_resolves() {
        for n in ["fig2", "fig4", "fig5", "fig6", "fig8", "fig9", "fig10", "fig11", "all"] {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("fig99").is_none());
    }
}
