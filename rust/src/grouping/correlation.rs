//! Correlation-aware embedding grouping — paper §III-B, Algorithm 1.
//!
//! Walks the embedding list in descending access-frequency order. Each
//! ungrouped embedding seeds a new group; a candidate pool is maintained as
//! the union of the neighborhoods of all current group members, and the
//! candidate with the **highest co-occurrence weight to the group** is
//! merged until the group reaches `group_size` ("edges connected to merged
//! embeddings are preserved" — weights accumulate as members join).
//!
//! Complexity: every edge is relaxed at most once per endpoint membership,
//! and the max-weight candidate is found with a lazy binary heap, so the
//! whole pass is `O(E log E)` — fast enough for the ~1M-node Sports
//! catalogue.
//!
//! Embeddings with no (remaining) neighbors are packed at the end in
//! frequency order, matching the algorithm's fallthrough where
//! `candidateList` never yields a usable candidate.

use super::{Mapper, Mapping};
use crate::graph::{Affinity, CoGraph};
use crate::util::{par, FxHashMap};
use std::collections::BinaryHeap;

/// Algorithm 1 mapper.
#[derive(Debug, Clone, Copy, Default)]
pub struct CorrelationMapper;

impl Mapper for CorrelationMapper {
    fn name(&self) -> &'static str {
        "recross"
    }

    fn map(&self, graph: &CoGraph, group_size: usize) -> Mapping {
        assert!(group_size > 0);
        let n = graph.num_nodes();
        let mut grouped = vec![false; n];
        let order = graph.ids_by_frequency();
        let groups = form_groups(graph, group_size, &order, &mut grouped);

        // Compact trailing partial groups of isolated embeddings: the loop
        // above creates one group per isolated seed; merge them so cold
        // singletons don't each burn a whole crossbar.
        let groups = compact_partial_groups(groups, group_size);
        Mapping::from_groups_complete(groups, group_size, n)
    }
}

/// The Algorithm 1 grouping loop over an explicit candidate-seed order.
///
/// Nodes already marked in `grouped` are invisible: they never seed a
/// group, never enter a candidate pool. The full mapping is
/// `form_groups(graph, gs, ids_by_frequency(), all-false)`; the delta
/// path calls it with only the *moved* ids unmarked (in the same
/// frequency order), which regroups exactly those ids while clean groups
/// keep their membership — bit-identically, because this is the same
/// code either way. Generic over [`Affinity`] so the incremental
/// `WindowGraph` is grouped directly, no CSR materialisation.
///
/// **Parallelism.** Groups never span connected components of the
/// ungrouped-node subgraph (candidates only ever enter via member
/// neighborhoods), so when that subgraph has several components —
/// the delta path's common case: many small dirty clusters — each
/// component's grouping walk runs on its own worker and the component
/// outputs merge sorted by each group's seed position in `order`. That
/// merge reproduces the serial walk's interleaving exactly (a serial
/// scan pushes groups in strictly increasing seed position), so the
/// result is **bit-identical for any worker count**, which the
/// worker-sweep fuzz in `tests/offline_delta.rs` pins. One giant
/// component (typical for a full build) falls back to the serial walk.
pub(crate) fn form_groups<G: Affinity + Sync>(
    graph: &G,
    group_size: usize,
    order: &[u32],
    grouped: &mut [bool],
) -> Vec<Vec<u32>> {
    assert!(group_size > 0);
    let workers = par::default_workers();
    if workers > 1 && order.len() > 1 {
        if let Some(groups) = form_groups_parallel(graph, group_size, order, grouped, workers) {
            return groups;
        }
    }
    form_groups_serial(graph, group_size, order, grouped)
}

/// The serial Algorithm 1 walk (also each parallel worker's inner loop).
fn form_groups_serial<G: Affinity>(
    graph: &G,
    group_size: usize,
    order: &[u32],
    grouped: &mut [bool],
) -> Vec<Vec<u32>> {
    let mut groups: Vec<Vec<u32>> = Vec::with_capacity(order.len().div_ceil(group_size));

    // Reusable per-group state (cleared between groups).
    // candidate weight-to-group; lazy max-heap of (weight, candidate).
    let mut cand_weight: FxHashMap<u32, u64> = FxHashMap::default();
    let mut heap: BinaryHeap<(u64, u32)> = BinaryHeap::new();

    for &seed in order {
        if grouped[seed as usize] {
            continue;
        }
        // --- start a new group at `seed` ---
        let mut group = Vec::with_capacity(group_size);
        group.push(seed);
        grouped[seed as usize] = true;
        cand_weight.clear();
        heap.clear();
        relax_neighbors(graph, seed, grouped, &mut cand_weight, &mut heap);

        while group.len() < group_size {
            // Pop until a live entry: current weight matches and the
            // candidate is still ungrouped (lazy deletion).
            let mut best: Option<u32> = None;
            while let Some((w, c)) = heap.pop() {
                if !grouped[c as usize] && cand_weight.get(&c) == Some(&w) {
                    best = Some(c);
                    break;
                }
            }
            let Some(chosen) = best else {
                break; // candidate list exhausted (Alg. 1 line 10 miss)
            };
            group.push(chosen);
            grouped[chosen as usize] = true;
            cand_weight.remove(&chosen);
            relax_neighbors(graph, chosen, grouped, &mut cand_weight, &mut heap);
        }
        groups.push(group);
    }
    groups
}

/// Connected-component parallel path; `None` when the ungrouped
/// subgraph is one component (or empty) and the serial walk should run.
///
/// Each worker clones the `grouped` mask and walks its components
/// serially (components are disjoint, so one mask per worker is safe);
/// the merge sorts all produced groups by their seed's position in
/// `order` — the exact serial push order — and writes the final marks
/// back into the caller's mask.
fn form_groups_parallel<G: Affinity + Sync>(
    graph: &G,
    group_size: usize,
    order: &[u32],
    grouped: &mut [bool],
    workers: usize,
) -> Option<Vec<Vec<u32>>> {
    let n = grouped.len();

    // Union-find over the ungrouped-node subgraph. Components are
    // computed over *all* unmarked nodes (not just `order`): an unmarked
    // node outside `order` can still be pulled into a group as a
    // candidate, so it must travel with its component.
    let mut parent: Vec<u32> = (0..n as u32).collect();
    fn find(parent: &mut [u32], mut v: u32) -> u32 {
        while parent[v as usize] != v {
            let up = parent[parent[v as usize] as usize];
            parent[v as usize] = up;
            v = up;
        }
        v
    }
    for v in 0..n as u32 {
        if grouped[v as usize] {
            continue;
        }
        for &(nb, _) in graph.neighbors(v) {
            if grouped[nb as usize] {
                continue;
            }
            let (ra, rb) = (find(&mut parent, v), find(&mut parent, nb));
            if ra != rb {
                // Root at the smaller id: deterministic, input-order free.
                let (lo, hi) = if ra < rb { (ra, rb) } else { (rb, ra) };
                parent[hi as usize] = lo;
            }
        }
    }

    // Partition `order` by component, components sequenced by first
    // appearance in `order` (only cosmetic — the final sort by seed
    // position is what fixes the output order).
    let mut comp_index: FxHashMap<u32, usize> = FxHashMap::default();
    let mut components: Vec<Vec<u32>> = Vec::new();
    for &v in order {
        if grouped[v as usize] {
            continue;
        }
        let root = find(&mut parent, v);
        let ci = *comp_index.entry(root).or_insert_with(|| {
            components.push(Vec::new());
            components.len() - 1
        });
        components[ci].push(v);
    }
    if components.len() < 2 {
        return None;
    }

    // Each worker takes a contiguous run of components with its own
    // mask copy; results carry (seed position, group).
    let mut pos = vec![usize::MAX; n];
    for (i, &v) in order.iter().enumerate() {
        pos[v as usize] = pos[v as usize].min(i);
    }
    let base_mask: &[bool] = grouped;
    let partials = par::map_ranges(components.len(), workers, 1, |_, range| {
        let mut mask = base_mask.to_vec();
        let mut out: Vec<(usize, Vec<u32>)> = Vec::new();
        for comp in &components[range] {
            for group in form_groups_serial(graph, group_size, comp, &mut mask) {
                out.push((pos[group[0] as usize], group));
            }
        }
        out
    });

    let mut tagged: Vec<(usize, Vec<u32>)> = partials.into_iter().flatten().collect();
    tagged.sort_unstable_by_key(|&(seed_pos, _)| seed_pos);
    let groups: Vec<Vec<u32>> = tagged.into_iter().map(|(_, g)| g).collect();
    for g in &groups {
        for &v in g {
            grouped[v as usize] = true;
        }
    }
    Some(groups)
}

/// Add/update the group's candidate pool with `v`'s neighborhood
/// (Alg. 1 lines 6–8 and 16: `Merge(candidateList, neighbors(...))`).
fn relax_neighbors<G: Affinity>(
    graph: &G,
    v: u32,
    grouped: &[bool],
    cand_weight: &mut FxHashMap<u32, u64>,
    heap: &mut BinaryHeap<(u64, u32)>,
) {
    for &(nb, w) in graph.neighbors(v) {
        if grouped[nb as usize] {
            continue;
        }
        let entry = cand_weight.entry(nb).or_insert(0);
        *entry += w as u64;
        heap.push((*entry, nb));
    }
}

/// Greedily merge under-filled groups (first-fit-decreasing) so that only
/// the final group may be partial. Keeps full groups untouched: member
/// order (and hence crossbar rows) of well-correlated groups is preserved.
pub(crate) fn compact_partial_groups(groups: Vec<Vec<u32>>, group_size: usize) -> Vec<Vec<u32>> {
    let (full, partial): (Vec<_>, Vec<_>) =
        groups.into_iter().partition(|g| g.len() == group_size);
    let mut out = full;
    let mut members: Vec<u32> = Vec::new();
    for g in partial {
        members.extend(g);
    }
    for chunk in members.chunks(group_size) {
        out.push(chunk.to_vec());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Query, Trace};

    fn build(queries: Vec<Vec<u32>>, n: u32) -> CoGraph {
        CoGraph::build(&Trace {
            num_embeddings: n,
            queries: queries.into_iter().map(Query::new).collect(),
        })
    }

    #[test]
    fn co_accessed_items_share_group() {
        // Two disjoint hot cliques {0,1,2,3} and {4,5,6,7}.
        let mut qs = Vec::new();
        for _ in 0..10 {
            qs.push(vec![0, 1, 2, 3]);
            qs.push(vec![4, 5, 6, 7]);
        }
        let g = build(qs, 8);
        let m = CorrelationMapper.map(&g, 4);
        let ga = m.slot_of(0).group;
        for e in 1..4 {
            assert_eq!(m.slot_of(e).group, ga, "clique A split");
        }
        let gb = m.slot_of(4).group;
        for e in 5..8 {
            assert_eq!(m.slot_of(e).group, gb, "clique B split");
        }
        assert_ne!(ga, gb);
    }

    #[test]
    fn stronger_edges_win() {
        // 0 co-occurs with 1 nine times, with 2 once; group size 2 must
        // pair 0 with 1.
        let mut qs = vec![vec![0, 2]];
        for _ in 0..9 {
            qs.push(vec![0, 1]);
        }
        let g = build(qs, 4);
        let m = CorrelationMapper.map(&g, 2);
        assert_eq!(m.slot_of(0).group, m.slot_of(1).group);
        assert_ne!(m.slot_of(0).group, m.slot_of(2).group);
    }

    #[test]
    fn weight_to_group_accumulates() {
        // 3 is weakly tied to 0 but strongly to {1,2} combined; after
        // {0,1,2} are grouped, 3's accumulated weight must pull it in
        // before the unrelated 4 (tied to 0 with the same single-edge
        // weight as 3).
        let mut qs = Vec::new();
        for _ in 0..10 {
            qs.push(vec![0, 1, 2]);
        }
        qs.push(vec![0, 3]);
        qs.push(vec![1, 3]);
        qs.push(vec![2, 3]);
        qs.push(vec![0, 4]);
        let g = build(qs, 6);
        let m = CorrelationMapper.map(&g, 4);
        let grp = m.slot_of(0).group;
        assert_eq!(m.slot_of(3).group, grp, "3 should join via accumulated weight");
        assert_ne!(m.slot_of(4).group, grp);
    }

    #[test]
    fn all_embeddings_grouped_once() {
        let mut qs = Vec::new();
        for i in 0..20u32 {
            qs.push(vec![i % 40, (i * 7) % 40, (i * 13) % 40]);
        }
        let g = build(qs, 40);
        let m = CorrelationMapper.map(&g, 8);
        // from_groups_complete() asserts coverage + uniqueness; check sizes.
        assert!(m.groups.iter().all(|grp| grp.len() <= 8));
        let placed: usize = m.groups.iter().map(Vec::len).sum();
        assert_eq!(placed, 40);
    }

    #[test]
    fn isolated_embeddings_compact() {
        // No edges at all: groups should still be ~full, not one-per-seed.
        let g = build(vec![vec![0], vec![1], vec![2]], 100);
        let m = CorrelationMapper.map(&g, 10);
        assert_eq!(m.num_groups(), 10);
    }

    #[test]
    fn fewer_groups_touched_than_naive() {
        // End-to-end sanity: on a clustered workload, Algorithm 1 must
        // touch far fewer crossbars per query than naive mapping.
        use crate::grouping::{Mapper, NaiveMapper};
        use crate::util::Rng;
        let mut rng = Rng::new(3);
        let mut qs = Vec::new();
        for _ in 0..300 {
            // cluster c occupies ids {c, c+50, c+100, ...}: scattered in id
            // space, coherent in co-occurrence.
            let c = rng.below(50) as u32;
            let items: Vec<u32> = (0..8).map(|k| c + 50 * k).collect();
            qs.push(items);
        }
        let g = build(qs.clone(), 400);
        let recross = CorrelationMapper.map(&g, 8);
        let naive = NaiveMapper.map(&g, 8);
        let mut scratch = Vec::new();
        let act = |m: &Mapping, scratch: &mut Vec<u32>| -> usize {
            qs.iter()
                .map(|q| {
                    let query = Query::new(q.clone());
                    m.groups_touched(&query.items, scratch)
                })
                .sum()
        };
        let a_re = act(&recross, &mut scratch);
        let a_nv = act(&naive, &mut scratch);
        assert!(
            a_re * 4 <= a_nv,
            "recross {a_re} activations vs naive {a_nv}: expected >=4x reduction"
        );
    }
}
