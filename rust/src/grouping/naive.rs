//! Naive mapping — the paper's baseline.
//!
//! "intuitively mapping the embeddings to crossbar based on the original
//! itemID" (§IV-B): item `i` goes to group `i / group_size`, row
//! `i % group_size`. Because item ids carry no locality (catalogue ids are
//! essentially hashes with respect to co-purchase structure), a query's
//! items scatter across many crossbars.

use super::{Mapper, Mapping};
use crate::graph::CoGraph;

/// ItemID-order mapper.
#[derive(Debug, Clone, Copy, Default)]
pub struct NaiveMapper;

impl Mapper for NaiveMapper {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn map(&self, graph: &CoGraph, group_size: usize) -> Mapping {
        assert!(group_size > 0);
        let n = graph.num_nodes();
        let mut groups = Vec::with_capacity(n.div_ceil(group_size));
        let mut current = Vec::with_capacity(group_size);
        for e in 0..n as u32 {
            current.push(e);
            if current.len() == group_size {
                groups.push(std::mem::take(&mut current));
            }
        }
        if !current.is_empty() {
            groups.push(current);
        }
        Mapping::from_groups_complete(groups, group_size, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Query, Trace};

    fn graph(n: u32) -> CoGraph {
        CoGraph::build(&Trace {
            num_embeddings: n,
            queries: vec![Query::new(vec![0])],
        })
    }

    #[test]
    fn packs_by_id() {
        let m = NaiveMapper.map(&graph(10), 4);
        assert_eq!(m.groups.len(), 3);
        assert_eq!(m.groups[0], vec![0, 1, 2, 3]);
        assert_eq!(m.groups[2], vec![8, 9]);
        assert_eq!(m.slot_of(5).group, 1);
        assert_eq!(m.slot_of(5).row, 1);
    }

    #[test]
    fn exact_multiple_has_full_groups() {
        let m = NaiveMapper.map(&graph(8), 4);
        assert_eq!(m.groups.len(), 2);
        assert!(m.groups.iter().all(|g| g.len() == 4));
    }
}
