//! Embeddings-to-crossbar mapping strategies (paper §III-A step ③).
//!
//! A [`Mapping`] assigns every embedding to a `(group, row)` slot, where a
//! group corresponds to one crossbar's worth of rows. Three strategies are
//! implemented:
//!
//! * [`naive::NaiveMapper`] — the paper's baseline: consecutive item ids
//!   fill consecutive crossbars.
//! * [`frequency::FrequencyMapper`] — the frequency-based strategy the
//!   paper compares against in Fig. 9 (cite [33]): sort by access
//!   frequency, pack consecutively.
//! * [`correlation::CorrelationMapper`] — ReCross's correlation-aware
//!   grouping (Algorithm 1) over the co-occurrence graph.

pub mod correlation;
pub mod frequency;
pub mod naive;

pub use correlation::CorrelationMapper;
pub use frequency::FrequencyMapper;
pub use naive::NaiveMapper;

use crate::graph::CoGraph;
use crate::workload::EmbeddingId;

/// Location of one embedding inside the crossbar pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slot {
    /// Group index == logical crossbar index (before replication).
    pub group: u32,
    /// Row (wordline) within the crossbar.
    pub row: u16,
}

/// A complete embeddings-to-crossbar assignment.
#[derive(Debug, Clone)]
pub struct Mapping {
    /// Rows per crossbar used by this mapping.
    pub group_size: usize,
    /// Members of each group, in row order.
    pub groups: Vec<Vec<EmbeddingId>>,
    /// Slot of every embedding (indexed by embedding id).
    pub slot: Vec<Slot>,
}

impl Mapping {
    /// Build the reverse index from a group list (validates coverage).
    pub fn from_groups(groups: Vec<Vec<EmbeddingId>>, group_size: usize, n: usize) -> Self {
        let mut slot = vec![
            Slot {
                group: u32::MAX,
                row: 0
            };
            n
        ];
        for (g, members) in groups.iter().enumerate() {
            assert!(
                members.len() <= group_size,
                "group {g} has {} members > group_size {group_size}",
                members.len()
            );
            for (r, &e) in members.iter().enumerate() {
                let s = &mut slot[e as usize];
                assert_eq!(s.group, u32::MAX, "embedding {e} placed twice");
                *s = Slot {
                    group: g as u32,
                    row: r as u16,
                };
            }
        }
        assert!(
            slot.iter().all(|s| s.group != u32::MAX),
            "not all embeddings placed"
        );
        Self {
            group_size,
            groups,
            slot,
        }
    }

    /// Number of groups (== logical crossbars before replication).
    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Number of embeddings placed.
    pub fn num_embeddings(&self) -> usize {
        self.slot.len()
    }

    /// Slot of an embedding.
    #[inline]
    pub fn slot_of(&self, e: EmbeddingId) -> Slot {
        self.slot[e as usize]
    }

    /// Distinct groups touched by a query — the crossbar *activations* this
    /// query costs (Fig. 9's metric), assuming one activation per touched
    /// crossbar.
    pub fn groups_touched(&self, items: &[EmbeddingId], scratch: &mut Vec<u32>) -> usize {
        scratch.clear();
        scratch.extend(items.iter().map(|&e| self.slot[e as usize].group));
        scratch.sort_unstable();
        scratch.dedup();
        scratch.len()
    }
}

/// A mapping strategy.
pub trait Mapper {
    /// Human-readable name used in reports.
    fn name(&self) -> &'static str;

    /// Produce a mapping for all embeddings of `graph` with `group_size`
    /// rows per crossbar.
    fn map(&self, graph: &CoGraph, group_size: usize) -> Mapping;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_groups_builds_reverse_index() {
        let m = Mapping::from_groups(vec![vec![2, 0], vec![1, 3]], 2, 4);
        assert_eq!(m.slot_of(2), Slot { group: 0, row: 0 });
        assert_eq!(m.slot_of(0), Slot { group: 0, row: 1 });
        assert_eq!(m.slot_of(1), Slot { group: 1, row: 0 });
        assert_eq!(m.slot_of(3), Slot { group: 1, row: 1 });
        assert_eq!(m.num_groups(), 2);
    }

    #[test]
    #[should_panic(expected = "placed twice")]
    fn duplicate_placement_panics() {
        Mapping::from_groups(vec![vec![0, 0]], 2, 1);
    }

    #[test]
    #[should_panic(expected = "not all embeddings placed")]
    fn missing_placement_panics() {
        Mapping::from_groups(vec![vec![0]], 2, 2);
    }

    #[test]
    #[should_panic(expected = "group_size")]
    fn oversized_group_panics() {
        Mapping::from_groups(vec![vec![0, 1, 2]], 2, 3);
    }

    #[test]
    fn groups_touched_counts_distinct() {
        let m = Mapping::from_groups(vec![vec![0, 1], vec![2, 3]], 2, 4);
        let mut scratch = Vec::new();
        assert_eq!(m.groups_touched(&[0, 1], &mut scratch), 1);
        assert_eq!(m.groups_touched(&[0, 2], &mut scratch), 2);
        assert_eq!(m.groups_touched(&[0, 1, 2, 3], &mut scratch), 2);
        assert_eq!(m.groups_touched(&[], &mut scratch), 0);
    }
}
