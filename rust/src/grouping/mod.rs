//! Embeddings-to-crossbar mapping strategies (paper §III-A step ③).
//!
//! A [`Mapping`] assigns every embedding to a `(group, row)` slot, where a
//! group corresponds to one crossbar's worth of rows. Three strategies are
//! implemented:
//!
//! * [`naive::NaiveMapper`] — the paper's baseline: consecutive item ids
//!   fill consecutive crossbars.
//! * [`frequency::FrequencyMapper`] — the frequency-based strategy the
//!   paper compares against in Fig. 9 (cite [33]): sort by access
//!   frequency, pack consecutively.
//! * [`correlation::CorrelationMapper`] — ReCross's correlation-aware
//!   grouping (Algorithm 1) over the co-occurrence graph.

pub mod correlation;
pub mod delta;
pub mod frequency;
pub mod naive;

pub use correlation::CorrelationMapper;
pub use delta::{regroup_subset, GroupingDelta};
pub use frequency::FrequencyMapper;
pub use naive::NaiveMapper;

use crate::graph::{CoGraph, PAR_MIN_QUERIES};
use crate::util::{par, FxHashMap};
use crate::workload::{EmbeddingId, Trace};
use std::cmp::Reverse;

/// Location of one embedding inside the crossbar pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Slot {
    /// Group index == logical crossbar index (before replication).
    pub group: u32,
    /// Row (wordline) within the crossbar.
    pub row: u16,
}

/// A complete embeddings-to-crossbar assignment.
#[derive(Debug, Clone)]
pub struct Mapping {
    /// Rows per crossbar used by this mapping.
    pub group_size: usize,
    /// Members of each group, in row order.
    pub groups: Vec<Vec<EmbeddingId>>,
    /// Slot of every embedding (indexed by embedding id).
    pub slot: Vec<Slot>,
    /// Group that absorbs cold-start lookups: ids above the catalogue size
    /// route here instead of indexing `slot` out of bounds.
    overflow_group: u32,
}

impl Mapping {
    /// Build the reverse index from a group list.
    ///
    /// Embeddings in `0..n` that no group claims (ids absent from the
    /// grouping history — the cold-start case) are packed into *overflow
    /// groups* appended after the listed ones, so every in-catalogue id
    /// has a real `(group, row)` slot and the numeric reduction over them
    /// stays exact. Ids `>= n` are routed to [`Mapping::overflow_group`]
    /// by [`Mapping::slot_of`].
    pub fn from_groups(groups: Vec<Vec<EmbeddingId>>, group_size: usize, n: usize) -> Self {
        let mut groups = groups;
        let mut slot = vec![
            Slot {
                group: u32::MAX,
                row: 0
            };
            n
        ];
        for (g, members) in groups.iter().enumerate() {
            assert!(
                members.len() <= group_size,
                "group {g} has {} members > group_size {group_size}",
                members.len()
            );
            for (r, &e) in members.iter().enumerate() {
                let s = &mut slot[e as usize];
                assert_eq!(s.group, u32::MAX, "embedding {e} placed twice");
                *s = Slot {
                    group: g as u32,
                    row: r as u16,
                };
            }
        }
        // Cold-start ids: pack every unplaced embedding into overflow
        // groups at the end instead of asserting (lookup histories do not
        // cover the whole catalogue).
        let unplaced: Vec<EmbeddingId> = slot
            .iter()
            .enumerate()
            .filter(|(_, s)| s.group == u32::MAX)
            .map(|(e, _)| e as EmbeddingId)
            .collect();
        for chunk in unplaced.chunks(group_size.max(1)) {
            let g = groups.len() as u32;
            for (r, &e) in chunk.iter().enumerate() {
                slot[e as usize] = Slot {
                    group: g,
                    row: r as u16,
                };
            }
            groups.push(chunk.to_vec());
        }
        let overflow_group = groups.len().saturating_sub(1) as u32;
        Self {
            group_size,
            groups,
            slot,
            overflow_group,
        }
    }

    /// As [`Mapping::from_groups`], but asserts the listed groups already
    /// cover every embedding — no overflow groups may be needed. The
    /// mapping *strategies* use this: a mapper that drops ids has a bug,
    /// and silently packing the dropped ids into locality-free overflow
    /// groups would hide it. Only genuine cold-start construction (ids
    /// absent from the grouping history) goes through the lenient
    /// [`Mapping::from_groups`].
    pub fn from_groups_complete(
        groups: Vec<Vec<EmbeddingId>>,
        group_size: usize,
        n: usize,
    ) -> Self {
        let listed = groups.len();
        let m = Self::from_groups(groups, group_size, n);
        assert_eq!(
            m.num_groups(),
            listed,
            "mapper left embeddings unplaced (overflow groups appended)"
        );
        m
    }

    /// The group that absorbs out-of-catalogue lookups. This is the last
    /// group — a dedicated overflow group when the grouping history left
    /// ids unplaced, otherwise it *aliases the last real group*: a cold
    /// miss is charged one activation on that crossbar (cost model only —
    /// every numeric path treats out-of-catalogue ids as zero
    /// contribution, so no real embedding's data is ever misread).
    pub fn overflow_group(&self) -> u32 {
        self.overflow_group
    }

    /// Number of groups (== logical crossbars before replication).
    pub fn num_groups(&self) -> usize {
        self.groups.len()
    }

    /// Number of embeddings placed.
    pub fn num_embeddings(&self) -> usize {
        self.slot.len()
    }

    /// Slot of an embedding. Ids beyond the catalogue (never seen by the
    /// offline phase) route to the overflow group's row 0 instead of
    /// panicking — the scheduler then charges them one activation on that
    /// crossbar, which is the cost model for a cold-start miss.
    #[inline]
    pub fn slot_of(&self, e: EmbeddingId) -> Slot {
        match self.slot.get(e as usize) {
            Some(s) => *s,
            None => Slot {
                group: self.overflow_group,
                row: 0,
            },
        }
    }

    /// Distinct groups touched by a query — the crossbar *activations* this
    /// query costs (Fig. 9's metric), assuming one activation per touched
    /// crossbar.
    pub fn groups_touched(&self, items: &[EmbeddingId], scratch: &mut Vec<u32>) -> usize {
        scratch.clear();
        scratch.extend(items.iter().map(|&e| self.slot_of(e).group));
        scratch.sort_unstable();
        scratch.dedup();
        scratch.len()
    }

    /// Per-group activation load **and** co-access adjacency in a single
    /// trace walk. `freqs` equals [`crate::allocation::group_frequencies`]
    /// and `adj` equals [`Mapping::group_adjacency`] (a regression test
    /// pins both); the shard partitioner and the rebalance path used to
    /// compute them in two separate walks over the same trace.
    pub fn group_stats(&self, trace: &Trace) -> GroupStats {
        let n = self.num_groups();
        // Epoch-stamped accumulation (like `allocation::group_frequencies`):
        // this walks the whole trace on every replanning pass, so the
        // per-query sort+dedup is replaced by an O(k) TouchSet with only
        // the ≤k distinct groups sorted for canonical pair order.
        //
        // The trace walk fans out over [`crate::util::par`]: each worker
        // accumulates a private (freqs, weights) partial over its query
        // range, merged by integer addition in worker order. Per-query
        // contributions are position-independent counts, so any partition
        // of the stream sums to the same totals bit-identically.
        let partials = par::map_ranges(
            trace.queries.len(),
            par::default_workers(),
            PAR_MIN_QUERIES,
            |_, range| {
                let mut freqs = vec![0u64; n];
                let mut weights: FxHashMap<u64, u64> = FxHashMap::default();
                let mut touch = TouchSet::default();
                for q in &trace.queries[range] {
                    touch.begin(n);
                    for &e in &q.items {
                        touch.add(self.slot_of(e).group);
                    }
                    touch.sort_touched();
                    let groups = touch.touched();
                    for (i, &a) in groups.iter().enumerate() {
                        freqs[a as usize] += 1;
                        for &b in &groups[i + 1..] {
                            // sorted ascending, so (a, b) is already canonical.
                            let key = ((a as u64) << 32) | b as u64;
                            *weights.entry(key).or_insert(0) += 1;
                        }
                    }
                }
                (freqs, weights)
            },
        );
        let mut freqs = vec![0u64; n];
        let mut weights: FxHashMap<u64, u64> = FxHashMap::default();
        for (pfreqs, pweights) in partials {
            if weights.is_empty() {
                weights = pweights; // adopt the first partial wholesale
            } else {
                for (k, w) in pweights {
                    *weights.entry(k).or_insert(0) += w;
                }
            }
            for (f, pf) in freqs.iter_mut().zip(&pfreqs) {
                *f += pf;
            }
        }
        let mut adj: Vec<Vec<(u32, u64)>> = vec![Vec::new(); n];
        for (key, w) in weights {
            let a = (key >> 32) as u32;
            let b = key as u32;
            adj[a as usize].push((b, w));
            adj[b as usize].push((a, w));
        }
        // Deterministic neighbour order regardless of hash-map iteration.
        for nbrs in &mut adj {
            nbrs.sort_unstable();
        }
        GroupStats { freqs, adj }
    }

    /// Group-level co-access graph over a trace: `adj[g]` lists
    /// `(neighbour, weight)` pairs where `weight` counts queries touching
    /// both groups. This is the co-occurrence graph *lifted* from
    /// embeddings to crossbars — the signal the shard partitioner uses to
    /// keep correlated crossbars on the same shard. (Convenience wrapper;
    /// callers that also need per-group loads should take one
    /// [`Mapping::group_stats`] pass instead.)
    pub fn group_adjacency(&self, trace: &Trace) -> Vec<Vec<(u32, u64)>> {
        self.group_stats(trace).adj
    }

    /// Shard-aware partitioner: assign every group to one of `shards`
    /// shards, preserving co-occurrence locality so cross-shard query
    /// fan-out stays low while per-shard load stays balanced.
    ///
    /// Greedy heaviest-first placement: groups are visited in descending
    /// activation load; each goes to the shard holding the most co-access
    /// weight with it, subject to a `(1 + slack)` cap on both the shard's
    /// summed load and its group count (ties broken toward the emptier
    /// shard, then the lower shard id — fully deterministic).
    pub fn partition_across(&self, trace: &Trace, shards: usize, slack: f64) -> Vec<u32> {
        // Per-group activation load — the same metric the replication
        // planner and the cluster report use — plus the co-access
        // adjacency, in one trace walk.
        let stats = self.group_stats(trace);
        self.partition_with(&stats, shards, slack, None)
    }

    /// [`Mapping::partition_across`] over precomputed [`GroupStats`], with
    /// an optional *hold* set: `keep = (prior, dirty)` pins every clean
    /// group (`!dirty[g]`) to its prior shard and re-places only the dirty
    /// ones, against load/count caps computed over the **total** load.
    /// This is the delta rebalance's partitioner — with `keep = None` (or
    /// everything dirty) it reduces to the full greedy pass bit-exactly,
    /// which is what lets the full recompute survive as the oracle.
    pub fn partition_with(
        &self,
        stats: &GroupStats,
        shards: usize,
        slack: f64,
        keep: Option<(&[u32], &[bool])>,
    ) -> Vec<u32> {
        assert!(shards > 0, "need at least one shard");
        assert!(slack >= 0.0, "negative balance slack");
        let n = self.num_groups();
        if shards == 1 || n == 0 {
            return vec![0; n];
        }
        let load = &stats.freqs;
        let adj = &stats.adj;
        assert_eq!(load.len(), n, "stats do not match this mapping");
        assert_eq!(adj.len(), n, "stats do not match this mapping");

        let total: u64 = load.iter().sum();
        let load_cap = ((total as f64 * (1.0 + slack)) / shards as f64).ceil() as u64;
        let count_cap = ((n as f64 * (1.0 + slack)) / shards as f64).ceil().max(1.0) as usize;

        let mut shard_of = vec![u32::MAX; n];
        let mut shard_load = vec![0u64; shards];
        let mut shard_count = vec![0usize; shards];
        let mut affinity = vec![0u64; shards];

        let mut order: Vec<u32> = match keep {
            None => (0..n as u32).collect(),
            Some((prior, dirty)) => {
                assert_eq!(prior.len(), n, "prior assignment does not match");
                assert_eq!(dirty.len(), n, "dirty flags do not match");
                for g in 0..n {
                    if !dirty[g] {
                        let s = prior[g] as usize;
                        assert!(s < shards, "prior shard {s} out of range");
                        shard_of[g] = prior[g];
                        shard_load[s] += load[g];
                        shard_count[s] += 1;
                    }
                }
                (0..n as u32).filter(|&g| dirty[g as usize]).collect()
            }
        };
        order.sort_by_key(|&g| (Reverse(load[g as usize]), g));

        for &g in &order {
            for a in &mut affinity {
                *a = 0;
            }
            for &(nb, w) in &adj[g as usize] {
                let s = shard_of[nb as usize];
                if s != u32::MAX {
                    affinity[s as usize] += w;
                }
            }
            // Best eligible shard: max affinity, then fewest groups, then
            // least load, then lowest id.
            let mut best: Option<usize> = None;
            for s in 0..shards {
                if shard_load[s] >= load_cap || shard_count[s] >= count_cap {
                    continue;
                }
                best = match best {
                    None => Some(s),
                    Some(b) => {
                        let cand = (affinity[s], Reverse(shard_count[s]), Reverse(shard_load[s]));
                        let cur = (affinity[b], Reverse(shard_count[b]), Reverse(shard_load[b]));
                        if cand > cur {
                            Some(s)
                        } else {
                            Some(b)
                        }
                    }
                };
            }
            // All shards at capacity (possible when slack rounds down
            // hard): fall back to the least-loaded shard.
            let s = best.unwrap_or_else(|| {
                (0..shards)
                    .min_by_key(|&s| (shard_load[s], shard_count[s], s))
                    .unwrap()
            });
            shard_of[g as usize] = s as u32;
            shard_load[s] += load[g as usize];
            shard_count[s] += 1;
        }
        shard_of
    }
}

/// Per-group activation load and co-access adjacency over one trace,
/// computed by a single [`Mapping::group_stats`] walk. The two fields
/// are definitionally equal to [`crate::allocation::group_frequencies`]
/// and [`Mapping::group_adjacency`] respectively.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GroupStats {
    /// Queries touching each group (distinct-groups-per-query counting).
    pub freqs: Vec<u64>,
    /// `(neighbour, weight)` co-access lists, sorted, both directions.
    pub adj: Vec<Vec<(u32, u64)>>,
}

/// Epoch-stamped distinct-group accumulator — the sort-free core of the
/// scheduler's run decomposition and the allocation planner's frequency
/// counting.
///
/// The naive way to collect a query's distinct groups is *collect, sort,
/// dedup*: O(k log k) per query with a fresh sort each time. `TouchSet`
/// keeps one slot per group (`stamp`/`count`, grown lazily to the
/// mapping's group count) and an epoch counter: [`TouchSet::begin`] bumps
/// the epoch, which invalidates every slot in O(1) — no O(num_groups)
/// clear — and [`TouchSet::add`] stamps, zeroes, and counts in O(1). Only
/// the ≤k *touched* groups are ever sorted (by the caller, when order
/// matters), so a k-lookup query costs O(k) to accumulate and O(k log k)
/// worst-case only over its distinct groups, not its items.
///
/// The epoch is a `u64`: it cannot wrap in any realistic run, so a stale
/// stamp can never alias a live one.
#[derive(Debug, Clone, Default)]
pub struct TouchSet {
    /// Current epoch; slots with `stamp[g] == epoch` are live.
    epoch: u64,
    /// Last epoch each group was touched in.
    stamp: Vec<u64>,
    /// Touch count per group, valid only when the stamp is current.
    count: Vec<u32>,
    /// Groups touched this epoch, in first-touch order.
    touched: Vec<u32>,
}

impl TouchSet {
    /// Start a new accumulation over `num_groups` groups. O(1) amortised
    /// (grows the slot arrays on first use or when the mapping grows).
    pub fn begin(&mut self, num_groups: usize) {
        if self.stamp.len() < num_groups {
            self.stamp.resize(num_groups, 0);
            self.count.resize(num_groups, 0);
        }
        self.epoch += 1;
        self.touched.clear();
    }

    /// Count one touch of group `g`.
    #[inline]
    pub fn add(&mut self, g: u32) {
        let gi = g as usize;
        if self.stamp[gi] != self.epoch {
            self.stamp[gi] = self.epoch;
            self.count[gi] = 0;
            self.touched.push(g);
        }
        self.count[gi] += 1;
    }

    /// Sort the touched-group list ascending (≤k elements).
    pub fn sort_touched(&mut self) {
        self.touched.sort_unstable();
    }

    /// Groups touched this epoch (first-touch order, or ascending after
    /// [`TouchSet::sort_touched`]).
    pub fn touched(&self) -> &[u32] {
        &self.touched
    }

    /// Touches of group `g` this epoch (0 if untouched).
    #[inline]
    pub fn count_of(&self, g: u32) -> u32 {
        let gi = g as usize;
        if self.stamp.get(gi) == Some(&self.epoch) {
            self.count[gi]
        } else {
            0
        }
    }
}

/// A mapping strategy.
pub trait Mapper {
    /// Human-readable name used in reports.
    fn name(&self) -> &'static str;

    /// Produce a mapping for all embeddings of `graph` with `group_size`
    /// rows per crossbar.
    fn map(&self, graph: &CoGraph, group_size: usize) -> Mapping;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_groups_builds_reverse_index() {
        let m = Mapping::from_groups(vec![vec![2, 0], vec![1, 3]], 2, 4);
        assert_eq!(m.slot_of(2), Slot { group: 0, row: 0 });
        assert_eq!(m.slot_of(0), Slot { group: 0, row: 1 });
        assert_eq!(m.slot_of(1), Slot { group: 1, row: 0 });
        assert_eq!(m.slot_of(3), Slot { group: 1, row: 1 });
        assert_eq!(m.num_groups(), 2);
    }

    #[test]
    #[should_panic(expected = "placed twice")]
    fn duplicate_placement_panics() {
        Mapping::from_groups(vec![vec![0, 0]], 2, 1);
    }

    #[test]
    fn unplaced_ids_routed_to_overflow_group() {
        // Regression: ids absent from the grouping history used to trip
        // the "not all embeddings placed" assert; they must land in an
        // overflow group with a real row instead.
        let m = Mapping::from_groups(vec![vec![0]], 2, 4);
        assert_eq!(m.num_groups(), 3); // [0], [1,2], [3]
        assert_eq!(m.slot_of(1), Slot { group: 1, row: 0 });
        assert_eq!(m.slot_of(2), Slot { group: 1, row: 1 });
        assert_eq!(m.slot_of(3), Slot { group: 2, row: 0 });
        assert_eq!(m.overflow_group(), 2);
        // Every group respects the capacity bound.
        assert!(m.groups.iter().all(|g| g.len() <= 2));
    }

    #[test]
    fn out_of_catalogue_ids_routed_to_overflow_group() {
        // Regression: slot_of used to index out of bounds for cold-start
        // ids the offline phase never saw.
        let m = Mapping::from_groups(vec![vec![0, 1], vec![2, 3]], 2, 4);
        let s = m.slot_of(1_000_000);
        assert_eq!(s.group, m.overflow_group());
        assert_eq!(s.row, 0);
        let mut scratch = Vec::new();
        // groups_touched must also survive unseen ids.
        assert_eq!(m.groups_touched(&[0, 1_000_000], &mut scratch), 2);
    }

    #[test]
    #[should_panic(expected = "group_size")]
    fn oversized_group_panics() {
        Mapping::from_groups(vec![vec![0, 1, 2]], 2, 3);
    }

    #[test]
    fn groups_touched_counts_distinct() {
        let m = Mapping::from_groups(vec![vec![0, 1], vec![2, 3]], 2, 4);
        let mut scratch = Vec::new();
        assert_eq!(m.groups_touched(&[0, 1], &mut scratch), 1);
        assert_eq!(m.groups_touched(&[0, 2], &mut scratch), 2);
        assert_eq!(m.groups_touched(&[0, 1, 2, 3], &mut scratch), 2);
        assert_eq!(m.groups_touched(&[], &mut scratch), 0);
    }

    /// 4 groups of 2; queries co-access groups (0,1) and (2,3).
    fn co_access_fixture() -> (Mapping, Trace) {
        let m = Mapping::from_groups(
            vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]],
            2,
            8,
        );
        let mut queries = Vec::new();
        for _ in 0..10 {
            queries.push(crate::workload::Query::new(vec![0, 2])); // g0 + g1
            queries.push(crate::workload::Query::new(vec![4, 6])); // g2 + g3
        }
        (
            m,
            Trace {
                num_embeddings: 8,
                queries,
            },
        )
    }

    #[test]
    fn group_adjacency_counts_co_access() {
        let (m, t) = co_access_fixture();
        let adj = m.group_adjacency(&t);
        assert_eq!(adj[0], vec![(1, 10)]);
        assert_eq!(adj[1], vec![(0, 10)]);
        assert_eq!(adj[2], vec![(3, 10)]);
        assert_eq!(adj[3], vec![(2, 10)]);
    }

    #[test]
    fn partition_keeps_correlated_groups_together() {
        let (m, t) = co_access_fixture();
        let shard_of = m.partition_across(&t, 2, 0.5);
        assert_eq!(shard_of.len(), 4);
        assert_eq!(shard_of[0], shard_of[1], "co-accessed groups split");
        assert_eq!(shard_of[2], shard_of[3], "co-accessed groups split");
        assert_ne!(shard_of[0], shard_of[2], "everything piled on one shard");
    }

    #[test]
    fn partition_is_deterministic_and_total() {
        let (m, t) = co_access_fixture();
        let a = m.partition_across(&t, 3, 0.25);
        let b = m.partition_across(&t, 3, 0.25);
        assert_eq!(a, b);
        assert!(a.iter().all(|&s| (s as usize) < 3));
    }

    #[test]
    fn single_shard_is_trivial() {
        let (m, t) = co_access_fixture();
        assert_eq!(m.partition_across(&t, 1, 0.0), vec![0; 4]);
    }

    #[test]
    fn group_stats_matches_the_two_single_purpose_passes() {
        // The deduplicated one-pass counter must agree exactly with the
        // passes it replaced, on a trace with repeats, singletons, and
        // out-of-catalogue ids.
        let (m, mut t) = co_access_fixture();
        t.queries.push(crate::workload::Query::new(vec![0]));
        t.queries.push(crate::workload::Query::new(vec![0, 1, 4, 1_000_000]));
        let stats = m.group_stats(&t);
        assert_eq!(stats.freqs, crate::allocation::group_frequencies(&m, &t));
        assert_eq!(stats.adj, m.group_adjacency(&t));
    }

    #[test]
    fn partition_with_all_dirty_matches_partition_across() {
        let (m, t) = co_access_fixture();
        let stats = m.group_stats(&t);
        let full = m.partition_across(&t, 2, 0.5);
        let prior = vec![0u32; m.num_groups()];
        let dirty = vec![true; m.num_groups()];
        assert_eq!(
            m.partition_with(&stats, 2, 0.5, Some((&prior, &dirty))),
            full
        );
        assert_eq!(m.partition_with(&stats, 2, 0.5, None), full);
    }

    #[test]
    fn partition_with_holds_clean_groups() {
        let (m, t) = co_access_fixture();
        let stats = m.group_stats(&t);
        let prior = m.partition_across(&t, 2, 0.5);
        // Only group 3 is dirty: groups 0..3 must keep their shard.
        let mut dirty = vec![false; 4];
        dirty[3] = true;
        let out = m.partition_with(&stats, 2, 0.5, Some((&prior, &dirty)));
        assert_eq!(out[..3], prior[..3]);
        assert!((out[3] as usize) < 2);
    }

    #[test]
    fn touch_set_counts_distinct_groups() {
        let mut ts = TouchSet::default();
        ts.begin(8);
        for g in [3, 1, 3, 3, 7, 1] {
            ts.add(g);
        }
        assert_eq!(ts.touched(), &[3, 1, 7], "first-touch order");
        ts.sort_touched();
        assert_eq!(ts.touched(), &[1, 3, 7]);
        assert_eq!(ts.count_of(3), 3);
        assert_eq!(ts.count_of(1), 2);
        assert_eq!(ts.count_of(7), 1);
        assert_eq!(ts.count_of(0), 0, "untouched group counts zero");
        assert_eq!(ts.count_of(100), 0, "out-of-range group counts zero");
    }

    #[test]
    fn touch_set_epochs_isolate_queries() {
        let mut ts = TouchSet::default();
        ts.begin(4);
        ts.add(2);
        ts.add(2);
        assert_eq!(ts.count_of(2), 2);
        // New epoch: previous counts are invisible without any O(n) clear.
        ts.begin(4);
        assert!(ts.touched().is_empty());
        assert_eq!(ts.count_of(2), 0);
        ts.add(0);
        assert_eq!(ts.touched(), &[0]);
        assert_eq!(ts.count_of(0), 1);
        // Growing the group universe mid-stream is fine.
        ts.begin(16);
        ts.add(15);
        assert_eq!(ts.count_of(15), 1);
    }

    #[test]
    fn touch_set_matches_sort_dedup_on_random_streams() {
        let mut rng = crate::util::Rng::new(77);
        let mut ts = TouchSet::default();
        for _ in 0..200 {
            let n = rng.range(1, 40) as usize;
            let k = rng.range(0, 60) as usize;
            let items: Vec<u32> = (0..k).map(|_| rng.below(n as u64) as u32).collect();
            ts.begin(n);
            for &g in &items {
                ts.add(g);
            }
            ts.sort_touched();
            let mut expect = items.clone();
            expect.sort_unstable();
            expect.dedup();
            assert_eq!(ts.touched(), &expect[..]);
            for &g in &expect {
                let count = items.iter().filter(|&&x| x == g).count() as u32;
                assert_eq!(ts.count_of(g), count);
            }
        }
    }
}
