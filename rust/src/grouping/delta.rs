//! Delta regrouping — Algorithm 1 re-derived only where affinity moved.
//!
//! [`regroup_subset`] takes the previous [`Mapping`], the current affinity
//! graph, and the set of *dirty* nodes (from
//! [`crate::graph::GraphDelta::dirty_nodes`]), and re-runs the grouping
//! loop over exactly the groups those nodes live in. Everything else is
//! untouched:
//!
//! * **Clean groups keep their group id, membership, and row order
//!   bit-identically** — their crossbar tiles need no re-install.
//! * Dirty groups' members are pooled and regrouped by the *same*
//!   [`super::correlation::form_groups`] loop the full mapper uses, in
//!   the same frequency order, then refilled into the vacated group ids
//!   ascending. Leftover vacated ids become empty groups; empty **dirty**
//!   groups at the tail are trimmed (clean groups never renumber).
//!
//! With every group dirty this reproduces
//! [`super::CorrelationMapper::map`] bit-exactly — same loop, same order,
//! same compaction — which is what lets the full recompute survive as the
//! differential-fuzz oracle (`tests/offline_delta.rs`).

use super::correlation::{compact_partial_groups, form_groups};
use super::Mapping;
use crate::graph::Affinity;
use std::cmp::Reverse;

/// What one [`regroup_subset`] call changed.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GroupingDelta {
    /// Group ids whose membership was re-derived (ascending). Ids at the
    /// tail may have been trimmed from the new mapping entirely.
    pub changed_groups: Vec<u32>,
    /// Embedding ids re-placed by this regroup (ascending) — the tile
    /// rows that moved. Everything not listed kept its exact slot.
    pub moved_ids: Vec<u32>,
}

impl GroupingDelta {
    pub fn is_empty(&self) -> bool {
        self.changed_groups.is_empty()
    }
}

/// Re-derive groups for the dirty nodes' groups only; see the module
/// docs for the identity contract. `graph` is the *current* affinity
/// state (typically a [`crate::graph::WindowGraph`] after
/// `apply_window`); `prev` supplies the group size and the clean layout.
pub fn regroup_subset<G: Affinity + Sync>(
    graph: &G,
    prev: &Mapping,
    dirty_nodes: &[u32],
) -> (Mapping, GroupingDelta) {
    let n = prev.num_embeddings();
    assert_eq!(
        graph.num_nodes(),
        n,
        "affinity graph does not match the previous mapping's catalogue"
    );
    let group_size = prev.group_size;

    // Dirty groups: every group containing a dirty node.
    let mut dirty: Vec<u32> = dirty_nodes
        .iter()
        .filter(|&&v| (v as usize) < n)
        .map(|&v| prev.slot_of(v).group)
        .collect();
    dirty.sort_unstable();
    dirty.dedup();
    if dirty.is_empty() {
        return (prev.clone(), GroupingDelta::default());
    }
    let mut is_dirty = vec![false; prev.num_groups()];
    for &g in &dirty {
        is_dirty[g as usize] = true;
    }

    // Whole dirty groups are re-derived: a group's internal row order is
    // a product of the grouping walk, so partial in-place edits would
    // diverge from what a fresh Algorithm 1 run produces.
    let mut moved: Vec<u32> = dirty
        .iter()
        .flat_map(|&g| prev.groups[g as usize].iter().copied())
        .collect();
    moved.sort_unstable();

    let mut grouped = vec![true; n];
    for &v in &moved {
        grouped[v as usize] = false;
    }
    // The same candidate order Algorithm 1 uses, restricted to the moved
    // ids — with every group dirty this equals `ids_by_frequency()`, so
    // full scope reproduces `CorrelationMapper::map` bit-identically.
    let mut order = moved.clone();
    order.sort_by_key(|&v| (Reverse(graph.freq(v)), v));

    let regrouped = form_groups(graph, group_size, &order, &mut grouped);
    let regrouped = compact_partial_groups(regrouped, group_size);
    debug_assert!(
        regrouped.len() <= dirty.len(),
        "regrouping produced more groups than it vacated"
    );

    // Refill vacated ids ascending; trim empty dirty groups off the tail
    // only, so clean groups never renumber.
    let mut groups = prev.groups.clone();
    let mut fresh = regrouped.into_iter();
    for &g in &dirty {
        groups[g as usize] = fresh.next().unwrap_or_default();
    }
    while let Some(last) = groups.last() {
        if last.is_empty() && is_dirty[groups.len() - 1] {
            groups.pop();
        } else {
            break;
        }
    }

    let mapping = Mapping::from_groups_complete(groups, group_size, n);
    let delta = GroupingDelta {
        changed_groups: dirty,
        moved_ids: moved,
    };
    (mapping, delta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{CoGraph, WindowGraph};
    use crate::grouping::{CorrelationMapper, Mapper};
    use crate::workload::{Query, Trace};

    fn trace(n: u32, queries: Vec<Vec<u32>>) -> Trace {
        Trace {
            num_embeddings: n,
            queries: queries.into_iter().map(Query::new).collect(),
        }
    }

    /// Two hot cliques + background noise.
    fn base_trace() -> Trace {
        let mut qs = Vec::new();
        for _ in 0..10 {
            qs.push(vec![0, 1, 2, 3]);
            qs.push(vec![4, 5, 6, 7]);
        }
        qs.push(vec![8, 9]);
        qs.push(vec![10, 11]);
        trace(16, qs)
    }

    fn assert_same_mapping(a: &Mapping, b: &Mapping) {
        assert_eq!(a.groups, b.groups);
        assert_eq!(a.slot, b.slot);
        assert_eq!(a.group_size, b.group_size);
    }

    #[test]
    fn empty_dirty_set_is_identity() {
        let g = CoGraph::build(&base_trace());
        let prev = CorrelationMapper.map(&g, 4);
        let (m, d) = regroup_subset(&g, &prev, &[]);
        assert!(d.is_empty());
        assert_same_mapping(&m, &prev);
    }

    #[test]
    fn full_scope_reproduces_map_bit_identically() {
        // Regroup everything against a *changed* graph: must equal a
        // fresh CorrelationMapper run on that graph.
        let t1 = base_trace();
        let g1 = CoGraph::build(&t1);
        let prev = CorrelationMapper.map(&g1, 4);

        let mut t2 = base_trace();
        for _ in 0..20 {
            t2.queries.push(Query::new(vec![0, 8, 12]));
        }
        let w = WindowGraph::from_trace(&t2);
        let all: Vec<u32> = (0..16).collect();
        let (m, d) = regroup_subset(&w, &prev, &all);
        let oracle = CorrelationMapper.map(&CoGraph::build(&t2), 4);
        assert_same_mapping(&m, &oracle);
        assert_eq!(d.moved_ids, all);
    }

    #[test]
    fn clean_groups_keep_rows_bit_identically() {
        let t = base_trace();
        let g = CoGraph::build(&t);
        let prev = CorrelationMapper.map(&g, 4);
        // Dirty only node 8: exactly its group is re-derived.
        let (m, d) = regroup_subset(&g, &prev, &[8]);
        let dirty_group = prev.slot_of(8).group;
        assert_eq!(d.changed_groups, vec![dirty_group]);
        for (gi, members) in prev.groups.iter().enumerate() {
            if gi as u32 != dirty_group {
                assert_eq!(&m.groups[gi], members, "clean group {gi} changed");
            }
        }
        // Clean ids keep their exact slot.
        for v in 0..16u32 {
            if !d.moved_ids.contains(&v) {
                assert_eq!(m.slot_of(v), prev.slot_of(v), "clean id {v} moved");
            }
        }
        // Moved ids are exactly the dirty group's former members.
        let mut expect: Vec<u32> = prev.groups[dirty_group as usize].clone();
        expect.sort_unstable();
        assert_eq!(d.moved_ids, expect);
    }

    #[test]
    fn regrouping_never_grows_the_group_count() {
        let t = base_trace();
        let g = CoGraph::build(&t);
        let prev = CorrelationMapper.map(&g, 4);
        for dirty in [vec![0u32], vec![0, 4], vec![0, 4, 8, 10], (0..16).collect()] {
            let (m, _) = regroup_subset(&g, &prev, &dirty);
            assert!(m.num_groups() <= prev.num_groups());
        }
    }
}
