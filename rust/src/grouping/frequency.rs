//! Frequency-based mapping — the Fig. 9 comparison point (paper cite [33]).
//!
//! Embeddings are sorted by descending access frequency and packed
//! consecutively. Hot embeddings end up co-located, which helps a little
//! (hot items do co-occur with other hot items more than uniformly), but
//! the strategy is blind to the actual co-occurrence structure, so most of
//! a query still scatters.

use super::{Mapper, Mapping};
use crate::graph::CoGraph;

/// Access-frequency-order mapper.
#[derive(Debug, Clone, Copy, Default)]
pub struct FrequencyMapper;

impl Mapper for FrequencyMapper {
    fn name(&self) -> &'static str {
        "frequency"
    }

    fn map(&self, graph: &CoGraph, group_size: usize) -> Mapping {
        assert!(group_size > 0);
        let n = graph.num_nodes();
        let ids = graph.ids_by_frequency();
        let mut groups = Vec::with_capacity(n.div_ceil(group_size));
        for chunk in ids.chunks(group_size) {
            groups.push(chunk.to_vec());
        }
        Mapping::from_groups_complete(groups, group_size, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Query, Trace};

    #[test]
    fn hot_embeddings_first() {
        // item 7 hottest, then 3, then the rest.
        let mut queries = vec![Query::new(vec![7, 3])];
        for _ in 0..5 {
            queries.push(Query::new(vec![7]));
        }
        queries.push(Query::new(vec![3]));
        let g = CoGraph::build(&Trace {
            num_embeddings: 10,
            queries,
        });
        let m = FrequencyMapper.map(&g, 4);
        assert_eq!(m.groups[0][0], 7);
        assert_eq!(m.groups[0][1], 3);
        assert_eq!(m.num_groups(), 3);
    }

    #[test]
    fn covers_all_embeddings() {
        let g = CoGraph::build(&Trace {
            num_embeddings: 13,
            queries: vec![Query::new(vec![0, 1])],
        });
        let m = FrequencyMapper.map(&g, 5);
        let placed: usize = m.groups.iter().map(Vec::len).sum();
        assert_eq!(placed, 13);
    }
}
