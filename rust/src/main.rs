//! `recross` launcher: offline-phase tooling, report harness, and the
//! serving demo — every subcommand a thin client of the
//! [`recross::deploy`] facade.
//!
//! ```text
//! recross report --figure <fig2|fig4|fig5|fig6|fig8|fig9|fig10|fig11|table1|all|ablation>
//! recross generate   --dataset software --out trace.rxtr
//! recross analyze    <trace.rxtr>
//! recross serve      --dataset software --requests 256
//! recross serve      --arrivals poisson --rate 50000  # open-loop latency sim
//! recross cluster    --shards 4 --dataset software # sharded scatter-gather pool
//! recross autotune   --dataset automotive          # pick dup ratio (knee)
//! recross status     --json                        # obs-instrumented drive -> metrics snapshot
//! recross status     --watch --interval 500        # streaming windowed telemetry + SLO alerts
//! ```
//!
//! Configuration flows through one precedence chain: built-in defaults
//! (`Config::serving_default` / `Config::open_loop_default`) < a
//! `--config` TOML file < explicitly passed CLI flags
//! (`Config::overlay_cli`).

use recross::config::Config;
use recross::coordinator::{BatchPolicy, Request};
use recross::deploy::{Deployment, Sharded, ShardingMode, SinglePool};
use recross::engine::Scheme;
use recross::metrics::{fit_power_law, percentile};
use recross::report::{self, Workbench};
use recross::util::cli::ArgSpec;
use recross::util::Rng;
use recross::workload::{access_frequencies, DatasetSpec, Generator, Trace};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let spec = ArgSpec::new("ReCross: ReRAM-crossbar embedding reduction (paper reproduction)")
        .positional(
            "command",
            "report | generate | analyze | serve | cluster | autotune | status",
        )
        .opt("config", "", "TOML config file (CLI flags override)")
        .opt("figure", "all", "report figure (fig2..fig11, table1, all, ablation)")
        .opt("dataset", "software", "dataset name (Table I)")
        .opt("scale", "0.05", "dataset scale factor (1.0 = paper size)")
        .opt("history", "4000", "history-trace queries (offline phase)")
        .opt("eval", "1024", "eval-trace queries")
        .opt("queries", "4096", "queries to generate")
        .opt("seed", "42", "rng seed")
        .opt("out", "trace.rxtr", "output path for generate")
        .opt("requests", "256", "requests to serve in the demo")
        .opt("batch", "32", "dynamic-batcher max batch")
        .opt(
            "arrivals",
            "closed",
            "serve traffic shape: closed|poisson|bursty|diurnal (open-loop sim)",
        )
        .opt("rate", "50000", "open-loop offered load, queries/second")
        .opt(
            "max-wait-us",
            "5",
            "dynamic-batcher max wait, µs (scheme.max_wait_us; live default 2000, open-loop 5)",
        )
        .opt("scheme", "recross", "serving scheme: recross|naive|frequency|nmars")
        .opt(
            "workers",
            "0",
            "offline-phase worker threads (offline.workers; 0 = all cores)",
        )
        .opt("artifacts", "artifacts", "AOT artifacts directory")
        .opt("shards", "4", "shard executors for the cluster mode")
        .opt("vnodes", "128", "virtual nodes per shard on the hash ring")
        .opt("partition", "locality", "group->shard partitioner: locality|hash")
        .opt("slack", "0.10", "locality partitioner balance slack")
        .opt("obs-sample", "1.0", "flight-recorder span sampling rate, 0..=1")
        .opt("obs-ring", "4096", "flight-recorder ring capacity (events)")
        .opt("trace", "", "write Chrome trace-event JSON here (status mode)")
        .opt("interval", "1000", "watch tick interval, ms (watch.interval_ms)")
        .opt("ticks", "0", "watch ticks before exiting; 0 streams until interrupted")
        .opt("slo-p99-ns", "5000000", "SLO: per-window p99 sojourn ceiling, ns")
        .opt("slo-depth", "64", "SLO: per-window mean queue-depth ceiling")
        .opt("alerts", "", "write the recross.alerts v1 JSON-lines stream here (watch mode)")
        .opt("store-hot", "64", "tiered store: crossbar-resident hot tiles (store.hot_tiles)")
        .opt(
            "store-dram",
            "0",
            "tiered store: DRAM-tier tile capacity, 0 = unbounded (store.dram_tiles)",
        )
        .opt("store-dram-ns", "120", "tiered store: DRAM tile-fetch latency, ns (store.dram_ns)")
        .opt("store-cold-ns", "2500", "tiered store: cold tile-fetch latency, ns (store.cold_ns)")
        .opt(
            "store-promote-hits",
            "2",
            "tiered store: window hits before promotion (store.promote_hits)",
        )
        .opt(
            "store-replan",
            "8",
            "tiered store: batches between tier replans (store.replan_batches)",
        )
        .flag("obs", "enable the observability plane (metrics + flight recorder)")
        .flag("json", "machine-readable metrics snapshot (status mode)")
        .flag(
            "watch",
            "stream windowed telemetry + SLO burn-rate alerts (status mode)",
        )
        .flag(
            "replica-routing",
            "spread hot-group replicas across shards; route by power-of-two-choices",
        )
        .flag(
            "rebalance",
            "arm the drift monitor and remap placement online (epoch swaps)",
        )
        .flag(
            "tiered",
            "serve from the capacity-constrained tiered store (status mode)",
        )
        .flag("verbose", "extra logging");

    let args = match spec.parse(&argv) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };

    let result = match args.pos(0).unwrap_or("") {
        "report" => cmd_report(&args),
        "generate" => cmd_generate(&args),
        "analyze" => cmd_analyze(&args),
        "serve" => cmd_serve(&args),
        "cluster" => cmd_cluster(&args),
        "autotune" => cmd_autotune(&args),
        "status" => cmd_status(&args),
        other => {
            eprintln!("unknown command {other:?}\n\n{}", spec.usage("recross"));
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// The one config chain every subcommand shares: `base` (the mode's
/// built-in defaults) < `--config` TOML < explicitly passed CLI flags.
fn cli_config(args: &recross::util::cli::Args, base: Config) -> anyhow::Result<Config> {
    let mut cfg = match args.get("config") {
        "" => base,
        path => Config::from_file_with_base(path, base)?,
    };
    cfg.overlay_cli(args)?;
    Ok(cfg)
}

fn parse_scheme(args: &recross::util::cli::Args) -> anyhow::Result<Scheme> {
    let name = args.get("scheme");
    Scheme::by_name(name).ok_or_else(|| anyhow::anyhow!("unknown scheme {name:?}"))
}

fn cmd_report(args: &recross::util::cli::Args) -> anyhow::Result<()> {
    let fig = args.get("figure");
    if fig == "table1" {
        println!("{}", report::table1());
        return Ok(());
    }
    let scale: f64 = args.get_as("scale").map_err(anyhow::Error::msg)?;
    let cfg = cli_config(args, Config::serving_default())?;
    let mut wb = Workbench::new(
        scale,
        cfg.workload.history_queries,
        cfg.workload.eval_queries,
        cfg.scheme.group_size,
        cfg.workload.seed,
    );
    if fig == "ablation" {
        println!("{}", report::ablation(&mut wb, &cfg.workload.dataset));
        return Ok(());
    }
    match report::by_name(fig) {
        Some(f) => {
            println!("{}", f(&mut wb));
            Ok(())
        }
        None => anyhow::bail!(
            "unknown figure {fig:?} (try fig2/fig4/fig5/fig6/fig8/fig9/fig10/fig11/table1/all/ablation)"
        ),
    }
}

fn cmd_generate(args: &recross::util::cli::Args) -> anyhow::Result<()> {
    let scale: f64 = args.get_as("scale").map_err(anyhow::Error::msg)?;
    let queries: usize = args.get_as("queries").map_err(anyhow::Error::msg)?;
    let cfg = cli_config(args, Config::serving_default())?;
    let spec = DatasetSpec::by_name(&cfg.workload.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {:?}", cfg.workload.dataset))?
        .scaled(scale);
    let g = Generator::new(&spec, cfg.workload.seed);
    let trace = g.trace(queries, cfg.workload.seed.wrapping_add(1));
    let out = args.get("out");
    trace.save(out)?;
    println!(
        "wrote {out}: {} queries, {} embeddings, {:.1} mean lookups/query",
        trace.queries.len(),
        trace.num_embeddings,
        trace.mean_lookups()
    );
    Ok(())
}

fn cmd_analyze(args: &recross::util::cli::Args) -> anyhow::Result<()> {
    let path = args
        .pos(1)
        .ok_or_else(|| anyhow::anyhow!("usage: recross analyze <trace.rxtr>"))?;
    let trace = Trace::load(path)?;
    println!("trace: {path}");
    println!("  embeddings:       {}", trace.num_embeddings);
    println!("  queries:          {}", trace.queries.len());
    println!("  total lookups:    {}", trace.total_lookups());
    println!("  mean lookups/qry: {:.2}", trace.mean_lookups());
    let freq = access_frequencies(&trace);
    let accessed = freq.iter().filter(|&&f| f > 0).count();
    println!(
        "  accessed items:   {} ({:.1}%)",
        accessed,
        100.0 * accessed as f64 / freq.len().max(1) as f64
    );
    match fit_power_law(&freq) {
        Some(f) => println!(
            "  access power-law: alpha={:.2} R^2={:.3} ({})",
            f.alpha,
            f.r_squared,
            if f.is_power_law() { "power-law" } else { "not power-law" }
        ),
        None => println!("  access power-law: insufficient data"),
    }
    Ok(())
}

fn cmd_autotune(args: &recross::util::cli::Args) -> anyhow::Result<()> {
    use recross::allocation::tune_dup_ratio;
    use recross::graph::CoGraph;
    use recross::workload::generate;
    let scale: f64 = args.get_as("scale").map_err(anyhow::Error::msg)?;
    let cfg = cli_config(args, Config::serving_default())?;
    let spec = DatasetSpec::by_name(&cfg.workload.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {:?}", cfg.workload.dataset))?
        .scaled(scale);
    let (history, eval) = generate(
        &spec,
        cfg.workload.history_queries,
        cfg.workload.eval_queries,
        cfg.workload.seed,
    );
    let graph = CoGraph::build(&history);
    println!(
        "auto-tuning duplication ratio on {} (scale {scale})...",
        cfg.workload.dataset
    );
    let result = tune_dup_ratio(
        &graph,
        &history,
        &eval,
        &cfg,
        &[0.0, 0.025, 0.05, 0.10, 0.20, 0.40],
        1.05,
    )?;
    println!("{:>8} {:>12} {:>10} {:>8}", "dup%", "time µs", "speedup", "xbars");
    for p in &result.sweep {
        let marker = if p.dup_ratio == result.chosen { "  <-- knee" } else { "" };
        println!(
            "{:>7.1}% {:>12.1} {:>9.2}x {:>8}{marker}",
            p.dup_ratio * 100.0,
            p.completion_ns / 1e3,
            p.speedup,
            p.physical_crossbars
        );
    }
    println!("\nchosen dup_ratio = {}", result.chosen);
    Ok(())
}

fn cmd_serve(args: &recross::util::cli::Args) -> anyhow::Result<()> {
    // `--arrivals poisson|bursty|diurnal` switches to the open-loop
    // simulated-time driver (no PJRT artifacts needed); the default
    // "closed" keeps the original live-thread demo below.
    match args.get("arrivals") {
        "closed" => {}
        name => {
            let kind = recross::loadgen::ArrivalKind::by_name(name).ok_or_else(|| {
                anyhow::anyhow!("unknown arrival process {name:?} (try poisson|bursty|diurnal)")
            })?;
            return cmd_serve_open_loop(args, kind);
        }
    }
    let scale: f64 = args.get_as("scale").map_err(anyhow::Error::msg)?;
    let n_requests = args.get_positive("requests").map_err(anyhow::Error::msg)?;
    let max_batch = args.get_positive("batch").map_err(anyhow::Error::msg)?;
    let scheme = parse_scheme(args)?;
    let cfg = cli_config(args, Config::serving_default())?;
    recross::runtime::require_artifacts(&cfg.artifacts_dir)?;

    println!(
        "starting server: dataset={} scheme={} scale={scale}",
        cfg.workload.dataset,
        scheme.name()
    );
    let spec = DatasetSpec::by_name(&cfg.workload.dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset"))?
        .scaled(scale);
    let seed = cfg.workload.seed;
    let dense_features = cfg.workload.dense_features;
    let gen = Generator::new(&spec, seed);
    let policy = BatchPolicy::from_config(&cfg, max_batch);
    let prepared = Deployment::of(cfg).scheme(scheme).scale(scale).build()?;
    let pool = SinglePool::spawn(prepared, policy)?;
    let handle = pool.handle();

    // Drive the demo workload.
    let mut rng = Rng::new(seed.wrapping_add(77));
    let reqs: Vec<Request> = (0..n_requests as u64)
        .map(|id| {
            let q = gen.query(&mut rng);
            Request {
                id,
                dense: (0..dense_features).map(|_| rng.normal() as f32).collect(),
                items: q.items,
            }
        })
        .collect();
    let t0 = std::time::Instant::now();
    let responses = handle.infer_many(reqs)?;
    let wall = t0.elapsed();

    let lat_ms: Vec<f64> = responses
        .iter()
        .map(|r| r.latency.as_secs_f64() * 1e3)
        .collect();
    let acts: u64 = responses.iter().map(|r| r.activations).sum();
    println!("served {} requests in {:.2?}", responses.len(), wall);
    println!(
        "  throughput:  {:.0} req/s",
        responses.len() as f64 / wall.as_secs_f64()
    );
    println!(
        "  latency ms:  p50 {:.2}  p95 {:.2}  p99 {:.2}",
        percentile(&lat_ms, 50.0),
        percentile(&lat_ms, 95.0),
        percentile(&lat_ms, 99.0)
    );
    println!(
        "  crossbar activations: {acts} ({:.1}/req)",
        acts as f64 / responses.len() as f64
    );
    if args.flag("verbose") {
        for r in responses.iter().take(5) {
            println!("  req {} -> logit {:.4}", r.id, r.logit);
        }
    }
    Ok(())
}

/// Open-loop serving simulation (`serve --arrivals poisson --rate R`):
/// no PJRT, no threads — a seeded arrival process stamps every query
/// with an arrival time, the live dynamic-batching policy decides batch
/// boundaries on the simulated clock, and the deployment's simulated
/// backends ([`recross::deploy::SimBackend`]) supply per-query service
/// times through the one [`recross::loadgen::drive`] loop. Reports
/// p50/p95/p99/p999 sojourn latency, throughput, and mean queue depth
/// for the single-pool *and* the `--shards`-way sharded back-ends on
/// identical traffic. Bit-reproducible for a fixed
/// `(dataset, scheme, arrivals, rate, seed)`.
fn cmd_serve_open_loop(
    args: &recross::util::cli::Args,
    kind: recross::loadgen::ArrivalKind,
) -> anyhow::Result<()> {
    use recross::loadgen::{drive, Arrivals, OpenLoopReport};
    use recross::util::fmt_ns;

    let scale: f64 = args.get_as("scale").map_err(anyhow::Error::msg)?;
    let n_requests = args.get_positive("requests").map_err(anyhow::Error::msg)?;
    let max_batch = args.get_positive("batch").map_err(anyhow::Error::msg)?;
    let shards = args.get_positive("shards").map_err(anyhow::Error::msg)?;
    let rate: f64 = args.get_as("rate").map_err(anyhow::Error::msg)?;
    anyhow::ensure!(rate > 0.0, "--rate must be positive");
    let slack: f64 = args.get_as("slack").map_err(anyhow::Error::msg)?;
    anyhow::ensure!(slack >= 0.0, "--slack must be non-negative");
    let scheme = parse_scheme(args)?;
    // Fast-fail before the offline phase runs (Prepared::sim re-checks
    // for programmatic callers).
    anyhow::ensure!(
        scheme != Scheme::Nmars,
        "the open-loop driver serves the MAC dataflow; scheme {:?} is not supported here",
        scheme.name()
    );
    let cfg = cli_config(args, Config::open_loop_default())?;
    let seed = cfg.workload.seed;
    let max_wait_us = cfg.scheme.max_wait_us;
    println!(
        "open-loop serving sim: dataset={} scheme={} arrivals={} rate={rate}/s seed={seed}",
        cfg.workload.dataset,
        scheme.name(),
        kind.name()
    );
    let policy = BatchPolicy::from_config(&cfg, max_batch);
    let prepared = Deployment::of(cfg).scheme(scheme).scale(scale).build()?;
    let single = prepared.sim()?;
    let sharded = prepared.sim_sharded(shards, slack)?;

    // Fresh traffic from the same catalogue (held-out seed), stamped by
    // the arrival process.
    let spec = DatasetSpec::by_name(&prepared.config().workload.dataset)
        .ok_or_else(|| {
            anyhow::anyhow!("unknown dataset {:?}", prepared.config().workload.dataset)
        })?
        .scaled(scale);
    let gen = Generator::new(&spec, seed);
    let trace = gen.trace(n_requests, seed.wrapping_add(3));
    let arrivals = Arrivals::from_kind(kind, rate, seed).take(trace.queries.len());
    println!(
        "queries={} batch<={max_batch} wait={max_wait_us}µs shards={shards} (locality)",
        trace.queries.len()
    );

    let single_r = drive(&single, &trace.queries, &arrivals, &policy);
    let sharded_r = drive(&sharded, &trace.queries, &arrivals, &policy);

    let row = |name: &str, r: &OpenLoopReport| {
        println!(
            "{name:<14} {:>10} {:>10} {:>10} {:>10} {:>11.0} {:>10.2}",
            fmt_ns(r.percentile_ns(50.0)),
            fmt_ns(r.percentile_ns(95.0)),
            fmt_ns(r.percentile_ns(99.0)),
            fmt_ns(r.percentile_ns(99.9)),
            r.throughput_qps(),
            r.mean_queue_depth(),
        );
    };
    println!(
        "\n{:<14} {:>10} {:>10} {:>10} {:>10} {:>11} {:>10}",
        "backend", "p50", "p95", "p99", "p999", "thrpt q/s", "mean-depth"
    );
    row("single-pool", &single_r);
    row(&format!("sharded({shards})"), &sharded_r);

    let backlog: Vec<String> = sharded_r
        .shards
        .iter()
        .map(|s| format!("s{}: mean {:.1} max {}", s.shard, s.mean_backlog, s.max_backlog))
        .collect();
    println!("\nper-shard backlog: {}", backlog.join("  "));
    let util: Vec<String> = sharded_r
        .shards
        .iter()
        .map(|s| format!("{:.0}%", 100.0 * s.utilization(sharded_r.horizon_ns)))
        .collect();
    println!(
        "per-shard utilization: {}  (single-pool: {:.0}%)",
        util.join(" "),
        100.0 * single_r.shards[0].utilization(single_r.horizon_ns)
    );
    if args.flag("verbose") {
        println!(
            "offered {:.0} q/s over {}; {} batches single, {} sharded",
            single_r.offered_qps,
            fmt_ns(single_r.horizon_ns),
            single_r.batches(),
            sharded_r.batches()
        );
    }
    Ok(())
}

/// Unified metrics-plane demo (`recross status`): run an
/// obs-instrumented open-loop drive of the `--shards`-way simulated
/// backend and print the one schema-versioned `recross.metrics`
/// snapshot every backend emits — `--json` for the machine-readable
/// form, `--trace <path>` to also dump the flight recorder's sampled
/// spans as Chrome trace-event JSON (load in Perfetto / about:tracing).
/// No PJRT artifacts needed; bit-reproducible for a fixed
/// `(dataset, scheme, arrivals, rate, seed)`.
fn cmd_status(args: &recross::util::cli::Args) -> anyhow::Result<()> {
    use recross::deploy::Backend;
    use recross::energy::{HostModel, HostParams, HostPlatform};
    use recross::loadgen::{drive, ArrivalKind, Arrivals};
    use recross::obs::{names, Obs};
    use recross::util::fmt_ns;
    use std::sync::Arc;

    let scale: f64 = args.get_as("scale").map_err(anyhow::Error::msg)?;
    let n_requests = args.get_positive("requests").map_err(anyhow::Error::msg)?;
    let max_batch = args.get_positive("batch").map_err(anyhow::Error::msg)?;
    let shards = args.get_positive("shards").map_err(anyhow::Error::msg)?;
    let rate: f64 = args.get_as("rate").map_err(anyhow::Error::msg)?;
    anyhow::ensure!(rate > 0.0, "--rate must be positive");
    let slack: f64 = args.get_as("slack").map_err(anyhow::Error::msg)?;
    anyhow::ensure!(slack >= 0.0, "--slack must be non-negative");
    let scheme = parse_scheme(args)?;
    anyhow::ensure!(
        scheme != Scheme::Nmars,
        "the open-loop driver serves the MAC dataflow; scheme {:?} is not supported here",
        scheme.name()
    );
    // Status mode's closed-loop default makes no sense here: stamp the
    // trace with a Poisson process unless another open-loop shape was
    // asked for.
    let kind = match args.get("arrivals") {
        "closed" => ArrivalKind::Poisson,
        name => ArrivalKind::by_name(name).ok_or_else(|| {
            anyhow::anyhow!("unknown arrival process {name:?} (try poisson|bursty|diurnal)")
        })?,
    };
    let json = args.flag("json");

    let mut cfg = cli_config(args, Config::open_loop_default())?;
    // This subcommand *is* the observability demo: always observe
    // (--obs-sample / --obs-ring still tune the recorder via the
    // overlay).
    cfg.obs.enabled = true;
    let obs = Obs::from_config(&cfg.obs);
    let seed = cfg.workload.seed;
    let dataset = cfg.workload.dataset.clone();
    let embedding_dim = cfg.hardware.embedding_dim;
    if !json {
        println!(
            "status drive: dataset={dataset} scheme={} arrivals={} rate={rate}/s shards={shards} seed={seed}",
            scheme.name(),
            kind.name()
        );
    }
    let prepared = Deployment::of(cfg).scheme(scheme).scale(scale).build()?;
    // `--tiered` swaps the sharded pool for the capacity-constrained
    // tiered twin: one executor serving through hot/DRAM/cold placement,
    // so the store.* family below carries real traffic.
    let backend: Box<dyn Backend + '_> = if args.flag("tiered") {
        Box::new(prepared.sim_tiered()?.with_obs(Arc::clone(&obs)))
    } else {
        Box::new(
            prepared
                .sim_sharded(shards, slack)?
                .with_obs(Arc::clone(&obs)),
        )
    };
    let backend = backend.as_ref();
    // The host-baseline comparison gauge (DDR-fetch energy per lookup).
    obs.gauge_set(
        names::ENERGY_HOST_PJ_PER_LOOKUP,
        HostModel::new(&HostParams::default(), embedding_dim).lookup_pj(HostPlatform::CpuOnly),
    );

    let spec = DatasetSpec::by_name(&dataset)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset {dataset:?}"))?
        .scaled(scale);
    let gen = Generator::new(&spec, seed);
    let policy = BatchPolicy::from_config(prepared.config(), max_batch);

    if args.flag("watch") {
        return run_watch(args, &prepared, backend, &obs, &gen, kind, rate, json, &policy);
    }

    let trace = gen.trace(n_requests, seed.wrapping_add(3));
    let arrivals = Arrivals::from_kind(kind, rate, seed).take(trace.queries.len());
    let report = drive(backend, &trace.queries, &arrivals, &policy);
    let snap = backend.metrics()?;

    if json {
        // Nothing else on stdout: `recross status --json > snap.json`
        // must parse.
        print!("{}", snap.to_json());
    } else {
        println!(
            "\nmetrics snapshot (schema {} v{}, source {:?})",
            recross::obs::MetricsSnapshot::SCHEMA,
            recross::obs::MetricsSnapshot::VERSION,
            snap.source
        );
        println!(
            "drive: {} queries, {} batches, p99 sojourn {}",
            report.queries(),
            report.batches(),
            fmt_ns(report.percentile_ns(99.0))
        );
        println!("\ncounters:");
        for (name, v) in &snap.counters {
            println!("  {name:<28} {v}");
        }
        println!("gauges:");
        for (name, v) in &snap.gauges {
            println!("  {name:<28} {v:.3}");
        }
        println!("summaries (count / mean / min / max):");
        for (name, s) in &snap.summaries {
            println!(
                "  {name:<28} {} / {:.1} / {:.1} / {:.1}",
                s.count(),
                s.mean(),
                s.min(),
                s.max()
            );
        }
        println!("histograms (value: count):");
        for (name, buckets) in &snap.histograms {
            let cells: Vec<String> = buckets.iter().map(|(v, c)| format!("{v}: {c}")).collect();
            println!("  {name:<28} {}", cells.join("  "));
        }
        // The PR 7 incremental-offline family, zero-filled: the generic
        // loops above only show metrics the drive actually touched, and a
        // plain status drive never rebalances — render the section anyway
        // so the family is discoverable (units in DESIGN.md's catalogue).
        let ctr = |n: &str| snap.counters.get(n).copied().unwrap_or(0);
        let gauge = |n: &str| snap.gauges.get(n).copied().unwrap_or(0.0);
        let pct = |num: u64, den: f64| if den > 0.0 { 100.0 * num as f64 / den } else { 0.0 };
        println!("offline phase (zeros until a rebalance runs):");
        println!(
            "  {:<28} {} (offline.workers = {})",
            "effective workers",
            recross::util::par::default_workers(),
            prepared.config().offline.workers
        );
        println!(
            "  {:<28} {} / {}",
            "refreshes / full rebuilds",
            ctr(names::OFFLINE_REFRESHES),
            ctr(names::OFFLINE_FULL_REBUILDS)
        );
        println!(
            "  {:<28} {} / {:.0} ({:.1}%)",
            "groups touched / total",
            ctr(names::OFFLINE_GROUPS_TOUCHED),
            gauge(names::OFFLINE_GROUPS_TOTAL),
            pct(ctr(names::OFFLINE_GROUPS_TOUCHED), gauge(names::OFFLINE_GROUPS_TOTAL))
        );
        println!(
            "  {:<28} {} / {:.0} ({:.1}%)",
            "ids moved / total",
            ctr(names::OFFLINE_IDS_MOVED),
            gauge(names::OFFLINE_IDS_TOTAL),
            pct(ctr(names::OFFLINE_IDS_MOVED), gauge(names::OFFLINE_IDS_TOTAL))
        );
        println!(
            "  {:<28} {} / {:.0} ({:.1}%)",
            "tiles installed / total",
            ctr(names::OFFLINE_TILES_INSTALLED),
            gauge(names::OFFLINE_TILES_TOTAL),
            pct(ctr(names::OFFLINE_TILES_INSTALLED), gauge(names::OFFLINE_TILES_TOTAL))
        );
        // The PR 10 tiered-store family, same zero-filled treatment: live
        // numbers under --tiered, a discoverable all-zero section under
        // the default fully-hot sharded pool.
        let store_hits = ctr(names::STORE_HOT_HITS)
            + ctr(names::STORE_DRAM_HITS)
            + ctr(names::STORE_COLD_HITS);
        println!("tiered store (zeros unless --tiered):");
        println!(
            "  {:<28} {} / {} / {}",
            "hot / dram / cold hits",
            ctr(names::STORE_HOT_HITS),
            ctr(names::STORE_DRAM_HITS),
            ctr(names::STORE_COLD_HITS)
        );
        println!(
            "  {:<28} {:.1}%",
            "hot hit rate",
            pct(ctr(names::STORE_HOT_HITS), store_hits as f64)
        );
        println!(
            "  {:<28} {:.0} / {:.0} / {:.0}",
            "hot / dram / cold tiles",
            gauge(names::STORE_HOT_TILES),
            gauge(names::STORE_DRAM_TILES),
            gauge(names::STORE_COLD_TILES)
        );
        println!(
            "  {:<28} {} / {} / {}",
            "replans / promoted / evicted",
            ctr(names::STORE_REPLANS),
            ctr(names::STORE_PROMOTIONS),
            ctr(names::STORE_EVICTIONS)
        );
        if let Some(s) = snap.summaries.get(names::STORE_MISS_NS) {
            println!(
                "  {:<28} {} (mean {:.1} ns, max {:.1} ns)",
                "miss charges",
                s.count(),
                s.mean(),
                s.max()
            );
        }
        println!(
            "flight recorder: {} spans held ({} recorded, {} dropped)",
            obs.recorder().len(),
            obs.recorder().recorded(),
            obs.recorder().dropped()
        );
    }
    let trace_out = args.get("trace");
    if !trace_out.is_empty() {
        std::fs::write(trace_out, obs.recorder().trace_json())?;
        // Stderr keeps `--json` stdout pure.
        eprintln!(
            "wrote {trace_out}: {} spans (Chrome trace-event JSON)",
            obs.recorder().len()
        );
    }
    Ok(())
}

/// Streaming watch mode (`recross status --watch`): every tick drives a
/// fresh seeded burst through the backend, advances a *simulated* clock
/// by `watch.interval_ms`, diffs the backend's metrics snapshot into a
/// telemetry [`recross::obs::Window`], and evaluates the SLO burn-rate
/// rules — emitting `recross.watch` v1 JSON-lines (`--json`) or a
/// redrawn `top`-style table. The wall-clock sleep only paces the loop;
/// every byte on stdout is a function of `(config, seed, tick)`, so two
/// runs with identical flags produce identical streams. `--ticks N`
/// bounds the run; `--alerts <path>` writes the `recross.alerts` v1
/// event stream on exit.
#[allow(clippy::too_many_arguments)]
fn run_watch(
    args: &recross::util::cli::Args,
    prepared: &recross::deploy::Prepared,
    backend: &dyn recross::deploy::Backend,
    obs: &recross::obs::Obs,
    gen: &Generator,
    kind: recross::loadgen::ArrivalKind,
    rate: f64,
    json: bool,
    policy: &BatchPolicy,
) -> anyhow::Result<()> {
    use recross::loadgen::{drive, Arrivals};
    use recross::obs::slo::{ALERTS_SCHEMA, ALERTS_VERSION};
    use recross::obs::{names, Watcher};
    use recross::util::{Clock, SimClock};

    let n_requests = args.get_positive("requests").map_err(anyhow::Error::msg)?;
    let wcfg = prepared.config().watch.clone();
    let scfg = prepared.config().slo.clone();
    let seed = prepared.config().workload.seed;
    let mut watcher = Watcher::from_config(&wcfg, &scfg);
    // Simulated time owns the windowing: ticks land on exact interval
    // multiples regardless of host scheduling jitter.
    let clock = SimClock::new();
    let mut alert_log = String::new();
    let mut tick: usize = 0;
    loop {
        tick += 1;
        // Fresh traffic each tick, salted by the tick index: the stream
        // is deterministic yet every window sees new queries.
        let salt = seed.wrapping_add(1_000 + tick as u64);
        let trace = gen.trace(n_requests, salt);
        let arrivals = Arrivals::from_kind(kind, rate, salt).take(trace.queries.len());
        let report = drive(backend, &trace.queries, &arrivals, policy);
        obs.gauge_set(names::LOADGEN_SOJOURN_P50_NS, report.percentile_ns(50.0));
        obs.gauge_set(names::LOADGEN_SOJOURN_P99_NS, report.percentile_ns(99.0));
        obs.gauge_set(names::LOADGEN_THROUGHPUT_QPS, report.throughput_qps());
        obs.incr(names::LOADGEN_QUERIES, report.queries() as u64);

        clock.advance(wcfg.interval_ms.saturating_mul(1_000_000));
        let snap = backend.metrics()?;
        let (window, alerts) = watcher.tick(clock.now_ns(), &snap);
        for a in &alerts {
            alert_log.push_str(&a.to_json_line());
            alert_log.push('\n');
        }
        if json {
            println!("{}", Watcher::watch_line(&window, &alerts));
        } else {
            print_watch_table(&window, &alerts);
        }
        if wcfg.ticks > 0 && tick >= wcfg.ticks {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(wcfg.interval_ms));
    }

    let alerts_out = args.get("alerts");
    if !alerts_out.is_empty() {
        std::fs::write(alerts_out, &alert_log)?;
        // Stderr keeps `--json` stdout pure.
        eprintln!(
            "wrote {alerts_out}: {} alert events ({ALERTS_SCHEMA} v{ALERTS_VERSION})",
            watcher.tracker().emitted()
        );
    }
    Ok(())
}

/// One `recross top`-style frame for the human watch mode: clears and
/// redraws when stdout is a terminal, appends frames when piped.
fn print_watch_table(w: &recross::obs::Window, alerts: &[recross::obs::Alert]) {
    use recross::obs::names;
    use recross::util::fmt_ns;
    use std::io::IsTerminal;

    if std::io::stdout().is_terminal() {
        print!("\x1b[2J\x1b[H");
    }
    println!(
        "recross watch — window {} @ {:.1}s (dt {} ms)",
        w.index,
        w.t_ns as f64 / 1e9,
        w.dt_ns / 1_000_000
    );
    let gauge_ns = |name| w.gauge(name).map_or_else(|| "-".into(), fmt_ns);
    println!("  {:<26} {:>12}", "sojourn p50", gauge_ns(names::LOADGEN_SOJOURN_P50_NS));
    println!("  {:<26} {:>12}", "sojourn p99", gauge_ns(names::LOADGEN_SOJOURN_P99_NS));
    let num = |v: Option<f64>| v.map_or_else(|| "-".into(), |x| format!("{x:.1}"));
    println!(
        "  {:<26} {:>12}",
        "throughput q/s",
        num(w.gauge(names::LOADGEN_THROUGHPUT_QPS))
    );
    println!(
        "  {:<26} {:>12}",
        "driven q/s",
        num(w.counter_rate(names::LOADGEN_QUERIES))
    );
    println!(
        "  {:<26} {:>12}",
        "queue depth (mean)",
        num(w.summary_mean(names::BATCHER_QUEUE_DEPTH))
    );
    println!(
        "  {:<26} {:>12}",
        "batch size (p99)",
        num(w.percentile(names::BATCHER_BATCH_SIZE, 99.0))
    );
    for a in alerts {
        println!(
            "  [{}] {} {}: value {:.1} vs threshold {:.1} (burn {:.2} over {} windows)",
            a.severity.as_str(),
            a.objective,
            a.state.as_str(),
            a.value,
            a.threshold,
            a.burn,
            a.windows,
        );
    }
}

/// Sharded serving demo: partition the pool across `--shards` executor
/// threads, drive the held-out eval trace through the scatter-gather
/// front-end, verify the merged reductions against the single-pool
/// reference, and print the per-shard load / fan-out report.
///
/// With `--replica-routing` the pool spreads hot-group replicas across
/// shards and routes each activation by power-of-two-choices; the report
/// then compares max-shard load and simulated completion against the
/// ownership-pinned placement on the same trace. With `--rebalance` the
/// drift monitor is armed and a stale placement triggers epoch-versioned
/// remaps between serving waves.
fn cmd_cluster(args: &recross::util::cli::Args) -> anyhow::Result<()> {
    use recross::allocation::group_frequencies;
    use recross::cluster::{
        report as cluster_report, simulate_with_replicas, ClusterConfig, PartitionPolicy,
        ReplicaPlan, RoutePolicy,
    };
    use recross::deploy::Backend;
    use recross::graph::DeltaParams;
    use recross::metrics::Histogram;
    use recross::obs::{names, Watcher};
    use recross::util::{Clock, SimClock};
    use recross::workload::Query;

    let scale: f64 = args.get_as("scale").map_err(anyhow::Error::msg)?;
    let n_requests = args.get_positive("requests").map_err(anyhow::Error::msg)?;
    let max_batch = args.get_positive("batch").map_err(anyhow::Error::msg)?;
    let shards = args.get_positive("shards").map_err(anyhow::Error::msg)?;
    let vnodes = args.get_positive("vnodes").map_err(anyhow::Error::msg)?;
    let scheme = parse_scheme(args)?;
    let policy = match args.get("partition") {
        "locality" => PartitionPolicy::Locality,
        "hash" => PartitionPolicy::Hash,
        other => anyhow::bail!("unknown partition policy {other:?} (try locality|hash)"),
    };
    let mode = ShardingMode::from_flags(args.flag("replica-routing"), args.flag("rebalance"));
    // Fast-fail before the offline phase runs (assemble_cluster
    // re-checks for programmatic callers).
    anyhow::ensure!(
        scheme != Scheme::Nmars,
        "the sharded pool serves the MAC dataflow; scheme {:?} is not supported here",
        scheme.name()
    );

    let mut cfg = cli_config(args, Config::serving_default())?;
    // The drift loop below feeds measured telemetry (the degradation
    // series) into the delta-rebalance thresholds, so the pool must
    // observe itself: force the metrics plane on for this subcommand.
    cfg.obs.enabled = true;
    let wcfg = cfg.watch.clone();
    let scfg = cfg.slo.clone();
    let slack: f64 = args.get_as("slack").map_err(anyhow::Error::msg)?;
    anyhow::ensure!(slack >= 0.0, "--slack must be non-negative");
    let ccfg = ClusterConfig {
        shards,
        vnodes: vnodes as u32,
        policy,
        batch: BatchPolicy::from_config(&cfg, max_batch),
        slack,
        mode,
    };
    println!(
        "starting sharded pool: dataset={} scheme={} shards={shards} partition={} routing={}",
        cfg.workload.dataset,
        scheme.name(),
        args.get("partition"),
        if mode.replica_routing() { "p2c-replicas" } else { "pinned" },
    );
    let prepared = Deployment::of(cfg).scheme(scheme).scale(scale).build()?;
    let pool = Sharded::spawn(&prepared, &ccfg)?;
    let handle = pool.handle();
    println!(
        "pool up: {} groups over {} shards (groups/shard: {:?})",
        pool.cluster().plan().num_groups(),
        pool.cluster().num_shards(),
        pool.cluster().plan().group_counts()
    );

    // Apples-to-apples placement comparison on the deterministic
    // simulator: ownership-pinned vs cross-shard replica routing over the
    // same (Zipf-skewed) eval trace.
    if mode.replica_routing() {
        let shared = pool.cluster().shared();
        let table = pool.cluster().routes();
        let freqs = group_frequencies(&shared.mapping, prepared.history());
        println!("{}", cluster_report::placement_summary(&table.replicas, &freqs));
        let pinned_plan = ReplicaPlan::pinned(&table.plan, &shared.replication);
        let batch_size = prepared.config().scheme.batch_size;
        let pinned = simulate_with_replicas(
            shared,
            &table.plan,
            &pinned_plan,
            prepared.eval(),
            batch_size,
            RoutePolicy::Pinned,
        );
        let routed = simulate_with_replicas(
            shared,
            &table.plan,
            &table.replicas,
            prepared.eval(),
            batch_size,
            RoutePolicy::PowerOfTwo,
        );
        let delta = 100.0 * (1.0 - routed.max_shard_load() as f64 / pinned.max_shard_load().max(1) as f64);
        println!(
            "pinned : max-shard load {:>8}, completion {}",
            pinned.max_shard_load(),
            recross::util::fmt_ns(pinned.stats.completion_ns)
        );
        println!(
            "routed : max-shard load {:>8} ({delta:+.1}% vs pinned), completion {}",
            routed.max_shard_load(),
            recross::util::fmt_ns(routed.stats.completion_ns)
        );
    }

    // Drive the held-out eval queries through the front-end in scatter
    // waves: reduce_many dispatches every sub-query of a wave before any
    // gather blocks, which is what lets the per-shard batchers fill
    // instead of idling out their max_wait window. Serving in waves (not
    // one giant batch) gives the drift monitor batch boundaries at which
    // a rebalance can swap epochs.
    let mut queries: Vec<Query> =
        prepared.eval().queries.iter().take(n_requests).cloned().collect();
    anyhow::ensure!(!queries.is_empty(), "eval trace is empty");
    if mode.rebalance() {
        // The eval trace matches the distribution the placement was
        // optimised for, so it can never look stale. Follow it with a
        // *drifted* phase — same catalogue, re-seeded co-purchase
        // structure (new communities, shifted popularity) — which is the
        // traffic shape the monitor exists to catch.
        let wl = &prepared.config().workload;
        let spec = DatasetSpec::by_name(&wl.dataset)
            .ok_or_else(|| anyhow::anyhow!("unknown dataset {:?}", wl.dataset))?
            .scaled(scale);
        let drifted_gen = Generator::new(&spec, wl.seed.wrapping_add(9_999));
        let drifted = drifted_gen.trace(n_requests, wl.seed.wrapping_add(10_000));
        println!(
            "drift phase: appending {} re-seeded queries (new co-purchase structure)",
            drifted.queries.len()
        );
        queries.extend(drifted.queries);
    }
    let wave = (max_batch * pool.cluster().num_shards()).max(64);
    // Telemetry watcher over the pool's own snapshots: one simulated
    // tick per serving wave diffs the metrics into windows, evaluates
    // the SLO burn-rate rules, and accumulates the drift-degradation
    // series that sizes the delta-rebalance thresholds below.
    let mut watcher = Watcher::from_config(&wcfg, &scfg);
    let wclock = SimClock::new();
    let mut responses = Vec::with_capacity(queries.len());
    // Traffic window since the last epoch swap — the sample the remap's
    // frequencies/partition are recomputed from. A single wave (64-ish
    // queries) is far too sparse for thousands of groups, so accumulate
    // across waves and reset only after a swap.
    let mut recent: Vec<Query> = Vec::new();
    let mut swaps = 0u64;
    let t0 = std::time::Instant::now();
    for chunk in queries.chunks(wave) {
        responses.extend(handle.reduce_many(chunk)?);
        wclock.advance(wcfg.interval_ms.saturating_mul(1_000_000));
        let (_, wave_alerts) = watcher.tick(wclock.now_ns(), &pool.metrics()?);
        for a in &wave_alerts {
            println!(
                "  slo [{}] {} {}: {:.1} vs {:.1} (burn {:.2}/{} windows)",
                a.severity.as_str(),
                a.objective,
                a.state.as_str(),
                a.value,
                a.threshold,
                a.burn,
                a.windows,
            );
        }
        if mode.rebalance() {
            recent.extend_from_slice(chunk);
            if handle.rebalance_due() {
                let degradation = handle.drift_degradation().unwrap_or(1.0);
                // Prefer the drift monitor's own recent-query ring (the
                // traffic that tripped the signal); the accumulated wave
                // window is the fallback when the ring is unarmed.
                let window = handle.drift_window().unwrap_or_else(|| Trace {
                    num_embeddings: prepared.eval().num_embeddings,
                    queries: std::mem::take(&mut recent),
                });
                recent.clear();
                // Thresholds from telemetry, not constants: the watched
                // degradation series decides how far a group must drift
                // before its tiles are re-derived (PR 7 follow-up).
                let params = DeltaParams::from_observed(
                    &watcher.series().gauge_values(names::DRIFT_DEGRADATION),
                );
                let report = pool.cluster().rebalance_incremental(&window, &params)?;
                swaps += 1;
                println!(
                    "drift detected (degradation {degradation:.2}, {} recent queries, \
                     rel threshold {:.2}) -> {} to epoch {} \
                     ({}/{} groups re-planned, {} shard installs, {}/{} tiles shipped)",
                    window.queries.len(),
                    params.rel_threshold,
                    if report.full { "full rebalance" } else { "delta rebalance" },
                    report.epoch,
                    report.groups_changed,
                    report.groups_total,
                    report.shards_installed,
                    report.tiles_installed,
                    report.tiles_total,
                );
            }
        }
    }
    let wall = t0.elapsed();

    // Exactness check against the single-pool reference reduction.
    let mut max_err = 0.0f32;
    for (q, r) in queries.iter().zip(&responses) {
        let expect = prepared.store().reduce_reference(&q.items);
        for (a, b) in r.reduced.iter().zip(&expect) {
            max_err = max_err.max((a - b).abs());
        }
    }

    let mut fanout = Histogram::new();
    for r in &responses {
        if r.fanout > 0 {
            fanout.add(r.fanout as u64);
        }
    }
    let statuses = handle.shard_status()?;
    let merged = handle.merged_sim_with_fanout(&statuses, &fanout);
    println!(
        "\n{}",
        cluster_report::render(&statuses, &fanout, &merged, wall, responses.len())
    );
    if mode.rebalance() {
        println!("epoch swaps: {swaps} (final epoch {})", pool.cluster().epoch());
    }
    println!("single-pool reference check: max |err| = {max_err:.2e}");
    anyhow::ensure!(
        max_err < 1e-4,
        "sharded reduction diverged from the single-pool reference"
    );
    if args.flag("verbose") {
        for r in responses.iter().take(5) {
            println!(
                "  query {} -> fanout {}, {} activations",
                r.id, r.fanout, r.activations
            );
        }
    }
    Ok(())
}
