//! Duplication-ratio auto-tuner — the "research opportunity" the paper
//! flags in §IV-B: the right area budget depends on the workload's access
//! density, so pick it from the measured time/area curve instead of a
//! global constant.
//!
//! Strategy: sweep candidate ratios, simulate the engine on a held-out
//! slice of the history, and choose the **knee** — the smallest ratio
//! whose marginal speedup over the previous candidate falls below
//! `min_gain` (Fig. 10's convergence point). This mirrors how a deployer
//! would size ReRAM area against tail latency.

use crate::config::Config;
use crate::engine::{Engine, Scheme};
use crate::graph::CoGraph;
use crate::workload::Trace;

/// One sweep point.
#[derive(Debug, Clone, PartialEq)]
pub struct TunePoint {
    pub dup_ratio: f64,
    pub completion_ns: f64,
    pub physical_crossbars: usize,
    /// Speedup over the dup-0 baseline.
    pub speedup: f64,
}

/// Auto-tune result.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneResult {
    /// Chosen ratio (the knee).
    pub chosen: f64,
    /// The full sweep, ascending ratio.
    pub sweep: Vec<TunePoint>,
}

/// Sweep `ratios` (must be ascending) and pick the knee.
///
/// `min_gain` is the marginal-speedup threshold: once an extra budget step
/// improves completion time by less than this factor, the previous step
/// is chosen. Typical value 1.05 (5%).
///
/// An empty candidate list is a caller configuration error, reported as a
/// typed error rather than a panic — an auto-derived sweep (e.g. filtered
/// against an area budget) can legitimately come up empty and deserves a
/// recoverable diagnosis, not a crashed tuner.
pub fn tune_dup_ratio(
    graph: &CoGraph,
    history: &Trace,
    validation: &Trace,
    cfg: &Config,
    ratios: &[f64],
    min_gain: f64,
) -> crate::Result<TuneResult> {
    anyhow::ensure!(
        !ratios.is_empty(),
        "dup-ratio sweep has no candidates; pass at least one ratio (e.g. 0.0)"
    );
    assert!(
        ratios.windows(2).all(|w| w[0] < w[1]),
        "ratios must be strictly ascending"
    );
    assert!(min_gain >= 1.0, "min_gain is a ratio >= 1.0");

    let mut sweep = Vec::with_capacity(ratios.len());
    let mut base_ns = None;
    for &r in ratios {
        let mut c = cfg.clone();
        c.scheme.dup_ratio = r;
        let engine = Engine::prepare(Scheme::ReCross, graph, history, &c);
        let stats = engine.run_trace(validation, c.scheme.batch_size);
        let base = *base_ns.get_or_insert(stats.completion_ns);
        sweep.push(TunePoint {
            dup_ratio: r,
            completion_ns: stats.completion_ns,
            physical_crossbars: engine.physical_crossbars(),
            speedup: base / stats.completion_ns,
        });
    }

    // Knee: first point whose successor improves by < min_gain.
    let mut chosen = sweep.last().expect("sweep is non-empty").dup_ratio;
    for w in sweep.windows(2) {
        let marginal = w[0].completion_ns / w[1].completion_ns;
        if marginal < min_gain {
            chosen = w[0].dup_ratio;
            break;
        }
    }
    Ok(TuneResult { chosen, sweep })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{generate, DatasetSpec};

    fn setup() -> (CoGraph, Trace, Trace, Config) {
        let spec = DatasetSpec::by_name("automotive").unwrap().scaled(0.03);
        let (history, eval) = generate(&spec, 1_500, 400, 42);
        let graph = CoGraph::build(&history);
        (graph, history, eval, Config::paper_default())
    }

    #[test]
    fn picks_a_swept_ratio_at_the_knee() {
        let (graph, history, eval, cfg) = setup();
        let ratios = [0.0, 0.05, 0.10, 0.20];
        let r = tune_dup_ratio(&graph, &history, &eval, &cfg, &ratios, 1.05).unwrap();
        assert!(ratios.contains(&r.chosen));
        assert_eq!(r.sweep.len(), 4);
        // Completion must be non-increasing in budget.
        for w in r.sweep.windows(2) {
            assert!(w[1].completion_ns <= w[0].completion_ns * 1.001);
        }
        // The chosen point's successor (if any) gains < 5%.
        let idx = r.sweep.iter().position(|p| p.dup_ratio == r.chosen).unwrap();
        if idx + 1 < r.sweep.len() {
            let marginal = r.sweep[idx].completion_ns / r.sweep[idx + 1].completion_ns;
            assert!(marginal < 1.05, "knee misplaced: marginal {marginal}");
        }
    }

    #[test]
    fn duplication_actually_helps_before_knee() {
        let (graph, history, eval, cfg) = setup();
        let r = tune_dup_ratio(&graph, &history, &eval, &cfg, &[0.0, 0.10], 1.0).unwrap();
        assert!(
            r.sweep[1].speedup > 1.0,
            "dup-10% should beat dup-0%: {:?}",
            r.sweep
        );
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn rejects_unsorted_ratios() {
        let (graph, history, eval, cfg) = setup();
        let _ = tune_dup_ratio(&graph, &history, &eval, &cfg, &[0.1, 0.05], 1.05);
    }

    #[test]
    fn empty_sweep_is_an_error_not_a_panic() {
        // Regression: this used to reach `sweep.last().unwrap()` (a
        // panic) instead of reporting a usable configuration error.
        let (graph, history, eval, cfg) = setup();
        let err = tune_dup_ratio(&graph, &history, &eval, &cfg, &[], 1.05)
            .expect_err("empty sweep must be rejected");
        assert!(
            err.to_string().contains("no candidates"),
            "unhelpful error: {err}"
        );
    }
}
