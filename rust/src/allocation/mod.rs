//! Access-aware crossbar allocation — paper §III-C.
//!
//! Even after correlation-aware grouping, crossbar access counts stay
//! power-law (Fig. 4): a few crossbars serve most of the batch and become
//! serial bottlenecks. ReCross replicates hot crossbars, choosing copy
//! counts by **log scaling** (Eq. 1):
//!
//! ```text
//! num_copies = floor( log(freq) / log(freq_total) * log(batch_size) )
//! ```
//!
//! which compresses the power-law head (no crossbar needs ~batch_size
//! copies — observed peak concurrent demand is far lower, Fig. 4b) while
//! still granting the warm middle of the distribution a copy or two
//! (Fig. 5's "after" pie chart).
//!
//! A `dup_ratio` area budget (Fig. 10 sweeps 0/5/10/20%) caps the total
//! number of extra crossbars; budget is spent on the hottest groups first.

pub mod autotune;

pub use autotune::{tune_dup_ratio, TunePoint, TuneResult};

use crate::graph::PAR_MIN_QUERIES;
use crate::grouping::Mapping;
use crate::util::par;
use crate::workload::Trace;

/// Replication plan layered on top of a [`Mapping`].
#[derive(Debug, Clone)]
pub struct Replication {
    /// Copies per group (>= 1; 1 means not duplicated).
    pub copies: Vec<u32>,
    /// Total physical crossbars (sum of copies).
    pub total_crossbars: usize,
    /// The batch size the plan was computed for.
    pub batch_size: usize,
}

impl Replication {
    /// A trivial plan: one copy per group (duplication disabled).
    pub fn identity(num_groups: usize, batch_size: usize) -> Self {
        Self {
            copies: vec![1; num_groups],
            total_crossbars: num_groups,
            batch_size,
        }
    }

    /// Wrap an explicit per-group copy vector (each entry >= 1). Used by
    /// the cluster layer to derive a shard's *local* replica counts from
    /// the cross-shard placement table.
    pub fn from_copies(copies: Vec<u32>, batch_size: usize) -> Self {
        assert!(copies.iter().all(|&c| c >= 1), "every group needs a copy");
        let total = copies.iter().map(|&c| c as usize).sum();
        Self {
            copies,
            total_crossbars: total,
            batch_size,
        }
    }

    /// Copies of group `g`.
    #[inline]
    pub fn copies_of(&self, g: u32) -> u32 {
        self.copies[g as usize]
    }

    /// Area overhead versus the unreplicated baseline (0.0 = none).
    pub fn area_overhead(&self) -> f64 {
        let base = self.copies.len();
        if base == 0 {
            return 0.0;
        }
        (self.total_crossbars as f64 - base as f64) / base as f64
    }

    /// Number of duplicated groups (copies > 1).
    pub fn duplicated_groups(&self) -> usize {
        self.copies.iter().filter(|&&c| c > 1).count()
    }
}

/// Per-group access frequency over a trace: how many *activations* each
/// group would receive (one per query that touches it).
///
/// Sort-free: the epoch-stamped [`crate::grouping::TouchSet`] collects
/// each query's distinct groups in O(k) instead of the old
/// sort+dedup's O(k log k) — this runs over the *whole history trace*
/// on every (re)planning pass, so it is offline-phase hot. The counts
/// are identical (integer increments, order-independent), which also
/// makes the walk safe to fan out over [`crate::util::par`]: each
/// worker counts a private frequency vector over its query range and
/// the partials merge by addition in worker order.
pub fn group_frequencies(mapping: &Mapping, trace: &Trace) -> Vec<u64> {
    let n = mapping.num_groups();
    let partials = par::map_ranges(
        trace.queries.len(),
        par::default_workers(),
        PAR_MIN_QUERIES,
        |_, range| {
            let mut freq = vec![0u64; n];
            let mut touch = crate::grouping::TouchSet::default();
            for q in &trace.queries[range] {
                touch.begin(n);
                for &e in &q.items {
                    touch.add(mapping.slot_of(e).group);
                }
                for &g in touch.touched() {
                    freq[g as usize] += 1;
                }
            }
            freq
        },
    );
    let mut freq = vec![0u64; n];
    for pfreq in partials {
        for (f, pf) in freq.iter_mut().zip(&pfreq) {
            *f += pf;
        }
    }
    freq
}

/// Eq. 1: desired copies for one group given its access frequency.
///
/// `freq_total` is the summed frequency over all groups, `batch_size` the
/// inference batch. Returns the *desired* number of copies, at least 1.
pub fn log_scaled_copies(freq: u64, freq_total: u64, batch_size: usize) -> u32 {
    if freq == 0 || freq_total <= 1 || batch_size <= 1 {
        return 1;
    }
    let ratio = (freq as f64).ln() / (freq_total as f64).ln();
    let desired = (ratio * (batch_size as f64).ln()).floor() as i64;
    desired.clamp(1, batch_size as i64) as u32
}

/// Naive (linear) copy rule the paper argues against (left pie of Fig. 5):
/// copies proportional to the frequency share, `ceil(freq/freq_max *
/// max_copies)`. Kept as an ablation baseline.
pub fn linear_copies(freq: u64, freq_max: u64, max_copies: u32) -> u32 {
    if freq == 0 || freq_max == 0 {
        return 1;
    }
    ((freq as f64 / freq_max as f64) * max_copies as f64).ceil().max(1.0) as u32
}

/// Compute the ReCross replication plan.
///
/// * `freqs` — per-group activation frequency from [`group_frequencies`].
/// * `batch_size` — Eq. 1's `batch_size`.
/// * `dup_ratio` — area budget: extra crossbars <= `dup_ratio * groups`.
///
/// Budget is granted in descending frequency order, one copy at a time
/// round-robin over the eligible groups, so a tight budget replicates the
/// hottest groups first rather than fully replicating one group.
pub fn plan_replication(freqs: &[u64], batch_size: usize, dup_ratio: f64) -> Replication {
    // The full plan is the delta plan with every group dirty over an
    // identity baseline — one code path, so the incremental re-solve and
    // this oracle cannot drift apart.
    let identity = Replication::identity(freqs.len(), batch_size);
    let all_dirty = vec![true; freqs.len()];
    plan_replication_delta(&identity, freqs, &all_dirty, batch_size, dup_ratio)
}

/// Re-solve Eq. 1 **only for the dirty groups**, holding every clean
/// group's copy count from `prev` fixed.
///
/// The held copies are charged against the `dup_ratio` budget first;
/// dirty groups share whatever remains, granted hottest-first
/// round-robin exactly like [`plan_replication`] (with everything dirty
/// the two are bit-identical — `plan_replication` literally calls this).
/// When the catalogue shrank (trailing groups trimmed by the delta
/// regroup), `prev` entries past `freqs.len()` drop off.
///
/// Holding clean copies means the plan can transiently exceed a *newly
/// lowered* budget (held extras are never confiscated); the bound is
/// restored by the next full re-plan.
pub fn plan_replication_delta(
    prev: &Replication,
    freqs: &[u64],
    dirty: &[bool],
    batch_size: usize,
    dup_ratio: f64,
) -> Replication {
    let num_groups = freqs.len();
    assert_eq!(dirty.len(), num_groups, "dirty flags do not match freqs");
    let freq_total: u64 = freqs.iter().sum();
    let budget = ((num_groups as f64) * dup_ratio).floor() as usize;

    // Clean groups keep their copies; dirty groups restart from 1.
    let copies: Vec<u32> = (0..num_groups)
        .map(|g| {
            if dirty[g] {
                1
            } else {
                prev.copies.get(g).copied().unwrap_or(1)
            }
        })
        .collect();
    let mut copies = copies;
    let held: usize = (0..num_groups)
        .filter(|&g| !dirty[g])
        .map(|g| (copies[g] - 1) as usize)
        .sum();
    let mut remaining = budget.saturating_sub(held);
    if remaining == 0 || freq_total == 0 {
        return Replication::from_copies(copies, batch_size);
    }

    // Desired copies per Eq. 1. The scoring is elementwise over `freqs`,
    // so it fans out over chunks concatenated in worker order — the
    // result is the same vector the serial map produced. (The grant loop
    // below stays serial: it is a stateful round-robin over the budget.)
    let desired: Vec<u32> = par::map_ranges(
        num_groups,
        par::default_workers(),
        PAR_MIN_QUERIES,
        |_, range| {
            freqs[range]
                .iter()
                .map(|&f| log_scaled_copies(f, freq_total, batch_size))
                .collect::<Vec<u32>>()
        },
    )
    .into_iter()
    .flatten()
    .collect();

    // Hottest dirty groups first (stable: ties stay in ascending id
    // order, matching the full plan).
    let mut order: Vec<usize> = (0..num_groups).filter(|&g| dirty[g]).collect();
    order.sort_by_key(|&g| std::cmp::Reverse(freqs[g]));

    // Round-robin grant: every pass gives one extra copy to each group that
    // still wants one, until the budget runs out. This matches the paper's
    // "balanced distribution of duplicated embeddings across crossbars".
    'outer: loop {
        let mut granted_any = false;
        for &g in &order {
            if copies[g] < desired[g] {
                copies[g] += 1;
                granted_any = true;
                remaining -= 1;
                if remaining == 0 {
                    break 'outer;
                }
            }
        }
        if !granted_any {
            break;
        }
    }

    Replication::from_copies(copies, batch_size)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grouping::Mapping;
    use crate::workload::{Query, Trace};

    #[test]
    fn eq1_matches_paper_formula() {
        // Hand-checked: freq=1000, total=100000, batch=256
        // ln(1000)/ln(100000) * ln(256) = 6.9078/11.5129 * 5.5452 = 3.327 -> 3
        assert_eq!(log_scaled_copies(1000, 100_000, 256), 3);
        // freq == total -> ratio 1 -> floor(ln 256) = 5
        assert_eq!(log_scaled_copies(100_000, 100_000, 256), 5);
        // tiny freq -> 1 (never 0: the group must exist)
        assert_eq!(log_scaled_copies(1, 100_000, 256), 1);
        assert_eq!(log_scaled_copies(0, 100_000, 256), 1);
    }

    #[test]
    fn eq1_compresses_head() {
        // A 100x hotter group gets far fewer than 100x the copies.
        let c_hot = log_scaled_copies(100_000, 1_000_000, 256);
        let c_warm = log_scaled_copies(1_000, 1_000_000, 256);
        assert!(c_hot <= c_warm * 3, "hot {c_hot} vs warm {c_warm}");
        assert!(c_hot > c_warm);
    }

    #[test]
    fn linear_rule_is_head_heavy() {
        // The ablation baseline gives the head nearly everything.
        assert_eq!(linear_copies(1000, 1000, 32), 32);
        assert_eq!(linear_copies(10, 1000, 32), 1);
    }

    #[test]
    fn budget_zero_means_identity() {
        let r = plan_replication(&[100, 50, 1], 256, 0.0);
        assert_eq!(r.copies, vec![1, 1, 1]);
        assert_eq!(r.area_overhead(), 0.0);
        assert_eq!(r.duplicated_groups(), 0);
    }

    #[test]
    fn budget_respected() {
        let freqs: Vec<u64> = (0..100).map(|i| 1000 / (i + 1)).collect();
        for &ratio in &[0.05, 0.10, 0.20] {
            let r = plan_replication(&freqs, 256, ratio);
            let extra = r.total_crossbars - freqs.len();
            assert!(
                extra <= (freqs.len() as f64 * ratio) as usize,
                "ratio {ratio}: extra {extra}"
            );
            assert!(r.area_overhead() <= ratio + 1e-9);
        }
    }

    #[test]
    fn hottest_groups_replicated_first() {
        let freqs = vec![1000, 900, 10, 5, 1, 1, 1, 1, 1, 1];
        let r = plan_replication(&freqs, 256, 0.2); // budget = 2
        assert!(r.copies[0] > 1);
        assert!(r.copies[1] > 1);
        assert!(r.copies[4..].iter().all(|&c| c == 1));
    }

    #[test]
    fn round_robin_spreads_budget() {
        // With budget 3 and two equally-desiring hot groups, the grant must
        // split 2/1, not 3/0.
        let freqs = vec![1_000_000, 1_000_000, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1, 1];
        let r = plan_replication(&freqs, 256, 0.2); // budget = 3
        assert!(r.copies[0] >= 2 && r.copies[1] >= 2);
        assert_eq!((r.copies[0] + r.copies[1]) as usize, 2 + 3);
    }

    #[test]
    fn group_frequencies_count_touches() {
        let m = Mapping::from_groups(vec![vec![0, 1], vec![2, 3]], 2, 4);
        let t = Trace {
            num_embeddings: 4,
            queries: vec![
                Query::new(vec![0, 1]),    // touches group 0 once
                Query::new(vec![0, 2]),    // touches both
                Query::new(vec![3]),       // touches group 1
            ],
        };
        assert_eq!(group_frequencies(&m, &t), vec![2, 2]);
    }

    #[test]
    fn identity_plan() {
        let r = Replication::identity(5, 64);
        assert_eq!(r.total_crossbars, 5);
        assert_eq!(r.copies_of(3), 1);
    }

    #[test]
    fn delta_all_dirty_matches_full_plan() {
        for seed in [1u64, 7, 42] {
            let mut s = seed;
            let freqs: Vec<u64> = (0..64)
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(1);
                    (s >> 33) % 10_000
                })
                .collect();
            let full = plan_replication(&freqs, 256, 0.15);
            let prev = Replication::identity(freqs.len(), 256);
            let delta =
                plan_replication_delta(&prev, &freqs, &vec![true; freqs.len()], 256, 0.15);
            assert_eq!(full.copies, delta.copies, "seed {seed}");
            assert_eq!(full.total_crossbars, delta.total_crossbars);
        }
    }

    #[test]
    fn delta_holds_clean_copies_fixed() {
        let freqs = vec![1000u64, 900, 800, 10, 5, 1, 1, 1, 1, 1];
        let prev = plan_replication(&freqs, 256, 0.3); // budget = 3
        assert!(prev.duplicated_groups() > 0);
        // Only group 3 dirty, with a new hot frequency.
        let mut new_freqs = freqs.clone();
        new_freqs[3] = 2000;
        let mut dirty = vec![false; freqs.len()];
        dirty[3] = true;
        let r = plan_replication_delta(&prev, &new_freqs, &dirty, 256, 0.3);
        for g in 0..freqs.len() {
            if g != 3 {
                assert_eq!(r.copies[g], prev.copies[g], "clean group {g} re-planned");
            }
        }
    }

    #[test]
    fn delta_budget_charges_held_copies() {
        let freqs = vec![1000u64, 900, 800, 700, 1, 1, 1, 1, 1, 1];
        let prev = plan_replication(&freqs, 256, 0.3); // budget = 3, all spent
        let held: usize = prev.copies.iter().map(|&c| (c - 1) as usize).sum();
        assert_eq!(held, 3);
        // Dirty a cold group: no budget remains, so it stays at 1 copy
        // and the total never exceeds groups + budget.
        let mut dirty = vec![false; freqs.len()];
        dirty[4] = true;
        let mut new_freqs = freqs.clone();
        new_freqs[4] = 5000;
        let r = plan_replication_delta(&prev, &new_freqs, &dirty, 256, 0.3);
        assert_eq!(r.copies[4], 1);
        assert!(r.total_crossbars <= freqs.len() + 3);
    }

    #[test]
    fn delta_survives_trimmed_catalogue() {
        // The new mapping has fewer groups than prev: trailing prev
        // entries just drop off, no panic.
        let prev = plan_replication(&[1000u64, 900, 10, 5], 256, 0.5);
        let r = plan_replication_delta(&prev, &[1000, 900, 10], &[false, true, false], 256, 0.5);
        assert_eq!(r.copies.len(), 3);
        assert_eq!(r.copies[0], prev.copies[0]);
    }
}
