//! Observability plane: live metrics + a per-query flight recorder.
//!
//! Two halves behind one handle ([`Obs`]):
//!
//! * **Metrics plane** ([`registry`]) — a [`MetricsRegistry`] of typed
//!   counters / gauges / summaries / histograms harvested at the seams
//!   of the serving stack (batcher closes, batch execution, cluster
//!   scatter/gather, rebalances), snapshot-exportable as one
//!   schema-versioned JSON (`recross.metrics` v1) from
//!   `Backend::metrics()` and `recross status --json`. The loadgen
//!   driver records through the *same* registry, so sim and live runs
//!   emit the same schema and are directly diffable.
//! * **Flight recorder** ([`recorder`]) — a fixed-capacity, sampled
//!   ring of per-query [`SpanEvent`]s (enqueue → batch-form → schedule
//!   → execute → merge) on injected-[`crate::util::Clock`] timestamps,
//!   dumpable as Chrome trace-event JSON for Perfetto.
//!
//! On top of the snapshot sits the **signal plane**: [`timeseries`]
//! diffs successive snapshots on injected clock ticks into fixed-
//! capacity per-metric rings (counter rates, gauge series, windowed
//! summary means, exact windowed histogram percentiles), and [`slo`]
//! evaluates declarative objectives over those windows with multi-window
//! burn-rate rules, emitting a deterministic `recross.alerts` v1 stream
//! (`recross status --watch`).
//!
//! **Off by default.** Construction is driven by
//! [`crate::config::ObsConfig`]; a disabled [`Obs`] reduces every
//! record call to one branch ([`Obs::enabled`] is a plain bool read —
//! no lock, no allocation), which `benches/obs_overhead.rs` pins.
//!
//! **Observation never perturbs the system.** All instrumented call
//! sites record *after* decisions are made, from values the serving
//! path already computed; schedules and reductions stay bit-identical
//! with recording enabled (see `tests/obs_integration.rs`).

pub mod recorder;
pub mod registry;
pub mod slo;
pub mod timeseries;

pub use recorder::{FlightRecorder, SpanEvent, Stage};
pub use registry::{MetricsRegistry, MetricsSnapshot};
pub use slo::{Alert, Objective, SloTracker, Watcher};
pub use timeseries::{TimeSeries, Window};

use crate::config::ObsConfig;
use crate::metrics::Summary;
use crate::sched::ExecStats;
use std::sync::Arc;

/// The metric catalogue: every name the serving stack records, in one
/// place (units in DESIGN.md §Observability).
pub mod names {
    /// Queue depth at batch close (queries) — summary.
    pub const BATCHER_QUEUE_DEPTH: &str = "batcher.queue_depth";
    /// Closed batch size (queries) — histogram.
    pub const BATCHER_BATCH_SIZE: &str = "batcher.batch_size";
    /// Per-query batch-formation wait (ns) — summary.
    pub const BATCHER_WAIT_NS: &str = "batcher.wait_ns";
    /// Batches closed by the max-wait deadline — counter.
    pub const BATCHER_CLOSE_DEADLINE: &str = "batcher.close_deadline";
    /// Batches closed by reaching max_batch — counter.
    pub const BATCHER_CLOSE_SIZE: &str = "batcher.close_size";

    /// Batches scheduled — counter.
    pub const SCHED_BATCHES: &str = "sched.batches";
    /// Slot-selection float comparisons (replica + bus) — counter.
    pub const SCHED_COMPARISONS: &str = "sched.comparisons";
    /// Slot tables served by the flat scan layout — counter.
    pub const SCHED_PATH_FLAT: &str = "sched.path_flat";
    /// Slot tables served by the tournament tree — counter.
    pub const SCHED_PATH_TREE: &str = "sched.path_tree";
    /// Queries scheduled — counter.
    pub const SCHED_QUERIES: &str = "sched.queries";
    /// Embedding lookups served — counter.
    pub const SCHED_LOOKUPS: &str = "sched.lookups";

    /// Crossbar activations dispatched — counter.
    pub const XBAR_ACTIVATIONS: &str = "xbar.activations";
    /// Activations that touched exactly one row — counter.
    pub const XBAR_SINGLE_ROW: &str = "xbar.single_row";
    /// Rows activated per activation — summary.
    pub const XBAR_ROWS_PER_ACTIVATION: &str = "xbar.rows_per_activation";

    /// ADC conversions taken in full MAC mode — counter.
    pub const ADC_MAC: &str = "adc.mac";
    /// ADC conversions gated to read mode (dynamic switch) — counter.
    pub const ADC_READ: &str = "adc.read";

    /// Modeled crossbar energy (pJ), accumulated — gauge.
    pub const ENERGY_TOTAL_PJ: &str = "energy.total_pj";
    /// Host-baseline energy per lookup (pJ) for comparison — gauge.
    pub const ENERGY_HOST_PJ_PER_LOOKUP: &str = "energy.host_pj_per_lookup";

    /// Scatter fan-out per query (shards) — histogram.
    pub const CLUSTER_FANOUT: &str = "cluster.fanout";
    /// Sub-queries dispatched — counter.
    pub const CLUSTER_SUBQUERIES: &str = "cluster.subqueries";
    /// In-flight sub-queries per shard, sampled at scatter — summary.
    pub const CLUSTER_INFLIGHT: &str = "cluster.inflight";
    /// Queries routed under power-of-two-choices — counter.
    pub const CLUSTER_ROUTE_P2C: &str = "cluster.route_p2c";
    /// Queries routed under ownership pinning — counter.
    pub const CLUSTER_ROUTE_PINNED: &str = "cluster.route_pinned";
    /// Current placement epoch — gauge.
    pub const CLUSTER_EPOCH: &str = "cluster.epoch";
    /// Epoch-swap rebalances performed — counter.
    pub const CLUSTER_REBALANCES: &str = "cluster.rebalances";

    /// Latest drift degradation ratio (1.0 = baseline) — gauge.
    pub const DRIFT_DEGRADATION: &str = "drift.degradation";

    /// Incremental offline refreshes performed — counter.
    pub const OFFLINE_REFRESHES: &str = "offline.refreshes";
    /// Full-scope offline rebuilds performed — counter.
    pub const OFFLINE_FULL_REBUILDS: &str = "offline.full_rebuilds";
    /// Groups re-derived across refreshes — counter.
    pub const OFFLINE_GROUPS_TOUCHED: &str = "offline.groups_touched";
    /// Groups in the current mapping — gauge.
    pub const OFFLINE_GROUPS_TOTAL: &str = "offline.groups_total";
    /// Embedding rows re-placed across refreshes — counter.
    pub const OFFLINE_IDS_MOVED: &str = "offline.ids_moved";
    /// Embedding rows in the catalogue — gauge.
    pub const OFFLINE_IDS_TOTAL: &str = "offline.ids_total";
    /// Shard tiles (re)installed by rebalances — counter.
    pub const OFFLINE_TILES_INSTALLED: &str = "offline.tiles_installed";
    /// Shard tiles across the cluster after the last rebalance — gauge.
    pub const OFFLINE_TILES_TOTAL: &str = "offline.tiles_total";

    /// Distinct tile touches served crossbar-resident — counter.
    pub const STORE_HOT_HITS: &str = "store.hot_hits";
    /// Distinct tile touches served from the DRAM tier — counter.
    pub const STORE_DRAM_HITS: &str = "store.dram_hits";
    /// Distinct tile touches served from the cold tier — counter.
    pub const STORE_COLD_HITS: &str = "store.cold_hits";
    /// Groups promoted into the hot tier — counter.
    pub const STORE_PROMOTIONS: &str = "store.promotions";
    /// Groups evicted from the hot tier — counter.
    pub const STORE_EVICTIONS: &str = "store.evictions";
    /// Tier replans applied by the `Tiered` backend — counter.
    pub const STORE_REPLANS: &str = "store.replans";
    /// Modeled per-batch miss-fetch cost (ns) — summary.
    pub const STORE_MISS_NS: &str = "store.miss_ns";
    /// Hot-tier tile occupancy — gauge.
    pub const STORE_HOT_TILES: &str = "store.hot_tiles";
    /// DRAM-tier tile occupancy — gauge.
    pub const STORE_DRAM_TILES: &str = "store.dram_tiles";
    /// Cold-tier tile count — gauge.
    pub const STORE_COLD_TILES: &str = "store.cold_tiles";

    /// Watch-loop p50 sojourn of the last drive window (ns) — gauge.
    pub const LOADGEN_SOJOURN_P50_NS: &str = "loadgen.sojourn_p50_ns";
    /// Watch-loop p99 sojourn of the last drive window (ns) — gauge.
    pub const LOADGEN_SOJOURN_P99_NS: &str = "loadgen.sojourn_p99_ns";
    /// Watch-loop achieved throughput of the last drive (qps) — gauge.
    pub const LOADGEN_THROUGHPUT_QPS: &str = "loadgen.throughput_qps";
    /// Queries driven through the watch loop — counter.
    pub const LOADGEN_QUERIES: &str = "loadgen.queries";
}

/// One shared handle over the metrics plane and the flight recorder.
/// Cloneable via `Arc`; every record method is a no-op (single branch)
/// when observability is disabled.
#[derive(Debug)]
pub struct Obs {
    enabled: bool,
    metrics: MetricsRegistry,
    recorder: FlightRecorder,
}

impl Obs {
    /// The do-nothing handle every serving path starts with.
    pub fn disabled() -> Arc<Obs> {
        Arc::new(Obs {
            enabled: false,
            metrics: MetricsRegistry::new(),
            recorder: FlightRecorder::new(0, 0.0),
        })
    }

    /// Build from config; `enabled: false` yields [`Obs::disabled`].
    pub fn from_config(cfg: &ObsConfig) -> Arc<Obs> {
        if !cfg.enabled {
            return Self::disabled();
        }
        Arc::new(Obs {
            enabled: true,
            metrics: MetricsRegistry::new(),
            recorder: FlightRecorder::new(cfg.ring_capacity, cfg.sample_rate),
        })
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    pub fn recorder(&self) -> &FlightRecorder {
        &self.recorder
    }

    /// Snapshot the metrics plane, labelled with its source backend.
    pub fn snapshot(&self, source: &str) -> MetricsSnapshot {
        self.metrics.snapshot(source)
    }

    // ---- record methods (all single-branch no-ops when disabled) ----

    pub fn incr(&self, name: &'static str, by: u64) {
        if self.enabled {
            self.metrics.incr(name, by);
        }
    }

    pub fn gauge_set(&self, name: &'static str, value: f64) {
        if self.enabled {
            self.metrics.gauge_set(name, value);
        }
    }

    pub fn gauge_add(&self, name: &'static str, value: f64) {
        if self.enabled {
            self.metrics.gauge_add(name, value);
        }
    }

    pub fn observe(&self, name: &'static str, x: f64) {
        if self.enabled {
            self.metrics.observe(name, x);
        }
    }

    pub fn merge_summary(&self, name: &'static str, local: &Summary) {
        if self.enabled {
            self.metrics.merge_summary(name, local);
        }
    }

    pub fn record_hist(&self, name: &'static str, value: u64, n: u64) {
        if self.enabled {
            self.metrics.record_hist(name, value, n);
        }
    }

    /// Harvest one executed batch's circuit-simulated cost into the
    /// scheduler / crossbar / ADC / energy metric families. Called at
    /// the batch seam from values [`ExecStats`] already carries — the
    /// schedule itself is untouched.
    pub fn record_exec(&self, st: &ExecStats) {
        if !self.enabled {
            return;
        }
        self.metrics.incr(names::SCHED_BATCHES, 1);
        self.metrics.incr(names::SCHED_QUERIES, st.queries);
        self.metrics.incr(names::SCHED_LOOKUPS, st.lookups);
        self.metrics.incr(names::XBAR_ACTIVATIONS, st.activations);
        self.metrics
            .incr(names::XBAR_SINGLE_ROW, st.single_row_activations);
        if st.activations > 0 {
            self.metrics.observe(
                names::XBAR_ROWS_PER_ACTIVATION,
                st.rows_activated as f64 / st.activations as f64,
            );
        }
        self.metrics.incr(names::ADC_MAC, st.mac_activations);
        self.metrics.incr(names::ADC_READ, st.read_activations);
        self.metrics.gauge_add(names::ENERGY_TOTAL_PJ, st.energy_pj);
    }

    /// Whether this query's spans should be recorded (deterministic in
    /// the query id; always false when disabled).
    pub fn sampled(&self, query: u64) -> bool {
        self.enabled && self.recorder.sampled(query)
    }

    /// Record a span for an already-[`Obs::sampled`] query.
    pub fn span(&self, stage: Stage, query: u64, lane: u32, start_ns: u64, end_ns: u64) {
        if self.enabled {
            self.recorder.record(SpanEvent {
                stage,
                query,
                lane,
                start_ns,
                dur_ns: end_ns.saturating_sub(start_ns),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_handle_records_nothing() {
        let obs = Obs::disabled();
        assert!(!obs.enabled());
        obs.incr(names::SCHED_BATCHES, 5);
        obs.observe(names::BATCHER_WAIT_NS, 1.0);
        obs.gauge_add(names::ENERGY_TOTAL_PJ, 2.0);
        obs.record_hist(names::CLUSTER_FANOUT, 2, 1);
        obs.span(Stage::Execute, 1, 0, 0, 10);
        assert!(!obs.sampled(0));
        let snap = obs.snapshot("off");
        assert!(snap.counters.is_empty());
        assert!(snap.gauges.is_empty());
        assert!(snap.summaries.is_empty());
        assert!(snap.histograms.is_empty());
        assert!(obs.recorder().is_empty());
    }

    #[test]
    fn enabled_handle_records_everything() {
        let cfg = ObsConfig {
            enabled: true,
            sample_rate: 1.0,
            ring_capacity: 8,
        };
        let obs = Obs::from_config(&cfg);
        assert!(obs.enabled());
        obs.incr(names::SCHED_BATCHES, 2);
        obs.gauge_set(names::CLUSTER_EPOCH, 3.0);
        obs.observe(names::BATCHER_QUEUE_DEPTH, 4.0);
        obs.record_hist(names::BATCHER_BATCH_SIZE, 32, 1);
        assert!(obs.sampled(123));
        obs.span(Stage::Enqueue, 123, 1, 100, 250);
        let snap = obs.snapshot("sim");
        assert_eq!(snap.counter(names::SCHED_BATCHES), 2);
        assert_eq!(snap.gauge(names::CLUSTER_EPOCH), 3.0);
        assert_eq!(snap.summaries[names::BATCHER_QUEUE_DEPTH].count(), 1);
        assert_eq!(obs.recorder().len(), 1);
        assert_eq!(obs.recorder().events()[0].dur_ns, 150);
    }

    #[test]
    fn from_config_disabled_is_inert() {
        let obs = Obs::from_config(&ObsConfig::default());
        assert!(!obs.enabled());
        assert_eq!(obs.recorder().capacity(), 0);
    }
}
