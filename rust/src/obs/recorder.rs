//! The flight recorder: a fixed-capacity, sampled ring buffer of
//! structured per-query span events, dumpable as Chrome trace-event
//! JSON (load the file in Perfetto / `chrome://tracing`).
//!
//! Every span carries timestamps from the serving stack's injected
//! [`crate::util::Clock`] timeline (`u64` nanoseconds) — the recorder
//! never reads a clock of its own, so a simulated drive produces the
//! same trace on every run.
//!
//! Sampling is **deterministic in the query id**: a query is recorded
//! iff `hash(qid) < rate * 2^64` with a fixed multiplicative hash, so
//! re-running the same workload samples the same queries and the
//! recorder adds no RNG state to the serving path.

use std::sync::Mutex;

/// Pipeline stage a span belongs to (the per-query lifecycle:
/// enqueue → batch-form → schedule → execute → merge).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    /// Waiting in the dynamic batcher (arrival → batch close).
    Enqueue,
    /// Batch formation (close decision; zero-duration marker spans).
    BatchForm,
    /// Scheduling / replica+channel selection for the batch.
    Schedule,
    /// Crossbar service (batch close → this query's finish).
    Execute,
    /// Scatter-gather merge (last sub-query finish → merged finish).
    Merge,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Enqueue => "enqueue",
            Stage::BatchForm => "batch-form",
            Stage::Schedule => "schedule",
            Stage::Execute => "execute",
            Stage::Merge => "merge",
        }
    }
}

/// One recorded span on the injected-clock timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    pub stage: Stage,
    /// Query id (the sampling key).
    pub query: u64,
    /// Lane the span ran on (shard / executor index); becomes the
    /// trace-event `tid` so Perfetto draws one track per executor.
    pub lane: u32,
    pub start_ns: u64,
    pub dur_ns: u64,
}

#[derive(Debug, Default)]
struct Ring {
    buf: Vec<SpanEvent>,
    /// Next overwrite position once the buffer is full.
    head: usize,
    /// Total spans ever recorded (len + dropped).
    recorded: u64,
}

/// Fixed-capacity ring of sampled [`SpanEvent`]s. Overwrites the oldest
/// span once full — a crash/latency investigation always sees the most
/// recent window.
#[derive(Debug)]
pub struct FlightRecorder {
    capacity: usize,
    sample_rate: f64,
    inner: Mutex<Ring>,
}

/// Fibonacci-hashing multiplier (same constant the cluster's routing
/// salt uses) — decorrelates sequential query ids before the sampling
/// threshold test.
const SAMPLE_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

impl FlightRecorder {
    /// `capacity` 0 disables recording entirely; `sample_rate` is the
    /// sampled fraction of query ids in `[0, 1]` (≥ 1.0 records all).
    pub fn new(capacity: usize, sample_rate: f64) -> Self {
        Self {
            capacity,
            sample_rate,
            inner: Mutex::new(Ring::default()),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn sample_rate(&self) -> f64 {
        self.sample_rate
    }

    /// Deterministic per-query sampling decision.
    pub fn sampled(&self, query: u64) -> bool {
        if self.capacity == 0 || self.sample_rate <= 0.0 {
            return false;
        }
        if self.sample_rate >= 1.0 {
            return true;
        }
        let threshold = (self.sample_rate * u64::MAX as f64) as u64;
        query.wrapping_mul(SAMPLE_MIX) < threshold
    }

    /// Record one span (the caller has already checked [`Self::sampled`];
    /// unsampled spans recorded anyway are kept — sampling is advisory).
    pub fn record(&self, ev: SpanEvent) {
        if self.capacity == 0 {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        g.recorded += 1;
        if g.buf.len() < self.capacity {
            g.buf.push(ev);
        } else {
            let head = g.head;
            g.buf[head] = ev;
            g.head = (head + 1) % self.capacity;
        }
    }

    /// Spans currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total spans ever recorded, including overwritten ones.
    pub fn recorded(&self) -> u64 {
        self.inner.lock().unwrap().recorded
    }

    /// Spans lost to ring overwrite.
    pub fn dropped(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        g.recorded - g.buf.len() as u64
    }

    /// The held spans in record order (oldest first).
    pub fn events(&self) -> Vec<SpanEvent> {
        let g = self.inner.lock().unwrap();
        let mut out = Vec::with_capacity(g.buf.len());
        out.extend_from_slice(&g.buf[g.head..]);
        out.extend_from_slice(&g.buf[..g.head]);
        out
    }

    /// Chrome trace-event JSON (`ph: "X"` complete events; `ts`/`dur`
    /// in microseconds per the trace format). Open in Perfetto or
    /// `chrome://tracing`.
    pub fn trace_json(&self) -> String {
        let events = self.events();
        let mut out = String::new();
        out.push_str("{\n\"displayTimeUnit\": \"ns\",\n\"traceEvents\": [\n");
        for (i, ev) in events.iter().enumerate() {
            out.push_str(&format!(
                "{{\"name\": \"{}\", \"cat\": \"recross\", \"ph\": \"X\", \
                 \"ts\": {:.3}, \"dur\": {:.3}, \"pid\": 0, \"tid\": {}, \
                 \"args\": {{\"query\": {}}}}}{}\n",
                ev.stage.name(),
                ev.start_ns as f64 / 1e3,
                ev.dur_ns as f64 / 1e3,
                ev.lane,
                ev.query,
                if i + 1 == events.len() { "" } else { "," }
            ));
        }
        out.push_str("]\n}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(query: u64, start_ns: u64) -> SpanEvent {
        SpanEvent {
            stage: Stage::Execute,
            query,
            lane: 0,
            start_ns,
            dur_ns: 10,
        }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let r = FlightRecorder::new(3, 1.0);
        for q in 0..5 {
            r.record(span(q, q * 100));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.recorded(), 5);
        assert_eq!(r.dropped(), 2);
        let qs: Vec<u64> = r.events().iter().map(|e| e.query).collect();
        assert_eq!(qs, vec![2, 3, 4]);
    }

    #[test]
    fn zero_capacity_records_nothing() {
        let r = FlightRecorder::new(0, 1.0);
        r.record(span(1, 0));
        assert!(r.is_empty());
        assert_eq!(r.recorded(), 0);
        assert!(!r.sampled(1));
    }

    #[test]
    fn sampling_is_deterministic_and_rate_shaped() {
        let r = FlightRecorder::new(16, 0.25);
        let first: Vec<bool> = (0..10_000).map(|q| r.sampled(q)).collect();
        let second: Vec<bool> = (0..10_000).map(|q| r.sampled(q)).collect();
        assert_eq!(first, second, "sampling must be deterministic");
        let hits = first.iter().filter(|&&s| s).count();
        // Multiplicative hashing over sequential ids is near-uniform.
        assert!((1_500..=3_500).contains(&hits), "hit rate {hits}/10000");

        let all = FlightRecorder::new(16, 1.0);
        assert!((0..100).all(|q| all.sampled(q)));
        let none = FlightRecorder::new(16, 0.0);
        assert!(!(0..100).any(|q| none.sampled(q)));
    }

    #[test]
    fn trace_json_is_chrome_format() {
        let r = FlightRecorder::new(8, 1.0);
        r.record(SpanEvent {
            stage: Stage::Enqueue,
            query: 7,
            lane: 2,
            start_ns: 1_500,
            dur_ns: 2_000,
        });
        let js = r.trace_json();
        assert!(js.contains("\"traceEvents\""));
        assert!(js.contains("\"name\": \"enqueue\""));
        assert!(js.contains("\"ph\": \"X\""));
        assert!(js.contains("\"ts\": 1.500"));
        assert!(js.contains("\"dur\": 2.000"));
        assert!(js.contains("\"tid\": 2"));
        assert!(js.contains("\"query\": 7"));
        // Empty recorder still emits a valid document.
        assert!(FlightRecorder::new(0, 0.0).trace_json().contains("traceEvents"));
    }

    #[test]
    fn stage_names_cover_the_lifecycle() {
        let names: Vec<&str> = [
            Stage::Enqueue,
            Stage::BatchForm,
            Stage::Schedule,
            Stage::Execute,
            Stage::Merge,
        ]
        .iter()
        .map(|s| s.name())
        .collect();
        assert_eq!(
            names,
            vec!["enqueue", "batch-form", "schedule", "execute", "merge"]
        );
    }
}
