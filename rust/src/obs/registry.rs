//! The metrics plane: a typed, named registry of counters, gauges,
//! summaries, and histograms, snapshot-exportable as one
//! schema-versioned JSON document.
//!
//! Design constraints, in order:
//!
//! 1. **Never perturb the system.** Recording happens at batch/wave
//!    boundaries only (the "seams" of the serving stack) and is purely
//!    additive — no instrumented code path changes a float operation,
//!    a batch boundary, or a scheduling decision.
//! 2. **One schema for sim and live.** `MetricsSnapshot::to_json`
//!    emits the same `recross.metrics` document whether the numbers
//!    came from [`crate::loadgen::drive`] on virtual time or a live
//!    executor thread, so the two are diffable.
//! 3. **Zero dependencies.** JSON is hand-rolled (the same discipline
//!    as `BENCH_sched.json`); non-finite floats serialize as `null`.
//!
//! The registry is `Sync` (a single `Mutex` over `BTreeMap`s — metric
//! updates are seam-rate, not activation-rate, so one lock is cheap and
//! keeps disabled-path overhead at a single branch in [`super::Obs`]).
//! Per-shard collection merges local [`Summary`] accumulators through
//! [`MetricsRegistry::merge_summary`] (Welford's parallel merge), so
//! shards never contend on the lock inside their serving loops.

use crate::metrics::{Histogram, Summary};
use std::collections::BTreeMap;
use std::sync::Mutex;

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    summaries: BTreeMap<&'static str, Summary>,
    histograms: BTreeMap<&'static str, Histogram>,
}

/// A process-wide registry of named metrics. Names are `&'static str`
/// constants (see [`super::names`]) so registration is allocation-free.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `by` to a monotone counter.
    pub fn incr(&self, name: &'static str, by: u64) {
        let mut g = self.inner.lock().unwrap();
        *g.counters.entry(name).or_insert(0) += by;
    }

    /// Set a gauge to its latest value (last-write-wins).
    pub fn gauge_set(&self, name: &'static str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        g.gauges.insert(name, value);
    }

    /// Accumulate into a gauge (for modeled totals like energy).
    pub fn gauge_add(&self, name: &'static str, value: f64) {
        let mut g = self.inner.lock().unwrap();
        *g.gauges.entry(name).or_insert(0.0) += value;
    }

    /// Add one observation to a streaming [`Summary`].
    pub fn observe(&self, name: &'static str, x: f64) {
        let mut g = self.inner.lock().unwrap();
        g.summaries.entry(name).or_insert_with(Summary::new).add(x);
    }

    /// Merge a locally-accumulated per-shard [`Summary`] into the
    /// registry's stream (Welford parallel merge — the per-shard
    /// collection path of the metrics plane).
    pub fn merge_summary(&self, name: &'static str, local: &Summary) {
        let mut g = self.inner.lock().unwrap();
        g.summaries
            .entry(name)
            .or_insert_with(Summary::new)
            .merge(local);
    }

    /// Record `n` observations of integer `value` into a histogram.
    pub fn record_hist(&self, name: &'static str, value: u64, n: u64) {
        let mut g = self.inner.lock().unwrap();
        g.histograms
            .entry(name)
            .or_insert_with(Histogram::new)
            .add_n(value, n);
    }

    /// A consistent point-in-time copy of every metric.
    pub fn snapshot(&self, source: &str) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        MetricsSnapshot {
            source: source.to_string(),
            counters: g.counters.iter().map(|(&k, &v)| (k.to_string(), v)).collect(),
            gauges: g.gauges.iter().map(|(&k, &v)| (k.to_string(), v)).collect(),
            summaries: g
                .summaries
                .iter()
                .map(|(&k, v)| (k.to_string(), v.clone()))
                .collect(),
            histograms: g
                .histograms
                .iter()
                .map(|(&k, v)| (k.to_string(), v.iter().collect()))
                .collect(),
        }
    }
}

/// An exported point-in-time view of a [`MetricsRegistry`] (or of
/// status-derived counters — see `Backend::metrics`). Serializes to the
/// `recross.metrics` JSON schema documented in DESIGN.md §Observability.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Which backend/run produced the numbers (`Backend::name()`).
    pub source: String,
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub summaries: BTreeMap<String, Summary>,
    /// Sparse `(value, count)` pairs in ascending value order.
    pub histograms: BTreeMap<String, Vec<(u64, u64)>>,
}

impl MetricsSnapshot {
    /// Schema identifier emitted in every JSON document.
    pub const SCHEMA: &'static str = "recross.metrics";
    /// Schema version; bump on any structural change.
    pub const VERSION: u32 = 1;

    pub fn new(source: &str) -> Self {
        Self {
            source: source.to_string(),
            ..Default::default()
        }
    }

    /// Counter value, 0 if never recorded.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Gauge value, 0.0 if never recorded.
    pub fn gauge(&self, name: &str) -> f64 {
        self.gauges.get(name).copied().unwrap_or(0.0)
    }

    /// Merge another snapshot into this one: counters add, gauges take
    /// the other side's value (last-write-wins), summaries merge via
    /// Welford, histogram counts add.
    pub fn merge(&mut self, other: &MetricsSnapshot) {
        // Counters and bucket counts saturate rather than wrap: a merge
        // of adversarial (or corrupted) near-`u64::MAX` snapshots must
        // stay monotone, never jump backwards past zero.
        for (k, v) in &other.counters {
            let c = self.counters.entry(k.clone()).or_insert(0);
            *c = c.saturating_add(*v);
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, v) in &other.summaries {
            self.summaries
                .entry(k.clone())
                .or_insert_with(Summary::new)
                .merge(v);
        }
        for (k, pairs) in &other.histograms {
            let mine = self.histograms.entry(k.clone()).or_default();
            // Merge two ascending sparse lists.
            let mut merged: BTreeMap<u64, u64> = mine.iter().copied().collect();
            for &(value, count) in pairs {
                let c = merged.entry(value).or_insert(0);
                *c = c.saturating_add(count);
            }
            *mine = merged.into_iter().collect();
        }
    }

    /// Hand-rolled, schema-versioned JSON document.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"schema\": \"{}\",\n", Self::SCHEMA));
        out.push_str(&format!("  \"version\": {},\n", Self::VERSION));
        out.push_str(&format!("  \"source\": \"{}\",\n", escape(&self.source)));
        out.push_str("  \"counters\": {");
        push_entries(&mut out, self.counters.iter(), |(k, v)| {
            format!("\"{}\": {v}", escape(k))
        });
        out.push_str("},\n  \"gauges\": {");
        push_entries(&mut out, self.gauges.iter(), |(k, v)| {
            format!("\"{}\": {}", escape(k), json_f64(**v))
        });
        out.push_str("},\n  \"summaries\": {");
        push_entries(&mut out, self.summaries.iter(), |(k, s)| {
            format!(
                "\"{}\": {{\"count\": {}, \"mean\": {}, \"stddev\": {}, \"min\": {}, \"max\": {}}}",
                escape(k),
                s.count(),
                json_f64(s.mean()),
                json_f64(s.stddev()),
                json_f64(s.min()),
                json_f64(s.max())
            )
        });
        out.push_str("},\n  \"histograms\": {");
        push_entries(&mut out, self.histograms.iter(), |(k, pairs)| {
            let body: Vec<String> = pairs.iter().map(|(v, c)| format!("[{v}, {c}]")).collect();
            format!("\"{}\": [{}]", escape(k), body.join(", "))
        });
        out.push_str("}\n}\n");
        out
    }
}

/// `f64` as a JSON number, or `null` for non-finite values (JSON has no
/// NaN/Infinity literals).
fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn push_entries<I, T, F>(out: &mut String, entries: I, render: F)
where
    I: Iterator<Item = T>,
    F: Fn(&T) -> String,
{
    let rendered: Vec<String> = entries.map(|e| render(&e)).collect();
    if rendered.is_empty() {
        return;
    }
    out.push_str("\n    ");
    out.push_str(&rendered.join(",\n    "));
    out.push_str("\n  ");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_and_summaries_roundtrip() {
        let r = MetricsRegistry::new();
        r.incr("a.count", 2);
        r.incr("a.count", 3);
        r.gauge_set("g.latest", 1.5);
        r.gauge_set("g.latest", 2.5);
        r.gauge_add("g.total", 1.0);
        r.gauge_add("g.total", 2.0);
        r.observe("s.x", 1.0);
        r.observe("s.x", 3.0);
        r.record_hist("h.v", 7, 2);
        let snap = r.snapshot("test");
        assert_eq!(snap.counter("a.count"), 5);
        assert_eq!(snap.gauge("g.latest"), 2.5);
        assert_eq!(snap.gauge("g.total"), 3.0);
        let s = &snap.summaries["s.x"];
        assert_eq!(s.count(), 2);
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert_eq!(snap.histograms["h.v"], vec![(7, 2)]);
        assert_eq!(snap.counter("missing"), 0);
    }

    #[test]
    fn merge_summary_uses_welford_merge() {
        let r = MetricsRegistry::new();
        let mut a = Summary::new();
        let mut b = Summary::new();
        for x in [1.0, 2.0] {
            a.add(x);
        }
        for x in [3.0, 4.0] {
            b.add(x);
        }
        r.merge_summary("s", &a);
        r.merge_summary("s", &b);
        let s = &r.snapshot("t").summaries["s"];
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn snapshot_merge_semantics() {
        let r1 = MetricsRegistry::new();
        let r2 = MetricsRegistry::new();
        r1.incr("c", 1);
        r2.incr("c", 2);
        r1.gauge_set("g", 1.0);
        r2.gauge_set("g", 9.0);
        r1.record_hist("h", 5, 1);
        r2.record_hist("h", 5, 2);
        r2.record_hist("h", 8, 1);
        r1.observe("s", 1.0);
        r2.observe("s", 5.0);
        let mut a = r1.snapshot("a");
        a.merge(&r2.snapshot("b"));
        assert_eq!(a.counter("c"), 3);
        assert_eq!(a.gauge("g"), 9.0);
        assert_eq!(a.histograms["h"], vec![(5, 3), (8, 1)]);
        assert_eq!(a.summaries["s"].count(), 2);
        assert_eq!(a.source, "a");
    }

    #[test]
    fn json_is_schema_versioned_and_escapes() {
        let r = MetricsRegistry::new();
        r.incr("n", 1);
        r.gauge_set("bad", f64::INFINITY);
        let js = r.snapshot("sim\"x").to_json();
        assert!(js.contains("\"schema\": \"recross.metrics\""));
        assert!(js.contains("\"version\": 1"));
        assert!(js.contains("\"source\": \"sim\\\"x\""));
        assert!(js.contains("\"n\": 1"));
        assert!(js.contains("\"bad\": null"));
    }

    #[test]
    fn empty_snapshot_has_empty_sections() {
        let js = MetricsRegistry::new().snapshot("none").to_json();
        assert!(js.contains("\"counters\": {}"));
        assert!(js.contains("\"histograms\": {}"));
    }
}
