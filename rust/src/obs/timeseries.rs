//! Windowed telemetry time-series: [`MetricsSnapshot`] diffs on injected
//! clock ticks.
//!
//! A [`MetricsSnapshot`] is one cumulative point in time; everything the
//! closed-loop consumers need — rates, windowed percentiles, sustained
//! breach detection — lives in the *difference* between successive
//! snapshots. [`TimeSeries::tick`] takes the current simulated-or-wall
//! time (an injected [`crate::util::Clock`] reading, so sim and live
//! share one code path and a [`crate::util::SimClock`] makes tick
//! sequences bit-reproducible) plus the current snapshot, diffs it
//! against the previous tick, and produces one [`Window`]:
//!
//! * **counters** (monotone) → per-window delta and rate/s
//!   (`saturating_sub`, so a registry reset degrades to a zero window
//!   instead of an underflow);
//! * **gauges** → the sampled value;
//! * **summaries** → per-window count and mean recovered from the
//!   Welford accumulators (`Δsum / Δcount`; extrema are cumulative and
//!   are not windowable, so they are deliberately absent);
//! * **histograms** → sparse bucket subtraction rebuilt into a
//!   [`Histogram`], so windowed percentiles are *exact* nearest-rank
//!   over exactly the window's observations.
//!
//! Each window is also retained in fixed-capacity per-metric rings
//! ([`Ring`]; oldest point evicted first), which is what
//! [`super::slo::SloTracker`] burn-rate rules and
//! [`crate::graph::DeltaParams::from_observed`] read.

use std::collections::{BTreeMap, VecDeque};

use crate::metrics::Histogram;
use crate::obs::MetricsSnapshot;

/// Fixed-capacity series of `(t_ns, value)` points, oldest evicted first.
#[derive(Debug, Clone)]
pub struct Ring {
    capacity: usize,
    points: VecDeque<(u64, f64)>,
}

impl Ring {
    /// Empty ring holding at most `capacity` points (`capacity >= 1`).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "a zero-capacity ring can hold nothing");
        Self {
            capacity,
            points: VecDeque::with_capacity(capacity),
        }
    }

    fn push(&mut self, t_ns: u64, value: f64) {
        if self.points.len() == self.capacity {
            self.points.pop_front();
        }
        self.points.push_back((t_ns, value));
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Points oldest → newest.
    pub fn iter(&self) -> impl Iterator<Item = (u64, f64)> + '_ {
        self.points.iter().copied()
    }

    /// Values only, oldest → newest.
    pub fn values(&self) -> Vec<f64> {
        self.points.iter().map(|&(_, v)| v).collect()
    }

    /// Most recent point, if any.
    pub fn latest(&self) -> Option<(u64, f64)> {
        self.points.back().copied()
    }
}

/// One counter over one window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CounterWindow {
    /// Increment over the window (0 if the counter reset).
    pub delta: u64,
    /// `delta` per second of window time (0 for a zero-length window).
    pub rate_per_sec: f64,
}

/// One summary over one window, recovered from the cumulative Welford
/// state: `count = Δcount`, `mean = Δsum / Δcount`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SummaryWindow {
    pub count: u64,
    pub sum: f64,
    pub mean: f64,
}

/// The product of one [`TimeSeries::tick`]: every metric family diffed
/// over `[t_ns - dt_ns, t_ns]`.
#[derive(Debug, Clone)]
pub struct Window {
    /// 0-based tick index.
    pub index: u64,
    /// Tick time (window end), ns on the injected clock.
    pub t_ns: u64,
    /// Window length, ns (0 on the first tick — its baseline is empty).
    pub dt_ns: u64,
    pub counters: BTreeMap<String, CounterWindow>,
    pub gauges: BTreeMap<String, f64>,
    pub summaries: BTreeMap<String, SummaryWindow>,
    /// Exactly the window's observations, per histogram metric.
    pub histograms: BTreeMap<String, Histogram>,
}

impl Window {
    pub fn counter_delta(&self, name: &str) -> Option<u64> {
        self.counters.get(name).map(|c| c.delta)
    }

    pub fn counter_rate(&self, name: &str) -> Option<f64> {
        self.counters.get(name).map(|c| c.rate_per_sec)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn summary_mean(&self, name: &str) -> Option<f64> {
        self.summaries.get(name).map(|s| s.mean)
    }

    /// Exact windowed percentile of a histogram metric, or `None` if the
    /// metric is absent or recorded nothing this window.
    pub fn percentile(&self, name: &str, p: f64) -> Option<f64> {
        let h = self.histograms.get(name)?;
        if h.total() == 0 {
            None
        } else {
            Some(h.percentile(p) as f64)
        }
    }
}

/// Per-metric windowed rings fed by snapshot diffs on clock ticks.
#[derive(Debug)]
pub struct TimeSeries {
    capacity: usize,
    ticks: u64,
    last: Option<(u64, MetricsSnapshot)>,
    counter_deltas: BTreeMap<String, Ring>,
    counter_rates: BTreeMap<String, Ring>,
    gauges: BTreeMap<String, Ring>,
    summary_means: BTreeMap<String, Ring>,
    histograms: BTreeMap<String, VecDeque<(u64, Histogram)>>,
}

impl TimeSeries {
    /// Empty pipeline whose per-metric rings hold `capacity` windows.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "time-series rings need capacity >= 1");
        Self {
            capacity,
            ticks: 0,
            last: None,
            counter_deltas: BTreeMap::new(),
            counter_rates: BTreeMap::new(),
            gauges: BTreeMap::new(),
            summary_means: BTreeMap::new(),
            histograms: BTreeMap::new(),
        }
    }

    /// Ticks taken so far.
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Ring capacity (windows retained per metric).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Diff `snap` against the previous tick's snapshot and absorb the
    /// resulting [`Window`] into the rings.
    ///
    /// The first tick has no baseline: its deltas are taken from an
    /// empty snapshot (i.e. "everything since start") over a zero-length
    /// window, so its rates are 0. `now_ns` must be non-decreasing
    /// across ticks (same contract as [`crate::util::SimClock::set`]).
    pub fn tick(&mut self, now_ns: u64, snap: &MetricsSnapshot) -> Window {
        let prev = self.last.take();
        let prev_t = prev.as_ref().map_or(now_ns, |&(t, _)| t);
        assert!(
            now_ns >= prev_t,
            "TimeSeries::tick({now_ns}) would rewind past {prev_t}"
        );
        let dt_ns = now_ns - prev_t;
        let secs = dt_ns as f64 / 1e9;
        let prev_snap = prev.as_ref().map(|(_, s)| s);

        let mut w = Window {
            index: self.ticks,
            t_ns: now_ns,
            dt_ns,
            counters: BTreeMap::new(),
            gauges: BTreeMap::new(),
            summaries: BTreeMap::new(),
            histograms: BTreeMap::new(),
        };

        for (k, &cur) in &snap.counters {
            let before = prev_snap.map_or(0, |p| p.counter(k));
            let delta = cur.saturating_sub(before);
            let rate = if secs > 0.0 { delta as f64 / secs } else { 0.0 };
            w.counters.insert(
                k.clone(),
                CounterWindow {
                    delta,
                    rate_per_sec: rate,
                },
            );
        }
        for (k, &v) in &snap.gauges {
            w.gauges.insert(k.clone(), v);
        }
        for (k, cur) in &snap.summaries {
            let (n0, s0) = prev_snap
                .and_then(|p| p.summaries.get(k))
                .map_or((0, 0.0), |s| (s.count(), s.sum()));
            let count = cur.count().saturating_sub(n0);
            let sum = cur.sum() - s0;
            let mean = if count > 0 { sum / count as f64 } else { 0.0 };
            w.summaries.insert(k.clone(), SummaryWindow { count, sum, mean });
        }
        for (k, pairs) in &snap.histograms {
            let before: BTreeMap<u64, u64> = prev_snap
                .and_then(|p| p.histograms.get(k))
                .map_or_else(BTreeMap::new, |v| v.iter().copied().collect());
            let mut h = Histogram::new();
            for &(value, count) in pairs {
                let delta = count.saturating_sub(before.get(&value).copied().unwrap_or(0));
                h.add_n(value, delta);
            }
            w.histograms.insert(k.clone(), h);
        }

        let cap = self.capacity;
        for (k, c) in &w.counters {
            ring_entry(&mut self.counter_deltas, k, cap).push(now_ns, c.delta as f64);
            ring_entry(&mut self.counter_rates, k, cap).push(now_ns, c.rate_per_sec);
        }
        for (k, &v) in &w.gauges {
            ring_entry(&mut self.gauges, k, cap).push(now_ns, v);
        }
        for (k, s) in &w.summaries {
            ring_entry(&mut self.summary_means, k, cap).push(now_ns, s.mean);
        }
        for (k, h) in &w.histograms {
            let ring = self
                .histograms
                .entry(k.clone())
                .or_insert_with(|| VecDeque::with_capacity(cap));
            if ring.len() == cap {
                ring.pop_front();
            }
            ring.push_back((now_ns, h.clone()));
        }

        self.last = Some((now_ns, snap.clone()));
        self.ticks += 1;
        w
    }

    /// Per-window increment series of a counter.
    pub fn counter_deltas(&self, name: &str) -> Option<&Ring> {
        self.counter_deltas.get(name)
    }

    /// Per-window rate/s series of a counter.
    pub fn counter_rates(&self, name: &str) -> Option<&Ring> {
        self.counter_rates.get(name)
    }

    /// Sampled gauge series.
    pub fn gauge_series(&self, name: &str) -> Option<&Ring> {
        self.gauges.get(name)
    }

    /// Per-window mean series of a summary.
    pub fn summary_means(&self, name: &str) -> Option<&Ring> {
        self.summary_means.get(name)
    }

    /// Retained `(t_ns, windowed Histogram)` pairs, oldest → newest.
    pub fn histogram_windows(&self, name: &str) -> Option<&VecDeque<(u64, Histogram)>> {
        self.histograms.get(name)
    }

    /// Gauge values oldest → newest (empty if the gauge never appeared) —
    /// the shape [`crate::graph::DeltaParams::from_observed`] consumes.
    pub fn gauge_values(&self, name: &str) -> Vec<f64> {
        self.gauges.get(name).map_or_else(Vec::new, Ring::values)
    }
}

fn ring_entry<'a>(
    map: &'a mut BTreeMap<String, Ring>,
    name: &str,
    capacity: usize,
) -> &'a mut Ring {
    if !map.contains_key(name) {
        map.insert(name.to_string(), Ring::new(capacity));
    }
    map.get_mut(name).expect("just inserted")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Summary;

    fn snap_with(counters: &[(&str, u64)], gauges: &[(&str, f64)]) -> MetricsSnapshot {
        let mut s = MetricsSnapshot::new("test");
        for &(k, v) in counters {
            s.counters.insert(k.to_string(), v);
        }
        for &(k, v) in gauges {
            s.gauges.insert(k.to_string(), v);
        }
        s
    }

    #[test]
    fn counters_diff_into_deltas_and_rates() {
        let mut ts = TimeSeries::new(8);
        let w0 = ts.tick(0, &snap_with(&[("c", 100)], &[]));
        // First tick: delta from the empty baseline, zero-length window.
        assert_eq!(w0.counter_delta("c"), Some(100));
        assert_eq!(w0.counter_rate("c"), Some(0.0));
        let w1 = ts.tick(2_000_000_000, &snap_with(&[("c", 160)], &[]));
        assert_eq!(w1.counter_delta("c"), Some(60));
        assert!((w1.counter_rate("c").unwrap() - 30.0).abs() < 1e-12);
        // A counter reset (monotonicity violated) degrades to zero.
        let w2 = ts.tick(3_000_000_000, &snap_with(&[("c", 40)], &[]));
        assert_eq!(w2.counter_delta("c"), Some(0));
        assert_eq!(w2.counter_rate("c"), Some(0.0));
        assert_eq!(ts.ticks(), 3);
        assert_eq!(ts.counter_deltas("c").unwrap().values(), vec![100.0, 60.0, 0.0]);
        assert_eq!(ts.counter_rates("c").unwrap().len(), 3);
    }

    #[test]
    fn gauges_sample_and_rings_evict_oldest() {
        let mut ts = TimeSeries::new(2);
        for i in 0..5u64 {
            let w = ts.tick(i * 10, &snap_with(&[], &[("g", i as f64)]));
            assert_eq!(w.gauge("g"), Some(i as f64));
        }
        let ring = ts.gauge_series("g").unwrap();
        assert_eq!(ring.capacity(), 2);
        assert_eq!(ring.values(), vec![3.0, 4.0]);
        assert_eq!(ring.latest(), Some((40, 4.0)));
        assert_eq!(ts.gauge_values("g"), vec![3.0, 4.0]);
        assert!(ts.gauge_values("missing").is_empty());
    }

    #[test]
    fn summaries_recover_window_count_and_mean() {
        let mut cum = Summary::new();
        cum.add(10.0);
        cum.add(20.0);
        let mut s0 = MetricsSnapshot::new("t");
        s0.summaries.insert("s".into(), cum.clone());
        let mut ts = TimeSeries::new(4);
        ts.tick(0, &s0);
        // Second window adds 30 and 50: count 2, mean 40.
        cum.add(30.0);
        cum.add(50.0);
        let mut s1 = MetricsSnapshot::new("t");
        s1.summaries.insert("s".into(), cum);
        let w = ts.tick(1_000, &s1);
        let sw = w.summaries["s"];
        assert_eq!(sw.count, 2);
        assert!((sw.mean - 40.0).abs() < 1e-9);
        assert!((sw.sum - 80.0).abs() < 1e-9);
        assert_eq!(w.summary_mean("s"), Some(sw.mean));
    }

    #[test]
    fn histogram_windows_give_exact_windowed_percentiles() {
        let mut s0 = MetricsSnapshot::new("t");
        s0.histograms.insert("h".into(), vec![(1, 5), (10, 1)]);
        let mut ts = TimeSeries::new(4);
        let w0 = ts.tick(0, &s0);
        assert_eq!(w0.percentile("h", 50.0), Some(1.0));
        // Window 1 adds 99 copies of value 100 and 1 more of value 1:
        // the windowed p50 sees only those 100 observations.
        let mut s1 = MetricsSnapshot::new("t");
        s1.histograms.insert("h".into(), vec![(1, 6), (10, 1), (100, 99)]);
        let w1 = ts.tick(1_000, &s1);
        let h = &w1.histograms["h"];
        assert_eq!(h.total(), 100);
        assert_eq!(h.count(1), 1);
        assert_eq!(h.count(100), 99);
        assert_eq!(w1.percentile("h", 50.0), Some(100.0));
        assert_eq!(w1.percentile("h", 99.0), Some(100.0));
        // An untouched histogram yields an empty window: no percentile.
        let w2 = ts.tick(2_000, &s1);
        assert_eq!(w2.percentile("h", 99.0), None);
        assert_eq!(ts.histogram_windows("h").unwrap().len(), 3);
    }

    #[test]
    #[should_panic(expected = "would rewind")]
    fn tick_rejects_time_travel() {
        let mut ts = TimeSeries::new(2);
        ts.tick(100, &MetricsSnapshot::new("t"));
        ts.tick(99, &MetricsSnapshot::new("t"));
    }

    #[test]
    fn tick_is_deterministic() {
        let run = || {
            let mut ts = TimeSeries::new(4);
            let mut out = Vec::new();
            for i in 0..6u64 {
                let s = snap_with(&[("c", i * i * 7)], &[("g", i as f64 * 0.5)]);
                let w = ts.tick(i * 1_000_000, &s);
                out.push((w.counter_delta("c"), w.gauge("g")));
            }
            out
        };
        assert_eq!(run(), run());
    }
}
