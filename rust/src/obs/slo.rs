//! Declarative SLOs over the telemetry time-series, evaluated with
//! multi-window burn-rate rules, emitting a deterministic
//! **`recross.alerts` v1** stream.
//!
//! An [`Objective`] names a windowed signal ([`SloSignal`] — a gauge, a
//! counter rate, a summary's window mean, or an exact windowed histogram
//! percentile), a threshold, and the side the signal must stay on
//! ([`Cmp`]). Each [`SloTracker::evaluate`] call samples every objective
//! against one [`Window`] and updates two Google-SRE-style burn-rate
//! rules per objective:
//!
//! * **fast** (severity `page`): the last `fast_windows` consecutive
//!   windows all breached — catches a sharp overload within one or two
//!   ticks;
//! * **slow** (severity `warn`): at least `slow_burn` of the last
//!   `slow_windows` windows breached — catches a slow sustained burn
//!   that never trips the fast rule.
//!
//! Alerts are **edge-triggered**: one `firing` event when a rule starts
//! to fire, one `resolved` event when it stops. The stream is a pure
//! function of the tick sequence — same windows in, same alert bytes
//! out ([`Alert::to_json_line`] uses the same non-finite→`null` float
//! rules as the metrics snapshot exporter).
//!
//! [`Watcher`] bundles a [`TimeSeries`] with a tracker — the composition
//! `recross status --watch` and the cluster drift loop both run.

use std::collections::VecDeque;

use super::timeseries::{TimeSeries, Window};
use crate::config::{SloConfig, WatchConfig};
use crate::obs::{names, MetricsSnapshot};

/// Schema tag of every alert event.
pub const ALERTS_SCHEMA: &str = "recross.alerts";
/// Alert stream schema version.
pub const ALERTS_VERSION: u32 = 1;

/// Which windowed signal an objective watches.
#[derive(Debug, Clone, PartialEq)]
pub enum SloSignal {
    /// Sampled gauge value.
    Gauge { metric: String },
    /// Counter increments per second over the window.
    CounterRate { metric: String },
    /// Summary mean over the window (`Δsum / Δcount`).
    SummaryMean { metric: String },
    /// Exact windowed percentile of a histogram metric.
    HistogramPercentile { metric: String, p: f64 },
}

impl SloSignal {
    /// Stable human/machine label, e.g. `p99(batcher.batch_size)`.
    pub fn label(&self) -> String {
        match self {
            SloSignal::Gauge { metric } => format!("gauge({metric})"),
            SloSignal::CounterRate { metric } => format!("rate({metric})"),
            SloSignal::SummaryMean { metric } => format!("mean({metric})"),
            SloSignal::HistogramPercentile { metric, p } => format!("p{p}({metric})"),
        }
    }

    /// Sample the signal from one window; `None` when the metric is
    /// absent (that window is not counted against the objective).
    pub fn sample(&self, w: &Window) -> Option<f64> {
        match self {
            SloSignal::Gauge { metric } => w.gauge(metric),
            SloSignal::CounterRate { metric } => w.counter_rate(metric),
            SloSignal::SummaryMean { metric } => w.summary_mean(metric),
            SloSignal::HistogramPercentile { metric, p } => w.percentile(metric, *p),
        }
    }
}

/// Side of the threshold the signal is *supposed* to stay on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Cmp {
    /// Healthy while `value <= threshold` (latency, depth, error rate).
    Below,
    /// Healthy while `value >= threshold` (throughput floors).
    Above,
}

/// Alert severity, one per burn-rate rule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    /// Fast-burn rule tripped: page.
    Page,
    /// Slow-burn rule tripped: warn.
    Warn,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Page => "page",
            Severity::Warn => "warn",
        }
    }
}

/// Edge direction of an alert event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlertState {
    Firing,
    Resolved,
}

impl AlertState {
    pub fn as_str(&self) -> &'static str {
        match self {
            AlertState::Firing => "firing",
            AlertState::Resolved => "resolved",
        }
    }
}

/// One declarative service-level objective.
#[derive(Debug, Clone, PartialEq)]
pub struct Objective {
    /// Stable name carried on every alert, e.g. `sojourn-p99`.
    pub name: String,
    pub signal: SloSignal,
    pub cmp: Cmp,
    pub threshold: f64,
    /// Fast rule: this many consecutive breached windows page.
    pub fast_windows: usize,
    /// Slow rule: evaluated over this many trailing windows.
    pub slow_windows: usize,
    /// Slow rule: breached fraction that warns, in `(0, 1]`.
    pub slow_burn: f64,
}

impl Objective {
    /// Objective with the default burn-rate rules (fast 1-window page,
    /// slow 12-window ≥ 50 % warn).
    pub fn new(name: &str, signal: SloSignal, cmp: Cmp, threshold: f64) -> Self {
        Self {
            name: name.to_string(),
            signal,
            cmp,
            threshold,
            fast_windows: 1,
            slow_windows: 12,
            slow_burn: 0.5,
        }
    }

    /// Override both burn-rate rules.
    pub fn with_burn_rules(
        mut self,
        fast_windows: usize,
        slow_windows: usize,
        slow_burn: f64,
    ) -> Self {
        assert!(fast_windows >= 1, "fast rule needs at least one window");
        assert!(
            slow_windows >= fast_windows,
            "slow rule must span at least the fast rule"
        );
        assert!(
            slow_burn > 0.0 && slow_burn <= 1.0,
            "slow_burn is a fraction in (0, 1]"
        );
        self.fast_windows = fast_windows;
        self.slow_windows = slow_windows;
        self.slow_burn = slow_burn;
        self
    }

    fn breached(&self, value: f64) -> bool {
        match self.cmp {
            Cmp::Below => value > self.threshold,
            Cmp::Above => value < self.threshold,
        }
    }
}

/// One edge-triggered alert event (`recross.alerts` v1).
#[derive(Debug, Clone, PartialEq)]
pub struct Alert {
    /// Monotone sequence number within one tracker's stream.
    pub seq: u64,
    /// Tick time of the evaluating window, ns.
    pub t_ns: u64,
    pub objective: String,
    /// Signal label ([`SloSignal::label`]).
    pub signal: String,
    pub severity: Severity,
    pub state: AlertState,
    /// The signal's sample in the evaluating window.
    pub value: f64,
    pub threshold: f64,
    /// Breached fraction over the rule's window span.
    pub burn: f64,
    /// The rule's window span.
    pub windows: usize,
}

impl Alert {
    /// One `recross.alerts` v1 event as a single JSON line (no trailing
    /// newline). Non-finite floats serialize as `null`, matching the
    /// metrics snapshot exporter, so the stream is byte-deterministic.
    pub fn to_json_line(&self) -> String {
        format!(
            "{{\"schema\": \"{}\", \"version\": {}, \"seq\": {}, \"t_ns\": {}, \
             \"objective\": \"{}\", \"signal\": \"{}\", \"severity\": \"{}\", \
             \"state\": \"{}\", \"value\": {}, \"threshold\": {}, \"burn\": {}, \
             \"windows\": {}}}",
            ALERTS_SCHEMA,
            ALERTS_VERSION,
            self.seq,
            self.t_ns,
            escape(&self.objective),
            escape(&self.signal),
            self.severity.as_str(),
            self.state.as_str(),
            json_f64(self.value),
            json_f64(self.threshold),
            json_f64(self.burn),
            self.windows,
        )
    }
}

/// Rolling per-objective rule state.
#[derive(Debug)]
struct ObjectiveState {
    /// Trailing breach flags, newest last, capped at `slow_windows`.
    breaches: VecDeque<bool>,
    fast_firing: bool,
    slow_firing: bool,
}

/// Evaluates a set of [`Objective`]s window by window.
#[derive(Debug, Default)]
pub struct SloTracker {
    objectives: Vec<Objective>,
    states: Vec<ObjectiveState>,
    seq: u64,
}

impl SloTracker {
    /// Tracker with no objectives (evaluates to an empty stream).
    pub fn new() -> Self {
        Self::default()
    }

    /// Add one objective (builder style).
    pub fn with_objective(mut self, o: Objective) -> Self {
        self.states.push(ObjectiveState {
            breaches: VecDeque::with_capacity(o.slow_windows),
            fast_firing: false,
            slow_firing: false,
        });
        self.objectives.push(o);
        self
    }

    /// The default objective set from the `slo.*` config block:
    ///
    /// * `sojourn-p99` — the watch loop's per-window p99 sojourn gauge
    ///   ([`names::LOADGEN_SOJOURN_P99_NS`]) stays below
    ///   `slo.p99_sojourn_ns`;
    /// * `queue-depth` — the window mean of
    ///   [`names::BATCHER_QUEUE_DEPTH`] stays below
    ///   `slo.max_queue_depth`.
    pub fn from_config(slo: &SloConfig) -> Self {
        Self::new()
            .with_objective(
                Objective::new(
                    "sojourn-p99",
                    SloSignal::Gauge {
                        metric: names::LOADGEN_SOJOURN_P99_NS.to_string(),
                    },
                    Cmp::Below,
                    slo.p99_sojourn_ns,
                )
                .with_burn_rules(slo.fast_windows, slo.slow_windows, slo.slow_burn),
            )
            .with_objective(
                Objective::new(
                    "queue-depth",
                    SloSignal::SummaryMean {
                        metric: names::BATCHER_QUEUE_DEPTH.to_string(),
                    },
                    Cmp::Below,
                    slo.max_queue_depth,
                )
                .with_burn_rules(slo.fast_windows, slo.slow_windows, slo.slow_burn),
            )
    }

    pub fn objectives(&self) -> &[Objective] {
        &self.objectives
    }

    /// Alert events emitted so far (= next sequence number).
    pub fn emitted(&self) -> u64 {
        self.seq
    }

    /// Sample every objective against one window and return the alert
    /// events whose rules changed state, in declaration order (fast rule
    /// before slow rule per objective).
    pub fn evaluate(&mut self, w: &Window) -> Vec<Alert> {
        let mut out = Vec::new();
        for (o, st) in self.objectives.iter().zip(&mut self.states) {
            let Some(value) = o.signal.sample(w) else {
                continue; // metric absent: the window is not counted
            };
            if st.breaches.len() == o.slow_windows {
                st.breaches.pop_front();
            }
            st.breaches.push_back(o.breached(value));

            // Fast rule: the last `fast_windows` samples all breached.
            let have_fast = st.breaches.len() >= o.fast_windows;
            let fast_hits = st
                .breaches
                .iter()
                .rev()
                .take(o.fast_windows)
                .filter(|&&b| b)
                .count();
            let fast_now = have_fast && fast_hits == o.fast_windows;
            let fast_burn = fast_hits as f64 / o.fast_windows as f64;

            // Slow rule: breached fraction over the full slow span.
            let have_slow = st.breaches.len() >= o.slow_windows;
            let slow_hits = st.breaches.iter().filter(|&&b| b).count();
            let slow_burn = slow_hits as f64 / o.slow_windows as f64;
            let slow_now = have_slow && slow_burn >= o.slow_burn;

            for (rule_now, firing, severity, burn, windows) in [
                (fast_now, &mut st.fast_firing, Severity::Page, fast_burn, o.fast_windows),
                (slow_now, &mut st.slow_firing, Severity::Warn, slow_burn, o.slow_windows),
            ] {
                if rule_now == *firing {
                    continue; // no edge
                }
                *firing = rule_now;
                out.push(Alert {
                    seq: self.seq,
                    t_ns: w.t_ns,
                    objective: o.name.clone(),
                    signal: o.signal.label(),
                    severity,
                    state: if rule_now {
                        AlertState::Firing
                    } else {
                        AlertState::Resolved
                    },
                    value,
                    threshold: o.threshold,
                    burn,
                    windows,
                });
                self.seq += 1;
            }
        }
        out
    }
}

/// A [`TimeSeries`] and an [`SloTracker`] ticking together — the closed
/// signal plane `recross status --watch` and the cluster drift loop run.
#[derive(Debug)]
pub struct Watcher {
    series: TimeSeries,
    tracker: SloTracker,
}

/// Schema tag of every `--watch` JSON line.
pub const WATCH_SCHEMA: &str = "recross.watch";
/// Watch stream schema version.
pub const WATCH_VERSION: u32 = 1;

impl Watcher {
    pub fn new(ring_capacity: usize, tracker: SloTracker) -> Self {
        Self {
            series: TimeSeries::new(ring_capacity),
            tracker,
        }
    }

    /// Watcher from the `watch.*` / `slo.*` config blocks.
    pub fn from_config(watch: &WatchConfig, slo: &SloConfig) -> Self {
        Self::new(watch.ring_capacity, SloTracker::from_config(slo))
    }

    /// One tick: diff the snapshot into the rings, evaluate every
    /// objective, return the window and its (possibly empty) alerts.
    pub fn tick(&mut self, now_ns: u64, snap: &MetricsSnapshot) -> (Window, Vec<Alert>) {
        let w = self.series.tick(now_ns, snap);
        let alerts = self.tracker.evaluate(&w);
        (w, alerts)
    }

    pub fn series(&self) -> &TimeSeries {
        &self.series
    }

    pub fn tracker(&self) -> &SloTracker {
        &self.tracker
    }

    /// One `recross.watch` v1 JSON line for a tick: the full window
    /// (counter deltas/rates, gauges, windowed summary means, windowed
    /// p50/p90/p99 per histogram) plus the tick's alert events inline.
    /// Byte-deterministic: BTreeMap ordering, snapshot-exporter float
    /// rules.
    pub fn watch_line(w: &Window, alerts: &[Alert]) -> String {
        let mut out = String::with_capacity(512);
        out.push_str(&format!(
            "{{\"schema\": \"{}\", \"version\": {}, \"tick\": {}, \"t_ns\": {}, \"dt_ns\": {}",
            WATCH_SCHEMA, WATCH_VERSION, w.index, w.t_ns, w.dt_ns
        ));
        out.push_str(", \"counters\": {");
        push_join(&mut out, w.counters.iter(), |(k, c)| {
            format!(
                "\"{}\": {{\"delta\": {}, \"rate_per_sec\": {}}}",
                escape(k),
                c.delta,
                json_f64(c.rate_per_sec)
            )
        });
        out.push_str("}, \"gauges\": {");
        push_join(&mut out, w.gauges.iter(), |(k, v)| {
            format!("\"{}\": {}", escape(k), json_f64(*v))
        });
        out.push_str("}, \"summaries\": {");
        push_join(&mut out, w.summaries.iter(), |(k, s)| {
            format!(
                "\"{}\": {{\"count\": {}, \"mean\": {}}}",
                escape(k),
                s.count,
                json_f64(s.mean)
            )
        });
        out.push_str("}, \"percentiles\": {");
        push_join(
            &mut out,
            w.histograms.iter().filter(|(_, h)| h.total() > 0),
            |(k, h)| {
                format!(
                    "\"{}\": {{\"p50\": {}, \"p90\": {}, \"p99\": {}}}",
                    escape(k),
                    h.percentile(50.0),
                    h.percentile(90.0),
                    h.percentile(99.0)
                )
            },
        );
        out.push_str("}, \"alerts\": [");
        push_join(&mut out, alerts.iter(), Alert::to_json_line);
        out.push_str("]}");
        out
    }
}

fn push_join<I, T, F>(out: &mut String, items: I, render: F)
where
    I: IntoIterator<Item = T>,
    F: Fn(T) -> String,
{
    for (i, item) in items.into_iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&render(item));
    }
}

/// Finite floats print shortest-round-trip; NaN/∞ are not JSON — `null`.
fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn gauge_window(index: u64, t_ns: u64, name: &str, value: f64) -> Window {
        let mut gauges = BTreeMap::new();
        gauges.insert(name.to_string(), value);
        Window {
            index,
            t_ns,
            dt_ns: 1_000,
            counters: BTreeMap::new(),
            gauges,
            summaries: BTreeMap::new(),
            histograms: BTreeMap::new(),
        }
    }

    fn latency_objective(fast: usize, slow: usize, burn: f64) -> Objective {
        Objective::new("lat", SloSignal::Gauge { metric: "g".into() }, Cmp::Below, 100.0)
            .with_burn_rules(fast, slow, burn)
    }

    #[test]
    fn fast_rule_pages_on_the_breach_and_resolves_after() {
        let mut t = SloTracker::new().with_objective(latency_objective(1, 4, 0.75));
        // Healthy windows: silence.
        for i in 0..3 {
            assert!(t.evaluate(&gauge_window(i, i * 10, "g", 50.0)).is_empty());
        }
        // One breach: the 1-window fast rule pages immediately.
        let a = t.evaluate(&gauge_window(3, 30, "g", 250.0));
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].severity, Severity::Page);
        assert_eq!(a[0].state, AlertState::Firing);
        assert_eq!(a[0].objective, "lat");
        assert_eq!(a[0].value, 250.0);
        assert_eq!(a[0].burn, 1.0);
        // Recovery: one resolved event, then silence.
        let r = t.evaluate(&gauge_window(4, 40, "g", 50.0));
        assert_eq!(r.len(), 1);
        assert_eq!(r[0].state, AlertState::Resolved);
        assert!(t.evaluate(&gauge_window(5, 50, "g", 50.0)).is_empty());
        assert_eq!(t.emitted(), 2);
    }

    #[test]
    fn slow_rule_warns_on_sustained_burn_only() {
        // fast=2 so isolated breaches never page; slow: ≥3 of 4 warn.
        let mut t = SloTracker::new().with_objective(latency_objective(2, 4, 0.75));
        // Alternating breaches: 2 of any 4, never 2 consecutive — silent.
        for i in 0..8u64 {
            let v = if i % 2 == 0 { 250.0 } else { 50.0 };
            assert!(t.evaluate(&gauge_window(i, i * 10, "g", v)).is_empty());
        }
        // Now a sustained burn: breach 3 of the last 4.
        assert!(t.evaluate(&gauge_window(8, 80, "g", 250.0)).is_empty());
        let a = t.evaluate(&gauge_window(9, 90, "g", 250.0));
        // Two consecutive breaches trip fast(2); 3-of-4 trips slow.
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].severity, Severity::Page);
        assert_eq!(a[1].severity, Severity::Warn);
        assert_eq!(a[1].burn, 0.75);
        assert_eq!(a[1].windows, 4);
    }

    #[test]
    fn above_objectives_breach_below_the_floor() {
        let o = Objective::new(
            "tput",
            SloSignal::CounterRate { metric: "c".into() },
            Cmp::Above,
            10.0,
        );
        assert!(o.breached(5.0));
        assert!(!o.breached(10.0));
        assert!(!o.breached(50.0));
    }

    #[test]
    fn missing_metric_windows_are_not_counted() {
        let mut t = SloTracker::new().with_objective(latency_objective(1, 2, 1.0));
        // The gauge never appears: no samples, no alerts.
        for i in 0..5 {
            assert!(t.evaluate(&gauge_window(i, i * 10, "other", 1e9)).is_empty());
        }
        assert_eq!(t.emitted(), 0);
    }

    #[test]
    fn alert_stream_is_byte_deterministic() {
        let run = || {
            let mut t = SloTracker::new().with_objective(latency_objective(1, 3, 0.67));
            let mut lines = String::new();
            for i in 0..10u64 {
                let v = if (4..8).contains(&i) { 300.0 } else { 10.0 };
                for a in t.evaluate(&gauge_window(i, i * 1_000, "g", v)) {
                    lines.push_str(&a.to_json_line());
                    lines.push('\n');
                }
            }
            lines
        };
        let a = run();
        assert_eq!(a, run());
        assert!(a.contains("\"schema\": \"recross.alerts\""));
        assert!(a.contains("\"version\": 1"));
        assert!(a.contains("\"state\": \"firing\""));
        assert!(a.contains("\"state\": \"resolved\""));
    }

    #[test]
    fn non_finite_samples_serialize_as_null() {
        let a = Alert {
            seq: 0,
            t_ns: 5,
            objective: "x".into(),
            signal: "gauge(g)".into(),
            severity: Severity::Page,
            state: AlertState::Firing,
            value: f64::NAN,
            threshold: f64::INFINITY,
            burn: 1.0,
            windows: 1,
        };
        let js = a.to_json_line();
        assert!(js.contains("\"value\": null"));
        assert!(js.contains("\"threshold\": null"));
        assert!(js.contains("\"burn\": 1"));
    }

    #[test]
    fn watch_line_carries_every_family_and_inline_alerts() {
        use crate::metrics::Histogram;
        use crate::obs::timeseries::CounterWindow;
        let mut w = gauge_window(2, 2_000, "g", 1.5);
        w.counters.insert(
            "c".into(),
            CounterWindow {
                delta: 7,
                rate_per_sec: 3.5,
            },
        );
        let mut h = Histogram::new();
        h.add_n(4, 10);
        w.histograms.insert("h".into(), h);
        w.histograms.insert("empty".into(), Histogram::new());
        let alerts = vec![Alert {
            seq: 0,
            t_ns: 2_000,
            objective: "lat".into(),
            signal: "gauge(g)".into(),
            severity: Severity::Warn,
            state: AlertState::Firing,
            value: 1.5,
            threshold: 1.0,
            burn: 0.5,
            windows: 4,
        }];
        let line = Watcher::watch_line(&w, &alerts);
        assert!(line.starts_with("{\"schema\": \"recross.watch\", \"version\": 1"));
        assert!(line.contains("\"tick\": 2"));
        assert!(line.contains("\"c\": {\"delta\": 7, \"rate_per_sec\": 3.5}"));
        assert!(line.contains("\"g\": 1.5"));
        assert!(line.contains("\"h\": {\"p50\": 4, \"p90\": 4, \"p99\": 4}"));
        // Histograms empty this window are omitted, not zero-filled.
        assert!(!line.contains("\"empty\""));
        assert!(line.contains("\"alerts\": [{\"schema\": \"recross.alerts\""));
        assert!(!line.contains('\n'));
    }

    #[test]
    fn watcher_composes_series_and_tracker() {
        let mut w = Watcher::new(
            8,
            SloTracker::new().with_objective(latency_objective(1, 2, 1.0)),
        );
        let mut snap = MetricsSnapshot::new("t");
        snap.gauges.insert("g".into(), 10.0);
        let (win, alerts) = w.tick(0, &snap);
        assert_eq!(win.index, 0);
        assert!(alerts.is_empty());
        snap.gauges.insert("g".into(), 500.0);
        let (_, alerts) = w.tick(1_000, &snap);
        assert_eq!(alerts.len(), 1);
        assert_eq!(w.series().ticks(), 2);
        assert_eq!(w.tracker().emitted(), 1);
    }
}
