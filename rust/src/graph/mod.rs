//! Co-occurrence list and graph (paper §III-A steps ① and ②).
//!
//! From the embedding-lookup history, ReCross derives
//! * the **access frequency** of every embedding, and
//! * a **co-occurrence graph**: nodes are embeddings, an edge `(a, b)`
//!   weighted by how many queries accessed `a` and `b` together.
//!
//! The graph is materialised in CSR-like form (a sorted neighbor array per
//! node) after a hash-map accumulation pass, so that the grouping
//! algorithm's inner loop (`neighbors(e)`, `weight(a, b)`) is
//! allocation-free.
//!
//! Long queries would contribute O(len²) pairs (Sports averages 96
//! lookups → 4.5k pairs per query); a deterministic per-query pair cap
//! subsamples pairs of very long queries to bound build cost, which
//! preserves the heavy co-occurrence structure (hot pairs recur across
//! many queries and survive sampling).
//!
//! Sampling is seeded **per query from the query's content** (not from a
//! shared sequential stream), so a query's pair contribution is a pure
//! function of `(seed, items)`. Two consequences the delta pipeline
//! depends on: the graph is invariant under query reordering, and adding
//! then retiring a query cancels exactly — which is what lets
//! [`WindowGraph::apply_window`] maintain the graph incrementally with
//! bit-exact agreement against a batch [`CoGraph::build_capped`] over the
//! same window.

use crate::util::{par, FxHashMap, Rng};
use crate::workload::Trace;

pub mod window;

pub use window::{DeltaParams, GraphDelta, NodeDelta, WindowGraph};

/// Default cap on sampled pairs per query.
pub const DEFAULT_PAIR_CAP: usize = 1024;

/// Minimum queries per worker chunk for the parallel counting passes —
/// below this the hash-map merge costs more than the count saves.
pub(crate) const PAR_MIN_QUERIES: usize = 32;

/// Read-only affinity view shared by [`CoGraph`] (batch CSR build) and
/// [`WindowGraph`] (incrementally maintained): per-node access frequency
/// plus the sorted `(neighbor, weight)` adjacency that Algorithm 1's
/// inner loop consumes. Grouping is generic over this trait so the delta
/// path regroups straight off the incremental structure without
/// materialising a CSR first.
pub trait Affinity {
    /// Number of nodes (embedding-table rows).
    fn num_nodes(&self) -> usize;
    /// Access frequency of `v` over the trace.
    fn freq(&self, v: u32) -> u64;
    /// Neighbors of `v` as `(neighbor, weight)`, sorted by neighbor id.
    fn neighbors(&self, v: u32) -> &[(u32, u32)];

    /// Node ids sorted by descending access frequency (ties by id) —
    /// the `sorted(embeddingList)` of Algorithm 1.
    fn ids_by_frequency(&self) -> Vec<u32> {
        let mut ids: Vec<u32> = (0..self.num_nodes() as u32).collect();
        ids.sort_by_key(|&v| (std::cmp::Reverse(self.freq(v)), v));
        ids
    }
}

/// Co-occurrence graph over embeddings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CoGraph {
    /// Number of nodes (embedding-table rows).
    n: usize,
    /// CSR offsets: neighbors of node `v` are `adj[off[v]..off[v+1]]`.
    off: Vec<usize>,
    /// `(neighbor, weight)` sorted by neighbor id within each node.
    adj: Vec<(u32, u32)>,
    /// Per-embedding access frequency over the history trace.
    freq: Vec<u64>,
}

impl CoGraph {
    /// Build from a history trace with the default pair cap.
    pub fn build(trace: &Trace) -> Self {
        Self::build_capped(trace, DEFAULT_PAIR_CAP, 0x9E3779B9)
    }

    /// Build with an explicit per-query pair cap and sampling seed.
    ///
    /// Each over-cap query is subsampled by an RNG seeded from
    /// `(seed, items)` — see [`query_seed`] — so its contribution does not
    /// depend on where in the trace it sits. The result is therefore
    /// invariant under query reordering, and identical to replaying the
    /// same queries through [`WindowGraph::apply_window`].
    ///
    /// The counting pass partitions the query stream across
    /// [`par::default_workers`] workers (content-seeded sampling makes
    /// each query's contribution position-independent, so partitioning
    /// is safe) into per-worker sparse partials merged in worker order.
    /// Partials combine by integer addition, so the merged counts — and
    /// hence the whole graph — are bit-identical for any worker count.
    pub fn build_capped(trace: &Trace, pair_cap: usize, seed: u64) -> Self {
        let n = trace.num_embeddings as usize;
        // FxHash + generous pre-size: this map sees tens of millions of
        // ops on self-generated keys (§Perf iteration 1).
        let partials = par::map_ranges(
            trace.queries.len(),
            par::default_workers(),
            PAR_MIN_QUERIES,
            |_, range| {
                let mut freq = vec![0u64; n];
                let mut pairs: FxHashMap<u64, u32> = FxHashMap::default();
                pairs.reserve(range.len().saturating_mul(pair_cap / 2));
                for q in &trace.queries[range] {
                    for &it in &q.items {
                        freq[it as usize] += 1;
                    }
                    for_each_query_pair(&q.items, pair_cap, seed, |k, w| {
                        *pairs.entry(k).or_insert(0) += w;
                    });
                }
                (freq, pairs)
            },
        );
        let mut freq = vec![0u64; n];
        let mut pairs: FxHashMap<u64, u32> = FxHashMap::default();
        for (pfreq, ppairs) in partials {
            if pairs.is_empty() {
                pairs = ppairs; // adopt the first partial wholesale
            } else {
                for (k, w) in ppairs {
                    *pairs.entry(k).or_insert(0) += w;
                }
            }
            for (f, pf) in freq.iter_mut().zip(&pfreq) {
                *f += pf;
            }
        }

        // Degree count -> CSR.
        let mut deg = vec![0usize; n];
        for k in pairs.keys() {
            let (a, b) = unkey(*k);
            deg[a as usize] += 1;
            deg[b as usize] += 1;
        }
        let mut off = vec![0usize; n + 1];
        for v in 0..n {
            off[v + 1] = off[v] + deg[v];
        }
        let mut adj = vec![(0u32, 0u32); off[n]];
        let mut cursor = off[..n].to_vec();
        for (&k, &w) in &pairs {
            let (a, b) = unkey(k);
            adj[cursor[a as usize]] = (b, w);
            cursor[a as usize] += 1;
            adj[cursor[b as usize]] = (a, w);
            cursor[b as usize] += 1;
        }
        for v in 0..n {
            adj[off[v]..off[v + 1]].sort_unstable_by_key(|&(nb, _)| nb);
        }
        Self { n, off, adj, freq }
    }

    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.n
    }

    /// Number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.adj.len() / 2
    }

    /// Access frequency of an embedding over the history.
    pub fn freq(&self, v: u32) -> u64 {
        self.freq[v as usize]
    }

    /// All access frequencies.
    pub fn freqs(&self) -> &[u64] {
        &self.freq
    }

    /// Neighbors of `v` as `(neighbor, weight)`, sorted by neighbor id.
    pub fn neighbors(&self, v: u32) -> &[(u32, u32)] {
        &self.adj[self.off[v as usize]..self.off[v as usize + 1]]
    }

    /// Co-occurrence degree (number of distinct co-accessed embeddings) —
    /// the quantity of the paper's Fig. 2.
    pub fn degree(&self, v: u32) -> usize {
        self.neighbors(v).len()
    }

    /// Edge weight between `a` and `b` (0 when not adjacent).
    pub fn weight(&self, a: u32, b: u32) -> u32 {
        let ns = self.neighbors(a);
        match ns.binary_search_by_key(&b, |&(nb, _)| nb) {
            Ok(i) => ns[i].1,
            Err(_) => 0,
        }
    }

    /// Embedding ids sorted by descending access frequency (ties by id) —
    /// the `sorted(embeddingList)` of Algorithm 1.
    pub fn ids_by_frequency(&self) -> Vec<u32> {
        let mut ids: Vec<u32> = (0..self.n as u32).collect();
        ids.sort_by_key(|&v| (std::cmp::Reverse(self.freq[v as usize]), v));
        ids
    }

    /// Degrees of all nodes (Fig. 2's y-axis data).
    pub fn degrees(&self) -> Vec<u64> {
        (0..self.n as u32).map(|v| self.degree(v) as u64).collect()
    }
}

impl Affinity for CoGraph {
    fn num_nodes(&self) -> usize {
        CoGraph::num_nodes(self)
    }
    fn freq(&self, v: u32) -> u64 {
        CoGraph::freq(self, v)
    }
    fn neighbors(&self, v: u32) -> &[(u32, u32)] {
        CoGraph::neighbors(self, v)
    }
}

/// Sampling seed for one query: a SplitMix64 fold of the build seed and
/// the query's (canonically sorted) item list. Seeding per query instead
/// of drawing from one sequential stream makes each query's sampled pair
/// set a pure function of its content — the property the incremental
/// window update relies on to retire a query's contribution exactly.
/// Duplicate-content queries deliberately sample identical pairs.
#[inline]
pub(crate) fn query_seed(seed: u64, items: &[u32]) -> u64 {
    use crate::util::rng::splitmix64;
    let mut h = seed ^ 0x5851_F42D_4C95_7F2D ^ items.len() as u64;
    for &it in items {
        let mut s = h.wrapping_add(it as u64);
        h = splitmix64(&mut s);
    }
    h
}

/// Emit every `(pair key, weight)` contribution of one query: exact
/// double loop when the query has at most `pair_cap` pairs, otherwise
/// `pair_cap` content-seeded random draws each weighted by
/// `round(total_pairs / pair_cap)` so accumulated weights stay on the
/// scale of exact counting. Single source of truth for both the batch
/// CSR build and the incremental window update — their agreement is
/// bit-exact because they share this pass.
pub(crate) fn for_each_query_pair(
    items: &[u32],
    pair_cap: usize,
    seed: u64,
    mut emit: impl FnMut(u64, u32),
) {
    let len = items.len();
    if len < 2 {
        return;
    }
    let total_pairs = len * (len - 1) / 2;
    if total_pairs <= pair_cap {
        for i in 0..len {
            for j in (i + 1)..len {
                emit(key(items[i], items[j]), 1);
            }
        }
    } else {
        let w = (total_pairs as f64 / pair_cap as f64).round().max(1.0) as u32;
        let mut rng = Rng::new(query_seed(seed, items));
        for _ in 0..pair_cap {
            let i = rng.index(len);
            let mut j = rng.index(len - 1);
            if j >= i {
                j += 1;
            }
            emit(key(items[i], items[j]), w);
        }
    }
}

#[inline]
pub(crate) fn key(a: u32, b: u32) -> u64 {
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    ((lo as u64) << 32) | hi as u64
}

#[inline]
pub(crate) fn unkey(k: u64) -> (u32, u32) {
    ((k >> 32) as u32, k as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Query;

    fn trace(queries: Vec<Vec<u32>>) -> Trace {
        Trace {
            num_embeddings: 16,
            queries: queries.into_iter().map(Query::new).collect(),
        }
    }

    #[test]
    fn weights_count_co_access() {
        let g = CoGraph::build(&trace(vec![vec![0, 1, 2], vec![0, 1], vec![3]]));
        assert_eq!(g.weight(0, 1), 2);
        assert_eq!(g.weight(1, 0), 2);
        assert_eq!(g.weight(0, 2), 1);
        assert_eq!(g.weight(1, 2), 1);
        assert_eq!(g.weight(0, 3), 0);
        assert_eq!(g.num_edges(), 3);
    }

    #[test]
    fn freq_and_degree() {
        let g = CoGraph::build(&trace(vec![vec![0, 1, 2], vec![0, 1], vec![0]]));
        assert_eq!(g.freq(0), 3);
        assert_eq!(g.freq(1), 2);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(3), 0);
    }

    #[test]
    fn neighbors_sorted() {
        let g = CoGraph::build(&trace(vec![vec![5, 1, 9, 3]]));
        let ns = g.neighbors(5);
        let ids: Vec<u32> = ns.iter().map(|&(n, _)| n).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted);
        assert_eq!(ids, vec![1, 3, 9]);
    }

    #[test]
    fn ids_by_frequency_desc() {
        let g = CoGraph::build(&trace(vec![vec![2, 3], vec![2], vec![2, 3], vec![7]]));
        let ids = g.ids_by_frequency();
        assert_eq!(ids[0], 2); // freq 3
        assert_eq!(ids[1], 3); // freq 2
        assert_eq!(ids[2], 7); // freq 1
    }

    #[test]
    fn singleton_queries_add_no_edges() {
        let g = CoGraph::build(&trace(vec![vec![1], vec![2], vec![3]]));
        assert_eq!(g.num_edges(), 0);
    }

    #[test]
    fn capped_build_preserves_hot_pairs() {
        // One very long query repeated; cap forces sampling but the hot
        // pair (0,1) also appears in many short queries and must dominate.
        let mut qs = vec![(0..60).collect::<Vec<u32>>(); 4];
        for _ in 0..50 {
            qs.push(vec![0, 1]);
        }
        let t = Trace {
            num_embeddings: 64,
            queries: qs.into_iter().map(Query::new).collect(),
        };
        let g = CoGraph::build_capped(&t, 100, 1);
        assert!(g.weight(0, 1) >= 50);
        // weight(0,1) must exceed weight between two arbitrary cold items
        assert!(g.weight(0, 1) > g.weight(40, 41));
    }

    #[test]
    fn deterministic_capped_build() {
        let t = trace(vec![(0..12).collect(), (0..12).collect()]);
        let a = CoGraph::build_capped(&t, 10, 7);
        let b = CoGraph::build_capped(&t, 10, 7);
        assert_eq!(a.adj, b.adj);
    }

    #[test]
    fn pinned_seed_full_graph_reproducibility() {
        // The whole graph (offsets, adjacency, frequencies) — not just the
        // edge list — is a pure function of (trace, cap, seed).
        let t = Trace {
            num_embeddings: 64,
            queries: vec![(0..60).collect::<Vec<u32>>(), (4..40).collect(), vec![1, 2]]
                .into_iter()
                .map(Query::new)
                .collect(),
        };
        assert_eq!(
            CoGraph::build_capped(&t, 16, 7),
            CoGraph::build_capped(&t, 16, 7)
        );
        // A different sampling seed draws different pairs for the over-cap
        // queries (16 of 1770 colliding across seeds is astronomically
        // unlikely), while the exact branch and frequencies are unaffected.
        let other = CoGraph::build_capped(&t, 16, 8);
        assert_ne!(CoGraph::build_capped(&t, 16, 7).adj, other.adj);
        assert_eq!(CoGraph::build_capped(&t, 16, 7).freqs(), other.freqs());
        assert_eq!(other.weight(1, 2), 1);
    }

    #[test]
    fn capped_sampling_conserves_weight_mass() {
        // The sampling contract: an over-cap query contributes exactly
        // `pair_cap` draws, each weighted round(total/cap), so its total
        // edge mass is pinned regardless of which pairs were drawn.
        let t = Trace {
            num_embeddings: 64,
            queries: vec![Query::new((0..60).collect())],
        };
        let g = CoGraph::build_capped(&t, 100, 1);
        // total_pairs = 60*59/2 = 1770, w = round(17.7) = 18.
        let mass: u64 = (0..64u32)
            .flat_map(|v| g.neighbors(v).iter().map(|&(_, w)| w as u64))
            .sum();
        assert_eq!(mass / 2, 100 * 18);
    }

    #[test]
    fn exact_branch_ignores_seed() {
        // Queries at or below the cap are counted exactly; the seed only
        // drives the subsampler.
        let t = trace(vec![vec![0, 1, 2, 3], vec![2, 3, 4]]);
        assert_eq!(
            CoGraph::build_capped(&t, 1024, 1),
            CoGraph::build_capped(&t, 1024, 999)
        );
    }

    #[test]
    fn query_order_invariance() {
        // Per-query content seeding makes the graph invariant under trace
        // reordering even when the subsampled branch fires — the property
        // the incremental window update is built on.
        let qs: Vec<Vec<u32>> = vec![
            (0..50).collect(),
            (10..70).collect(),
            vec![1, 2, 3],
            (20..75).collect(),
            vec![7, 8],
        ];
        let fwd = Trace {
            num_embeddings: 80,
            queries: qs.iter().cloned().map(Query::new).collect(),
        };
        let rev = Trace {
            num_embeddings: 80,
            queries: qs.iter().rev().cloned().map(Query::new).collect(),
        };
        assert_eq!(
            CoGraph::build_capped(&fwd, 16, 42),
            CoGraph::build_capped(&rev, 16, 42)
        );
    }
}
