//! Incrementally maintained co-occurrence graph over a sliding trace
//! window — the O(window) front of the delta offline phase.
//!
//! [`CoGraph::build`] walks the entire history on every rebalance; at
//! millions-of-rows table sizes that makes adaptation itself the
//! bottleneck. [`WindowGraph`] keeps the same frequencies and edge
//! weights as mutable state and updates them with
//! [`WindowGraph::apply_window`]: the added queries' pair contributions
//! are accumulated, the retired queries' contributions subtracted, and
//! nothing else is touched.
//!
//! **Exactness.** Both paths share one per-query pair pass
//! ([`super::for_each_query_pair`]), whose subsampler is seeded from the
//! query's content. A query therefore contributes the same pairs whether
//! it is counted forward (batch build), incrementally added, or retired —
//! so add/retire cancel exactly and, for any add/retire sequence reaching
//! the same window, [`WindowGraph::to_cograph`] is **bit-identical** to
//! `CoGraph::build_capped` over that window. The differential fuzz in
//! `tests/offline_delta.rs` holds this identity over hundreds of drifting
//! workloads.
//!
//! The adjacency is stored per node as a sorted `(neighbor, weight)` row,
//! which is exactly the shape Algorithm 1's inner loop consumes — so
//! [`WindowGraph`] implements [`Affinity`] and the grouping delta runs
//! directly on it, never materialising a CSR.

use super::{for_each_query_pair, unkey, Affinity, CoGraph, DEFAULT_PAIR_CAP, PAR_MIN_QUERIES};
use crate::util::{par, FxHashMap};
use crate::workload::Trace;

/// Scoping thresholds deciding which net-changed nodes are *dirty*
/// (worth regrouping). A node is dirty when its absolute change
/// `|Δfreq| + Σ|Δweight|` exceeds `abs_floor` **and** exceeds
/// `rel_threshold` of its pre-update mass (frequency + incident weight
/// sum). Both gates exist: the relative one keeps hot nodes from
/// thrashing on proportionally tiny shifts, the absolute floor keeps
/// cold nodes from regrouping on single-query noise.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeltaParams {
    /// Dirty requires `change > rel_threshold * old_mass`.
    pub rel_threshold: f64,
    /// ...and `change > abs_floor`.
    pub abs_floor: u64,
}

impl Default for DeltaParams {
    fn default() -> Self {
        Self {
            rel_threshold: 0.25,
            abs_floor: 8,
        }
    }
}

impl DeltaParams {
    /// Maximal sensitivity: every net-changed node counts as dirty.
    /// (A *full* recompute is a separate, explicit API — threshold
    /// scoping can only ever see nodes the update touched.)
    pub fn sensitive() -> Self {
        Self {
            rel_threshold: 0.0,
            abs_floor: 0,
        }
    }

    /// Derive the relative threshold from a *measured* drift-degradation
    /// series (the `drift.degradation` gauge ring the watch loop keeps:
    /// activations-per-lookup EMA over its rebaselined value, 1.0 = no
    /// drift). The threshold is set to twice the series' median distance
    /// from 1.0 — twice the typical excursion, so routine wobble stays
    /// below the gate and only genuinely atypical drift dirties nodes —
    /// clamped to `[0.05, 0.5]` (never hair-trigger, never inert). The
    /// absolute floor is noise-driven, not drift-driven, and keeps its
    /// default. An empty series carries no evidence and yields the
    /// default parameters unchanged.
    pub fn from_observed(degradation: &[f64]) -> Self {
        if degradation.is_empty() {
            return Self::default();
        }
        let mut dist: Vec<f64> = degradation.iter().map(|d| (d - 1.0).abs()).collect();
        dist.sort_by(|a, b| a.partial_cmp(b).expect("degradation must be finite"));
        let median = dist[dist.len() / 2];
        Self {
            rel_threshold: (2.0 * median).clamp(0.05, 0.5),
            ..Self::default()
        }
    }
}

/// Net change recorded for one node by [`WindowGraph::apply_window`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeDelta {
    pub node: u32,
    /// |net access-frequency change| across the update.
    pub dfreq: u64,
    /// Sum of |net weight change| over the node's incident edges.
    pub dweight: u64,
    /// Pre-update mass (frequency + incident weight sum) — the
    /// denominator for relative-change scoping.
    pub old_mass: u64,
}

/// What one [`WindowGraph::apply_window`] call changed, in a form the
/// grouping delta can scope from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GraphDelta {
    /// Nodes with a non-zero net change, ascending by id.
    pub nodes: Vec<NodeDelta>,
    pub queries_added: usize,
    pub queries_retired: usize,
}

impl GraphDelta {
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Nodes whose affinity neighborhood changed enough (per `params`)
    /// to warrant re-deriving their groups.
    pub fn dirty_nodes(&self, params: &DeltaParams) -> Vec<u32> {
        self.nodes
            .iter()
            .filter(|nd| {
                let change = nd.dfreq + nd.dweight;
                change > params.abs_floor
                    && (change as f64) > params.rel_threshold * nd.old_mass as f64
            })
            .map(|nd| nd.node)
            .collect()
    }
}

/// Co-occurrence frequencies and edge weights over a sliding window,
/// maintained in O(added + retired) per update.
#[derive(Debug, Clone)]
pub struct WindowGraph {
    n: usize,
    pair_cap: usize,
    seed: u64,
    freq: Vec<u64>,
    /// Incident edge-weight sum per node (kept alongside so `old_mass`
    /// is O(1) at delta time).
    wsum: Vec<u64>,
    /// Sorted `(neighbor, weight)` row per node.
    adj: Vec<Vec<(u32, u32)>>,
    queries: usize,
}

impl WindowGraph {
    /// Empty window over a catalogue of `num_embeddings` rows, with the
    /// same default pair cap and seed as [`CoGraph::build`].
    pub fn new(num_embeddings: u32) -> Self {
        Self::with_params(num_embeddings, DEFAULT_PAIR_CAP, 0x9E3779B9)
    }

    /// Empty window with an explicit per-query pair cap and sampling seed.
    pub fn with_params(num_embeddings: u32, pair_cap: usize, seed: u64) -> Self {
        let n = num_embeddings as usize;
        Self {
            n,
            pair_cap,
            seed,
            freq: vec![0; n],
            wsum: vec![0; n],
            adj: vec![Vec::new(); n],
            queries: 0,
        }
    }

    /// Window initialised from a trace — bit-identical to
    /// `CoGraph::build(window)` when converted via [`Self::to_cograph`].
    pub fn from_trace(window: &Trace) -> Self {
        Self::from_trace_capped(window, DEFAULT_PAIR_CAP, 0x9E3779B9)
    }

    /// Window initialised from a trace with explicit cap and seed.
    pub fn from_trace_capped(window: &Trace, pair_cap: usize, seed: u64) -> Self {
        let mut g = Self::with_params(window.num_embeddings, pair_cap, seed);
        let empty = Trace {
            num_embeddings: window.num_embeddings,
            queries: Vec::new(),
        };
        g.apply_window(window, &empty);
        g
    }

    /// Number of nodes (embedding-table rows).
    pub fn num_nodes(&self) -> usize {
        self.n
    }

    /// Queries currently accounted in the window.
    pub fn num_queries(&self) -> usize {
        self.queries
    }

    /// Per-query pair cap this window samples with.
    pub fn pair_cap(&self) -> usize {
        self.pair_cap
    }

    /// Content-seeding base for the subsampler.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Slide the window: add `added`'s contributions, subtract
    /// `retired`'s. O(added + retired) work, independent of catalogue and
    /// window size. `retired` must be a sub-multiset of the queries the
    /// window currently accounts for (panics otherwise — weights would
    /// go negative).
    ///
    /// Returns the net per-node change for delta scoping.
    pub fn apply_window(&mut self, added: &Trace, retired: &Trace) -> GraphDelta {
        assert_eq!(
            added.num_embeddings as usize, self.n,
            "added trace catalogue does not match the window"
        );
        assert_eq!(
            retired.num_embeddings as usize, self.n,
            "retired trace catalogue does not match the window"
        );
        assert!(
            retired.queries.len() <= self.queries,
            "retiring {} queries from a window of {}",
            retired.queries.len(),
            self.queries
        );

        // Signed net deltas first: a query added and retired in the same
        // call cancels here and touches nothing below. The counting
        // fans out across `par::default_workers` (content-seeded
        // sampling makes contributions position-independent); partials
        // merge by signed integer addition in worker order, so the net
        // deltas are bit-identical for any worker count.
        let (pair_cap, seed) = (self.pair_cap, self.seed);
        let mut dfreq: FxHashMap<u32, i64> = FxHashMap::default();
        let mut dpair: FxHashMap<u64, i64> = FxHashMap::default();
        for (trace, sign) in [(added, 1i64), (retired, -1i64)] {
            let partials = par::map_ranges(
                trace.queries.len(),
                par::default_workers(),
                PAR_MIN_QUERIES,
                |_, range| {
                    let mut pfreq: FxHashMap<u32, i64> = FxHashMap::default();
                    let mut ppair: FxHashMap<u64, i64> = FxHashMap::default();
                    for q in &trace.queries[range] {
                        for &it in &q.items {
                            *pfreq.entry(it).or_insert(0) += sign;
                        }
                        for_each_query_pair(&q.items, pair_cap, seed, |k, w| {
                            *ppair.entry(k).or_insert(0) += sign * w as i64;
                        });
                    }
                    (pfreq, ppair)
                },
            );
            for (pfreq, ppair) in partials {
                for (v, d) in pfreq {
                    *dfreq.entry(v).or_insert(0) += d;
                }
                for (k, d) in ppair {
                    *dpair.entry(k).or_insert(0) += d;
                }
            }
        }

        // Per-node change magnitudes + pre-update mass, before mutating.
        let mut acc: FxHashMap<u32, (u64, u64)> = FxHashMap::default();
        for (&v, &d) in &dfreq {
            if d != 0 {
                acc.entry(v).or_insert((0, 0)).0 = d.unsigned_abs();
            }
        }
        for (&k, &d) in &dpair {
            if d != 0 {
                let (a, b) = unkey(k);
                acc.entry(a).or_insert((0, 0)).1 += d.unsigned_abs();
                acc.entry(b).or_insert((0, 0)).1 += d.unsigned_abs();
            }
        }
        let mut nodes: Vec<NodeDelta> = acc
            .iter()
            .map(|(&v, &(df, dw))| NodeDelta {
                node: v,
                dfreq: df,
                dweight: dw,
                old_mass: self.freq[v as usize] + self.wsum[v as usize],
            })
            .collect();
        nodes.sort_unstable_by_key(|nd| nd.node);

        // Apply.
        for (&v, &d) in &dfreq {
            let next = self.freq[v as usize] as i64 + d;
            assert!(
                next >= 0,
                "retired trace is not a sub-multiset of the window (freq of {v} would go negative)"
            );
            self.freq[v as usize] = next as u64;
        }
        for (&k, &d) in &dpair {
            if d != 0 {
                self.edge_apply(k, d);
            }
        }
        self.queries = self.queries + added.queries.len() - retired.queries.len();

        GraphDelta {
            nodes,
            queries_added: added.queries.len(),
            queries_retired: retired.queries.len(),
        }
    }

    fn edge_apply(&mut self, k: u64, d: i64) {
        let (a, b) = unkey(k);
        let row = &self.adj[a as usize];
        let cur = match row.binary_search_by_key(&b, |&(nb, _)| nb) {
            Ok(i) => row[i].1 as i64,
            Err(_) => 0,
        };
        let next = cur + d;
        assert!(
            next >= 0,
            "retired trace is not a sub-multiset of the window (edge ({a},{b}) would go negative)"
        );
        let next = next as u32;
        Self::set_weight(&mut self.adj[a as usize], b, next);
        Self::set_weight(&mut self.adj[b as usize], a, next);
        self.wsum[a as usize] = (self.wsum[a as usize] as i64 + d) as u64;
        self.wsum[b as usize] = (self.wsum[b as usize] as i64 + d) as u64;
    }

    /// Set, insert, or (on zero) remove one entry of a sorted row.
    fn set_weight(row: &mut Vec<(u32, u32)>, nb: u32, w: u32) {
        match row.binary_search_by_key(&nb, |&(x, _)| x) {
            Ok(i) => {
                if w == 0 {
                    row.remove(i);
                } else {
                    row[i].1 = w;
                }
            }
            Err(i) => {
                if w > 0 {
                    row.insert(i, (nb, w));
                }
            }
        }
    }

    /// Number of undirected edges currently in the window.
    pub fn num_edges(&self) -> usize {
        self.adj.iter().map(Vec::len).sum::<usize>() / 2
    }

    /// Edge weight between `a` and `b` (0 when not adjacent).
    pub fn weight(&self, a: u32, b: u32) -> u32 {
        let row = &self.adj[a as usize];
        match row.binary_search_by_key(&b, |&(nb, _)| nb) {
            Ok(i) => row[i].1,
            Err(_) => 0,
        }
    }

    /// Incident edge-weight sum of `v`.
    pub fn weight_sum(&self, v: u32) -> u64 {
        self.wsum[v as usize]
    }

    /// Materialise the window as a batch [`CoGraph`] — bit-identical to
    /// `CoGraph::build_capped` over the same window contents, which is
    /// what the differential fuzz pins. Used by the full-recompute oracle
    /// path; the incremental path groups off [`Affinity`] directly.
    pub fn to_cograph(&self) -> CoGraph {
        let mut off = vec![0usize; self.n + 1];
        for v in 0..self.n {
            off[v + 1] = off[v] + self.adj[v].len();
        }
        let mut adj = Vec::with_capacity(off[self.n]);
        for row in &self.adj {
            adj.extend_from_slice(row);
        }
        CoGraph {
            n: self.n,
            off,
            adj,
            freq: self.freq.clone(),
        }
    }
}

impl Affinity for WindowGraph {
    fn num_nodes(&self) -> usize {
        self.n
    }
    fn freq(&self, v: u32) -> u64 {
        self.freq[v as usize]
    }
    fn neighbors(&self, v: u32) -> &[(u32, u32)] {
        &self.adj[v as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Query;

    fn trace(n: u32, queries: Vec<Vec<u32>>) -> Trace {
        Trace {
            num_embeddings: n,
            queries: queries.into_iter().map(Query::new).collect(),
        }
    }

    /// Mixed-length workload: short exact queries plus over-cap sampled
    /// ones (cap 16 below), deterministically derived from `salt`.
    fn wave(n: u32, salt: u64, count: usize) -> Trace {
        let mut rng = crate::util::Rng::new(salt);
        let queries = (0..count)
            .map(|_| {
                let len = 2 + rng.index(30);
                (0..len).map(|_| rng.index(n as usize) as u32).collect()
            })
            .collect();
        trace(n, queries)
    }

    fn concat(a: &Trace, b: &Trace) -> Trace {
        let mut queries = a.queries.clone();
        queries.extend(b.queries.iter().cloned());
        Trace {
            num_embeddings: a.num_embeddings,
            queries,
        }
    }

    #[test]
    fn from_observed_scales_with_measured_drift() {
        // No evidence: defaults untouched.
        assert_eq!(DeltaParams::from_observed(&[]), DeltaParams::default());
        // Quiet pool (degradation hugs 1.0): clamped to the floor, well
        // below the default 0.25 — rebalances scope tighter.
        let quiet = DeltaParams::from_observed(&[1.0, 1.01, 0.99, 1.02, 1.0]);
        assert_eq!(quiet.rel_threshold, 0.05);
        // Typical excursion 0.1 → threshold 2x = 0.2.
        let moving = DeltaParams::from_observed(&[1.1, 0.9, 1.1, 1.12, 0.88]);
        assert!((moving.rel_threshold - 0.2).abs() < 1e-2);
        // Violent drift: capped at 0.5, never inert.
        let wild = DeltaParams::from_observed(&[2.0, 3.0, 0.2]);
        assert_eq!(wild.rel_threshold, 0.5);
        // The absolute floor is noise-driven and never moves.
        assert_eq!(wild.abs_floor, DeltaParams::default().abs_floor);
    }

    #[test]
    fn from_trace_matches_batch_build() {
        let t = wave(48, 1, 40);
        assert_eq!(
            WindowGraph::from_trace_capped(&t, 16, 7).to_cograph(),
            CoGraph::build_capped(&t, 16, 7)
        );
    }

    #[test]
    fn incremental_slide_matches_batch_build() {
        // Slide through three waves with a 2-wave window; after each
        // slide the incremental state must equal the batch build over
        // exactly the live window.
        let waves: Vec<Trace> = (0..4).map(|i| wave(48, 100 + i, 25)).collect();
        let mut g = WindowGraph::from_trace_capped(&concat(&waves[0], &waves[1]), 16, 7);
        for i in 2..4 {
            g.apply_window(&waves[i], &waves[i - 2]);
            let live = concat(&waves[i - 1], &waves[i]);
            assert_eq!(g.to_cograph(), CoGraph::build_capped(&live, 16, 7), "wave {i}");
            assert_eq!(g.num_queries(), live.queries.len());
        }
    }

    #[test]
    fn retire_everything_empties_the_window() {
        let t = wave(32, 5, 20);
        let mut g = WindowGraph::from_trace_capped(&t, 16, 7);
        let empty = trace(32, vec![]);
        g.apply_window(&empty, &t);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.num_queries(), 0);
        assert_eq!(g.to_cograph(), CoGraph::build_capped(&empty, 16, 7));
    }

    #[test]
    fn delta_reports_net_change_and_old_mass() {
        let mut g = WindowGraph::from_trace_capped(&trace(8, vec![vec![0, 1], vec![0, 1]]), 16, 7);
        // Old mass of node 0: freq 2 + incident weight 2.
        let d = g.apply_window(&trace(8, vec![vec![0, 2]]), &trace(8, vec![vec![0, 1]]));
        let n0 = d.nodes.iter().find(|nd| nd.node == 0).unwrap();
        assert_eq!(n0.old_mass, 4);
        assert_eq!(n0.dfreq, 0); // -1 retired +1 added: net zero
        assert_eq!(n0.dweight, 2); // edge (0,1) -1, edge (0,2) +1
        assert_eq!(g.weight(0, 1), 1);
        assert_eq!(g.weight(0, 2), 1);
        // Node ids come out ascending.
        let ids: Vec<u32> = d.nodes.iter().map(|nd| nd.node).collect();
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(ids, sorted);
    }

    #[test]
    fn dirty_nodes_respect_thresholds() {
        let base: Vec<Vec<u32>> = (0..20).map(|_| vec![0, 1]).collect();
        let mut g = WindowGraph::from_trace_capped(&trace(8, base), 16, 7);
        // One query touching (2,3) is a big relative change for cold
        // nodes but below any reasonable absolute floor.
        let d = g.apply_window(&trace(8, vec![vec![2, 3]]), &trace(8, vec![]));
        assert!(d.dirty_nodes(&DeltaParams::default()).is_empty());
        assert_eq!(d.dirty_nodes(&DeltaParams::sensitive()), vec![2, 3]);
        // Hot nodes need a proportionally large change: 3 more (0,1)
        // queries is under 25% of mass 40, 30 more is far over.
        let d = g.apply_window(&trace(8, (0..3).map(|_| vec![0, 1]).collect()), &trace(8, vec![]));
        assert!(d.dirty_nodes(&DeltaParams::default()).is_empty());
        let d = g.apply_window(&trace(8, (0..30).map(|_| vec![0, 1]).collect()), &trace(8, vec![]));
        assert_eq!(d.dirty_nodes(&DeltaParams::default()), vec![0, 1]);
    }

    #[test]
    #[should_panic(expected = "sub-multiset")]
    fn retiring_a_query_never_added_panics() {
        let mut g = WindowGraph::from_trace_capped(&trace(8, vec![vec![0, 1]]), 16, 7);
        g.apply_window(&trace(8, vec![]), &trace(8, vec![vec![2, 3]]));
    }
}
