//! Deterministic data-parallel substrate: scoped fork-join with
//! **fixed-order merge**.
//!
//! Every parallel pass in the offline phase follows the same shape:
//! partition an index range into contiguous chunks, compute an
//! order-independent partial per chunk on its own thread, then merge the
//! partials **in chunk order** on the calling thread. Because
//!
//! 1. chunk boundaries depend only on `(len, workers, min_chunk)` — never
//!    on thread scheduling — and
//! 2. partials are merged in chunk order, not completion order,
//!
//! the result is a pure function of the inputs and the worker count, and
//! every caller in this crate additionally arranges its partials to be
//! *merge-order independent* (integer sums, disjoint index sets), making
//! the result bit-identical for **any** worker count including 1. That
//! stronger per-call-site property is what `tests/offline_delta.rs`
//! fuzzes across worker counts {1, 2, 8}.
//!
//! The substrate is intentionally tiny: no pool, no work stealing, no
//! channels. [`map_ranges`] spawns scoped threads (`std::thread::scope`)
//! for all chunks but the first, computes the first chunk on the calling
//! thread, and joins in spawn order. A single-chunk split (short input,
//! `workers == 1`) runs entirely inline — the serial path *is* the
//! parallel path at width 1, so there is no second implementation to
//! drift.
//!
//! The process-wide default worker count is a plain atomic
//! ([`set_default_workers`] / [`default_workers`]), threaded from
//! `offline.workers` config (0 = one worker per available core). Races
//! on the setting are benign by construction: any in-flight pass
//! observes *some* valid width, and all widths produce bit-identical
//! results.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide default worker count; 0 = resolve from
/// `available_parallelism` at use time.
static DEFAULT_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// Set the process-wide default worker count for offline-phase parallel
/// passes. `0` means "one worker per available core" (resolved lazily by
/// [`default_workers`]). Threaded from `offline.workers` config by the
/// deployment builder and `PreparedEngine::prepare`.
pub fn set_default_workers(n: usize) {
    DEFAULT_WORKERS.store(n, Ordering::Relaxed);
}

/// The effective default worker count: the configured value, or (when
/// configured as 0) the machine's available parallelism. Always ≥ 1.
pub fn default_workers() -> usize {
    match DEFAULT_WORKERS.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        n => n,
    }
}

/// Split `0..len` into at most `workers` contiguous chunks of at least
/// `min_chunk` elements each (except possibly when `len < min_chunk`,
/// which yields one short chunk). Deterministic in the arguments: the
/// first `len % k` chunks get one extra element.
pub fn chunk_ranges(len: usize, workers: usize, min_chunk: usize) -> Vec<Range<usize>> {
    if len == 0 {
        return Vec::new();
    }
    let workers = workers.max(1);
    let grain_cap = len.div_ceil(min_chunk.max(1));
    let k = workers.min(grain_cap).max(1);
    let base = len / k;
    let extra = len % k;
    let mut out = Vec::with_capacity(k);
    let mut start = 0;
    for i in 0..k {
        let size = base + usize::from(i < extra);
        out.push(start..start + size);
        start += size;
    }
    debug_assert_eq!(start, len);
    out
}

/// Fork-join map over the chunks of `0..len`: runs `f(chunk_index,
/// range)` for each chunk of [`chunk_ranges`] and returns the results
/// **in chunk order** regardless of completion order. A single-chunk
/// split runs inline with no thread spawned, so `workers == 1` is the
/// plain serial loop.
///
/// Panics in a worker propagate to the caller (matching what the same
/// code running inline would do).
pub fn map_ranges<R, F>(len: usize, workers: usize, min_chunk: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, Range<usize>) -> R + Sync,
{
    let chunks = chunk_ranges(len, workers, min_chunk);
    if chunks.len() <= 1 {
        return chunks.into_iter().map(|r| f(0, r)).collect();
    }
    std::thread::scope(|s| {
        let f = &f;
        let mut iter = chunks.into_iter();
        let first = iter.next().expect("chunk_ranges returned >= 2 chunks");
        let handles: Vec<_> = iter
            .enumerate()
            .map(|(i, r)| s.spawn(move || f(i + 1, r)))
            .collect();
        let mut out = Vec::with_capacity(handles.len() + 1);
        out.push(f(0, first));
        for h in handles {
            out.push(h.join().expect("offline-phase worker panicked"));
        }
        out
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_the_range_exactly() {
        for len in [0usize, 1, 7, 31, 32, 33, 100, 257] {
            for workers in [1usize, 2, 3, 8, 64] {
                for min_chunk in [1usize, 16, 32] {
                    let chunks = chunk_ranges(len, workers, min_chunk);
                    let total: usize = chunks.iter().map(|r| r.len()).sum();
                    assert_eq!(total, len, "len {len} workers {workers}");
                    let mut next = 0;
                    for r in &chunks {
                        assert_eq!(r.start, next, "chunks not contiguous");
                        assert!(!r.is_empty(), "empty chunk at len {len}");
                        next = r.end;
                    }
                    assert!(chunks.len() <= workers.max(1));
                }
            }
        }
    }

    #[test]
    fn min_chunk_bounds_the_split() {
        // 100 elements at grain 32 can sustain at most ceil(100/32) = 4
        // chunks no matter how many workers are offered.
        assert_eq!(chunk_ranges(100, 64, 32).len(), 4);
        // A short input still yields one (short) chunk.
        assert_eq!(chunk_ranges(5, 8, 32), vec![0..5]);
    }

    #[test]
    fn map_ranges_returns_in_chunk_order() {
        for workers in [1usize, 2, 3, 8] {
            let parts = map_ranges(100, workers, 1, |i, r| (i, r.start, r.end));
            for (k, &(i, _, _)) in parts.iter().enumerate() {
                assert_eq!(i, k, "results out of chunk order");
            }
            let sum: usize = parts.iter().map(|&(_, s, e)| e - s).sum();
            assert_eq!(sum, 100);
        }
    }

    #[test]
    fn partial_sums_match_any_worker_count() {
        let data: Vec<u64> = (0..1000).map(|i| i * 7 + 3).collect();
        let serial: u64 = data.iter().sum();
        for workers in [1usize, 2, 5, 8, 16] {
            let total: u64 = map_ranges(data.len(), workers, 1, |_, r| {
                data[r].iter().sum::<u64>()
            })
            .into_iter()
            .sum();
            assert_eq!(total, serial, "workers {workers}");
        }
    }

    #[test]
    fn default_workers_is_positive() {
        // The global is shared with every other test in this binary
        // (`PreparedEngine::prepare` resets it from config), so only the
        // race-free invariant is asserted: whatever is configured, the
        // resolved width is at least 1.
        set_default_workers(0);
        assert!(default_workers() >= 1);
        set_default_workers(3);
        assert!(default_workers() >= 1);
        set_default_workers(0);
    }

    #[test]
    fn empty_input_spawns_nothing() {
        let parts: Vec<u32> = map_ranges(0, 8, 1, |_, _| unreachable!());
        assert!(parts.is_empty());
    }
}
