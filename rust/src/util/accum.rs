//! Blocked numeric accumulators for the f32 reduce hot paths.
//!
//! The serving-side reductions ([`crate::cluster::ShardStore::reduce_into`],
//! [`crate::coordinator::EmbeddingStore::reduce_reference`]) sum embedding
//! rows element-wise into a `dim`-long accumulator. A naive `zip` loop
//! carries a loop-dependent bounds check and gives the compiler one add
//! chain; the tiles are already laid out contiguously (`[R, D]`
//! row-major), so the data is ILP-friendly — the loop just has to say so.
//! [`add_assign_4wide`] processes four independent lanes per iteration
//! via `chunks_exact`, which the compiler turns into branch-free
//! vector/multiple-issue code.
//!
//! Each output element still accumulates its inputs in exactly the same
//! order as the scalar loop (blocking is across the *dim* axis, never
//! across summands), so results are bit-identical — the same contract the
//! scheduler rewrite holds itself to.

/// Element-wise `out[i] += src[i]` over the common prefix of the two
/// slices (callers pass equal lengths; the `zip`-like truncation matches
/// the scalar loop this replaces). Four independent lanes per iteration.
#[inline]
pub fn add_assign_4wide(out: &mut [f32], src: &[f32]) {
    let n = out.len().min(src.len());
    let (out, src) = (&mut out[..n], &src[..n]);
    let mut o4 = out.chunks_exact_mut(4);
    let mut s4 = src.chunks_exact(4);
    for (o, s) in (&mut o4).zip(&mut s4) {
        o[0] += s[0];
        o[1] += s[1];
        o[2] += s[2];
        o[3] += s[3];
    }
    for (o, &s) in o4.into_remainder().iter_mut().zip(s4.remainder()) {
        *o += s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn scalar(out: &mut [f32], src: &[f32]) {
        for (o, &s) in out.iter_mut().zip(src) {
            *o += s;
        }
    }

    #[test]
    fn matches_scalar_loop_bit_for_bit() {
        let mut rng = Rng::new(5);
        for dim in 0..33 {
            let src: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let mut a: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let mut b = a.clone();
            add_assign_4wide(&mut a, &src);
            scalar(&mut b, &src);
            assert_eq!(a, b, "dim {dim}");
        }
    }

    #[test]
    fn repeated_accumulation_stays_exact() {
        // Order of summands per element is unchanged, so even a float-
        // unfriendly sequence accumulates identically.
        let rows: Vec<Vec<f32>> = vec![
            vec![1e8, 1.0, -1e8, 0.5, 3.0, -0.25, 7.0],
            vec![-1e8, 2.0, 1e8, 0.25, -3.0, 0.125, 0.0],
            vec![1.5, -2.0, 42.0, -0.5, 0.0, 1.0, -7.0],
        ];
        let mut a = vec![0.0f32; 7];
        let mut b = vec![0.0f32; 7];
        for r in &rows {
            add_assign_4wide(&mut a, r);
            scalar(&mut b, r);
        }
        assert_eq!(a, b);
    }

    #[test]
    fn truncates_to_common_prefix() {
        let mut out = vec![1.0f32; 6];
        add_assign_4wide(&mut out, &[1.0, 1.0, 1.0]);
        assert_eq!(out, vec![2.0, 2.0, 2.0, 1.0, 1.0, 1.0]);
    }
}
