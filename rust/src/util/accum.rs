//! Blocked/SIMD numeric accumulators for the f32 reduce hot paths.
//!
//! The serving-side reductions ([`crate::cluster::ShardStore::reduce_into`],
//! [`crate::coordinator::EmbeddingStore::reduce_reference`]) sum embedding
//! rows element-wise into a `dim`-long accumulator. The tiles are laid out
//! contiguously (`[R, D]` row-major), so the inner loop is pure
//! memory-bandwidth-bound streaming — exactly the shape that rewards wide
//! lanes. [`add_assign_4wide`] is the one entry point; on `x86_64` it
//! dispatches to explicit `std::arch` SIMD:
//!
//! | path   | lanes | gate |
//! |--------|-------|------|
//! | AVX2   | 8×f32 | `is_x86_feature_detected!("avx2")`, cached once |
//! | SSE2   | 4×f32 | baseline — part of the `x86_64` ABI, no check |
//! | scalar | 4-wide blocked | every other architecture |
//!
//! Each output element still accumulates its inputs in exactly the same
//! order as the scalar loop: blocking/vectorizing is across the *dim*
//! axis only, never across summands, and element-wise `+` involves no
//! reassociation — `_mm_add_ps(a, b)[i]` is IEEE-identical to
//! `a[i] + b[i]`. Results are therefore **bit-identical** across all
//! three paths (pinned by the property test below over every dim
//! 0..=67 and several row counts), the same contract the scheduler
//! rewrite holds itself to.

/// Element-wise `out[i] += src[i]` over the common prefix of the two
/// slices (callers pass equal lengths; the `zip`-like truncation matches
/// the scalar loop this replaces). Dispatches to the widest SIMD path
/// the CPU supports; bit-identical on every path.
#[inline]
pub fn add_assign_4wide(out: &mut [f32], src: &[f32]) {
    let n = out.len().min(src.len());
    let (out, src) = (&mut out[..n], &src[..n]);
    #[cfg(target_arch = "x86_64")]
    {
        if avx2_available() {
            // SAFETY: gated on runtime AVX2 detection.
            unsafe { add_assign_avx2(out, src) };
        } else {
            // SAFETY: SSE2 is baseline x86_64 — always present.
            unsafe { add_assign_sse2(out, src) };
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    add_assign_blocked(out, src);
}

/// The portable blocked path: four independent lanes per iteration via
/// `chunks_exact`, which the compiler turns into branch-free
/// vector/multiple-issue code. Non-x86 fallback and the test oracle the
/// SIMD paths are pinned against.
#[cfg_attr(target_arch = "x86_64", allow(dead_code))]
#[inline]
fn add_assign_blocked(out: &mut [f32], src: &[f32]) {
    let n = out.len().min(src.len());
    let (out, src) = (&mut out[..n], &src[..n]);
    let mut o4 = out.chunks_exact_mut(4);
    let mut s4 = src.chunks_exact(4);
    for (o, s) in (&mut o4).zip(&mut s4) {
        o[0] += s[0];
        o[1] += s[1];
        o[2] += s[2];
        o[3] += s[3];
    }
    for (o, &s) in o4.into_remainder().iter_mut().zip(s4.remainder()) {
        *o += s;
    }
}

/// AVX2 availability, detected once and cached (the dispatch sits on a
/// per-reduction hot path; `is_x86_feature_detected!` itself consults an
/// atomic but we keep the probe in one place).
#[cfg(target_arch = "x86_64")]
#[inline]
fn avx2_available() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    // 0 = unknown, 1 = no, 2 = yes.
    static AVX2: AtomicU8 = AtomicU8::new(0);
    match AVX2.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => {
            let yes = std::is_x86_feature_detected!("avx2");
            AVX2.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
            yes
        }
    }
}

/// SSE2 path: 4×f32 per iteration with unaligned loads/stores.
///
/// Safety: SSE2 is part of the x86_64 baseline ABI, so this is sound to
/// call on any x86_64 CPU; `unsafe` only covers the raw-pointer
/// loads/stores, whose bounds the `chunks_exact` split guarantees.
#[cfg(target_arch = "x86_64")]
#[inline]
unsafe fn add_assign_sse2(out: &mut [f32], src: &[f32]) {
    use std::arch::x86_64::{_mm_add_ps, _mm_loadu_ps, _mm_storeu_ps};
    debug_assert_eq!(out.len(), src.len());
    let mut o4 = out.chunks_exact_mut(4);
    let mut s4 = src.chunks_exact(4);
    for (o, s) in (&mut o4).zip(&mut s4) {
        let sum = _mm_add_ps(_mm_loadu_ps(o.as_ptr()), _mm_loadu_ps(s.as_ptr()));
        _mm_storeu_ps(o.as_mut_ptr(), sum);
    }
    for (o, &s) in o4.into_remainder().iter_mut().zip(s4.remainder()) {
        *o += s;
    }
}

/// AVX2 path: 8×f32 per iteration; the ≤7-element tail falls through to
/// the scalar loop (same per-element order, so still bit-identical).
///
/// Safety: caller must have verified AVX2 support ([`avx2_available`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn add_assign_avx2(out: &mut [f32], src: &[f32]) {
    use std::arch::x86_64::{_mm256_add_ps, _mm256_loadu_ps, _mm256_storeu_ps};
    debug_assert_eq!(out.len(), src.len());
    let mut o8 = out.chunks_exact_mut(8);
    let mut s8 = src.chunks_exact(8);
    for (o, s) in (&mut o8).zip(&mut s8) {
        let sum = _mm256_add_ps(_mm256_loadu_ps(o.as_ptr()), _mm256_loadu_ps(s.as_ptr()));
        _mm256_storeu_ps(o.as_mut_ptr(), sum);
    }
    for (o, &s) in o8.into_remainder().iter_mut().zip(s8.remainder()) {
        *o += s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn scalar(out: &mut [f32], src: &[f32]) {
        for (o, &s) in out.iter_mut().zip(src) {
            *o += s;
        }
    }

    #[test]
    fn matches_scalar_loop_bit_for_bit() {
        let mut rng = Rng::new(5);
        for dim in 0..33 {
            let src: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let mut a: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let mut b = a.clone();
            add_assign_4wide(&mut a, &src);
            scalar(&mut b, &src);
            assert_eq!(a, b, "dim {dim}");
        }
    }

    /// The satellite property test: every dim 0..=67 × row count
    /// {1, 2, 7, 64}, asserting the dispatching entry point AND each
    /// individual path (blocked, SSE2, AVX2 when present) accumulate
    /// bit-exactly like the naive scalar loop — including the
    /// remainder-lane tail (67 = 8·8 + 3 exercises both the 8-wide and
    /// 4-wide tails).
    #[test]
    fn all_paths_match_naive_scalar_for_every_dim_and_row_count() {
        let mut rng = Rng::new(0xACC);
        for dim in 0..=67usize {
            for rows in [1usize, 2, 7, 64] {
                let table: Vec<Vec<f32>> = (0..rows)
                    .map(|_| (0..dim).map(|_| rng.normal() as f32 * 1e4).collect())
                    .collect();
                let init: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();

                let mut oracle = init.clone();
                for r in &table {
                    scalar(&mut oracle, r);
                }

                let mut via_entry = init.clone();
                let mut via_blocked = init.clone();
                for r in &table {
                    add_assign_4wide(&mut via_entry, r);
                    add_assign_blocked(&mut via_blocked, r);
                }
                assert_eq!(via_entry, oracle, "dispatch: dim {dim} rows {rows}");
                assert_eq!(via_blocked, oracle, "blocked: dim {dim} rows {rows}");

                #[cfg(target_arch = "x86_64")]
                {
                    let mut via_sse2 = init.clone();
                    for r in &table {
                        // SAFETY: SSE2 is baseline x86_64.
                        unsafe { add_assign_sse2(&mut via_sse2, r) };
                    }
                    assert_eq!(via_sse2, oracle, "sse2: dim {dim} rows {rows}");
                    if avx2_available() {
                        let mut via_avx2 = init.clone();
                        for r in &table {
                            // SAFETY: gated on runtime AVX2 detection.
                            unsafe { add_assign_avx2(&mut via_avx2, r) };
                        }
                        assert_eq!(via_avx2, oracle, "avx2: dim {dim} rows {rows}");
                    }
                }
            }
        }
    }

    #[test]
    fn repeated_accumulation_stays_exact() {
        // Order of summands per element is unchanged, so even a float-
        // unfriendly sequence accumulates identically.
        let rows: Vec<Vec<f32>> = vec![
            vec![1e8, 1.0, -1e8, 0.5, 3.0, -0.25, 7.0],
            vec![-1e8, 2.0, 1e8, 0.25, -3.0, 0.125, 0.0],
            vec![1.5, -2.0, 42.0, -0.5, 0.0, 1.0, -7.0],
        ];
        let mut a = vec![0.0f32; 7];
        let mut b = vec![0.0f32; 7];
        for r in &rows {
            add_assign_4wide(&mut a, r);
            scalar(&mut b, r);
        }
        assert_eq!(a, b);
    }

    #[test]
    fn truncates_to_common_prefix() {
        let mut out = vec![1.0f32; 6];
        add_assign_4wide(&mut out, &[1.0, 1.0, 1.0]);
        assert_eq!(out, vec![2.0, 2.0, 2.0, 1.0, 1.0, 1.0]);
    }
}
