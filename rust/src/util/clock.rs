//! Injected time sources.
//!
//! Everything time-dependent on the serving path (the dynamic batcher's
//! `max_wait` deadline, the open-loop load generator's arrival schedule)
//! speaks one vocabulary: **nanoseconds since an epoch** as a `u64`. A
//! [`Clock`] supplies "now" in that vocabulary; the live executor threads
//! inject a [`WallClock`] (monotonic, anchored at thread start) while
//! tests and the simulated-time driver inject a [`SimClock`] they advance
//! by hand — the same policy code runs bit-reproducibly in both worlds.

use std::cell::Cell;
use std::time::Instant;

/// A source of "now", in nanoseconds since the clock's epoch.
///
/// Implementations must be monotone non-decreasing: consumers (the
/// batcher, the open-loop driver) assume time never runs backwards.
pub trait Clock {
    fn now_ns(&self) -> u64;
}

/// Monotonic wall clock anchored at construction time.
#[derive(Debug, Clone)]
pub struct WallClock {
    origin: Instant,
}

impl WallClock {
    pub fn new() -> Self {
        Self {
            origin: Instant::now(),
        }
    }

    /// Convert an `Instant` into this clock's nanosecond timeline
    /// (saturating to 0 for instants before the origin).
    pub fn instant_ns(&self, at: Instant) -> u64 {
        at.duration_since(self.origin).as_nanos() as u64
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }
}

/// A manually advanced clock for simulations and deterministic tests.
///
/// Interior mutability (`Cell`) lets a driver hold `&SimClock` alongside
/// other borrows while stepping time forward; the type is intentionally
/// `!Sync` — simulated time belongs to exactly one thread.
#[derive(Debug, Default)]
pub struct SimClock {
    now: Cell<u64>,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Starting at `t0` ns.
    pub fn at(t0: u64) -> Self {
        Self { now: Cell::new(t0) }
    }

    /// Jump to an absolute time. Panics if `t` would move time backwards.
    pub fn set(&self, t: u64) {
        assert!(
            t >= self.now.get(),
            "SimClock::set({t}) would rewind past {}",
            self.now.get()
        );
        self.now.set(t);
    }

    /// Step forward by `dt` ns.
    pub fn advance(&self, dt: u64) {
        self.now.set(self.now.get().saturating_add(dt));
    }
}

impl Clock for SimClock {
    fn now_ns(&self) -> u64 {
        self.now.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_starts_at_zero_and_advances() {
        let c = SimClock::new();
        assert_eq!(c.now_ns(), 0);
        c.advance(250);
        assert_eq!(c.now_ns(), 250);
        c.set(1_000);
        assert_eq!(c.now_ns(), 1_000);
    }

    #[test]
    #[should_panic(expected = "rewind")]
    fn sim_clock_refuses_to_rewind() {
        let c = SimClock::at(500);
        c.set(100);
    }

    #[test]
    fn wall_clock_is_monotone() {
        let c = WallClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn wall_clock_maps_instants_onto_its_timeline() {
        let c = WallClock::new();
        let t = Instant::now();
        let ns = c.instant_ns(t);
        // `t` was taken after the origin, so it maps at or after 0 and
        // no later than "now".
        assert!(ns <= c.now_ns());
    }
}
