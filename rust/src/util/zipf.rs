//! Exact Zipf(α) sampling over `n` ranks.
//!
//! The paper's central empirical observation (§II-C, Fig. 2) is that both
//! embedding access frequency and co-occurrence degree follow a power law.
//! The workload generator therefore draws item popularity from a Zipf
//! distribution: `P(rank = k) ∝ 1 / k^α`.
//!
//! Implementation: a precomputed cumulative table + binary search
//! (inverse-CDF). Exact, O(log n) per draw, O(n) memory — fine up to the
//! ~1M embeddings of the Sports dataset and fully deterministic, which
//! rejection samplers with floating-point envelopes are not across
//! platforms.

use super::rng::Rng;

/// An exact Zipf(α) sampler over ranks `0..n` (rank 0 is the hottest).
#[derive(Debug, Clone)]
pub struct Zipf {
    /// Cumulative probability for each rank; `cdf[n-1] == 1.0`.
    cdf: Vec<f64>,
    alpha: f64,
}

impl Zipf {
    /// Build a sampler over `n` ranks with exponent `alpha > 0`.
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0, "Zipf over zero ranks");
        assert!(alpha > 0.0, "Zipf exponent must be positive");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        // Guard against floating-point shortfall at the tail.
        *cdf.last_mut().unwrap() = 1.0;
        Self { cdf, alpha }
    }

    /// Number of ranks.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// True if the sampler has a single rank.
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// The configured exponent.
    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Draw a rank in `[0, n)`; rank 0 is the most popular.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        // partition_point returns the first rank whose cdf >= u.
        self.cdf.partition_point(|&c| c < u)
    }

    /// Probability mass of a given rank.
    pub fn pmf(&self, rank: usize) -> f64 {
        if rank == 0 {
            self.cdf[0]
        } else {
            self.cdf[rank] - self.cdf[rank - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_in_bounds() {
        let z = Zipf::new(1000, 1.1);
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(z.sample(&mut r) < 1000);
        }
    }

    #[test]
    fn pmf_sums_to_one() {
        let z = Zipf::new(500, 0.9);
        let total: f64 = (0..500).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rank0_is_hottest_and_matches_pmf() {
        let z = Zipf::new(100, 1.2);
        let mut r = Rng::new(5);
        let n = 200_000;
        let mut counts = vec![0usize; 100];
        for _ in 0..n {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[90]);
        let emp0 = counts[0] as f64 / n as f64;
        assert!((emp0 - z.pmf(0)).abs() < 0.01, "emp {emp0} vs {}", z.pmf(0));
    }

    #[test]
    fn empirical_follows_power_law_slope() {
        // log(freq) vs log(rank+1) should be roughly linear with slope -α.
        let alpha = 1.0;
        let z = Zipf::new(10_000, alpha);
        let mut r = Rng::new(42);
        let mut counts = vec![0usize; 10_000];
        for _ in 0..2_000_000 {
            counts[z.sample(&mut r)] += 1;
        }
        // Fit over well-populated head ranks.
        let pts: Vec<(f64, f64)> = (0..200)
            .filter(|&k| counts[k] > 0)
            .map(|k| (((k + 1) as f64).ln(), (counts[k] as f64).ln()))
            .collect();
        let n = pts.len() as f64;
        let sx: f64 = pts.iter().map(|p| p.0).sum();
        let sy: f64 = pts.iter().map(|p| p.1).sum();
        let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
        let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
        assert!(
            (slope + alpha).abs() < 0.1,
            "fitted slope {slope}, expected {}",
            -alpha
        );
    }
}
