//! Self-contained utility substrates.
//!
//! The reproduction environment is fully offline, so everything that would
//! normally come from a crate (`rand`, `clap`, `criterion`, `proptest`) is
//! implemented here from scratch:
//!
//! * [`accum`] — blocked 4-wide f32 accumulators for the serving-side
//!   reduce hot paths.
//! * [`rng`] — a `SplitMix64`-seeded `xoshiro256**` PRNG with the sampling
//!   helpers the workload generator needs.
//! * [`clock`] — injected time sources (wall + simulated) so the batcher
//!   and the open-loop load generator run on one nanosecond timeline.
//! * [`zipf`] — an exact inverse-CDF Zipf(α) sampler (the paper's power-law
//!   access distributions).
//! * [`cli`] — a small declarative command-line parser for the launcher.
//! * [`bench`] — a criterion-style measurement harness used by
//!   `rust/benches/*` (warm-up, iterations, mean/stddev/median reporting).
//! * [`fxhash`] — a fast multiplicative hasher for trusted integer keys
//!   (the graph build's hot path).
//! * [`par`] — deterministic scoped fork-join with fixed-order merge; the
//!   offline phase's data-parallel substrate (bit-identical results for
//!   any worker count).

pub mod accum;
pub mod bench;
pub mod cli;
pub mod clock;
pub mod fxhash;
pub mod par;
pub mod rng;
pub mod zipf;

pub use clock::{Clock, SimClock, WallClock};
pub use fxhash::{FxHashMap, FxHashSet};
pub use rng::Rng;
pub use zipf::Zipf;

/// Format a nanosecond quantity with an adaptive unit (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a picojoule quantity with an adaptive unit (pJ/nJ/µJ/mJ/J).
pub fn fmt_pj(pj: f64) -> String {
    if pj < 1e3 {
        format!("{pj:.1} pJ")
    } else if pj < 1e6 {
        format!("{:.2} nJ", pj / 1e3)
    } else if pj < 1e9 {
        format!("{:.2} µJ", pj / 1e6)
    } else if pj < 1e12 {
        format!("{:.2} mJ", pj / 1e9)
    } else {
        format!("{:.3} J", pj / 1e12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with(" s"));
    }

    #[test]
    fn fmt_pj_units() {
        assert!(fmt_pj(3.0).ends_with("pJ"));
        assert!(fmt_pj(3e3).ends_with("nJ"));
        assert!(fmt_pj(3e6).ends_with("µJ"));
        assert!(fmt_pj(3e9).ends_with("mJ"));
        assert!(fmt_pj(3e12).ends_with(" J"));
    }
}
